"""Quality-per-byte of calibrated per-layer policies vs uniform formats.

Runs the full ``repro.calib`` pipeline — collect activation/KV statistics
on synthetic batches, sweep the six MX element formats per layer, search
under byte budgets — and compares the auto-selected per-layer
``PolicyTable`` against every uniform single-format baseline on the two
axes that matter for a KV cache: mean round-trip SQNR (dB, over every
(role, layer) slot) and total KV bytes per token position (codes + E8M0
scales, bit-packed, summed over layers).

A policy *dominates* a baseline when it is at least as good on both axes
and strictly better on one.  The committed ``BENCH_calib.json`` asserts
(via ``validate_bench_calib.py``, run in CI) that each auto row dominates
at least one uniform baseline — the acceptance bar for the search being
worth its wall time.

Emits the harness CSV rows (name, calibration+search wall us, derived
quality@bytes) and the machine-readable ``BENCH_calib.json``
(schema ``bench_calib/v1``; unknown fields are schema drift and fail the
validator).
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path
from typing import List, Tuple

import numpy as np

DEFAULT_OUT = Path(__file__).resolve().parent.parent / "BENCH_calib.json"

ARCH = "chatglm3_6b"
ROLES = ("kv_key", "kv_value")


def _dominates(sq, by, base_sq, base_by) -> bool:
    """At least as good on both axes, strictly better on one."""
    return (sq >= base_sq and by <= base_by) and (sq > base_sq
                                                  or by < base_by)


def run(smoke: bool = True, out_path: Path = DEFAULT_OUT
        ) -> List[Tuple[str, float, str]]:
    import jax

    from repro.calib import (collect_model_stats, search_kv_policy,
                             sweep_role)
    from repro.calib.sweep import DEFAULT_CANDIDATES
    from repro.models import Model, load_reduced
    from repro.serve.paging import spec_side_nbytes

    n_layers = 4 if smoke else 8
    n_batches = 2 if smoke else 4
    batch, seq = (2, 32) if smoke else (4, 64)

    cfg = load_reduced(ARCH, n_layers=n_layers)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    batches = [rng.integers(0, cfg.vocab, size=(batch, seq)
                            ).astype(np.int32) for _ in range(n_batches)]

    t0 = time.perf_counter()
    stats = collect_model_stats(model, params, batches, roles=ROLES)
    calib_s = time.perf_counter() - t0

    cost = lambda spec: float(spec_side_nbytes(spec, cfg.n_kv_heads,
                                               cfg.hd))
    sweeps = {role: sweep_role(stats, role, cost) for role in ROLES}

    # ---- uniform single-format baselines (same sweep, same samples) ----
    baselines = []
    for spec in DEFAULT_CANDIDATES:
        picked = [next(s for s in scored if s.spec == spec)
                  for role in ROLES for scored in sweeps[role].values()]
        baselines.append({
            "name": f"uniform-{spec.fmt}",
            "quant": f"kv_key={spec},kv_value={spec}",
            "kv_bytes_per_token": float(sum(s.nbytes for s in picked)),
            "mean_sqnr_db": float(np.mean([s.sqnr_db for s in picked])),
        })

    # ---- budget-constrained auto selection ----
    by_fmt = {b["name"].split("-")[1]: b for b in baselines}
    budgets = {
        # all the bytes of an 8-bit uniform cache: the search is free to
        # spend them on whichever 8-bit format measures best per layer
        "auto-8bit": by_fmt["e4m3"]["kv_bytes_per_token"],
        # three quarters of that: forces per-layer / per-role mixing
        "auto-6bit": 0.75 * by_fmt["e4m3"]["kv_bytes_per_token"],
    }
    autos = []
    rows: List[Tuple[str, float, str]] = []
    for name, budget in budgets.items():
        t0 = time.perf_counter()
        res = search_kv_policy(stats, budget, cfg)
        search_s = time.perf_counter() - t0
        dom = [b["name"] for b in baselines
               if _dominates(res.mean_sqnr_db, res.total_nbytes,
                             b["mean_sqnr_db"], b["kv_bytes_per_token"])]
        autos.append({
            "name": name,
            "budget_bytes_per_token": float(budget),
            "kv_bytes_per_token": float(res.total_nbytes),
            "mean_sqnr_db": float(res.mean_sqnr_db),
            "n_layer_overrides": len(res.table.overrides),
            "table": res.table.to_json_dict(),
            "dominates": dom,
        })
        rows.append((f"calib_{name}", (calib_s + search_s) * 1e6,
                     f"{res.mean_sqnr_db:.1f}dB@"
                     f"{res.total_nbytes:.0f}B/tok"))
    for b in baselines:
        rows.append((f"calib_{b['name']}", calib_s * 1e6,
                     f"{b['mean_sqnr_db']:.1f}dB@"
                     f"{b['kv_bytes_per_token']:.0f}B/tok"))

    doc = {
        "schema": "bench_calib/v1",
        "arch": f"{ARCH}-reduced",
        "n_layers": int(n_layers),
        "calib_batches": int(n_batches),
        "calib_tokens": int(n_batches * batch * seq),
        "roles": list(ROLES),
        "calib_wall_s": float(calib_s),
        "baselines": baselines,
        "auto": autos,
    }
    out_path.write_text(json.dumps(doc, indent=2) + "\n")
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="toy sizes (CI bench-smoke job)")
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--out", type=Path, default=DEFAULT_OUT)
    args = ap.parse_args()
    for name, us, derived in run(smoke=not args.full, out_path=args.out):
        print(f"{name},{us:.1f},{derived}")
    print(f"# wrote {args.out}")


if __name__ == "__main__":
    main()
