"""Gradient-exchange compression: wire-byte accounting + end-to-end error of
the MX-compressed all-reduce (analytic bytes; numerical error measured via
the quantize path the collective uses)."""
from __future__ import annotations

from typing import List, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core import quantize_dequantize
from repro.core.grad_compress import exchanged_bytes

N_PARAMS = 10_000_000


def run() -> List[Tuple[str, float, str]]:
    rows = []
    for ndev in (16, 256, 512):
        base = exchanged_bytes(N_PARAMS, ndev, compressed=False)
        comp = exchanged_bytes(N_PARAMS, ndev, compressed=True)
        rows.append((f"allreduce_bytes_n{ndev}", 0.0,
                     f"{base/1e6:.1f}MB_f32;{comp/1e6:.1f}MB_mx;"
                     f"{base/comp:.2f}x"))
    rng = np.random.default_rng(3)
    g = rng.normal(size=1 << 20).astype(np.float32) * 1e-3
    for fmt in ("e4m3", "e5m2", "int8"):
        gq = np.asarray(quantize_dequantize(jnp.asarray(g), fmt=fmt,
                                            mode="ocp"))
        rel = np.abs(gq - g).max() / np.abs(g).max()
        cos = float(np.dot(g, gq) / (np.linalg.norm(g)
                                     * np.linalg.norm(gq)))
        rows.append((f"gradcompress_err_{fmt}", 0.0,
                     f"maxrel={rel:.4f};cos={cos:.6f}"))
    return rows


if __name__ == "__main__":
    for name, us, d in run():
        print(f"{name},{us:.1f},{d}")
