"""Table VIII analog: converter cost per MX format.

The paper reports LUTs + critical path per format on a Virtex UltraScale;
the TPU-native analog is conversion throughput of the (jitted) converter —
elements/second and us per 32x32-block call — plus the storage ratio the
format buys.  Both the pure-JAX path and the Pallas kernel (interpret mode,
correctness path on CPU) are timed; interpret-mode timings are NOT TPU
estimates and are labeled as such.
"""
from __future__ import annotations

import time
from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ALL_FORMATS, mx_quantize

N_ROWS, N_COLS = 256, 4096          # 1M elements = 32k paper-blocks
REPS = 20


def _time(fn, *args) -> float:
    fn(*args)[0].block_until_ready()      # compile + warm
    t0 = time.perf_counter()
    for _ in range(REPS):
        out = fn(*args)
        jax.tree_util.tree_leaves(out)[0].block_until_ready()
    return (time.perf_counter() - t0) / REPS * 1e6      # us


def run() -> List[Tuple[str, float, str]]:
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(N_ROWS, N_COLS)).astype(np.float32))
    rows = []
    for f in ALL_FORMATS:
        for mode in ("paper", "ocp"):
            fn = jax.jit(lambda t, fmt=f.name, m=mode:
                         (mx_quantize(t, fmt=fmt, mode=m).codes,))
            us = _time(fn, x)
            elems = N_ROWS * N_COLS
            gbps = elems * 4 / (us * 1e-6) / 1e9
            rows.append((f"convert_{f.name}_{mode}", us,
                         f"{gbps:.1f}GB/s_in;{f.bits_per_element():.2f}"
                         f"bits/elt"))
    return rows


if __name__ == "__main__":
    for name, us, d in run():
        print(f"{name},{us:.1f},{d}")
