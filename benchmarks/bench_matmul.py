"""MX-weight matmul vs f32 matmul: wall time (CPU; kernel correctness path)
and the weight-byte reduction that drives the TPU memory-roofline win."""
from __future__ import annotations

import time
from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import mx_quantize
from repro.core.formats import get_format
from repro.kernels.ref import mx_matmul_2d_ref

M, K, N = 256, 2048, 2048
REPS = 10


def _time(fn, *args) -> float:
    fn(*args).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(REPS):
        fn(*args).block_until_ready()
    return (time.perf_counter() - t0) / REPS * 1e6


def run() -> List[Tuple[str, float, str]]:
    rng = np.random.default_rng(2)
    a = jnp.asarray(rng.normal(size=(M, K)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(K, N)).astype(np.float32) * 0.05)
    rows = []
    base = _time(jax.jit(lambda x, y: x @ y), a, w)
    rows.append(("matmul_f32_base", base, f"{2*M*K*N/base/1e3:.1f}GFLOP/s"))
    for fmt in ("e4m3", "int8", "e2m1"):
        mx = mx_quantize(w, fmt=fmt, mode="ocp", axis=0)
        fn = jax.jit(lambda x, c, s, f=fmt:
                     mx_matmul_2d_ref(x, c, s, fmt=f, mode="ocp"))
        us = _time(fn, a, mx.codes, mx.scales)
        f = get_format(fmt)
        wr = 32 / f.bits_per_element()
        rows.append((f"matmul_mx_{fmt}", us,
                     f"weightbytes/4={wr:.2f}x_smaller_vs_f32"))
    return rows


if __name__ == "__main__":
    for name, us, d in run():
        print(f"{name},{us:.1f},{d}")
