"""Weight-resident MX matmul: fused dequant-in-VMEM kernel vs the
dequant-then-einsum fallback, per element format.

Measures, at a decode-like skinny-M shape, (a) wall time of the fused
Pallas kernel (codes stay bit-packed in memory; tiles unpacked + scaled
in VMEM) vs the fallback that materializes the f32 weight, (b) the weight
HBM bytes each format stores (codes + E8M0 scales, ``spec.storage_nbytes``
accounting), and (c) the max |fused - einsum| output difference.  Wall
times are CPU-container numbers (interpret mode, the correctness path);
the HBM byte column is what drives the TPU memory-roofline win.

Writes the ``bench_matmul/v1`` JSON artifact consumed by
``validate_bench_matmul.py`` (CI bench-smoke job):

    PYTHONPATH=src python benchmarks/bench_matmul.py --smoke
    PYTHONPATH=src python benchmarks/bench_matmul.py --full
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path
from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import MXWeight, QuantSpec
from repro.core.formats import ALL_FORMATS
from repro.kernels.ops import mx_matmul_resident

DEFAULT_OUT = Path(__file__).resolve().parent.parent / "BENCH_matmul.json"
FULL = dict(m=8, k=2048, n=2048, reps=20)
SMOKE = dict(m=4, k=256, n=128, reps=3)
MODE = "ocp"


def _time(fn, *args, reps: int) -> float:
    fn(*args).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(reps):
        fn(*args).block_until_ready()
    return (time.perf_counter() - t0) / reps * 1e6


def _impl_fn(mw: MXWeight, impl: str):
    # close over the static MXWeight metadata; jit over the array leaves
    def fn(a, codes, scales):
        w = MXWeight(codes, scales, mw.fmt, mw.mode, mw.block,
                     mw.packed, mw.k, mw.n)
        return mx_matmul_resident(a, w, impl)
    return jax.jit(fn)


def run(smoke: bool = True, out_path: Path = DEFAULT_OUT
        ) -> List[Tuple[str, float, str]]:
    sizes = SMOKE if smoke else FULL
    m, k, n, reps = sizes["m"], sizes["k"], sizes["n"], sizes["reps"]
    rng = np.random.default_rng(2)
    a = jnp.asarray(rng.normal(size=(m, k)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(k, n)).astype(np.float32) * 0.05)

    rows = []
    doc_rows = []
    base_us = _time(jax.jit(lambda x, y: x @ y), a, w, reps=reps)
    rows.append(("matmul_f32_base", base_us,
                 f"{2 * m * k * n / base_us / 1e3:.1f}GFLOP/s"))
    for f in ALL_FORMATS:
        fmt = f.name
        spec = QuantSpec(fmt, MODE, 32, True)
        mw = MXWeight.quantize(w, spec)
        fused = _impl_fn(mw, "fused")
        eins = _impl_fn(mw, "einsum")
        fused_us = _time(fused, a, mw.codes, mw.scales, reps=reps)
        einsum_us = _time(eins, a, mw.codes, mw.scales, reps=reps)
        diff = float(jnp.max(jnp.abs(fused(a, mw.codes, mw.scales)
                                     - eins(a, mw.codes, mw.scales))))
        speedup = einsum_us / fused_us
        bpw = mw.nbytes * 8 / (k * n)
        doc_rows.append({
            "spec": str(spec),
            "fmt": fmt,
            "mode": MODE,
            "packed": mw.packed,
            "weight_bytes": mw.nbytes,
            "bits_per_weight": bpw,
            "fused_us": fused_us,
            "einsum_us": einsum_us,
            "speedup": speedup,
            "max_abs_diff": diff,
        })
        rows.append((f"matmul_mx_{fmt}_fused", fused_us,
                     f"{speedup:.2f}x_vs_einsum_{bpw:.2f}bits/w"))
    doc = {
        "schema": "bench_matmul/v1",
        "m": m, "k": k, "n": n, "reps": reps,
        "dtype": "float32",
        "baseline_f32_us": base_us,
        "rows": doc_rows,
    }
    out_path.write_text(json.dumps(doc, indent=2) + "\n")
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="toy sizes (CI bench-smoke job)")
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--out", type=Path, default=DEFAULT_OUT)
    args = ap.parse_args()
    for name, us, derived in run(smoke=not args.full, out_path=args.out):
        print(f"{name},{us:.1f},{derived}")
    print(f"# wrote {args.out}")


if __name__ == "__main__":
    main()
