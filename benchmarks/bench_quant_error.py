"""Tables III-VII analog: quantization quality per format x rounding mode.

The paper's truth tables define the rounding behaviour; the ML-relevant
summary is SQNR (dB) per format under realistic tensor distributions, and
the paper-vs-OCP delta (ties-away + FTZ vs RNE + subnormals).
"""
from __future__ import annotations

from typing import List, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core import ALL_FORMATS, metrics, quantize_dequantize

N = 1 << 16


def _dists():
    rng = np.random.default_rng(1)
    return {
        "gauss": rng.normal(size=N).astype(np.float32),
        "uniform": rng.uniform(-1, 1, size=N).astype(np.float32),
        "heavy": (rng.standard_t(df=2, size=N) * 0.5).astype(np.float32),
        "weights": (rng.normal(size=N) * 0.02).astype(np.float32),
    }


def run() -> List[Tuple[str, float, str]]:
    rows = []
    for f in ALL_FORMATS:
        for mode in ("paper", "ocp"):
            sq = []
            for dname, x in _dists().items():
                xq = quantize_dequantize(jnp.asarray(x), fmt=f.name,
                                         mode=mode)
                sq.append(float(metrics.sqnr_db(jnp.asarray(x), xq)))
            rows.append((f"sqnr_{f.name}_{mode}", 0.0,
                         f"{np.mean(sq):.2f}dB_mean;"
                         f"{min(sq):.2f}dB_worst"))
    return rows


if __name__ == "__main__":
    for name, us, d in run():
        print(f"{name},{us:.1f},{d}")
