"""Roofline summary from the dry-run artifacts (see launch/dryrun.py)."""
from __future__ import annotations

from typing import List, Tuple

from repro.launch.report import load_artifacts


def run() -> List[Tuple[str, float, str]]:
    rows = []
    for a in load_artifacts():
        if a.get("status") != "ok" or "roofline" not in a:
            continue
        if a.get("mesh") != "single":
            continue
        t = a["roofline"]
        step_s = max(t["compute_s"], t["memory_s"], t["collective_s"])
        frac = t["compute_s"] / step_s if step_s else 0.0
        rows.append((
            f"roofline_{a['arch']}_{a['shape']}_{a['variant']}",
            step_s * 1e6,
            f"dom={t['dominant'].replace('_s','')};"
            f"compute_frac={frac:.3f};"
            f"modelHLO={a.get('model_vs_hlo_flops', 0) or 0:.2f}"))
    return rows


if __name__ == "__main__":
    for name, us, d in run():
        print(f"{name},{us:.1f},{d}")
