"""Continuous-batching serving throughput over the paged MX KV cache.

Serves the same request trace through ``ContinuousBatchingEngine`` under
several cache policies (fp32 pages, uniform MX INT8/E4M3 pages, and the
mixed per-role INT8-keys/E2M1-values policy) and batch mixes (uniform vs
mixed prompt lengths), and emits both the harness CSV rows and a
machine-readable ``BENCH_serve.json``:

    {"schema": "bench_serve/v3", "arch": ..., "page_size": ...,
     "max_slots": ..., "new_tokens": ..., "sync_every": ...,
     "configs": [{"cache": "mx-int8", "kv_fmt": "int8", "mode": "ocp",
                  "kv_key_fmt": "int8", "kv_value_fmt": "int8",
                  "quant": "kv_key=int8@32:ocp,kv_value=int8@32:ocp",
                  "mix": "mixed", "requests": N, "prompt_tokens": ...,
                  "generated_tokens": ..., "decode_steps": ...,
                  "wall_s": ..., "tokens_per_s": ...,
                  "prefill_s": ..., "decode_s": ..., "sync_s": ...,
                  "decode_tokens_per_s": ..., "sync_points": ...,
                  "kv_pool_bytes": ...,
                  "prefix_cache": false, "shared_prefix_tokens": 0,
                  "prefix_hit_rate": 0.0, "prefill_tokens_computed": ...,
                  "kv_pages_shared": 0, "kv_pages_mapped_peak": ...,
                  "kv_pool_bytes_effective": ...}, ...]}

Schema v3 (this PR) adds prefix-sharing accounting to every row plus a
``mix="prefix"`` sweep (mx-int8 cache): uniform-length prompts whose first
``shared_prefix_tokens`` tokens repeat a warmed system prompt, swept over
both the shared-prefix length and the request count.  On those rows the
engine serves one warmup request (populating the prefix trie), resets its
counters, then serves the trace — so ``prefill_tokens_computed`` is the
exact steady-state suffix work ``N * (L - c)`` and
``kv_pool_bytes_effective`` (peak *distinct* pages mapped by slot block
tables, times page bytes) shows the working-set dedupe.  The savings on
both metrics scale with the product of traffic and shared fraction —
superlinear in either axis alone — which
``validate_bench_serve.py`` re-derives and asserts from the committed
artifact.

Schema v4 (this PR) adds a top-level ``"traffic"`` section: the same
engine config served through the asyncio front end under an on/off bursty
arrival process at two intensities, once with ``admission="reject"``
(reject-on-full baseline) and once with ``admission="block"`` +
preempt-and-swap.  Each row carries p50/p99 TTFT and inter-token latency,
preemption/swap accounting, and the **per-request records** (arrival /
token / finish timestamps as millisecond offsets from trace start) the
validator re-derives every percentile and preemption count from.  The
headline claim — at equal pool bytes, preempt-and-swap sustains strictly
higher admitted-request throughput than reject-on-full at every swept
intensity — is asserted by the validator against the raw records.

Schema v5 (this PR) adds a top-level ``"faults"`` section: the same
engine config served through the asyncio front end under a **seeded
fault plan** (one request quarantined once and retried to success, one
poisoned on every attempt until ``RetriesExhausted``), with per-request
outcome counts — ``served`` (finished clean), ``retried`` (finished
after >= 1 retry), ``quarantined`` (permanently failed) — that must
partition ``submitted``, the plan's ``fired`` log, wall times for the
faulted vs fault-free run (their difference is the recovery cost), and a
``health_overhead`` block comparing best-of-5 decode-phase wall time
with the numeric-health guards on vs off at a steady-state serving
geometry (8 slots, 16-step fused windows).  The validator re-derives
``served + retried + quarantined == submitted``, the recovery wall time,
and the overhead fraction, and asserts overhead <= 5%.

Schema v6 (this PR) adds a top-level ``"observability"`` section: the
acceptance scenario — bursty on/off arrivals through the asyncio front
end with preempt-and-swap, a seeded ``prefill_nan:nth=1`` fault plan,
and a retry budget of 1 — served with the full observability stack on
(metrics registry + per-request trace spans + periodic MX-health
sampling).  The run writes the committed ``trace/v1`` smoke artifact
``BENCH_trace.jsonl`` (validated standalone by
``benchmarks/validate_trace.py``: nesting re-derived, span sums
bounded by request walls, unknown fields rejected), asserts in-process
that every request track closes exactly once, re-serves a fixed
workload traced vs untraced to prove **token identity**, and measures
the traced decode-phase overhead (best-of-5, 8 slots / 16-step fused
windows) against the <= 5% budget the validator enforces.

Wall times are CPU-container numbers (correctness path — Pallas interpret
mode when attn_impl=flash); the relative fp32-vs-MX pool bytes, the phase
split, and the prefix-sharing deltas are the portable signals.  Validate
with ``python benchmarks/validate_bench_serve.py``.
"""
from __future__ import annotations

import argparse
import asyncio
import json
import time
from pathlib import Path
from typing import List, Tuple

import numpy as np

DEFAULT_OUT = Path(__file__).resolve().parent.parent / "BENCH_serve.json"
DEFAULT_TRACE = Path(__file__).resolve().parent.parent \
    / "BENCH_trace.jsonl"

ARCH = "chatglm3_6b"
SYNC_EVERY = 8
# cache name -> QuantPolicy grammar (None = dense pages, compute dtype)
CACHE_CONFIGS = (
    ("fp32", None),
    ("mx-int8", "kv=int8@32:ocp"),
    ("mx-e4m3", "kv=e4m3@32:ocp"),
    ("mx-mixed", "kv_key=int8@32:ocp,kv_value=e2m1@32:ocp"),
)
MIXES = ("uniform", "mixed")
PREFIX_CACHE_NAME = "mx-int8"   # the prefix sweep rides this cache config
TRAFFIC_CACHE_NAME = "mx-int8"  # ... and so does the async traffic sweep


def _prompt_lens(mix: str, n_req: int, base: int,
                 rng: np.random.Generator) -> np.ndarray:
    if mix == "uniform":
        return np.full(n_req, base)
    return rng.integers(max(2, base // 3), 2 * base, size=n_req)


def _policy_fields(policy) -> dict:
    kk = policy.kv_key if policy else None
    kv = policy.kv_value if policy else None
    return {
        "kv_fmt": None if kk is None else (
            kk.fmt if kk.fmt == kv.fmt else f"{kk.fmt}+{kv.fmt}"),
        "mode": kk.mode if kk else None,
        "kv_key_fmt": kk.fmt if kk else None,
        "kv_value_fmt": kv.fmt if kv else None,
        "quant": str(policy) if policy else None,
    }


def _prefix_sweep(model, params, cfg, policy, *, max_slots, page_size,
                  new_tokens, sync_every, rows, configs):
    """mix="prefix" rows: uniform-length prompts sharing a warmed
    ``c``-token system prompt, swept over (c, N).  Single measured pass
    after warmup+reset: the counters are exact, not averaged."""
    import jax                                          # noqa: F401
    from repro.serve import ContinuousBatchingEngine, GenerationConfig

    L = 3 * page_size                                   # uniform prompt len
    n_base = 2 * max_slots
    sweep = [(0, n_base), (page_size, n_base), (2 * page_size, n_base),
             (page_size, 2 * n_base), (2 * page_size, 2 * n_base)]
    bucket = -(-L // page_size) * page_size
    for c, n_req in sweep:
        rng = np.random.default_rng(7)
        prefix = rng.integers(0, cfg.vocab, size=c).astype(np.int32)
        prompts = [np.concatenate(
            [prefix, rng.integers(0, cfg.vocab, size=L - c)
             .astype(np.int32)]) for _ in range(n_req)]
        eng = ContinuousBatchingEngine(
            model, params, max_slots=max_slots,
            page_size=page_size, max_len=L + new_tokens + 1,
            gen=GenerationConfig(max_new_tokens=new_tokens),
            sync_every=sync_every, prefill_bucket=bucket,
            prefix_cache=True)
        if c:
            eng.add_request(prefix, 1)                  # warm the trie
            eng.run()
            eng.reset_metrics()
        t0 = time.perf_counter()
        for p in prompts:
            eng.add_request(p, new_tokens)
        out = eng.run()
        dt = time.perf_counter() - t0
        toks = sum(len(v) for v in out.values())
        tps = toks / dt if dt > 0 else 0.0
        dec_toks = toks - len(out)
        name = f"serve_{PREFIX_CACHE_NAME}_prefix_c{c}_n{n_req}"
        rows.append((name, dt / toks * 1e6, f"{tps:.1f}tok/s"))
        configs.append({
            "cache": PREFIX_CACHE_NAME,
            **_policy_fields(policy),
            "mix": "prefix",
            "prefill_bucket": int(bucket),
            "requests": int(n_req),
            "prompt_tokens": int(n_req * L),
            "generated_tokens": int(toks),
            "decode_steps": int(eng.n_steps),
            "sync_points": int(eng.n_syncs),
            "wall_s": float(dt),
            "tokens_per_s": float(tps),
            "prefill_s": float(eng.phase["prefill"]),
            "decode_s": float(eng.phase["decode"]),
            "sync_s": float(eng.phase["sync"]),
            "decode_tokens_per_s": float(
                dec_toks / eng.phase["decode"])
            if eng.phase["decode"] > 0 else 0.0,
            "kv_pool_bytes": eng.kv_pool_nbytes,
            "prefix_cache": True,
            "shared_prefix_tokens": int(c),
            "prefix_hit_rate": float(eng.prefix_hit_rate),
            "prefill_tokens_computed": int(eng.prefill_tokens_computed),
            "kv_pages_shared": int(eng.peak_shared_pages),
            "kv_pages_mapped_peak": int(eng.peak_mapped_pages),
            "kv_pool_bytes_effective": int(eng.kv_pool_bytes_effective),
        })


def _percentile(samples, q):
    """Nearest-rank percentile — the single implementation lives in
    ``repro.obs.metrics``; the validator re-derives it dependency-free
    (the committed rows are checked against the raw records)."""
    from repro.obs.metrics import percentile
    return percentile(samples, q)


def _traffic_row(model, params, cfg, *, policy_name, arrival_spec,
                 arrivals, max_slots, page_size, max_len, num_pages,
                 sync_every, warm_prompts, new_tokens):
    """Serve one (intensity x SLO-policy) cell through the asyncio front
    end and report latency percentiles + per-request records.

    ``policy_name`` — "reject" (admission='reject', no preemption: the
    reject-on-full baseline) or "preempt" (admission='block' +
    preempt-and-swap).  Both run the *same* engine geometry — equal pool
    bytes — and the same deterministic arrival trace.

    Warmup requests (one per prefill shape) compile the jitted closures,
    then ``reset_metrics`` opens the measurement window — stale TTFT
    samples or hit rates from warmup cannot leak into the row.
    """
    from repro.serve import (AsyncServer, ContinuousBatchingEngine,
                             GenerationConfig, latency_summary, replay)

    eng = ContinuousBatchingEngine(
        model, params, max_slots=max_slots, page_size=page_size,
        max_len=max_len, num_pages=num_pages,
        gen=GenerationConfig(max_new_tokens=new_tokens),
        sync_every=sync_every, prefill_bucket=max_len,
        preempt=(policy_name == "preempt"))
    for p in warm_prompts:                  # compile prefill + windows
        eng.add_request(p, new_tokens)
    eng.run()
    eng.reset_metrics()

    async def go():
        async with AsyncServer(
                eng, admission=("reject" if policy_name == "reject"
                                else "block")) as srv:
            return await replay(srv, arrivals, speedup=1.0)

    t0 = time.perf_counter()
    _, rejected = asyncio.run(go())
    wall = time.perf_counter() - t0

    fin = eng.finished_in_window
    # per-request records: ms offsets from the first arrival; every
    # latency/percentile/preemption figure below re-derives from these
    # exact serialized values, so the validator's recomputation is
    # bit-for-bit
    records = []
    t_zero = min(r.arrival_t for r in fin) if fin else 0.0
    for r in sorted(fin, key=lambda r: r.arrival_t):
        records.append({
            "priority": int(r.priority),
            "deadline_ms": (None if r.deadline_s is None
                            else float(r.deadline_s * 1e3)),
            "prompt_tokens": int(r.prompt_len),
            "generated_tokens": int(len(r.out)),
            "arrival_ms": float((r.arrival_t - t_zero) * 1e3),
            "token_ms": [float((t - t_zero) * 1e3) for t in r.t_tokens],
            "finished_ms": float((r.t_finished - t_zero) * 1e3),
            "n_preemptions": int(r.n_preemptions),
        })
    ttft = [rec["token_ms"][0] - rec["arrival_ms"] for rec in records]
    itl = [b - a for rec in records
           for a, b in zip(rec["token_ms"], rec["token_ms"][1:])]
    met = [rec["token_ms"][0] - rec["arrival_ms"] <= rec["deadline_ms"]
           for rec in records if rec["deadline_ms"] is not None]
    toks = sum(rec["generated_tokens"] for rec in records)
    row = {
        "arrival": arrival_spec,
        "policy": policy_name,
        "n_arrivals": int(len(arrivals)),
        "n_served": int(len(records)),
        "n_rejected": int(len(rejected)),
        "wall_s": float(wall),
        "admitted_per_s": float(len(records) / wall if wall > 0 else 0.0),
        "generated_tokens": int(toks),
        "ttft_p50_ms": float(_percentile(ttft, 50)) if ttft else 0.0,
        "ttft_p99_ms": float(_percentile(ttft, 99)) if ttft else 0.0,
        "itl_p50_ms": float(_percentile(itl, 50)) if itl else 0.0,
        "itl_p99_ms": float(_percentile(itl, 99)) if itl else 0.0,
        "slo_attainment": float(sum(met) / len(met)) if met else 1.0,
        "n_preemptions": int(eng.n_preemptions),
        "n_restores": int(eng.n_restores),
        "swap_bytes_out": int(eng.swap_store.bytes_out),
        "swap_bytes_in": int(eng.swap_store.bytes_in),
        "kv_pool_bytes": int(eng.kv_pool_nbytes),
        "requests": records,
    }
    assert len(records) + len(rejected) == len(arrivals)
    return row, latency_summary(fin)


def _traffic_sweep(model, params, cfg, policy, *, max_slots, page_size,
                   new_tokens, sync_every, smoke, rows):
    """The (arrival intensity x SLO policy) grid: bursty on/off traffic
    mixing an interactive class (priority 0, TTFT deadline) with a batch
    class (priority 1, longer generations), served once with
    reject-on-full and once with preempt-and-swap at equal pool bytes."""
    from repro.serve import TrafficClass, on_off_times, synthesize

    # tighter than the throughput rows: 2 slots and long batch
    # generations, so a burst oversubscribes the engine and the SLO
    # policies actually diverge
    max_slots = 2
    ts_sync = 4
    gen_it = 12                         # interactive generation length
    gen_batch = (36, 49)                # batch class range
    n_req = 20 if smoke else 28
    lo, hi = 8, 24
    classes = [
        TrafficClass("interactive", (lo, hi), (gen_it, gen_it + 1),
                     priority=0, deadline_s=0.35, weight=1.5),
        TrafficClass("batch", (lo, hi), gen_batch, priority=1,
                     weight=1.0),
    ]
    max_len = (hi - 1) + gen_batch[1]
    num_pages = 1 + max_slots * _ceil_pages(max_len, page_size)
    warm_prompts = [np.arange(1, 1 + lo, dtype=np.int32),
                    np.arange(1, 1 + hi - 1, dtype=np.int32)]

    # bursts far over slot capacity; the off gap lets the backlog drain,
    # so the wall is span-dominated for both policies (claim robustness:
    # admitted/s then tracks served counts, not drain speed)
    intensities = [("onoff:60:0.15:2.0", 60.0, 0.15, 2.0),
                   ("onoff:120:0.15:2.0", 120.0, 0.15, 2.0)]
    out_rows = []
    for spec, rate, on_s, off_s in intensities:
        times = on_off_times(rate, n_req, on_s=on_s, off_s=off_s, seed=11)
        arrivals = synthesize(times, classes, cfg.vocab, seed=11)
        for policy_name in ("reject", "preempt"):
            row, summ = _traffic_row(
                model, params, cfg, policy_name=policy_name,
                arrival_spec=spec, arrivals=arrivals,
                max_slots=max_slots, page_size=page_size,
                max_len=max_len, num_pages=num_pages,
                sync_every=ts_sync, warm_prompts=warm_prompts,
                new_tokens=gen_it)
            name = f"serve_traffic_{spec.split(':')[1]}rps_{policy_name}"
            rows.append((name, row["ttft_p99_ms"] * 1e3,
                         f"{row['admitted_per_s']:.2f}req/s"))
            out_rows.append(row)
    return {
        "cache": TRAFFIC_CACHE_NAME,
        "quant": str(policy),
        "max_slots": int(max_slots),
        "page_size": int(page_size),
        "sync_every": int(ts_sync),
        "num_pages": int(num_pages),
        "new_tokens": int(gen_it),
        "classes": [{
            "name": c.name, "priority": c.priority,
            "deadline_ms": (None if c.deadline_s is None
                            else c.deadline_s * 1e3),
            "weight": c.weight,
        } for c in classes],
        "rows": out_rows,
    }


FAULT_PLAN = "prefill_nan:rid=2,prefill_nan:rid=4:always"
FAULT_SEED = 20260808


def _fault_sweep(model, params, cfg, policy, *, page_size, rows):
    """The v5 ``faults`` section: a seeded fault plan served through the
    asyncio front end with a retry budget of 1.

    The plan (rids count from 1 — rid 0 is the warmup request) poisons
    rid 2's prefill once (quarantined, retried, replayed clean: lands in
    ``retried``) and rid 4's on every attempt (``RetriesExhausted``:
    lands in ``quarantined``); the other requests are ``served``
    untouched.  The same workload runs fault-free first on an identical
    engine, so the wall-time difference is the recovery cost and the
    healthy token streams can be asserted identical.  A separate
    best-of-5 decode-phase comparison measures the numeric-health guards
    themselves at a steady-state geometry (8 slots, 16-step windows)
    where the per-window scale scan amortizes — the <= 5% budget the
    validator enforces."""
    from repro.serve import (AsyncServer, ContinuousBatchingEngine,
                             FaultPlan, GenerationConfig)

    n_req, plen, new_tokens = 6, 12, 8
    rng = np.random.default_rng(5)
    prompts = [rng.integers(1, cfg.vocab, size=plen).astype(np.int32)
               for _ in range(n_req)]

    def build(faults=None):
        eng = ContinuousBatchingEngine(
            model, params, max_slots=4, page_size=page_size,
            max_len=plen + new_tokens + 1,
            gen=GenerationConfig(max_new_tokens=new_tokens),
            sync_every=4, faults=faults)
        eng.add_request(np.arange(1, 1 + plen, dtype=np.int32),
                        new_tokens)                 # warmup takes rid 0
        eng.run()
        eng.reset_metrics()
        return eng

    async def go(eng):
        async with AsyncServer(eng, retries=1,
                               retry_backoff_s=0.01) as srv:
            streams = [await srv.submit(p, new_tokens) for p in prompts]
            res = await asyncio.gather(
                *(s.tokens() for s in streams), return_exceptions=True)
            return srv, streams, res

    t0 = time.perf_counter()
    _, _, clean = asyncio.run(go(build()))
    clean_wall = time.perf_counter() - t0

    plan = FaultPlan.parse(FAULT_PLAN, seed=FAULT_SEED)
    eng = build(faults=plan)
    t0 = time.perf_counter()
    srv, streams, res = asyncio.run(go(eng))
    wall = time.perf_counter() - t0

    served = retried = quarantined = 0
    for st, toks, want in zip(streams, res, clean):
        if isinstance(toks, Exception):
            quarantined += 1
            continue
        if st.request.n_retries:
            retried += 1
        else:
            served += 1
        # healthy/recovered streams replay the fault-free run exactly
        assert np.array_equal(toks, want), \
            f"rid {st.rid}: faulted tokens diverge from clean run"
    assert served + retried + quarantined == n_req

    def decode_best(health):
        dprompts = [rng.integers(1, cfg.vocab, size=plen
                                 ).astype(np.int32) for _ in range(8)]
        heng = ContinuousBatchingEngine(
            model, params, max_slots=8, page_size=page_size,
            max_len=plen + 48 + 1, sync_every=16,
            gen=GenerationConfig(max_new_tokens=48),
            health_checks=health)

        def serve():
            for p in dprompts:
                heng.add_request(p, 48)
            d0 = heng.phase["decode"]
            heng.run()
            return heng.phase["decode"] - d0

        serve()                                     # warm the closures
        return min(serve() for _ in range(5))

    dec_on, dec_off = decode_best(True), decode_best(False)
    overhead = dec_on / dec_off - 1.0
    rows.append(("serve_faults_recovery", wall * 1e6,
                 f"{quarantined}quar/{retried}retry"))
    rows.append(("serve_health_overhead", dec_on * 1e6,
                 f"{overhead * 100:.2f}%"))
    return {
        "plan": FAULT_PLAN,
        "seed": int(FAULT_SEED),
        "retry_budget": 1,
        "submitted": int(n_req),
        "served": int(served),
        "retried": int(retried),
        "quarantined": int(quarantined),
        "retry_attempts": int(srv.n_retried),
        "fired": [[s, r, int(n)] for s, r, n in plan.fired],
        "wall_s": float(wall),
        "clean_wall_s": float(clean_wall),
        "recovery_wall_s": float(max(0.0, wall - clean_wall)),
        "health_overhead": {
            "max_slots": 8,
            "sync_every": 16,
            "new_tokens": 48,
            "decode_s_on": float(dec_on),
            "decode_s_off": float(dec_off),
            "overhead_frac": float(overhead),
        },
    }


OBS_ARRIVAL = "onoff:40:0.15:1.0"
OBS_FAULT_PLAN = "prefill_nan:nth=1"
OBS_SEED = 20260808


def _obs_sweep(model, params, cfg, policy, *, page_size, rows,
               trace_out: Path):
    """The v6 ``observability`` section: the acceptance scenario served
    with the full observability stack on.

    One traced run — bursty on/off arrivals, preempt-and-swap, a seeded
    fault plan firing once (quarantine -> retry -> success), retry
    budget 1 — writes the committed ``trace/v1`` smoke artifact and is
    checked in-process for span-lifecycle health (every request track
    closes exactly one root ``request`` span; ``validate_nesting``
    raises otherwise).  A fixed synchronous workload then runs traced vs
    untraced to assert token identity, and a best-of-5 *interleaved*
    decode-phase comparison at the steady-state geometry (8 slots,
    16-step fused windows) measures what tracing costs where it must
    not cost: the decode phase reuses existing host-sync stamps, so the
    overhead the validator bounds at 5% is pure measurement noise —
    reps alternate traced/untraced so host drift cancels instead of
    masquerading as overhead."""
    from repro.obs import MetricsRegistry, Tracer, validate_nesting
    from repro.serve import (AsyncServer, ContinuousBatchingEngine,
                             FaultPlan, GenerationConfig, TrafficClass,
                             on_off_times, replay, synthesize)

    n_req, new_tokens = 8, 6
    lo, hi = 6, 14
    classes = [
        TrafficClass("interactive", (lo, hi),
                     (new_tokens, new_tokens + 1), priority=0,
                     deadline_s=0.5, weight=1.0),
        TrafficClass("batch", (lo, hi), (new_tokens, new_tokens + 1),
                     priority=1, weight=1.0),
    ]
    times = on_off_times(40.0, n_req, on_s=0.15, off_s=1.0, seed=13)
    arrivals = synthesize(times, classes, cfg.vocab, seed=13)
    max_len = (hi - 1) + new_tokens + 1

    tracer = Tracer(meta={"bench": "observability",
                          "arrival": OBS_ARRIVAL,
                          "plan": OBS_FAULT_PLAN, "seed": OBS_SEED,
                          "quant": str(policy), "retry": 1})
    eng = ContinuousBatchingEngine(
        model, params, max_slots=2, page_size=page_size,
        max_len=max_len, gen=GenerationConfig(max_new_tokens=new_tokens),
        sync_every=4, preempt=True,
        faults=FaultPlan.parse(OBS_FAULT_PLAN, seed=OBS_SEED),
        metrics=MetricsRegistry(), tracer=tracer, obs_interval=2)

    async def go():
        async with AsyncServer(eng, admission="block", retries=1,
                               retry_backoff_s=0.01) as srv:
            streams, rejected = await replay(srv, arrivals, speedup=1.0)
            return srv, streams, rejected

    t0 = time.perf_counter()
    srv, streams, rejected = asyncio.run(go())
    wall = time.perf_counter() - t0
    assert not rejected                     # block admission never drops
    eng.finalize_trace()
    roots = validate_nesting(tracer.events)  # raises on lifecycle bugs
    tracks = sorted(r for r in roots if r is not None)
    for rid in tracks:
        assert roots[rid] == ["request"], \
            f"rid {rid}: roots {roots[rid]} != one request span"
    status = {}
    for ev in tracer.events:
        if ev["ph"] == "E" and ev["name"] == "request":
            status[ev["rid"]] = (ev.get("args") or {}).get("status")
    finished = sum(1 for s in status.values() if s == "finished")
    failed = sum(1 for s in status.values() if s == "failed")
    tracer.write_jsonl(trace_out)

    def serve_once(traced):
        obs = dict(metrics=MetricsRegistry(), tracer=Tracer(),
                   obs_interval=1) if traced else {}
        e2 = ContinuousBatchingEngine(
            model, params, max_slots=2, page_size=page_size,
            max_len=max_len,
            gen=GenerationConfig(max_new_tokens=new_tokens),
            sync_every=4, **obs)
        rng = np.random.default_rng(17)
        for n in (7, 12, 9):
            e2.add_request(
                rng.integers(1, cfg.vocab, size=n).astype(np.int32),
                new_tokens)
        return e2.run()

    want, got = serve_once(False), serve_once(True)
    identical = sorted(want) == sorted(got) and all(
        np.array_equal(got[r], want[r]) for r in want)

    def decode_overhead():
        rng = np.random.default_rng(23)
        dprompts = [rng.integers(1, cfg.vocab, size=12
                                 ).astype(np.int32) for _ in range(8)]

        def mk(traced):
            obs = dict(metrics=MetricsRegistry(),
                       tracer=Tracer()) if traced else {}
            return ContinuousBatchingEngine(
                model, params, max_slots=8, page_size=page_size,
                max_len=12 + 48 + 1, sync_every=16,
                gen=GenerationConfig(max_new_tokens=48), **obs)

        def serve(heng):
            for p in dprompts:
                heng.add_request(p, 48)
            d0 = heng.phase["decode"]
            heng.run()
            return heng.phase["decode"] - d0

        on_e, off_e = mk(True), mk(False)
        serve(on_e), serve(off_e)               # warm the closures
        ons, offs = [], []
        for _ in range(5):      # interleaved reps so host drift (cache
            offs.append(serve(off_e))   # warm-up, frequency scaling)
            ons.append(serve(on_e))     # hits both sides equally
        return min(ons), min(offs)

    dec_on, dec_off = decode_overhead()
    overhead = dec_on / dec_off - 1.0
    rows.append(("serve_obs_trace", wall * 1e6,
                 f"{len(tracer.events)}ev/{len(tracks)}req"))
    rows.append(("serve_trace_overhead", dec_on * 1e6,
                 f"{overhead * 100:.2f}%"))
    return {
        "arrival": OBS_ARRIVAL,
        "plan": OBS_FAULT_PLAN,
        "seed": int(OBS_SEED),
        "retry_budget": 1,
        "submitted": int(len(arrivals)),
        "finished": int(finished),
        "failed": int(failed),
        "retried": int(srv.n_retried),
        "n_preemptions": int(eng.n_preemptions),
        "trace_file": trace_out.name,
        "trace_events": int(len(tracer.events)),
        "trace_tracks": int(len(tracks)),
        "token_identical": bool(identical),
        "trace_overhead": {
            "max_slots": 8,
            "sync_every": 16,
            "new_tokens": 48,
            "decode_s_on": float(dec_on),
            "decode_s_off": float(dec_off),
            "overhead_frac": float(overhead),
        },
    }


def _ceil_pages(tokens: int, page_size: int) -> int:
    return max(1, -(-tokens // page_size))


def run(smoke: bool = True, out_path: Path = DEFAULT_OUT,
        sync_every: int = SYNC_EVERY,
        trace_out: Path = DEFAULT_TRACE) -> List[Tuple[str, float, str]]:
    import jax

    from repro.models import Model, load_reduced
    from repro.models.config import QuantPolicy
    from repro.serve import ContinuousBatchingEngine, GenerationConfig

    # toy sizes: the CPU container measures the schedule, not the silicon
    max_slots = 4 if smoke else 8
    page_size = 8 if smoke else 16
    n_req = 8 if smoke else 24
    base_len = 10 if smoke else 48
    new_tokens = 6 if smoke else 24

    rows: List[Tuple[str, float, str]] = []
    configs = []
    for cache_name, policy_s in CACHE_CONFIGS:
        over = {}
        policy = None
        if policy_s is not None:
            policy = QuantPolicy.parse(policy_s)
            over["mx"] = policy
        cfg = load_reduced(ARCH, **over)
        model = Model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        for mix in MIXES:
            rng = np.random.default_rng(0)
            lens = _prompt_lens(mix, n_req, base_len, rng)
            max_len = int(lens.max()) + new_tokens + 1
            prompts = [rng.integers(0, cfg.vocab, size=int(n)
                                    ).astype(np.int32) for n in lens]

            # one prefill bucket sized to the trace's max prompt: every
            # admission wave prefills as a single padded batch (one trace
            # shape) instead of one bucket group per distinct length
            bucket = -(-int(lens.max()) // page_size) * page_size
            eng = ContinuousBatchingEngine(
                model, params, max_slots=max_slots,
                page_size=page_size, max_len=max_len,
                gen=GenerationConfig(max_new_tokens=new_tokens),
                sync_every=sync_every, prefill_bucket=bucket)

            def serve():
                for p in prompts:
                    eng.add_request(p, new_tokens)
                steps0, syncs0 = eng.n_steps, eng.n_syncs
                pt0 = eng.prefill_tokens_computed
                ph0 = dict(eng.phase)
                t0 = time.perf_counter()
                out = eng.run()
                dt = time.perf_counter() - t0
                ph = {k: eng.phase[k] - ph0[k] for k in ph0}
                return out, dt, eng.n_steps - steps0, \
                    eng.n_syncs - syncs0, ph, \
                    eng.prefill_tokens_computed - pt0

            serve()       # reusing the engine keeps its jitted closures
            # warm -> best of 5 steady-state repetitions (the container's
            # CPU wall clock is noisy at these ~10ms scales)
            out, dt, steps, syncs, ph, ptoks = min(
                (serve() for _ in range(5)), key=lambda r: r[1])
            toks = sum(len(v) for v in out.values())
            tps = toks / dt if dt > 0 else 0.0
            dec_toks = toks - len(out)      # prefill emits one per request
            name = f"serve_{cache_name}_{mix}"
            rows.append((name, dt / toks * 1e6, f"{tps:.1f}tok/s"))
            configs.append({
                "cache": cache_name,
                **_policy_fields(policy),
                "mix": mix,
                "prefill_bucket": int(bucket),
                "requests": int(n_req),
                "prompt_tokens": int(lens.sum()),
                "generated_tokens": int(toks),
                "decode_steps": int(steps),
                "sync_points": int(syncs),
                "wall_s": float(dt),
                "tokens_per_s": float(tps),
                "prefill_s": float(ph["prefill"]),
                "decode_s": float(ph["decode"]),
                "sync_s": float(ph["sync"]),
                "decode_tokens_per_s": float(
                    dec_toks / ph["decode"]) if ph["decode"] > 0 else 0.0,
                "kv_pool_bytes": eng.kv_pool_nbytes,
                "prefix_cache": False,
                "shared_prefix_tokens": 0,
                "prefix_hit_rate": 0.0,
                "prefill_tokens_computed": int(ptoks),
                "kv_pages_shared": int(eng.peak_shared_pages),
                "kv_pages_mapped_peak": int(eng.peak_mapped_pages),
                "kv_pool_bytes_effective": int(
                    eng.kv_pool_bytes_effective),
            })
        if cache_name == PREFIX_CACHE_NAME:
            _prefix_sweep(model, params, cfg, policy,
                          max_slots=max_slots, page_size=page_size,
                          new_tokens=new_tokens, sync_every=sync_every,
                          rows=rows, configs=configs)
        if cache_name == TRAFFIC_CACHE_NAME:
            traffic = _traffic_sweep(
                model, params, cfg, policy, max_slots=max_slots,
                page_size=page_size, new_tokens=new_tokens,
                sync_every=sync_every, smoke=smoke, rows=rows)
            faults = _fault_sweep(model, params, cfg, policy,
                                  page_size=page_size, rows=rows)
            obs = _obs_sweep(model, params, cfg, policy,
                             page_size=page_size, rows=rows,
                             trace_out=trace_out)

    doc = {
        "schema": "bench_serve/v6",
        "arch": f"{ARCH}-reduced",
        "page_size": int(page_size),
        "max_slots": int(max_slots),
        "new_tokens": int(new_tokens),
        "sync_every": int(sync_every),
        "configs": configs,
        "traffic": traffic,
        "faults": faults,
        "observability": obs,
    }
    out_path.write_text(json.dumps(doc, indent=2) + "\n")
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="toy sizes (CI bench-smoke job)")
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--sync-every", type=int, default=SYNC_EVERY)
    ap.add_argument("--out", type=Path, default=DEFAULT_OUT)
    ap.add_argument("--trace-out", type=Path, default=DEFAULT_TRACE,
                    help="trace/v1 JSONL smoke artifact "
                         "(validate_trace.py checks it)")
    args = ap.parse_args()
    for name, us, derived in run(smoke=not args.full, out_path=args.out,
                                 sync_every=args.sync_every,
                                 trace_out=args.trace_out):
        print(f"{name},{us:.1f},{derived}")
    print(f"# wrote {args.out} and {args.trace_out}")


if __name__ == "__main__":
    main()
