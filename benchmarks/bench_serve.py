"""Continuous-batching serving throughput over the paged MX KV cache.

Serves the same request trace through ``ContinuousBatchingEngine`` under
several cache policies (fp32 pages, uniform MX INT8/E4M3 pages, and the
mixed per-role INT8-keys/E2M1-values policy) and batch mixes (uniform vs
mixed prompt lengths), and emits both the harness CSV rows and a
machine-readable ``BENCH_serve.json``:

    {"schema": "bench_serve/v2", "arch": ..., "page_size": ...,
     "max_slots": ..., "new_tokens": ..., "sync_every": ...,
     "configs": [{"cache": "mx-int8", "kv_fmt": "int8", "mode": "ocp",
                  "kv_key_fmt": "int8", "kv_value_fmt": "int8",
                  "quant": "kv_key=int8@32:ocp,kv_value=int8@32:ocp",
                  "mix": "mixed", "requests": N, "prompt_tokens": ...,
                  "generated_tokens": ..., "decode_steps": ...,
                  "wall_s": ..., "tokens_per_s": ...,
                  "prefill_s": ..., "decode_s": ..., "sync_s": ...,
                  "decode_tokens_per_s": ..., "sync_points": ...,
                  "kv_pool_bytes": ...}, ...]}

Schema v2 (this PR) adds the per-phase wall-time split — prefill (bucket-
batched prompt processing + page scatter) vs decode (the fused
device-resident ``lax.scan`` windows) vs host-sync (scheduling, token
drains, page grants) — plus ``sync_every``/``sync_points`` so the fused
loop's dispatch amortization is visible in the artifact.

Wall times are CPU-container numbers (correctness path — Pallas interpret
mode when attn_impl=flash); the relative fp32-vs-MX pool bytes, the phase
split, and the schedule shape (decode steps vs request count) are the
portable signals.  Validate with
``python benchmarks/validate_bench_serve.py``.
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path
from typing import List, Tuple

import numpy as np

DEFAULT_OUT = Path(__file__).resolve().parent.parent / "BENCH_serve.json"

ARCH = "chatglm3_6b"
SYNC_EVERY = 8
# cache name -> QuantPolicy grammar (None = dense pages, compute dtype)
CACHE_CONFIGS = (
    ("fp32", None),
    ("mx-int8", "kv=int8@32:ocp"),
    ("mx-e4m3", "kv=e4m3@32:ocp"),
    ("mx-mixed", "kv_key=int8@32:ocp,kv_value=e2m1@32:ocp"),
)
MIXES = ("uniform", "mixed")


def _prompt_lens(mix: str, n_req: int, base: int,
                 rng: np.random.Generator) -> np.ndarray:
    if mix == "uniform":
        return np.full(n_req, base)
    return rng.integers(max(2, base // 3), 2 * base, size=n_req)


def run(smoke: bool = True, out_path: Path = DEFAULT_OUT,
        sync_every: int = SYNC_EVERY) -> List[Tuple[str, float, str]]:
    import jax

    from repro.models import Model, load_reduced
    from repro.models.config import QuantPolicy
    from repro.serve import ContinuousBatchingEngine, GenerationConfig

    # toy sizes: the CPU container measures the schedule, not the silicon
    max_slots = 4 if smoke else 8
    page_size = 8 if smoke else 16
    n_req = 8 if smoke else 24
    base_len = 10 if smoke else 48
    new_tokens = 6 if smoke else 24

    rows: List[Tuple[str, float, str]] = []
    configs = []
    for cache_name, policy_s in CACHE_CONFIGS:
        over = {}
        policy = None
        if policy_s is not None:
            policy = QuantPolicy.parse(policy_s)
            over["mx"] = policy
        cfg = load_reduced(ARCH, **over)
        model = Model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        for mix in MIXES:
            rng = np.random.default_rng(0)
            lens = _prompt_lens(mix, n_req, base_len, rng)
            max_len = int(lens.max()) + new_tokens + 1
            prompts = [rng.integers(0, cfg.vocab, size=int(n)
                                    ).astype(np.int32) for n in lens]

            # one prefill bucket sized to the trace's max prompt: every
            # admission wave prefills as a single padded batch (one trace
            # shape) instead of one bucket group per distinct length
            bucket = -(-int(lens.max()) // page_size) * page_size
            eng = ContinuousBatchingEngine(
                model, params, max_slots=max_slots,
                page_size=page_size, max_len=max_len,
                gen=GenerationConfig(max_new_tokens=new_tokens),
                sync_every=sync_every, prefill_bucket=bucket)

            def serve():
                for p in prompts:
                    eng.add_request(p, new_tokens)
                steps0, syncs0 = eng.n_steps, eng.n_syncs
                ph0 = dict(eng.phase)
                t0 = time.perf_counter()
                out = eng.run()
                dt = time.perf_counter() - t0
                ph = {k: eng.phase[k] - ph0[k] for k in ph0}
                return out, dt, eng.n_steps - steps0, \
                    eng.n_syncs - syncs0, ph

            serve()       # reusing the engine keeps its jitted closures
            # warm -> best of 5 steady-state repetitions (the container's
            # CPU wall clock is noisy at these ~10ms scales)
            out, dt, steps, syncs, ph = min(
                (serve() for _ in range(5)), key=lambda r: r[1])
            toks = sum(len(v) for v in out.values())
            tps = toks / dt
            dec_toks = toks - len(out)      # prefill emits one per request
            name = f"serve_{cache_name}_{mix}"
            rows.append((name, dt / toks * 1e6, f"{tps:.1f}tok/s"))
            kk = policy.kv_key if policy else None
            kv = policy.kv_value if policy else None
            configs.append({
                "cache": cache_name,
                "kv_fmt": None if kk is None else (
                    kk.fmt if kk.fmt == kv.fmt else f"{kk.fmt}+{kv.fmt}"),
                "mode": kk.mode if kk else None,
                "kv_key_fmt": kk.fmt if kk else None,
                "kv_value_fmt": kv.fmt if kv else None,
                "quant": str(policy) if policy else None,
                "mix": mix,
                "prefill_bucket": int(bucket),
                "requests": int(n_req),
                "prompt_tokens": int(lens.sum()),
                "generated_tokens": int(toks),
                "decode_steps": int(steps),
                "sync_points": int(syncs),
                "wall_s": float(dt),
                "tokens_per_s": float(tps),
                "prefill_s": float(ph["prefill"]),
                "decode_s": float(ph["decode"]),
                "sync_s": float(ph["sync"]),
                "decode_tokens_per_s": float(
                    dec_toks / ph["decode"]) if ph["decode"] > 0 else 0.0,
                "kv_pool_bytes": eng.kv_pool_nbytes,
            })

    doc = {
        "schema": "bench_serve/v2",
        "arch": f"{ARCH}-reduced",
        "page_size": int(page_size),
        "max_slots": int(max_slots),
        "new_tokens": int(new_tokens),
        "sync_every": int(sync_every),
        "configs": configs,
    }
    out_path.write_text(json.dumps(doc, indent=2) + "\n")
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="toy sizes (CI bench-smoke job)")
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--sync-every", type=int, default=SYNC_EVERY)
    ap.add_argument("--out", type=Path, default=DEFAULT_OUT)
    args = ap.parse_args()
    for name, us, derived in run(smoke=not args.full, out_path=args.out,
                                 sync_every=args.sync_every):
        print(f"{name},{us:.1f},{derived}")
    print(f"# wrote {args.out}")


if __name__ == "__main__":
    main()
