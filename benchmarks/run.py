"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  Wall times are CPU-container
numbers (correctness path); the TPU performance story lives in the roofline
artifacts (EXPERIMENTS.md §Roofline / §Perf).
"""
from __future__ import annotations

import sys


def main() -> None:
    from benchmarks import (bench_collective, bench_convert, bench_matmul,
                            bench_quant_error, bench_roofline)
    mods = {
        "convert (Table VIII analog)": bench_convert,
        "quant error (Tables III-VII analog)": bench_quant_error,
        "mx matmul": bench_matmul,
        "grad collective compression": bench_collective,
        "roofline (dry-run artifacts)": bench_roofline,
    }
    print("name,us_per_call,derived")
    for title, mod in mods.items():
        print(f"# --- {title} ---")
        try:
            for name, us, derived in mod.run():
                print(f"{name},{us:.1f},{derived}")
        except Exception as e:     # keep the harness green per-module
            print(f"# {title} FAILED: {type(e).__name__}: {e}",
                  file=sys.stderr)
            raise


if __name__ == "__main__":
    main()
