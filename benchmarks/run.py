"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  Wall times are CPU-container
numbers (correctness path); the TPU performance story lives in the roofline
artifacts (EXPERIMENTS.md §Roofline / §Perf).

Per-module failures don't abort the sweep: every module runs, the failures
are summarized at the end, and the harness exits nonzero if any failed.
"""
from __future__ import annotations

import sys
import traceback


def main() -> None:
    from benchmarks import (bench_calib, bench_collective, bench_convert,
                            bench_matmul, bench_quant_error, bench_roofline,
                            bench_serve)
    mods = {
        "convert (Table VIII analog)": bench_convert,
        "quant error (Tables III-VII analog)": bench_quant_error,
        "mx matmul": bench_matmul,
        "grad collective compression": bench_collective,
        "roofline (dry-run artifacts)": bench_roofline,
        "paged-KV continuous batching": bench_serve,
        "calibrated auto policies (quality/byte)": bench_calib,
    }
    print("name,us_per_call,derived")
    failures = []
    for title, mod in mods.items():
        print(f"# --- {title} ---")
        try:
            for name, us, derived in mod.run():
                print(f"{name},{us:.1f},{derived}")
        except Exception as e:
            print(f"# {title} FAILED: {type(e).__name__}: {e}",
                  file=sys.stderr)
            traceback.print_exc(file=sys.stderr)
            failures.append((title, f"{type(e).__name__}: {e}"))
    if failures:
        print(f"# {len(failures)}/{len(mods)} modules FAILED:",
              file=sys.stderr)
        for title, err in failures:
            print(f"#   {title}: {err}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
