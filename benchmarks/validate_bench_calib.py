"""Validate BENCH_calib.json against the bench_calib/v1 schema (dep-free).

    python benchmarks/validate_bench_calib.py [BENCH_calib.json]

Beyond field typing (unknown fields are schema drift and fail, like the
bench_serve v2 validator), this re-derives the quality-per-byte dominance
claims: every auto row must dominate at least one uniform baseline —
mean SQNR >= the baseline's at <= its KV bytes per token, strictly better
on one axis — and its claimed ``dominates`` list must match what the
row's own numbers imply.  Exits nonzero with a per-field report.
"""
from __future__ import annotations

import json
import sys
from pathlib import Path

SCHEMA = "bench_calib/v1"
TOP_FIELDS = {
    "schema": str,
    "arch": str,
    "n_layers": int,
    "calib_batches": int,
    "calib_tokens": int,
    "roles": list,
    "calib_wall_s": float,
    "baselines": list,
    "auto": list,
}
BASELINE_FIELDS = {
    "name": str,
    "quant": str,
    "kv_bytes_per_token": float,
    "mean_sqnr_db": float,
}
AUTO_FIELDS = {
    "name": str,
    "budget_bytes_per_token": float,
    "kv_bytes_per_token": float,
    "mean_sqnr_db": float,
    "n_layer_overrides": int,
    "table": dict,
    "dominates": list,
}
KNOWN_FMTS = ("int8", "e4m3", "e5m2", "e3m2", "e2m3", "e2m1")


def _fields(errs, obj, fields, where):
    for field, ty in fields.items():
        if field not in obj:
            errs.append(f"{where}: missing field {field!r}")
        elif ty is float and isinstance(obj[field], int) \
                and not isinstance(obj[field], bool):
            pass                               # ints are acceptable floats
        elif not isinstance(obj[field], ty) or isinstance(obj[field], bool):
            errs.append(f"{where}.{field}: expected {ty.__name__}, "
                        f"got {type(obj[field]).__name__}")
    for field in sorted(set(obj) - set(fields)):
        errs.append(f"{where}: unknown field {field!r} (schema drift — "
                    f"extend the validator in the same PR)")


def _dominates(sq, by, base_sq, base_by) -> bool:
    return (sq >= base_sq and by <= base_by) and (sq > base_sq
                                                  or by < base_by)


def check(doc) -> list:
    errs = []
    _fields(errs, doc, TOP_FIELDS, "top-level")
    if errs:
        return errs
    if doc["schema"] != SCHEMA:
        errs.append(f"schema: expected {SCHEMA!r}, got {doc['schema']!r}")
    if doc["n_layers"] < 2:
        errs.append("n_layers: per-layer selection needs >= 2 layers")
    if len(doc["baselines"]) < 2:
        errs.append("baselines: need >= 2 uniform-format baselines")
    if len(doc["auto"]) < 1:
        errs.append("auto: need >= 1 budget-constrained selection")
    for i, b in enumerate(doc["baselines"]):
        _fields(errs, b, BASELINE_FIELDS, f"baselines[{i}]")
    for i, a in enumerate(doc["auto"]):
        _fields(errs, a, AUTO_FIELDS, f"auto[{i}]")
    if errs:
        return errs
    for i, b in enumerate(doc["baselines"]):
        fmt = b["name"].removeprefix("uniform-")
        if fmt not in KNOWN_FMTS:
            errs.append(f"baselines[{i}].name: unknown format {fmt!r}")
        if b["kv_bytes_per_token"] <= 0:
            errs.append(f"baselines[{i}]: non-positive bytes")
    for i, a in enumerate(doc["auto"]):
        where = f"auto[{i}] ({a['name']})"
        if a["kv_bytes_per_token"] > a["budget_bytes_per_token"] * 1.0001:
            errs.append(f"{where}: selected bytes "
                        f"{a['kv_bytes_per_token']:.4g} exceed the budget "
                        f"{a['budget_bytes_per_token']:.4g}")
        if a["table"].get("schema") != "policy_table/v1":
            errs.append(f"{where}: table is not a policy_table/v1 doc")
        implied = [b["name"] for b in doc["baselines"]
                   if _dominates(a["mean_sqnr_db"],
                                 a["kv_bytes_per_token"],
                                 b["mean_sqnr_db"],
                                 b["kv_bytes_per_token"])]
        if sorted(a["dominates"]) != sorted(implied):
            errs.append(f"{where}: dominates claims {a['dominates']} but "
                        f"the row's numbers imply {implied}")
        if not implied:
            errs.append(
                f"{where}: dominates no uniform baseline — the "
                f"auto-selected policy must beat at least one "
                f"single-format cache on quality-per-byte")
    return errs


def main() -> None:
    path = Path(sys.argv[1]) if len(sys.argv) > 1 else \
        Path(__file__).resolve().parent.parent / "BENCH_calib.json"
    try:
        doc = json.loads(path.read_text())
    except (OSError, json.JSONDecodeError) as e:
        print(f"{path}: unreadable: {e}", file=sys.stderr)
        sys.exit(1)
    errs = check(doc)
    if errs:
        print(f"{path}: {len(errs)} schema violation(s):", file=sys.stderr)
        for e in errs:
            print(f"  - {e}", file=sys.stderr)
        sys.exit(1)
    autos = {a["name"]: a["dominates"] for a in doc["auto"]}
    print(f"{path}: valid {SCHEMA} ({len(doc['baselines'])} baselines; "
          f"dominance: {autos})")


if __name__ == "__main__":
    main()
