"""Validate BENCH_matmul.json against the bench_matmul/v1 schema (dep-free).

    python benchmarks/validate_bench_matmul.py [BENCH_matmul.json]

Beyond field typing (unknown fields are schema drift and fail, like the
other bench validators), this re-derives the claims the artifact makes:

  * weight_bytes must equal the ``spec.storage_nbytes`` accounting —
    packed code bytes for K rows (2/byte for 4-bit, 4/3-bytes for 6-bit,
    1/byte for 8-bit) plus one E8M0 scale byte per 32 rows, per column —
    recomputed here from the row's spec string alone;
  * bits_per_weight and speedup must match the row's own numbers;
  * every row must show fused >= dequant-einsum throughput (speedup >= 1)
    at equal results (max_abs_diff small relative to the f32 outputs);
  * all six element formats must be present exactly once.

Exits nonzero with a per-field report.
"""
from __future__ import annotations

import json
import sys
from pathlib import Path

SCHEMA = "bench_matmul/v1"
TOP_FIELDS = {
    "schema": str,
    "m": int,
    "k": int,
    "n": int,
    "reps": int,
    "dtype": str,
    "baseline_f32_us": float,
    "rows": list,
}
ROW_FIELDS = {
    "spec": str,
    "fmt": str,
    "mode": str,
    "packed": bool,
    "weight_bytes": int,
    "bits_per_weight": float,
    "fused_us": float,
    "einsum_us": float,
    "speedup": float,
    "max_abs_diff": float,
}
KNOWN_FMTS = ("e5m2", "e4m3", "e3m2", "e2m3", "e2m1", "int8")
CODE_BITS = {"e5m2": 8, "e4m3": 8, "int8": 8, "e3m2": 6, "e2m3": 6,
             "e2m1": 4}
BLOCK = 32
# |fused - einsum| tolerance: both paths accumulate f32 over K; tile-order
# differences stay within a few ulps of the output magnitude
DIFF_TOL = 1e-3


def _fields(errs, obj, fields, where):
    for field, ty in fields.items():
        if field not in obj:
            errs.append(f"{where}: missing field {field!r}")
        elif ty is float and isinstance(obj[field], int) \
                and not isinstance(obj[field], bool):
            pass                               # ints are acceptable floats
        elif ty is not bool and isinstance(obj[field], bool):
            errs.append(f"{where}.{field}: expected {ty.__name__}, got bool")
        elif not isinstance(obj[field], ty):
            errs.append(f"{where}.{field}: expected {ty.__name__}, "
                        f"got {type(obj[field]).__name__}")
    for field in sorted(set(obj) - set(fields)):
        errs.append(f"{where}: unknown field {field!r} (schema drift — "
                    f"extend the validator in the same PR)")


def _code_nbytes(fmt: str, k: int) -> int:
    bits = CODE_BITS[fmt]
    if bits <= 4:
        return (k + 1) // 2
    if bits <= 6:
        return (k + 3) // 4 * 3
    return k


def _weight_nbytes(fmt: str, packed: bool, k: int, n: int) -> int:
    kp = -(-k // BLOCK) * BLOCK
    code = _code_nbytes(fmt, kp) if packed else kp
    return code * n + (kp // BLOCK) * n


def check(doc) -> list:
    errs = []
    _fields(errs, doc, TOP_FIELDS, "top-level")
    if errs:
        return errs
    if doc["schema"] != SCHEMA:
        errs.append(f"schema: expected {SCHEMA!r}, got {doc['schema']!r}")
    for dim in ("m", "k", "n", "reps"):
        if doc[dim] < 1:
            errs.append(f"{dim}: must be >= 1, got {doc[dim]}")
    if doc["k"] % BLOCK:
        errs.append(f"k: must be a multiple of the scale block {BLOCK}, "
                    f"got {doc['k']}")
    k, n = doc["k"], doc["n"]
    seen = []
    for i, row in enumerate(doc["rows"]):
        where = f"rows[{i}]"
        _fields(errs, row, ROW_FIELDS, where)
        if set(ROW_FIELDS) - set(row):
            continue
        fmt = row["fmt"]
        if fmt not in KNOWN_FMTS:
            errs.append(f"{where}.fmt: unknown format {fmt!r}")
            continue
        seen.append(fmt)
        if not row["spec"].startswith(fmt):
            errs.append(f"{where}.spec: {row['spec']!r} does not name "
                        f"fmt {fmt!r}")
        if row["mode"] not in ("paper", "ocp"):
            errs.append(f"{where}.mode: {row['mode']!r}")
        if row["packed"] != (CODE_BITS[fmt] < 8):
            errs.append(f"{where}.packed: {row['packed']} but {fmt} has "
                        f"{CODE_BITS[fmt]}-bit codes (sub-byte formats "
                        f"pack, 8-bit formats store 1 code/byte)")
        want = _weight_nbytes(fmt, row["packed"], k, n)
        if row["weight_bytes"] != want:
            errs.append(f"{where}.weight_bytes: claimed "
                        f"{row['weight_bytes']}, storage_nbytes accounting "
                        f"gives {want} for {fmt} at K={k}, N={n}")
        bpw = row["weight_bytes"] * 8 / (k * n)
        if abs(row["bits_per_weight"] - bpw) > 1e-6:
            errs.append(f"{where}.bits_per_weight: claimed "
                        f"{row['bits_per_weight']}, re-derived {bpw}")
        if row["fused_us"] <= 0 or row["einsum_us"] <= 0:
            errs.append(f"{where}: non-positive wall time")
            continue
        speedup = row["einsum_us"] / row["fused_us"]
        if abs(row["speedup"] - speedup) > 1e-6 * max(1.0, speedup):
            errs.append(f"{where}.speedup: claimed {row['speedup']}, "
                        f"einsum_us/fused_us = {speedup}")
        if speedup < 1.0:
            errs.append(f"{where}: fused slower than dequant-einsum "
                        f"({row['fused_us']:.1f}us vs "
                        f"{row['einsum_us']:.1f}us) — the fused kernel "
                        f"must win at equal results")
        if row["max_abs_diff"] > DIFF_TOL:
            errs.append(f"{where}.max_abs_diff: {row['max_abs_diff']} "
                        f"exceeds {DIFF_TOL} — fused and einsum paths "
                        f"disagree beyond accumulation-order noise")
    if sorted(seen) != sorted(KNOWN_FMTS):
        errs.append(f"rows: expected all six formats exactly once, "
                    f"got {seen}")
    return errs


def main() -> None:
    path = Path(sys.argv[1]) if len(sys.argv) > 1 else Path(
        "BENCH_matmul.json")
    doc = json.loads(path.read_text())
    errs = check(doc)
    if errs:
        print(f"{path}: {len(errs)} error(s)")
        for e in errs:
            print(f"  - {e}")
        sys.exit(1)
    rows = doc["rows"]
    best = max(rows, key=lambda r: r["speedup"])
    print(f"{path}: OK — schema {SCHEMA}, {len(rows)} formats at "
          f"M={doc['m']} K={doc['k']} N={doc['n']}; fused/einsum speedup "
          f"{min(r['speedup'] for r in rows):.2f}-"
          f"{best['speedup']:.2f}x (best {best['fmt']})")


if __name__ == "__main__":
    main()
