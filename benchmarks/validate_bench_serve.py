"""Validate BENCH_serve.json against the bench_serve/v6 schema (dep-free).

    python benchmarks/validate_bench_serve.py [BENCH_serve.json]

Schema v6 adds the top-level ``"observability"`` section: the traced
acceptance scenario (bursty arrivals + preempt + seeded fault + retry)
plus the telemetry cost claims.  The validator re-derives the request
partition (``finished + failed == submitted``), requires the retry
path to have actually fired, requires **token identity** between the
traced and untraced serves, recomputes the traced decode-phase
overhead fraction from the committed on/off decode times, and asserts
it within the **5%** budget.  The trace artifact itself
(``BENCH_trace.jsonl``) is validated separately by
``benchmarks/validate_trace.py``.

Schema v5 added the top-level ``"faults"`` section: a seeded fault plan
served through the asyncio front end with a retry budget.  The validator
re-derives the request-outcome partition — ``served + retried +
quarantined == submitted`` — checks that the section actually exercised
recovery (at least one permanent quarantine, at least one successful
retry, a non-empty ``fired`` log naming known sites), recomputes the
recovery wall time from the committed faulted/clean walls, and asserts
the numeric-health guards cost at most **5%** of decode-phase wall time
(``overhead_frac`` re-derived from the committed on/off decode times).

Schema v4 added the top-level ``"traffic"`` section: bursty arrivals
served through the asyncio front end at two intensities under two SLO
policies (reject-on-full vs preempt-and-swap).  The validator does not
trust the section's summary numbers: every TTFT/ITL percentile, the SLO
attainment, the admitted-request throughput, and the preemption/restore
counts are **re-derived from the per-request records** (millisecond
timestamp offsets) and must match the row exactly.  The headline claim —
at equal pool bytes, preempt-and-swap sustains strictly higher
admitted-request throughput than reject-on-full at *every* swept
intensity — is asserted from those re-derived values.

Schema v3 added prefix-sharing accounting (``prefix_cache``,
``shared_prefix_tokens``, ``prefix_hit_rate``, ``prefill_tokens_computed``,
``kv_pages_shared``, ``kv_pages_mapped_peak``,
``kv_pool_bytes_effective``) and the ``mix="prefix"`` sweep rows.  Beyond
field/type checks the validator *re-derives* the sweep's counters from
first principles and asserts the artifact's two claims:

* exactness — on a warmed trie every admission matches the full shared
  prefix, so ``prefill_tokens_computed == N * (L - c)`` and the peak
  working set is ``c/ps`` shared pages (counted once) plus
  ``max_slots * (P - c/ps)`` private pages;
* superlinearity — the prefill-token savings ``N*L - computed == N*c``
  scale with the *product* of traffic and shared-prefix length, so along
  the sweep's (c, N) diagonal they grow strictly faster than along either
  axis alone (superadditivity), and effective pool bytes per prompt token
  drop on the diagonal below both single-axis rows.

Exits nonzero with a per-field report on mismatch — including *unknown*
fields, so the emitted artifact can't silently drift from the schema
documented in README §Prefix caching & copy-on-write.
"""
from __future__ import annotations

import json
import math
import sys
from pathlib import Path

SCHEMA = "bench_serve/v6"
TOP_FIELDS = {
    "schema": str,
    "arch": str,
    "page_size": int,
    "max_slots": int,
    "new_tokens": int,
    "sync_every": int,
    "configs": list,
    "traffic": dict,
    "faults": dict,
    "observability": dict,
}
CONFIG_FIELDS = {
    "cache": str,
    "kv_fmt": (str, type(None)),
    "mode": (str, type(None)),
    "kv_key_fmt": (str, type(None)),
    "kv_value_fmt": (str, type(None)),
    "quant": (str, type(None)),
    "mix": str,
    "prefill_bucket": int,
    "requests": int,
    "prompt_tokens": int,
    "generated_tokens": int,
    "decode_steps": int,
    "sync_points": int,
    "wall_s": float,
    "tokens_per_s": float,
    "prefill_s": float,
    "decode_s": float,
    "sync_s": float,
    "decode_tokens_per_s": float,
    "kv_pool_bytes": int,
    "prefix_cache": bool,
    "shared_prefix_tokens": int,
    "prefix_hit_rate": (float, int),
    "prefill_tokens_computed": int,
    "kv_pages_shared": int,
    "kv_pages_mapped_peak": int,
    "kv_pool_bytes_effective": int,
}
KNOWN_CACHES = {"fp32", "mx-int8", "mx-e4m3", "mx-e5m2", "mx-e3m2",
                "mx-e2m3", "mx-e2m1", "mx-mixed"}
KNOWN_MIXES = {"uniform", "mixed", "prefix"}
KNOWN_FMTS = {"int8", "e4m3", "e5m2", "e3m2", "e2m3", "e2m1", None}
TRAFFIC_FIELDS = {
    "cache": str,
    "quant": str,
    "max_slots": int,
    "page_size": int,
    "sync_every": int,
    "num_pages": int,
    "new_tokens": int,
    "classes": list,
    "rows": list,
}
CLASS_FIELDS = {
    "name": str,
    "priority": int,
    "deadline_ms": (float, int, type(None)),
    "weight": (float, int),
}
TRAFFIC_ROW_FIELDS = {
    "arrival": str,
    "policy": str,
    "n_arrivals": int,
    "n_served": int,
    "n_rejected": int,
    "wall_s": float,
    "admitted_per_s": float,
    "generated_tokens": int,
    "ttft_p50_ms": float,
    "ttft_p99_ms": float,
    "itl_p50_ms": float,
    "itl_p99_ms": float,
    "slo_attainment": (float, int),
    "n_preemptions": int,
    "n_restores": int,
    "swap_bytes_out": int,
    "swap_bytes_in": int,
    "kv_pool_bytes": int,
    "requests": list,
}
RECORD_FIELDS = {
    "priority": int,
    "deadline_ms": (float, int, type(None)),
    "prompt_tokens": int,
    "generated_tokens": int,
    "arrival_ms": (float, int),
    "token_ms": list,
    "finished_ms": (float, int),
    "n_preemptions": int,
}
KNOWN_POLICIES = {"reject", "preempt"}
FAULTS_FIELDS = {
    "plan": str,
    "seed": int,
    "retry_budget": int,
    "submitted": int,
    "served": int,
    "retried": int,
    "quarantined": int,
    "retry_attempts": int,
    "fired": list,
    "wall_s": float,
    "clean_wall_s": float,
    "recovery_wall_s": (float, int),
    "health_overhead": dict,
}
HEALTH_OVERHEAD_FIELDS = {
    "max_slots": int,
    "sync_every": int,
    "new_tokens": int,
    "decode_s_on": float,
    "decode_s_off": float,
    "overhead_frac": float,
}
KNOWN_FAULT_SITES = {"page_corrupt", "swap_corrupt", "prefill_nan",
                     "kernel_fail", "alloc_fail", "stall"}
HEALTH_OVERHEAD_BUDGET = 0.05
OBS_FIELDS = {
    "arrival": str,
    "plan": str,
    "seed": int,
    "retry_budget": int,
    "submitted": int,
    "finished": int,
    "failed": int,
    "retried": int,
    "n_preemptions": int,
    "trace_file": str,
    "trace_events": int,
    "trace_tracks": int,
    "token_identical": bool,
    "trace_overhead": dict,
}
TRACE_OVERHEAD_FIELDS = {
    "max_slots": int,
    "sync_every": int,
    "new_tokens": int,
    "decode_s_on": float,
    "decode_s_off": float,
    "overhead_frac": float,
}
TRACE_OVERHEAD_BUDGET = 0.05


def _pages(tokens: int, page_size: int) -> int:
    return max(1, -(-tokens // page_size))


def _percentile(samples, q):
    """Nearest-rank percentile — in lockstep with
    ``repro.obs.metrics.percentile`` (which the front end and the bench
    both use): the committed rows must reproduce bit-for-bit from the
    records.  Re-implemented here with the same boundary semantics —
    empty raises ValueError (never IndexError via ``s[-1]``), a single
    sample is every percentile of itself — because this validator must
    stay importable without the repro package."""
    if not samples:
        raise ValueError("percentile of an empty sample set")
    s = sorted(samples)
    rank = max(1, math.ceil((q / 100.0) * len(s)))
    return s[rank - 1]


def _check_prefix_row(i, c, doc, errs) -> None:
    """Re-derive the mix="prefix" counters from first principles."""
    ps = doc["page_size"]
    slots = doc["max_slots"]
    new = doc["new_tokens"]
    n = c["requests"]
    cpfx = c["shared_prefix_tokens"]
    if not c["prefix_cache"]:
        errs.append(f"configs[{i}]: prefix row without prefix_cache")
        return
    if c["prompt_tokens"] % n:
        errs.append(f"configs[{i}]: prefix rows are uniform-length "
                    f"(prompt_tokens % requests != 0)")
        return
    length = c["prompt_tokens"] // n
    if cpfx % ps or cpfx >= length:
        errs.append(f"configs[{i}]: shared_prefix_tokens must be a "
                    f"page multiple below the prompt length")
        return
    # exactness: warmed trie -> every admission matches the full shared
    # prefix and computes only the suffix
    want = n * (length - cpfx)
    if c["prefill_tokens_computed"] != want:
        errs.append(f"configs[{i}]: prefill_tokens_computed "
                    f"{c['prefill_tokens_computed']} != N*(L-c) = {want}")
    want_rate = 1.0 if cpfx else 0.0
    if abs(c["prefix_hit_rate"] - want_rate) > 1e-9:
        errs.append(f"configs[{i}]: prefix_hit_rate "
                    f"{c['prefix_hit_rate']} != {want_rate}")
    if c["kv_pages_shared"] != cpfx // ps:
        errs.append(f"configs[{i}]: kv_pages_shared "
                    f"{c['kv_pages_shared']} != c/ps = {cpfx // ps}")
    # peak working set: the shared chain counts once, each of the
    # max_slots concurrent slots adds only its private pages
    total_pages = _pages(length + new, ps)
    conc = min(n, slots)
    want_peak = cpfx // ps + conc * (total_pages - cpfx // ps) if cpfx \
        else conc * total_pages
    if c["kv_pages_mapped_peak"] != want_peak:
        errs.append(f"configs[{i}]: kv_pages_mapped_peak "
                    f"{c['kv_pages_mapped_peak']} != {want_peak}")
    num_pages = 1 + slots * _pages(length + new + 1, ps)
    want_eff = want_peak * (c["kv_pool_bytes"] // num_pages)
    if c["kv_pool_bytes_effective"] != want_eff:
        errs.append(f"configs[{i}]: kv_pool_bytes_effective "
                    f"{c['kv_pool_bytes_effective']} != peak * page "
                    f"bytes = {want_eff}")


def _check_prefix_claims(prows, errs) -> None:
    """The committed sweep must witness both headline claims."""
    if not prows:
        errs.append("configs: no mix='prefix' rows (schema v3 requires "
                    "the prefix-sharing sweep)")
        return
    key = {}
    for c in prows:
        if c["prompt_tokens"] % c["requests"] == 0:
            key[(c["shared_prefix_tokens"], c["requests"])] = c
    ns = sorted({n for _, n in key})
    cs = sorted({cc for cc, _ in key})
    if 0 not in cs or len([c for c in cs if c > 0]) < 2 or len(ns) < 2:
        errs.append("prefix sweep: need a c=0 baseline, >= 2 shared "
                    "lengths, and >= 2 request counts")
        return
    c1, c2 = [c for c in cs if c > 0][:2]
    n1, n2 = ns[0], ns[-1]
    # monotone drop in the shared length at fixed N
    for n in ns:
        col = [key[(cc, n)] for cc in cs if (cc, n) in key]
        for a, b in zip(col, col[1:]):
            if not (b["prefill_tokens_computed"]
                    < a["prefill_tokens_computed"]):
                errs.append(f"prefix sweep: prefill_tokens_computed not "
                            f"strictly decreasing in c at N={n}")
            if not (b["kv_pool_bytes_effective"]
                    < a["kv_pool_bytes_effective"]):
                errs.append(f"prefix sweep: kv_pool_bytes_effective not "
                            f"strictly decreasing in c at N={n}")

    def savings(cc, n):
        row = key[(cc, n)]
        return row["prompt_tokens"] - row["prefill_tokens_computed"]

    def eff_per_tok(cc, n):
        row = key[(cc, n)]
        return row["kv_pool_bytes_effective"] / row["prompt_tokens"]

    quad = [(cc, n) for cc in (c1, c2) for n in (n1, n2)]
    if all(q in key for q in quad):
        # prefill savings compound: the (c2, n2) diagonal beats the sum
        # of its single-axis neighbours (strict superadditivity), i.e.
        # savings scale with traffic x shared fraction
        lhs = savings(c2, n2) + savings(c1, n1)
        rhs = savings(c2, n1) + savings(c1, n2)
        if not lhs > rhs:
            errs.append(f"prefix sweep: prefill-token savings not "
                        f"superadditive over (c, N): {lhs} <= {rhs}")
        if not (savings(c2, n2) >= 2 * savings(c2, n1)
                and savings(c2, n2) >= 2 * savings(c1, n2)):
            errs.append("prefix sweep: diagonal savings fail to double "
                        "both single-axis rows")
        # effective pool bytes per prompt token drop superlinearly too:
        # the diagonal undercuts both single-axis neighbours
        if not (eff_per_tok(c2, n2) < eff_per_tok(c2, n1)
                and eff_per_tok(c2, n2) < eff_per_tok(c1, n2)):
            errs.append("prefix sweep: effective bytes per prompt token "
                        "on the diagonal fail to undercut both axes")
    else:
        errs.append("prefix sweep: incomplete (c, N) grid — need rows at "
                    f"({c1}|{c2}) x ({n1}|{n2})")


def _fields_ok(obj, spec, where, errs) -> bool:
    """Typed-field + unknown-field sweep shared by the traffic checks."""
    before = len(errs)
    for field, ty in spec.items():
        if field not in obj:
            errs.append(f"{where}: missing field {field!r}")
        elif not isinstance(obj[field], ty) \
                or (ty is int and isinstance(obj[field], bool)):
            tn = ty.__name__ if isinstance(ty, type) else \
                "/".join(t.__name__ for t in ty)
            errs.append(f"{where}.{field}: expected {tn}, "
                        f"got {type(obj[field]).__name__}")
    for field in sorted(set(obj) - set(spec)):
        errs.append(f"{where}: unknown field {field!r} (schema drift — "
                    f"extend the validator in the same PR)")
    return len(errs) == before


def _check_traffic_row(j, r, classes, errs) -> None:
    """Re-derive every summary figure of one (intensity x policy) row
    from its per-request records.  The bench computed the row *from* the
    exact serialized values, so the recomputation must match bit-for-bit
    (the 1e-9 slack only forgives float re-formatting, not drift)."""
    w = f"traffic.rows[{j}]"
    if r["policy"] not in KNOWN_POLICIES:
        errs.append(f"{w}.policy: unknown {r['policy']!r}")
        return
    recs = r["requests"]
    if r["n_served"] != len(recs):
        errs.append(f"{w}: n_served {r['n_served']} != "
                    f"len(requests) {len(recs)}")
        return
    if r["n_served"] + r["n_rejected"] != r["n_arrivals"]:
        errs.append(f"{w}: served + rejected != n_arrivals "
                    f"({r['n_served']} + {r['n_rejected']} != "
                    f"{r['n_arrivals']})")
    if r["wall_s"] <= 0 or r["kv_pool_bytes"] <= 0:
        errs.append(f"{w}: non-positive wall_s / kv_pool_bytes")
        return
    if not recs:
        errs.append(f"{w}: no served requests — the row measures nothing")
        return
    class_keys = {(c["priority"], c["deadline_ms"]) for c in classes}
    ok = True
    for k, rec in enumerate(recs):
        if not _fields_ok(rec, RECORD_FIELDS, f"{w}.requests[{k}]", errs):
            ok = False
            continue
        tms = rec["token_ms"]
        if len(tms) != rec["generated_tokens"] or not tms:
            errs.append(f"{w}.requests[{k}]: len(token_ms) != "
                        f"generated_tokens (or empty)")
            ok = False
            continue
        if rec["prompt_tokens"] <= 0:
            errs.append(f"{w}.requests[{k}]: non-positive prompt_tokens")
        if any(b < a for a, b in zip(tms, tms[1:])):
            errs.append(f"{w}.requests[{k}]: token_ms not monotone")
        if not rec["arrival_ms"] <= tms[0]:
            errs.append(f"{w}.requests[{k}]: first token before arrival")
        if not tms[-1] <= rec["finished_ms"]:
            errs.append(f"{w}.requests[{k}]: finished before last token")
        if rec["arrival_ms"] < 0:
            errs.append(f"{w}.requests[{k}]: negative arrival_ms "
                        f"(offsets are from the first arrival)")
        if (rec["priority"], rec["deadline_ms"]) not in class_keys:
            errs.append(f"{w}.requests[{k}]: (priority, deadline_ms) "
                        f"matches no declared traffic class")
        if rec["n_preemptions"] < 0 or (
                r["policy"] == "reject" and rec["n_preemptions"]):
            errs.append(f"{w}.requests[{k}]: preemptions on a "
                        f"reject-policy record")
    if not ok:
        return
    if min(rec["arrival_ms"] for rec in recs) != 0.0:
        errs.append(f"{w}: offsets not zeroed on the first arrival")
    # latency percentiles, SLO attainment, throughput: recompute from
    # the records with the very formulas the bench used
    ttft = [rec["token_ms"][0] - rec["arrival_ms"] for rec in recs]
    itl = [b - a for rec in recs
           for a, b in zip(rec["token_ms"], rec["token_ms"][1:])]
    met = [rec["token_ms"][0] - rec["arrival_ms"] <= rec["deadline_ms"]
           for rec in recs if rec["deadline_ms"] is not None]
    want = {
        "ttft_p50_ms": _percentile(ttft, 50) if ttft else 0.0,
        "ttft_p99_ms": _percentile(ttft, 99) if ttft else 0.0,
        "itl_p50_ms": _percentile(itl, 50) if itl else 0.0,
        "itl_p99_ms": _percentile(itl, 99) if itl else 0.0,
        "slo_attainment": sum(met) / len(met) if met else 1.0,
        "admitted_per_s": r["n_served"] / r["wall_s"],
    }
    for field, val in want.items():
        if abs(r[field] - val) > 1e-9 * max(1.0, abs(val)):
            errs.append(f"{w}.{field}: {r[field]} does not re-derive "
                        f"from the records (want {val})")
    if not 0.0 <= r["slo_attainment"] <= 1.0:
        errs.append(f"{w}: slo_attainment outside [0, 1]")
    if r["generated_tokens"] != sum(rec["generated_tokens"]
                                    for rec in recs):
        errs.append(f"{w}: generated_tokens != sum over records")
    # preemption accounting: the row counters are sums of what the
    # records witnessed, and every swapped-out page came back
    npre = sum(rec["n_preemptions"] for rec in recs)
    if r["n_preemptions"] != npre:
        errs.append(f"{w}: n_preemptions {r['n_preemptions']} != "
                    f"sum over records {npre}")
    if r["n_restores"] != r["n_preemptions"]:
        errs.append(f"{w}: n_restores != n_preemptions (a preempted "
                    f"request never resumed)")
    if r["swap_bytes_in"] != r["swap_bytes_out"]:
        errs.append(f"{w}: swap_bytes_in != swap_bytes_out")
    if (r["swap_bytes_out"] > 0) != (r["n_preemptions"] > 0):
        errs.append(f"{w}: swap bytes inconsistent with preemption count")
    if r["policy"] == "reject":
        if r["n_preemptions"] or r["n_restores"] or r["swap_bytes_out"]:
            errs.append(f"{w}: reject row carries preempt/swap state")
    else:
        if r["n_rejected"]:
            errs.append(f"{w}: preempt row rejected requests (block "
                        f"admission never drops)")


def _check_traffic(t, errs) -> None:
    """The v4 traffic section: bursty arrivals under two SLO policies at
    equal pool bytes, plus the headline preempt-vs-reject claim."""
    if not _fields_ok(t, TRAFFIC_FIELDS, "traffic", errs):
        return
    if t["cache"] not in KNOWN_CACHES:
        errs.append(f"traffic.cache: unknown {t['cache']!r}")
    for f in ("max_slots", "page_size", "sync_every", "num_pages",
              "new_tokens"):
        if t[f] < 1:
            errs.append(f"traffic.{f}: must be >= 1, got {t[f]}")
    classes = t["classes"]
    ok = all(_fields_ok(c, CLASS_FIELDS, f"traffic.classes[{i}]", errs)
             for i, c in enumerate(classes))
    if not ok:
        return
    if len(classes) < 2 \
            or not any(c["deadline_ms"] is not None and c["priority"] == 0
                       for c in classes) \
            or not any(c["deadline_ms"] is None and c["priority"] > 0
                       for c in classes):
        errs.append("traffic.classes: need an interactive class "
                    "(priority 0, TTFT deadline) and a lower-importance "
                    "batch class (no deadline)")
    if any(c["weight"] <= 0 for c in classes):
        errs.append("traffic.classes: non-positive weight")
    before = len(errs)
    for j, r in enumerate(t["rows"]):
        if _fields_ok(r, TRAFFIC_ROW_FIELDS, f"traffic.rows[{j}]", errs):
            _check_traffic_row(j, r, classes, errs)
    if len(errs) != before:
        return
    # the claim: at every swept intensity and equal pool bytes,
    # preempt-and-swap admits strictly more requests per second than
    # reject-on-full — and the sweep actually exercised both mechanisms
    grid = {}
    for j, r in enumerate(t["rows"]):
        if (r["arrival"], r["policy"]) in grid:
            errs.append(f"traffic.rows[{j}]: duplicate "
                        f"(arrival, policy) cell")
            return
        grid[(r["arrival"], r["policy"])] = r
    arrivals = sorted({a for a, _ in grid})
    if len(arrivals) < 2:
        errs.append("traffic.rows: need >= 2 arrival intensities")
        return
    pools = {r["kv_pool_bytes"] for r in t["rows"]}
    if len(pools) != 1:
        errs.append(f"traffic.rows: unequal kv_pool_bytes across the "
                    f"grid {sorted(pools)} — the comparison is void")
    for a in arrivals:
        rej, pre = grid.get((a, "reject")), grid.get((a, "preempt"))
        if rej is None or pre is None:
            errs.append(f"traffic.rows: intensity {a!r} missing a "
                        f"reject/preempt cell")
            continue
        if not pre["admitted_per_s"] > rej["admitted_per_s"]:
            errs.append(f"traffic claim: at {a!r} preempt admitted/s "
                        f"{pre['admitted_per_s']:.3f} fails to beat "
                        f"reject {rej['admitted_per_s']:.3f}")
    if not sum(r["n_preemptions"] for r in t["rows"]) > 0:
        errs.append("traffic claim: no preemption anywhere in the sweep "
                    "— the preempt rows never exercised the mechanism")
    if not sum(r["n_rejected"] for r in t["rows"]
               if r["policy"] == "reject") > 0:
        errs.append("traffic claim: the reject baseline never dropped a "
                    "request — the comparison is vacuous")


def _check_faults(f, errs) -> None:
    """The v5 faults section: re-derive the outcome partition, the
    recovery wall time, and the health-guard overhead budget."""
    if not _fields_ok(f, FAULTS_FIELDS, "faults", errs):
        return
    if f["submitted"] < 3:
        errs.append("faults.submitted: need >= 3 requests so served, "
                    "retried, and quarantined can all be witnessed")
    # the headline re-derivation: outcomes partition the submissions
    total = f["served"] + f["retried"] + f["quarantined"]
    if total != f["submitted"]:
        errs.append(f"faults: served + retried + quarantined = {total} "
                    f"!= submitted {f['submitted']}")
    if any(f[k] < 0 for k in ("served", "retried", "quarantined",
                              "retry_attempts", "retry_budget")):
        errs.append("faults: negative outcome count")
    # the section must actually exercise recovery, not just report zeros
    if f["quarantined"] < 1:
        errs.append("faults: no permanent quarantine — the exhaustion "
                    "path was never exercised")
    if f["retried"] < 1:
        errs.append("faults: no successful retry — the recovery path "
                    "was never exercised")
    if f["retry_attempts"] < f["retried"]:
        errs.append(f"faults: retry_attempts {f['retry_attempts']} < "
                    f"requests that finished via retry {f['retried']}")
    if not f["fired"]:
        errs.append("faults: empty fired log — the plan never fired")
    for k, rec in enumerate(f["fired"]):
        if (not isinstance(rec, list) or len(rec) != 3
                or rec[0] not in KNOWN_FAULT_SITES
                or not isinstance(rec[2], int)
                or not (rec[1] is None or isinstance(rec[1], int))):
            errs.append(f"faults.fired[{k}]: expected "
                        f"[site, rid|null, count], got {rec!r}")
    if f["wall_s"] <= 0 or f["clean_wall_s"] <= 0:
        errs.append("faults: non-positive wall times")
        return
    want_rec = max(0.0, f["wall_s"] - f["clean_wall_s"])
    if abs(f["recovery_wall_s"] - want_rec) > 1e-9 * max(1.0, want_rec):
        errs.append(f"faults.recovery_wall_s: {f['recovery_wall_s']} "
                    f"does not re-derive from wall_s - clean_wall_s "
                    f"(want {want_rec})")
    h = f["health_overhead"]
    if not _fields_ok(h, HEALTH_OVERHEAD_FIELDS, "faults.health_overhead",
                      errs):
        return
    if h["decode_s_on"] <= 0 or h["decode_s_off"] <= 0:
        errs.append("faults.health_overhead: non-positive decode times")
        return
    want_frac = h["decode_s_on"] / h["decode_s_off"] - 1.0
    if abs(h["overhead_frac"] - want_frac) > 1e-9 * max(1.0,
                                                        abs(want_frac)):
        errs.append(f"faults.health_overhead.overhead_frac: "
                    f"{h['overhead_frac']} does not re-derive from the "
                    f"decode times (want {want_frac})")
    if h["overhead_frac"] > HEALTH_OVERHEAD_BUDGET:
        errs.append(f"faults claim: health-guard overhead "
                    f"{h['overhead_frac']:.4f} exceeds the "
                    f"{HEALTH_OVERHEAD_BUDGET:.0%} decode-phase budget")


def _check_obs(o, errs) -> None:
    """The v6 observability section: re-derive the request partition,
    require the telemetry-cost claims, and sanity-check the trace
    artifact pointers (validate_trace.py checks the artifact itself)."""
    if not _fields_ok(o, OBS_FIELDS, "observability", errs):
        return
    if o["finished"] + o["failed"] != o["submitted"]:
        errs.append(f"observability: finished + failed = "
                    f"{o['finished'] + o['failed']} != submitted "
                    f"{o['submitted']}")
    if o["submitted"] < 3:
        errs.append("observability.submitted: need >= 3 requests for a "
                    "meaningful trace")
    if o["retried"] < 1:
        errs.append("observability: the seeded fault plan never drove a "
                    "retry — the quarantine/retry spans are unwitnessed")
    if o["retry_budget"] < 1 or o["seed"] < 0 or o["n_preemptions"] < 0:
        errs.append("observability: negative/zero budget, seed, or "
                    "preemption count")
    if not o["trace_file"].endswith(".jsonl"):
        errs.append(f"observability.trace_file: {o['trace_file']!r} is "
                    f"not a JSONL artifact")
    if o["trace_events"] <= 0:
        errs.append("observability.trace_events: empty trace")
    # every submitted request owns exactly one trace track (retries and
    # preemptions reuse the rid, so the counts match exactly)
    if o["trace_tracks"] != o["submitted"]:
        errs.append(f"observability: trace_tracks {o['trace_tracks']} "
                    f"!= submitted {o['submitted']}")
    if o["token_identical"] is not True:
        errs.append("observability claim: tracing+metrics perturbed the "
                    "token streams (token_identical is false)")
    t = o["trace_overhead"]
    if not _fields_ok(t, TRACE_OVERHEAD_FIELDS,
                      "observability.trace_overhead", errs):
        return
    if t["decode_s_on"] <= 0 or t["decode_s_off"] <= 0:
        errs.append("observability.trace_overhead: non-positive decode "
                    "times")
        return
    want_frac = t["decode_s_on"] / t["decode_s_off"] - 1.0
    if abs(t["overhead_frac"] - want_frac) > 1e-9 * max(1.0,
                                                        abs(want_frac)):
        errs.append(f"observability.trace_overhead.overhead_frac: "
                    f"{t['overhead_frac']} does not re-derive from the "
                    f"decode times (want {want_frac})")
    if t["overhead_frac"] > TRACE_OVERHEAD_BUDGET:
        errs.append(f"observability claim: traced decode-phase overhead "
                    f"{t['overhead_frac']:.4f} exceeds the "
                    f"{TRACE_OVERHEAD_BUDGET:.0%} budget")


def check(doc) -> list:
    errs = []
    for field, ty in TOP_FIELDS.items():
        if field not in doc:
            errs.append(f"missing top-level field {field!r}")
        elif not isinstance(doc[field], ty):
            errs.append(f"{field!r}: expected {ty.__name__}, "
                        f"got {type(doc[field]).__name__}")
    for field in sorted(set(doc) - set(TOP_FIELDS)):
        errs.append(f"unknown top-level field {field!r} (schema drift — "
                    f"extend the validator in the same PR)")
    if errs:
        return errs
    if doc["schema"] != SCHEMA:
        errs.append(f"schema: expected {SCHEMA!r}, got {doc['schema']!r}")
    if doc["sync_every"] < 1:
        errs.append(f"sync_every: must be >= 1, got {doc['sync_every']}")
    if len(doc["configs"]) < 2:
        errs.append("configs: need >= 2 cache configurations")
    for i, c in enumerate(doc["configs"]):
        before = len(errs)
        for field, ty in CONFIG_FIELDS.items():
            if field not in c:
                errs.append(f"configs[{i}]: missing field {field!r}")
            elif not isinstance(c[field], ty) \
                    or (ty is int and isinstance(c[field], bool)):
                tn = ty.__name__ if isinstance(ty, type) else \
                    "/".join(t.__name__ for t in ty)
                errs.append(f"configs[{i}].{field}: expected {tn}, "
                            f"got {type(c[field]).__name__}")
        for field in sorted(set(c) - set(CONFIG_FIELDS)):
            errs.append(f"configs[{i}]: unknown field {field!r} (schema "
                        f"drift — extend the validator in the same PR)")
        if len(errs) == before:          # this config's fields are sound
            if c["cache"] not in KNOWN_CACHES:
                errs.append(f"configs[{i}].cache: unknown {c['cache']!r}")
            if c["mix"] not in KNOWN_MIXES:
                errs.append(f"configs[{i}].mix: unknown {c['mix']!r}")
            for role in ("kv_key_fmt", "kv_value_fmt"):
                if c[role] not in KNOWN_FMTS:
                    errs.append(f"configs[{i}].{role}: unknown "
                                f"{c[role]!r}")
            if (c["kv_key_fmt"] is None) != (c["kv_value_fmt"] is None):
                errs.append(f"configs[{i}]: kv_key_fmt/kv_value_fmt must "
                            f"be set together")
            if c["cache"] == "mx-mixed" \
                    and c["kv_key_fmt"] == c["kv_value_fmt"]:
                errs.append(f"configs[{i}]: mx-mixed row must carry "
                            f"distinct key/value formats")
            if c["tokens_per_s"] <= 0 or c["wall_s"] <= 0:
                errs.append(f"configs[{i}]: non-positive throughput")
            if c["generated_tokens"] <= 0 or c["kv_pool_bytes"] <= 0:
                errs.append(f"configs[{i}]: non-positive token/byte counts")
            if c["sync_points"] <= 0:
                errs.append(f"configs[{i}]: non-positive sync_points")
            if c["decode_steps"] < c["sync_points"]:
                errs.append(f"configs[{i}]: decode_steps < sync_points "
                            f"(each fused window runs >= 1 device step)")
            for ph in ("prefill_s", "decode_s", "sync_s"):
                if c[ph] < 0:
                    errs.append(f"configs[{i}].{ph}: negative phase time")
            if len(errs) == before \
                    and c["prefill_s"] + c["decode_s"] > c["wall_s"] * 1.05:
                errs.append(f"configs[{i}]: prefill_s + decode_s exceed "
                            f"wall_s (phase accounting broken)")
            if c["decode_tokens_per_s"] < 0:
                errs.append(f"configs[{i}]: negative decode throughput")
            if not 0.0 <= c["prefix_hit_rate"] <= 1.0:
                errs.append(f"configs[{i}]: prefix_hit_rate outside "
                            f"[0, 1]")
            if c["prefill_tokens_computed"] <= 0:
                errs.append(f"configs[{i}]: non-positive "
                            f"prefill_tokens_computed")
            if not 0 < c["kv_pool_bytes_effective"] <= c["kv_pool_bytes"]:
                errs.append(f"configs[{i}]: kv_pool_bytes_effective "
                            f"outside (0, kv_pool_bytes]")
            if c["kv_pages_mapped_peak"] <= 0:
                errs.append(f"configs[{i}]: non-positive "
                            f"kv_pages_mapped_peak")
            if c["mix"] == "prefix":
                if len(errs) == before:
                    _check_prefix_row(i, c, doc, errs)
            else:
                # no sharing on these rows: every prompt position is
                # computed, nothing is mapped twice
                if c["prefix_cache"] or c["shared_prefix_tokens"] \
                        or c["prefix_hit_rate"] or c["kv_pages_shared"]:
                    errs.append(f"configs[{i}]: non-prefix row carries "
                                f"prefix-sharing state")
                if c["prefill_tokens_computed"] != c["prompt_tokens"]:
                    errs.append(f"configs[{i}]: prefill_tokens_computed "
                                f"!= prompt_tokens on a non-prefix row")
    caches = {c.get("cache") for c in doc["configs"]}
    if len(caches) < 2:
        errs.append(f"configs: need >= 2 distinct cache types, got {caches}")
    if "mx-mixed" not in caches:
        errs.append("configs: missing the mixed-policy row (mx-mixed: "
                    "INT8 keys / E2M1 values)")
    if not errs:
        _check_prefix_claims(
            [c for c in doc["configs"] if c["mix"] == "prefix"], errs)
        _check_traffic(doc["traffic"], errs)
        _check_faults(doc["faults"], errs)
        _check_obs(doc["observability"], errs)
    return errs


def main() -> None:
    path = Path(sys.argv[1]) if len(sys.argv) > 1 else \
        Path(__file__).resolve().parent.parent / "BENCH_serve.json"
    try:
        doc = json.loads(path.read_text())
    except (OSError, json.JSONDecodeError) as e:
        print(f"{path}: unreadable: {e}", file=sys.stderr)
        sys.exit(1)
    errs = check(doc)
    if errs:
        print(f"{path}: {len(errs)} schema violation(s):", file=sys.stderr)
        for e in errs:
            print(f"  - {e}", file=sys.stderr)
        sys.exit(1)
    caches = sorted({c["cache"] for c in doc["configs"]})
    npfx = sum(c["mix"] == "prefix" for c in doc["configs"])
    trows = doc["traffic"]["rows"]
    obs = doc["observability"]
    print(f"{path}: valid {SCHEMA} ({len(doc['configs'])} configs, "
          f"caches={caches}, sync_every={doc['sync_every']}, "
          f"prefix_rows={npfx}, traffic_rows={len(trows)}, "
          f"preemptions={sum(r['n_preemptions'] for r in trows)}, "
          f"trace_events={obs['trace_events']}, trace_overhead="
          f"{obs['trace_overhead']['overhead_frac']:.2%})")


if __name__ == "__main__":
    main()
