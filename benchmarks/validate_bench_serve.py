"""Validate BENCH_serve.json against the bench_serve/v2 schema (dep-free).

    python benchmarks/validate_bench_serve.py [BENCH_serve.json]

Schema v2 adds the per-phase wall-time split (prefill vs decode vs
host-sync) and the fused-window accounting (``sync_every`` /
``sync_points``) of the device-resident decode loop.  Exits nonzero with a
per-field report on mismatch — including *unknown* fields, so the emitted
artifact can't silently drift from the schema documented in README
§Continuous batching & paged KV.
"""
from __future__ import annotations

import json
import sys
from pathlib import Path

SCHEMA = "bench_serve/v2"
TOP_FIELDS = {
    "schema": str,
    "arch": str,
    "page_size": int,
    "max_slots": int,
    "new_tokens": int,
    "sync_every": int,
    "configs": list,
}
CONFIG_FIELDS = {
    "cache": str,
    "kv_fmt": (str, type(None)),
    "mode": (str, type(None)),
    "kv_key_fmt": (str, type(None)),
    "kv_value_fmt": (str, type(None)),
    "quant": (str, type(None)),
    "mix": str,
    "prefill_bucket": int,
    "requests": int,
    "prompt_tokens": int,
    "generated_tokens": int,
    "decode_steps": int,
    "sync_points": int,
    "wall_s": float,
    "tokens_per_s": float,
    "prefill_s": float,
    "decode_s": float,
    "sync_s": float,
    "decode_tokens_per_s": float,
    "kv_pool_bytes": int,
}
KNOWN_CACHES = {"fp32", "mx-int8", "mx-e4m3", "mx-e5m2", "mx-e3m2",
                "mx-e2m3", "mx-e2m1", "mx-mixed"}
KNOWN_MIXES = {"uniform", "mixed"}
KNOWN_FMTS = {"int8", "e4m3", "e5m2", "e3m2", "e2m3", "e2m1", None}


def check(doc) -> list:
    errs = []
    for field, ty in TOP_FIELDS.items():
        if field not in doc:
            errs.append(f"missing top-level field {field!r}")
        elif not isinstance(doc[field], ty):
            errs.append(f"{field!r}: expected {ty.__name__}, "
                        f"got {type(doc[field]).__name__}")
    for field in sorted(set(doc) - set(TOP_FIELDS)):
        errs.append(f"unknown top-level field {field!r} (schema drift — "
                    f"extend the validator in the same PR)")
    if errs:
        return errs
    if doc["schema"] != SCHEMA:
        errs.append(f"schema: expected {SCHEMA!r}, got {doc['schema']!r}")
    if doc["sync_every"] < 1:
        errs.append(f"sync_every: must be >= 1, got {doc['sync_every']}")
    if len(doc["configs"]) < 2:
        errs.append("configs: need >= 2 cache configurations")
    for i, c in enumerate(doc["configs"]):
        before = len(errs)
        for field, ty in CONFIG_FIELDS.items():
            if field not in c:
                errs.append(f"configs[{i}]: missing field {field!r}")
            elif not isinstance(c[field], ty):
                tn = ty.__name__ if isinstance(ty, type) else \
                    "/".join(t.__name__ for t in ty)
                errs.append(f"configs[{i}].{field}: expected {tn}, "
                            f"got {type(c[field]).__name__}")
        for field in sorted(set(c) - set(CONFIG_FIELDS)):
            errs.append(f"configs[{i}]: unknown field {field!r} (schema "
                        f"drift — extend the validator in the same PR)")
        if len(errs) == before:          # this config's fields are sound
            if c["cache"] not in KNOWN_CACHES:
                errs.append(f"configs[{i}].cache: unknown {c['cache']!r}")
            if c["mix"] not in KNOWN_MIXES:
                errs.append(f"configs[{i}].mix: unknown {c['mix']!r}")
            for role in ("kv_key_fmt", "kv_value_fmt"):
                if c[role] not in KNOWN_FMTS:
                    errs.append(f"configs[{i}].{role}: unknown "
                                f"{c[role]!r}")
            if (c["kv_key_fmt"] is None) != (c["kv_value_fmt"] is None):
                errs.append(f"configs[{i}]: kv_key_fmt/kv_value_fmt must "
                            f"be set together")
            if c["cache"] == "mx-mixed" \
                    and c["kv_key_fmt"] == c["kv_value_fmt"]:
                errs.append(f"configs[{i}]: mx-mixed row must carry "
                            f"distinct key/value formats")
            if c["tokens_per_s"] <= 0 or c["wall_s"] <= 0:
                errs.append(f"configs[{i}]: non-positive throughput")
            if c["generated_tokens"] <= 0 or c["kv_pool_bytes"] <= 0:
                errs.append(f"configs[{i}]: non-positive token/byte counts")
            if c["sync_points"] <= 0:
                errs.append(f"configs[{i}]: non-positive sync_points")
            if c["decode_steps"] < c["sync_points"]:
                errs.append(f"configs[{i}]: decode_steps < sync_points "
                            f"(each fused window runs >= 1 device step)")
            for ph in ("prefill_s", "decode_s", "sync_s"):
                if c[ph] < 0:
                    errs.append(f"configs[{i}].{ph}: negative phase time")
            if len(errs) == before \
                    and c["prefill_s"] + c["decode_s"] > c["wall_s"] * 1.05:
                errs.append(f"configs[{i}]: prefill_s + decode_s exceed "
                            f"wall_s (phase accounting broken)")
            if c["decode_tokens_per_s"] < 0:
                errs.append(f"configs[{i}]: negative decode throughput")
    caches = {c.get("cache") for c in doc["configs"]}
    if len(caches) < 2:
        errs.append(f"configs: need >= 2 distinct cache types, got {caches}")
    if "mx-mixed" not in caches:
        errs.append("configs: missing the mixed-policy row (mx-mixed: "
                    "INT8 keys / E2M1 values)")
    return errs


def main() -> None:
    path = Path(sys.argv[1]) if len(sys.argv) > 1 else \
        Path(__file__).resolve().parent.parent / "BENCH_serve.json"
    try:
        doc = json.loads(path.read_text())
    except (OSError, json.JSONDecodeError) as e:
        print(f"{path}: unreadable: {e}", file=sys.stderr)
        sys.exit(1)
    errs = check(doc)
    if errs:
        print(f"{path}: {len(errs)} schema violation(s):", file=sys.stderr)
        for e in errs:
            print(f"  - {e}", file=sys.stderr)
        sys.exit(1)
    caches = sorted({c["cache"] for c in doc["configs"]})
    print(f"{path}: valid {SCHEMA} ({len(doc['configs'])} configs, "
          f"caches={caches}, sync_every={doc['sync_every']})")


if __name__ == "__main__":
    main()
