"""Validate a ``trace/v1`` JSONL artifact (dependency-free).

    python benchmarks/validate_trace.py [BENCH_trace.jsonl]

Re-derives everything from the serialized lines alone — no ``repro``
import, so schema drift in the emitter cannot hide behind shared code:

* line 0 is the header ``{"schema": "trace/v1", "meta": {...}}`` with
  no extra fields;
* every event carries exactly the ``trace/v1`` fields
  (``seq``/``ph``/``name``/``cat``/``rid``/``t_us`` plus optional
  ``args``) — **unknown fields are rejected**; ``seq`` is dense from 0
  in file order, ``ph`` is B/E/I, ``rid`` is an int or null (null = the
  engine track), ``t_us`` a non-negative int, ``args`` an object;
* per-track nesting is re-derived with a stack: every E closes the
  innermost open B of its track by name, the per-track clock is
  monotone, and no track is left open at EOF;
* every request track (``rid != null``) completes **exactly one**
  root-level ``request`` span carrying a terminal ``status``
  (finished / failed / aborted), and on every track the summed
  durations of a root span's direct children never exceed the root's
  wall — strict nesting makes siblings disjoint, so span-sum <= wall
  is an arithmetic consequence the committed ``t_us`` values must
  actually satisfy.

Exits nonzero with a per-line report on violation.
"""
from __future__ import annotations

import json
import sys
from pathlib import Path

SCHEMA = "trace/v1"
REQUIRED = {"seq": int, "name": str, "cat": str, "t_us": int}
OPTIONAL = {"args"}
PHASES = {"B", "E", "I"}
TERMINAL = {"finished", "failed", "aborted"}


def _is_int(v) -> bool:
    return isinstance(v, int) and not isinstance(v, bool)


def _check_event_shape(i, ev, errs) -> bool:
    w = f"events[{i}]"
    if not isinstance(ev, dict):
        errs.append(f"{w}: not a JSON object")
        return False
    unknown = set(ev) - set(REQUIRED) - {"ph", "rid"} - OPTIONAL
    if unknown:
        errs.append(f"{w}: unknown field(s) {sorted(unknown)} (schema "
                    f"drift — extend the validator in the same PR)")
        return False
    ok = True
    for field, ty in REQUIRED.items():
        if field not in ev:
            errs.append(f"{w}: missing field {field!r}")
            ok = False
        elif ty is int and not _is_int(ev[field]):
            errs.append(f"{w}.{field}: expected int, "
                        f"got {type(ev[field]).__name__}")
            ok = False
        elif ty is str and not isinstance(ev[field], str):
            errs.append(f"{w}.{field}: expected str, "
                        f"got {type(ev[field]).__name__}")
            ok = False
    if ev.get("ph") not in PHASES:
        errs.append(f"{w}.ph: expected one of {sorted(PHASES)}, "
                    f"got {ev.get('ph')!r}")
        ok = False
    if "rid" not in ev or not (ev["rid"] is None or _is_int(ev["rid"])):
        errs.append(f"{w}.rid: expected int or null")
        ok = False
    if "args" in ev and not isinstance(ev["args"], dict):
        errs.append(f"{w}.args: expected object")
        ok = False
    if not ok:
        return False
    if ev["seq"] != i:
        errs.append(f"{w}.seq: {ev['seq']} != file position {i} "
                    f"(seq must be dense from 0)")
    if ev["t_us"] < 0:
        errs.append(f"{w}.t_us: negative timestamp")
    return True


def check(header, events) -> list:
    errs = []
    if not isinstance(header, dict) or set(header) != {"schema", "meta"}:
        errs.append("header: expected exactly "
                    "{'schema': 'trace/v1', 'meta': {...}}")
        return errs
    if header["schema"] != SCHEMA:
        errs.append(f"header.schema: expected {SCHEMA!r}, "
                    f"got {header['schema']!r}")
    if not isinstance(header["meta"], dict):
        errs.append("header.meta: expected object")
    if errs:
        return errs

    stacks = {}                    # track -> [begin event, ...]
    last_t = {}                    # track -> latest t_us seen
    child_sum = {}                 # track -> summed depth-1 child walls
    roots = {}                     # track -> [(name, wall, args), ...]
    for i, ev in enumerate(events):
        if not _check_event_shape(i, ev, errs):
            return errs            # later checks need sound fields
        rid, t = ev["rid"], ev["t_us"]
        if t < last_t.get(rid, t):
            errs.append(f"events[{i}]: track {rid} clock moved "
                        f"backwards ({t} < {last_t[rid]})")
            return errs
        last_t[rid] = t
        if ev["ph"] == "B":
            stacks.setdefault(rid, []).append(ev)
        elif ev["ph"] == "E":
            stack = stacks.get(rid)
            if not stack or stack[-1]["name"] != ev["name"]:
                top = stack[-1]["name"] if stack else "nothing"
                errs.append(f"events[{i}]: E {ev['name']!r} does not "
                            f"close the innermost B of track {rid} "
                            f"({top} is open)")
                return errs
            b = stack.pop()
            wall = t - b["t_us"]
            if len(stack) == 1:    # direct child of the open root
                child_sum[rid] = child_sum.get(rid, 0) + wall
            elif not stack:        # a root-level span completed
                kids = child_sum.pop(rid, 0)
                if kids > wall:
                    errs.append(
                        f"events[{i}]: track {rid} root "
                        f"{ev['name']!r}: child span sum {kids}us "
                        f"exceeds the root wall {wall}us")
                roots.setdefault(rid, []).append(
                    (ev["name"], wall, ev.get("args") or {}))

    still_open = {rid: [b["name"] for b in st]
                  for rid, st in stacks.items() if st}
    if still_open:
        errs.append(f"tracks left open at EOF: {still_open}")

    req_tracks = sorted(r for r in roots if r is not None)
    if not req_tracks:
        errs.append("no request tracks (rid != null) in the trace")
    for rid in req_tracks:
        spans = roots[rid]
        if [name for name, _, _ in spans] != ["request"]:
            errs.append(f"track {rid}: expected exactly one root "
                        f"'request' span, got "
                        f"{[name for name, _, _ in spans]}")
            continue
        st = spans[0][2].get("status")
        if st not in TERMINAL:
            errs.append(f"track {rid}: root request span status "
                        f"{st!r} not in {sorted(TERMINAL)}")
    return errs


def main() -> None:
    path = Path(sys.argv[1]) if len(sys.argv) > 1 else \
        Path(__file__).resolve().parent.parent / "BENCH_trace.jsonl"
    try:
        lines = path.read_text().splitlines()
        parsed = [json.loads(ln) for ln in lines if ln.strip()]
    except (OSError, json.JSONDecodeError) as e:
        print(f"{path}: unreadable: {e}", file=sys.stderr)
        sys.exit(1)
    if not parsed:
        print(f"{path}: empty trace", file=sys.stderr)
        sys.exit(1)
    errs = check(parsed[0], parsed[1:])
    if errs:
        print(f"{path}: {len(errs)} trace violation(s):", file=sys.stderr)
        for e in errs:
            print(f"  - {e}", file=sys.stderr)
        sys.exit(1)
    events = parsed[1:]
    tracks = {ev["rid"] for ev in events}
    print(f"{path}: valid {SCHEMA} ({len(events)} events, "
          f"{len(tracks - {None})} request tracks, "
          f"{sum(1 for e in events if e['ph'] == 'I')} instants)")


if __name__ == "__main__":
    main()
