"""Calibration walkthrough: measure per-layer activation statistics,
sweep the MX formats, search a per-layer KV policy under byte budgets,
round-trip it through JSON, and serve with it.

    PYTHONPATH=src python examples/calibrate_policy.py
"""
import jax
import numpy as np

from repro.calib import (collect_model_stats, search_kv_policy,
                         sweep_role)
from repro.core import PolicyTable, QuantSpec
from repro.models import Model, apply_policy_table, load_reduced
from repro.serve import ContinuousBatchingEngine, GenerationConfig
from repro.serve.paging import kv_cache_token_nbytes, spec_side_nbytes

ARCH = "chatglm3_6b"
N_LAYERS = 4
CALIB_BATCHES, B, S = 2, 2, 32


def main() -> None:
    cfg = load_reduced(ARCH, n_layers=N_LAYERS)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    # 1. collect — a few batches through the instrumented forward
    rng = np.random.default_rng(0)
    batches = [rng.integers(0, cfg.vocab, size=(B, S)).astype(np.int32)
               for _ in range(CALIB_BATCHES)]
    stats = collect_model_stats(model, params, batches,
                                roles=("kv_key", "kv_value"))
    ts = stats.stats["kv_key"][0]
    print(f"[calib] kv_key layer 0: {ts.count} values, absmax "
          f"{ts.absmax:.3f}, rms {ts.rms:.3f}, p99 biased exponent "
          f"{ts.exp_percentile(0.99)}")

    # 2. sweep — every format scored on every layer's sample
    cost = lambda s: float(spec_side_nbytes(s, cfg.n_kv_heads, cfg.hd))
    sw = sweep_role(stats, "kv_value", cost)
    print("[sweep] kv_value layer 0 (best first):")
    for s in sw[0]:
        print(f"        {s}")

    # 3. search — budgets in KV bytes per token summed over layers
    full8 = 2 * N_LAYERS * cost(QuantSpec("int8", "ocp"))
    for label, budget in [("8-bit", full8), ("~6-bit", 0.75 * full8)]:
        res = search_kv_policy(stats, budget, cfg)
        print(f"[search {label}] " +
              res.describe().replace("\n", "\n" + " " * 15))

    # 4. JSON round-trip + apply + serve
    table = PolicyTable.from_json(res.table.to_json())
    assert table == res.table
    cfg_auto = apply_policy_table(cfg, table)
    print(f"[apply] {kv_cache_token_nbytes(cfg_auto)} KV bytes/token "
          f"across {cfg_auto.n_layers} layers "
          f"(uniform int8 would be {full8:.0f})")
    eng = ContinuousBatchingEngine(
        Model(cfg_auto), params, max_slots=2, page_size=8, max_len=24,
        gen=GenerationConfig(max_new_tokens=4))
    for n in (5, 9, 12):
        eng.add_request(rng.integers(0, cfg.vocab, size=n
                                     ).astype(np.int32), 4)
    out = eng.run()
    print(f"[serve] {len(out)} requests under the auto table; pool "
          f"{eng.kv_pool_nbytes / 1e3:.1f} kB; first tokens "
          f"{out[min(out)].tolist()}")


if __name__ == "__main__":
    main()
