"""Quickstart: convert FP32 tensors to every MX format (the paper's
algorithm) through the QuantSpec API, inspect scales/codes, and measure
reconstruction quality.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax.numpy as jnp
import numpy as np

from repro.core import (ALL_FORMATS, QuantSpec, metrics, mx_dequantize,
                        mx_quantize)
from repro.kernels.ops import mx_quantize_pallas


def main() -> None:
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(8, 256)).astype(np.float32))

    print("=== paper worked example (E5M2) ===")
    v = np.zeros(32, np.float32)
    v[:4] = [np.uint32(b).view(np.float32) for b in
             [0x55B00000, 0x54600000, 0x15900000, 0xC7900000]]
    mx = mx_quantize(jnp.asarray(v), QuantSpec.parse("e5m2@32:paper"))
    print(f"shared scale X = {int(np.asarray(mx.scales)[0]):#010b} "
          f"(paper: 0b10011100)")
    print("P1..P4 =", [f"{c:#010b}" for c in np.asarray(mx.codes)[:4]])

    print("\n=== all formats, both modes (random gaussian) ===")
    print(f"{'format':8s} {'mode':6s} {'bits/elt':>9s} {'SQNR dB':>8s} "
          f"{'max rel err vs blockmax':>24s}")
    for f in ALL_FORMATS:
        for mode in ("paper", "ocp"):
            mx = mx_quantize(x, QuantSpec(f.name, mode))
            y = mx_dequantize(mx)
            sq = float(metrics.sqnr_db(x, y))
            mr = float(metrics.max_rel_err_vs_blockmax(x, y))
            print(f"{f.name:8s} {mode:6s} {f.bits_per_element():9.2f} "
                  f"{sq:8.2f} {mr:24.4f}")

    print("\n=== Pallas kernel path (interpret) is bit-identical ===")
    spec = QuantSpec("e4m3", "paper")
    mx_k = mx_quantize_pallas(x, spec)
    mx_c = mx_quantize(x, spec)
    same = bool(jnp.all(mx_k.codes == mx_c.codes)
                & jnp.all(mx_k.scales == mx_c.scales))
    print("kernel == reference:", same)


if __name__ == "__main__":
    main()
