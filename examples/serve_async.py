"""Async serving example: bursty SLO traffic through the asyncio front
end, reject-on-full vs preempt-and-swap at equal KV pool bytes.

A two-class workload (interactive: priority 0 with a TTFT deadline;
batch: priority 1, longer generations) arrives in on/off bursts that
oversubscribe a 2-slot engine.  The reject baseline drops what cannot
start immediately; preempt-and-swap instead swaps the batch victim's MX
KV pages to host memory, serves the interactive request, and restores
the victim token-identically — so it admits every request.

    PYTHONPATH=src python examples/serve_async.py
"""
import asyncio

import jax
import numpy as np

from repro.models import Model, load_reduced
from repro.models.config import QuantPolicy
from repro.serve import (AsyncServer, ContinuousBatchingEngine,
                         GenerationConfig, TrafficClass, latency_summary,
                         on_off_times, replay, synthesize)

PAGE, SLOTS, MAX_LEN = 8, 2, 72


def main() -> None:
    cfg = load_reduced("chatglm3_6b",
                       mx=QuantPolicy.parse("kv=int8@32:ocp"))
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    classes = [
        TrafficClass("interactive", (8, 24), (12, 13),
                     priority=0, deadline_s=0.35, weight=1.5),
        TrafficClass("batch", (8, 24), (36, 49), priority=1),
    ]
    arrivals = synthesize(
        on_off_times(60.0, 20, on_s=0.15, off_s=2.0, seed=11),
        classes, cfg.vocab, seed=11)

    for policy in ("reject", "preempt"):
        eng = ContinuousBatchingEngine(
            model, params, max_slots=SLOTS, page_size=PAGE,
            max_len=MAX_LEN, num_pages=1 + SLOTS * (MAX_LEN // PAGE + 1),
            gen=GenerationConfig(max_new_tokens=12), sync_every=4,
            preempt=(policy == "preempt"))
        # warm the jit closures, then open a clean measurement window
        eng.add_request(np.arange(1, 9, dtype=np.int32), 2)
        eng.run()
        eng.reset_metrics()

        async def go():
            admission = "reject" if policy == "reject" else "block"
            async with AsyncServer(eng, admission=admission) as srv:
                return await replay(srv, arrivals, speedup=1.0)

        _, rejected = asyncio.run(go())
        summ = latency_summary(eng.finished_in_window)
        print(f"[{policy:7s}] served={int(summ['n_requests']):2d}/"
              f"{len(arrivals)} rejected={len(rejected):2d} "
              f"preemptions={eng.n_preemptions} "
              f"swap={eng.swap_store.bytes_out / 1e3:.1f}kB "
              f"ttft_p99={summ.get('ttft_p99_ms', 0.0):7.1f}ms "
              f"slo={summ.get('slo_attainment', 1.0):.2f}")


if __name__ == "__main__":
    main()
