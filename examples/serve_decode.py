"""Serving example: batched greedy decoding with an MX KV cache — uniform
INT8 pages, and a mixed per-role policy (INT8 keys + E2M1 values) that the
pre-spec API could not express.

    PYTHONPATH=src python examples/serve_decode.py
"""
import jax
import numpy as np

from repro.models import Model, load_reduced, make_concrete_batch
from repro.models.config import QuantPolicy
from repro.serve import GenerationConfig, ServeEngine

B, PROMPT, NEW = 4, 48, 24


def main() -> None:
    for label, over in [
        ("bf16 KV cache", {}),
        ("MX-INT8 KV cache",
         {"mx": QuantPolicy.parse("kv=int8@32:ocp")}),
        ("mixed INT8-K / E2M1-V cache",
         {"mx": QuantPolicy.parse("kv_key=int8@32:ocp,"
                                  "kv_value=e2m1@32:ocp")}),
    ]:
        cfg = load_reduced("yi_34b", **over)
        model = Model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        batch = make_concrete_batch(cfg, B, PROMPT)
        batch.pop("labels")
        eng = ServeEngine(model, params, max_len=PROMPT + NEW + 8)
        out = eng.generate(batch, GenerationConfig(max_new_tokens=NEW))
        cache = jax.eval_shape(lambda: model.init_cache(B, PROMPT + NEW))
        nbytes = sum(np.prod(l.shape) * l.dtype.itemsize
                     for l in jax.tree_util.tree_leaves(cache))
        print(f"[{label}] cache={nbytes/1e6:.2f}MB  "
              f"first tokens={out[0][:8].tolist()}")


if __name__ == "__main__":
    main()
