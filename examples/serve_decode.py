"""Serving example: batched greedy decoding with an MX-INT8 KV cache
(2x smaller than bf16; the decode-roofline lever from the paper's format).

    PYTHONPATH=src python examples/serve_decode.py
"""
import time

import jax
import numpy as np

from repro.models import Model, load_reduced, make_concrete_batch
from repro.models.config import MXPolicy
from repro.serve import GenerationConfig, ServeEngine

B, PROMPT, NEW = 4, 48, 24


def main() -> None:
    for label, over in [
        ("bf16 KV cache", {}),
        ("MX-INT8 KV cache",
         {"mx": MXPolicy(mode="ocp", kv_cache=True, kv_fmt="int8")}),
    ]:
        cfg = load_reduced("yi_34b", **over)
        model = Model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        batch = make_concrete_batch(cfg, B, PROMPT)
        batch.pop("labels")
        eng = ServeEngine(model, params, max_len=PROMPT + NEW + 8)
        out = eng.generate(batch, GenerationConfig(max_new_tokens=NEW))
        cache = jax.eval_shape(lambda: model.init_cache(B, PROMPT + NEW))
        nbytes = sum(np.prod(l.shape) * l.dtype.itemsize
                     for l in jax.tree_util.tree_leaves(cache))
        print(f"[{label}] cache={nbytes/1e6:.2f}MB  "
              f"first tokens={out[0][:8].tolist()}")


if __name__ == "__main__":
    main()
