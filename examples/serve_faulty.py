"""Fault-tolerance drill: a seeded fault plan against the async server.

Six requests are served twice on the same engine geometry: once clean
(the reference), once under a deterministic `FaultPlan` that poisons the
prefill of rid 2 (transient — one retry replays it clean) and of rid 4
on *every* attempt (terminal — it exhausts the 1-retry budget and
surfaces `RetriesExhausted`).  The drill asserts the failure stayed
contained: every healthy stream is token-identical to the clean run,
the retried stream recovered token-identically, and exactly the
always-poisoned request failed.

    PYTHONPATH=src python examples/serve_faulty.py
"""
import asyncio

import jax
import numpy as np

from repro.models import Model, load_reduced
from repro.models.config import QuantPolicy
from repro.serve import (AsyncServer, ContinuousBatchingEngine, FaultPlan,
                         GenerationConfig, QuarantinedError)

PAGE, SLOTS, MAX_LEN, NEW, N_REQ = 8, 4, 48, 8, 6


def build_engine(model, params, faults):
    eng = ContinuousBatchingEngine(
        model, params, max_slots=SLOTS, page_size=PAGE, max_len=MAX_LEN,
        num_pages=1 + SLOTS * (MAX_LEN // PAGE + 1),
        gen=GenerationConfig(max_new_tokens=NEW), sync_every=4,
        faults=faults)
    # warm the jit closures (rid 0), then open a clean window: the fault
    # plan's rid targets below are engine request ids, so the warmup
    # shifts the drill's requests to rids 1..6
    eng.add_request(np.arange(1, 9, dtype=np.int32), 2)
    eng.run()
    eng.reset_metrics()
    return eng


async def serve(eng, prompts, retries):
    async with AsyncServer(eng, admission="block", retries=retries,
                           retry_backoff_s=0.01) as srv:
        streams = [await srv.submit(p, NEW) for p in prompts]
        toks = await asyncio.gather(*(s.tokens() for s in streams),
                                    return_exceptions=True)
        return srv, streams, toks


def main() -> None:
    cfg = load_reduced(
        "chatglm3_6b",
        mx=QuantPolicy.parse("kv_key=int8@32:paper,kv_value=e4m3@32:paper"))
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = [rng.integers(1, cfg.vocab, size=n).astype(np.int32)
               for n in rng.integers(7, 14, size=N_REQ)]

    clean_eng = build_engine(model, params, faults=None)
    _, _, clean = asyncio.run(serve(clean_eng, prompts, retries=0))

    plan = FaultPlan.parse("prefill_nan:rid=2,prefill_nan:rid=4:always",
                           seed=20260808)
    eng = build_engine(model, params, faults=plan)
    srv, streams, toks = asyncio.run(serve(eng, prompts, retries=1))

    for st, got, want in zip(streams, toks, clean):
        if isinstance(got, QuarantinedError):
            print(f"rid {st.rid}: QUARANTINED after retry budget "
                  f"({st.request.error})")
            assert st.rid == 4, "only the always-poisoned rid may fail"
        elif st.request.n_retries:
            np.testing.assert_array_equal(got, want)
            print(f"rid {st.rid}: recovered on retry "
                  f"{st.request.n_retries}, token-identical")
            assert st.rid == 2
        else:
            np.testing.assert_array_equal(got, want)
            print(f"rid {st.rid}: healthy, token-identical to clean run")

    print(f"fired={plan.fired} retried={srv.n_retried} "
          f"failed={srv.n_failed}")
    # n_retried counts retry *attempts*: rid 2's successful replay plus
    # rid 4's doomed one; n_failed counts terminal quarantines only
    assert srv.n_retried == 2 and srv.n_failed == 1
    print("drill passed: failures contained, healthy streams unaffected")


if __name__ == "__main__":
    main()
