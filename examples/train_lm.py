"""End-to-end driver: train a ~100M-param LM for a few hundred steps with
the paper's MX converter in the training loop (weight fake-quant, E4M3),
checkpointing + auto-resume included.

    PYTHONPATH=src python examples/train_lm.py [--steps 300] [--mx paper]

~100M config: 8 layers, d=512, GQA 8/2 heads, ff=2048, vocab=32000
(embeddings dominate: 2*32000*512 = 33M + 8 layers * ~8M = ~96M params).
"""
import argparse
import tempfile

import jax

from repro.data import DataConfig, SyntheticLM, make_batch_for
from repro.models import Model
from repro.models.config import ModelConfig, QuantPolicy
from repro.optim import AdamWConfig
from repro.train import (LoopConfig, build_train_step, init_train_state,
                         train_loop)


def config(mx_mode: str) -> ModelConfig:
    mx = QuantPolicy() if mx_mode == "off" else \
        QuantPolicy.parse(f"weights=e4m3@32:{mx_mode}")
    return ModelConfig(
        name="lm100m", family="decoder", n_layers=8, d_model=512,
        n_heads=8, n_kv_heads=2, d_ff=2048, vocab=32000, head_dim=64,
        mx=mx, dtype="float32", param_dtype="float32", remat=False)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--mx", choices=["off", "paper", "ocp"],
                    default="paper")
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    cfg = config(args.mx)
    model = Model(cfg)
    params, opt_state = init_train_state(model, jax.random.PRNGKey(0))
    n = sum(int(p.size) for p in jax.tree_util.tree_leaves(params))
    print(f"[example] {n/1e6:.1f}M params, MX={args.mx}")
    opt_cfg = AdamWConfig(lr=6e-4, warmup_steps=30, total_steps=args.steps)
    step = jax.jit(build_train_step(model, opt_cfg,
                                    fake_quant=(args.mx != "off")))
    data = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                                  global_batch=args.batch, seed=7))
    ckpt = args.ckpt_dir or tempfile.mkdtemp(prefix="repro_lm100m_")
    out = train_loop(
        LoopConfig(total_steps=args.steps, ckpt_dir=ckpt, ckpt_every=100,
                   log_every=20),
        step, params, opt_state,
        lambda i: make_batch_for(cfg, data.batch(i)))
    h = out["history"]
    print(f"[example] loss {h[0]['loss']:.3f} -> {h[-1]['loss']:.3f}; "
          f"checkpoints in {ckpt}")
    assert h[-1]["loss"] < h[0]["loss"], "training failed to reduce loss"


if __name__ == "__main__":
    main()
