"""repro — FP32->MX conversion (arXiv:2411.03149) grown into a sharded
jax_pallas training/serving system.  Subpackages: core (the converter),
kernels (Pallas), dist (sharding rules), models, train, serve, launch."""

__version__ = "0.1.0"
