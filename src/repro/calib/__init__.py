"""repro.calib — activation-statistics calibration and budget-constrained
auto-selection of per-layer quantization policies.

The pipeline has three stages, one module each:

``stats``         — run a handful of calibration batches through the
                    instrumented model forward and accumulate, per tensor
                    role and per layer, streaming statistics (absmax,
                    biased-exponent histogram, moments) plus a bounded
                    block sample of the raw values.
``sweep``         — score every candidate ``QuantSpec`` in a search space
                    against the collected samples using ``core.metrics``
                    (SQNR, block-relative error) and the spec's storage
                    cost.
``policy_search`` — pick, under a byte budget, the per-layer spec
                    assignment maximizing quality, emitted as a
                    ``core.spec.PolicyTable`` (JSON-serializable; applied
                    with ``models.config.apply_policy_table``).
"""
from repro.calib.stats import (  # noqa: F401
    CalibStats, TensorStats, collect_model_stats,
)
from repro.calib.sweep import (  # noqa: F401
    DEFAULT_CANDIDATES, ScoredSpec, score_sample, sweep_role,
    weight_param_nbytes,
)
from repro.calib.policy_search import (  # noqa: F401
    SearchResult, parse_auto_budget, search_kv_policy,
    search_weights_policy,
)
