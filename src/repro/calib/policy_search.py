"""Budget-constrained per-layer policy selection.

Given per-layer candidate sweeps (``repro.calib.sweep``) and a byte
budget, pick the spec assignment maximizing quality and emit it as a
``core.spec.PolicyTable``.

The search is greedy marginal analysis: start every (role, layer) slot at
its highest-SQNR candidate, then — while the total cost exceeds the
budget — apply the single downgrade with the smallest quality loss per
byte saved (each slot's next option is the best-SQNR candidate among its
strictly cheaper ones).  Candidate lists are identical across layers, so
the search spends its budget where the calibration statistics say the
tensors are hardest to quantize, which is exactly the per-layer
sensitivity structure the OCP MX report observes.

Budget semantics (see README §Calibration & auto policies):

* serving (``search_kv_policy``)  — total KV-cache bytes per token
  position summed over all layers (codes + E8M0 scales, bit-packed when
  the spec says so): the unit ``serve.paging.kv_cache_token_nbytes``
  reports and the page pools actually allocate.
* training (``search_weights_policy``) — average bytes per weight
  parameter (element code bits + amortized scale, over 8).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.spec import PolicyTable, QuantPolicy, QuantSpec
from repro.serve.paging import spec_side_nbytes

from repro.calib.stats import CalibStats
from repro.calib.sweep import (DEFAULT_CANDIDATES, ScoredSpec, sweep_role,
                               weight_param_nbytes)

Slot = Tuple[str, int]                       # (role, layer)


def parse_auto_budget(text: str) -> float:
    """Parse the ``auto:<budget>`` quantization-flag form; the budget is a
    positive float in the caller's byte unit (KV bytes/token for serving,
    bytes/param for training)."""
    if not isinstance(text, str) or not (text == "auto"
                                         or text.startswith("auto:")):
        raise ValueError(f"not an auto policy spec: {text!r}; expected "
                         f"'auto:<bytes>'")
    _, sep, rest = text.partition(":")
    if not sep or not rest:
        raise ValueError(
            f"auto policy {text!r} needs a byte budget: 'auto:<bytes>' "
            f"(e.g. 'auto:96' = 96 KV bytes per token across all layers)")
    try:
        budget = float(rest)
    except ValueError:
        raise ValueError(
            f"bad auto budget {rest!r} in {text!r}; expected a positive "
            f"number of bytes") from None
    if budget <= 0:
        raise ValueError(f"auto budget must be positive, got {budget!r}")
    return budget


@dataclasses.dataclass
class SearchResult:
    """The selected table plus its quality/cost accounting."""

    table: PolicyTable
    total_nbytes: float                       # in the budget's unit
    budget_nbytes: float
    mean_sqnr_db: float                       # over all chosen slots
    chosen: Dict[Slot, ScoredSpec]
    total_params: Optional[int] = None        # weights search only

    def describe(self) -> str:
        lines = [f"auto policy: {self.total_nbytes:.4g}B used of "
                 f"{self.budget_nbytes:.4g}B budget"
                 + (f" ({self.total_nbytes / self.total_params:.3f} "
                    f"B/param)" if self.total_params else "")
                 + f", mean SQNR {self.mean_sqnr_db:.1f}dB"]
        for (role, layer), s in sorted(self.chosen.items(),
                                       key=lambda kv: (kv[0][1], kv[0][0])):
            lines.append(f"  layer {layer:>2} {role:<9} -> {s}")
        return "\n".join(lines)


def _greedy_select(sweeps: Dict[str, Dict[int, List[ScoredSpec]]],
                   budget: float) -> Dict[Slot, ScoredSpec]:
    slots: Dict[Slot, List[ScoredSpec]] = {}
    for role, per_layer in sweeps.items():
        for layer, scored in per_layer.items():
            slots[(role, layer)] = scored
    if not slots:
        raise ValueError("nothing to search: empty sweep")
    choice: Dict[Slot, ScoredSpec] = {s: c[0] for s, c in slots.items()}
    floor = sum(min(c, key=lambda s: s.nbytes).nbytes
                for c in slots.values())
    if floor > budget:
        raise ValueError(
            f"budget {budget:.4g}B infeasible: even the cheapest "
            f"candidates need {floor:.4g}B "
            f"(raise the budget or widen the search space)")

    def total() -> float:
        return sum(s.nbytes for s in choice.values())

    while total() > budget:
        best: Optional[Tuple[float, Slot, ScoredSpec]] = None
        for slot, cands in slots.items():
            cur = choice[slot]
            cheaper = [c for c in cands if c.nbytes < cur.nbytes]
            if not cheaper:
                continue
            nxt = max(cheaper, key=lambda s: s.sqnr_db)
            rate = (cur.sqnr_db - nxt.sqnr_db) \
                / max(1e-9, cur.nbytes - nxt.nbytes)
            if best is None or rate < best[0]:
                best = (rate, slot, nxt)
        assert best is not None, "feasibility was checked above"
        choice[best[1]] = best[2]
    return choice


def _build_table(choice: Dict[Slot, ScoredSpec], n_layers: int,
                 base: QuantPolicy) -> PolicyTable:
    """Per-layer policies from the chosen specs, on top of ``base`` (whose
    untouched roles carry through); the most common layer policy becomes
    the table default so overrides stay minimal."""
    per_layer: List[QuantPolicy] = []
    for i in range(n_layers):
        kw = {role: s.spec for (role, layer), s in choice.items()
              if layer == i}
        per_layer.append(base.replace(**kw))
    counts: Dict[QuantPolicy, int] = {}
    for p in per_layer:
        counts[p] = counts.get(p, 0) + 1
    default = max(counts, key=counts.get)
    overrides = tuple((i, p) for i, p in enumerate(per_layer)
                      if p != default)
    return PolicyTable(default=default, overrides=overrides)


def _result(choice, table, budget) -> SearchResult:
    total = sum(s.nbytes for s in choice.values())
    mean_sqnr = sum(s.sqnr_db for s in choice.values()) / len(choice)
    return SearchResult(table=table, total_nbytes=total,
                        budget_nbytes=budget, mean_sqnr_db=mean_sqnr,
                        chosen=choice)


def search_kv_policy(stats: CalibStats, budget_bytes_per_token: float,
                     cfg, *,
                     candidates: Sequence[QuantSpec] = DEFAULT_CANDIDATES,
                     ) -> SearchResult:
    """Select per-layer ``kv_key``/``kv_value`` specs under a total
    KV-bytes-per-token budget (summed over every layer, K and V, codes +
    scales — the unit ``serve.paging.kv_cache_token_nbytes`` reports).

    Roles other than the two KV roles keep ``cfg.mx``'s values.  Raises
    ``ValueError`` when even the cheapest candidates overflow the budget.
    """
    n_kv, hd = cfg.n_kv_heads, cfg.hd
    cost = lambda spec: float(spec_side_nbytes(spec, n_kv, hd))
    sweeps = {role: sweep_role(stats, role, cost, candidates)
              for role in ("kv_key", "kv_value")}
    choice = _greedy_select(sweeps, budget_bytes_per_token)
    table = _build_table(choice, cfg.n_layers, cfg.mx)
    return _result(choice, table, budget_bytes_per_token)


def search_weights_policy(stats: CalibStats,
                          budget_bytes_per_param: float, cfg, *,
                          candidates: Sequence[QuantSpec]
                          = DEFAULT_CANDIDATES) -> SearchResult:
    """Select per-layer ``weights`` specs under an average
    bytes-per-parameter budget.

    Layers are charged by their actual parameter counts (from the
    calibration statistics), so a model mixing small dense layers with
    huge MoE layers cannot satisfy the budget on a per-layer average
    while blowing the true parameter-weighted one: ``total_nbytes`` /
    total params <= ``budget_bytes_per_param`` holds exactly."""
    swept = sweep_role(stats, "weights", weight_param_nbytes, candidates)
    layer_params = {layer: stats.role_layers("weights")[layer].count
                    for layer in swept}
    sweeps = {"weights": {
        layer: [dataclasses.replace(s, nbytes=s.nbytes
                                    * layer_params[layer])
                for s in scored]
        for layer, scored in swept.items()}}
    total_params = sum(layer_params.values())
    budget = budget_bytes_per_param * total_params
    choice = _greedy_select(sweeps, budget)
    table = _build_table(choice, cfg.n_layers, cfg.mx)
    res = _result(choice, table, budget)
    res.total_params = int(total_params)
    return res
