"""Streaming per-(role, layer) tensor statistics for calibration.

``collect_model_stats`` runs a handful of calibration batches through the
instrumented model forward (``Model.forward_calib`` taps the clean,
pre-quantization tensors of the ``activations``/``kv_key``/``kv_value``
roles per layer), reads the ``weights`` role straight off the params, and
optionally runs an LM-loss backward pass for the ``grads`` role.  Each
batch's reduction — absmax, sum, sum of squares, biased-FP32-exponent
histogram — happens **in-jit** on device; the host only merges the
per-batch scalar/histogram results and keeps a bounded row sample of each
tensor reshaped to ``(rows, block)`` blocks, which is what
``repro.calib.sweep`` scores candidate specs against.

Samples are block-rows along each role's quantization axis (head_dim for
KV, the feature dim for activations, the input dim for weights), so
quantizing a sample with ``axis=-1`` reproduces the exact block
decomposition the real consumer uses.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.formats import DEFAULT_BLOCK

ROLES_FORWARD = ("activations", "kv_key", "kv_value")
ALL_ROLES = ("weights", "activations", "kv_key", "kv_value", "grads")

# params leaves excluded from the weights role: not consumed by dense()/
# the expert einsums (router runs in f32 outside the quantized matmuls;
# norm gains and biases are 1-D)
_WEIGHT_EXCLUDE = ("router",)


# =============================================================================
# TensorStats — one (role, layer)'s streaming accumulator
# =============================================================================
@dataclasses.dataclass
class TensorStats:
    """Streaming statistics plus a bounded block sample of one tensor
    stream.  ``exp_hist[e]`` counts finite non-zero elements with biased
    FP32 exponent ``e`` (the quantity the converter's comparator tree and
    shared-scale selection consume)."""

    count: int = 0
    n_zero: int = 0
    absmax: float = 0.0
    total: float = 0.0
    sumsq: float = 0.0
    exp_hist: np.ndarray = dataclasses.field(
        default_factory=lambda: np.zeros(256, np.int64))
    sample: Optional[np.ndarray] = None       # (rows, block) f32

    # ------------------------------------------------------------- derived
    @property
    def mean(self) -> float:
        return self.total / max(1, self.count)

    @property
    def rms(self) -> float:
        return float(np.sqrt(self.sumsq / max(1, self.count)))

    @property
    def zero_frac(self) -> float:
        return self.n_zero / max(1, self.count)

    def exp_percentile(self, q: float) -> int:
        """Biased-exponent value at quantile ``q`` of the histogram (the
        dynamic-range signal format selection keys on)."""
        c = np.cumsum(self.exp_hist)
        if c[-1] == 0:
            return 0
        return int(np.searchsorted(c, q * c[-1], side="left"))

    # ------------------------------------------------------------ mutation
    def merge(self, other: "TensorStats",
              sample_rows: int = 4096) -> "TensorStats":
        """Fold ``other`` into this accumulator (streaming merge)."""
        self.count += other.count
        self.n_zero += other.n_zero
        self.absmax = max(self.absmax, other.absmax)
        self.total += other.total
        self.sumsq += other.sumsq
        self.exp_hist = self.exp_hist + other.exp_hist
        if other.sample is not None:
            if self.sample is None:
                self.sample = other.sample[:sample_rows]
            elif self.sample.shape[0] < sample_rows:
                room = sample_rows - self.sample.shape[0]
                self.sample = np.concatenate(
                    [self.sample, other.sample[:room]], axis=0)
        return self


# =============================================================================
# in-jit per-tensor reduction
# =============================================================================
def _block_rows(x: jax.Array, block: int) -> jax.Array:
    """Reshape to (rows, block) f32 along the trailing (quantization)
    axis, zero-padding the trailing dim to a block multiple."""
    x = x.astype(jnp.float32)
    d = x.shape[-1]
    pad = (-d) % block
    if pad:
        x = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)])
    return x.reshape(-1, block)

def tensor_reduction(x: jax.Array, block: int = DEFAULT_BLOCK,
                     sample_rows: int = 4096) -> Dict[str, jax.Array]:
    """The jit-friendly reduction: scalar moments + exponent histogram +
    a deterministic leading-rows sample (all device arrays)."""
    rows = _block_rows(x, block)
    flat = rows.reshape(-1)
    bits = jax.lax.bitcast_convert_type(flat, jnp.uint32)
    exp = ((bits >> 23) & jnp.uint32(0xFF)).astype(jnp.int32)
    finite_nz = (exp != 0xFF) & (flat != 0.0)
    hist = jnp.zeros((256,), jnp.int32).at[
        jnp.where(finite_nz, exp, 0)].add(finite_nz.astype(jnp.int32))
    return {
        "count": jnp.asarray(flat.size, jnp.int32),
        "n_zero": jnp.sum(flat == 0.0).astype(jnp.int32),
        "absmax": jnp.max(jnp.abs(flat)),
        "total": jnp.sum(flat),
        "sumsq": jnp.sum(flat * flat),
        "exp_hist": hist,
        "sample": rows[:sample_rows],
    }


def _to_stats(red) -> TensorStats:
    return TensorStats(
        count=int(red["count"]), n_zero=int(red["n_zero"]),
        absmax=float(red["absmax"]), total=float(red["total"]),
        sumsq=float(red["sumsq"]),
        exp_hist=np.asarray(red["exp_hist"], np.int64),
        sample=np.asarray(red["sample"], np.float32))


# =============================================================================
# CalibStats — the full collection result
# =============================================================================
@dataclasses.dataclass
class CalibStats:
    """``stats[role][layer]`` for every collected role; ``n_layers`` uses
    absolute indices (leading dense layers first, then the scanned
    stack), matching ``PolicyTable`` layer numbering."""

    arch: str
    n_layers: int
    n_batches: int
    stats: Dict[str, Dict[int, TensorStats]]

    def role_layers(self, role: str) -> Dict[int, TensorStats]:
        if role not in self.stats:
            raise KeyError(
                f"role {role!r} was not collected; have "
                f"{sorted(self.stats)} (pass it in roles= to "
                f"collect_model_stats)")
        return self.stats[role]


def _layer_weight_leaves(params) -> List[List[Tuple[str, jax.Array]]]:
    """Per absolute layer: the (name, array) matmul weight leaves the
    ``weights`` role quantizes."""
    def leaves(tree):
        flat = jax.tree_util.tree_flatten_with_path(tree)[0]
        out = []
        for path, leaf in flat:
            name = jax.tree_util.keystr(path)
            if leaf.ndim >= 2 and not any(x in name
                                          for x in _WEIGHT_EXCLUDE):
                out.append((name, leaf))
        return out

    per_layer = []
    for dl in params.get("dense_layers", []):
        per_layer.append(leaves(dl))
    n_scan = jax.tree_util.tree_leaves(params["layers"])[0].shape[0]
    for i in range(n_scan):
        sl = jax.tree_util.tree_map(lambda p: p[i], params["layers"])
        per_layer.append(leaves(sl))
    return per_layer


def _lm_loss(model, params, tokens):
    """Next-token cross-entropy (the grads-role calibration signal)."""
    logits, aux = model.forward(params, {"tokens": tokens})
    vocab = model.cfg.vocab
    lp = jax.nn.log_softmax(logits[:, :-1, :vocab].astype(jnp.float32))
    tgt = tokens[:, 1:]
    nll = -jnp.take_along_axis(lp, tgt[..., None], axis=-1)
    return jnp.mean(nll) + 0.01 * aux


def collect_model_stats(model, params,
                        batches: Iterable[np.ndarray], *,
                        roles: Sequence[str] = ROLES_FORWARD + ("weights",),
                        block: int = DEFAULT_BLOCK,
                        sample_rows: int = 4096) -> CalibStats:
    """Collect per-(role, layer) statistics from calibration batches.

    ``model`` is a ``models.registry.Model`` (GQA decoder family for the
    forward-tapped roles); ``batches`` yields ``(B, S)`` int32 token
    arrays.  Weight-role statistics come straight from ``params`` (no
    forward needed); the ``grads`` role, when requested, runs one LM-loss
    backward per batch.  Per-batch reductions run in one jitted call;
    the host merges them streamingly."""
    roles = tuple(roles)
    for r in roles:
        if r not in ALL_ROLES:
            raise ValueError(f"unknown tensor role {r!r}; choose from "
                             f"{list(ALL_ROLES)}")
    cfg = model.cfg
    acc: Dict[str, Dict[int, TensorStats]] = {r: {} for r in roles}

    fwd_roles = tuple(r for r in roles if r in ROLES_FORWARD)
    red = functools.partial(tensor_reduction, block=block,
                            sample_rows=sample_rows)

    @jax.jit
    def _forward_stats(params, tokens):
        _, _, taps = model.forward_calib(params, {"tokens": tokens})
        return {r: [red(t) for t in taps[r]] for r in fwd_roles}

    @jax.jit
    def _grad_stats(params, tokens):
        grads = jax.grad(lambda p: _lm_loss(model, p, tokens))(params)
        out = []
        for lvs in _layer_weight_leaves(grads):
            cat = jnp.concatenate(
                [_block_rows(g.swapaxes(-1, -2), block) for _, g in lvs],
                axis=0)
            out.append(red(cat))
        return out

    n_batches = 0
    for tokens in batches:
        tokens = jnp.asarray(tokens, jnp.int32)
        n_batches += 1
        if fwd_roles:
            per_role = jax.device_get(_forward_stats(params, tokens))
            for role, reds in per_role.items():
                for layer, r in enumerate(reds):
                    acc[role].setdefault(layer, TensorStats()).merge(
                        _to_stats(r), sample_rows)
        if "grads" in roles:
            for layer, r in enumerate(
                    jax.device_get(_grad_stats(params, tokens))):
                acc["grads"].setdefault(layer, TensorStats()).merge(
                    _to_stats(r), sample_rows)

    if "weights" in roles:
        @jax.jit
        def _weight_stats(params):
            out = []
            for lvs in _layer_weight_leaves(params):
                # dense() quantizes 2-D weights along axis 0 and the MoE
                # expert einsums their (e, d_in, d_out) stacks along axis
                # 1 — in both cases the second-to-last axis, so swap it
                # last before cutting block rows
                cat = jnp.concatenate(
                    [_block_rows(w.swapaxes(-1, -2), block)
                     for _, w in lvs], axis=0)
                out.append(red(cat))
            return out

        for layer, r in enumerate(jax.device_get(_weight_stats(params))):
            acc["weights"].setdefault(layer, TensorStats()).merge(
                _to_stats(r), sample_rows)

    n_layers = max((max(d) + 1 for d in acc.values() if d), default=0)
    return CalibStats(arch=cfg.name, n_layers=n_layers,
                      n_batches=n_batches, stats=acc)
