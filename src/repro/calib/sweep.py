"""Candidate-spec sweep: score every ``QuantSpec`` in a search space
against the collected calibration tensors.

Quality comes from ``core.metrics`` — SQNR of the quantize-dequantize
round trip and the block-relative max error — computed on each (role,
layer)'s block sample; cost comes from the spec's storage layout
(``QuantSpec.storage_nbytes`` + the amortized E8M0 scale), through
whatever per-unit cost function the caller supplies (bytes per token for
KV roles via ``serve.paging.spec_side_nbytes``, bytes per parameter for
weights).

The default search space is the paper's six element formats at the
kernel-supported block 32 in OCP mode (the decode kernels' scale layout
is 32-wide, and the sample rows are 32-element blocks).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Sequence

import jax
import numpy as np

from repro.core.convert import quantize_dequantize
from repro.core.formats import DEFAULT_BLOCK, SCALE_BITS, get_format
from repro.core.metrics import max_rel_err_vs_blockmax, sqnr_db
from repro.core.spec import QuantSpec

from repro.calib.stats import CalibStats

DEFAULT_CANDIDATES = tuple(
    QuantSpec(f, "ocp", DEFAULT_BLOCK)
    for f in ("int8", "e4m3", "e5m2", "e3m2", "e2m3", "e2m1"))


def weight_param_nbytes(spec: QuantSpec) -> float:
    """Bytes one weight parameter costs under ``spec`` (element code bits
    + the shared scale amortized over the block)."""
    f = get_format(spec.fmt)
    bits = f.code_bits if spec.packed else 8
    return (bits + SCALE_BITS / spec.block) / 8.0


@dataclasses.dataclass(frozen=True)
class ScoredSpec:
    """One candidate's quality/cost scores on one (role, layer) sample."""

    spec: QuantSpec
    sqnr_db: float
    max_rel_err: float
    nbytes: float          # per role unit (token or parameter)

    def __str__(self) -> str:
        return (f"{self.spec} sqnr={self.sqnr_db:.1f}dB "
                f"mre={self.max_rel_err:.3g} {self.nbytes:.4g}B")


def score_sample(sample: np.ndarray, spec: QuantSpec) -> Dict[str, float]:
    """Quality of quantizing ``sample`` ((rows, block) f32) under
    ``spec``: SQNR (dB) and block-relative max error."""
    x = jax.numpy.asarray(sample, jax.numpy.float32)
    xq = quantize_dequantize(x, spec, axis=-1)
    return {"sqnr_db": float(sqnr_db(x, xq)),
            "max_rel_err": float(max_rel_err_vs_blockmax(x, xq,
                                                         spec.block))}


def sweep_role(stats: CalibStats, role: str,
               cost_fn: Callable[[QuantSpec], float],
               candidates: Sequence[QuantSpec] = DEFAULT_CANDIDATES,
               ) -> Dict[int, List[ScoredSpec]]:
    """Score every candidate on every layer of ``role``.

    Returns ``{layer: [ScoredSpec ...]}`` sorted best-quality-first; the
    per-layer lists all cover the same candidates, so the policy search
    can trade layers against each other under one byte budget.
    """
    if not candidates:
        raise ValueError("empty candidate search space")
    out: Dict[int, List[ScoredSpec]] = {}
    for layer, ts in sorted(stats.role_layers(role).items()):
        if ts.sample is None or ts.sample.size == 0:
            raise ValueError(
                f"role {role!r} layer {layer}: no sample collected "
                f"(collect_model_stats keeps block samples by default)")
        scored = []
        for spec in candidates:
            q = score_sample(ts.sample, spec)
            scored.append(ScoredSpec(spec=spec, sqnr_db=q["sqnr_db"],
                                     max_rel_err=q["max_rel_err"],
                                     nbytes=float(cost_fn(spec))))
        scored.sort(key=lambda s: s.sqnr_db, reverse=True)
        out[layer] = scored
    return out
