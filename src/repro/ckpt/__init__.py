from repro.ckpt.checkpoint import (  # noqa: F401
    latest_step, restore, save_atomic, gc_old,
)
