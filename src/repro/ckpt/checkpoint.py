"""Atomic, mesh-elastic checkpointing.

Layout:  <dir>/step_<N>/   arrays.npz  (flat path -> np array)
                            manifest.json (step, data cursor, tree paths,
                                           user metadata)
Writes go to ``step_<N>.tmp`` then ``os.replace`` — a crash mid-save never
corrupts the latest checkpoint (fault-tolerance requirement).  Restore is
mesh-agnostic: arrays are saved unsharded and re-placed via ``device_put``
with the target sharding, so a job may resume on a different mesh shape
(elastic scaling).  Multi-host note: each host saves its addressable shards
under ``host_<k>`` in the same layout; restore stitches by path (the
single-process container exercises the one-host path).
"""
from __future__ import annotations

import json
import os
import re
import shutil
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                       for k in path)
        flat[key] = np.asarray(leaf)
    return flat


def save_atomic(ckpt_dir: str, step: int, state: Dict[str, Any],
                metadata: Optional[Dict[str, Any]] = None) -> str:
    """state: pytree dict (params/opt_state/...); metadata: JSON-able."""
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    flat = _flatten(state)
    np.savez(os.path.join(tmp, "arrays.npz"), **flat)
    treedef = jax.tree_util.tree_structure(state)
    manifest = {
        "step": step,
        "keys": sorted(flat.keys()),
        "treedef": str(treedef),
        "metadata": metadata or {},
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)
    return final


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(m.group(1)) for d in os.listdir(ckpt_dir)
             if (m := re.fullmatch(r"step_(\d+)", d))]
    return max(steps) if steps else None


def restore(ckpt_dir: str, step: int, like: Any,
            shardings: Any = None) -> Tuple[Any, Dict[str, Any]]:
    """Restore into the structure of ``like`` (shapes must match); if
    ``shardings`` (same pytree of NamedSharding) is given, leaves are placed
    with it — this is the elastic-mesh path."""
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    data = np.load(os.path.join(path, "arrays.npz"))
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    leaves_p, treedef = jax.tree_util.tree_flatten_with_path(like)
    shard_leaves = (jax.tree_util.tree_leaves(shardings)
                    if shardings is not None else [None] * len(leaves_p))
    out = []
    for (pth, leaf), shd in zip(leaves_p, shard_leaves):
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                       for k in pth)
        arr = data[key]
        assert arr.shape == tuple(leaf.shape), (key, arr.shape, leaf.shape)
        arr = arr.astype(leaf.dtype)
        out.append(jax.device_put(arr, shd) if shd is not None
                   else jax.device_put(arr))
    return jax.tree_util.tree_unflatten(treedef, out), manifest["metadata"]


def gc_old(ckpt_dir: str, keep: int = 3) -> None:
    if not os.path.isdir(ckpt_dir):
        return
    steps = sorted(int(m.group(1)) for d in os.listdir(ckpt_dir)
                   if (m := re.fullmatch(r"step_(\d+)", d)))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"),
                      ignore_errors=True)
