"""Assigned architecture configs (exact dims from the public literature).

Every config file exports ``CONFIG`` (the full assigned architecture) and
``reduced()`` (a small same-family config for CPU smoke tests).
"""
from repro.models.registry import ARCH_IDS  # noqa: F401
