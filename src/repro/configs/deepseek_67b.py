"""deepseek-67b [dense] — llama-arch GQA [arXiv:2401.02954].

95L d=8192 64H kv=8 d_ff=22016 vocab=102400.
"""
import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-67b", family="decoder",
    n_layers=95, d_model=8192, n_heads=64, n_kv_heads=8, d_ff=22016,
    vocab=102400, head_dim=128,
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=3, d_model=128, n_heads=4, n_kv_heads=2, d_ff=256,
        vocab=512, head_dim=32, remat=False)
