"""deepseek-v2-236b [moe] — MLA kv_lora=512, 2 shared + 160 routed top-6
[arXiv:2405.04434].

60L d=5120 128H, expert d_ff=1536, vocab=102400; layer 0 dense (ff=12288);
MLA: q_lora=1536, kv_lora=512, qk_nope=128, qk_rope=64, v_head=128.
"""
import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-236b", family="decoder",
    n_layers=60, d_model=5120, n_heads=128, n_kv_heads=128,
    d_ff=12288, vocab=102400,
    n_experts=160, n_shared_experts=2, moe_topk=6, moe_d_ff=1536,
    n_dense_layers=1, capacity_factor=1.25,
    mla=True, q_lora=1536, kv_lora=512, qk_nope_dim=128, qk_rope_dim=64,
    v_head_dim=128,
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=3, d_model=128, n_heads=4, n_kv_heads=4, d_ff=256,
        vocab=512, n_experts=8, n_shared_experts=1, moe_topk=2, moe_d_ff=64,
        n_dense_layers=1, q_lora=48, kv_lora=32, qk_nope_dim=32,
        qk_rope_dim=16, v_head_dim=32, remat=False)
