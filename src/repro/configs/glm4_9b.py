"""glm4-9b [dense] — RoPE (half), GQA kv=2 [hf:THUDM/glm-4-9b].

40L d=4096 32H kv=2 d_ff=13696 vocab=151552.
"""
import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="glm4-9b", family="decoder",
    n_layers=40, d_model=4096, n_heads=32, n_kv_heads=2, d_ff=13696,
    vocab=151552, head_dim=128, rope_frac=0.5,
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, d_ff=256,
        vocab=512, head_dim=32, remat=False)
