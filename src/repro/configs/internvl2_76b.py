"""internvl2-76b [vlm] — InternViT + InternLM2 [arXiv:2404.16821].

Backbone only (the brief): 80L d=8192 64H GQA kv=8 d_ff=28672 vocab=128256.
The ViT frontend is a stub: input_specs feeds 256 precomputed patch
embeddings as prefix tokens.
"""
import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-76b", family="decoder",
    n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8, d_ff=28672,
    vocab=128256, head_dim=128, rope_theta=1_000_000.0,
    prefix_len=256, frontend="patch",
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, d_ff=256,
        vocab=512, head_dim=32, prefix_len=8, remat=False)
