"""moonshot-v1-16b-a3b [moe] — kimi/moonlight 64e top-6
[hf:moonshotai/Moonlight-16B-A3B].

48L d=2048 16H kv=16, expert d_ff=1408, 2 shared experts, vocab=163840;
layer 0 dense (ff=11264).
"""
import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="moonshot-v1-16b-a3b", family="decoder",
    n_layers=48, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=11264, vocab=163840, head_dim=128,
    n_experts=64, n_shared_experts=2, moe_topk=6, moe_d_ff=1408,
    n_dense_layers=1, capacity_factor=1.25,
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=3, d_model=128, n_heads=4, n_kv_heads=4, d_ff=256,
        vocab=512, head_dim=32, n_experts=8, n_shared_experts=2, moe_topk=2,
        moe_d_ff=64, n_dense_layers=1, remat=False)
