"""rwkv6-7b [ssm] — Finch, data-dependent decay linear attention
[arXiv:2404.05892].

32L d=4096 (attention-free) d_ff=14336 vocab=65536.
"""
import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-7b", family="rwkv",
    n_layers=32, d_model=4096, n_heads=64, n_kv_heads=64, d_ff=14336,
    vocab=65536, head_dim=64,
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=128, n_heads=2, n_kv_heads=2, d_ff=256,
        vocab=512, head_dim=64, remat=False)
