"""seamless-m4t-medium [audio] — enc-dec multimodal [arXiv:2308.11596].

12L (12 enc + 12 dec) d=1024 16H MHA d_ff=4096 vocab=256206.  The speech
frontend is a stub: the encoder consumes precomputed frame embeddings.
"""
import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium", family="encdec",
    n_layers=24, n_enc_layers=12, n_dec_layers=12,
    d_model=1024, n_heads=16, n_kv_heads=16, d_ff=4096,
    vocab=256206, head_dim=64, gated_mlp=False, frontend="frames",
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=4, n_enc_layers=2, n_dec_layers=2, d_model=96,
        n_heads=4, n_kv_heads=4, d_ff=192, vocab=512, head_dim=24,
        remat=False)
