"""yi-34b [dense] — llama-arch GQA [arXiv:2403.04652].

60L d=7168 56H kv=8 d_ff=20480 vocab=64000.
"""
import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="yi-34b", family="decoder",
    n_layers=60, d_model=7168, n_heads=56, n_kv_heads=8, d_ff=20480,
    vocab=64000, head_dim=128, rope_theta=5_000_000.0,
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=112, n_heads=7, n_kv_heads=1, d_ff=224,
        vocab=512, head_dim=16, remat=False)
