"""zamba2-1.2b [hybrid] — Mamba2 backbone + shared attn block
[arXiv:2411.15242].

38L d=2048 32H(kv=32, head 64) d_ff=8192 vocab=32000 ssm_state=64.
The shared attention+MLP block (one weight set) is invoked every 2 Mamba2
layers (19 invocation sites, each with its own KV cache).
"""
import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b", family="hybrid",
    n_layers=38, d_model=2048, n_heads=32, n_kv_heads=32, d_ff=8192,
    vocab=32000, head_dim=64, ssm_state=64, ssm_expand=2, d_conv=4,
    attn_every=2,
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=4, d_model=128, n_heads=2, n_kv_heads=2, d_ff=256,
        vocab=512, head_dim=64, ssm_state=16, attn_every=2, remat=False)
