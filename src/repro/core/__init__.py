"""repro.core — the paper's contribution: FP32 -> MX-format conversion."""
from repro.core.formats import (  # noqa: F401
    ALL_FORMATS, DEFAULT_BLOCK, E2M1, E2M3, E3M2, E4M3, E5M2, FORMATS, INT8,
    MXFormat, SCALE_BIAS, SCALE_INF, SCALE_NAN, get_format,
)
from repro.core.spec import (  # noqa: F401
    MODES, PolicyTable, QuantPolicy, QuantSpec, ROLES, as_spec,
    resolve_spec,
)
from repro.core.convert import (  # noqa: F401
    MXArray, block_max_exponent, decode_elements, max_exponent_tree,
    mx_dequantize, mx_error_bound, mx_quantize, pow2_f32, quantize_dequantize,
    scale_to_f32, shared_scale,
)
from repro.core.pack import (  # noqa: F401
    pack_codes, pack_codes_rows, packed_nbytes, unpack_codes,
    unpack_codes_rows,
)
from repro.core.mx_weight import (  # noqa: F401
    MXWeight, mx_weight_nbytes, params_nbytes,
)
from repro.core import metrics  # noqa: F401
