"""FP32 -> MX conversion (the paper's three-step algorithm) in pure JAX.

This is the reference, integer-exact realization of the Gorodecky & Sousa
converter.  Two modes are provided:

``mode="paper"`` — faithful to the paper:
  * step 1: 5-level comparator tree over biased FP32 exponents; non-finite
    inputs (exponent 0xFF) are excluded from the max (the ``comp`` module
    forwards the other operand).
  * step 2: ``X = EV_max - (2^(K-1) - 1)`` clamped at 0 (the paper's ``div``
    module); a block containing NaN gets the marker ``X=0xFF``, a block
    containing +/-Inf (and no NaN) gets ``X=0xFE``.
  * step 3: element biased exponent ``EK = E - X + bias``; elements below the
    normal range are FLUSHED TO ZERO (the paper has no subnormals); the
    mantissa keeps R+1 bits and is rounded to R bits round-to-nearest,
    TIES-AWAY (paper Tables III-VII); a rounding carry at the top exponent
    SATURATES to the largest finite value ("no quantization" rows).

  Paper ambiguities resolved here (see DESIGN.md §1):
  * the underflow test "EK_raw > 2^K" is off by a small constant in the paper;
    the hardware intent (and the worked example V3/V4) is "below the normal
    range" => we flush when the pre-round biased exponent is <= 0.
  * FP32 zeros/subnormals (E == 0) quantize to 0.
  * INT8 (paper gives no table): sign-magnitude 1.6 fixed point,
    mag = ties_away(|v| / 2^(X-127) * 64), clamped to 127.

``mode="ocp"`` — OCP MX spec v1.0 semantics (the beyond-paper production
mode): ``X = EV_max - emax_elem``, full-precision round-to-nearest-EVEN with
sticky bits, subnormal elements encoded, overflow saturates to max finite,
INT8 is two's complement.

Both modes share step 1.  All arithmetic is integer bit-manipulation on
``bitcast(u32)`` views, so the Pallas kernel (repro/kernels/mx_quant.py) can
be asserted bit-identical against this module.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import formats as F
from repro.core.formats import MXFormat, get_format
from repro.core.spec import MODES, QuantSpec, resolve_spec  # noqa: F401

Array = jax.Array

_I32 = jnp.int32
_U32 = jnp.uint32
_U8 = jnp.uint8

# the historical defaults of this module's entry points (paper mode)
_PAPER_DEFAULT = QuantSpec("e4m3", "paper")


# =============================================================================
# MXArray container (pytree)
# =============================================================================
@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class MXArray:
    """A tensor quantized to MX format.

    ``codes``  uint8 — one element code per input value (low bits used for
               sub-byte formats; see repro/core/pack.py for packed storage).
    ``scales`` uint8 — E8M0 shared scale, one per block along ``axis``.
    """

    codes: Array
    scales: Array
    fmt: str                 # static
    mode: str                # static
    block: int               # static
    orig_len: int            # static: unpadded length along the block axis
    axis: int                # static: axis (normalized, >= 0) blocks run along

    # -- pytree protocol ----------------------------------------------------
    def tree_flatten(self):
        return (self.codes, self.scales), (
            self.fmt, self.mode, self.block, self.orig_len, self.axis)

    @classmethod
    def tree_unflatten(cls, aux, children):
        codes, scales = children
        return cls(codes, scales, *aux)

    # -- validated construction ---------------------------------------------
    @classmethod
    def from_spec(cls, codes: Array, scales: Array, spec: QuantSpec, *,
                  orig_len: Optional[int] = None,
                  axis: int = -1) -> "MXArray":
        """The validated constructor: checks fmt/mode/block consistency and
        the codes/scales shape contract before building the container.
        All call sites outside the pytree protocol should use this.

        MXArray codes are always stored one byte per element — the spec's
        ``packed`` storage preference applies to packed consumers (the
        paged KV pool), not to this container."""
        from repro.core.spec import as_spec
        spec = as_spec(spec)          # rejects None/'none' with a clear error
        axis = _normalize_axis(axis, codes.ndim)
        n = codes.shape[axis]
        if n % spec.block:
            raise ValueError(
                f"codes axis {axis} has length {n}, not a multiple of "
                f"block={spec.block}")
        want = list(codes.shape)
        want[axis] = n // spec.block
        if tuple(scales.shape) != tuple(want):
            raise ValueError(
                f"scales shape {tuple(scales.shape)} does not match codes "
                f"{tuple(codes.shape)} blocked by {spec.block} along axis "
                f"{axis} (expected {tuple(want)})")
        orig_len = n if orig_len is None else int(orig_len)
        if not (0 < orig_len <= n) or n - orig_len >= spec.block:
            raise ValueError(
                f"orig_len={orig_len} inconsistent with padded length {n} "
                f"(must satisfy 0 < orig_len <= {n} with less than one "
                f"block of padding)")
        return cls(codes=codes, scales=scales, fmt=spec.fmt, mode=spec.mode,
                   block=spec.block, orig_len=orig_len, axis=axis)

    @property
    def spec(self) -> QuantSpec:
        """The QuantSpec this array was quantized under.  ``packed`` is
        reported False because MXArray codes are stored one byte per
        element regardless of the quantizing spec's storage preference
        (so ``spec.storage_nbytes`` matches this container's layout)."""
        return QuantSpec(self.fmt, self.mode, self.block, packed=False)

    @property
    def format(self) -> MXFormat:
        return get_format(self.fmt)

    @property
    def shape(self) -> Tuple[int, ...]:
        s = list(self.codes.shape)
        s[self.axis] = self.orig_len
        return tuple(s)

    @property
    def nbytes_logical(self) -> float:
        """Storage cost in bytes under ideal bit-packing (for roofline math)."""
        n = int(np.prod(self.shape))
        return n * self.format.bits_per_element() / 8.0

    def dequantize(self) -> Array:
        return mx_dequantize(self)


# =============================================================================
# Bit-level helpers
# =============================================================================
def _f32_fields(x: Array) -> Tuple[Array, Array, Array]:
    """sign (i32 0/1), biased exponent (i32), 23-bit mantissa (i32)."""
    bits = jax.lax.bitcast_convert_type(x.astype(jnp.float32), _U32)
    sign = (bits >> 31).astype(_I32)
    exp = ((bits >> 23) & _U32(0xFF)).astype(_I32)
    man = (bits & _U32(0x7FFFFF)).astype(_I32)
    return sign, exp, man


def pow2_f32(e: Array) -> Array:
    """Exact 2^e as f32 for integer e in [-149, 127], incl. subnormals.

    Split into two in-range halves so each bitcast constructs a normal f32.
    """
    e = e.astype(_I32)
    e1 = jnp.clip(e, -126, 127)
    e2 = e - e1                                  # residual in [-23, 0]
    b1 = ((e1 + 127).astype(_U32) << 23)
    b2 = ((e2 + 127).astype(_U32) << 23)
    return (jax.lax.bitcast_convert_type(b1, jnp.float32)
            * jax.lax.bitcast_convert_type(b2, jnp.float32))


def scale_to_f32(scales: Array) -> Array:
    """Decode E8M0 scale codes to f32 (2^(X-127)); X=0 -> 2^-127 subnormal."""
    return pow2_f32(scales.astype(_I32) - F.SCALE_BIAS)


# =============================================================================
# Step 1 — largest power of two among the block (comparator tree)
# =============================================================================
def max_exponent_tree(exp_eff: Array) -> Array:
    """Pairwise max tree over the trailing (block) axis, exactly mirroring the
    paper's 5-level ``comp`` tree (for block=32).  Non-finite exclusion is the
    caller's job (pass exponents already masked to 0)."""
    x = exp_eff
    while x.shape[-1] > 1:
        x = jnp.maximum(x[..., 0::2], x[..., 1::2])
    return x[..., 0]


def block_max_exponent(exp: Array, finite: Array) -> Array:
    """EV_max per block with non-finite inputs excluded (paper ``comp``)."""
    exp_eff = jnp.where(finite, exp, 0)
    return max_exponent_tree(exp_eff)


# =============================================================================
# Step 2 — shared scale X
# =============================================================================
def shared_scale(ev_max: Array, fmt: MXFormat, mode: str,
                 any_nan: Array, any_inf: Array) -> Array:
    if mode == "paper":
        sub = fmt.bias            # paper: subtract the element bias
    else:
        sub = fmt.emax_ocp        # ocp: subtract the element emax
    x = jnp.maximum(ev_max - sub, 0)
    x = jnp.minimum(x, 0xFD if mode == "paper" else 0xFE)
    if mode == "paper":
        x = jnp.where(any_inf, F.SCALE_INF, x)
        x = jnp.where(any_nan, F.SCALE_NAN, x)
    else:
        x = jnp.where(any_nan | any_inf, F.SCALE_NAN, x)
    return x.astype(_U8)


# =============================================================================
# Step 3 — per-element quantization
# =============================================================================
def _quant_float_paper(sign: Array, exp: Array, man: Array, xblk: Array,
                       fmt: MXFormat, sign_erratum: bool = False) -> Array:
    """Paper-mode EKMR element quantization (integer-exact).

    ``sign_erratum=True`` reproduces the paper's ±E rule bit-exactly: for
    negative inputs the hardware computes ``EK_raw = X + bias + E`` (worked
    example V4), which flushes nearly every negative element to -0.  The
    framework default is the corrected magnitude-based rule (the paper's own
    Tables III-VII are sign-independent, as is the MX definition [1,2]).
    """
    K, R, bias = fmt.ebits, fmt.mbits, fmt.bias
    eb = exp - xblk.astype(_I32) + bias          # tentative biased elem exp
    if sign_erratum:
        # EK_raw = X + bias -+ E ; flush when EK_raw > 2^K (paper text).
        ek_raw = xblk.astype(_I32) + bias + jnp.where(sign == 1, exp, -exp)
        eb = jnp.where(ek_raw > (1 << K), -1, eb)   # force the flush branch
    # round R+1 kept mantissa bits to R, ties-away (Tables III-VII)
    kept = man >> (23 - (R + 1))                 # R+1 bits
    rnd = (kept + 1) >> 1
    carry = rnd >> R
    mant = jnp.where(carry > 0, 0, rnd) & fmt.mant_mask
    eb2 = eb + carry
    # saturate at the largest finite ("no quantization" rows)
    sat = eb2 > fmt.max_exp_paper
    mant = jnp.where(sat, fmt.mant_mask, mant)
    eb2 = jnp.minimum(eb2, fmt.max_exp_paper)
    # flush-to-zero below the normal range (paper has no subnormals);
    # FP32 zeros/subnormals (exp==0) also flush.
    zero = (eb <= 0) | (exp == 0)
    body = jnp.where(zero, 0, (eb2 << R) | mant)
    return ((sign << fmt.sign_shift) | body).astype(_U8)


def _quant_float_ocp(sign: Array, exp: Array, man: Array, xblk: Array,
                     fmt: MXFormat) -> Array:
    """OCP-mode EKMR element quantization: full-sticky RNE + subnormals."""
    K, R, bias = fmt.ebits, fmt.mbits, fmt.bias
    eb = exp - xblk.astype(_I32) + bias
    sig = (1 << 23) | man                        # 24-bit significand
    sh_sub = jnp.maximum(0, 1 - eb)              # extra shift into subnormals
    shift = jnp.clip((23 - R) + sh_sub, 0, 30)
    low = sig & ((1 << shift) - 1)
    half = (1 << shift) >> 1
    q = sig >> shift
    round_up = (low > half) | ((low == half) & ((q & 1) == 1))
    q = q + round_up.astype(_I32)
    # Normal path: q in [2^R, 2^(R+1)]; carry renormalizes.
    ebn = jnp.maximum(eb, 1)
    ncarry = q >> (R + 1)                        # 1 iff q == 2^(R+1)
    qn = jnp.where(ncarry > 0, 1 << R, q)
    ebn = ebn + ncarry
    mant_n = qn - (1 << R)
    # Subnormal path (eb <= 0): q in [0, 2^R]; q == 2^R promotes to min normal.
    promote = q >> R
    mant_s = jnp.where(promote > 0, 0, q)
    eb_s = promote                               # 0 (subnormal) or 1
    is_sub = eb <= 0
    mant = jnp.where(is_sub, mant_s, mant_n)
    ebf = jnp.where(is_sub, eb_s, ebn)
    # Overflow -> saturate to max finite (E4M3 reserves 1111|111 = NaN).
    top_e, top_m = fmt.max_exp_ocp, fmt.max_mant_at_top_ocp
    over = (ebf > top_e) | ((ebf == top_e) & (mant > top_m))
    mant = jnp.where(over, top_m, mant)
    ebf = jnp.where(over, top_e, ebf)
    # FP32 zeros/subnormals quantize to (signed) zero.
    zero = exp == 0
    body = jnp.where(zero, 0, (ebf << R) | mant)
    return ((sign << fmt.sign_shift) | body).astype(_U8)


def _quant_int8(sign: Array, exp: Array, man: Array, xblk: Array,
                mode: str) -> Array:
    """INT8 element: value = m * 2^(X-127), m has 6 fractional bits."""
    fmt = F.INT8
    e_u = exp - xblk.astype(_I32)                # unbiased scaled exponent
    sig = (1 << 23) | man
    # magnitude in 1/64 units: sig * 2^(e_u + 6 - 23)  => shift = 17 - e_u
    shift = jnp.clip(17 - e_u, 0, 30)
    low = sig & ((1 << shift) - 1)
    half = (1 << shift) >> 1
    q = sig >> shift
    if mode == "paper":                          # ties-away
        q = q + (low >= half).astype(_I32) * (half > 0)
    else:                                        # RNE
        q = q + ((low > half) | ((low == half) & ((q & 1) == 1))).astype(_I32)
    q = jnp.where(exp == 0, 0, q)                # FP32 zero/subnormal
    if mode == "paper":                          # sign-magnitude
        mag = jnp.minimum(q, 127)
        return ((sign << 7) | mag).astype(_U8)
    # ocp: two's complement in [-128, 127]
    signed = jnp.where(sign == 1, -q, q)
    signed = jnp.clip(signed, -128, 127)
    return jax.lax.bitcast_convert_type(signed.astype(jnp.int8), _U8)


def _marker_codes(sign: Array, fmt: MXFormat, kind: str) -> Array:
    """Paper NaN/Inf element markers: top exponent + nan_mantissa / 0."""
    if fmt.is_int:
        mag = 127 if kind == "nan" else 126
        return ((sign << 7) | mag).astype(_U8)
    mant = fmt.nan_mantissa if kind == "nan" else 0
    body = (fmt.exp_mask << fmt.mbits) | mant
    return ((sign << fmt.sign_shift) | body).astype(_U8)


# =============================================================================
# Public API
# =============================================================================
def _normalize_axis(axis: int, ndim: int) -> int:
    axis = axis % ndim
    return axis


def _to_blocked(x: Array, block: int, axis: int) -> Tuple[Array, int]:
    """Move ``axis`` last and zero-pad to a block multiple."""
    x = jnp.moveaxis(x, axis, -1)
    n = x.shape[-1]
    pad = (-n) % block
    if pad:
        x = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)])
    return x, n


def mx_quantize(x: Array, spec=None, mode: Optional[str] = None,
                block: Optional[int] = None, axis: int = -1,
                sign_erratum: bool = False, *,
                fmt: Optional[str] = None) -> MXArray:
    """Convert a float tensor to MX format along ``axis`` (paper steps 1-3).

    ``spec`` is a :class:`QuantSpec` (or a spec string such as
    ``"e4m3@32:ocp"``); the default is the paper-faithful
    ``e4m3@32:paper``.  The ``fmt=``/``mode=``/``block=`` keyword form is
    a deprecation shim (warns once)."""
    spec = resolve_spec(spec, fmt, mode, block, default=_PAPER_DEFAULT,
                        caller="mx_quantize")
    return _mx_quantize(x, spec, axis, sign_erratum)


@functools.partial(jax.jit, static_argnames=("spec", "axis", "sign_erratum"))
def _mx_quantize(x: Array, spec: QuantSpec, axis: int,
                 sign_erratum: bool) -> MXArray:
    f = spec.format
    mode, block = spec.mode, spec.block
    axis = _normalize_axis(axis, x.ndim)
    xb, orig_len = _to_blocked(x, block, axis)
    lead = xb.shape[:-1]
    nblk = xb.shape[-1] // block
    xg = xb.reshape(lead + (nblk, block))

    sign, exp, man = _f32_fields(xg)
    finite = exp != 0xFF
    is_nan = (~finite) & (man != 0)
    is_inf = (~finite) & (man == 0)
    any_nan = jnp.any(is_nan, axis=-1)
    any_inf = jnp.any(is_inf, axis=-1)

    ev_max = block_max_exponent(exp, finite)                     # step 1
    xscale = shared_scale(ev_max, f, mode, any_nan, any_inf)     # step 2

    xblk = jnp.broadcast_to(xscale[..., None].astype(_I32), xg.shape)
    if f.is_int:                                                 # step 3
        codes = _quant_int8(sign, exp, man, xblk, mode)
    elif mode == "paper":
        codes = _quant_float_paper(sign, exp, man, xblk, f,
                                   sign_erratum=sign_erratum)
    else:
        codes = _quant_float_ocp(sign, exp, man, xblk, f)

    if mode == "paper":
        # NaN/Inf markers poison the whole block (paper div/P_i rules).
        blk_nan = jnp.broadcast_to(any_nan[..., None], xg.shape)
        blk_inf = jnp.broadcast_to(any_inf[..., None], xg.shape)
        codes = jnp.where(blk_inf, _marker_codes(sign, f, "inf"), codes)
        codes = jnp.where(blk_nan, _marker_codes(sign, f, "nan"), codes)
    else:
        # ocp: X=NaN poisons on dequant; keep per-element NaN codes where
        # the format can express them, else max-finite.
        pass

    codes = codes.reshape(lead + (nblk * block,))
    # undo the moveaxis: element codes and per-block scales both return to
    # having their block dimension at ``axis``
    codes = jnp.moveaxis(codes, -1, axis)
    scales = jnp.moveaxis(xscale, -1, axis)
    return MXArray.from_spec(codes, scales, spec, orig_len=orig_len,
                             axis=axis)


def decode_elements(codes: Array, fmt: MXFormat, mode: str) -> Array:
    """Element code -> f32 value relative to the scale (no scale applied)."""
    c = codes.astype(_I32)
    if fmt.is_int:
        if mode == "paper":                      # sign-magnitude 1.6
            sign = (c >> 7) & 1
            mag = (c & 0x7F).astype(jnp.float32) / 64.0
            return jnp.where(sign == 1, -mag, mag)
        i8 = jax.lax.bitcast_convert_type(codes.astype(_U8), jnp.int8)
        return i8.astype(jnp.float32) / 64.0
    R, bias = fmt.mbits, fmt.bias
    sign = (c >> fmt.sign_shift) & 1
    e = (c >> R) & fmt.exp_mask
    m = c & fmt.mant_mask
    frac = m.astype(jnp.float32) / float(1 << R)
    if mode == "ocp":
        sub = e == 0
        val = jnp.where(sub,
                        frac * pow2_f32(jnp.full_like(e, 1 - bias)),
                        (1.0 + frac) * pow2_f32(e - bias))
        if fmt.has_ieee_specials:
            top = e == fmt.exp_mask
            val = jnp.where(top & (m == 0), jnp.inf, val)
            val = jnp.where(top & (m != 0), jnp.nan, val)
        if fmt.e4m3_style_nan:
            val = jnp.where((e == fmt.exp_mask) & (m == fmt.mant_mask),
                            jnp.nan, val)
    else:
        # paper: exp==0 codes are true zeros (FTZ); no subnormals.
        val = jnp.where(e == 0, 0.0, (1.0 + frac) * pow2_f32(e - bias))
        top = e == fmt.exp_mask                  # paper marker space
        val = jnp.where(top & (m == 0), jnp.inf, val)
        val = jnp.where(top & (m != 0), jnp.nan, val)
    return jnp.where(sign == 1, -val, val)


def mx_dequantize(mx: MXArray) -> Array:
    """MXArray -> f32 tensor (the backward transformation)."""
    f = mx.format
    codes = jnp.moveaxis(mx.codes, mx.axis, -1)
    scales = jnp.moveaxis(mx.scales, mx.axis, -1)
    lead = codes.shape[:-1]
    nblk = scales.shape[-1]
    cg = codes.reshape(lead + (nblk, mx.block))
    elem = decode_elements(cg, f, mx.mode)
    sfac = scale_to_f32(scales)[..., None]
    val = elem * sfac
    if mx.mode == "paper":
        snan = scales == F.SCALE_NAN
        sinf = scales == F.SCALE_INF
        val = jnp.where(snan[..., None], jnp.nan, val)
        sgn = jnp.where((cg >> f.sign_shift) & 1 == 1, -1.0, 1.0)
        val = jnp.where(sinf[..., None], sgn * jnp.inf, val)
    else:
        val = jnp.where((scales == F.SCALE_NAN)[..., None], jnp.nan, val)
    val = val.reshape(lead + (nblk * mx.block,))[..., :mx.orig_len]
    return jnp.moveaxis(val, -1, mx.axis)


def quantize_dequantize(x: Array, spec=None, mode: Optional[str] = None,
                        block: Optional[int] = None, axis: int = -1, *,
                        fmt: Optional[str] = None) -> Array:
    """Fake-quantization round trip (used for QAT-style layers and tests).
    Spec-based like :func:`mx_quantize`; old kwargs warn once."""
    spec = resolve_spec(spec, fmt, mode, block, default=_PAPER_DEFAULT,
                        caller="quantize_dequantize")
    return mx_dequantize(_mx_quantize(x, spec, axis, False))


def mx_error_bound(spec: "QuantSpec | str | MXFormat" = "e4m3",
                   mode: Optional[str] = None) -> float:
    """Worst-case |dequant(quant(v)) - v| / 2^(X-127+emax-ish) style bound:
    relative to the largest block element, error <= 2^-mbits (paper keeps
    R+1 bits then rounds ties-away) — used by property tests.  The bound
    depends only on the element format; ``mode`` is a legacy no-op."""
    del mode
    f = spec.format if isinstance(spec, QuantSpec) else get_format(
        spec if isinstance(spec, MXFormat) else QuantSpec.parse(spec).fmt)
    if f.is_int:
        return 2.0 ** (-f.int_frac_bits)         # 1/64 ulp at scale
    # one ulp at the top binade of the block: 2^(emax_unbiased - R)
    return 2.0 ** (-f.mbits)
