"""MX-format descriptors (paper Table I + OCP MX spec constants).

The paper considers six element formats sharing an 8-bit E8M0 scale per
32-element block: E5M2, E4M3, E3M2, E2M3, E2M1 and INT8.  ``MXFormat``
captures both the paper's parameterization (K exponent bits, R mantissa
bits, bias = 2^(K-1)-1) and the OCP MX spec constants (emax, max finite,
NaN/Inf encodability) needed for the spec-compliant "ocp" mode.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

SCALE_BITS = 8          # w: shared scale X is E8M0
SCALE_BIAS = 127        # X encodes 2^(X-127)
SCALE_NAN = 0xFF        # paper: X == 11111111 -> block is NaN
SCALE_INF = 0xFE        # paper: X == 11111110 -> block is +/-Inf marker
DEFAULT_BLOCK = 32      # n: paper converts 32 FP32 values per block


@dataclasses.dataclass(frozen=True)
class MXFormat:
    """One EKMR element format (sign bit implicit, per paper Table I)."""

    name: str
    ebits: int                 # K
    mbits: int                 # R
    is_int: bool = False       # INT8 is scaled fixed-point, not EKMR float
    emax_ocp: int = 0          # OCP spec emax of the element format
    nan_mantissa: int = 0      # paper's NaN marker mantissa (w/ top exponent)
    has_ieee_specials: bool = False  # ocp mode: top exponent reserved (E5M2)
    e4m3_style_nan: bool = False     # ocp mode: only S.1111.111 is NaN

    # ------------------------------------------------------------------ paper
    @property
    def bias(self) -> int:
        """Element exponent bias; the paper uses 2^(K-1)-1 (0 for INT8)."""
        return (1 << (self.ebits - 1)) - 1 if self.ebits > 1 else 0

    @property
    def code_bits(self) -> int:
        return 1 + self.ebits + self.mbits

    @property
    def max_exp_paper(self) -> int:
        """Largest biased element exponent the paper emits (2^K - 2)."""
        return (1 << self.ebits) - 2

    # -------------------------------------------------------------------- ocp
    @property
    def max_exp_ocp(self) -> int:
        """Largest biased exponent usable for finite values in ocp mode."""
        if self.has_ieee_specials:          # E5M2: top exponent = Inf/NaN
            return (1 << self.ebits) - 2
        return (1 << self.ebits) - 1        # E4M3/E3M2/E2M3/E2M1: no Inf

    @property
    def max_mant_at_top_ocp(self) -> int:
        """Largest mantissa allowed at max_exp_ocp (E4M3 reserves 111=NaN)."""
        full = (1 << self.mbits) - 1
        return full - 1 if self.e4m3_style_nan else full

    # ------------------------------------------------------------------ both
    @property
    def mant_mask(self) -> int:
        return (1 << self.mbits) - 1

    @property
    def exp_mask(self) -> int:
        return (1 << self.ebits) - 1

    @property
    def sign_shift(self) -> int:
        return self.ebits + self.mbits

    @property
    def int_frac_bits(self) -> int:
        """INT8: fractional bits of the 2's-complement / sign-magnitude value."""
        return self.mbits  # 6 for INT8 (value = m / 64)

    def bits_per_element(self) -> float:
        """Storage bits per element incl. the amortized shared scale."""
        return self.code_bits + SCALE_BITS / DEFAULT_BLOCK


E5M2 = MXFormat("e5m2", 5, 2, emax_ocp=15, nan_mantissa=0b10,
                has_ieee_specials=True)
E4M3 = MXFormat("e4m3", 4, 3, emax_ocp=8, nan_mantissa=0b111,
                e4m3_style_nan=True)
E3M2 = MXFormat("e3m2", 3, 2, emax_ocp=4, nan_mantissa=0b10)
E2M3 = MXFormat("e2m3", 2, 3, emax_ocp=2, nan_mantissa=0b110)
E2M1 = MXFormat("e2m1", 2, 1, emax_ocp=2, nan_mantissa=0b1)
INT8 = MXFormat("int8", 1, 6, is_int=True, emax_ocp=0)

FORMATS: Dict[str, MXFormat] = {
    f.name: f for f in (E5M2, E4M3, E3M2, E2M3, E2M1, INT8)
}

FP8_FORMATS: Tuple[MXFormat, ...] = (E5M2, E4M3)
FP6_FORMATS: Tuple[MXFormat, ...] = (E3M2, E2M3)
FP4_FORMATS: Tuple[MXFormat, ...] = (E2M1,)
ALL_FORMATS: Tuple[MXFormat, ...] = tuple(FORMATS.values())


def poison_threshold(mode: str) -> int:
    """Smallest E8M0 scale byte that marks a non-finite block under
    ``mode``.  Paper mode clamps legitimate scales to 0xFD and encodes
    Inf/NaN blocks as SCALE_INF/SCALE_NAN, so anything >= SCALE_INF is a
    marker; ocp mode uses the full 0xFE range for finite scales and folds
    both specials into SCALE_NAN.  A uint8 ``scale >= threshold`` compare
    is therefore a complete poison detector — no dequantization needed
    (the serving health guards rely on this)."""
    if mode not in ("paper", "ocp"):
        raise ValueError(f"unknown MX mode {mode!r}")
    return SCALE_INF if mode == "paper" else SCALE_NAN


def get_format(name: str | MXFormat) -> MXFormat:
    if isinstance(name, MXFormat):
        return name
    try:
        return FORMATS[name.lower()]
    except KeyError as e:
        raise ValueError(
            f"unknown MX format {name!r}; choose from {sorted(FORMATS)}"
        ) from e
