"""MX-compressed gradient collectives (the paper's converter as a
distributed-optimization trick).

The exchange pattern (inside ``shard_map`` over the data-parallel axes):

    psum_scatter (f32)  ->  mx_quantize (8.25 bit)  ->  all_gather (u8)
                        ->  mx_dequantize

The reduction itself stays f32 (sums of quantized values would accumulate
bias); only the *broadcast half* of the all-reduce is compressed, cutting
exchanged bytes from 2x f32-size to (1x f32 + 0.26x) — a 2.6x byte
reduction on the wire, and ~7.8x on the inter-pod hop when the scatter is
hierarchical (intra-pod first).  Error is bounded per 32-block by the format
ulp (tests assert it).
"""
from __future__ import annotations

from typing import Optional, Sequence

import jax
import jax.numpy as jnp

from repro.core.convert import MXArray, mx_dequantize, mx_quantize
from repro.core.spec import QuantSpec, resolve_spec
from repro.dist import compat

AxisNames = Sequence[str]

_GRAD_DEFAULT = QuantSpec("e4m3", "ocp")


def _grads_spec(spec, fmt, mode, block) -> QuantSpec:
    """Resolve the exchange spec: explicit arg > legacy kwargs > the
    ``grads`` role of the policy installed with the sharding rules >
    the e4m3/ocp default."""
    if spec is None and fmt is None and mode is None and block is None:
        from repro.dist.sharding import quant_spec_for
        rule = quant_spec_for("grads")
        if rule is not None:
            return rule
    return resolve_spec(spec, fmt, mode, block, default=_GRAD_DEFAULT,
                        caller="mx_allreduce")


def mx_allreduce_mean(g: jax.Array, axis_names: AxisNames, spec=None,
                      mode: Optional[str] = None,
                      block: Optional[int] = None, *,
                      fmt: Optional[str] = None) -> jax.Array:
    """All-reduce-mean of ``g`` over ``axis_names`` with MX-compressed
    gather.  Must run inside shard_map with those axes manual.  ``spec``
    is a QuantSpec (default: the policy's ``grads`` role if sharding rules
    carry one, else e4m3/ocp); ``fmt=``/``mode=`` kwargs are the
    deprecation shim."""
    spec = _grads_spec(spec, fmt, mode, block)
    block = spec.block
    names = tuple(axis_names)
    n = 1
    for a in names:
        n *= compat.axis_size(a)
    if n == 1:
        return g
    shape = g.shape
    flat = g.astype(jnp.float32).reshape(-1)
    pad = (-flat.shape[0]) % (n * block)
    if pad:
        flat = jnp.pad(flat, (0, pad))
    # hierarchical f32 reduce-scatter: outer axis (pod) first, then inner —
    # each step leaves this device with a 1/k shard of the partial sums
    x = flat
    for a in names:
        k = compat.axis_size(a)
        x = jax.lax.psum_scatter(x.reshape(k, -1), a,
                                 scatter_dimension=0, tiled=False)
    shard = x.reshape(-1) / n
    # compress the owned shard, all-gather codes+scales, decompress
    mx = mx_quantize(shard, spec)
    codes, scales = mx.codes, mx.scales
    for a in reversed(names):
        codes = jax.lax.all_gather(codes, a, tiled=True)
        scales = jax.lax.all_gather(scales, a, tiled=True)
    out = mx_dequantize(MXArray.from_spec(codes, scales, spec, axis=0))
    return out[: g.size].reshape(shape).astype(g.dtype)


def mx_allreduce_tree(grads, axis_names: AxisNames, spec=None,
                      mode: Optional[str] = None, *,
                      fmt: Optional[str] = None
                      ) -> "jax.tree_util.PyTreeDef":
    """Apply mx_allreduce_mean over every leaf of a gradient pytree."""
    spec = _grads_spec(spec, fmt, mode, None)
    return jax.tree_util.tree_map(
        lambda g: mx_allreduce_mean(g, axis_names, spec), grads)


def exchanged_bytes(n_params: int, n_devices: int,
                    spec: "QuantSpec | str" = "e4m3",
                    compressed: bool = True) -> float:
    """Analytic wire bytes per device for one gradient all-reduce (ring):
    baseline f32 ring all-reduce moves 2 * P * 4 * (n-1)/n bytes;
    compressed: scatter f32 (P*4*(n-1)/n) + gather MX (P*1.03*(n-1)/n)."""
    from repro.core.spec import as_spec
    f = (n_devices - 1) / n_devices
    if not compressed:
        return 2 * n_params * 4 * f
    mx_b = as_spec(spec).format.bits_per_element() / 8.0
    return (n_params * 4 + n_params * mx_b) * f
