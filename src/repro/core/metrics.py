"""Quantization-quality metrics for MX conversion (benchmark substrate)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def sqnr_db(x: jax.Array, xq: jax.Array) -> jax.Array:
    """Signal-to-quantization-noise ratio in dB (higher is better)."""
    x = x.astype(jnp.float32)
    err = x - xq.astype(jnp.float32)
    ps = jnp.mean(x * x)
    pn = jnp.mean(err * err) + 1e-30
    return 10.0 * jnp.log10(ps / pn)


def max_rel_err_vs_blockmax(x: jax.Array, xq: jax.Array,
                            block: int = 32) -> jax.Array:
    """max |x - xq| / max|block| — the natural error scale for a shared-scale
    format (each element's ulp is set by the block maximum).

    When the trailing dim is shorter than ``block`` the whole row is one
    (short) block: the error is scaled by the full-row max instead of
    reducing over zero blocks (which used to yield ``-inf``)."""
    d = x.shape[-1]
    if d < block:
        block = d                     # fall back to the full-row max
    n = d // block * block
    xb = x[..., :n].reshape(x.shape[:-1] + (-1, block)).astype(jnp.float32)
    qb = xq[..., :n].reshape(x.shape[:-1] + (-1, block)).astype(jnp.float32)
    bmax = jnp.max(jnp.abs(xb), axis=-1, keepdims=True) + 1e-30
    return jnp.max(jnp.abs(xb - qb) / bmax)


def mse(x: jax.Array, xq: jax.Array) -> jax.Array:
    d = x.astype(jnp.float32) - xq.astype(jnp.float32)
    return jnp.mean(d * d)
