"""Weight-resident MX storage: the serve-time weight container.

An ``MXWeight`` holds a matmul weight W (..., K, N) entirely in MX form:

  * ``codes``  uint8 — element codes along the contraction axis (axis -2),
    bit-packed via ``pack_codes_rows`` when the spec is packed and sub-byte
    (E2M1: 2 codes/byte; E3M2/E2M3: 4 codes/3 bytes), so HBM holds
    ``spec.storage_nbytes(K)`` byte rows instead of K fp rows.
  * ``scales`` uint8 — E8M0 shared scales, one per ``block`` rows:
    (..., K/32, N).

fp weights are never materialized back to HBM: the fused matmul kernel
(``kernels.mx_matmul``) unpacks code tiles and applies scales in VMEM inside
the tile loop.  Leading batch axes (scan-stacked layers, MoE expert dims)
ride along — MXWeight is a registered pytree with static format metadata,
so ``lax.scan`` slicing and ``tree_map`` indexing preserve the spec.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.convert import MXArray, mx_dequantize, mx_quantize
from repro.core.pack import pack_codes_rows, unpack_codes_rows
from repro.core.spec import QuantSpec, as_spec

Array = jax.Array


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class MXWeight:
    """A weight-resident MX matmul operand: packed codes + E8M0 scales."""
    codes: Array             # (..., storage_nbytes(Kp), N) u8 if packed
    #                          else (..., Kp, N) u8
    scales: Array            # (..., Kp // block, N) u8
    fmt: str                 # static: element format name
    mode: str                # static: "paper" | "ocp"
    block: int               # static: codes per shared scale
    packed: bool             # static: sub-byte codes bit-packed along K
    k: int                   # static: logical (unpadded) contraction length
    n: int                   # static: output width

    def tree_flatten(self):
        return ((self.codes, self.scales),
                (self.fmt, self.mode, self.block, self.packed,
                 self.k, self.n))

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, *aux)

    @property
    def spec(self) -> QuantSpec:
        return QuantSpec(self.fmt, self.mode, self.block, self.packed)

    @property
    def kp(self) -> int:
        """Contraction length padded up to a block multiple."""
        return self.scales.shape[-2] * self.block

    @property
    def nbytes(self) -> int:
        """HBM bytes as stored (codes + scales, one byte per element)."""
        return int(np.prod(self.codes.shape) + np.prod(self.scales.shape))

    @classmethod
    def quantize(cls, w: Array, spec) -> "MXWeight":
        """Quantize W (..., K, N) along the contraction axis (-2)."""
        spec = as_spec(spec)
        if w.ndim < 2:
            raise ValueError(f"MXWeight needs a (..., K, N) weight, "
                             f"got shape {tuple(w.shape)}")
        k, n = w.shape[-2], w.shape[-1]
        mx = mx_quantize(w.astype(jnp.float32), spec, axis=w.ndim - 2)
        codes = mx.codes
        packed = bool(spec.packed and spec.format.code_bits < 8)
        if packed:
            codes = pack_codes_rows(codes, spec.fmt)
        return cls(codes=codes, scales=mx.scales, fmt=spec.fmt,
                   mode=spec.mode, block=spec.block, packed=packed,
                   k=int(k), n=int(n))

    def unpacked_codes(self) -> Array:
        """Codes with the bit-packing undone: (..., Kp, N) u8."""
        if not self.packed:
            return self.codes
        return unpack_codes_rows(self.codes, self.fmt, self.kp)

    def dequantize(self) -> Array:
        """Materialize the f32 weight (..., K, N) — fallback path only."""
        codes = self.unpacked_codes()
        mx = MXArray.from_spec(
            codes, self.scales,
            QuantSpec(self.fmt, self.mode, self.block, packed=False),
            orig_len=self.k, axis=codes.ndim - 2)
        return mx_dequantize(mx)

    def take(self, i: int) -> "MXWeight":
        """Slice one entry off the leading batch axis (e.g. one MoE expert)."""
        return dataclasses.replace(self, codes=self.codes[i],
                                   scales=self.scales[i])


def mx_weight_nbytes(k: int, n: int, spec) -> int:
    """Analytic HBM bytes for one (K, N) weight stored per ``spec``.

    ``storage_nbytes`` bytes of codes per column plus one E8M0 byte per
    block of 32 rows — e.g. packed E2M1 at block 32 is 4 + 8/32 = 4.25
    bits/weight vs 32 for f32.
    """
    spec = as_spec(spec)
    kp = -(-k // spec.block) * spec.block
    return spec.storage_nbytes(kp) * n + (kp // spec.block) * n


def params_nbytes(params) -> int:
    """Total bytes of a param pytree as stored (MXWeight leaves flatten to
    their uint8 codes/scales; fp leaves count at their dtype width)."""
    return int(sum(int(np.prod(leaf.shape)) * leaf.dtype.itemsize
                   for leaf in jax.tree_util.tree_leaves(params)))
