"""Bit-packed storage for sub-byte MX element codes.

The paper's converter emits 8/6/4-bit private elements.  On TPU the HBM win
of FP6/FP4 only materializes if codes are actually bit-packed; this module
provides the pack/unpack transforms used by the weight-storage path:

  * E2M1 (4-bit): 2 codes / byte
  * E3M2, E2M3 (6-bit): 4 codes / 3 bytes
  * E5M2, E4M3, INT8 (8-bit): identity

Packing always operates on the trailing axis, which must be a multiple of
``DEFAULT_BLOCK`` (guaranteed by mx_quantize's padding).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.formats import MXFormat, get_format

_U8 = jnp.uint8


def packed_nbytes(fmt: MXFormat | str, n: int) -> int:
    f = get_format(fmt)
    if f.code_bits <= 4:
        return (n + 1) // 2
    if f.code_bits <= 6:
        return (n + 3) // 4 * 3
    return n


def pack_codes(codes: jax.Array, fmt: MXFormat | str) -> jax.Array:
    """uint8 codes (values < 2^code_bits) -> packed uint8 stream."""
    f = get_format(fmt)
    if f.code_bits == 8:
        return codes
    c = codes.astype(jnp.uint32)
    lead, n = codes.shape[:-1], codes.shape[-1]
    if f.code_bits <= 4:                     # 2 per byte: [lo | hi<<4]
        assert n % 2 == 0, "4-bit packing needs an even trailing axis"
        pair = c.reshape(lead + (n // 2, 2))
        out = pair[..., 0] | (pair[..., 1] << 4)
        return out.astype(_U8)
    # 6-bit: 4 codes -> 3 bytes, little-endian bit order
    assert n % 4 == 0, "6-bit packing needs a trailing axis multiple of 4"
    quad = c.reshape(lead + (n // 4, 4))
    w = (quad[..., 0] | (quad[..., 1] << 6) | (quad[..., 2] << 12)
         | (quad[..., 3] << 18))             # 24 bits
    b0 = w & 0xFF
    b1 = (w >> 8) & 0xFF
    b2 = (w >> 16) & 0xFF
    return jnp.stack([b0, b1, b2], axis=-1).reshape(
        lead + (n // 4 * 3,)).astype(_U8)


def pack_codes_rows(codes: jax.Array, fmt: MXFormat | str) -> jax.Array:
    """Pack along axis -2 (a weight's contraction axis).

    codes (..., K, N) -> (..., packed_nbytes(K), N): byte r of the output
    holds the same codes as byte r of ``pack_codes`` applied to each column,
    so a row slice [r0:r0+packed_nbytes(BK)] is exactly the packed form of
    code rows [k0:k0+BK] when k0/BK are multiples of 4 — which lets the
    matmul kernel fetch packed tiles with a plain BlockSpec and unpack them
    in VMEM.
    """
    f = get_format(fmt)
    if f.code_bits == 8:
        return codes
    c = codes.astype(jnp.uint32)
    lead, (k, n) = codes.shape[:-2], codes.shape[-2:]
    if f.code_bits <= 4:                     # 2 rows per byte row
        assert k % 2 == 0, "4-bit packing needs an even code-row count"
        pair = c.reshape(lead + (k // 2, 2, n))
        out = pair[..., 0, :] | (pair[..., 1, :] << 4)
        return out.astype(_U8)
    # 6-bit: 4 code rows -> 3 byte rows, little-endian bit order
    assert k % 4 == 0, "6-bit packing needs a code-row count multiple of 4"
    quad = c.reshape(lead + (k // 4, 4, n))
    w = (quad[..., 0, :] | (quad[..., 1, :] << 6) | (quad[..., 2, :] << 12)
         | (quad[..., 3, :] << 18))          # 24 bits per column
    b = jnp.stack([w & 0xFF, (w >> 8) & 0xFF, (w >> 16) & 0xFF], axis=-2)
    return b.reshape(lead + (k // 4 * 3, n)).astype(_U8)


def unpack_codes_rows(packed: jax.Array, fmt: MXFormat | str,
                      k: int) -> jax.Array:
    """Inverse of ``pack_codes_rows``: (..., nbytes, N) -> (..., k, N)."""
    f = get_format(fmt)
    if f.code_bits == 8:
        return packed
    p = packed.astype(jnp.uint32)
    lead, n = packed.shape[:-2], packed.shape[-1]
    if f.code_bits <= 4:
        lo = p & 0xF
        hi = (p >> 4) & 0xF
        out = jnp.stack([lo, hi], axis=-2).reshape(lead + (k, n))
        return out.astype(_U8)
    trip = p.reshape(lead + (k // 4, 3, n))
    w = (trip[..., 0, :] | (trip[..., 1, :] << 8) | (trip[..., 2, :] << 16))
    c = jnp.stack([w & 0x3F, (w >> 6) & 0x3F, (w >> 12) & 0x3F,
                   (w >> 18) & 0x3F], axis=-2)
    return c.reshape(lead + (k, n)).astype(_U8)


def unpack_codes(packed: jax.Array, fmt: MXFormat | str, n: int) -> jax.Array:
    """Packed uint8 stream -> uint8 codes of trailing length ``n``."""
    f = get_format(fmt)
    if f.code_bits == 8:
        return packed
    p = packed.astype(jnp.uint32)
    lead = packed.shape[:-1]
    if f.code_bits <= 4:
        lo = p & 0xF
        hi = (p >> 4) & 0xF
        out = jnp.stack([lo, hi], axis=-1).reshape(lead + (n,))
        return out.astype(_U8)
    trip = p.reshape(lead + (n // 4, 3))
    w = trip[..., 0] | (trip[..., 1] << 8) | (trip[..., 2] << 16)
    c0 = w & 0x3F
    c1 = (w >> 6) & 0x3F
    c2 = (w >> 12) & 0x3F
    c3 = (w >> 18) & 0x3F
    return jnp.stack([c0, c1, c2, c3], axis=-1).reshape(
        lead + (n,)).astype(_U8)
