"""The unified quantization API: ``QuantSpec`` ("how to quantize one
tensor") and ``QuantPolicy`` ("which spec applies to which tensor role").

A ``QuantSpec`` bundles the paper converter's three parameters — element
format, conversion mode, block size — plus the storage packing preference,
into one frozen, hashable object that can ride through ``jax.jit`` as a
static argument and through pytree aux data.  The string grammar

    fmt[@block][:mode][+packed|+unpacked]

round-trips through ``QuantSpec.parse`` / ``str()``:

    >>> str(QuantSpec.parse("int8@32:ocp"))
    'int8@32:ocp'

``QuantSpec.parse("none")`` returns ``None`` — the fp-passthrough sentinel
(no quantization for that role).

A ``QuantPolicy`` maps the five tensor roles — ``weights``,
``activations``, ``kv_key``, ``kv_value``, ``grads`` — to an optional spec
each, so e.g. INT8 keys can coexist with E2M1 values in the same serving
engine.  Its grammar is a comma-joined list of ``role=spec`` entries (the
shorthand role ``kv`` sets both KV roles):

    >>> QuantPolicy.parse("kv_key=int8@32:ocp,kv_value=e2m1@32:ocp")

The legacy ``MXPolicy`` constructor and the ``fmt=``/``mode=``/``block=``
keyword forms of the public conversion entry points keep working through
deprecation shims built on ``resolve_spec`` (each shimmed entry point
warns exactly once per process).
"""
from __future__ import annotations

import dataclasses
import json
import re
import warnings
from typing import Dict, Mapping, Optional, Tuple, Union

from repro.core.formats import DEFAULT_BLOCK, MXFormat, get_format

MODES: Tuple[str, ...] = ("paper", "ocp")

# tensor roles a QuantPolicy can address, in canonical order
ROLES: Tuple[str, ...] = ("weights", "activations", "kv_key", "kv_value",
                          "grads")

_NONE_TOKENS = ("none", "off", "fp")

_SPEC_RE = re.compile(
    r"^(?P<fmt>[^@:+=,\s]+)"
    r"(?:@(?P<block>[^:+]*))?"
    r"(?::(?P<mode>[^+]*))?"
    r"(?:\+(?P<flag>.*))?$")


# =============================================================================
# deprecation bookkeeping (warn once per call site)
# =============================================================================
_WARNED: set = set()


def warn_deprecated(key: str, message: str) -> None:
    """Emit ``message`` as a DeprecationWarning once per ``key``."""
    if key in _WARNED:
        return
    _WARNED.add(key)
    warnings.warn(message, DeprecationWarning, stacklevel=3)


def reset_deprecation_warnings() -> None:
    """Clear the warn-once registry (test hook)."""
    _WARNED.clear()


# =============================================================================
# QuantSpec
# =============================================================================
@dataclasses.dataclass(frozen=True)
class QuantSpec:
    """How to quantize one tensor: element format, mode, block, packing.

    ``packed`` is a storage preference: consumers that support bit-packed
    sub-byte codes (the paged KV page pool) honor it; plain ``MXArray``
    codes always stay one byte per element.
    """

    fmt: str = "e4m3"
    mode: str = "ocp"
    block: int = DEFAULT_BLOCK
    packed: bool = True

    def __post_init__(self):
        # normalize the format name through the registry (raises with the
        # valid-name list on an unknown format)
        object.__setattr__(self, "fmt", get_format(self.fmt).name)
        if self.mode not in MODES:
            raise ValueError(
                f"unknown MX conversion mode {self.mode!r}; choose from "
                f"{list(MODES)}")
        if not isinstance(self.block, int) or isinstance(self.block, bool) \
                or self.block < 1:
            raise ValueError(
                f"block must be a positive integer, got {self.block!r}")

    # ------------------------------------------------------------- grammar
    @classmethod
    def parse(cls, text: str) -> Optional["QuantSpec"]:
        """Parse ``fmt[@block][:mode][+packed|+unpacked]``.

        ``"none"`` / ``"off"`` / ``"fp"`` return ``None`` (fp passthrough).
        Omitted fields take the dataclass defaults (block 32, mode "ocp",
        packed).  Raises ValueError with a precise message on bad input.
        """
        if not isinstance(text, str):
            raise TypeError(f"QuantSpec.parse expects a str, "
                            f"got {type(text).__name__}")
        s = text.strip().lower()
        if not s:
            raise ValueError("empty quantization spec; expected "
                             "'fmt[@block][:mode]' or 'none'")
        if s in _NONE_TOKENS:
            return None
        m = _SPEC_RE.match(s)
        if m is None:
            raise ValueError(
                f"malformed quantization spec {text!r}; expected "
                f"'fmt[@block][:mode][+packed|+unpacked]', "
                f"e.g. 'int8@32:ocp'")
        kw: dict = {"fmt": m.group("fmt")}
        blk = m.group("block")
        if blk is not None:
            if not blk.isdigit() or int(blk) < 1:
                raise ValueError(
                    f"bad block {blk!r} in spec {text!r}; block must be a "
                    f"positive integer (e.g. 'e4m3@32')")
            kw["block"] = int(blk)
        mode = m.group("mode")
        if mode is not None:
            if mode not in MODES:
                raise ValueError(
                    f"bad mode {mode!r} in spec {text!r}; choose from "
                    f"{list(MODES)}")
            kw["mode"] = mode
        flag = m.group("flag")
        if flag is not None:
            if flag not in ("packed", "unpacked"):
                raise ValueError(
                    f"bad flag {flag!r} in spec {text!r}; the only flags "
                    f"are '+packed' and '+unpacked'")
            kw["packed"] = flag == "packed"
        return cls(**kw)          # __post_init__ validates fmt

    def __str__(self) -> str:
        s = f"{self.fmt}@{self.block}:{self.mode}"
        if not self.packed:
            s += "+unpacked"
        return s

    # ------------------------------------------------------------- helpers
    @property
    def format(self) -> MXFormat:
        return get_format(self.fmt)

    def storage_nbytes(self, n: int) -> int:
        """Bytes needed to store ``n`` element codes under this spec's
        packing preference (bit-packed for sub-byte formats iff packed)."""
        from repro.core.pack import packed_nbytes
        return packed_nbytes(self.fmt, n) if self.packed else n

    def replace(self, **kw) -> "QuantSpec":
        return dataclasses.replace(self, **kw)


def as_spec(spec) -> QuantSpec:
    """Coerce a QuantSpec | spec-string into a QuantSpec (no deprecation
    semantics; ``None``/"none" is rejected — use the policy for absence)."""
    if isinstance(spec, QuantSpec):
        return spec
    if isinstance(spec, str):
        out = QuantSpec.parse(spec)
        if out is None:
            raise ValueError("'none' is not a concrete QuantSpec; pass a "
                             "format spec such as 'e4m3@32:ocp'")
        return out
    raise TypeError(f"expected QuantSpec or spec string, "
                    f"got {type(spec).__name__}")


# =============================================================================
# legacy-kwarg resolution (the deprecation shims' engine)
# =============================================================================
def resolve_spec(spec=None, fmt=None, mode=None, block=None, *,
                 default: Optional[QuantSpec] = None,
                 caller: str = "mx") -> QuantSpec:
    """Resolve the argument soup of a legacy-compatible entry point.

    New forms (no warning): ``spec`` is a QuantSpec, a full spec string
    (contains '@', ':' or '+'), or None with no legacy kwargs (-> the
    entry point's ``default``).  Legacy forms (one DeprecationWarning per
    entry point per process): ``fmt=``/``mode=``/``block=`` kwargs, or a
    bare format name
    in the ``spec`` slot (the old positional-``fmt`` call shape); missing
    legacy fields fall back to ``default``'s, preserving each entry
    point's historical defaults.
    """
    base = default if default is not None else QuantSpec()
    legacy = fmt is not None or mode is not None or block is not None
    if isinstance(spec, QuantSpec):
        if legacy:
            raise TypeError(
                f"{caller}: pass either a QuantSpec or the deprecated "
                f"fmt=/mode=/block= kwargs, not both")
        return spec
    if isinstance(spec, str):
        if any(c in spec for c in "@:+"):
            if legacy:
                raise TypeError(
                    f"{caller}: got both a spec string {spec!r} and "
                    f"deprecated fmt=/mode=/block= kwargs")
            return as_spec(spec)
        # bare format name: the old positional-fmt call shape
        if fmt is not None:
            raise TypeError(f"{caller}: format given twice "
                            f"({spec!r} and fmt={fmt!r})")
        fmt, legacy = spec, True
    elif spec is not None:
        raise TypeError(f"{caller}: spec must be a QuantSpec, a spec "
                        f"string or None, got {type(spec).__name__}")
    if not legacy:
        return base
    warn_deprecated(
        f"{caller}:kwargs",
        f"{caller}: the fmt=/mode=/block= keyword form is deprecated; "
        f"pass a QuantSpec (e.g. QuantSpec.parse("
        f"'{fmt or base.fmt}@{block or base.block}:{mode or base.mode}'))")
    return QuantSpec(fmt=fmt if fmt is not None else base.fmt,
                     mode=mode if mode is not None else base.mode,
                     block=block if block is not None else base.block,
                     packed=base.packed)


def resolve_kv_specs(spec=None, key_spec=None, value_spec=None, fmt=None,
                     mode=None, block=None, *,
                     default: Optional[QuantSpec] = None,
                     caller: str = "mx") -> Tuple[QuantSpec, QuantSpec]:
    """Resolve the (key, value) spec pair of a KV-cache consumer.

    New forms: ``key_spec`` + ``value_spec`` (both required when either is
    given), or the uniform ``spec``.  Legacy ``fmt=``/``mode=`` kwargs set
    both roles to the same spec (one DeprecationWarning per caller).
    """
    base = default if default is not None else QuantSpec()
    legacy = fmt is not None or mode is not None or block is not None
    if legacy:
        if spec is not None or key_spec is not None \
                or value_spec is not None:
            raise TypeError(
                f"{caller}: pass either specs or the deprecated "
                f"fmt=/mode= kwargs, not both")
        s = resolve_spec(None, fmt, mode, block, default=base,
                         caller=caller)
        return s, s
    if spec is not None:
        if key_spec is not None or value_spec is not None:
            raise TypeError(f"{caller}: pass spec= (uniform) or "
                            f"key_spec=/value_spec=, not both")
        s = as_spec(spec)
        return s, s
    if (key_spec is None) != (value_spec is None):
        raise TypeError(f"{caller}: key_spec and value_spec must be "
                        f"given together")
    if key_spec is None:
        return base, base
    return as_spec(key_spec), as_spec(value_spec)


# =============================================================================
# QuantPolicy
# =============================================================================
def _coerce_role(name: str, value) -> Optional[QuantSpec]:
    if value is None:
        return None
    if isinstance(value, str):
        return QuantSpec.parse(value)
    if isinstance(value, QuantSpec):
        return value
    raise TypeError(f"policy role {name!r} must be a QuantSpec, a spec "
                    f"string or None, got {type(value).__name__}")


@dataclasses.dataclass(frozen=True)
class QuantPolicy:
    """Per-tensor-role quantization policy (role -> optional QuantSpec).

    ``None`` for a role means fp passthrough.  ``kv_key`` and ``kv_value``
    must be set together (the KV cache layout is either quantized or
    dense); they may carry *different* specs — mixed-format KV serving.
    """

    weights: Optional[QuantSpec] = None
    activations: Optional[QuantSpec] = None
    kv_key: Optional[QuantSpec] = None
    kv_value: Optional[QuantSpec] = None
    grads: Optional[QuantSpec] = None

    def __post_init__(self):
        for role in ROLES:
            object.__setattr__(self, role,
                               _coerce_role(role, getattr(self, role)))
        if (self.kv_key is None) != (self.kv_value is None):
            raise ValueError(
                "kv_key and kv_value must be set together (use the same "
                "spec for a uniform cache, or 'kv=<spec>' in the policy "
                "grammar)")

    # ------------------------------------------------------------- grammar
    @classmethod
    def parse(cls, text: str) -> "QuantPolicy":
        """Parse ``role=spec[,role=spec...]``; ``kv=`` sets both KV roles;
        empty / ``"none"`` is the all-passthrough policy."""
        if not isinstance(text, str):
            raise TypeError(f"QuantPolicy.parse expects a str, "
                            f"got {type(text).__name__}")
        s = text.strip().lower()
        if not s or s in _NONE_TOKENS:
            return cls()
        kw: dict = {}

        def put(role, sp):
            if role in kw:
                raise ValueError(f"role {role!r} given twice in "
                                 f"policy {text!r}")
            kw[role] = sp

        for item in s.split(","):
            item = item.strip()
            if not item:
                continue
            if "=" not in item:
                raise ValueError(
                    f"malformed policy entry {item!r} in {text!r}; "
                    f"expected 'role=spec' with role in "
                    f"{list(ROLES)} (or 'kv')")
            role, _, spec_s = item.partition("=")
            role = role.strip()
            sp = QuantSpec.parse(spec_s.strip())
            if role == "kv":
                put("kv_key", sp)
                put("kv_value", sp)
            elif role in ROLES:
                put(role, sp)
            else:
                raise ValueError(
                    f"unknown tensor role {role!r} in policy {text!r}; "
                    f"choose from {list(ROLES)} (or 'kv' for both KV "
                    f"roles)")
        return cls(**kw)

    def __str__(self) -> str:
        items = [f"{r}={getattr(self, r)}" for r in ROLES
                 if getattr(self, r) is not None]
        return ",".join(items) if items else "none"

    # ------------------------------------------------------------ accessors
    def role(self, name: str) -> Optional[QuantSpec]:
        if name not in ROLES:
            raise ValueError(f"unknown tensor role {name!r}; choose from "
                             f"{list(ROLES)}")
        return getattr(self, name)

    def replace(self, **kw) -> "QuantPolicy":
        return dataclasses.replace(self, **kw)

    # ----------------------------------------------------------------- JSON
    def to_json_dict(self) -> Dict[str, str]:
        """Role -> spec-string mapping of the set roles (the JSON form)."""
        return {r: str(getattr(self, r)) for r in ROLES
                if getattr(self, r) is not None}

    @classmethod
    def from_json_dict(cls, d: Mapping, *,
                       where: str = "policy") -> "QuantPolicy":
        """Build a policy from a ``{role: spec-string}`` mapping, raising
        precise errors that name ``where`` plus the offending role/spec
        (mirrors ``QuantSpec.parse`` error style)."""
        if not isinstance(d, Mapping):
            raise ValueError(f"{where}: expected an object mapping roles "
                             f"to spec strings, got "
                             f"{type(d).__name__}")
        kw: dict = {}
        for role, spec_s in d.items():
            if role not in ROLES:
                raise ValueError(
                    f"{where}: unknown tensor role {role!r}; choose from "
                    f"{list(ROLES)}")
            if not isinstance(spec_s, str):
                raise ValueError(
                    f"{where}: role {role!r} must map to a spec string, "
                    f"got {type(spec_s).__name__}")
            try:
                kw[role] = QuantSpec.parse(spec_s)
            except (TypeError, ValueError) as e:
                raise ValueError(
                    f"{where}: role {role!r}: bad spec {spec_s!r}: "
                    f"{e}") from e
        try:
            return cls(**kw)
        except ValueError as e:       # kv_key/kv_value pairing violation
            raise ValueError(f"{where}: {e}") from e

    # ------------------------------------------- legacy MXPolicy read shims
    @property
    def kv_cache(self) -> bool:
        """Legacy read shim: is the KV cache quantized?"""
        return self.kv_key is not None

    @property
    def kv_fmt(self) -> Optional[str]:
        """Legacy read shim: the key-role element format name."""
        return self.kv_key.fmt if self.kv_key is not None else None


# =============================================================================
# PolicyTable — per-layer QuantPolicy (role + layer -> spec)
# =============================================================================
POLICY_TABLE_SCHEMA = "policy_table/v1"


@dataclasses.dataclass(frozen=True)
class PolicyTable:
    """A per-layer quantization policy: ``default`` applies to every layer
    not named in ``overrides`` (a sorted ``(layer, QuantPolicy)`` tuple).

    Layers are indexed absolutely (leading dense layers first, then the
    scanned stack, matching ``ModelConfig`` layer order).  The table is
    frozen and hashable, so — like ``QuantSpec``/``QuantPolicy`` — it can
    ride through ``jax.jit`` static arguments and config dataclasses.

    An all-layers-identical table carries no information beyond its
    default; ``collapse()`` returns the plain ``QuantPolicy`` in that case
    so consumers keep the uniform (scanned, bit-identical) code path.
    """

    default: QuantPolicy = dataclasses.field(default_factory=QuantPolicy)
    overrides: Tuple[Tuple[int, QuantPolicy], ...] = ()

    def __post_init__(self):
        if isinstance(self.default, str):
            object.__setattr__(self, "default",
                               QuantPolicy.parse(self.default))
        if not isinstance(self.default, QuantPolicy):
            raise TypeError(
                f"PolicyTable default must be a QuantPolicy or policy "
                f"string, got {type(self.default).__name__}")
        ov = self.overrides
        if isinstance(ov, Mapping):
            ov = tuple(sorted(ov.items()))
        items = []
        seen = set()
        for entry in ov:
            try:
                layer, pol = entry
            except (TypeError, ValueError):
                raise TypeError(
                    f"PolicyTable overrides entries must be (layer, "
                    f"policy) pairs, got {entry!r}") from None
            if not isinstance(layer, int) or isinstance(layer, bool) \
                    or layer < 0:
                raise ValueError(
                    f"PolicyTable layer index must be a non-negative "
                    f"int, got {layer!r}")
            if layer in seen:
                raise ValueError(f"layer {layer} given twice in "
                                 f"PolicyTable overrides")
            seen.add(layer)
            if isinstance(pol, str):
                pol = QuantPolicy.parse(pol)
            if not isinstance(pol, QuantPolicy):
                raise TypeError(
                    f"PolicyTable layer {layer} policy must be a "
                    f"QuantPolicy or policy string, got "
                    f"{type(pol).__name__}")
            items.append((layer, pol))
        object.__setattr__(self, "overrides", tuple(sorted(items)))

    # ----------------------------------------------------------- accessors
    def layer(self, i: int) -> QuantPolicy:
        """The effective policy of absolute layer ``i``."""
        for layer, pol in self.overrides:
            if layer == i:
                return pol
        return self.default

    def spec(self, role: str, layer: int) -> Optional[QuantSpec]:
        """Resolve role + layer -> optional QuantSpec."""
        return self.layer(layer).role(role)

    @property
    def is_uniform(self) -> bool:
        return all(pol == self.default for _, pol in self.overrides)

    def collapse(self) -> Union[QuantPolicy, "PolicyTable"]:
        """The plain ``QuantPolicy`` when every layer agrees, else self."""
        return self.default if self.is_uniform else self

    def replace(self, **kw) -> "PolicyTable":
        return dataclasses.replace(self, **kw)

    def __str__(self) -> str:
        ov = ",".join(f"{i}:[{p}]" for i, p in self.overrides)
        return f"table(default=[{self.default}]" + \
            (f",{ov})" if ov else ")")

    # ----------------------------------------------------------------- JSON
    def to_json_dict(self) -> dict:
        return {
            "schema": POLICY_TABLE_SCHEMA,
            "default": self.default.to_json_dict(),
            "layers": {str(i): p.to_json_dict()
                       for i, p in self.overrides},
        }

    def to_json(self) -> str:
        return json.dumps(self.to_json_dict(), indent=2) + "\n"

    @classmethod
    def from_json_dict(cls, doc) -> "PolicyTable":
        """Parse the ``policy_table/v1`` JSON document form.  Errors are
        precise: they name the offending layer, role, and spec string."""
        if not isinstance(doc, Mapping):
            raise ValueError(
                f"policy table: expected a JSON object, got "
                f"{type(doc).__name__}")
        unknown = sorted(set(doc) - {"schema", "default", "layers"})
        if unknown:
            raise ValueError(
                f"policy table: unknown field(s) {unknown}; expected "
                f"'schema', 'default', 'layers'")
        schema = doc.get("schema")
        if schema != POLICY_TABLE_SCHEMA:
            raise ValueError(
                f"policy table: schema {schema!r} is not "
                f"{POLICY_TABLE_SCHEMA!r}")
        default = QuantPolicy.from_json_dict(doc.get("default", {}),
                                             where="policy table default")
        layers = doc.get("layers", {})
        if not isinstance(layers, Mapping):
            raise ValueError(
                f"policy table: 'layers' must be an object mapping layer "
                f"indices to policies, got {type(layers).__name__}")
        overrides = []
        for key, pol_d in layers.items():
            try:
                layer = int(key)
                if layer < 0:
                    raise ValueError
            except (TypeError, ValueError):
                raise ValueError(
                    f"policy table: bad layer index {key!r}; keys must be "
                    f"non-negative integers") from None
            pol = QuantPolicy.from_json_dict(
                pol_d, where=f"policy table layer {layer}")
            overrides.append((layer, pol))
        return cls(default=default, overrides=tuple(overrides))

    @classmethod
    def from_json(cls, text: str) -> "PolicyTable":
        try:
            doc = json.loads(text)
        except json.JSONDecodeError as e:
            raise ValueError(f"policy table: invalid JSON: {e}") from e
        return cls.from_json_dict(doc)


def mx_policy(fmt: str = "e4m3", mode: str = "ocp",
              block: int = DEFAULT_BLOCK, weights: bool = False,
              kv_cache: bool = False, grads: bool = False,
              kv_fmt: str = "int8",
              grad_fmt: str = "e4m3") -> QuantPolicy:
    """Deprecation shim for the pre-spec ``MXPolicy`` dataclass: maps the
    old where-booleans + how-strings onto a ``QuantPolicy`` (one
    DeprecationWarning per process)."""
    warn_deprecated(
        "MXPolicy",
        "MXPolicy is deprecated; build a QuantPolicy instead, e.g. "
        "QuantPolicy.parse('kv=int8@32:ocp,weights=e4m3@32:ocp')")
    kv = QuantSpec(kv_fmt, mode, block) if kv_cache else None
    return QuantPolicy(
        weights=QuantSpec(fmt, mode, block) if weights else None,
        kv_key=kv, kv_value=kv,
        grads=QuantSpec(grad_fmt, mode, block) if grads else None)
