"""Deterministic, checkpointable data pipeline.

``SyntheticLM`` generates a learnable affine-Markov token stream: the cursor
is just the step number, so a restart from a checkpoint replays bit-identical
batches (fault-tolerance requirement).  ``token_file_reader`` is the
file-backed path (memmap of uint16/uint32 tokens) with the same cursor
semantics, for realism.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    noise: float = 0.05            # fraction of non-Markov tokens
    mult: int = 31                 # affine map: next = (mult*t + 7) % vocab


class SyntheticLM:
    """Learnable synthetic LM stream; batch(step) is a pure function."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        c = self.cfg
        rng = np.random.default_rng((c.seed << 32) ^ step)
        b, s = c.global_batch, c.seq_len
        toks = np.empty((b, s + 1), np.int32)
        toks[:, 0] = rng.integers(0, c.vocab, size=b)
        noise = rng.random((b, s)) < c.noise
        rand = rng.integers(0, c.vocab, size=(b, s))
        for t in range(s):
            nxt = (toks[:, t] * c.mult + 7) % c.vocab
            toks[:, t + 1] = np.where(noise[:, t], rand[:, t], nxt)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch(step)
            step += 1


def token_file_reader(path: str, seq_len: int, global_batch: int,
                      dtype=np.uint16):
    """Memmap token-file reader; cursor = step (deterministic restart)."""
    data = np.memmap(path, dtype=dtype, mode="r")
    per_batch = seq_len * global_batch + 1
    n_steps = (len(data) - 1) // (seq_len * global_batch)

    def batch(step: int) -> Dict[str, np.ndarray]:
        ofs = (step % n_steps) * seq_len * global_batch
        chunk = np.asarray(data[ofs: ofs + per_batch], np.int32)
        toks = chunk[:-1].reshape(global_batch, seq_len)
        labs = chunk[1:].reshape(global_batch, seq_len)
        return {"tokens": toks, "labels": labs}

    return batch, n_steps


def make_batch_for(cfg: ModelConfig, data: Dict[str, np.ndarray],
                   prefix_rng: Optional[np.random.Generator] = None):
    """Adapt a raw token batch to the arch's input dict (modality stubs)."""
    b, s = data["tokens"].shape
    out = {"tokens": jnp.asarray(data["tokens"]),
           "labels": jnp.asarray(data["labels"])}
    if cfg.family == "encdec":
        rng = prefix_rng or np.random.default_rng(0)
        out["frames"] = jnp.asarray(
            rng.normal(size=(b, s, cfg.d_model)).astype(np.float32) * 0.02)
    elif cfg.frontend == "patch" and cfg.prefix_len:
        rng = prefix_rng or np.random.default_rng(0)
        out["prefix_embeds"] = jnp.asarray(
            rng.normal(size=(b, cfg.prefix_len, cfg.d_model))
            .astype(np.float32) * 0.02)
        out["labels"] = jnp.concatenate(
            [jnp.full((b, cfg.prefix_len), -1, jnp.int32), out["labels"]],
            axis=1)
    return out
