"""repro.dist — logical sharding rules + jax version compat."""
from repro.dist import compat  # noqa: F401  (installs jax API shims)
from repro.dist.sharding import (  # noqa: F401
    Rules, bf16_matmul_out_enabled, current_rules, logical, make_rules,
    param_specs, use_rules, weight_gather_enabled, weight_gather_mode,
)
