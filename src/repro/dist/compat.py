"""Version-compatibility layer over the jax sharding surface.

The codebase (and the test-suite) is written against the modern spellings —
``jax.set_mesh``, ``jax.shard_map(..., axis_names=..., check_vma=...)``,
``jax.sharding.get_abstract_mesh()`` — which live elsewhere (or not at all)
on the jax 0.4.x wheels in this container:

  * ``shard_map`` is ``jax.experimental.shard_map.shard_map`` with the
    inverse parameterization: ``auto`` (axes left to GSPMD) instead of
    ``axis_names`` (axes made manual), ``check_rep`` instead of
    ``check_vma``.
  * there is no global mesh setter; the 0.4.x equivalent is the
    ``Mesh.__enter__`` resource-env context manager.
  * ``jax.lax.axis_size`` does not exist; inside a shard_map body the
    static axis size is recovered with ``jax.lax.psum(1, name)``.

``install()`` (run on import) adds the missing top-level names so one
spelling works across versions; each shim is only installed when the real
thing is absent, so upgrading jax silently switches to the native API.
"""
from __future__ import annotations

import threading

import jax

# Native entry points, captured BEFORE install() patches anything: None on
# 0.4.x, the real functions on modern jax.
_NATIVE_SHARD_MAP = getattr(jax, "shard_map", None)
_NATIVE_SET_MESH = getattr(jax, "set_mesh", None)
_NATIVE_GET_ABSTRACT_MESH = getattr(jax.sharding, "get_abstract_mesh", None)

_state = threading.local()


def _mesh_stack():
    stack = getattr(_state, "meshes", None)
    if stack is None:
        stack = _state.meshes = []
    return stack


def _resource_env_mesh():
    """The 0.4.x ``with mesh:`` resource-env mesh, or None."""
    try:
        from jax._src import mesh as mesh_lib
        m = mesh_lib.thread_resources.env.physical_mesh
        if m is not None and not m.empty:
            return m
    except Exception:
        pass
    return None


def current_mesh():
    """The innermost ambient mesh, else None.  A concrete Mesh on 0.4.x
    (from our ``set_mesh`` shim or a bare ``with mesh:`` context); on
    modern jax, whatever the native ``jax.set_mesh`` installed (an
    AbstractMesh — still carries axis_names/shape for rule resolution)."""
    stack = _mesh_stack()
    if stack:
        return stack[-1]
    if _NATIVE_GET_ABSTRACT_MESH is not None:
        m = _NATIVE_GET_ABSTRACT_MESH()
        if m is not None and not m.empty:
            return m
    return _resource_env_mesh()


class _SetMeshContext:
    """Matches modern ``jax.set_mesh`` calling semantics: a plain call
    installs the mesh immediately (global set); used as a context manager
    it additionally restores the previous state on exit."""

    def __init__(self, mesh):
        self.mesh = mesh
        _mesh_stack().append(mesh)
        mesh.__enter__()                 # 0.4.x resource-env (bare-P specs)

    def __enter__(self):
        return self.mesh

    def __exit__(self, *exc):
        self.mesh.__exit__(*exc)
        _mesh_stack().pop()
        return False


def set_mesh(mesh):
    """``jax.set_mesh`` stand-in; delegates to the native setter when jax
    ships one."""
    if _NATIVE_SET_MESH is not None:
        return _NATIVE_SET_MESH(mesh)
    return _SetMeshContext(mesh)


def get_abstract_mesh():
    """``jax.sharding.get_abstract_mesh`` equivalent (native-aware via
    ``current_mesh``).  Returns the ambient mesh or None; callers test
    ``mesh is None or mesh.empty``."""
    return current_mesh()


def shard_map(f, mesh=None, in_specs=None, out_specs=None, *,
              axis_names=None, check_vma=True, auto=None, check_rep=None):
    """Modern ``jax.shard_map`` signature, dispatching to the native
    implementation when jax ships one and otherwise mapped onto the 0.4.x
    experimental API: ``axis_names`` (manual axes) becomes
    ``auto = mesh.axes - axis_names``; ``check_vma`` becomes ``check_rep``.
    """
    rep = check_vma if check_rep is None else check_rep
    if _NATIVE_SHARD_MAP is not None:
        kw = {}
        if axis_names is not None:
            kw["axis_names"] = set(axis_names)
        elif auto is not None:
            kw["axis_names"] = set(mesh.axis_names) - set(auto)
        return _NATIVE_SHARD_MAP(f, mesh=mesh, in_specs=in_specs,
                                 out_specs=out_specs, check_vma=bool(rep),
                                 **kw)
    from jax.experimental.shard_map import shard_map as _sm
    # 0.4.x: partial-auto + lax.scan fatally crashes the SPMD partitioner,
    # so every axis goes manual here; axes the caller wanted automatic
    # carry replicated compute (their in/out_specs never mention them, so
    # the specs stay valid).
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=bool(rep), auto=frozenset())


def axis_size(name) -> int:
    """Static size of a manual mesh axis from inside a shard_map body."""
    ax = getattr(jax.lax, "axis_size", None)
    if ax is not None:
        return ax(name)
    return jax.lax.psum(1, name)         # concrete int at trace time


def constrain(x, spec, mesh=None):
    """with_sharding_constraint against the ambient mesh.  With a concrete
    mesh the spec is bound via NamedSharding (no context needed); with an
    abstract mesh (newer jax) the bare spec is passed through."""
    mesh = mesh if mesh is not None else current_mesh()
    if mesh is None:
        return x
    if isinstance(mesh, jax.sharding.Mesh):
        return jax.lax.with_sharding_constraint(
            x, jax.sharding.NamedSharding(mesh, spec))
    return jax.lax.with_sharding_constraint(x, spec)


def install() -> None:
    """Install missing top-level names onto jax (no-ops on new jax)."""
    if not hasattr(jax, "set_mesh"):
        jax.set_mesh = set_mesh
    if not hasattr(jax, "shard_map"):
        jax.shard_map = shard_map
    if not hasattr(jax.sharding, "get_abstract_mesh"):
        jax.sharding.get_abstract_mesh = get_abstract_mesh
    # 0.4.x Compiled.cost_analysis returns a per-device *list* of dicts;
    # the modern API returns the single dict callers expect
    try:
        from jax._src.stages import Compiled
        orig = Compiled.cost_analysis
        if not getattr(orig, "_repro_normalized", False):
            def cost_analysis(self, _orig=orig):
                out = _orig(self)
                if isinstance(out, list):
                    return out[0] if out else {}
                return out
            cost_analysis._repro_normalized = True
            Compiled.cost_analysis = cost_analysis
    except Exception:
        pass


install()
