"""Logical sharding-rule engine: named-axis rules -> GSPMD constraints.

The model zoo is written against *logical* axis names ("batch", "model",
"seq", "wgather", "kv_batch"); a rule table maps each name to zero or more
physical mesh axes.  The launcher builds one table per cell
(``make_rules``), installs it around trace time (``use_rules``), and every
``logical(x, *names)`` call site inside the models resolves to a
``with_sharding_constraint`` — or to an identity no-op when no rules are
installed, so single-device tests and eager exploration never pay a
sharding tax.

Physical axis convention (see repro.launch.mesh):
  pod    — inter-pod data parallelism (gradient reduction only)
  data   — intra-pod DP / FSDP shard axis
  model  — tensor / expert / sequence parallelism

Rule names:
  batch     — activation batch dim            -> ("pod","data") ∩ mesh
  kv_batch  — KV-cache batch dim (decode reads stay local)
  model     — TP-sharded activation dim       -> ("model",)
  seq       — sequence dim (long-context)     -> ("model",) when seq_sharded
  wgather   — FSDP weight-gather axes; None disables use-point gathering
              (decode posture: weights stay resident)

``param_specs`` derives PartitionSpecs for arbitrary parameter pytrees from
the zoo's naming conventions (embed/lm_head, stacked-scan containers, MoE
expert tables, rank-1 norms); the launcher validates divisibility per mesh
(repro.launch.cells._validated) before using them.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
from jax.sharding import PartitionSpec as P

from repro.dist import compat

Rules = Dict[str, Any]

DP_AXES = ("pod", "data")       # data-parallel axes, outermost first
TP_AXIS = "model"

# parameter containers whose leaves carry a leading lax.scan layer dim
STACKED_CONTAINERS = frozenset(
    {"layers", "enc_layers", "dec_layers", "blocks"})
# output projections: input dim is the TP-sharded one (Megatron row-parallel)
ROW_PARALLEL = frozenset({"wo", "w2"})

_state = threading.local()


def _stack():
    stack = getattr(_state, "rules", None)
    if stack is None:
        stack = _state.rules = []
    return stack


# =============================================================================
# rule tables
# =============================================================================
def make_rules(axes: Sequence[str], *, fsdp_params: bool = True,
               seq_sharded: bool = False, bf16_matmul_out: bool = False,
               pure_fsdp: bool = False,
               paged_pool_sharded: bool = False,
               quant: Any = None) -> Rules:
    """Build a logical->physical rule table for a mesh with ``axes``.

    ``fsdp_params``    — enable use-point weight gathering (ZeRO-3); decode
                         cells pass False so weights stay resident.
    ``seq_sharded``    — shard the sequence dim of activations/caches over
                         "model" (long-context cells).
    ``bf16_matmul_out``— matmuls emit bf16 (halves TP all-reduce payloads).
    ``pure_fsdp``      — gather the *whole* weight per layer (no dim left
                         TP-sharded); for narrow TP-unfriendly archs.
    ``paged_pool_sharded`` — shard the paged-KV page pool's page dim over
                         the data axes (spreads pool HBM across DP ranks at
                         the cost of a block-table gather per decode step);
                         default False replicates the pool so any slot can
                         reference any physical page locally.
    ``quant``          — a ``repro.core.spec.QuantPolicy`` to install with
                         the rules; distributed consumers resolve their
                         per-tensor-role specs through ``quant_spec_for``
                         (e.g. the compressed-DP gradient exchange reads
                         the "grads" role).
    """
    axes = tuple(axes)
    batch = tuple(a for a in DP_AXES if a in axes)
    model = tuple(a for a in axes if a == TP_AXIS)
    wgather: Optional[Tuple[str, ...]] = None
    if fsdp_params:
        wgather = ("data",) if "data" in axes else (batch or None)
    return {
        "batch": batch,
        "kv_batch": batch,
        "model": model,
        "seq": model if seq_sharded else None,
        "kv_pages": batch if paged_pool_sharded else None,
        "wgather": wgather,
        "wgather_mode": "full" if pure_fsdp else "col",
        "bf16_matmul_out": bool(bf16_matmul_out),
        "quant": quant,
    }


@contextlib.contextmanager
def use_rules(rules: Rules):
    """Install ``rules`` for the duration of the context (trace time)."""
    _stack().append(rules)
    try:
        yield rules
    finally:
        _stack().pop()


def current_rules() -> Optional[Rules]:
    stack = _stack()
    return stack[-1] if stack else None


# =============================================================================
# toggles consumed by models/layers.py and kernels/ops.py
# =============================================================================
def weight_gather_enabled() -> bool:
    r = current_rules()
    return bool(r and r.get("wgather"))


def weight_gather_mode() -> str:
    r = current_rules()
    return (r or {}).get("wgather_mode", "col")


def bf16_matmul_out_enabled() -> bool:
    r = current_rules()
    return bool(r and r.get("bf16_matmul_out"))


def quant_spec_for(role: str):
    """The installed rules' per-tensor-role quantization spec, or None.

    Distributed consumers key their compression off the policy this way
    (e.g. ``grad_compress.mx_allreduce_mean`` defaults its exchange spec
    to the "grads" role) rather than threading fmt/mode strings."""
    r = current_rules()
    pol = (r or {}).get("quant")
    return pol.role(role) if pol is not None else None


# =============================================================================
# use-point constraints
# =============================================================================
def _axes_size(mesh, axes: Tuple[str, ...]) -> int:
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def model_axis_size() -> int:
    """Mesh extent behind the logical "model" name under the installed
    rules (1 when no rules/mesh are active).  Divisibility checks at MX
    weight use points key off this: a dim that this size does not divide
    is silently replicated by ``logical`` rather than sharded."""
    rules = current_rules()
    if rules is None:
        return 1
    mesh = compat.current_mesh()
    if mesh is None:
        return 1
    axes = tuple(a for a in (rules.get("model") or ())
                 if a in mesh.axis_names)
    return _axes_size(mesh, axes) if axes else 1


def logical(x: jax.Array, *names) -> jax.Array:
    """Constrain ``x`` dim-by-dim via the installed rules.

    Each entry of ``names`` is a logical axis name or None (replicated /
    gathered).  Identity no-op when no rules or no mesh are installed; rule
    axes missing from the mesh or not dividing the dim are dropped.
    """
    rules = current_rules()
    if rules is None:
        return x
    mesh = compat.current_mesh()
    if mesh is None:
        return x
    entries = []
    for i in range(x.ndim):
        name = names[i] if i < len(names) else None
        axes = rules.get(name) if isinstance(name, str) else None
        if axes:
            axes = tuple(a for a in axes if a in mesh.axis_names)
        if axes and x.shape[i] % _axes_size(mesh, axes) == 0:
            entries.append(axes if len(axes) > 1 else axes[0])
        else:
            # None is a *hard* replication constraint — this is what forces
            # the per-layer FSDP all-gather at weight use points
            entries.append(None)
    return compat.constrain(x, P(*entries), mesh)


# =============================================================================
# parameter PartitionSpecs
# =============================================================================
def _spec_for(keys: Tuple[str, ...], ndim: int) -> P:
    stacked = any(k in STACKED_CONTAINERS for k in keys)
    lead: Tuple[Any, ...] = (None,) if stacked else ()
    nd = ndim - len(lead)
    name = keys[-1] if keys else ""
    if nd <= 1:
        return P(*(lead + (None,) * nd))         # norms/biases: replicated
    if "experts" in keys:
        # MoE expert tables (E, d_in, d_ff[, ...]): expert-parallel over
        # "model", FSDP over "data" on the next dim, rest replicated
        return P(*(lead + (TP_AXIS, "data") + (None,) * (nd - 2)))
    if name == "embed" and nd == 2:
        # vocab-sharded over "model" so the tied-head logits matmul is
        # col-parallel without a transpose-reshard
        return P(*(lead + (TP_AXIS, "data")))
    if name in ROW_PARALLEL:
        body = (None,) * (nd - 2) + (TP_AXIS, "data")
    else:
        body = (None,) * (nd - 2) + ("data", TP_AXIS)   # col (default)
    return P(*(lead + body))


def param_specs(params) -> Any:
    """PartitionSpec pytree mirroring ``params`` (arrays or
    ShapeDtypeStructs).  Divisibility against a concrete mesh is the
    caller's job (see repro.launch.cells._validated)."""
    def one(path, leaf):
        keys = tuple(str(getattr(p, "key", getattr(p, "idx", p)))
                     for p in path)
        return _spec_for(keys, len(leaf.shape))

    return jax.tree_util.tree_map_with_path(one, params)
