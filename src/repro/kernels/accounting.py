"""Analytic cost registry for Pallas kernels.

Interpret-mode Pallas grids lower to XLA while loops, whose bodies HLO cost
analysis counts ONCE — exactly right for VMEM-resident scratch (bytes), but
an undercount for kernel FLOPs.  Kernel wrappers therefore ``record()``
their analytic FLOPs (and HBM I/O bytes) at trace time; the dry-run wraps
lowering in ``collect()`` and adds the corrections (EXPERIMENTS.md §Method).
"""
from __future__ import annotations

import contextlib
import threading

_state = threading.local()


@contextlib.contextmanager
def collect(metrics=None):
    """Collect kernel cost corrections for the ``with`` body; yields the
    accumulator dict.  ``metrics`` (a
    :class:`repro.obs.metrics.MetricsRegistry`) additionally folds the
    collected totals into the shared ``kernels.*`` counters on exit, so
    dry-run cost accounting exports through the same registry snapshot
    as the serving stack."""
    prev = getattr(_state, "acc", None)
    acc = {"flops": 0.0, "io_bytes": 0.0, "calls": 0}
    _state.acc = acc
    try:
        yield acc
    finally:
        _state.acc = prev
        if metrics is not None:
            metrics.counter(
                "kernels.flops",
                "analytic kernel FLOPs recorded at trace time"
            ).inc(acc["flops"])
            metrics.counter(
                "kernels.io_bytes",
                "analytic kernel HBM I/O bytes").inc(acc["io_bytes"])
            metrics.counter(
                "kernels.calls", "kernel cost records").inc(acc["calls"])


@contextlib.contextmanager
def scale(factor: int):
    """Multiply recorded costs by ``factor`` — installed by layer_scan()
    around the scan trace, because lax.scan traces its body ONCE regardless
    of depth (a kernel call inside the scan executes ``factor`` times)."""
    prev = getattr(_state, "scale", 1)
    _state.scale = prev * int(factor)
    try:
        yield
    finally:
        _state.scale = prev


def record(flops: float, io_bytes: float) -> None:
    acc = getattr(_state, "acc", None)
    if acc is not None:
        k = getattr(_state, "scale", 1)
        acc["flops"] += float(flops) * k
        acc["io_bytes"] += float(io_bytes) * k
        acc["calls"] += 1
