"""Backend-aware execution-mode defaults for the Pallas kernels.

Every kernel entry point takes ``interpret=None`` and resolves it through
``resolve_interpret``: explicit booleans win, then the
``REPRO_PALLAS_INTERPRET`` environment override, and finally the backend —
interpret mode (kernel bodies executed in Python, the correctness path)
everywhere except a real TPU, where the kernels compile to Mosaic.  This
keeps CPU CI bit-exact while real hardware gets compiled kernels without
any call-site churn.

**Supervised dispatch** (graceful degradation): callers with a dense
fallback route their kernel through :func:`supervised` — on the first
failure of a named op (a Pallas trace/lowering error, or an injected
launch fault) the op is marked degraded, the failure is logged once, and
``supervised`` returns None so the caller's existing ``if out is None``
dense path takes over.  Every later trace of that op skips the kernel
outright, so serving keeps running at dense speed instead of crashing.
Both paths are token-identical by construction (asserted by the kernel
correctness suites), so degradation changes throughput, never tokens.
"""
from __future__ import annotations

import logging
import os
from typing import Dict, Optional

import jax

ENV_VAR = "REPRO_PALLAS_INTERPRET"


def resolve_interpret(interpret: Optional[bool] = None) -> bool:
    """Resolve an ``interpret`` kwarg: explicit value > env var > backend.

    ``None`` means "interpret only off-TPU".  The result is a plain bool so
    it can ride through jit static arguments.
    """
    if interpret is not None:
        return bool(interpret)
    env = os.environ.get(ENV_VAR)
    if env is not None:
        return env != "0"
    return jax.default_backend() != "tpu"


MATMUL_ENV_VAR = "REPRO_MX_MATMUL_IMPL"
MATMUL_IMPLS = ("fused", "einsum")


def resolve_matmul_impl(impl: Optional[str] = None) -> str:
    """Resolve the weight-resident matmul path: explicit value > env > fused.

    ``"fused"`` runs the Pallas dequant-in-VMEM kernel (sub-byte codes
    unpacked inside the tile loop; fp weights never hit HBM); ``"einsum"``
    dequantizes the whole weight and falls back to a plain einsum.  Like
    ``resolve_interpret``, the ``REPRO_MX_MATMUL_IMPL`` environment override
    is read at trace time, so flipping it only affects newly traced
    computations.
    """
    if impl is None:
        impl = os.environ.get(MATMUL_ENV_VAR, "fused")
    if impl not in MATMUL_IMPLS:
        raise ValueError(
            f"unknown mx matmul impl {impl!r}; expected one of {MATMUL_IMPLS}")
    return impl


# =============================================================================
# Supervised kernel dispatch (log once, degrade to dense, keep serving)
# =============================================================================
class KernelFault(RuntimeError):
    """An injected kernel launch failure (see ``repro.serve.faults``)."""


_log = logging.getLogger("repro.kernels")
_degraded: Dict[str, str] = {}      # op -> first failure reason
_injected: set = set()              # ops armed to fail at next trace


def is_degraded(op: str) -> bool:
    return op in _degraded


def degraded_ops() -> Dict[str, str]:
    """Snapshot of degraded ops and the failure that demoted each."""
    return dict(_degraded)


def degrade(op: str, reason: str) -> None:
    """Mark ``op`` degraded; the first demotion is logged (once)."""
    if op not in _degraded:
        _degraded[op] = reason
        _log.warning("kernel %r failed (%s); degrading to the dense "
                     "fallback path for this process", op, reason)


def reset_degradation() -> None:
    """Clear degradations and armed failures (test isolation)."""
    _degraded.clear()
    _injected.clear()


def inject_failure(op: str) -> None:
    """Arm a one-shot failure: the next ``supervised(op, ...)`` raises
    (and therefore degrades) instead of running the kernel.  Consumed at
    trace time — the caller must force a retrace (fresh ``jax.jit``
    wrapper) for an already-compiled computation to hit it."""
    _injected.add(op)


def supervised(op: str, fn, *args, **kwargs):
    """Run kernel ``fn`` under supervision.  Returns its result, or None
    when ``op`` is degraded or ``fn`` raises — the caller's dense
    fallback path must handle None (the pre-existing contract of the
    paged-attention kernel gate)."""
    if op in _degraded:
        return None
    try:
        if op in _injected:
            _injected.discard(op)
            raise KernelFault(f"injected {op} launch failure")
        return fn(*args, **kwargs)
    except Exception as e:          # noqa: BLE001 — demote, don't crash
        degrade(op, repr(e))
        return None
