"""Backend-aware execution-mode defaults for the Pallas kernels.

Every kernel entry point takes ``interpret=None`` and resolves it through
``resolve_interpret``: explicit booleans win, then the
``REPRO_PALLAS_INTERPRET`` environment override, and finally the backend —
interpret mode (kernel bodies executed in Python, the correctness path)
everywhere except a real TPU, where the kernels compile to Mosaic.  This
keeps CPU CI bit-exact while real hardware gets compiled kernels without
any call-site churn.
"""
from __future__ import annotations

import os
from typing import Optional

import jax

ENV_VAR = "REPRO_PALLAS_INTERPRET"


def resolve_interpret(interpret: Optional[bool] = None) -> bool:
    """Resolve an ``interpret`` kwarg: explicit value > env var > backend.

    ``None`` means "interpret only off-TPU".  The result is a plain bool so
    it can ride through jit static arguments.
    """
    if interpret is not None:
        return bool(interpret)
    env = os.environ.get(ENV_VAR)
    if env is not None:
        return env != "0"
    return jax.default_backend() != "tpu"


MATMUL_ENV_VAR = "REPRO_MX_MATMUL_IMPL"
MATMUL_IMPLS = ("fused", "einsum")


def resolve_matmul_impl(impl: Optional[str] = None) -> str:
    """Resolve the weight-resident matmul path: explicit value > env > fused.

    ``"fused"`` runs the Pallas dequant-in-VMEM kernel (sub-byte codes
    unpacked inside the tile loop; fp weights never hit HBM); ``"einsum"``
    dequantizes the whole weight and falls back to a plain einsum.  Like
    ``resolve_interpret``, the ``REPRO_MX_MATMUL_IMPL`` environment override
    is read at trace time, so flipping it only affects newly traced
    computations.
    """
    if impl is None:
        impl = os.environ.get(MATMUL_ENV_VAR, "fused")
    if impl not in MATMUL_IMPLS:
        raise ValueError(
            f"unknown mx matmul impl {impl!r}; expected one of {MATMUL_IMPLS}")
    return impl
