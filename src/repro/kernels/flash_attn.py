"""Pallas flash-attention (forward) kernel — online-softmax over key blocks.

The beyond-paper memory-roofline lever for the 32k prefill cells: the
(Sq x Sk) score matrix lives in VMEM scratch and never touches HBM; HBM
traffic is exactly q + k + v + o.  Layout per grid step (bh, iq, ik):

    VMEM:  q block (blk_q, D), k/v blocks (blk_k, D),
           scratch acc (blk_q, D) f32 + running max/denominator (blk_q,)

Causal masking is applied with global block offsets; diagonal blocks are
partially masked, strictly-upper blocks contribute nothing (their compute is
wasted — acceptable v1; a skip would need a data-dependent grid).

Backward is a custom_vjp that recomputes attention densely (chunk-free) —
the forward-only serving/prefill paths get the full win; training gets the
forward half.
"""
from __future__ import annotations

import functools
import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import accounting
from repro.kernels.backend import resolve_interpret

DEFAULT_BLK_Q = 256
DEFAULT_BLK_K = 512
NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc, mrow, lrow, *,
                  scale: float, causal: bool, blk_q: int, blk_k: int,
                  nk: int):
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)
        mrow[...] = jnp.full_like(mrow, NEG_INF)
        lrow[...] = jnp.zeros_like(lrow)

    q = q_ref[0].astype(jnp.float32)                 # (blk_q, D)
    k = k_ref[0].astype(jnp.float32)                 # (blk_k, D)
    v = v_ref[0].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if causal:
        iq = pl.program_id(1)
        rows = iq * blk_q + jax.lax.broadcasted_iota(jnp.int32,
                                                     (blk_q, blk_k), 0)
        cols = ik * blk_k + jax.lax.broadcasted_iota(jnp.int32,
                                                     (blk_q, blk_k), 1)
        s = jnp.where(cols <= rows, s, NEG_INF)
    m_prev = mrow[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    p = jnp.exp(s - m_new[:, None])                  # NEG_INF rows -> ~0
    alpha = jnp.exp(m_prev - m_new)
    lrow[...] = lrow[...] * alpha + jnp.sum(p, axis=-1)
    acc[...] = acc[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    mrow[...] = m_new

    @pl.when(ik == nk - 1)
    def _done():
        denom = jnp.where(lrow[...] == 0.0, 1.0, lrow[...])
        o_ref[0] = (acc[...] / denom[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "blk_q", "blk_k",
                                             "interpret"))
def flash_attention_bhsd(q: jax.Array, k: jax.Array, v: jax.Array, *,
                         causal: bool = True, blk_q: int = DEFAULT_BLK_Q,
                         blk_k: int = DEFAULT_BLK_K,
                         interpret: bool = True) -> jax.Array:
    """q (BH, Sq, D), k/v (BH, Sk, D) -> o (BH, Sq, D).  Sq/Sk are padded
    to block multiples; padded key columns are masked via the causal rule
    (causal=True) or must be absent (non-causal requires Sk % blk_k == 0).
    """
    bh, sq, d = q.shape
    sk = k.shape[1]
    scale = 1.0 / np.sqrt(d)
    bq = min(blk_q, sq)
    bk = min(blk_k, sk)
    pq, pk = (-sq) % bq, (-sk) % bk
    if pk and not causal:
        raise ValueError("non-causal flash needs Sk % blk_k == 0")
    if pq:
        q = jnp.pad(q, ((0, 0), (0, pq), (0, 0)))
    if pk:
        k = jnp.pad(k, ((0, 0), (0, pk), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pk), (0, 0)))
    nq, nk = q.shape[1] // bq, k.shape[1] // bk
    kernel = functools.partial(_flash_kernel, scale=scale, causal=causal,
                               blk_q=bq, blk_k=bk, nk=nk)
    out = pl.pallas_call(
        kernel,
        grid=(bh, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, d), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :sq]


def _flash_bshd_fwd(q, k, v, causal, interpret):
    """(B,S,H,D) wrapper with GQA expansion; returns o (B,Sq,H,D)."""
    interpret = resolve_interpret(interpret)
    b, sq, h, d = q.shape
    sk, hkv = k.shape[1], k.shape[2]
    if hkv != h:
        rep = h // hkv
        idx = jnp.arange(h) // rep
        k = jnp.take(k, idx, axis=2)
        v = jnp.take(v, idx, axis=2)
    qt = q.transpose(0, 2, 1, 3).reshape(b * h, sq, d)
    kt = k.transpose(0, 2, 1, 3).reshape(b * h, sk, d)
    vt = v.transpose(0, 2, 1, 3).reshape(b * h, sk, d)
    # analytic kernel cost (interpret-mode while bodies are counted once)
    flops = 4.0 * b * h * sq * sk * d * (0.5 if causal else 1.0)
    io = (qt.size + kt.size + vt.size * 2) * q.dtype.itemsize
    accounting.record(flops, io)
    o = flash_attention_bhsd(qt, kt, vt, causal=causal,
                             interpret=interpret)
    return o.reshape(b, h, sq, d).transpose(0, 2, 1, 3)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def flash_attention(q, k, v, causal: bool = True, interpret=None):
    """Flash attention (B,S,H,D) with GQA k/v (B,S,Hkv,D).

    ``interpret=None`` resolves backend-aware (interpret only off-TPU)."""
    return _flash_bshd_fwd(q, k, v, causal, interpret)


def _fwd(q, k, v, causal, interpret):
    return _flash_bshd_fwd(q, k, v, causal, interpret), (q, k, v)


def _dense_ref(q, k, v, causal):
    b, sq, h, d = q.shape
    hkv = k.shape[2]
    rep = h // hkv
    qg = q.reshape(b, sq, hkv, rep, d).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    s = jnp.einsum("bqgrd,bkgd->bgrqk", qg, kf) / np.sqrt(d)
    if causal:
        sk = k.shape[1]
        mask = jnp.arange(sk)[None, :] <= jnp.arange(sq)[:, None]
        s = jnp.where(mask[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bgrqk,bkgd->bqgrd", p, vf)
    return o.reshape(b, sq, h, d).astype(q.dtype)


def _bwd(causal, interpret, res, do):
    """Backward by dense recomputation (forward-only paths never hit this)."""
    q, k, v = res
    _, vjp = jax.vjp(lambda q_, k_, v_: _dense_ref(q_, k_, v_, causal),
                     q, k, v)
    return vjp(do)


flash_attention.defvjp(_fwd, _bwd)
