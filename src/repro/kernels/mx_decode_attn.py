"""Pallas decode-attention kernel over an MX-quantized KV cache.

The paper's converter fused with its consumer: the KV cache stays uint8
(codes + E8M0 scales) in HBM; dequantization happens block-by-block in VMEM
inside the online-softmax loop, so HLO-level HBM traffic is the *quantized*
cache — the full memory-roofline win of the format (a separate dequantize op
would write the f32 cache back to HBM and give most of it back).

K and V carry independent ``QuantSpec``s (the ``kv_key`` / ``kv_value``
policy roles), so e.g. INT8 keys can pair with E2M1 values in one cache.

Grid (B, Hq, nk); per step:
    q_ref        (1, 1, D)        query for this (batch, head)
    kc/vc_ref    (1, blk_k, 1, D)       u8 element codes (kv head = h//rep)
    ks/vs_ref    (1, blk_k, 1, D/32)    u8 E8M0 scales
    mask_ref     (1, blk_k)       valid-position mask (pos-dependent)
    scratch      acc (1, D) f32, m/l (1,) f32

``mx_paged_decode_attention`` is the continuous-batching variant: K/V live
in a shared page pool (pages of ``page_size`` tokens, sub-byte codes
bit-packed via repro.core.pack when the spec says ``packed``) and each
slot's logical sequence is the concatenation of the pages named by its
block-table row.  The block table and per-slot lengths ride in as
scalar-prefetch operands so the BlockSpec index maps can translate
(slot, page-step) -> physical page before the DMA is issued — the gather
happens at the HBM->VMEM boundary and HBM traffic stays at the quantized
cache, exactly as in the contiguous kernel.  The two pools are sized
per-role: a packed E2M1 value pool really is half the bytes of its INT8
key pool.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.convert import decode_elements, scale_to_f32
from repro.core.pack import unpack_codes
from repro.core.spec import QuantSpec, resolve_kv_specs
from repro.kernels import accounting
from repro.kernels.backend import resolve_interpret

DEFAULT_BLK_K = 512
NEG_INF = -1e30

_KV_DEFAULT = QuantSpec("int8", "ocp")


def _require_block32(key_spec: QuantSpec, value_spec: QuantSpec,
                     caller: str) -> None:
    """The decode kernels' scale layout is hardwired to 32-wide blocks
    (D/32 scale columns); reject other block sizes instead of silently
    dequantizing with the wrong grouping."""
    for role, spec in (("key_spec", key_spec), ("value_spec", value_spec)):
        if spec.block != 32:
            raise ValueError(
                f"{caller}: {role}={spec} has block={spec.block}, but the "
                f"decode-attention kernels support only block=32 scale "
                f"layouts")


def _dequant_block(codes, scales, spec: QuantSpec):
    """(blk_k, D) u8 + (blk_k, D/32) u8 -> (blk_k, D) f32, in VMEM."""
    blk, d = codes.shape
    elem = decode_elements(codes, spec.format, spec.mode)
    sfac = scale_to_f32(scales)                     # (blk_k, D/32)
    w = elem.reshape(blk, d // 32, 32) * sfac[:, :, None]
    return w.reshape(blk, d)


def _dequant_pool_block(codes, scales, spec: QuantSpec, d):
    """(blk, CB) pool u8 + (blk, D/32) u8 -> (blk, D) f32.  Unpacks the
    bit-packed sub-byte codes in VMEM when the spec stores packed
    (identity for 8-bit formats), then dequantizes like the contiguous
    path."""
    if spec.packed:
        codes = unpack_codes(codes, spec.fmt, d)
    return _dequant_block(codes, scales, spec)


def _decode_kernel(q_ref, kc_ref, ks_ref, vc_ref, vs_ref, mask_ref, o_ref,
                   acc, mrow, lrow, *, key_spec: QuantSpec,
                   value_spec: QuantSpec, nk: int):
    jk = pl.program_id(2)

    @pl.when(jk == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)
        mrow[...] = jnp.full_like(mrow, NEG_INF)
        lrow[...] = jnp.zeros_like(lrow)

    q = q_ref[0, 0].astype(jnp.float32)                    # (1, D)
    k = _dequant_block(kc_ref[0, :, 0, :], ks_ref[0, :, 0, :], key_spec)
    v = _dequant_block(vc_ref[0, :, 0, :], vs_ref[0, :, 0, :], value_spec)
    d = q.shape[-1]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) \
        / np.sqrt(d)                                       # (1, blk_k)
    valid = mask_ref[0][None, :]
    s = jnp.where(valid, s, NEG_INF)
    m_prev = mrow[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    p = jnp.exp(s - m_new[:, None])
    alpha = jnp.exp(m_prev - m_new)
    lrow[...] = lrow[...] * alpha + jnp.sum(p, axis=-1)
    acc[...] = acc[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    mrow[...] = m_new

    @pl.when(jk == nk - 1)
    def _done():
        denom = jnp.where(lrow[...] == 0.0, 1.0, lrow[...])
        o_ref[0, 0] = (acc[...] / denom[:, None]).astype(o_ref.dtype)


def mx_decode_attention(q: jax.Array, k_codes: jax.Array,
                        k_scales: jax.Array, v_codes: jax.Array,
                        v_scales: jax.Array, pos: jax.Array, *,
                        spec=None, key_spec=None, value_spec=None,
                        rep: int = 1, blk_k: int = DEFAULT_BLK_K,
                        interpret: Optional[bool] = None,
                        fmt: Optional[str] = None,
                        mode: Optional[str] = None) -> jax.Array:
    """q (B,1,Hq,D); cache codes (B,S,Hkv,D) u8 + scales (B,S,Hkv,D/32);
    attends over positions <= pos.  Returns (B,1,Hq,D).

    ``key_spec``/``value_spec`` (or the uniform ``spec``) select the
    per-role element formats; the ``fmt=``/``mode=`` kwargs are the
    uniform deprecation shim (warns once).  ``interpret=None`` resolves
    backend-aware (interpret only off-TPU)."""
    key_spec, value_spec = resolve_kv_specs(
        spec, key_spec, value_spec, fmt, mode, default=_KV_DEFAULT,
        caller="mx_decode_attention")
    _require_block32(key_spec, value_spec, "mx_decode_attention")
    return _mx_decode_attention(q, k_codes, k_scales, v_codes, v_scales,
                                pos, key_spec, value_spec, rep, blk_k,
                                resolve_interpret(interpret))


@functools.partial(jax.jit, static_argnames=("key_spec", "value_spec",
                                             "rep", "blk_k", "interpret"))
def _mx_decode_attention(q, k_codes, k_scales, v_codes, v_scales, pos,
                         key_spec: QuantSpec, value_spec: QuantSpec,
                         rep: int, blk_k: int,
                         interpret: bool) -> jax.Array:
    b, _, hq, d = q.shape
    s, hkv = k_codes.shape[1], k_codes.shape[2]
    bk = min(blk_k, s)
    assert s % bk == 0, (s, bk)
    nk = s // bk
    mask = (jnp.arange(s)[None, :] <= pos).astype(jnp.bool_)
    mask = jnp.broadcast_to(mask, (b, s))
    qt = q[:, 0][:, :, None, :]                            # (B, Hq, 1, D)
    kernel = functools.partial(_decode_kernel, key_spec=key_spec,
                               value_spec=value_spec, nk=nk)
    nbl = d // 32
    out = pl.pallas_call(
        kernel,
        grid=(b, hq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, 1, d), lambda bb, h, j: (bb, h, 0, 0)),
            pl.BlockSpec((1, bk, 1, d),
                         lambda bb, h, j, rep=rep: (bb, j, h // rep, 0)),
            pl.BlockSpec((1, bk, 1, nbl),
                         lambda bb, h, j, rep=rep: (bb, j, h // rep, 0)),
            pl.BlockSpec((1, bk, 1, d),
                         lambda bb, h, j, rep=rep: (bb, j, h // rep, 0)),
            pl.BlockSpec((1, bk, 1, nbl),
                         lambda bb, h, j, rep=rep: (bb, j, h // rep, 0)),
            pl.BlockSpec((1, bk), lambda bb, h, j: (bb, j)),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, d), lambda bb, h, j: (bb, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hq, 1, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((1, d), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
        ],
        interpret=interpret,
    )(qt, k_codes, k_scales, v_codes, v_scales, mask)
    # analytic cost: dequant+dot over the full cache per query
    flops = 4.0 * b * hq * s * d + 10.0 * b * hq * s * d  # dots + dequant
    io = (k_codes.size + v_codes.size + k_scales.size + v_scales.size
          + q.size * q.dtype.itemsize * 2)
    accounting.record(flops, io)
    return out.transpose(0, 2, 1, 3)                       # (B, 1, Hq, D)


# =============================================================================
# Paged variant (continuous batching)
# =============================================================================
def _paged_kernel(bt_ref, len_ref, q_ref, kc_ref, ks_ref, vc_ref, vs_ref,
                  o_ref, acc, mrow, lrow, *, key_spec: QuantSpec,
                  value_spec: QuantSpec, d: int, page: int, np_max: int):
    bb = pl.program_id(0)
    jk = pl.program_id(2)

    @pl.when(jk == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)
        mrow[...] = jnp.full_like(mrow, NEG_INF)
        lrow[...] = jnp.zeros_like(lrow)

    q = q_ref[0, 0].astype(jnp.float32)                    # (1, D)
    k = _dequant_pool_block(kc_ref[0, :, 0, :], ks_ref[0, :, 0, :],
                            key_spec, d)
    v = _dequant_pool_block(vc_ref[0, :, 0, :], vs_ref[0, :, 0, :],
                            value_spec, d)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) \
        / np.sqrt(d)                                       # (1, page)
    pos = jk * page + jax.lax.broadcasted_iota(jnp.int32, (1, page), 1)
    valid = pos <= len_ref[bb]
    s = jnp.where(valid, s, NEG_INF)
    m_prev = mrow[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    p = jnp.exp(s - m_new[:, None])
    alpha = jnp.exp(m_prev - m_new)
    lrow[...] = lrow[...] * alpha + jnp.sum(p, axis=-1)
    acc[...] = acc[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    mrow[...] = m_new

    @pl.when(jk == np_max - 1)
    def _done():
        denom = jnp.where(lrow[...] == 0.0, 1.0, lrow[...])
        o_ref[0, 0] = (acc[...] / denom[:, None]).astype(o_ref.dtype)


def mx_paged_decode_attention(q: jax.Array, kc_pool: jax.Array,
                              ks_pool: jax.Array, vc_pool: jax.Array,
                              vs_pool: jax.Array, block_tables: jax.Array,
                              lengths: jax.Array, *, spec=None,
                              key_spec=None, value_spec=None, rep: int = 1,
                              interpret: Optional[bool] = None,
                              fmt: Optional[str] = None,
                              mode: Optional[str] = None) -> jax.Array:
    """Decode attention over a paged MX KV cache.

    q             (B, 1, Hq, D)
    kc/vc_pool    (n_pages, page, Hkv, CB) u8 — CB is the per-role storage
                  bytes per token-head (== D for 8-bit or unpacked specs;
                  bit-packed below that); K and V pools may differ
    ks/vs_pool    (n_pages, page, Hkv, D/32) u8 E8M0 scales
    block_tables  (B, max_pages) i32 physical page per (slot, logical page);
                  rows padded with 0 (a reserved trash page) past the slot's
                  allocation — those positions are masked by ``lengths``.
    lengths       (B,) i32 — slot b attends to logical positions <= lengths[b]

    Returns (B, 1, Hq, D).  The block table and lengths are scalar-prefetch
    operands: index maps resolve the physical page before the page's DMA.
    ``key_spec``/``value_spec`` (or uniform ``spec``) pick the per-role
    formats; ``fmt=``/``mode=`` is the uniform deprecation shim.
    """
    key_spec, value_spec = resolve_kv_specs(
        spec, key_spec, value_spec, fmt, mode, default=_KV_DEFAULT,
        caller="mx_paged_decode_attention")
    _require_block32(key_spec, value_spec, "mx_paged_decode_attention")
    return _mx_paged_decode_attention(q, kc_pool, ks_pool, vc_pool,
                                      vs_pool, block_tables, lengths,
                                      key_spec, value_spec, rep,
                                      resolve_interpret(interpret))


@functools.partial(jax.jit, static_argnames=("key_spec", "value_spec",
                                             "rep", "interpret"))
def _mx_paged_decode_attention(q, kc_pool, ks_pool, vc_pool, vs_pool,
                               block_tables, lengths,
                               key_spec: QuantSpec, value_spec: QuantSpec,
                               rep: int, interpret: bool) -> jax.Array:
    b, _, hq, d = q.shape
    n_pages, page, hkv, cb_k = kc_pool.shape
    cb_v = vc_pool.shape[-1]
    np_max = block_tables.shape[1]
    assert cb_k == key_spec.storage_nbytes(d), (cb_k, key_spec, d)
    assert cb_v == value_spec.storage_nbytes(d), (cb_v, value_spec, d)
    nbl = d // 32
    qt = q[:, 0][:, :, None, :]                            # (B, Hq, 1, D)
    kernel = functools.partial(_paged_kernel, key_spec=key_spec,
                               value_spec=value_spec, d=d, page=page,
                               np_max=np_max)

    def page_map(bb, h, j, bt, ln, rep=rep):
        return (bt[bb, j], 0, h // rep, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, hq, np_max),
        in_specs=[
            pl.BlockSpec((1, 1, 1, d),
                         lambda bb, h, j, bt, ln: (bb, h, 0, 0)),
            pl.BlockSpec((1, page, 1, cb_k), page_map),
            pl.BlockSpec((1, page, 1, nbl), page_map),
            pl.BlockSpec((1, page, 1, cb_v), page_map),
            pl.BlockSpec((1, page, 1, nbl), page_map),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, d),
                               lambda bb, h, j, bt, ln: (bb, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((1, d), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, hq, 1, d), q.dtype),
        interpret=interpret,
    )(block_tables, lengths, qt, kc_pool, ks_pool, vc_pool, vs_pool)
    # analytic cost: the gathered pages (quantized bytes), not the pool
    s = np_max * page
    flops = 4.0 * b * hq * s * d + 10.0 * b * hq * s * d
    io = (b * s * hkv * (cb_k + cb_v + 2 * nbl)
          + q.size * q.dtype.itemsize * 2)
    accounting.record(flops, io)
    return out.transpose(0, 2, 1, 3)                       # (B, 1, Hq, D)
