"""Pallas TPU kernel: matmul with MX-quantized weights (dequant-in-VMEM).

This is the *consumer* that makes the paper's converter a framework feature:
weights live in HBM as MX element codes (uint8) + E8M0 scales (uint8, one per
32 along the contraction axis), cutting weight HBM traffic ~3.9x vs f32
(~1.94x vs bf16).  Each grid step:

  HBM->VMEM:  A tile (BM, BK) f32/bf16, W codes (BK, BN) u8,
              W scales (BK/32, BN) u8
  VMEM:       branchless decode codes -> f32  (VPU)
              multiply by broadcast scales    (VPU)
              A @ W_deq accumulated in f32    (MXU)

Tiling: BM=BN=BK=256 default => A 256 KiB + codes 64 KiB + scales 2 KiB +
acc 256 KiB per step; MXU dims are multiples of 128.  The contraction axis
is the innermost grid dimension; the output tile is revisited and
accumulated across it (standard Pallas reduction pattern).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.convert import decode_elements, scale_to_f32
from repro.core.pack import packed_nbytes, unpack_codes_rows
from repro.core.spec import QuantSpec, resolve_spec
from repro.kernels.backend import resolve_interpret

DEFAULT_BM = 256
DEFAULT_BN = 256
DEFAULT_BK = 256


def dequant_tile(codes: jax.Array, scales: jax.Array,
                 spec: QuantSpec) -> jax.Array:
    """(BK, BN) u8 codes + (BK//block, BN) u8 scales -> (BK, BN) f32."""
    bk, bn = codes.shape
    block = spec.block
    elem = decode_elements(codes, spec.format, spec.mode)
    sfac = scale_to_f32(scales)                      # (BK//block, BN)
    w = elem.reshape(bk // block, block, bn) * sfac[:, None, :]
    return w.reshape(bk, bn)


def _mx_matmul_kernel(a_ref, c_ref, s_ref, o_ref, *, spec: QuantSpec,
                      bk: int, packed: bool):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    a = a_ref[...].astype(jnp.float32)
    codes = c_ref[...]
    if packed:
        # sub-byte codes arrive bit-packed along K; unpack the tile in VMEM
        codes = unpack_codes_rows(codes, spec.fmt, bk)
    w = dequant_tile(codes, s_ref[...], spec)
    o_ref[...] += jnp.dot(a, w, preferred_element_type=jnp.float32)


def mx_matmul_2d(a: jax.Array, codes: jax.Array, scales: jax.Array,
                 spec=None, mode: Optional[str] = None,
                 block: Optional[int] = None, bm: int = DEFAULT_BM,
                 bn: int = DEFAULT_BN, bk: int = DEFAULT_BK,
                 interpret: Optional[bool] = None, *,
                 fmt: Optional[str] = None) -> jax.Array:
    """a (M, K) @ dequant(codes (K, N), scales (K//block, N)) -> (M, N) f32.

    K must be a multiple of the spec's block; M/N/K are padded to tile
    multiples.  When ``spec.packed`` and the format is sub-byte, ``codes``
    is the bit-packed byte stream along K — shape (storage_nbytes(K), N) —
    and each grid step unpacks its tile in VMEM, so fp (or even unpacked
    u8) weights never round-trip through HBM.  ``spec`` is a QuantSpec
    (deprecation shim: fmt=/mode=).  ``interpret=None`` resolves
    backend-aware (interpret only off-TPU)."""
    spec = resolve_spec(spec, fmt, mode, block,
                        default=QuantSpec("e4m3", "paper"),
                        caller="mx_matmul_2d")
    return _mx_matmul_2d(a, codes, scales, spec, bm, bn, bk,
                         resolve_interpret(interpret))


@functools.partial(jax.jit,
                   static_argnames=("spec", "bm", "bn", "bk", "interpret"))
def _mx_matmul_2d(a: jax.Array, codes: jax.Array, scales: jax.Array,
                  spec: QuantSpec, bm: int, bn: int, bk: int,
                  interpret: bool) -> jax.Array:
    block = spec.block
    m, k = a.shape
    kc, n = codes.shape
    # Packed-ness is inferred from the code rows, not spec.packed: legacy
    # callers pass unpacked (K, N) codes under specs whose packed flag
    # defaults to True, while the weight-resident path ships the bit-packed
    # byte stream (storage_nbytes(K), N).  Sub-byte packing always shrinks
    # the row count, so the two layouts are unambiguous.
    if kc == k:
        packed = False
    elif spec.format.code_bits < 8 and kc == packed_nbytes(spec.fmt, k):
        packed = True
    else:
        raise ValueError(
            f"codes have {kc} rows; expected K={k} (unpacked) or "
            f"storage_nbytes(K)={packed_nbytes(spec.fmt, k)} (bit-packed) "
            f"for fmt={spec.fmt}")
    assert k % block == 0, f"K={k} must be a multiple of block={block}"
    if min(bm, bn, bk) < 1:
        raise ValueError(f"tile sizes must be positive, got "
                         f"bm={bm}, bn={bn}, bk={bk}")
    bm_ = min(bm, m)
    bn_ = min(bn, n)
    # The scale BlockSpec covers bk_ // block rows, so a bk_ that is not a
    # block multiple would silently truncate the scale tile (e.g. bk=48,
    # block=32 -> one scale row stretched over 48 code rows).  Round down
    # to a whole number of blocks and refuse tiles smaller than one block.
    bk_ = min(bk, k)
    bk_ -= bk_ % block
    if bk_ == 0:
        raise ValueError(
            f"bk={bk} is smaller than the scale block ({block}); the "
            f"contraction tile must cover at least one full block")
    pm, pn, pk = (-m) % bm_, (-n) % bn_, (-k) % bk_
    # k and bk_ are both block multiples, so pk is too: the zero-padded
    # code/scale rows line up on block boundaries and decode to exact 0.0
    # (decode(0) == 0.0 in every format/mode, and 0.0 * 2^-127 == 0.0).
    ap = jnp.pad(a, ((0, pm), (0, pk)))
    pkc = packed_nbytes(spec.fmt, pk) if packed else pk
    cp = jnp.pad(codes, ((0, pkc), (0, pn)))
    sp = jnp.pad(scales, ((0, pk // block), (0, pn)))
    mp, kp = ap.shape
    np_ = cp.shape[1]
    grid = (mp // bm_, np_ // bn_, kp // bk_)
    # bk_ is a multiple of block >= 32, so packed byte rows stay tile-linear:
    # tile kk starts at byte row kk * storage_nbytes(bk_).
    cbk = packed_nbytes(spec.fmt, bk_) if packed else bk_
    kernel = functools.partial(_mx_matmul_kernel, spec=spec, bk=bk_,
                               packed=packed)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm_, bk_), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((cbk, bn_), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bk_ // block, bn_), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm_, bn_), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=interpret,
    )(ap, cp, sp)
    return out[:m, :n]
