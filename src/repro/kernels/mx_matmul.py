"""Pallas TPU kernel: matmul with MX-quantized weights (dequant-in-VMEM).

This is the *consumer* that makes the paper's converter a framework feature:
weights live in HBM as MX element codes (uint8) + E8M0 scales (uint8, one per
32 along the contraction axis), cutting weight HBM traffic ~3.9x vs f32
(~1.94x vs bf16).  Each grid step:

  HBM->VMEM:  A tile (BM, BK) f32/bf16, W codes (BK, BN) u8,
              W scales (BK/32, BN) u8
  VMEM:       branchless decode codes -> f32  (VPU)
              multiply by broadcast scales    (VPU)
              A @ W_deq accumulated in f32    (MXU)

Tiling: BM=BN=BK=256 default => A 256 KiB + codes 64 KiB + scales 2 KiB +
acc 256 KiB per step; MXU dims are multiples of 128.  The contraction axis
is the innermost grid dimension; the output tile is revisited and
accumulated across it (standard Pallas reduction pattern).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.convert import decode_elements, scale_to_f32
from repro.core.spec import QuantSpec, resolve_spec
from repro.kernels.backend import resolve_interpret

DEFAULT_BM = 256
DEFAULT_BN = 256
DEFAULT_BK = 256


def dequant_tile(codes: jax.Array, scales: jax.Array,
                 spec: QuantSpec) -> jax.Array:
    """(BK, BN) u8 codes + (BK//block, BN) u8 scales -> (BK, BN) f32."""
    bk, bn = codes.shape
    block = spec.block
    elem = decode_elements(codes, spec.format, spec.mode)
    sfac = scale_to_f32(scales)                      # (BK//block, BN)
    w = elem.reshape(bk // block, block, bn) * sfac[:, None, :]
    return w.reshape(bk, bn)


def _mx_matmul_kernel(a_ref, c_ref, s_ref, o_ref, *, spec: QuantSpec):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    a = a_ref[...].astype(jnp.float32)
    w = dequant_tile(c_ref[...], s_ref[...], spec)
    o_ref[...] += jnp.dot(a, w, preferred_element_type=jnp.float32)


def mx_matmul_2d(a: jax.Array, codes: jax.Array, scales: jax.Array,
                 spec=None, mode: Optional[str] = None,
                 block: Optional[int] = None, bm: int = DEFAULT_BM,
                 bn: int = DEFAULT_BN, bk: int = DEFAULT_BK,
                 interpret: Optional[bool] = None, *,
                 fmt: Optional[str] = None) -> jax.Array:
    """a (M, K) @ dequant(codes (K, N), scales (K//block, N)) -> (M, N) f32.

    K must be a multiple of the spec's block; M/N/K are padded to tile
    multiples.  ``spec`` is a QuantSpec (deprecation shim: fmt=/mode=).
    ``interpret=None`` resolves backend-aware (interpret only off-TPU)."""
    spec = resolve_spec(spec, fmt, mode, block,
                        default=QuantSpec("e4m3", "paper"),
                        caller="mx_matmul_2d")
    return _mx_matmul_2d(a, codes, scales, spec, bm, bn, bk,
                         resolve_interpret(interpret))


@functools.partial(jax.jit,
                   static_argnames=("spec", "bm", "bn", "bk", "interpret"))
def _mx_matmul_2d(a: jax.Array, codes: jax.Array, scales: jax.Array,
                  spec: QuantSpec, bm: int, bn: int, bk: int,
                  interpret: bool) -> jax.Array:
    block = spec.block
    m, k = a.shape
    k2, n = codes.shape
    assert k == k2, (a.shape, codes.shape)
    assert k % block == 0, f"K={k} must be a multiple of block={block}"
    bm_ = min(bm, m)
    bn_ = min(bn, n)
    bk_ = min(bk, k)
    pm, pn, pk = (-m) % bm_, (-n) % bn_, (-k) % bk_
    ap = jnp.pad(a, ((0, pm), (0, pk)))
    cp = jnp.pad(codes, ((0, pk), (0, pn)))
    sp = jnp.pad(scales, ((0, pk // block), (0, pn)))
    mp, kp = ap.shape
    np_ = cp.shape[1]
    grid = (mp // bm_, np_ // bn_, kp // bk_)
    kernel = functools.partial(_mx_matmul_kernel, spec=spec)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm_, bk_), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk_, bn_), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bk_ // block, bn_), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm_, bn_), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=interpret,
    )(ap, cp, sp)
    return out[:m, :n]
