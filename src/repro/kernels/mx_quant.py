"""Pallas TPU kernel for the paper's FP32 -> MX converter.

TPU-native adaptation of the combinational circuit (DESIGN.md §2):

  * the paper's 32-input comparator tree  -> lane-local max over a 32-wide
    trailing sub-axis of the VMEM tile (the VPU reduces with the same
    O(log 32) tree, 8x128 lanes at a time);
  * the 32 parallel LUT quantizers        -> branchless integer ops on the
    bitcast(u32) view of the tile (mask/shift exponent extract, add-shift
    ties-away rounding, selects for FTZ / saturation / markers);
  * the 1288-pin I/O interface            -> double-buffered HBM->VMEM tile
    pipeline driven by ``pl.pallas_call`` BlockSpecs.

Tile geometry: inputs are processed as (BM, BN) f32 tiles with BN a multiple
of 32*128 so each 8x128 VREG row holds 4 whole MX blocks; the per-block scale
tile is (BM, BN//32).  Default (256, 512) => 512 KiB in + 132 KiB out per
grid step, comfortably inside a v5e core's ~16 MiB VMEM with double
buffering.

The kernel body reuses the *same* integer-exact element functions as the
pure-JAX reference (repro/core/convert.py), so tests assert bit-identity.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.convert import (_f32_fields, _quant_float_ocp,
                                _quant_float_paper, _quant_int8,
                                _marker_codes, shared_scale)
from repro.core.formats import MXFormat
from repro.core.spec import QuantSpec, resolve_spec
from repro.kernels.backend import resolve_interpret

DEFAULT_BM = 256
DEFAULT_BN = 512  # multiple of 32 (block) and 128 (lanes)


def _quant_tile(x_tile: jax.Array, fmt: MXFormat, mode: str,
                block: int) -> Tuple[jax.Array, jax.Array]:
    """Quantize one (BM, BN) f32 tile -> (codes u8 (BM,BN), scales u8
    (BM, BN//block)).  Pure jnp: runs inside the Pallas kernel body and in
    the reference oracle."""
    bm, bn = x_tile.shape
    xg = x_tile.reshape(bm, bn // block, block)
    sign, exp, man = _f32_fields(xg)
    finite = exp != 0xFF
    is_nan = (~finite) & (man != 0)
    is_inf = (~finite) & (man == 0)
    any_nan = jnp.any(is_nan, axis=-1)
    any_inf = jnp.any(is_inf, axis=-1)
    # step 1: comparator tree == lane max over the 32-wide sub-axis
    ev_max = jnp.max(jnp.where(finite, exp, 0), axis=-1)
    # step 2: shared scale
    xscale = shared_scale(ev_max, fmt, mode, any_nan, any_inf)
    xblk = jnp.broadcast_to(xscale[..., None].astype(jnp.int32), xg.shape)
    # step 3: private elements
    if fmt.is_int:
        codes = _quant_int8(sign, exp, man, xblk, mode)
    elif mode == "paper":
        codes = _quant_float_paper(sign, exp, man, xblk, fmt)
    else:
        codes = _quant_float_ocp(sign, exp, man, xblk, fmt)
    if mode == "paper":
        blk_nan = jnp.broadcast_to(any_nan[..., None], xg.shape)
        blk_inf = jnp.broadcast_to(any_inf[..., None], xg.shape)
        codes = jnp.where(blk_inf, _marker_codes(sign, fmt, "inf"), codes)
        codes = jnp.where(blk_nan, _marker_codes(sign, fmt, "nan"), codes)
    return codes.reshape(bm, bn), xscale


def _mx_quant_kernel(x_ref, codes_ref, scales_ref, *, fmt: MXFormat,
                     mode: str, block: int):
    x = x_ref[...].astype(jnp.float32)
    codes, scales = _quant_tile(x, fmt, mode, block)
    codes_ref[...] = codes
    scales_ref[...] = scales


def mx_quantize_2d(x: jax.Array, spec=None, mode: Optional[str] = None,
                   block: Optional[int] = None, bm: int = DEFAULT_BM,
                   bn: int = DEFAULT_BN,
                   interpret: Optional[bool] = None, *,
                   fmt: Optional[str] = None
                   ) -> Tuple[jax.Array, jax.Array]:
    """Quantize a 2-D array (M, N) along the trailing axis with the Pallas
    converter kernel.  M, N need not be tile-aligned (zero padding; zeros
    never perturb a block's max exponent).  ``spec`` is a QuantSpec; the
    ``fmt=``/``mode=``/``block=`` kwargs are the deprecation shim.
    ``interpret=None`` resolves backend-aware (interpret only off-TPU)."""
    spec = resolve_spec(spec, fmt, mode, block,
                        default=QuantSpec("e4m3", "paper"),
                        caller="mx_quantize_2d")
    return _mx_quantize_2d(x, spec, bm, bn, resolve_interpret(interpret))


@functools.partial(jax.jit,
                   static_argnames=("spec", "bm", "bn", "interpret"))
def _mx_quantize_2d(x: jax.Array, spec: QuantSpec, bm: int, bn: int,
                    interpret: bool) -> Tuple[jax.Array, jax.Array]:
    f, mode, block = spec.format, spec.mode, spec.block
    m, n = x.shape
    bm_ = min(bm, max(1, m))
    bn_ = min(bn, n) if n % block == 0 and n < bn else bn
    # pad to tile multiples (zeros are neutral for the exponent max)
    pm = (-m) % bm_
    pn = (-n) % bn_
    xp = jnp.pad(x.astype(jnp.float32), ((0, pm), (0, pn)))
    mp, np_ = xp.shape
    grid = (mp // bm_, np_ // bn_)
    kernel = functools.partial(_mx_quant_kernel, fmt=f, mode=mode,
                               block=block)
    codes, scales = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((bm_, bn_), lambda i, j: (i, j))],
        out_specs=[
            pl.BlockSpec((bm_, bn_), lambda i, j: (i, j)),
            pl.BlockSpec((bm_, bn_ // block), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((mp, np_), jnp.uint8),
            jax.ShapeDtypeStruct((mp, np_ // block), jnp.uint8),
        ],
        interpret=interpret,
    )(xp)
    return codes[:m, :n], scales[:m, : (n + block - 1) // block]
