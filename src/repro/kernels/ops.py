"""Jit'd public wrappers over the Pallas kernels.

Pallas execution mode is backend-aware (``kernels.backend``): interpret
mode (kernel bodies executed in Python for correctness validation) on CPU,
compiled Mosaic on TPU; ``REPRO_PALLAS_INTERPRET=0/1`` overrides either
way.  Every wrapper below passes ``interpret=None`` through to the kernels,
which resolve it per call.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.convert import MXArray
from repro.core.spec import QuantSpec, resolve_spec
from repro.kernels import mx_matmul as _mm
from repro.kernels import mx_quant as _mq

_PAPER_DEFAULT = QuantSpec("e4m3", "paper")


def mx_quantize_pallas(x: jax.Array, spec=None, mode: Optional[str] = None,
                       block: Optional[int] = None, *,
                       fmt: Optional[str] = None) -> MXArray:
    """Quantize an ND tensor along its trailing axis with the Pallas
    converter kernel; returns the same MXArray container as the pure-JAX
    path (bit-identical codes/scales).  ``spec`` is a QuantSpec; the
    ``fmt=``/``mode=``/``block=`` kwargs are the deprecation shim."""
    spec = resolve_spec(spec, fmt, mode, block, default=_PAPER_DEFAULT,
                        caller="mx_quantize_pallas")
    shape = x.shape
    n = shape[-1]
    x2 = x.reshape(-1, n)
    codes, scales = _mq.mx_quantize_2d(x2, spec, interpret=None)
    nblk = (n + spec.block - 1) // spec.block
    # re-pad codes to the block multiple to match MXArray's invariant
    pad = nblk * spec.block - n
    if pad:
        codes = jnp.pad(codes, ((0, 0), (0, pad)))
    codes = codes.reshape(shape[:-1] + (nblk * spec.block,))
    scales = scales.reshape(shape[:-1] + (nblk,))
    return MXArray.from_spec(codes, scales, spec, orig_len=n,
                             axis=len(shape) - 1)


def mx_matmul(a: jax.Array, w: MXArray) -> jax.Array:
    """a (..., K) @ w, where w is an MXArray of logical shape (K, N)
    quantized along axis 0 (the contraction axis)."""
    assert w.axis == 0, "weights must be quantized along the contraction dim"
    k, n = w.shape
    lead = a.shape[:-1]
    a2 = a.reshape(-1, a.shape[-1])
    out = _mm.mx_matmul_2d(a2, w.codes, w.scales, w.spec,
                           interpret=None)
    return out.reshape(lead + (n,))


def mx_matmul_resident(a: jax.Array, w, impl: Optional[str] = None
                       ) -> jax.Array:
    """a (..., K) @ dequant(w) for a weight-resident ``MXWeight`` (K, N).

    Dispatches through ``kernels.backend.resolve_matmul_impl``: "fused"
    feeds the (possibly bit-packed) codes straight into the Pallas kernel,
    which unpacks and dequantizes tiles in VMEM; "einsum" materializes the
    f32 weight and contracts with a plain einsum.  Both return f32 and are
    bit-identical when the contraction fits one k-tile (K <= bk).
    """
    from repro.core.mx_weight import MXWeight
    from repro.kernels import backend
    from repro.kernels.backend import resolve_matmul_impl
    assert isinstance(w, MXWeight), type(w)
    assert w.codes.ndim == 2, (
        f"mx_matmul_resident takes a single (K, N) weight, codes shape "
        f"{tuple(w.codes.shape)}; slice batch axes with w.take(i)")
    impl = resolve_matmul_impl(impl)
    lead = a.shape[:-1]

    def einsum_path():
        wd = w.dequantize().astype(a.dtype)
        return jnp.einsum("...k,kn->...n", a, wd,
                          preferred_element_type=jnp.float32)

    if impl == "einsum":
        return einsum_path()

    def fused_path():
        a2 = a.reshape(-1, a.shape[-1])
        if a2.shape[1] != w.kp:      # K was padded to a block multiple
            a2 = jnp.pad(a2, ((0, 0), (0, w.kp - a2.shape[1])))
        from repro.kernels.backend import resolve_interpret
        if resolve_interpret(None):
            # interpret mode (CPU correctness path): per-grid-step overhead
            # dominates, so cover N in one tile and K in few — 5-10x faster
            # than VMEM-sized tiles at decode shapes, same results
            out = _mm.mx_matmul_2d(a2, w.codes, w.scales, w.spec,
                                   bn=w.n, bk=min(w.kp, 1024))
        else:
            out = _mm.mx_matmul_2d(a2, w.codes, w.scales, w.spec)
        return out.reshape(lead + (w.n,))

    # supervised dispatch: a failed Pallas matmul degrades the op to the
    # einsum path (logged once) instead of killing the serving process
    out = backend.supervised("mx_matmul", fused_path)
    return einsum_path() if out is None else out


def quantize_weight(w: jax.Array, spec=None, mode: Optional[str] = None,
                    block: Optional[int] = None, *,
                    fmt: Optional[str] = None) -> MXArray:
    """Quantize a (K, N) weight along K (contraction) for mx_matmul."""
    from repro.core.convert import mx_quantize
    spec = resolve_spec(spec, fmt, mode, block, default=_PAPER_DEFAULT,
                        caller="quantize_weight")
    return mx_quantize(w, spec, axis=0)


def flash_attention_ctx(q: jax.Array, k: jax.Array, v: jax.Array,
                        causal: bool = True):
    """Flash attention, sharding-aware.

    With sharding rules installed (launcher/dry-run), wraps the Pallas call
    in shard_map manual over (batch, model): q sharded by heads over
    "model", k/v replicated over "model" (GQA kv-heads rarely divide the TP
    axis); the GQA expansion happens per-shard with global head offsets.
    Returns None if the head count does not divide the model axis (caller
    falls back to dense attention).
    """
    from jax.sharding import PartitionSpec as P
    from repro.dist import compat
    from repro.dist.sharding import current_rules
    from repro.kernels.flash_attn import flash_attention

    rules = current_rules()
    if rules is None:
        return flash_attention(q, k, v, causal)
    mesh = compat.get_abstract_mesh()
    if mesh is None or mesh.empty or not rules.get("model"):
        return flash_attention(q, k, v, causal)
    model_ax = rules["model"][0]
    batch_axes = rules.get("batch")
    h, hkv = q.shape[2], k.shape[2]
    rep = h // hkv
    msize = mesh.shape[model_ax]
    bsz = q.shape[0]
    if h % msize != 0 or (batch_axes and bsz % _prod(
            mesh.shape[a] for a in batch_axes) != 0):
        return None
    qspec = P(batch_axes, None, model_ax, None)
    kvspec = P(batch_axes, None, None, None)

    def body(ql, kl, vl):
        hl = ql.shape[2]
        off = jax.lax.axis_index(model_ax) * hl
        idx = (off + jnp.arange(hl)) // rep
        ke = jnp.take(kl, idx, axis=2)
        ve = jnp.take(vl, idx, axis=2)
        return flash_attention(ql, ke, ve, causal)

    manual = set(a for a in ((batch_axes or ()) + (model_ax,)))
    return compat.shard_map(body, mesh=mesh,
                            in_specs=(qspec, kvspec, kvspec),
                            out_specs=qspec, check_vma=False,
                            axis_names=manual)(q, k, v)


def _prod(it):
    out = 1
    for x in it:
        out *= x
    return out


def mx_decode_attention_ctx(q: jax.Array, cache: dict, pos, cfg):
    """Sharded wrapper for the MX decode-attention kernel: the u8 cache is
    consumed directly (batch-sharded over the data axes); q is sliced to
    the local batch by shard_map.  Returns (B, 1, Hq, D) or None if the
    cache layout is unsupported (caller falls back to dequant + dense)."""
    from jax.sharding import PartitionSpec as P
    from repro.dist import compat
    from repro.dist.sharding import current_rules
    from repro.kernels.mx_decode_attn import mx_decode_attention

    kc, ks = cache["k_codes"], cache["k_scales"]
    vc, vs = cache["v_codes"], cache["v_scales"]
    hq, d = q.shape[2], q.shape[3]
    hkv = kc.shape[2]
    rep = hq // hkv
    kk, kv = cfg.mx.kv_key, cfg.mx.kv_value
    if d % 32 or kc.shape[-1] != d or vc.shape[-1] != d \
            or kk.block != 32 or kv.block != 32:
        return None                      # padded code layout unsupported

    def call(q_, kc_, ks_, vc_, vs_, pos_):
        return mx_decode_attention(q_, kc_, ks_, vc_, vs_, pos_,
                                   key_spec=kk, value_spec=kv, rep=rep)

    rules = current_rules()
    if rules is None:
        return call(q, kc, ks, vc, vs, pos)
    mesh = compat.get_abstract_mesh()
    if mesh is None or mesh.empty:
        return call(q, kc, ks, vc, vs, pos)
    ba = rules.get("kv_batch") or ("data",)
    ba = tuple(a for a in ba if a in mesh.axis_names)
    if q.shape[0] % _prod(mesh.shape[a] for a in ba):
        return None
    bspec = P(ba, None, None, None)
    return compat.shard_map(call, mesh=mesh,
                            in_specs=(bspec, bspec, bspec, bspec, bspec,
                                      P()),
                            out_specs=bspec, check_vma=False,
                            axis_names=set(ba))(q, kc, ks, vc, vs, pos)


def mx_paged_decode_attention_ctx(q: jax.Array, pool: dict,
                                  block_tables: jax.Array,
                                  lengths: jax.Array, cfg):
    """Sharded wrapper for the paged MX decode-attention kernel.

    Slots (the batch dim of q / block tables / lengths) shard over the
    "kv_batch" axes; the page pool follows the "kv_pages" rule — None
    (default) replicates it inside the shard_map region so any slot can
    reference any physical page without a gather.  Returns (B, 1, Hq, D)
    or None if the layout is unsupported (caller falls back to the
    gather + dense path).

    This wrapper is also the kernel entry of the *scanned* decode step:
    the serving engine's fused multi-step window traces it once inside a
    ``lax.scan`` body whose carry includes the page pool, so everything
    here must be trace-stable — the mesh/rules are resolved from ambient
    context (constant across the window), the scalar-prefetch operands
    (block table, lengths) are scan-carried values, and the shard_map
    region closes over no per-step Python state.  On jax 0.4.x,
    dist.compat lowers shard_map-under-scan to full-manual mode
    (see repro.dist.compat.shard_map)."""
    from jax.sharding import PartitionSpec as P
    from repro.dist import compat
    from repro.dist.sharding import current_rules
    from repro.kernels.mx_decode_attn import mx_paged_decode_attention

    kc, ks = pool["kc_pages"], pool["ks_pages"]
    vc, vs = pool["vc_pages"], pool["vs_pages"]
    hq, d = q.shape[2], q.shape[3]
    hkv = kc.shape[2]
    rep = hq // hkv
    kk, kv = cfg.mx.kv_key, cfg.mx.kv_value
    if d % 32 or ks.shape[-1] * 32 != d \
            or kk.block != 32 or kv.block != 32 \
            or kc.shape[-1] != kk.storage_nbytes(d) \
            or vc.shape[-1] != kv.storage_nbytes(d):
        return None                      # padded head dim unsupported

    def call(q_, kc_, ks_, vc_, vs_, bt_, ln_):
        return mx_paged_decode_attention(q_, kc_, ks_, vc_, vs_, bt_, ln_,
                                         key_spec=kk, value_spec=kv,
                                         rep=rep)

    rules = current_rules()
    if rules is None:
        return call(q, kc, ks, vc, vs, block_tables, lengths)
    mesh = compat.get_abstract_mesh()
    if mesh is None or mesh.empty:
        return call(q, kc, ks, vc, vs, block_tables, lengths)
    if rules.get("kv_pages"):
        return None                      # sharded pool: use gather fallback
    ba = rules.get("kv_batch") or ("data",)
    ba = tuple(a for a in ba if a in mesh.axis_names)
    if q.shape[0] % _prod(mesh.shape[a] for a in ba):
        return None
    bspec = P(ba, None, None, None)
    pspec = P()                          # pool replicated per shard
    return compat.shard_map(call, mesh=mesh,
                            in_specs=(bspec, pspec, pspec, pspec, pspec,
                                      P(ba, None), P(ba)),
                            out_specs=bspec, check_vma=False,
                            axis_names=set(ba))(q, kc, ks, vc, vs,
                                                block_tables, lengths)
