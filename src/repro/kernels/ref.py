"""Pure-jnp oracles for every Pallas kernel (bit-exact / allclose targets)."""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.convert import (decode_elements, mx_quantize, scale_to_f32)
from repro.core.pack import unpack_codes
from repro.core.spec import QuantSpec, resolve_kv_specs, resolve_spec

_PAPER_DEFAULT = QuantSpec("e4m3", "paper")
_KV_DEFAULT = QuantSpec("int8", "ocp")


def mx_quantize_2d_ref(x: jax.Array, spec=None, mode: Optional[str] = None,
                       block: Optional[int] = None, *,
                       fmt: Optional[str] = None
                       ) -> Tuple[jax.Array, jax.Array]:
    """Oracle for kernels.mx_quant.mx_quantize_2d (trailing-axis blocks)."""
    spec = resolve_spec(spec, fmt, mode, block, default=_PAPER_DEFAULT,
                        caller="mx_quantize_2d_ref")
    mx = mx_quantize(x.astype(jnp.float32), spec, axis=-1)
    n = x.shape[-1]
    nblk = (n + spec.block - 1) // spec.block
    return mx.codes[..., :n], mx.scales[..., :nblk]


def dequant_ref(codes: jax.Array, scales: jax.Array, spec=None,
                mode: Optional[str] = None, block: Optional[int] = None, *,
                fmt: Optional[str] = None) -> jax.Array:
    """Dequantize (K, N) codes quantized along axis 0 (contraction dim)."""
    spec = resolve_spec(spec, fmt, mode, block, default=_PAPER_DEFAULT,
                        caller="dequant_ref")
    k, n = codes.shape
    elem = decode_elements(codes, spec.format, spec.mode)
    sfac = scale_to_f32(scales)
    w = elem.reshape(k // spec.block, spec.block, n) * sfac[:, None, :]
    return w.reshape(k, n)


def mx_matmul_2d_ref(a: jax.Array, codes: jax.Array, scales: jax.Array,
                     spec=None, mode: Optional[str] = None,
                     block: Optional[int] = None, *,
                     fmt: Optional[str] = None) -> jax.Array:
    """Oracle for kernels.mx_matmul.mx_matmul_2d."""
    spec = resolve_spec(spec, fmt, mode, block, default=_PAPER_DEFAULT,
                        caller="mx_matmul_2d_ref")
    w = dequant_ref(codes, scales, spec)
    return jnp.dot(a.astype(jnp.float32), w,
                   preferred_element_type=jnp.float32)


def _dequant_cache_ref(codes: jax.Array, scales: jax.Array,
                       spec: QuantSpec) -> jax.Array:
    """(B, S, H, D) u8 codes + (B, S, H, D/32) scales -> f32."""
    d = codes.shape[-1]
    elem = decode_elements(codes, spec.format, spec.mode)
    sfac = scale_to_f32(scales)
    w = elem.reshape(codes.shape[:-1] + (d // 32, 32)) * sfac[..., None]
    return w.reshape(codes.shape)


def mx_decode_attention_ref(q: jax.Array, k_codes: jax.Array,
                            k_scales: jax.Array, v_codes: jax.Array,
                            v_scales: jax.Array, lengths, *, spec=None,
                            key_spec=None, value_spec=None, rep: int = 1,
                            fmt: Optional[str] = None,
                            mode: Optional[str] = None) -> jax.Array:
    """Oracle for kernels.mx_decode_attn.mx_decode_attention (and, with a
    per-slot ``lengths`` vector, for the paged kernel's semantics over an
    already-gathered contiguous layout): dequantize the whole cache, dense
    masked softmax over positions <= lengths[b].  q (B,1,Hq,D) -> same."""
    from repro.kernels.mx_decode_attn import _require_block32

    key_spec, value_spec = resolve_kv_specs(
        spec, key_spec, value_spec, fmt, mode, default=_KV_DEFAULT,
        caller="mx_decode_attention_ref")
    _require_block32(key_spec, value_spec, "mx_decode_attention_ref")
    k = _dequant_cache_ref(k_codes, k_scales, key_spec)
    v = _dequant_cache_ref(v_codes, v_scales, value_spec)
    b, s, hkv, d = k.shape
    hq = q.shape[2]
    idx = jnp.arange(hq) // rep
    ke = jnp.take(k, idx, axis=2)
    ve = jnp.take(v, idx, axis=2)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32), ke,
                        preferred_element_type=jnp.float32) / jnp.sqrt(
        jnp.float32(d))
    lengths = jnp.asarray(lengths, jnp.int32).reshape(-1)
    mask = jnp.arange(s)[None, None, None, :] <= \
        lengths[:, None, None, None]
    scores = jnp.where(mask, scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, ve,
                     preferred_element_type=jnp.float32)
    return out.astype(q.dtype)


def mx_paged_decode_attention_ref(q: jax.Array, kc_pool: jax.Array,
                                  ks_pool: jax.Array, vc_pool: jax.Array,
                                  vs_pool: jax.Array,
                                  block_tables: jax.Array, lengths,
                                  *, spec=None, key_spec=None,
                                  value_spec=None, rep: int = 1,
                                  fmt: Optional[str] = None,
                                  mode: Optional[str] = None) -> jax.Array:
    """Oracle for kernels.mx_decode_attn.mx_paged_decode_attention: gather
    the block-table pages into a contiguous layout, unpack the bit-packed
    codes per role, then run the contiguous reference."""
    key_spec, value_spec = resolve_kv_specs(
        spec, key_spec, value_spec, fmt, mode, default=_KV_DEFAULT,
        caller="mx_paged_decode_attention_ref")
    d = ks_pool.shape[-1] * 32
    b, np_max = block_tables.shape
    page, hkv = kc_pool.shape[1], kc_pool.shape[2]

    def gather(pool, last):
        g = pool[block_tables]                    # (B, np_max, page, H, X)
        return g.reshape(b, np_max * page, hkv, last)

    def codes_of(pool, role_spec):
        g = gather(pool, pool.shape[-1])
        return unpack_codes(g, role_spec.fmt, d) if role_spec.packed else g

    kc = codes_of(kc_pool, key_spec)
    vc = codes_of(vc_pool, value_spec)
    ks = gather(ks_pool, ks_pool.shape[-1])
    vs = gather(vs_pool, vs_pool.shape[-1])
    return mx_decode_attention_ref(q, kc, ks, vc, vs, lengths,
                                   key_spec=key_spec,
                                   value_spec=value_spec, rep=rep)
