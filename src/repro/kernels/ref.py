"""Pure-jnp oracles for every Pallas kernel (bit-exact / allclose targets)."""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core import formats as F
from repro.core.convert import (decode_elements, mx_quantize, scale_to_f32)
from repro.core.formats import get_format


def mx_quantize_2d_ref(x: jax.Array, fmt: str = "e4m3", mode: str = "paper",
                       block: int = F.DEFAULT_BLOCK
                       ) -> Tuple[jax.Array, jax.Array]:
    """Oracle for kernels.mx_quant.mx_quantize_2d (trailing-axis blocks)."""
    mx = mx_quantize(x.astype(jnp.float32), fmt=fmt, mode=mode, block=block,
                     axis=-1)
    n = x.shape[-1]
    nblk = (n + block - 1) // block
    return mx.codes[..., :n], mx.scales[..., :nblk]


def dequant_ref(codes: jax.Array, scales: jax.Array, fmt: str, mode: str,
                block: int = F.DEFAULT_BLOCK) -> jax.Array:
    """Dequantize (K, N) codes quantized along axis 0 (contraction dim)."""
    f = get_format(fmt)
    k, n = codes.shape
    elem = decode_elements(codes, f, mode)
    sfac = scale_to_f32(scales)
    w = elem.reshape(k // block, block, n) * sfac[:, None, :]
    return w.reshape(k, n)


def mx_matmul_2d_ref(a: jax.Array, codes: jax.Array, scales: jax.Array,
                     fmt: str = "e4m3", mode: str = "paper",
                     block: int = F.DEFAULT_BLOCK) -> jax.Array:
    """Oracle for kernels.mx_matmul.mx_matmul_2d."""
    w = dequant_ref(codes, scales, fmt, mode, block)
    return jnp.dot(a.astype(jnp.float32), w,
                   preferred_element_type=jnp.float32)
