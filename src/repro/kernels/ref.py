"""Pure-jnp oracles for every Pallas kernel (bit-exact / allclose targets)."""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core import formats as F
from repro.core.convert import (decode_elements, mx_quantize, scale_to_f32)
from repro.core.formats import get_format
from repro.core.pack import unpack_codes


def mx_quantize_2d_ref(x: jax.Array, fmt: str = "e4m3", mode: str = "paper",
                       block: int = F.DEFAULT_BLOCK
                       ) -> Tuple[jax.Array, jax.Array]:
    """Oracle for kernels.mx_quant.mx_quantize_2d (trailing-axis blocks)."""
    mx = mx_quantize(x.astype(jnp.float32), fmt=fmt, mode=mode, block=block,
                     axis=-1)
    n = x.shape[-1]
    nblk = (n + block - 1) // block
    return mx.codes[..., :n], mx.scales[..., :nblk]


def dequant_ref(codes: jax.Array, scales: jax.Array, fmt: str, mode: str,
                block: int = F.DEFAULT_BLOCK) -> jax.Array:
    """Dequantize (K, N) codes quantized along axis 0 (contraction dim)."""
    f = get_format(fmt)
    k, n = codes.shape
    elem = decode_elements(codes, f, mode)
    sfac = scale_to_f32(scales)
    w = elem.reshape(k // block, block, n) * sfac[:, None, :]
    return w.reshape(k, n)


def mx_matmul_2d_ref(a: jax.Array, codes: jax.Array, scales: jax.Array,
                     fmt: str = "e4m3", mode: str = "paper",
                     block: int = F.DEFAULT_BLOCK) -> jax.Array:
    """Oracle for kernels.mx_matmul.mx_matmul_2d."""
    w = dequant_ref(codes, scales, fmt, mode, block)
    return jnp.dot(a.astype(jnp.float32), w,
                   preferred_element_type=jnp.float32)


def _dequant_cache_ref(codes: jax.Array, scales: jax.Array, fmt: str,
                       mode: str) -> jax.Array:
    """(B, S, H, D) u8 codes + (B, S, H, D/32) scales -> f32."""
    f = get_format(fmt)
    d = codes.shape[-1]
    elem = decode_elements(codes, f, mode)
    sfac = scale_to_f32(scales)
    w = elem.reshape(codes.shape[:-1] + (d // 32, 32)) * sfac[..., None]
    return w.reshape(codes.shape)


def mx_decode_attention_ref(q: jax.Array, k_codes: jax.Array,
                            k_scales: jax.Array, v_codes: jax.Array,
                            v_scales: jax.Array, lengths, *, fmt: str,
                            mode: str, rep: int = 1) -> jax.Array:
    """Oracle for kernels.mx_decode_attn.mx_decode_attention (and, with a
    per-slot ``lengths`` vector, for the paged kernel's semantics over an
    already-gathered contiguous layout): dequantize the whole cache, dense
    masked softmax over positions <= lengths[b].  q (B,1,Hq,D) -> same."""
    k = _dequant_cache_ref(k_codes, k_scales, fmt, mode)
    v = _dequant_cache_ref(v_codes, v_scales, fmt, mode)
    b, s, hkv, d = k.shape
    hq = q.shape[2]
    idx = jnp.arange(hq) // rep
    ke = jnp.take(k, idx, axis=2)
    ve = jnp.take(v, idx, axis=2)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32), ke,
                        preferred_element_type=jnp.float32) / jnp.sqrt(
        jnp.float32(d))
    lengths = jnp.asarray(lengths, jnp.int32).reshape(-1)
    mask = jnp.arange(s)[None, None, None, :] <= \
        lengths[:, None, None, None]
    scores = jnp.where(mask, scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, ve,
                     preferred_element_type=jnp.float32)
    return out.astype(q.dtype)


def mx_paged_decode_attention_ref(q: jax.Array, kc_pool: jax.Array,
                                  ks_pool: jax.Array, vc_pool: jax.Array,
                                  vs_pool: jax.Array,
                                  block_tables: jax.Array, lengths,
                                  *, fmt: str, mode: str,
                                  rep: int = 1) -> jax.Array:
    """Oracle for kernels.mx_decode_attn.mx_paged_decode_attention: gather
    the block-table pages into a contiguous layout, unpack the bit-packed
    codes, then run the contiguous reference."""
    d = ks_pool.shape[-1] * 32
    b, np_max = block_tables.shape
    page, hkv = kc_pool.shape[1], kc_pool.shape[2]

    def gather(pool, last):
        g = pool[block_tables]                    # (B, np_max, page, H, X)
        return g.reshape(b, np_max * page, hkv, last)

    kc = unpack_codes(gather(kc_pool, kc_pool.shape[-1]), fmt, d)
    vc = unpack_codes(gather(vc_pool, vc_pool.shape[-1]), fmt, d)
    ks = gather(ks_pool, ks_pool.shape[-1])
    vs = gather(vs_pool, vs_pool.shape[-1])
    return mx_decode_attention_ref(q, kc, ks, vc, vs, lengths, fmt=fmt,
                                   mode=mode, rep=rep)
