"""Dry-run cell construction: (arch x shape x variant x mesh) -> a jittable
step function + ShapeDtypeStruct args + in/out shardings.

Variants:
  baseline  — bf16 weights/KV, no MX anywhere (the fp reference).
  paper     — the paper-faithful technique in the loop: MX weight
              fake-quant in training; MX(paper-mode) INT8 KV cache +
              MX weight storage for decode.
  optimized — beyond-paper: OCP-mode formats + every hillclimb lever
              (see EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.dist.sharding import make_rules, param_specs, use_rules
from repro.models import (Model, batch_specs, decode_specs, load_config)
from repro.models.config import (ModelConfig, QuantPolicy, QuantSpec,
                                 SHAPES, ShapeSpec)
from repro.optim import AdamWConfig, adamw_init
from repro.train.step import build_train_step

KV_CACHE_LEAVES_ATTN = {"k", "v", "k_codes", "k_scales", "v_codes",
                        "v_scales"}
KV_CACHE_LEAVES_MLA = {"ckv", "kr", "ckv_codes", "ckv_scales", "kr_codes",
                       "kr_scales"}
STATE_LEAVES_B4 = {"ssm", "tmix_state"}
STATE_LEAVES_B3 = {"conv", "tmix_prev", "cmix_prev"}


def variant_config(arch: str, variant: str) -> ModelConfig:
    cfg = load_config(arch)
    if variant == "baseline":
        return cfg
    if variant == "paper":
        mx = QuantPolicy(weights=QuantSpec("e4m3", "paper"),
                         kv_key=QuantSpec("int8", "paper"),
                         kv_value=QuantSpec("int8", "paper"),
                         grads=QuantSpec("e4m3", "paper"))
        return dataclasses.replace(cfg, mx=mx)
    if variant == "optimized":
        mx = QuantPolicy.parse(
            "weights=e4m3@32:ocp,kv=int8@32:ocp,grads=e4m3@32:ocp")
        return dataclasses.replace(cfg, mx=mx, attn_impl="flash")
    raise ValueError(f"unknown variant {variant!r}")


# =============================================================================
# sharding helpers
# =============================================================================
def _axis_size(mesh, entry) -> int:
    if entry is None:
        return 1
    if isinstance(entry, tuple):
        n = 1
        for e in entry:
            n *= mesh.shape.get(e, 1)
        return n
    return mesh.shape.get(entry, 1)


def _validated(spec: P, shape, mesh) -> P:
    """Drop spec axes that do not divide the corresponding dim."""
    entries = list(spec) + [None] * (len(shape) - len(spec))
    out = []
    for dim, e in zip(shape, entries[: len(shape)]):
        out.append(e if e is not None and dim % _axis_size(mesh, e) == 0
                   else None)
    return P(*out)


def shardings_for_params(params_sds, mesh) -> Any:
    specs = param_specs(params_sds)
    return jax.tree_util.tree_map(
        lambda sds, sp: NamedSharding(mesh, _validated(sp, sds.shape, mesh)),
        params_sds, specs)


def _batch_axes(mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def shardings_for_batch(batch_sds, mesh) -> Any:
    ba = _batch_axes(mesh)

    def one(sds):
        spec = _validated(P(ba), sds.shape, mesh)
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map(one, batch_sds)


def shardings_for_cache(cache_sds, mesh, *, seq_sharded: bool) -> Any:
    ba = _batch_axes(mesh)

    def one(path, sds):
        name = str(getattr(path[-1], "key", path[-1]))
        nd = len(sds.shape)
        ent: list = [None] * nd
        if name in KV_CACHE_LEAVES_ATTN and nd >= 4:
            ent[nd - 4] = ba
            if seq_sharded:
                ent[nd - 3] = "model"
        elif name in KV_CACHE_LEAVES_MLA and nd >= 3:
            ent[nd - 3] = ba
            if seq_sharded:
                ent[nd - 2] = "model"
        elif name in STATE_LEAVES_B4 and nd >= 4:
            ent[nd - 4] = ba
        elif name in STATE_LEAVES_B3 and nd >= 3:
            ent[nd - 3] = ba
        spec = _validated(P(*ent), sds.shape, mesh)
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(one, cache_sds)


# =============================================================================
# cell construction
# =============================================================================
@dataclasses.dataclass
class Cell:
    arch: str
    shape: ShapeSpec
    variant: str
    cfg: ModelConfig
    fn: Any                  # python callable
    args: Tuple[Any, ...]    # ShapeDtypeStruct pytrees
    in_shardings: Tuple[Any, ...]
    out_shardings: Any
    kind: str
    mesh: Any = None


def build_cell(arch: str, shape_name: str, mesh, variant: str = "baseline",
               n_layers_override: Optional[int] = None) -> Cell:
    shape = SHAPES[shape_name]
    cfg = variant_config(arch, variant)
    if n_layers_override is not None:
        # accounting compile: small depth + UNROLLED layer scan, so HLO cost
        # analysis (which visits while-loop bodies once) is exact; the
        # delta between two depths then gives exact per-layer numbers
        over = {"n_layers": n_layers_override, "scan_unroll": True}
        if cfg.family == "encdec":
            over["n_enc_layers"] = n_layers_override // 2
            over["n_dec_layers"] = n_layers_override // 2
        cfg = dataclasses.replace(cfg, **over)
    model = Model(cfg)
    # decode: weights stay resident (no per-token ZeRO-3 gather); train and
    # prefill gather weights per layer (FSDP).  The optimized variant adds
    # the beyond-paper levers (see EXPERIMENTS.md §Perf):
    #   * bf16 matmul outputs (halves TP all-reduce payloads),
    #   * pure-FSDP for narrow TP-unfriendly archs (rwkv) in training,
    #   * replicated decode activations (caches stay batch-sharded).
    rkw = dict(seq_sharded=(shape.name == "long_500k"),
               fsdp_params=(shape.kind != "decode"))
    if variant == "optimized":
        rkw["bf16_matmul_out"] = True
        if cfg.family == "rwkv" and shape.kind == "train":
            rkw["pure_fsdp"] = True
        # (refuted lever, kept off: replicating decode activations made the
        #  lm_head/logits bytes 16x worse — see EXPERIMENTS.md §Perf)
    rules = make_rules(mesh.axis_names, **rkw)

    params_sds = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    pshard = shardings_for_params(params_sds, mesh)

    if shape.kind == "train":
        opt_cfg = AdamWConfig()
        opt_sds = jax.eval_shape(adamw_init, params_sds)
        # optimizer state (master/m/v) shards follow the param specs
        oshard = {k: pshard for k in opt_sds}
        b_sds = batch_specs(cfg, shape)
        bshard = shardings_for_batch(b_sds, mesh)
        step_sds = jax.ShapeDtypeStruct((), jnp.int32)
        fn = build_train_step(
            model, opt_cfg, microbatches=1,
            fake_quant=(cfg.mx.weights is not None
                        or cfg.mx.activations is not None))

        def wrapped(params, opt_state, batch, step):
            with use_rules(rules):
                return fn(params, opt_state, batch, step)

        return Cell(arch, shape, variant, cfg, wrapped,
                    (params_sds, opt_sds, b_sds, step_sds),
                    (pshard, oshard, bshard, None),
                    (pshard, oshard, None), "train", mesh)

    if shape.kind == "prefill":
        b_sds = batch_specs(cfg, shape)
        b_sds.pop("labels", None)
        bshard = shardings_for_batch(b_sds, mesh)
        max_len = shape.seq_len // 2 if cfg.family == "encdec" \
            else shape.seq_len

        def pre_fn(params, batch):
            with use_rules(rules):
                logits, cache, pos = model.prefill(params, batch,
                                                   max_len=max_len,
                                                   fake_quant=False)
                return logits, cache

        return Cell(arch, shape, variant, cfg, pre_fn,
                    (params_sds, b_sds), (pshard, bshard), None, "prefill",
                    mesh)

    # decode
    d_sds = decode_specs(cfg, shape)
    cshard = shardings_for_cache(d_sds["cache"], mesh,
                                 seq_sharded=(shape.name == "long_500k"))
    tshard = shardings_for_batch(d_sds["token"], mesh)

    def dec_fn(params, token, cache, pos):
        with use_rules(rules):
            return model.decode_step(params, token, cache, pos)

    return Cell(arch, shape, variant, cfg, dec_fn,
                (params_sds, d_sds["token"], d_sds["cache"], d_sds["pos"]),
                (pshard, tshard, cshard, None),
                (None, cshard), "decode", mesh)


def lower_cell(cell: Cell):
    """Trace + lower under the cell's mesh (sharding constraints with bare
    PartitionSpecs need the mesh in context).

    Donation mirrors production: train donates params+optimizer state
    (in-place update), decode donates the KV cache (in-place
    dynamic_update_slice instead of a full-cache copy per token).
    """
    donate = ()
    if cell.kind == "train":
        donate = (0, 1)
    elif cell.kind == "decode":
        donate = (2,)
    jf = jax.jit(cell.fn, in_shardings=cell.in_shardings,
                 out_shardings=cell.out_shardings,
                 donate_argnums=donate)
    with jax.set_mesh(cell.mesh):
        return jf.lower(*cell.args)
