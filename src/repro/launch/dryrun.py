import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any jax import: jax locks the device
# count on first init.  512 host devices back the 2x16x16 production mesh.

import argparse    # noqa: E402
import dataclasses # noqa: E402
import json        # noqa: E402
import time        # noqa: E402
import traceback   # noqa: E402
from typing import Dict, Optional  # noqa: E402

import jax  # noqa: E402

from repro.launch.cells import build_cell, lower_cell          # noqa: E402
from repro.launch.hlo_stats import collective_bytes, reshard_ops  # noqa: E402
from repro.launch.mesh import make_production_mesh              # noqa: E402
from repro.models import SHAPES, applicable_shapes, load_config  # noqa: E402
from repro.models.registry import ARCH_IDS                      # noqa: E402

# TPU v5e hardware constants (per chip)
PEAK_FLOPS = 197e12        # bf16
HBM_BW = 819e9             # B/s
ICI_BW = 50e9              # B/s per link

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__),
                            "..", "..", "..", "experiments", "artifacts")


def delta_depths(arch: str) -> tuple:
    """Two small scan depths for exact per-layer accounting (see module
    docstring of launch/hlo_stats.py)."""
    cfg = load_config(arch)
    if cfg.family == "hybrid":
        k = cfg.attn_every
        return (k, 2 * k)
    if cfg.family == "encdec":
        return (4, 8)
    if cfg.n_dense_layers:
        return (cfg.n_dense_layers + 1, cfg.n_dense_layers + 3)
    return (2, 4)


def _kernel_corrections(cfg, shape_name: str, variant: str, kind: str,
                        n_layers: int, mesh) -> Dict[str, float]:
    """Analytic per-device FLOPs of Pallas kernels (interpret-mode grids
    lower to while loops whose bodies HLO cost analysis counts once; kernel
    I/O bytes ARE counted at the call boundary, so only FLOPs need adding).
    Deterministic — trace-time recording is unreliable under jit caching."""
    sp = SHAPES[shape_name]
    flops = 0.0
    if variant != "optimized":
        return {"flops": 0.0}
    ndata = mesh.shape.get("data", 1) * mesh.shape.get("pod", 1)
    nmodel = mesh.shape.get("model", 1)
    if kind in ("train", "prefill") and cfg.attn_impl == "flash" \
            and cfg.family in ("decoder", "encdec", "hybrid") \
            and cfg.n_heads % nmodel == 0:
        b_loc = max(1, sp.global_batch // ndata)
        h_loc = cfg.n_heads // nmodel
        s = sp.seq_len // 2 if cfg.family == "encdec" else sp.seq_len
        per_layer = 4.0 * b_loc * h_loc * s * s * cfg.hd * 0.5
        n_attn = n_layers
        if cfg.family == "hybrid":
            n_attn = n_layers // cfg.attn_every
        if cfg.family == "encdec":
            n_attn = n_layers // 2            # decoder self-attn only
        flops += per_layer * n_attn
    if kind == "decode" and cfg.mx.kv_key is not None \
            and cfg.attn_impl == "flash" \
            and not cfg.mla and cfg.family == "decoder" \
            and cfg.hd % 32 == 0:
        b_loc = max(1, sp.global_batch // ndata)
        per_layer = 14.0 * b_loc * cfg.n_heads * sp.seq_len * cfg.hd
        flops += per_layer * n_layers
    return {"flops": flops}


def _compile_stats(arch: str, shape: str, mesh, variant: str,
                   n_layers: Optional[int]) -> Dict:
    t0 = time.time()
    cell = build_cell(arch, shape, mesh, variant,
                      n_layers_override=n_layers)
    lowered = lower_cell(cell)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0
    ca = compiled.cost_analysis() or {}
    text = compiled.as_text()
    coll = collective_bytes(text)
    resh = reshard_ops(text)
    try:
        mem = compiled.memory_analysis()
        memd = {
            "argument_bytes": int(mem.argument_size_in_bytes),
            "output_bytes": int(mem.output_size_in_bytes),
            "temp_bytes": int(mem.temp_size_in_bytes),
            "alias_bytes": int(mem.alias_size_in_bytes),
        }
    except Exception as e:            # pragma: no cover
        memd = {"error": str(e)}
    kacc = _kernel_corrections(cell.cfg, shape, variant, cell.kind,
                               n_layers or cell.cfg.n_layers, mesh)
    return {
        "n_layers": n_layers,
        "flops_per_device": float(ca.get("flops", 0.0)) + kacc["flops"],
        "bytes_per_device": float(ca.get("bytes accessed", 0.0)),
        "kernel_corrections": dict(kacc),
        "collective_bytes_per_device": coll,
        "reshard_ops": resh,
        "memory": memd,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
    }


def run_cell(arch: str, shape_name: str, mesh_kind: str,
             variant: str = "baseline", out_dir: str = ARTIFACT_DIR,
             accounting: bool = True, full: bool = True,
             print_analysis: bool = False) -> Dict:
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    ndev = mesh.size
    cfg = load_config(arch)
    name = f"{arch}__{shape_name}__{mesh_kind}__{variant}"
    result: Dict = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind,
        "variant": variant, "n_devices": ndev,
        "status": "ok",
    }
    try:
        if accounting:
            la, lb = delta_depths(arch)
            sa = _compile_stats(arch, shape_name, mesh, variant, la)
            sb = _compile_stats(arch, shape_name, mesh, variant, lb)
            span = lb - la
            lfull = cfg.n_layers

            def extrapolate(a, b):
                return a + (b - a) / span * (lfull - la)

            flops_dev = extrapolate(sa["flops_per_device"],
                                    sb["flops_per_device"])
            bytes_dev = extrapolate(sa["bytes_per_device"],
                                    sb["bytes_per_device"])
            coll_dev = {
                k: extrapolate(sa["collective_bytes_per_device"][k],
                               sb["collective_bytes_per_device"][k])
                for k in sa["collective_bytes_per_device"]}
            result["accounting"] = {
                "depths": [la, lb], "small": sa, "large": sb,
                "flops_per_device": flops_dev,
                "bytes_per_device": bytes_dev,
                "collective_bytes_per_device": coll_dev,
            }
            # roofline terms (seconds) — per-device quantities
            terms = {
                "compute_s": flops_dev / PEAK_FLOPS,
                "memory_s": bytes_dev / HBM_BW,
                "collective_s": coll_dev["total"] / ICI_BW,
            }
            result["roofline"] = terms
            result["roofline"]["dominant"] = max(
                ("compute_s", "memory_s", "collective_s"),
                key=lambda k: terms[k])
            # model flops (useful-work reference)
            sp = SHAPES[shape_name]
            n_active = cfg.active_param_count()
            if sp.kind == "train":
                model_flops = 6 * n_active * sp.tokens
            else:
                per_tok = 2 * n_active
                toks = sp.tokens if sp.kind == "prefill" \
                    else sp.global_batch
                model_flops = per_tok * toks
            result["model_flops"] = float(model_flops)
            hlo_total = flops_dev * ndev
            result["model_vs_hlo_flops"] = (
                float(model_flops / hlo_total) if hlo_total else None)
        if full:
            sf = _compile_stats(arch, shape_name, mesh, variant, None)
            result["full"] = sf
            if print_analysis:
                print(f"[{name}] memory_analysis: {sf['memory']}")
                print(f"[{name}] cost_analysis: flops/dev="
                      f"{sf['flops_per_device']:.3e} bytes/dev="
                      f"{sf['bytes_per_device']:.3e}")
    except Exception as e:
        result["status"] = "error"
        result["error"] = f"{type(e).__name__}: {e}"
        result["traceback"] = traceback.format_exc()[-4000:]
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, name + ".json")
    with open(path, "w") as f:
        json.dump(result, f, indent=1, default=str)
    print(f"[dryrun] {name}: {result['status']}"
          + (f" ({result.get('error')})" if result["status"] != "ok"
             else ""))
    return result


def cell_list(mesh_kind: str):
    for arch in ARCH_IDS:
        cfg = load_config(arch)
        for sp in applicable_shapes(cfg):
            yield arch, sp.name, mesh_kind


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--variant", default="baseline",
                    choices=["baseline", "paper", "optimized"])
    ap.add_argument("--out", default=ARTIFACT_DIR)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--no-accounting", action="store_true",
                    help="skip the two delta-depth compiles")
    ap.add_argument("--no-full", action="store_true",
                    help="skip the full-depth compile (accounting only)")
    args = ap.parse_args()

    todo = list(cell_list(args.mesh)) if args.all else \
        [(args.arch, args.shape, args.mesh)]
    for arch, shape, mesh_kind in todo:
        name = f"{arch}__{shape}__{mesh_kind}__{args.variant}"
        path = os.path.join(args.out, name + ".json")
        if args.skip_existing and os.path.exists(path):
            try:
                with open(path) as f:
                    if json.load(f).get("status") == "ok":
                        print(f"[dryrun] skip {name} (exists, ok)")
                        continue
            except Exception:
                pass
        run_cell(arch, shape, mesh_kind, args.variant, args.out,
                 accounting=not args.no_accounting, full=not args.no_full,
                 print_analysis=True)


if __name__ == "__main__":
    main()
