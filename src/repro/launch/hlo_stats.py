"""Collective-byte accounting from compiled (SPMD-partitioned) HLO text.

``compiled.as_text()`` is the per-device program after GSPMD partitioning;
collective ops carry per-device shard shapes.  We sum the RESULT-shape bytes
of every collective instruction (all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute), per op kind.

XLA's cost analysis visits while-loop bodies once, so callers combine this
with the delta-compile method (launch/dryrun.py): stats from two compiles at
different scan depths give exact per-layer numbers.
"""
from __future__ import annotations

import re
from typing import Dict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

# e.g.  %all-gather.3 = bf16[4,512]{1,0} all-gather(...)
#       ROOT %t = (f32[2,4]{...}, f32[2,4]{...}) tuple(...)
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_INSTR_RE = re.compile(
    r"=\s*(\([^)]*\)|\S+)\s+(all-gather|all-reduce|reduce-scatter"
    r"|all-to-all|collective-permute)(-start|-done)?\(")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-op-kind result bytes (per device) of every collective instr.
    ``-start`` variants are counted; matching ``-done`` are skipped."""
    out: Dict[str, int] = {k: 0 for k in COLLECTIVES}
    out["count"] = 0
    for m in _INSTR_RE.finditer(hlo_text):
        shape_str, kind, startdone = m.group(1), m.group(2), m.group(3)
        if startdone == "-done":
            continue
        out[kind] += _shape_bytes(shape_str)
        out["count"] += 1
    out["total"] = sum(out[k] for k in COLLECTIVES)
    return out


def reshard_ops(hlo_text: str) -> Dict[str, int]:
    """Diagnostics: count layout-change ops that often indicate sharding
    mismatches worth hillclimbing (transpose/reshape between sharded ops)."""
    return {
        "transpose": len(re.findall(r"\btranspose\(", hlo_text)),
        "dynamic-slice": len(re.findall(r"\bdynamic-slice\(", hlo_text)),
        "copy": len(re.findall(r"= \S+ copy\(", hlo_text)),
    }
