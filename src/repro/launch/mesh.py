"""Production mesh construction (function, not module constant — importing
this module never touches jax device state)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single-pod (256 chips) or 2x16x16 multi-pod (512 chips).

    Axes:
      pod   — inter-pod data parallelism (gradient reduction only; the only
              traffic crossing the slow inter-pod links)
      data  — intra-pod DP/FSDP
      model — tensor/expert/sequence parallelism
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(ndev: int = 8):
    """Small mesh for CI-scale dry-run tests (subprocess with 8 devices).
    Degrades to a thinner "model" axis when fewer devices are available
    (ndev=1 -> 1x1) instead of building an impossible (0, 4) mesh."""
    model = next(m for m in (4, 2, 1) if ndev % m == 0)
    return jax.make_mesh((ndev // model, model), ("data", "model"))
