"""Render EXPERIMENTS.md roofline/dry-run tables from dry-run artifacts."""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__),
                            "..", "..", "..", "experiments", "artifacts")


def load_artifacts(adir: str = ARTIFACT_DIR) -> List[Dict]:
    out = []
    for p in sorted(glob.glob(os.path.join(adir, "*.json"))):
        with open(p) as f:
            out.append(json.load(f))
    return out


def _fmt_s(x: float) -> str:
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x*1e3:.1f}ms"
    return f"{x*1e6:.0f}us"


def roofline_table(arts: List[Dict], mesh: str = "single",
                   variant: str = "baseline") -> str:
    rows = ["| arch | shape | compute | memory | collective | dominant | "
            "MODEL/HLO FLOPs | note |",
            "|---|---|---|---|---|---|---|---|"]
    for a in arts:
        if a.get("mesh") != mesh or a.get("variant") != variant:
            continue
        if a.get("status") != "ok" or "roofline" not in a:
            rows.append(f"| {a['arch']} | {a['shape']} | — | — | — | — | — "
                        f"| {a.get('status')}: "
                        f"{str(a.get('error'))[:60]} |")
            continue
        t = a["roofline"]
        ratio = a.get("model_vs_hlo_flops")
        rows.append(
            f"| {a['arch']} | {a['shape']} | {_fmt_s(t['compute_s'])} | "
            f"{_fmt_s(t['memory_s'])} | {_fmt_s(t['collective_s'])} | "
            f"{t['dominant'].replace('_s','')} | "
            f"{ratio:.2f} | |")
    return "\n".join(rows)


def dryrun_table(arts: List[Dict], variant: str = "baseline") -> str:
    rows = ["| arch | shape | mesh | status | compile | bytes arg/dev | "
            "temp (host est.) | collectives |",
            "|---|---|---|---|---|---|---|---|"]
    for a in arts:
        if a.get("variant") != variant:
            continue
        full = a.get("full") or (a.get("accounting") or {}).get("large")
        if a.get("status") != "ok" or not full:
            rows.append(f"| {a['arch']} | {a['shape']} | {a['mesh']} | "
                        f"FAIL {str(a.get('error'))[:60]} | | | | |")
            continue
        mem = full.get("memory", {})
        coll = full.get("collective_bytes_per_device", {})
        ndev = a.get("n_devices", 256)
        rows.append(
            f"| {a['arch']} | {a['shape']} | {a['mesh']} | ok | "
            f"{full.get('compile_s','?')}s | "
            f"{mem.get('argument_bytes', 0)/1e9:.2f}GB | "
            f"{mem.get('temp_bytes', 0)/ndev/1e9:.2f}GB/dev | "
            f"{coll.get('count', 0):.0f} ops |")
    return "\n".join(rows)


def feed_registry(arts: List[Dict], metrics) -> None:
    """Fold dry-run artifact stats into a
    :class:`repro.obs.metrics.MetricsRegistry` — launch reports and the
    serving stack share one snapshot format, so a single
    ``registry.snapshot()`` JSON can carry both."""
    metrics.gauge("report.artifacts",
                  "dry-run artifacts loaded").set(len(arts))
    by_status = metrics.counter("report.status", "artifacts by status")
    compile_h = metrics.histogram("report.compile_s",
                                  "full-compile wall seconds")
    for a in arts:
        by_status.inc(status=str(a.get("status")))
        full = a.get("full") or (a.get("accounting") or {}).get("large")
        if full and isinstance(full.get("compile_s"), (int, float)):
            compile_h.observe(float(full["compile_s"]))


def main() -> None:
    from repro.obs.metrics import MetricsRegistry

    arts = load_artifacts()
    print("## Roofline (single-pod 16x16, baseline)\n")
    print(roofline_table(arts))
    print("\n## Dry-run status\n")
    print(dryrun_table(arts))
    reg = MetricsRegistry()
    feed_registry(arts, reg)
    snap = reg.snapshot()
    print(f"\nartifacts: {snap['gauges']['report.artifacts']} "
          f"({snap['counters']['report.status']}), compile_s "
          f"{snap['histograms']['report.compile_s']}")


if __name__ == "__main__":
    main()
