"""Serving launcher: batched greedy/sampled generation with optional MX
weights + MX KV cache (the paper's converter on the serving path).

The quantization policy is one ``--quant`` flag of ``role=spec`` pairs
(see ``repro.core.spec``); K and V pages may use different formats:

    PYTHONPATH=src python -m repro.launch.serve --arch yi_34b --reduced \
        --batch 4 --prompt-len 32 --new-tokens 16 --quant kv=int8@32:ocp

Continuous batching over the paged MX KV cache (variable-length prompts
admitted mid-flight; see README §Continuous batching & paged KV), with
mixed-format pages:

    PYTHONPATH=src python -m repro.launch.serve --arch yi_34b --reduced \
        --paged --page-size 16 --batch 8 --requests 24 --mixed \
        --quant kv_key=int8@32:ocp,kv_value=e2m1@32:ocp

``--quant auto:<budget>`` runs calibrate -> search -> serve in one
command: a few synthetic batches are pushed through the instrumented
forward (``--calib-batches``), every candidate format is scored per
layer, and the budget-constrained search (``repro.calib``) emits a
per-layer ``PolicyTable`` — the budget is total KV bytes per token
summed over all layers (codes + scales, bit-packed).  ``--save-policy``
writes the table as JSON; ``--policy-json`` serves a previously saved
table directly:

    PYTHONPATH=src python -m repro.launch.serve --arch chatglm3_6b \
        --reduced --paged --quant auto:96 --calib-batches 4 \
        --save-policy /tmp/policy.json

Fault tolerance (README §Fault tolerance): numeric-health guards are on
by default in paged mode; ``--faults`` injects a seeded deterministic
fault plan, and the async front end recovers via ``--retry`` (quarantine
retry budget), ``--watchdog`` + ``--snapshot-every`` (stalled-step
restore from an engine checkpoint):

    PYTHONPATH=src python -m repro.launch.serve --arch chatglm3_6b \
        --reduced --paged --batch 4 --requests 8 --arrival poisson:50 \
        --quant kv=int8@32:ocp --faults prefill_nan:rid=2:always \
        --retry 1 --watchdog 30 --snapshot-every 1

``--mx-kv``/``--mx-mode`` are deprecated aliases for uniform KV policies.
"""
from __future__ import annotations

import argparse
import time

from repro.obs.metrics import rate as safe_rate  # noqa: F401 (re-export)

# ``safe_rate`` is now an alias of ``repro.obs.metrics.rate`` — the one
# zero-duration-safe throughput guard the launcher, the async reporter,
# and bench_serve all share (three hand-rolled copies used to drift).


def make_tracer(args, cfg):
    """Build the launcher's Tracer when ``--trace-out``/``--chrome-trace``
    asked for one (None otherwise); the run's identifying knobs ride the
    trace header's ``meta``."""
    if not (args.trace_out or args.chrome_trace):
        return None
    from repro.obs import Tracer
    return Tracer(meta={"arch": args.arch, "quant": str(cfg.mx),
                        "arrival": args.arrival,
                        "preempt": bool(args.preempt),
                        "faults": args.faults or "",
                        "retry": args.retry,
                        "sync_every": args.sync_every})


def write_obs(args, eng, srv=None) -> None:
    """Export the run's observability artifacts: close every open trace
    track (``finalize_trace``), then write the trace/v1 JSONL, the
    Chrome trace, and the unified metrics snapshot as requested."""
    import json
    if eng.tracer is not None:
        eng.finalize_trace()
        if args.trace_out:
            eng.tracer.write_jsonl(args.trace_out)
            print(f"[serve] wrote trace/v1 JSONL -> {args.trace_out} "
                  f"({len(eng.tracer.events)} events)")
        if args.chrome_trace:
            eng.tracer.write_chrome(args.chrome_trace)
            print(f"[serve] wrote Chrome trace -> {args.chrome_trace}")
    if args.metrics_json:
        snap = srv.obs_snapshot() if srv is not None \
            else {"engine": eng.metrics.snapshot()}
        with open(args.metrics_json, "w") as f:
            json.dump(snap, f, indent=1, sort_keys=True)
        print(f"[serve] wrote metrics snapshot -> {args.metrics_json}")


def parse_arrival(spec: str):
    """Parse an ``--arrival`` spec into ``(kind, params)``:

    ``batch``                      — pre-load every request (PR 2-7 path)
    ``poisson:<rate>``             — Poisson arrivals at <rate> req/s
    ``onoff:<rate>:<on_s>:<off_s>``— bursty on/off modulated Poisson
    ``trace:<path>``               — replay a JSONL trace
    """
    parts = spec.split(":")
    kind = parts[0]
    try:
        if kind == "batch" and len(parts) == 1:
            return "batch", ()
        if kind == "poisson" and len(parts) == 2:
            return "poisson", (float(parts[1]),)
        if kind == "onoff" and len(parts) == 4:
            return "onoff", (float(parts[1]), float(parts[2]),
                             float(parts[3]))
        if kind == "trace" and len(parts) >= 2:
            return "trace", (spec.split(":", 1)[1],)
    except ValueError:
        pass
    raise ValueError(
        f"bad --arrival {spec!r}: expected batch, poisson:<rate>, "
        f"onoff:<rate>:<on_s>:<off_s>, or trace:<path>")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4,
                    help="static batch size / paged decode slots")
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--quant", default=None,
                    help="quantization policy, e.g. "
                         "'kv_key=int8@32:ocp,kv_value=e2m1@32:ocp' "
                         "(roles: weights, activations, kv_key, kv_value, "
                         "grads; 'kv=' sets both KV roles), or "
                         "'auto:<bytes>' to calibrate and search a "
                         "per-layer policy under a total KV "
                         "bytes-per-token budget")
    ap.add_argument("--calib-batches", type=int, default=4,
                    help="calibration batches for --quant auto "
                         "(each --batch x --prompt-len synthetic tokens)")
    ap.add_argument("--save-policy", default=None,
                    help="write the auto-selected PolicyTable JSON here")
    ap.add_argument("--policy-json", default=None,
                    help="serve a previously saved PolicyTable JSON "
                         "(skips calibration)")
    ap.add_argument("--mx-kv", choices=["off", "int8", "e4m3", "e5m2",
                                        "e3m2", "e2m3", "e2m1"],
                    default="off",
                    help="deprecated: use --quant kv=<fmt>@32:<mode>")
    ap.add_argument("--mx-mode", choices=["paper", "ocp"], default="ocp",
                    help="deprecated: use --quant")
    ap.add_argument("--weight-resident", action="store_true",
                    help="store decoder/MoE matmul weights in their "
                         "policy's 'weights' spec (uint8 codes, bit-packed "
                         "for sub-byte formats, + E8M0 scales) and serve "
                         "through the fused dequant-in-VMEM matmul kernel "
                         "— fp weights never materialize in HBM; needs a "
                         "weights role, e.g. --quant weights=e4m3@32:ocp")
    ap.add_argument("--shard", action="store_true",
                    help="serve under a (data, model) mesh with the decode "
                         "sharding rules (needs >1 device)")
    ap.add_argument("--paged", action="store_true",
                    help="continuous batching over the paged KV cache")
    ap.add_argument("--page-size", type=int, default=16,
                    help="tokens per KV page (paged mode)")
    ap.add_argument("--sync-every", type=int, default=8,
                    help="paged mode: decode steps fused into one "
                         "device-resident lax.scan window; the host syncs "
                         "(drain tokens / evict / admit) only at window "
                         "boundaries.  1 = the per-step loop "
                         "(token-identical either way)")
    ap.add_argument("--prefill-bucket", type=int, default=0,
                    help="paged mode: pad admission prompts to a multiple "
                         "of this (rounded up to a page multiple; default "
                         "--page-size) and prefill same-bucket admissions "
                         "as one batch")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="paged mode: share full KV pages across requests "
                         "with a common prompt prefix (refcounted, "
                         "copy-on-write); only the uncached suffix is "
                         "prefilled — outputs stay token-identical")
    ap.add_argument("--shared-prefix", type=int, default=0,
                    help="paged mode: give every synthetic prompt this "
                         "many common leading tokens (a system prompt) "
                         "so --prefix-cache has something to share")
    ap.add_argument("--requests", type=int, default=0,
                    help="paged mode: total requests to serve "
                         "(default 2x --batch)")
    ap.add_argument("--mixed", action="store_true",
                    help="paged mode: vary prompt lengths around "
                         "--prompt-len instead of equal lengths")
    ap.add_argument("--shard-pool", action="store_true",
                    help="shard the page pool's page dim over the data "
                         "axes (with --shard)")
    ap.add_argument("--arrival", default="batch",
                    help="paged mode request arrivals: 'batch' (pre-load "
                         "everything), 'poisson:<rate>' req/s, "
                         "'onoff:<rate>:<on_s>:<off_s>' bursty, or "
                         "'trace:<path>' JSONL replay — non-batch "
                         "arrivals serve through the asyncio front end")
    ap.add_argument("--slo", type=float, default=0.0,
                    help="async mode: mark ~2/3 of synthetic requests as "
                         "an interactive class (priority 0) with this "
                         "TTFT deadline in seconds; the rest become a "
                         "batch class (priority 1, no deadline).  0 = "
                         "single default class")
    ap.add_argument("--preempt", action="store_true",
                    help="async mode: preempt-and-swap — under pool "
                         "pressure a lower-priority victim's MX KV pages "
                         "swap (still packed) to host memory and restore "
                         "token-identically on re-admission")
    ap.add_argument("--admission", choices=["block", "reject"],
                    default="block",
                    help="async mode: backpressure policy — 'block' "
                         "queues submissions, 'reject' drops requests "
                         "that cannot start immediately")
    ap.add_argument("--speedup", type=float, default=0.0,
                    help="async mode: divide trace arrival times by this "
                         "(0 = submit as fast as the loop allows)")
    ap.add_argument("--faults", default=None,
                    help="paged mode: seeded fault-injection plan, e.g. "
                         "'prefill_nan:rid=1:always,kernel_fail:nth=0,"
                         "stall:nth=2:stall_s=30' (sites: page_corrupt, "
                         "swap_corrupt, prefill_nan, kernel_fail, "
                         "alloc_fail, stall — see repro.serve.faults)")
    ap.add_argument("--fault-seed", type=int, default=0,
                    help="seed for the --faults plan's randomness "
                         "(which byte/page/slot each firing corrupts)")
    ap.add_argument("--no-health-checks", action="store_true",
                    help="paged mode: disable the in-jit numeric-health "
                         "guards (finite-logits + MX scale-poison scans); "
                         "poisoned requests stream garbage instead of "
                         "being quarantined")
    ap.add_argument("--retry", type=int, default=0,
                    help="async mode: per-request retry budget for "
                         "quarantined requests (jittered exponential "
                         "backoff; RetriesExhausted after N attempts)")
    ap.add_argument("--retry-backoff", type=float, default=0.05,
                    help="async mode: base retry backoff in seconds")
    ap.add_argument("--snapshot-every", type=int, default=0,
                    help="async mode: engine snapshot cadence in sync "
                         "windows (0 = only when --watchdog needs one)")
    ap.add_argument("--watchdog", type=float, default=0.0,
                    help="async mode: stalled-step watchdog timeout in "
                         "seconds; a hung step is aborted and the engine "
                         "restored from the last snapshot (0 = off). "
                         "Must comfortably exceed first-trace compile "
                         "time or slow-but-healthy steps trip spurious "
                         "recoveries")
    ap.add_argument("--trace-out", default=None,
                    help="paged mode: write per-request trace spans "
                         "(queued / prefill / decode windows / preempt / "
                         "restore / quarantine / retry) as trace/v1 "
                         "JSONL to this path — zero extra host syncs; "
                         "token-identical on/off")
    ap.add_argument("--chrome-trace", default=None,
                    help="paged mode: additionally export the trace as "
                         "a Chrome trace_event JSON (load in Perfetto / "
                         "chrome://tracing)")
    ap.add_argument("--metrics-json", default=None,
                    help="paged mode: write the unified metrics-registry "
                         "snapshot (engine + scheduler + paging + prefix "
                         "+ swap + mx.* gauges, plus server counters and "
                         "the latency summary in async mode) as JSON")
    ap.add_argument("--obs-interval", type=int, default=0,
                    help="paged mode: sample the MX-health gauges "
                         "(shared-scale saturation/clip + underflow "
                         "rates, poison markers, per KV role) every N "
                         "sync windows (0 = never; each sample is one "
                         "scalar device reduction)")
    args = ap.parse_args()

    import contextlib
    from pathlib import Path

    import numpy as np
    import jax

    from repro.dist import compat
    from repro.dist.sharding import make_rules
    from repro.launch.mesh import make_test_mesh
    from repro.models import Model, load_config, load_reduced, \
        make_concrete_batch
    from repro.models.config import (PolicyTable, QuantPolicy, QuantSpec,
                                     apply_policy_table)
    from repro.serve import (ContinuousBatchingEngine, GenerationConfig,
                             ServeEngine)
    from repro.serve.paging import kv_cache_token_nbytes

    over = {}
    auto_budget = None
    if args.policy_json and (args.quant or args.mx_kv != "off"):
        ap.error("--policy-json and --quant/--mx-kv are mutually "
                 "exclusive: the saved table already fixes the policy "
                 "(re-run calibration with --quant auto:<budget> to "
                 "replace it)")
    if args.quant and (args.quant == "auto"
                       or args.quant.startswith("auto:")):
        from repro.calib import parse_auto_budget
        auto_budget = parse_auto_budget(args.quant)
    elif args.quant:
        over["mx"] = QuantPolicy.parse(args.quant)
    elif args.mx_kv != "off":
        print(f"[serve] --mx-kv/--mx-mode are deprecated; use "
              f"--quant kv={args.mx_kv}@32:{args.mx_mode}")
        kv = QuantSpec(args.mx_kv, args.mx_mode)
        over["mx"] = QuantPolicy(kv_key=kv, kv_value=kv)
    cfg = (load_reduced if args.reduced else load_config)(args.arch, **over)
    if args.policy_json:
        cfg = apply_policy_table(
            cfg, PolicyTable.from_json(Path(args.policy_json).read_text()))
        print(f"[serve] policy table from {args.policy_json}: {cfg.mx}"
              + (f" + {len(cfg.mx_table.overrides)} layer overrides"
                 if cfg.mx_table is not None else ""))
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    if auto_budget is not None:
        # calibrate -> search -> apply (params are policy-independent,
        # so the freshly initialized weights serve the selected table)
        from repro.calib import collect_model_stats, search_kv_policy
        rng = np.random.default_rng(1)
        batches = [rng.integers(0, cfg.vocab,
                                size=(args.batch, args.prompt_len)
                                ).astype(np.int32)
                   for _ in range(max(1, args.calib_batches))]
        t0 = time.perf_counter()
        stats = collect_model_stats(model, params, batches,
                                    roles=("kv_key", "kv_value"))
        res = search_kv_policy(stats, auto_budget, cfg)
        dt = time.perf_counter() - t0
        print(f"[serve] calibrated {len(batches)} batches + searched "
              f"in {dt:.2f}s")
        print("[serve] " + res.describe().replace("\n", "\n[serve] "))
        if args.save_policy:
            Path(args.save_policy).write_text(res.table.to_json())
            print(f"[serve] wrote policy table -> {args.save_policy}")
        cfg = apply_policy_table(cfg, res.table)
        model = Model(cfg)
        print(f"[serve] KV cache: {kv_cache_token_nbytes(cfg)} B/token "
              f"across {cfg.n_layers} layers "
              f"(budget {auto_budget:.4g} B/token)")
    if args.weight_resident:
        from repro.core.mx_weight import params_nbytes
        has_weights = cfg.mx.weights is not None or (
            cfg.mx_table is not None
            and any(cfg.layer_cfg(i).mx.weights is not None
                    for i in range(cfg.n_layers)))
        if not has_weights:
            ap.error("--weight-resident needs a 'weights' role in the "
                     "policy, e.g. --quant weights=e4m3@32:ocp")
        fp_bytes = params_nbytes(params)
        params = model.quantize_weights(params)
        mx_bytes = params_nbytes(params)
        print(f"[serve] weight-resident: params {fp_bytes / 1e6:.2f} MB fp "
              f"-> {mx_bytes / 1e6:.2f} MB MX "
              f"({fp_bytes / max(mx_bytes, 1):.2f}x smaller)")
    rules = None
    mesh_ctx = contextlib.nullcontext()
    if args.shard:
        mesh = make_test_mesh(jax.device_count())
        # decode posture: weights stay resident (no per-token ZeRO-3 gather)
        rules = make_rules(mesh.axis_names, fsdp_params=False,
                           paged_pool_sharded=args.shard_pool,
                           quant=cfg.mx)
        mesh_ctx = compat.set_mesh(mesh)
        print(f"[serve] sharded over mesh {dict(mesh.shape)}")
    gen = GenerationConfig(max_new_tokens=args.new_tokens,
                           temperature=args.temperature)

    arrival_kind, arrival_params = parse_arrival(args.arrival)
    if arrival_kind != "batch" and not args.paged:
        ap.error("--arrival needs --paged (the async front end drives "
                 "the continuous-batching engine)")
    if not args.paged and (args.faults or args.no_health_checks):
        ap.error("--faults/--no-health-checks need --paged (the guards "
                 "and injection sites live in the paged engine)")
    if arrival_kind == "batch" and (args.retry or args.watchdog > 0
                                    or args.snapshot_every):
        ap.error("--retry/--watchdog/--snapshot-every need a non-batch "
                 "--arrival (they are front-end recovery policies)")
    if not args.paged and (args.trace_out or args.chrome_trace
                           or args.metrics_json or args.obs_interval):
        ap.error("--trace-out/--chrome-trace/--metrics-json/"
                 "--obs-interval need --paged (the observability layer "
                 "instruments the continuous-batching engine)")

    faults = None
    if args.faults:
        from repro.serve import FaultPlan
        faults = FaultPlan.parse(args.faults, seed=args.fault_seed)
        print(f"[serve] fault plan (seed {args.fault_seed}): {faults}")

    if args.paged and arrival_kind != "batch":
        _serve_async(args, cfg, model, params, rules, mesh_ctx, gen,
                     arrival_kind, arrival_params, faults)
        return

    if args.paged:
        rng = np.random.default_rng(0)
        n_req = args.requests or 2 * args.batch
        if args.mixed:
            lens = rng.integers(max(1, args.prompt_len // 4),
                                2 * args.prompt_len, size=n_req)
        else:
            lens = np.full(n_req, args.prompt_len)
        max_len = int(lens.max()) + args.shared_prefix \
            + args.new_tokens + 1
        eng = ContinuousBatchingEngine(
            model, params, max_slots=args.batch,
            page_size=args.page_size, max_len=max_len, rules=rules,
            gen=gen, sync_every=args.sync_every,
            prefill_bucket=args.prefill_bucket or None,
            prefix_cache=args.prefix_cache, preempt=args.preempt,
            health_checks=not args.no_health_checks, faults=faults,
            tracer=make_tracer(args, cfg),
            obs_interval=args.obs_interval)
        shared = rng.integers(0, cfg.vocab, size=args.shared_prefix
                              ).astype(np.int32)
        prompts = []
        for n in lens:
            tail = rng.integers(0, cfg.vocab,
                                size=max(1, int(n))).astype(np.int32)
            prompts.append(np.concatenate([shared, tail])
                           if args.shared_prefix else tail)
        with mesh_ctx:
            t0 = time.perf_counter()
            for p in prompts:
                eng.add_request(p, args.new_tokens)
            out = eng.run()
            dt = time.perf_counter() - t0
        toks = sum(len(v) for v in out.values())
        ph = eng.phase
        print(f"[serve] {cfg.name} paged quant={cfg.mx} "
              f"page={args.page_size} sync_every={args.sync_every}: "
              f"{len(out)} requests "
              f"({'mixed' if args.mixed else 'uniform'} lengths), "
              f"{toks} tokens in {dt:.2f}s (incl. compile) — "
              f"{safe_rate(toks, dt):.1f} tok/s, "
              f"{eng.n_steps} decode steps in "
              f"{eng.n_syncs} fused windows, "
              f"{eng.blocks.free_pages}/{eng.blocks.num_pages} pages free")
        print(f"[serve] HBM pools: weights "
              f"{eng.weight_pool_nbytes / 1024:.1f} KiB"
              f"{' (MX-resident)' if args.weight_resident else ' (fp)'}, "
              f"kv pages {eng.kv_pool_nbytes / 1024:.1f} KiB")
        print(f"[serve] phase wall: prefill {ph['prefill']:.2f}s, "
              f"decode {ph['decode']:.2f}s, host-sync {ph['sync']:.2f}s")
        if args.prefix_cache:
            px = eng.prefix
            print(f"[serve] prefix cache: hit rate "
                  f"{eng.prefix_hit_rate:.2f} ({px.hits}/{px.lookups} "
                  f"admissions), {px.tokens_matched} tokens reused, "
                  f"{eng.prefill_tokens_computed} prefill tokens "
                  f"computed, {eng.n_cow_forks} COW forks, "
                  f"peak shared pages {eng.peak_shared_pages}, "
                  f"effective pool "
                  f"{eng.kv_pool_bytes_effective / 1024:.1f} KiB "
                  f"(allocated {eng.kv_pool_nbytes / 1024:.1f} KiB)")
        if faults is not None or eng.n_quarantined:
            from repro.kernels import backend
            fails = eng.scheduler.failed
            print(f"[serve] fault tolerance: {eng.n_quarantined} "
                  f"quarantined of {len(out) + len(fails)} submitted"
                  + (f", fired sites "
                     f"{sorted({s for s, _, _ in faults.fired})}"
                     if faults is not None and faults.fired else ""))
            for r in fails:
                print(f"[serve]   rid {r.rid} quarantined: {r.error}")
            for op, why in backend.degraded_ops().items():
                print(f"[serve]   kernel {op!r} degraded to dense: {why}")
        if out:
            first = out[min(out)]
            print("[serve] sample output tokens:", first[:12].tolist())
        write_obs(args, eng)
        return

    batch = make_concrete_batch(cfg, args.batch, args.prompt_len)
    batch.pop("labels", None)
    eng = ServeEngine(model, params,
                      max_len=args.prompt_len + args.new_tokens + 8,
                      rules=rules)
    with mesh_ctx:
        t0 = time.perf_counter()
        out = eng.generate(batch, gen)       # includes compile
        t_first = time.perf_counter() - t0
        t0 = time.perf_counter()
        out = eng.generate(batch, gen)
        t_steady = time.perf_counter() - t0
    toks = out.size
    print(f"[serve] {cfg.name} quant={cfg.mx}: generated {toks} tokens; "
          f"first {t_first:.2f}s (incl. compile), steady {t_steady:.2f}s "
          f"({safe_rate(toks, t_steady):.1f} tok/s)")
    print(f"[serve] weight HBM: {eng.weight_pool_nbytes / 1024:.1f} KiB"
          f"{' (MX-resident)' if args.weight_resident else ' (fp)'}")
    print("[serve] sample output tokens:", out[0][:12].tolist())


def _serve_async(args, cfg, model, params, rules, mesh_ctx, gen,
                 arrival_kind, arrival_params, faults=None) -> None:
    """Drive the continuous-batching engine through the asyncio front end
    under a synthetic (or replayed) arrival process and report tail
    latency + preemption accounting."""
    import asyncio

    import numpy as np

    from repro.serve import (AsyncServer, ContinuousBatchingEngine,
                             TrafficClass, latency_summary, load_trace,
                             on_off_times, poisson_times, replay,
                             synthesize)

    n_req = args.requests or 2 * args.batch
    if arrival_kind == "trace":
        arrivals = load_trace(arrival_params[0])
        max_prompt = max(a.prompt.shape[0] for a in arrivals)
        max_new = max(a.max_new_tokens for a in arrivals)
    else:
        if arrival_kind == "poisson":
            times = poisson_times(arrival_params[0], n_req, seed=0)
        else:
            rate, on_s, off_s = arrival_params
            times = on_off_times(rate, n_req, on_s=on_s, off_s=off_s,
                                 seed=0)
        lo = max(1, args.prompt_len // 4)
        hi = max(lo + 1, 2 * args.prompt_len) if args.mixed \
            else args.prompt_len + 1
        lo = lo if args.mixed else args.prompt_len
        if args.slo > 0:
            classes = [
                TrafficClass("interactive", (lo, hi),
                             (args.new_tokens, args.new_tokens + 1),
                             priority=0, deadline_s=args.slo, weight=2.0),
                TrafficClass("batch", (lo, hi),
                             (args.new_tokens, 2 * args.new_tokens + 1),
                             priority=1, weight=1.0),
            ]
        else:
            classes = [TrafficClass("default", (lo, hi),
                                    (args.new_tokens,
                                     args.new_tokens + 1))]
        arrivals = synthesize(times, classes, cfg.vocab, seed=0)
        max_prompt = max(a.prompt.shape[0] for a in arrivals)
        max_new = max(a.max_new_tokens for a in arrivals)

    eng = ContinuousBatchingEngine(
        model, params, max_slots=args.batch, page_size=args.page_size,
        max_len=max_prompt + max_new + 1, rules=rules, gen=gen,
        sync_every=args.sync_every,
        prefill_bucket=args.prefill_bucket or None,
        prefix_cache=args.prefix_cache, preempt=args.preempt,
        health_checks=not args.no_health_checks, faults=faults,
        tracer=make_tracer(args, cfg),
        obs_interval=args.obs_interval)
    speedup = args.speedup if args.speedup > 0 else float("inf")
    srv_kw = dict(admission=args.admission, retries=args.retry,
                  retry_backoff_s=args.retry_backoff)
    if args.watchdog > 0:
        srv_kw.update(use_executor=True, watchdog_s=args.watchdog,
                      snapshot_every=args.snapshot_every or 1)
    elif args.snapshot_every:
        srv_kw["snapshot_every"] = args.snapshot_every
    servers = []

    async def run():
        async with AsyncServer(eng, **srv_kw) as srv:
            servers.append(srv)
            return await replay(srv, arrivals, speedup=speedup)

    with mesh_ctx:
        t0 = time.perf_counter()
        streams, rejected = asyncio.run(run())
        dt = time.perf_counter() - t0

    fin = eng.finished_in_window
    summ = latency_summary(fin)
    toks = sum(len(r.out) for r in fin)
    print(f"[serve] {cfg.name} async quant={cfg.mx} "
          f"arrival={args.arrival} admission={args.admission} "
          f"preempt={'on' if args.preempt else 'off'}: "
          f"{len(fin)} served / {len(rejected)} rejected of "
          f"{len(arrivals)} arrivals, {toks} tokens in {dt:.2f}s "
          f"(incl. compile) — {safe_rate(toks, dt):.1f} tok/s, "
          f"{safe_rate(len(fin), dt):.2f} admitted req/s")
    if "ttft_p50_ms" in summ:
        print(f"[serve] TTFT p50 {summ['ttft_p50_ms']:.1f} ms / "
              f"p99 {summ['ttft_p99_ms']:.1f} ms"
              + (f", ITL p50 {summ['itl_p50_ms']:.2f} ms / "
                 f"p99 {summ['itl_p99_ms']:.2f} ms"
                 if "itl_p50_ms" in summ else ""))
    if "slo_attainment" in summ:
        print(f"[serve] SLO attainment (TTFT <= {args.slo:.3g}s): "
              f"{summ['slo_attainment']:.1%}")
    ph = eng.phase
    print(f"[serve] phase wall: prefill {ph['prefill']:.2f}s, decode "
          f"{ph['decode']:.2f}s, host-sync {ph['sync']:.2f}s, swap "
          f"{ph['swap']:.2f}s")
    if args.preempt:
        sw = eng.swap_store
        print(f"[serve] preempt-and-swap: {eng.n_preemptions} "
              f"preemptions, {eng.n_restores} restores, swap traffic "
              f"{sw.bytes_out / 1024:.1f} KiB out / "
              f"{sw.bytes_in / 1024:.1f} KiB in (MX-packed), peak "
              f"resident {sw.peak_resident_bytes / 1024:.1f} KiB")
    srv = servers[0] if servers else None
    if faults is not None or args.retry or args.watchdog > 0 \
            or eng.n_quarantined:
        from repro.kernels import backend
        print(f"[serve] fault tolerance: {eng.n_quarantined} quarantine "
              f"events, {srv.n_retried if srv else 0} retries, "
              f"{srv.n_failed if srv else 0} permanent failures, "
              f"{srv.n_recoveries if srv else 0} watchdog recoveries"
              + (f", fired sites "
                 f"{sorted({s for s, _, _ in faults.fired})}"
                 if faults is not None and faults.fired else ""))
        for r in eng.scheduler.failed:
            print(f"[serve]   rid {r.rid} quarantined: {r.error}")
        for op, why in backend.degraded_ops().items():
            print(f"[serve]   kernel {op!r} degraded to dense: {why}")
    write_obs(args, eng, srv)


if __name__ == "__main__":
    main()
