"""Serving launcher: batched greedy/sampled generation with optional MX
weights + MX KV cache (the paper's converter on the serving path).

    PYTHONPATH=src python -m repro.launch.serve --arch yi_34b --reduced \
        --batch 4 --prompt-len 32 --new-tokens 16 --mx-kv int8
"""
from __future__ import annotations

import argparse
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--mx-kv", choices=["off", "int8", "e4m3", "e5m2"],
                    default="off")
    ap.add_argument("--mx-mode", choices=["paper", "ocp"], default="ocp")
    ap.add_argument("--shard", action="store_true",
                    help="serve under a (data, model) mesh with the decode "
                         "sharding rules (needs >1 device)")
    args = ap.parse_args()

    import contextlib

    import jax

    from repro.dist import compat
    from repro.dist.sharding import make_rules
    from repro.launch.mesh import make_test_mesh
    from repro.models import Model, load_config, load_reduced, \
        make_concrete_batch
    from repro.models.config import MXPolicy
    from repro.serve import GenerationConfig, ServeEngine

    over = {}
    if args.mx_kv != "off":
        over["mx"] = MXPolicy(mode=args.mx_mode, kv_cache=True,
                              kv_fmt=args.mx_kv)
    cfg = (load_reduced if args.reduced else load_config)(args.arch, **over)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = make_concrete_batch(cfg, args.batch, args.prompt_len)
    batch.pop("labels", None)
    rules = None
    mesh_ctx = contextlib.nullcontext()
    if args.shard:
        mesh = make_test_mesh(jax.device_count())
        # decode posture: weights stay resident (no per-token ZeRO-3 gather)
        rules = make_rules(mesh.axis_names, fsdp_params=False)
        mesh_ctx = compat.set_mesh(mesh)
        print(f"[serve] sharded over mesh {dict(mesh.shape)}")
    eng = ServeEngine(model, params,
                      max_len=args.prompt_len + args.new_tokens + 8,
                      rules=rules)
    gen = GenerationConfig(max_new_tokens=args.new_tokens,
                           temperature=args.temperature)
    with mesh_ctx:
        t0 = time.perf_counter()
        out = eng.generate(batch, gen)       # includes compile
        t_first = time.perf_counter() - t0
        t0 = time.perf_counter()
        out = eng.generate(batch, gen)
        t_steady = time.perf_counter() - t0
    toks = out.size
    print(f"[serve] {cfg.name} mx_kv={args.mx_kv}: generated {toks} tokens; "
          f"first {t_first:.2f}s (incl. compile), steady {t_steady:.2f}s "
          f"({toks / t_steady:.1f} tok/s)")
    print("[serve] sample output tokens:", out[0][:12].tolist())


if __name__ == "__main__":
    main()
