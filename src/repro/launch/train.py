"""Training launcher.

Single-process usage (CPU container, reduced configs / ~100M models):

    PYTHONPATH=src python -m repro.launch.train --arch chatglm3_6b \
        --reduced --steps 200 --batch 8 --seq 128 --ckpt-dir /tmp/ck

Production posture (documented; the mesh/sharding path is what the dry-run
proves out): one process per host, jax.distributed.initialize(), the same
build_train_step jitted with the param/batch shardings from
repro.launch.cells, the fault-tolerant loop from repro.train.loop (atomic
checkpoints + auto-resume + straggler watchdog), and the launcher retried by
the cluster scheduler on failure.  Recommended libtpu env for overlap:
    LIBTPU_INIT_ARGS="--xla_tpu_enable_async_collective_fusion=true
      --xla_tpu_enable_latency_hiding_scheduler=true
      --xla_tpu_overlap_compute_collective_tc=true"
MX levers: --quant takes the unified per-role policy (e.g.
--quant weights=e4m3@32:ocp,grads=e4m3@32:ocp), --mx {off,paper,ocp} is
the deprecated uniform alias, and --compressed-dp switches the gradient
exchange to the MX-compressed collective (ZeRO-1 explicit-DP path; the
exchange format follows the policy's ``grads`` role).

``--quant auto:<bytes-per-param>`` calibrates instead of hand-picking:
weight statistics come straight off the initialized params, gradient
statistics from ``--calib-batches`` LM-loss backward passes, and the
budget-constrained search (``repro.calib``) assigns each layer its own
``weights`` spec under the average bytes-per-parameter budget (element
code bits + amortized E8M0 scale, over 8 — e.g. int8@32 costs 1.031,
e2m1@32 costs 0.531), plus one uniform ``grads`` spec for the compressed
collective.  The result is a per-layer ``PolicyTable`` trained with QAT
fake-quantization.
"""
from __future__ import annotations

import argparse
import os


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--quant", default=None,
                    help="quantization policy, e.g. "
                         "'weights=e4m3@32:ocp,grads=e4m3@32:ocp', or "
                         "'auto:<bytes-per-param>' to calibrate and "
                         "search a per-layer weights policy")
    ap.add_argument("--calib-batches", type=int, default=2,
                    help="gradient-statistics batches for --quant auto")
    ap.add_argument("--save-policy", default=None,
                    help="write the auto-selected PolicyTable JSON here")
    ap.add_argument("--mx", choices=["off", "paper", "ocp"], default="off",
                    help="deprecated: use --quant (applies e4m3 to "
                         "weights+grads in the given mode)")
    ap.add_argument("--compressed-dp", action="store_true",
                    help="explicit-DP shard_map step with MX-compressed "
                         "gradient all-reduce (needs >1 device)")
    ap.add_argument("--devices", type=int, default=0,
                    help="force host device count (testing)")
    args = ap.parse_args()

    if args.devices:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.devices}")

    import jax

    from repro.data import DataConfig, SyntheticLM, make_batch_for
    from repro.models import Model, load_config, load_reduced
    from repro.models.config import QuantPolicy
    from repro.optim import AdamWConfig
    from repro.train import (LoopConfig, build_train_step,
                             build_train_step_compressed_dp,
                             init_train_state, train_loop)

    over = {}
    auto_budget = None
    if args.quant and (args.quant == "auto"
                       or args.quant.startswith("auto:")):
        from repro.calib import parse_auto_budget
        auto_budget = parse_auto_budget(args.quant)
    elif args.quant:
        over["mx"] = QuantPolicy.parse(args.quant)
    elif args.mx != "off":
        print(f"[train] --mx is deprecated; use --quant "
              f"weights=e4m3@32:{args.mx},grads=e4m3@32:{args.mx}")
        over["mx"] = QuantPolicy.parse(
            f"weights=e4m3@32:{args.mx},grads=e4m3@32:{args.mx}")
    cfg = (load_reduced if args.reduced else load_config)(args.arch, **over)
    model = Model(cfg)
    params, opt_state = init_train_state(model, jax.random.PRNGKey(0))

    if auto_budget is not None:
        import numpy as np

        from repro.calib import (collect_model_stats, search_weights_policy,
                                 sweep_role, weight_param_nbytes)
        from repro.models.config import apply_policy_table

        rng = np.random.default_rng(1)
        batches = [rng.integers(0, cfg.vocab, size=(args.batch, args.seq)
                                ).astype(np.int32)
                   for _ in range(max(1, args.calib_batches))]
        stats = collect_model_stats(model, params, batches,
                                    roles=("weights", "grads"))
        res = search_weights_policy(stats, auto_budget, cfg)
        # one uniform grads spec for the compressed collective: the best
        # aggregate-gradient SQNR among candidates inside the same
        # bytes-per-param budget
        gsweep = sweep_role(stats, "grads", weight_param_nbytes)
        agg = {}
        for scored in gsweep.values():
            for s in scored:
                a = agg.setdefault(s.spec, [0.0, 0])
                a[0] += s.sqnr_db
                a[1] += 1
        in_budget = {sp: v[0] / v[1] for sp, v in agg.items()
                     if weight_param_nbytes(sp) <= auto_budget}
        table = res.table
        if in_budget:
            gspec = max(in_budget, key=in_budget.get)
            table = table.replace(
                default=table.default.replace(grads=gspec),
                overrides=tuple((i, p.replace(grads=gspec))
                                for i, p in table.overrides))
            print(f"[train] grads role -> {gspec} "
                  f"({in_budget[gspec]:.1f}dB aggregate SQNR)")
        print("[train] " + res.describe().replace("\n", "\n[train] "))
        if args.save_policy:
            from pathlib import Path
            Path(args.save_policy).write_text(table.to_json())
            print(f"[train] wrote policy table -> {args.save_policy}")
        cfg = apply_policy_table(cfg, table)
        model = Model(cfg)
    n_params = sum(int(p.size) for p in jax.tree_util.tree_leaves(params))
    print(f"[train] {cfg.name}: {n_params/1e6:.1f}M params, "
          f"quant={cfg.mx}, devices={jax.device_count()}")
    opt_cfg = AdamWConfig(lr=args.lr, warmup_steps=min(20, args.steps // 10
                                                       + 1),
                          total_steps=args.steps)
    fake_quant = cfg.mx.weights is not None \
        or cfg.mx.activations is not None
    if args.compressed_dp:
        ndev = jax.device_count()
        mesh = jax.make_mesh((ndev,), ("data",))
        step = build_train_step_compressed_dp(
            model, opt_cfg, mesh=mesh, dp_axes=("data",),
            fake_quant=fake_quant)
        step = jax.jit(step)
        ctx = jax.set_mesh(mesh)
    else:
        step = jax.jit(build_train_step(model, opt_cfg,
                                        microbatches=args.microbatches,
                                        fake_quant=fake_quant))
        import contextlib
        ctx = contextlib.nullcontext()
    data = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                                  global_batch=args.batch))

    def batch_fn(i):
        return make_batch_for(cfg, data.batch(i))

    loop_cfg = LoopConfig(total_steps=args.steps, ckpt_dir=args.ckpt_dir,
                          ckpt_every=args.ckpt_every)
    with ctx:
        out = train_loop(loop_cfg, step, params, opt_state, batch_fn)
    h = out["history"]
    print(f"[train] done: loss {h[0]['loss']:.4f} -> {h[-1]['loss']:.4f} "
          f"over {len(h)} steps")


if __name__ == "__main__":
    main()
