"""repro.models — the assigned architecture zoo."""
from repro.models.config import (  # noqa: F401
    ALL_SHAPES, ModelConfig, MXPolicy, PolicyTable, QuantPolicy, QuantSpec,
    SHAPES, ShapeSpec, applicable_shapes, apply_policy_table,
)
from repro.models.registry import (  # noqa: F401
    ARCH_IDS, Model, batch_specs, decode_specs, load_config, load_reduced,
    make_concrete_batch,
)
