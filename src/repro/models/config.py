"""Model configuration shared by every assigned architecture.

Quantization is configured with a per-tensor-role ``QuantPolicy`` (see
``repro.core.spec``): each role — weights, activations, kv_key, kv_value,
grads — carries an optional ``QuantSpec`` (element format @ block : mode),
so e.g. INT8 keys can pair with E2M1 values.  ``MXPolicy`` is the
deprecation shim over the old where-booleans + how-strings form.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

from repro.core.spec import (  # noqa: F401  (re-exported for callers)
    PolicyTable, QuantPolicy, QuantSpec, mx_policy as MXPolicy,
)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # decoder | encdec | hybrid | rwkv
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None
    rope_frac: float = 1.0         # chatglm3/glm4 rotate half the head dim
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5
    gated_mlp: bool = True         # SwiGLU (llama-style) vs plain GELU
    tie_embeddings: bool = False
    # --- MoE ---
    n_experts: int = 0
    n_shared_experts: int = 0
    moe_topk: int = 0
    moe_d_ff: int = 0              # per-expert hidden dim
    n_dense_layers: int = 0        # leading dense layers before MoE stack
    capacity_factor: float = 1.25
    # --- MLA (deepseek-v2) ---
    mla: bool = False
    kv_lora: int = 0
    q_lora: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0
    # --- SSM / hybrid ---
    ssm_state: int = 0
    ssm_expand: int = 2
    d_conv: int = 4
    attn_every: int = 0            # zamba2: shared attn block period
    # --- enc-dec ---
    n_enc_layers: int = 0
    n_dec_layers: int = 0
    # --- modality frontend stubs ---
    prefix_len: int = 0            # internvl2: ViT patch tokens (stub embeds)
    frontend: str = "none"         # none | patch | frames
    # --- numerics / the paper's technique ---
    mx: QuantPolicy = dataclasses.field(default_factory=QuantPolicy)
    # per-layer policy table (role + layer -> spec).  Never set directly:
    # go through ``apply_policy_table`` so an all-layers-identical table
    # collapses to the uniform ``mx`` (keeping the scanned, bit-identical
    # layer stack).  When set, ``mx`` mirrors the table's default and the
    # decoder unrolls its layer loop with per-layer configs.
    mx_table: Optional[PolicyTable] = None
    dtype: str = "bfloat16"        # compute dtype
    param_dtype: str = "bfloat16"  # stored parameter dtype (master is f32)
    remat: bool = True             # activation checkpointing per layer
    scan_unroll: bool = False      # unroll the layer scan (dry-run
    #                                accounting: XLA cost analysis counts
    #                                while-loop bodies once)
    attn_impl: str = "dense"       # dense | flash (Pallas online-softmax;
    #                                falls back to dense when heads don't
    #                                divide the model axis)

    @property
    def hd(self) -> int:
        if self.head_dim is not None:
            return self.head_dim
        return self.d_model // self.n_heads

    # ---------------------------------------------- per-layer quantization
    @property
    def per_layer_mx(self) -> bool:
        """True when a (non-uniform) per-layer policy table is installed."""
        return self.mx_table is not None

    def layer_policy(self, i: int) -> QuantPolicy:
        """The quantization policy of absolute layer ``i`` (leading dense
        layers first, then the scanned stack)."""
        if self.mx_table is None:
            return self.mx
        return self.mx_table.layer(i)

    def layer_cfg(self, i: int) -> "ModelConfig":
        """A uniform-policy view of this config for layer ``i`` — what the
        decoder's unrolled per-layer loop passes to the layer kernels."""
        if self.mx_table is None:
            return self
        return dataclasses.replace(self, mx=self.mx_table.layer(i),
                                   mx_table=None)

    @property
    def sub_quadratic(self) -> bool:
        """True if the arch supports 500k-token decode (SSM/hybrid/linear)."""
        return self.family in ("hybrid", "rwkv")

    @property
    def has_decoder(self) -> bool:
        return True  # every assigned arch has a decode path (enc-dec incl.)

    def param_count(self) -> int:
        """Analytic parameter count (for 6ND roofline math)."""
        d, v = self.d_model, self.vocab
        emb = v * d * (1 if self.tie_embeddings else 2)
        if self.family == "rwkv":
            per = 4 * d * d + 2 * d * self.d_ff + 10 * d  # tmix + cmix approx
            return emb + self.n_layers * per
        if self.family == "hybrid":
            din = self.ssm_expand * d
            per = d * (2 * din + 2 * self.ssm_state + din // 64) + din * d \
                + din * self.d_conv
            attn = 4 * d * d + 3 * d * self.d_ff
            n_attn = (self.n_layers // self.attn_every) if self.attn_every \
                else 0
            return emb + self.n_layers * per + attn  # shared attn counted 1x
        hd, nh, nkv = self.hd, self.n_heads, self.n_kv_heads
        if self.mla:
            attn = d * (self.q_lora or d) \
                + (self.q_lora or d) * nh * (self.qk_nope_dim
                                             + self.qk_rope_dim) \
                + d * (self.kv_lora + self.qk_rope_dim) \
                + self.kv_lora * nh * (self.qk_nope_dim + self.v_head_dim) \
                + nh * self.v_head_dim * d
        else:
            attn = d * nh * hd + 2 * d * nkv * hd + nh * hd * d
        mlp_mult = 3 if self.gated_mlp else 2
        dense_mlp = mlp_mult * d * self.d_ff
        if self.n_experts:
            expert = mlp_mult * d * self.moe_d_ff
            moe_mlp = self.n_experts * expert \
                + self.n_shared_experts * expert + d * self.n_experts
            n_moe = self.n_layers - self.n_dense_layers
            mlp_total = self.n_dense_layers * dense_mlp + n_moe * moe_mlp
        else:
            mlp_total = self.n_layers * dense_mlp
        n_l = self.n_layers if not self.family == "encdec" \
            else (self.n_enc_layers + self.n_dec_layers)
        layers = n_l * attn + mlp_total
        if self.family == "encdec":
            layers += self.n_dec_layers * attn  # cross-attention
        return emb + layers

    def active_param_count(self) -> int:
        """Active params per token (MoE: routed top-k + shared only)."""
        if not self.n_experts:
            return self.param_count()
        full = self.param_count()
        mlp_mult = 3 if self.gated_mlp else 2
        expert = mlp_mult * self.d_model * self.moe_d_ff
        n_moe = self.n_layers - self.n_dense_layers
        inactive = n_moe * (self.n_experts - self.moe_topk) * expert
        return full - inactive


def apply_policy_table(cfg: ModelConfig,
                       table: PolicyTable) -> ModelConfig:
    """Install a per-layer ``PolicyTable`` on a config.

    An all-layers-identical table collapses to its default ``QuantPolicy``
    (``mx_table`` stays ``None``), so the model keeps the scanned layer
    stack and is bit-identical to the uniform policy it names.  Non-uniform
    tables are decoder-family only (the other families have no per-layer
    cache plumbing) and must not name layers past ``n_layers``.
    """
    if isinstance(table, QuantPolicy):
        return dataclasses.replace(cfg, mx=table, mx_table=None)
    if not isinstance(table, PolicyTable):
        raise TypeError(f"expected a PolicyTable or QuantPolicy, got "
                        f"{type(table).__name__}")
    collapsed = table.collapse()
    if isinstance(collapsed, QuantPolicy):
        return dataclasses.replace(cfg, mx=collapsed, mx_table=None)
    if cfg.family != "decoder":
        raise NotImplementedError(
            f"{cfg.name}: per-layer policy tables cover the decoder "
            f"family; {cfg.family!r} models take a uniform QuantPolicy")
    bad = [i for i, _ in table.overrides if i >= cfg.n_layers]
    if bad:
        raise ValueError(
            f"{cfg.name}: policy table names layer(s) {bad} but the "
            f"model has {cfg.n_layers} layers (indices 0.."
            f"{cfg.n_layers - 1})")
    return dataclasses.replace(cfg, mx=table.default, mx_table=table)


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    """One (input-shape) cell: what gets lowered in the dry-run."""
    name: str
    seq_len: int
    global_batch: int
    kind: str                      # train | prefill | decode

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


TRAIN_4K = ShapeSpec("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeSpec("prefill_32k", 32768, 32, "prefill")
DECODE_32K = ShapeSpec("decode_32k", 32768, 128, "decode")
LONG_500K = ShapeSpec("long_500k", 524288, 1, "decode")

ALL_SHAPES: Tuple[ShapeSpec, ...] = (TRAIN_4K, PREFILL_32K, DECODE_32K,
                                     LONG_500K)
SHAPES = {s.name: s for s in ALL_SHAPES}


def applicable_shapes(cfg: ModelConfig) -> Tuple[ShapeSpec, ...]:
    """long_500k only for sub-quadratic archs (brief rule)."""
    out = [TRAIN_4K, PREFILL_32K, DECODE_32K]
    if cfg.sub_quadratic:
        out.append(LONG_500K)
    return tuple(out)
