"""Decoder-only LM driver: dense GQA, MLA, MoE, prefix-embed (VLM) variants.

Layers are stacked and driven by ``lax.scan`` (compile-time discipline: one
layer's HLO regardless of depth).  Caches are layer-stacked pytrees carried
through the same scan.

Per-layer quantization (``cfg.mx_table``, a ``PolicyTable``): the scan
body is traced once, so layer-varying *static* specs — and the per-layer
KV cache shapes they imply — cannot ride through it.  When a non-uniform
table is installed (``apply_policy_table`` collapses uniform ones), every
layer walk in this module unrolls into a Python loop over per-layer
configs (``cfg.layer_cfg(i)``) and caches become per-layer lists, each
sized by its own layer's ``kv_key``/``kv_value`` specs.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.dist.sharding import logical
from repro.models import layers as L
from repro.models.config import ModelConfig

VOCAB_PAD = 512


def padded_vocab(cfg: ModelConfig) -> int:
    return -(-cfg.vocab // VOCAB_PAD) * VOCAB_PAD


def _layer_init(key, cfg: ModelConfig, moe_layer: bool) -> Dict[str, Any]:
    k1, k2 = jax.random.split(key)
    d = cfg.d_model
    dt = L.dtype_of(cfg)
    p = {"ln1": jnp.ones((d,), dt), "ln2": jnp.ones((d,), dt)}
    if cfg.mla:
        p["attn"] = L.mla_init(k1, cfg)
    else:
        p["attn"] = L.attn_init(k1, cfg)
    if moe_layer:
        p["moe"] = L.moe_init(k2, cfg)
    else:
        p["mlp"] = L.mlp_init(k2, cfg)
    return p


def init(key, cfg: ModelConfig) -> Dict[str, Any]:
    vp = padded_vocab(cfg)
    d = cfg.d_model
    dt = L.dtype_of(cfg)
    keys = jax.random.split(key, 4)
    n_scan = cfg.n_layers - cfg.n_dense_layers
    lkeys = jax.random.split(keys[0], n_scan)
    moe_layer = cfg.n_experts > 0
    layers = jax.vmap(lambda k: _layer_init(k, cfg, moe_layer))(lkeys)
    params = {
        "embed": L.embed_init(keys[1], vp, d, dt),
        "layers": layers,
        "norm_f": jnp.ones((d,), dt),
    }
    if cfg.n_dense_layers:
        dkeys = jax.random.split(keys[2], cfg.n_dense_layers)
        params["dense_layers"] = [
            _layer_init(k, cfg, moe_layer=False) for k in dkeys]
    if not cfg.tie_embeddings:
        params["lm_head"] = L.dense_init(keys[3], d, vp, dt)
    return params


def _block(lp, x, cfg: ModelConfig, *, positions, cache=None, cache_pos=None,
           moe_layer: bool, fake_quant: bool,
           paged=None, paged_prefill=None,
           tap=None) -> Tuple[jax.Array, Any, jax.Array]:
    h = L.rms_norm(x, lp["ln1"], cfg.norm_eps)
    s = x.shape[1]
    if paged_prefill is not None:
        block_tables, starts, prompt_lens = paged_prefill
        a, new_cache = L.attention_paged_prefill(
            lp["attn"], h, cfg, pool=cache, block_tables=block_tables,
            starts=starts, prompt_lens=prompt_lens, fake_quant=fake_quant)
    elif paged is not None:
        block_tables, lengths = paged
        a, new_cache = L.attention_paged_decode(
            lp["attn"], h, cfg, pool=cache, block_tables=block_tables,
            lengths=lengths, fake_quant=fake_quant)
    elif cfg.mla:
        if cache is not None and s == 1:
            a, new_cache = L.mla_decode(lp["attn"], h, cfg, cache=cache,
                                        cache_pos=cache_pos,
                                        fake_quant=fake_quant)
        else:
            a, new_cache = L.mla_attention(lp["attn"], h, cfg,
                                           positions=positions, cache=cache,
                                           cache_pos=cache_pos,
                                           fake_quant=fake_quant)
    else:
        a, new_cache = L.attention(lp["attn"], h, cfg, positions=positions,
                                   cache=cache, cache_pos=cache_pos,
                                   fake_quant=fake_quant, tap=tap)
    x = x + a
    h2 = L.rms_norm(x, lp["ln2"], cfg.norm_eps)
    if tap is not None:
        # the activations role quantizes matmul inputs; the two post-norm
        # hidden states are the layer's representative matmul inputs
        tap["activations"] = jnp.concatenate([h, h2], axis=1)
    aux = jnp.zeros((), jnp.float32)
    if moe_layer:
        m, aux = L.moe(lp["moe"], h2, cfg, fake_quant)
    else:
        m = L.mlp(lp["mlp"], h2, cfg, fake_quant)
    return x + m, new_cache, aux


def _embed(params, cfg, tokens, prefix_embeds):
    x = jnp.take(params["embed"], tokens, axis=0).astype(L.dtype_of(cfg))
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(x.dtype), x], axis=1)
    return logical(x, "batch", None, None)


def _head(params, cfg, x):
    x = L.rms_norm(x, params["norm_f"], cfg.norm_eps)
    head = params.get("lm_head")
    if head is None:
        head = params["embed"].T
    logits = jnp.einsum("bsd,dv->bsv", x, head.astype(x.dtype),
                        preferred_element_type=jnp.float32)
    return logical(logits, "batch", None, "model")


def _scan_layer_params(params, i: int):
    """Layer ``i``'s slice of the scanned-layer params (stacked pytree, or
    a per-layer list when heterogeneous weight specs force one — see
    ``quantize_weights``)."""
    layers = params["layers"]
    if isinstance(layers, (list, tuple)):
        return layers[i]
    return jax.tree_util.tree_map(lambda p: p[i], layers)


def _scan_cfgs(cfg: ModelConfig):
    """Per-layer configs of the scanned stack (absolute layer indices
    continue after the leading dense layers)."""
    n_scan = cfg.n_layers - cfg.n_dense_layers
    return [cfg.layer_cfg(cfg.n_dense_layers + i) for i in range(n_scan)]


# matmul weight leaves quantized by ``quantize_weights`` — all stored
# (..., K, N) with the contraction axis at -2.  Router logits, norms,
# embeddings, and the (tied or separate) LM head stay fp.
_WEIGHT_KEYS = frozenset({"wq", "wk", "wv", "wo", "w1", "w2", "w3"})


def _quantize_layer_tree(lp, spec):
    """MXWeight-quantize every matmul weight leaf of one layer's params
    (leading scan/expert axes ride along); None spec keeps the layer fp."""
    if spec is None:
        return lp

    def walk(d):
        out = {}
        for key, val in d.items():
            if isinstance(val, dict):
                out[key] = walk(val)
            elif key in _WEIGHT_KEYS and getattr(val, "ndim", 0) >= 2:
                out[key] = L.MXWeight.quantize(val, spec)
            else:
                out[key] = val
        return out

    return walk(lp)


def quantize_weights(params, cfg: ModelConfig):
    """Convert matmul weights to weight-resident MXWeight storage.

    Uniform policy (``cfg.mx.weights``): the stacked scanned-layer pytree
    is quantized in place — MXWeight is a registered pytree, so
    ``lax.scan`` still slices one layer per step.  Non-uniform tables
    (``cfg.mx_table``): ``params["layers"]`` becomes a per-layer list,
    each layer quantized per its own ``layer_cfg(i).mx.weights`` (layers
    whose table entry has no weights role stay fp) — the unrolled walks
    already consume lists via ``_scan_layer_params``.
    """
    if cfg.mla:
        raise NotImplementedError(
            "weight-resident storage covers the GQA decoder family "
            "(MLA projections are not routed through MXWeight yet)")
    out = dict(params)
    if cfg.mx_table is not None:
        out["layers"] = [
            _quantize_layer_tree(_scan_layer_params(params, i),
                                 cfg_i.mx.weights)
            for i, cfg_i in enumerate(_scan_cfgs(cfg))]
    else:
        out["layers"] = _quantize_layer_tree(params["layers"],
                                             cfg.mx.weights)
    if "dense_layers" in params:
        out["dense_layers"] = [
            _quantize_layer_tree(dl, cfg.layer_cfg(i).mx.weights)
            for i, dl in enumerate(params["dense_layers"])]
    return out


def forward(params, tokens, cfg: ModelConfig, *, prefix_embeds=None,
            fake_quant: bool = False) -> Tuple[jax.Array, jax.Array]:
    """Training forward: (B,S)->(B,S,Vp) logits + MoE aux loss."""
    x = _embed(params, cfg, tokens, prefix_embeds)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))
    moe_layer = cfg.n_experts > 0
    for i, dl in enumerate(params.get("dense_layers", [])):
        x, _, _ = _block(dl, x, cfg.layer_cfg(i), positions=positions,
                         moe_layer=False, fake_quant=fake_quant)
    if cfg.mx_table is not None:
        # non-uniform per-layer policy: specs are jit-static, so the
        # layer walk unrolls — each layer checkpointed like the scanned
        # stack (auto-policy QAT training runs through this path)
        auxs = []
        for i, cfg_i in enumerate(_scan_cfgs(cfg)):
            def one(lp, x, cfg_i=cfg_i):
                y, _, aux = _block(lp, x, cfg_i, positions=positions,
                                   moe_layer=moe_layer,
                                   fake_quant=fake_quant)
                return y, aux

            fn = jax.checkpoint(one) if cfg.remat else one
            x, aux = fn(_scan_layer_params(params, i), x)
            auxs.append(aux)
        return _head(params, cfg, x), jnp.mean(jnp.stack(auxs))

    def step(carry, lp):
        y, new_cache, aux = _block(lp, carry, cfg, positions=positions,
                                   moe_layer=moe_layer,
                                   fake_quant=fake_quant)
        return y, aux

    step_fn = jax.checkpoint(step) if cfg.remat else step
    x, auxs = L.layer_scan(step_fn, x, params["layers"], cfg)
    return _head(params, cfg, x), jnp.mean(auxs)


def init_cache(cfg: ModelConfig, batch: int, max_len: int):
    n_scan = cfg.n_layers - cfg.n_dense_layers
    if cfg.mla:
        mk = lambda c, ld: L.init_mla_cache(c, batch, max_len, layers_dim=ld)
    else:
        mk = lambda c, ld: L.init_kv_cache(c, batch, max_len, c.n_kv_heads,
                                           c.hd, layers_dim=ld)
    if cfg.mx_table is not None:
        # per-layer specs => per-layer cache shapes: a list, not a stack
        cache = {"layers": [mk(c, ()) for c in _scan_cfgs(cfg)]}
    else:
        cache = {"layers": mk(cfg, (n_scan,))}
    if cfg.n_dense_layers:
        cache["dense_layers"] = [mk(cfg.layer_cfg(i), ())
                                 for i in range(cfg.n_dense_layers)]
    return cache


def init_paged_cache(cfg: ModelConfig, num_pages: int, page_size: int):
    """Paged page-pool analogue of ``init_cache``: pages are shared across
    requests via per-slot block tables (see repro.serve).  Under a
    per-layer policy table each layer's pool is sized by its own
    ``kv_key``/``kv_value`` specs (e.g. INT8 pages on sensitive layers,
    half-size packed E2M1 pages elsewhere)."""
    if cfg.mla:
        raise NotImplementedError(
            "paged KV serving covers the GQA decoder family; the MLA "
            "compressed cache keeps the contiguous layout")
    n_scan = cfg.n_layers - cfg.n_dense_layers
    mk = lambda c, ld: L.init_paged_kv_cache(c, num_pages, page_size,
                                             c.n_kv_heads, c.hd,
                                             layers_dim=ld)
    if cfg.mx_table is not None:
        cache = {"layers": [mk(c, ()) for c in _scan_cfgs(cfg)]}
    else:
        cache = {"layers": mk(cfg, (n_scan,))}
    if cfg.n_dense_layers:
        cache["dense_layers"] = [mk(cfg.layer_cfg(i), ())
                                 for i in range(cfg.n_dense_layers)]
    return cache


def _run_layers(params, cache, x, cfg, positions, cache_pos, fake_quant):
    moe_layer = cfg.n_experts > 0
    new_dense = []
    for i, dl in enumerate(params.get("dense_layers", [])):
        x, nc, _ = _block(dl, x, cfg.layer_cfg(i), positions=positions,
                          cache=cache["dense_layers"][i],
                          cache_pos=cache_pos, moe_layer=False,
                          fake_quant=fake_quant)
        new_dense.append(nc)
    if cfg.mx_table is not None:
        new_layer_cache = []
        for i, cfg_i in enumerate(_scan_cfgs(cfg)):
            x, nc, _ = _block(_scan_layer_params(params, i), x, cfg_i,
                              positions=positions, cache=cache["layers"][i],
                              cache_pos=cache_pos, moe_layer=moe_layer,
                              fake_quant=fake_quant)
            new_layer_cache.append(nc)
    else:
        def step(carry, xs):
            lp, cache_l = xs
            y, nc, _ = _block(lp, carry, cfg, positions=positions,
                              cache=cache_l, cache_pos=cache_pos,
                              moe_layer=moe_layer, fake_quant=fake_quant)
            return y, nc

        x, new_layer_cache = L.layer_scan(
            step, x, (params["layers"], cache["layers"]), cfg)
    new_cache = {"layers": new_layer_cache}
    if new_dense:
        new_cache["dense_layers"] = new_dense
    return x, new_cache


def prefill(params, tokens, cfg: ModelConfig, *, max_len: int,
            prefix_embeds=None, fake_quant: bool = False):
    """Process the prompt, fill the cache at [0, S); returns (logits, cache,
    next position)."""
    x = _embed(params, cfg, tokens, prefix_embeds)
    b, s, _ = x.shape
    cache = init_cache(cfg, b, max_len)
    positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))
    x, cache = _run_layers(params, cache, x, cfg, positions, 0, fake_quant)
    return _head(params, cfg, x), cache, s


def decode_step(params, token, cache, pos, cfg: ModelConfig, *,
                fake_quant: bool = False):
    """One decode step: token (B,) int32, pos scalar int32 (cache length so
    far).  Returns (logits (B,1,Vp), new cache)."""
    x = _embed(params, cfg, token[:, None], None)
    b = x.shape[0]
    positions = jnp.full((b, 1), pos)
    x, cache = _run_layers(params, cache, x, cfg, positions, pos, fake_quant)
    return _head(params, cfg, x), cache


def forward_calib(params, tokens, cfg: ModelConfig, *, prefix_embeds=None):
    """Instrumented forward for activation-statistics calibration.

    Runs the layer walk *unrolled* (the same per-layer path a non-uniform
    policy table uses) and taps, per absolute layer, the tensors each
    quantizable role would see: ``activations`` (the two post-norm matmul
    inputs, concatenated along the sequence axis), and the post-RoPE,
    pre-quantization ``kv_key``/``kv_value`` projections.  Nothing is
    quantized on this path, so the taps are clean regardless of ``cfg.mx``.

    Returns ``(logits, aux, taps)`` with ``taps[role][layer]`` a float
    tensor.  MLA configs are not supported (calibration targets the GQA
    paged-serving family, the same constraint as ``init_paged_cache``).
    """
    if cfg.mla:
        raise NotImplementedError(
            "forward_calib covers the GQA decoder family; the MLA "
            "compressed cache has no per-head K/V tensors to calibrate")
    x = _embed(params, cfg, tokens, prefix_embeds)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))
    moe_layer = cfg.n_experts > 0
    taps = {"activations": [], "kv_key": [], "kv_value": []}

    def run(lp, x, cfg_i, moe):
        tap = {}
        x, _, aux = _block(lp, x, cfg_i, positions=positions, moe_layer=moe,
                           fake_quant=False, tap=tap)
        taps["activations"].append(tap["activations"])
        taps["kv_key"].append(tap["k"])
        taps["kv_value"].append(tap["v"])
        return x, aux

    auxs = []
    for i, dl in enumerate(params.get("dense_layers", [])):
        x, aux = run(dl, x, cfg.layer_cfg(i), False)
        auxs.append(aux)
    for i, cfg_i in enumerate(_scan_cfgs(cfg)):
        x, aux = run(_scan_layer_params(params, i), x, cfg_i, moe_layer)
        auxs.append(aux)
    return _head(params, cfg, x), jnp.mean(jnp.stack(auxs)), taps


def sample_tokens(logits, keys, temperature: float):
    """Sample one token per slot inside the jitted decode path.

    logits (B, vocab) f32; keys (B, 2) uint32 per-slot PRNG keys.  The keys
    are split every call regardless of temperature, so greedy and sampled
    runs share one key-evolution schedule and the fused multi-step loop is
    token-identical to the per-step loop at any temperature.  Returns
    (new keys, tokens (B,) int32)."""
    split = jax.vmap(lambda k: jax.random.split(k, 2))(keys)
    keys, sub = split[:, 0], split[:, 1]
    if temperature <= 0.0:
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    else:
        nxt = jax.vmap(
            lambda k, row: jax.random.categorical(
                k, row.astype(jnp.float32) / temperature)
        )(sub, logits).astype(jnp.int32)
    return keys, nxt


def scatter_prefill(cfg: ModelConfig, pool, cache, page_ids):
    """Scatter a batched contiguous prefill cache (G requests padded to the
    same page-multiple length) into the paged pools; one donated scatter
    per leaf, sub-byte codes packed on the way (see
    layers.paged_cache_scatter)."""
    if cfg.mx_table is not None:
        new = {"layers": [
            L.paged_cache_scatter(pg, cg, page_ids, cfg_i)
            for pg, cg, cfg_i in zip(pool["layers"], cache["layers"],
                                     _scan_cfgs(cfg))]}
    else:
        new = {"layers": L.paged_cache_scatter(
            pool["layers"], cache["layers"], page_ids, cfg)}
    if "dense_layers" in pool:
        new["dense_layers"] = [
            L.paged_cache_scatter(pg, cg, page_ids, cfg.layer_cfg(i))
            for i, (pg, cg) in enumerate(zip(pool["dense_layers"],
                                             cache["dense_layers"]))]
    return new


def copy_pool_pages(pool, src, dst):
    """Copy page contents src[i] -> dst[i] in every pool leaf (COW fork
    under prefix sharing).  src/dst (M,) i32 physical page ids; leaves are
    (P, page, n_kv, X) or layer-stacked (n_scan, P, page, n_kv, X) — the
    bytes copy verbatim whatever the layer's spec, so one call covers
    uniform policies, per-layer tables, and fp pools alike."""
    def leaf(x):
        return x.at[:, dst].set(x[:, src]) if x.ndim == 5 \
            else x.at[dst].set(x[src])
    return jax.tree_util.tree_map(leaf, pool)


def paged_prefill_suffix(params, tokens, starts, prompt_lens, cache,
                         block_tables, cfg: ModelConfig, *,
                         fake_quant: bool = False):
    """Prefill only the *uncached suffix* of G prompts over the paged KV
    cache (prefix sharing): request g's tokens cover prompt positions
    [starts[g], prompt_lens[g]), padded on the right; earlier positions
    are already resident in the slot's (shared, read-only) prefix pages.

    tokens (G, S) int32; starts/prompt_lens (G,) int32; block_tables
    (G, max_pages) int32 — the slots' full rows, with any copy-on-write
    fork already applied.  Returns (logits (G, S, Vp), new page pools);
    logits row i of request g corresponds to prompt position
    ``starts[g] + i`` (the engine samples at ``prompt_lens - starts - 1``).
    """
    x = _embed(params, cfg, tokens, None)
    paged_prefill = (block_tables, starts, prompt_lens)
    moe_layer = cfg.n_experts > 0
    new_dense = []
    for i, dl in enumerate(params.get("dense_layers", [])):
        x, nc, _ = _block(dl, x, cfg.layer_cfg(i), positions=None,
                          cache=cache["dense_layers"][i], moe_layer=False,
                          fake_quant=fake_quant,
                          paged_prefill=paged_prefill)
        new_dense.append(nc)
    if cfg.mx_table is not None:
        new_layer_cache = []
        for i, cfg_i in enumerate(_scan_cfgs(cfg)):
            x, nc, _ = _block(_scan_layer_params(params, i), x, cfg_i,
                              positions=None, cache=cache["layers"][i],
                              moe_layer=moe_layer, fake_quant=fake_quant,
                              paged_prefill=paged_prefill)
            new_layer_cache.append(nc)
    else:
        def step(carry, xs):
            lp, cache_l = xs
            y, nc, _ = _block(lp, carry, cfg, positions=None, cache=cache_l,
                              moe_layer=moe_layer, fake_quant=fake_quant,
                              paged_prefill=paged_prefill)
            return y, nc

        x, new_layer_cache = L.layer_scan(
            step, x, (params["layers"], cache["layers"]), cfg)
    new_cache = {"layers": new_layer_cache}
    if new_dense:
        new_cache["dense_layers"] = new_dense
    return _head(params, cfg, x), new_cache


def paged_decode_step(params, token, cache, block_tables, lengths,
                      cfg: ModelConfig, *, fake_quant: bool = False):
    """One continuous-batching decode step over the paged KV cache.

    token (B,) int32 — one in-flight token per slot; block_tables
    (B, max_pages) int32; lengths (B,) int32 — slot b's token sits at
    position lengths[b] (0 and a zeroed block-table row for idle slots).
    Returns (logits (B,1,Vp), new page pools)."""
    x = _embed(params, cfg, token[:, None], None)
    paged = (block_tables, lengths)
    moe_layer = cfg.n_experts > 0
    new_dense = []
    for i, dl in enumerate(params.get("dense_layers", [])):
        x, nc, _ = _block(dl, x, cfg.layer_cfg(i), positions=None,
                          cache=cache["dense_layers"][i], moe_layer=False,
                          fake_quant=fake_quant, paged=paged)
        new_dense.append(nc)
    if cfg.mx_table is not None:
        new_layer_cache = []
        for i, cfg_i in enumerate(_scan_cfgs(cfg)):
            x, nc, _ = _block(_scan_layer_params(params, i), x, cfg_i,
                              positions=None, cache=cache["layers"][i],
                              moe_layer=moe_layer, fake_quant=fake_quant,
                              paged=paged)
            new_layer_cache.append(nc)
    else:
        def step(carry, xs):
            lp, cache_l = xs
            y, nc, _ = _block(lp, carry, cfg, positions=None, cache=cache_l,
                              moe_layer=moe_layer, fake_quant=fake_quant,
                              paged=paged)
            return y, nc

        x, new_layer_cache = L.layer_scan(
            step, x, (params["layers"], cache["layers"]), cfg)
    new_cache = {"layers": new_layer_cache}
    if new_dense:
        new_cache["dense_layers"] = new_dense
    return _head(params, cfg, x), new_cache


def paged_decode_multi_step(params, token, cache, block_tables, lengths,
                            remaining, keys, cfg: ModelConfig, *,
                            n_steps: int, temperature: float = 0.0,
                            trash_page: int = 0,
                            fake_quant: bool = False,
                            health: bool = False):
    """``n_steps`` fused continuous-batching decode steps in one
    ``lax.scan`` — the device-resident hot loop.

    Carries tokens, per-slot lengths, remaining generation budgets, and
    PRNG keys on device; each iteration runs ``paged_decode_step`` (KV
    writes land in the paged pool inside the scan) and samples the next
    token with ``sample_tokens``.  Slots whose budget hits zero are masked:
    their block-table row is re-pointed at ``trash_page`` (the serving
    engine passes ``repro.serve.paging.TRASH_PAGE``) and their
    length/token freeze, so over-generated steps can never corrupt live
    pages (idle slots enter with remaining == 0 and stay masked).  The
    caller must have pre-granted every page the window's writes need
    (``Scheduler.plan_window``).

    token/lengths/remaining (B,) int32; keys (B, 2) uint32.  Returns
    (tokens (n_steps, B) int32, new cache, new lengths, new remaining,
    new keys) — plus, with ``health=True``, a (B,) bool flagging slots
    whose sampled logits went non-finite at any *active* step of the
    window (the finite-logits half of the serving numeric-health guard;
    masked/done slots are exempt, since their logits are garbage by
    design).  The flag rides the scan carry, so it costs one (B, vocab)
    ``isfinite`` reduction per step and nothing on the host.
    """
    vocab = cfg.vocab

    def one(carry, _):
        tok, cache, lengths, remaining, keys, bad = carry
        done = remaining <= 0
        bt = jnp.where(done[:, None], trash_page, block_tables)
        ln = jnp.where(done, 0, lengths)
        logits, cache = paged_decode_step(params, tok, cache, bt, ln, cfg,
                                          fake_quant=fake_quant)
        last = logits[:, -1, :vocab]
        keys, nxt = sample_tokens(last, keys, temperature)
        if health:
            bad = bad | (~jnp.all(jnp.isfinite(last), axis=-1) & ~done)
        nxt = jnp.where(done, tok, nxt)
        lengths = jnp.where(done, lengths, lengths + 1)
        remaining = jnp.where(done, remaining, remaining - 1)
        return (nxt, cache, lengths, remaining, keys, bad), nxt

    bad0 = jnp.zeros(token.shape, bool)
    (token, cache, lengths, remaining, keys, bad), toks = jax.lax.scan(
        one, (token, cache, lengths, remaining, keys, bad0), None,
        length=n_steps)
    if health:
        return toks, cache, lengths, remaining, keys, bad
    return toks, cache, lengths, remaining, keys
