"""Encoder-decoder transformer (seamless-m4t family).

The speech frontend is a STUB per the brief: the encoder consumes
precomputed frame embeddings (B, S_enc, d) directly.  The decoder is a
standard causal LM with cross-attention; decode caches the decoder self-KV
plus the (once-computed) cross K/V.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.dist.sharding import logical
from repro.models import layers as L
from repro.models.config import ModelConfig
from repro.models.decoder import padded_vocab


def _enc_layer_init(key, cfg):
    k1, k2 = jax.random.split(key)
    d = cfg.d_model
    dt = L.dtype_of(cfg)
    return {"ln1": jnp.ones((d,), dt), "attn": L.attn_init(k1, cfg),
            "ln2": jnp.ones((d,), dt), "mlp": L.mlp_init(k2, cfg)}


def _dec_layer_init(key, cfg):
    k1, k2, k3 = jax.random.split(key, 3)
    d = cfg.d_model
    dt = L.dtype_of(cfg)
    return {"ln1": jnp.ones((d,), dt), "attn": L.attn_init(k1, cfg),
            "lnx": jnp.ones((d,), dt), "xattn": L.attn_init(k2, cfg),
            "ln2": jnp.ones((d,), dt), "mlp": L.mlp_init(k3, cfg)}


def init(key, cfg: ModelConfig) -> Dict[str, Any]:
    vp = padded_vocab(cfg)
    d = cfg.d_model
    dt = L.dtype_of(cfg)
    ks = jax.random.split(key, 5)
    ekeys = jax.random.split(ks[0], cfg.n_enc_layers)
    dkeys = jax.random.split(ks[1], cfg.n_dec_layers)
    return {
        "embed": L.embed_init(ks[2], vp, d, dt),
        "enc_layers": jax.vmap(lambda k: _enc_layer_init(k, cfg))(ekeys),
        "dec_layers": jax.vmap(lambda k: _dec_layer_init(k, cfg))(dkeys),
        "enc_norm": jnp.ones((d,), dt),
        "norm_f": jnp.ones((d,), dt),
        "lm_head": L.dense_init(ks[3], d, vp, dt),
    }


def encode(params, frames: jax.Array, cfg: ModelConfig,
           fake_quant: bool = False) -> jax.Array:
    """frames: precomputed frame embeddings (B, S_enc, d) — frontend stub."""
    x = logical(frames.astype(L.dtype_of(cfg)), "batch", None, None)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))

    def step(carry, lp):
        h = L.rms_norm(carry, lp["ln1"], cfg.norm_eps)
        a, _ = L.attention(lp["attn"], h, cfg, positions=positions,
                           causal=False, fake_quant=fake_quant)
        x1 = carry + a
        h = L.rms_norm(x1, lp["ln2"], cfg.norm_eps)
        return x1 + L.mlp(lp["mlp"], h, cfg, fake_quant), None

    step_fn = jax.checkpoint(step) if cfg.remat else step
    x, _ = L.layer_scan(step_fn, x, params["enc_layers"], cfg)
    return L.rms_norm(x, params["enc_norm"], cfg.norm_eps)


def _cross_kv(lp, enc_out, cfg, fake_quant):
    b, se, _ = enc_out.shape
    nkv, hd = cfg.n_kv_heads, cfg.hd
    k = L.dense(enc_out, lp["xattn"]["wk"], cfg.mx, fake_quant)
    v = L.dense(enc_out, lp["xattn"]["wv"], cfg.mx, fake_quant)
    return k.reshape(b, se, nkv, hd), v.reshape(b, se, nkv, hd)


def _dec_block(lp, x, cfg, *, positions, enc_out=None, cross_kv=None,
               cache=None, cache_pos=None, fake_quant=False):
    h = L.rms_norm(x, lp["ln1"], cfg.norm_eps)
    a, new_cache = L.attention(lp["attn"], h, cfg, positions=positions,
                               cache=cache, cache_pos=cache_pos,
                               fake_quant=fake_quant)
    x = x + a
    h = L.rms_norm(x, lp["lnx"], cfg.norm_eps)
    if cross_kv is None:
        cross_kv = _cross_kv(lp, enc_out, cfg, fake_quant)
    xa, _ = L.attention(lp["xattn"], h, cfg, positions=positions,
                        causal=False, kv_override=cross_kv,
                        fake_quant=fake_quant)
    x = x + xa
    h = L.rms_norm(x, lp["ln2"], cfg.norm_eps)
    return x + L.mlp(lp["mlp"], h, cfg, fake_quant), new_cache


def forward(params, frames, tokens, cfg: ModelConfig,
            fake_quant: bool = False) -> Tuple[jax.Array, jax.Array]:
    """Training: frames (B,S_enc,d) + decoder tokens (B,S_dec) -> logits."""
    enc_out = encode(params, frames, cfg, fake_quant)
    x = jnp.take(params["embed"], tokens, axis=0).astype(L.dtype_of(cfg))
    x = logical(x, "batch", None, None)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))

    def step(carry, lp):
        y, _ = _dec_block(lp, carry, cfg, positions=positions,
                          enc_out=enc_out, fake_quant=fake_quant)
        return y, None

    step_fn = jax.checkpoint(step) if cfg.remat else step
    x, _ = L.layer_scan(step_fn, x, params["dec_layers"], cfg)
    x = L.rms_norm(x, params["norm_f"], cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", x,
                        params["lm_head"].astype(x.dtype),
                        preferred_element_type=jnp.float32)
    return logical(logits, "batch", None, "model"), jnp.zeros((),
                                                              jnp.float32)


def init_cache(cfg: ModelConfig, batch: int, max_len: int, s_enc: int):
    nd = cfg.n_dec_layers
    self_kv = L.init_kv_cache(cfg, batch, max_len, cfg.n_kv_heads, cfg.hd,
                              layers_dim=(nd,))
    dt = L.dtype_of(cfg)
    cross = {"k": jnp.zeros((nd, batch, s_enc, cfg.n_kv_heads, cfg.hd), dt),
             "v": jnp.zeros((nd, batch, s_enc, cfg.n_kv_heads, cfg.hd), dt)}
    return {"self": self_kv, "cross": cross}


def prefill(params, frames, tokens, cfg: ModelConfig, *, max_len: int,
            fake_quant: bool = False):
    """Encode + consume decoder prompt; returns (logits, cache, next_pos)."""
    enc_out = encode(params, frames, cfg, fake_quant)
    x = jnp.take(params["embed"], tokens, axis=0).astype(L.dtype_of(cfg))
    b, s, _ = x.shape
    cache = init_cache(cfg, b, max_len, enc_out.shape[1])
    positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))

    def step(carry, xs):
        lp, cache_l = xs
        ck, cv = _cross_kv(lp, enc_out, cfg, fake_quant)
        y, nc = _dec_block(lp, carry, cfg, positions=positions,
                           cross_kv=(ck, cv), cache=cache_l, cache_pos=0,
                           fake_quant=fake_quant)
        return y, (nc, ck, cv)

    x, (self_c, cks, cvs) = L.layer_scan(
        step, x, (params["dec_layers"], cache["self"]), cfg)
    cache = {"self": self_c, "cross": {"k": cks, "v": cvs}}
    x = L.rms_norm(x, params["norm_f"], cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", x,
                        params["lm_head"].astype(x.dtype),
                        preferred_element_type=jnp.float32)
    return logits, cache, s


def decode_step(params, token, cache, pos, cfg: ModelConfig,
                fake_quant: bool = False):
    x = jnp.take(params["embed"], token[:, None], axis=0
                 ).astype(L.dtype_of(cfg))
    b = x.shape[0]
    positions = jnp.full((b, 1), pos)

    def step(carry, xs):
        lp, cache_l, ck, cv = xs
        y, nc = _dec_block(lp, carry, cfg, positions=positions,
                           cross_kv=(ck, cv), cache=cache_l, cache_pos=pos,
                           fake_quant=fake_quant)
        return y, nc

    x, self_c = L.layer_scan(
        step, x, (params["dec_layers"], cache["self"], cache["cross"]["k"],
                  cache["cross"]["v"]), cfg)
    cache = {"self": self_c, "cross": cache["cross"]}
    x = L.rms_norm(x, params["norm_f"], cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", x,
                        params["lm_head"].astype(x.dtype),
                        preferred_element_type=jnp.float32)
    return logits, cache
