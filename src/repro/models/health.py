"""Numeric-health reductions over the paged MX KV pool.

The paper's converter gives every 32-element block an E8M0 scale byte and
reserves the top encodings for non-finite blocks (SCALE_INF/SCALE_NAN in
paper mode; ocp mode folds both into SCALE_NAN).  That makes poison
detection on a serving pool a pure uint8 compare over the *scale* leaves
— a few bytes per token per layer, no dequantization, no touching the
(much larger) code pages.  :func:`slot_scale_poison` folds that compare
into the engine's jitted decode/prefill closures so a NaN/Inf-poisoned
slot is flagged at the window boundary and quarantined before its
garbage tokens are ever emitted.

Scope: MX pools get marker detection; fp (bf16/f32) pools have no scale
bytes, so they rely on the finite-logits guard the decode scan carries
(``decoder.paged_decode_multi_step(health=True)``) — a NaN page always
surfaces as non-finite logits for the slot that attends it.

Masking matters: a slot's block-table row is trash-padded past its
allocation, and recycled pages may still hold stale marker bytes from a
previously quarantined request at positions the new owner has not yet
written.  Both are excluded by the position mask (``pos < length``):
only bytes the slot actually wrote (prefill scatter, decode writes, or a
swap restore) can flag it.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.formats import poison_threshold
from repro.models.layers import paged_page_size


def _group_poison(group, page_tables, live, kk, kv):
    """Poison flags for one layer group's pool dict.

    ``group`` — one layer's (or the stacked scan's) pool leaves;
    ``page_tables`` (B, n) physical page ids; ``live`` (B, n*page) bool
    position mask.  Returns (B,) bool."""
    b = page_tables.shape[0]
    flags = jnp.zeros((b,), bool)
    for sk, spec in (("ks_pages", kk), ("vs_pages", kv)):
        leaf = group.get(sk)
        if leaf is None or spec is None:    # fp pool: no scale bytes
            continue
        thr = jnp.uint8(poison_threshold(spec.mode))
        g = leaf[:, page_tables] if leaf.ndim == 5 else leaf[page_tables]
        bad = g >= thr
        if leaf.ndim == 5:                  # layer-stacked: any layer
            bad = jnp.any(bad, axis=0)
        bad = jnp.any(bad, axis=(-1, -2))   # over (n_kv, blocks)
        flags = flags | jnp.any(bad.reshape(b, -1) & live, axis=-1)
    return flags


def slot_scale_poison(pool, page_tables, lengths, cfg):
    """Per-slot MX-block poison detection: (B,) bool, True where any
    SCALE_NAN/SCALE_INF marker byte sits inside the slot's *live* cache
    positions (pos < lengths[b]) across every layer's K and V pools.

    ``pool`` is the engine's page-pool pytree ({"layers": leaf-dict or
    per-layer list, "dense_layers": [...]}); ``page_tables`` (B, n) int32
    physical page ids per slot (a block-table slice or a prefill's
    page_ids); ``lengths`` (B,) int32 written positions.  Jit-safe; the
    threshold is mode-aware per layer/role (paper: >= SCALE_INF, ocp:
    == SCALE_NAN — see ``core.formats.poison_threshold``).
    """
    page = paged_page_size(
        pool["layers"][0] if isinstance(pool["layers"], list)
        else pool["layers"])
    b, n = page_tables.shape
    live = jnp.arange(n * page)[None, :] < lengths[:, None]
    flags = jnp.zeros((b,), bool)
    lay = pool["layers"]
    if isinstance(lay, list):               # per-layer PolicyTable pools
        for i, g in enumerate(lay):
            c = cfg.layer_cfg(cfg.n_dense_layers + i)
            flags = flags | _group_poison(g, page_tables, live,
                                          c.mx.kv_key, c.mx.kv_value)
    else:
        flags = flags | _group_poison(lay, page_tables, live,
                                      cfg.mx.kv_key, cfg.mx.kv_value)
    for i, g in enumerate(pool.get("dense_layers", [])):
        c = cfg.layer_cfg(i)
        flags = flags | _group_poison(g, page_tables, live,
                                      c.mx.kv_key, c.mx.kv_value)
    return flags
