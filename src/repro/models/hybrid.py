"""Zamba2-style hybrid: Mamba2 backbone + one SHARED attention block applied
every ``attn_every`` layers (weights reused at every invocation; each
invocation site still owns its own KV cache)."""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.dist.sharding import logical
from repro.models import layers as L
from repro.models import ssm
from repro.models.config import ModelConfig
from repro.models.decoder import padded_vocab


def _n_groups(cfg: ModelConfig) -> int:
    assert cfg.n_layers % cfg.attn_every == 0, \
        "n_layers must divide by attn_every"
    return cfg.n_layers // cfg.attn_every


def init(key, cfg: ModelConfig) -> Dict[str, Any]:
    vp = padded_vocab(cfg)
    d = cfg.d_model
    dt = L.dtype_of(cfg)
    ks = jax.random.split(key, 6)
    mkeys = jax.random.split(ks[0], cfg.n_layers)
    k1, k2 = jax.random.split(ks[1])
    shared = {"ln1": jnp.ones((d,), dt), "attn": L.attn_init(k1, cfg),
              "ln2": jnp.ones((d,), dt), "mlp": L.mlp_init(k2, cfg)}
    return {
        "embed": L.embed_init(ks[2], vp, d, dt),
        "blocks": jax.vmap(lambda k: ssm.mamba_init(k, cfg))(mkeys),
        "shared_attn": shared,
        "norm_f": jnp.ones((d,), dt),
        "lm_head": L.dense_init(ks[3], d, vp, dt),
    }


def _shared_block(sp, x, cfg, *, positions, cache=None, cache_pos=None,
                  fake_quant=False):
    h = L.rms_norm(x, sp["ln1"], cfg.norm_eps)
    a, nc = L.attention(sp["attn"], h, cfg, positions=positions, cache=cache,
                        cache_pos=cache_pos, fake_quant=fake_quant)
    x = x + a
    h = L.rms_norm(x, sp["ln2"], cfg.norm_eps)
    return x + L.mlp(sp["mlp"], h, cfg, fake_quant), nc


def _grouped(tree, g: int, k: int):
    """Reshape layer-stacked params (L, ...) -> (G, k, ...)."""
    return jax.tree_util.tree_map(
        lambda t: t.reshape((g, k) + t.shape[1:]), tree)


def forward(params, tokens, cfg: ModelConfig, *, fake_quant: bool = False
            ) -> Tuple[jax.Array, jax.Array]:
    x = jnp.take(params["embed"], tokens, axis=0).astype(L.dtype_of(cfg))
    x = logical(x, "batch", None, None)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))
    g = _n_groups(cfg)
    blocks = _grouped(params["blocks"], g, cfg.attn_every)
    sp = params["shared_attn"]

    def group_step(carry, gp):
        y, _ = _shared_block(sp, carry, cfg, positions=positions,
                             fake_quant=fake_quant)
        for i in range(cfg.attn_every):
            lp = jax.tree_util.tree_map(lambda t: t[i], gp)
            y, _ = ssm.mamba_block(lp, y, cfg, fake_quant=fake_quant)
        return y, None

    step_fn = jax.checkpoint(group_step) if cfg.remat else group_step
    x, _ = L.layer_scan(step_fn, x, blocks, cfg)
    x = L.rms_norm(x, params["norm_f"], cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", x,
                        params["lm_head"].astype(x.dtype),
                        preferred_element_type=jnp.float32)
    return logical(logits, "batch", None, "model"), jnp.zeros((),
                                                              jnp.float32)


def init_cache(cfg: ModelConfig, batch: int, max_len: int):
    g = _n_groups(cfg)
    return {
        "attn": L.init_kv_cache(cfg, batch, max_len, cfg.n_kv_heads, cfg.hd,
                                layers_dim=(g,)),
        "mamba": ssm.mamba_init_cache(cfg, batch,
                                      layers_dim=(g, cfg.attn_every)),
    }


def _run(params, cache, x, cfg, positions, cache_pos, fake_quant,
         decode: bool):
    g = _n_groups(cfg)
    blocks = _grouped(params["blocks"], g, cfg.attn_every)
    sp = params["shared_attn"]

    def group_step(carry, xs):
        gp, attn_c, mamba_c = xs
        y, attn_nc = _shared_block(sp, carry, cfg, positions=positions,
                                   cache=attn_c, cache_pos=cache_pos,
                                   fake_quant=fake_quant)
        mamba_ncs = []
        for i in range(cfg.attn_every):
            lp = jax.tree_util.tree_map(lambda t: t[i], gp)
            mc = jax.tree_util.tree_map(lambda t: t[i], mamba_c)
            if decode:
                y, nc = ssm.mamba_decode(lp, y, cfg, mc,
                                         fake_quant=fake_quant)
            else:
                y, nc = ssm.mamba_block(lp, y, cfg, cache=mc,
                                        fake_quant=fake_quant)
            mamba_ncs.append(nc)
        mamba_nc = jax.tree_util.tree_map(
            lambda *ts: jnp.stack(ts), *mamba_ncs)
        return y, (attn_nc, mamba_nc)

    x, (attn_c, mamba_c) = L.layer_scan(
        group_step, x, (blocks, cache["attn"], cache["mamba"]), cfg)
    return x, {"attn": attn_c, "mamba": mamba_c}


def prefill(params, tokens, cfg: ModelConfig, *, max_len: int,
            fake_quant: bool = False):
    x = jnp.take(params["embed"], tokens, axis=0).astype(L.dtype_of(cfg))
    b, s, _ = x.shape
    cache = init_cache(cfg, b, max_len)
    positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))
    x, cache = _run(params, cache, x, cfg, positions, 0, fake_quant,
                    decode=False)
    x = L.rms_norm(x, params["norm_f"], cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", x,
                        params["lm_head"].astype(x.dtype),
                        preferred_element_type=jnp.float32)
    return logits, cache, s


def decode_step(params, token, cache, pos, cfg: ModelConfig,
                fake_quant: bool = False):
    x = jnp.take(params["embed"], token[:, None], axis=0
                 ).astype(L.dtype_of(cfg))
    b = x.shape[0]
    positions = jnp.full((b, 1), pos)
    x, cache = _run(params, cache, x, cfg, positions, pos, fake_quant,
                    decode=True)
    x = L.rms_norm(x, params["norm_f"], cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", x,
                        params["lm_head"].astype(x.dtype),
                        preferred_element_type=jnp.float32)
    return logits, cache
