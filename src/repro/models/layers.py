"""Shared neural layers for the model zoo (functional JAX, no framework).

Every matmul routes through ``dense()``, which is where the paper's MX
converter plugs in, steered by the per-tensor-role ``QuantPolicy``:
  * training     — fake-quantization of weights (and optionally
                   activations) per the ``weights``/``activations`` roles;
  * serving      — weights stored as MXArray (uint8 codes + E8M0 scales),
                   dequantized on the fly => ~4x less weight HBM traffic;
  * KV caches    — quantized along head_dim per the ``kv_key``/``kv_value``
                   roles, which may carry *different* element formats
                   (e.g. INT8 keys + E2M1 values).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.convert import (MXArray, mx_dequantize, mx_quantize,
                                quantize_dequantize)
from repro.core.mx_weight import MXWeight
from repro.core.pack import pack_codes, unpack_codes
from repro.core.spec import QuantPolicy, QuantSpec
from repro.dist.sharding import (bf16_matmul_out_enabled, logical,
                                 model_axis_size, weight_gather_enabled,
                                 weight_gather_mode)
from repro.models.config import ModelConfig

Params = Dict[str, Any]


def dtype_of(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def layer_scan(step, carry, xs, cfg: ModelConfig):
    """lax.scan over stacked layers; unrolled when cfg.scan_unroll (dry-run
    accounting mode — while-loop bodies are counted once by HLO cost
    analysis, so accounting lowers a small unrolled depth).  The accounting
    scale context makes kernel-cost records inside the body count once per
    layer (scan traces its body once)."""
    from repro.kernels import accounting
    depth = jax.tree_util.tree_leaves(xs)[0].shape[0]
    with accounting.scale(depth):
        return jax.lax.scan(step, carry, xs,
                            unroll=True if cfg.scan_unroll else 1)


# =============================================================================
# init helpers
# =============================================================================
def dense_init(key, d_in: int, d_out: int, dtype, scale: float = 1.0):
    std = scale / np.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * std
            ).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype):
    return (jax.random.normal(key, (vocab, d), jnp.float32) * 0.02
            ).astype(dtype)


# =============================================================================
# primitives
# =============================================================================
def rms_norm(x: jax.Array, w: jax.Array, eps: float) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * w.astype(jnp.float32)).astype(x.dtype)


def _gather_spec(tp: str, rank: int):
    """FSDP use-point constraint: un-shard the 'data' dim of the weight so
    GSPMD inserts a per-layer weight all-gather (ZeRO-3) instead of
    k-parallel matmuls that all-reduce activations.  In pure-FSDP mode
    (weight_gather_mode() == "full") the whole weight is gathered and no
    dim stays TP-sharded."""
    lead = (None,) * (rank - 2)
    if weight_gather_mode() == "full" or tp == "none":
        return lead + (None, None)
    if tp == "row":
        return lead + ("model", None)
    return lead + (None, "model")          # col (default)


def _check_mx_row_gather(k: int, block: int, tp: str) -> None:
    """Row-parallel ("model" on K) gather of an MX weight shards the codes'
    K axis *and* the scales' K//block axis with the same spec.  ``logical``
    silently replicates any dim the mesh does not divide, so a K that
    shards while K//block does not would leave codes "model"-sharded but
    scales replicated — inconsistent layouts feeding one matmul.  Refuse
    loudly instead."""
    if tp != "row" or weight_gather_mode() == "full":
        return
    ms = model_axis_size()
    if ms <= 1:
        return
    kblk = k // block
    if k % ms == 0 and kblk % ms != 0:
        raise ValueError(
            f"row-parallel FSDP gather cannot shard this MX weight: the "
            f"codes' contraction axis (K={k}) divides the 'model' axis "
            f"size {ms}, but the scales' axis (K//block={kblk}, block="
            f"{block}) does not — pad K to a multiple of {ms * block} or "
            f"store this weight unquantized")


def dense(x: jax.Array, w, mx: Optional[QuantPolicy] = None,
          fake_quant: bool = False, tp: str = "col") -> jax.Array:
    """x @ w steered by the policy's ``weights``/``activations`` roles
    (see module docstring).

    ``tp`` is the tensor-parallel role of the weight: "col" shards the
    output dim over "model", "row" the input dim (Megatron convention).
    """
    gather = weight_gather_enabled()
    if fake_quant and mx is not None and mx.activations is not None:
        x = quantize_dequantize(x.astype(jnp.float32), mx.activations,
                                axis=-1).astype(x.dtype)
    if isinstance(w, MXWeight):
        # weight-resident serving: codes (possibly bit-packed) + scales go
        # straight to the fused kernel, which dequantizes tiles in VMEM —
        # fp weights are never materialized in HBM
        if gather:
            _check_mx_row_gather(w.kp, w.block, tp)
            spec = _gather_spec(tp, w.codes.ndim)
            w = dataclasses.replace(w, codes=logical(w.codes, *spec),
                                    scales=logical(w.scales, *spec))
        from repro.kernels.backend import resolve_matmul_impl
        if resolve_matmul_impl() == "fused":
            from repro.kernels.ops import mx_matmul_resident
            return mx_matmul_resident(x, w).astype(x.dtype)
        wd = w.dequantize().astype(x.dtype)
    elif isinstance(w, MXArray):
        # gather the *codes* (u8): the FSDP all-gather moves ~4x fewer
        # bytes than gathering f32/bf16 weights — the paper's converter as
        # a collective-compression lever
        if gather:
            _check_mx_row_gather(w.codes.shape[-2], w.block, tp)
            spec = _gather_spec(tp, w.codes.ndim)
            w = dataclasses.replace(w, codes=logical(w.codes, *spec),
                                    scales=logical(w.scales, *spec))
        wd = mx_dequantize(w).astype(x.dtype)
    else:
        if gather:
            w = logical(w, *_gather_spec(tp, w.ndim))
        if fake_quant and mx is not None and mx.weights is not None:
            wd = quantize_dequantize(w.astype(jnp.float32), mx.weights,
                                     axis=0).astype(x.dtype)
        else:
            wd = w.astype(x.dtype)
    # bf16 outputs halve TP partial-sum all-reduce payloads and f32
    # intermediate traffic; the MXU accumulates f32 internally either way
    pref = x.dtype if bf16_matmul_out_enabled() else jnp.float32
    y = jnp.einsum("...k,kn->...n", x, wd, preferred_element_type=pref)
    return y.astype(x.dtype)


def rope_tables(positions: jax.Array, dim: int, theta: float):
    """cos/sin tables (…, dim/2) in f32 for the given positions."""
    half = dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array,
               rope_frac: float = 1.0) -> jax.Array:
    """Rotate the first ``rope_frac`` of the head dim (chatglm-style 2d RoPE
    rotates half).  x: (B, S, H, D); cos/sin: (B?, S, D_r/2)."""
    d = x.shape[-1]
    dr = int(d * rope_frac)
    dr -= dr % 2
    xr, xp = x[..., :dr], x[..., dr:]
    x1, x2 = xr[..., : dr // 2], xr[..., dr // 2:]
    c = cos[..., : dr // 2][:, :, None, :].astype(jnp.float32)
    s = sin[..., : dr // 2][:, :, None, :].astype(jnp.float32)
    x1f, x2f = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate([x1f * c - x2f * s, x2f * c + x1f * s], axis=-1)
    return jnp.concatenate([out.astype(x.dtype), xp], axis=-1)


def softmax_f32(scores: jax.Array, axis: int = -1) -> jax.Array:
    return jax.nn.softmax(scores.astype(jnp.float32), axis=axis)


# =============================================================================
# KV cache (bf16 or MX; per-role key/value specs)
# =============================================================================
def _code_len(dim: int, block: int) -> int:
    return -(-dim // block) * block


def init_kv_cache(cfg: ModelConfig, batch: int, max_len: int,
                  n_kv: int, hd: int, layers_dim: Tuple[int, ...] = ()):
    """Allocate one attention layer's cache (optionally layer-stacked).
    K and V are sized per their policy roles (blocks may differ)."""
    kk, kv = cfg.mx.kv_key, cfg.mx.kv_value
    if kk is not None:
        def side(spec):
            cl = _code_len(hd, spec.block)
            codes = jnp.zeros(layers_dim + (batch, max_len, n_kv, cl),
                              jnp.uint8)
            scales = jnp.zeros(
                layers_dim + (batch, max_len, n_kv, cl // spec.block),
                jnp.uint8)
            return codes, scales

        kc, ks = side(kk)
        vc, vs = side(kv)
        return {"k_codes": kc, "k_scales": ks,
                "v_codes": vc, "v_scales": vs}
    shape = layers_dim + (batch, max_len, n_kv, hd)
    z = jnp.zeros(shape, dtype_of(cfg))
    return {"k": z, "v": z}


def _kv_quant(x: jax.Array, spec: QuantSpec) -> Tuple[jax.Array, jax.Array]:
    mx = mx_quantize(x.astype(jnp.float32), spec, axis=-1)
    return mx.codes, mx.scales


def _kv_dequant(codes: jax.Array, scales: jax.Array, spec: QuantSpec,
                dtype, orig_len: Optional[int] = None) -> jax.Array:
    mx = MXArray.from_spec(codes, scales, spec, orig_len=orig_len,
                           axis=codes.ndim - 1)
    return mx_dequantize(mx).astype(dtype)


def cache_write(cache, k: jax.Array, v: jax.Array, pos, cfg: ModelConfig):
    """Write k/v (B, s, n_kv, hd) into the cache at position ``pos``.

    k/v arrive head-sharded over "model" (col-parallel projections); the
    cache is stored batch-sharded/model-replicated so decode reads never
    all-gather the full cache — only the one-token update is gathered."""
    k = logical(k, "kv_batch", None, None, None)
    v = logical(v, "kv_batch", None, None, None)
    if cfg.mx.kv_key is not None:
        kc, ks = _kv_quant(k, cfg.mx.kv_key)
        vc, vs = _kv_quant(v, cfg.mx.kv_value)
        upd = dict(k_codes=kc, k_scales=ks, v_codes=vc, v_scales=vs)
        out = {}
        for name, val in upd.items():
            tgt = cache[name]
            idx = (0, pos) + (0,) * (tgt.ndim - 2)
            out[name] = jax.lax.dynamic_update_slice(tgt, val, idx)
        return out
    idx = (0, pos, 0, 0)
    return {"k": jax.lax.dynamic_update_slice(cache["k"], k.astype(
                cache["k"].dtype), idx),
            "v": jax.lax.dynamic_update_slice(cache["v"], v.astype(
                cache["v"].dtype), idx)}


def cache_read(cache, cfg: ModelConfig, dtype, hd: Optional[int] = None):
    if cfg.mx.kv_key is not None:
        k = _kv_dequant(cache["k_codes"], cache["k_scales"], cfg.mx.kv_key,
                        dtype, hd)
        v = _kv_dequant(cache["v_codes"], cache["v_scales"],
                        cfg.mx.kv_value, dtype, hd)
        return k, v
    return cache["k"].astype(dtype), cache["v"].astype(dtype)


# =============================================================================
# Paged KV cache (continuous batching)
# =============================================================================
def init_paged_kv_cache(cfg: ModelConfig, num_pages: int, page_size: int,
                        n_kv: int, hd: int,
                        layers_dim: Tuple[int, ...] = ()):
    """Allocate one attention layer's page pool (optionally layer-stacked).

    MX layout packs sub-byte element codes via repro.core.pack (when the
    role's spec says ``packed``), so an FP4 pool really is ~4x smaller
    than bf16 in HBM — and K/V pools are sized per their own roles, so
    INT8 keys can share an engine with half-size E2M1 value pages.
    Page 0 is reserved by the serving engine as the trash page (inactive
    slots write there)."""
    kk, kv = cfg.mx.kv_key, cfg.mx.kv_value
    if kk is not None:
        def side(spec):
            cl = _code_len(hd, spec.block)
            cb = spec.storage_nbytes(cl)
            codes = jnp.zeros(
                layers_dim + (num_pages, page_size, n_kv, cb), jnp.uint8)
            scales = jnp.zeros(
                layers_dim + (num_pages, page_size, n_kv, cl // spec.block),
                jnp.uint8)
            return codes, scales

        kc, ks = side(kk)
        vc, vs = side(kv)
        return {"kc_pages": kc, "ks_pages": ks,
                "vc_pages": vc, "vs_pages": vs}
    # distinct buffers per key: the serving engine donates the pool into
    # its jitted step, and aliased leaves would be donated twice
    shape = layers_dim + (num_pages, page_size, n_kv, hd)
    return {"k_pages": jnp.zeros(shape, dtype_of(cfg)),
            "v_pages": jnp.zeros(shape, dtype_of(cfg))}


def paged_page_size(pool) -> int:
    leaf = pool.get("kc_pages", pool.get("k_pages"))
    return leaf.shape[-3]


# pool key -> (contiguous prefill-cache key, element-code policy role)
PAGED_POOL_KEYS = {
    "kc_pages": ("k_codes", "kv_key"), "ks_pages": ("k_scales", None),
    "vc_pages": ("v_codes", "kv_value"), "vs_pages": ("v_scales", None),
    "k_pages": ("k", None), "v_pages": ("v", None),
}


def paged_cache_scatter(pool, cache, page_ids, cfg: ModelConfig):
    """Scatter a *batched* contiguous prefill cache into the page pool.

    ``pool``/``cache`` are one layer group's dicts (optionally
    layer-stacked on a leading axis); cache leaves are (…, G, Lp, n_kv, X)
    for G prefilled requests padded to the same Lp (a page multiple).
    ``page_ids`` (G, npr) names the physical page of each (request, logical
    page); rows are padded with the trash page where a request's padded
    prompt exceeds its allocation, so bucket padding never touches live
    pages.  Sub-byte codes are bit-packed per role on the way — once, on
    device — and all G requests' pages land in a single scatter per leaf.
    """
    policy = cfg.mx
    g, npr = page_ids.shape
    flat = page_ids.reshape(-1)
    page = paged_page_size(pool)
    out = {}
    for pk, leaf in pool.items():
        ck, role = PAGED_POOL_KEYS[pk]
        val = cache[ck]
        stacked = val.ndim == 5          # (n_scan, G, Lp, n_kv, X)
        spec = policy.role(role) if role is not None else None
        if spec is not None and spec.packed:
            val = pack_codes(val, spec.fmt)
        lead = val.shape[:-4] if stacked else ()
        val = val.reshape(lead + (g * npr, page) + val.shape[-2:])
        out[pk] = leaf.at[:, flat].set(val) if stacked \
            else leaf.at[flat].set(val)
    return out


def paged_cache_write(pool, k: jax.Array, v: jax.Array, pages: jax.Array,
                      offsets: jax.Array, cfg: ModelConfig):
    """Scatter one token per slot into the page pool.

    k/v (B, 1, n_kv, hd); pages/offsets (B,) i32 — slot b's token lands at
    pool[pages[b], offsets[b]].  Distinct active slots own distinct pages,
    so the scatter indices never collide except on the trash page."""
    kk, kv = cfg.mx.kv_key, cfg.mx.kv_value
    if kk is not None:
        kc, ks = _kv_quant(k, kk)
        vc, vs = _kv_quant(v, kv)
        if kk.packed:
            kc = pack_codes(kc, kk.fmt)
        if kv.packed:
            vc = pack_codes(vc, kv.fmt)
        upd = dict(kc_pages=kc, ks_pages=ks, vc_pages=vc, vs_pages=vs)
        return {name: logical(pool[name].at[pages, offsets].set(val[:, 0]),
                              "kv_pages", None, None, None)
                for name, val in upd.items()}
    dt = pool["k_pages"].dtype
    return {"k_pages": logical(pool["k_pages"].at[pages, offsets].set(
                k[:, 0].astype(dt)), "kv_pages", None, None, None),
            "v_pages": logical(pool["v_pages"].at[pages, offsets].set(
                v[:, 0].astype(dt)), "kv_pages", None, None, None)}


def paged_cache_write_tokens(pool, k: jax.Array, v: jax.Array,
                             pages: jax.Array, offsets: jax.Array,
                             cfg: ModelConfig):
    """Scatter a multi-token span per slot into the page pool (suffix
    prefill under prefix caching).

    k/v (G, S, n_kv, hd); pages/offsets (G, S) i32 — request g's token i
    lands at pool[pages[g, i], offsets[g, i]].  Padded positions must be
    routed to the trash page by the caller; live (page, offset) pairs
    never collide across requests because every written page is private
    to its slot (shared pages were copy-on-write forked first)."""
    kk, kv = cfg.mx.kv_key, cfg.mx.kv_value
    if kk is not None:
        kc, ks = _kv_quant(k, kk)
        vc, vs = _kv_quant(v, kv)
        if kk.packed:
            kc = pack_codes(kc, kk.fmt)
        if kv.packed:
            vc = pack_codes(vc, kv.fmt)
        upd = dict(kc_pages=kc, ks_pages=ks, vc_pages=vc, vs_pages=vs)
        return {name: logical(pool[name].at[pages, offsets].set(val),
                              "kv_pages", None, None, None)
                for name, val in upd.items()}
    dt = pool["k_pages"].dtype
    return {"k_pages": logical(pool["k_pages"].at[pages, offsets].set(
                k.astype(dt)), "kv_pages", None, None, None),
            "v_pages": logical(pool["v_pages"].at[pages, offsets].set(
                v.astype(dt)), "kv_pages", None, None, None)}


def paged_cache_gather(pool, block_tables: jax.Array, cfg: ModelConfig,
                       dtype, hd: int) -> Tuple[jax.Array, jax.Array]:
    """Gather a slot-major contiguous (B, max_pages*page, n_kv, hd) K/V view
    through the block table (dense-attention fallback path; the Pallas
    kernel gathers at the HBM->VMEM boundary instead)."""
    b, np_max = block_tables.shape
    if cfg.mx.kv_key is not None:
        def one(codes_key, scales_key, spec):
            cl = _code_len(hd, spec.block)
            c = pool[codes_key][block_tables]   # (B, np, page, n_kv, CB)
            c = c.reshape((b, -1) + c.shape[3:])
            if spec.packed:
                c = unpack_codes(c, spec.fmt, cl)
            s = pool[scales_key][block_tables]
            s = s.reshape((b, -1) + s.shape[3:])
            return _kv_dequant(c, s, spec, dtype, hd)

        return (one("kc_pages", "ks_pages", cfg.mx.kv_key),
                one("vc_pages", "vs_pages", cfg.mx.kv_value))
    k = pool["k_pages"][block_tables]
    v = pool["v_pages"][block_tables]
    k = k.reshape((b, -1) + k.shape[3:])
    v = v.reshape((b, -1) + v.shape[3:])
    return k.astype(dtype), v.astype(dtype)


def attention_paged_decode(p: Params, x: jax.Array, cfg: ModelConfig, *,
                           pool, block_tables: jax.Array,
                           lengths: jax.Array, fake_quant: bool = False
                           ) -> Tuple[jax.Array, Any]:
    """GQA decode over the paged KV cache: x (B, 1, d); slot b's new token
    sits at logical position lengths[b] and attends positions <= lengths[b].
    Inactive slots (lengths 0, zeroed block-table row) write to the trash
    page and their outputs are discarded by the engine."""
    b, s, d = x.shape                          # s == 1
    hd, nh, nkv = cfg.hd, cfg.n_heads, cfg.n_kv_heads
    mx = cfg.mx
    q = dense(x, p["wq"], mx, fake_quant)
    q = logical(q, "batch", None, "model").reshape(b, s, nh, hd)
    k = dense(x, p["wk"], mx, fake_quant).reshape(b, s, nkv, hd)
    v = dense(x, p["wv"], mx, fake_quant).reshape(b, s, nkv, hd)
    positions = lengths[:, None]
    cos, sin = rope_tables(positions, hd, cfg.rope_theta)
    q = apply_rope(q, cos, sin, cfg.rope_frac)
    k = apply_rope(k, cos, sin, cfg.rope_frac)
    page = paged_page_size(pool)
    pages = jnp.take_along_axis(
        block_tables, (lengths // page)[:, None], axis=1)[:, 0]
    pool = paged_cache_write(pool, k, v, pages, lengths % page, cfg)
    q = logical(q, "kv_batch", None, None, None)
    out = None
    if cfg.mx.kv_key is not None and cfg.attn_impl == "flash":
        from repro.kernels import backend
        from repro.kernels.ops import mx_paged_decode_attention_ctx
        # supervised dispatch: a failed (or degraded) kernel returns None
        # and the dense gather path below serves token-identically
        out = backend.supervised("paged_attn", mx_paged_decode_attention_ctx,
                                 q, pool, block_tables, lengths, cfg)
    if out is None:
        ka, va = paged_cache_gather(pool, block_tables, cfg, x.dtype, hd)
        # keep the gathered view slot-sharded (decode reads stay local);
        # without this GSPMD may replicate the full gathered KV per rank
        ka = logical(ka, "kv_batch", None, None, None)
        va = logical(va, "kv_batch", None, None, None)
        sk = ka.shape[1]
        mask = jnp.arange(sk)[None, None, None, None, :] \
            <= lengths[:, None, None, None, None]
        out = _sdpa_gqa(q, ka, va, mask)
    out = out.reshape(b, s, nh * hd)
    out = dense(out, p["wo"], mx, fake_quant, tp="row")
    return logical(out, "batch", None, None), pool


def attention_paged_prefill(p: Params, x: jax.Array, cfg: ModelConfig, *,
                            pool, block_tables: jax.Array,
                            starts: jax.Array, prompt_lens: jax.Array,
                            trash_page: int = 0,
                            fake_quant: bool = False
                            ) -> Tuple[jax.Array, Any]:
    """GQA prefill of an uncached prompt *suffix* over the paged KV cache
    (prefix sharing): x (G, S, d) holds request g's prompt tokens from
    position ``starts[g]`` (padded past ``prompt_lens[g] - starts[g]``).

    The suffix k/v are written into the slot's private pages first, then
    every query attends the *gathered dequantized* page view — prefix
    positions come from the shared (read-only) pages, suffix positions
    from the bytes just written.  The contiguous prefill attends the same
    dequantized values under an MX policy (see ``attention``), so a
    shared-prefix suffix prefill is bit-identical to the full one.  This
    path is dense on purpose: the flash prefill kernel's online softmax is
    only allclose-level vs ``_sdpa_gqa``, and prefix caching promises
    token identity, not tolerance.

    Padded positions write to ``trash_page`` and their logits are garbage
    the engine never reads."""
    g, s, d = x.shape
    hd, nh, nkv = cfg.hd, cfg.n_heads, cfg.n_kv_heads
    mx = cfg.mx
    q = dense(x, p["wq"], mx, fake_quant)
    q = logical(q, "batch", None, "model").reshape(g, s, nh, hd)
    k = dense(x, p["wk"], mx, fake_quant).reshape(g, s, nkv, hd)
    v = dense(x, p["wv"], mx, fake_quant).reshape(g, s, nkv, hd)
    positions = starts[:, None] + jnp.arange(s)[None, :]        # (G, S)
    cos, sin = rope_tables(positions, hd, cfg.rope_theta)
    q = apply_rope(q, cos, sin, cfg.rope_frac)
    k = apply_rope(k, cos, sin, cfg.rope_frac)
    page = paged_page_size(pool)
    np_max = block_tables.shape[1]
    valid = positions < prompt_lens[:, None]
    page_idx = jnp.clip(positions // page, 0, np_max - 1)
    pages = jnp.where(valid,
                      jnp.take_along_axis(block_tables, page_idx, axis=1),
                      trash_page)
    pool = paged_cache_write_tokens(pool, k, v, pages, positions % page,
                                    cfg)
    q = logical(q, "kv_batch", None, None, None)
    ka, va = paged_cache_gather(pool, block_tables, cfg, x.dtype, hd)
    ka = logical(ka, "kv_batch", None, None, None)
    va = logical(va, "kv_batch", None, None, None)
    sk = ka.shape[1]
    mask = jnp.arange(sk)[None, None, None, None, :] \
        <= positions[:, None, None, :, None]
    out = _sdpa_gqa(q, ka, va, mask)
    out = out.reshape(g, s, nh * hd)
    out = dense(out, p["wo"], mx, fake_quant, tp="row")
    return logical(out, "batch", None, None), pool


# =============================================================================
# GQA attention
# =============================================================================
def attn_init(key, cfg: ModelConfig, d: Optional[int] = None) -> Params:
    d = d or cfg.d_model
    hd, nh, nkv = cfg.hd, cfg.n_heads, cfg.n_kv_heads
    ks = jax.random.split(key, 4)
    dt = dtype_of(cfg)
    return {
        "wq": dense_init(ks[0], d, nh * hd, dt),
        "wk": dense_init(ks[1], d, nkv * hd, dt),
        "wv": dense_init(ks[2], d, nkv * hd, dt),
        "wo": dense_init(ks[3], nh * hd, d, dt),
    }


def _sdpa_gqa(q, k, v, mask) -> jax.Array:
    """Grouped-query attention without materializing repeated K/V.

    q (B,Sq,Hq,D), k/v (B,Sk,Hkv,D); Hq = Hkv * rep.  mask broadcastable to
    (B, 1, 1, Sq, Sk).  Grouped einsums keep K/V in their stored layout —
    no (B,Sk,Hq,D) expansion ever hits HBM.
    """
    b, sq, hq, d = q.shape
    hkv = k.shape[2]
    rep = hq // hkv
    qg = q.reshape(b, sq, hkv, rep, d)
    scale = 1.0 / np.sqrt(d)
    scores = jnp.einsum("bqgrd,bkgd->bgrqk", qg, k,
                        preferred_element_type=jnp.float32) * scale
    scores = jnp.where(mask, scores, -1e30)
    probs = softmax_f32(scores).astype(q.dtype)
    out = jnp.einsum("bgrqk,bkgd->bqgrd", probs, v,
                     preferred_element_type=jnp.float32).astype(q.dtype)
    return out.reshape(b, sq, hq, d)


def attention(p: Params, x: jax.Array, cfg: ModelConfig, *,
              positions: jax.Array, causal: bool = True,
              cache=None, cache_pos=None, fake_quant: bool = False,
              kv_override: Optional[Tuple[jax.Array, jax.Array]] = None,
              tap: Optional[dict] = None) -> Tuple[jax.Array, Any]:
    """GQA attention.  Full mode (cache=None): self-attention over x.
    Decode mode: x is (B,1,d), cache holds S_max past k/v, cache_pos scalar.
    ``kv_override`` serves cross-attention (k/v from the encoder).
    ``tap`` (calibration hook): a dict the post-RoPE, pre-quantization
    k/v land in — exactly the tensors the ``kv_key``/``kv_value`` policy
    roles will quantize (see repro.calib.stats)."""
    b, s, d = x.shape
    hd, nh, nkv = cfg.hd, cfg.n_heads, cfg.n_kv_heads
    mx = cfg.mx
    quant_prefill = False
    q = dense(x, p["wq"], mx, fake_quant)
    q = logical(q, "batch", None, "model")
    q = q.reshape(b, s, nh, hd)
    if kv_override is None:
        k = dense(x, p["wk"], mx, fake_quant).reshape(b, s, nkv, hd)
        v = dense(x, p["wv"], mx, fake_quant).reshape(b, s, nkv, hd)
        cos, sin = rope_tables(positions, hd, cfg.rope_theta)
        q = apply_rope(q, cos, sin, cfg.rope_frac)
        k = apply_rope(k, cos, sin, cfg.rope_frac)
        if tap is not None:
            tap["k"], tap["v"] = k, v
    else:
        k, v = kv_override
    new_cache = cache
    if cache is not None and kv_override is None:
        new_cache = cache_write(cache, k, v,
                                0 if cache_pos is None else cache_pos, cfg)
        if s == 1:
            # decode: attend over the (possibly MX-quantized) cache.
            # GQA decode compute is tiny; replicate it over "model" so the
            # cache (batch-sharded) is never all-gathered — otherwise GSPMD
            # kv-subgroup-shards the read and gathers the full cache to
            # honor the cache's replicated output contract.
            q = logical(q, "kv_batch", None, None, None)
            if cfg.mx.kv_key is not None and cfg.attn_impl == "flash":
                # fused path: the u8 cache never leaves HBM un-quantized —
                # dequant happens in VMEM inside the kernel
                from repro.kernels.ops import mx_decode_attention_ctx
                ofused = mx_decode_attention_ctx(q, new_cache, cache_pos,
                                                 cfg)
                if ofused is not None:
                    out = ofused.reshape(b, s, nh * hd)
                    out = dense(out, p["wo"], mx, fake_quant, tp="row")
                    return logical(out, "batch", None, None), new_cache
            k, v = cache_read(new_cache, cfg, x.dtype, hd)
            k = logical(k, "kv_batch", None, None, None)
            v = logical(v, "kv_batch", None, None, None)
            sk = k.shape[1]
            kpos = jnp.arange(sk)
            mask = (kpos[None, None, None, None, :] <= cache_pos)
        else:
            # prefill: the cache keeps the quantized copy for subsequent
            # decode steps.  Under an MX policy, attend the *dequantized*
            # cache view rather than the fresh k/v: suffix-only prefill
            # over shared prefix pages (repro.serve prefix caching) can
            # only read quantized bytes, so attending them here too keeps
            # full and suffix prefill bit-identical.  An fp cache
            # round-trips exactly — the fresh path stands.
            if cfg.mx.kv_key is not None:
                kq, vq = cache_read(new_cache, cfg, x.dtype, hd)
                k, v = kq[:, :s], vq[:, :s]
                quant_prefill = True
            sk = k.shape[1]
            qpos = jnp.arange(s)
            kpos = jnp.arange(sk)
            mask = kpos[None, None, None, None, :] \
                <= qpos[None, None, None, :, None]
    else:
        sk = k.shape[1]
        if causal:
            qpos = jnp.arange(s)
            kpos = jnp.arange(sk)
            mask = kpos[None, None, None, None, :] \
                <= qpos[None, None, None, :, None]
        else:
            mask = jnp.ones((1, 1, 1, s, sk), bool)
    out = None
    # quantize-aware prefill stays dense: the paged suffix-prefill path it
    # must match bit-for-bit is dense, and the flash kernel's online
    # softmax is only allclose-level against _sdpa_gqa
    if cfg.attn_impl == "flash" and causal and s > 1 \
            and s == k.shape[1] and not quant_prefill:
        from repro.kernels.ops import flash_attention_ctx
        out = flash_attention_ctx(q, k, v, causal=True)
    if out is None:
        out = _sdpa_gqa(q, k, v, mask)
    out = out.reshape(b, s, nh * hd)
    out = dense(out, p["wo"], mx, fake_quant, tp="row")
    return logical(out, "batch", None, None), new_cache


# =============================================================================
# MLA attention (deepseek-v2): compressed KV cache + absorbed decode
# =============================================================================
def mla_init(key, cfg: ModelConfig) -> Params:
    d = cfg.d_model
    nh = cfg.n_heads
    dq = cfg.q_lora or d
    qk = cfg.qk_nope_dim + cfg.qk_rope_dim
    ks = jax.random.split(key, 8)
    dt = dtype_of(cfg)
    p = {
        "w_dkv": dense_init(ks[0], d, cfg.kv_lora, dt),
        "w_kr": dense_init(ks[1], d, cfg.qk_rope_dim, dt),
        "w_uk": dense_init(ks[2], cfg.kv_lora, nh * cfg.qk_nope_dim, dt),
        "w_uv": dense_init(ks[3], cfg.kv_lora, nh * cfg.v_head_dim, dt),
        "wo": dense_init(ks[4], nh * cfg.v_head_dim, d, dt),
        "kv_norm": jnp.ones((cfg.kv_lora,), dt),
    }
    if cfg.q_lora:
        p["w_dq"] = dense_init(ks[5], d, dq, dt)
        p["q_norm"] = jnp.ones((dq,), dt)
        p["w_uq"] = dense_init(ks[6], dq, nh * qk, dt)
    else:
        p["w_uq"] = dense_init(ks[6], d, nh * qk, dt)
    return p


def init_mla_cache(cfg: ModelConfig, batch: int, max_len: int,
                   layers_dim: Tuple[int, ...] = ()):
    """MLA caches the compressed c_kv (kv_lora) + shared k_rope — 576 values
    per token instead of 2*H*hd = 32768; optionally MX-quantized.  The
    compressed cache has no separate K/V tensors, so it follows the
    ``kv_key`` role's spec."""
    dt = dtype_of(cfg)
    ckv = layers_dim + (batch, max_len, cfg.kv_lora)
    krs = layers_dim + (batch, max_len, cfg.qk_rope_dim)
    spec = cfg.mx.kv_key
    if spec is not None:
        cl = _code_len(cfg.kv_lora, spec.block)
        clr = _code_len(cfg.qk_rope_dim, spec.block)
        return {"ckv_codes": jnp.zeros(
                    layers_dim + (batch, max_len, cl), jnp.uint8),
                "ckv_scales": jnp.zeros(
                    layers_dim + (batch, max_len, cl // spec.block),
                    jnp.uint8),
                "kr_codes": jnp.zeros(
                    layers_dim + (batch, max_len, clr), jnp.uint8),
                "kr_scales": jnp.zeros(
                    layers_dim + (batch, max_len, clr // spec.block),
                    jnp.uint8)}
    return {"ckv": jnp.zeros(ckv, dt), "kr": jnp.zeros(krs, dt)}


def _q_heads(p, x, cfg, fake_quant):
    b, s, _ = x.shape
    nh = cfg.n_heads
    qk = cfg.qk_nope_dim + cfg.qk_rope_dim
    mx = cfg.mx
    if cfg.q_lora:
        cq = dense(x, p["w_dq"], mx, fake_quant)
        cq = rms_norm(cq, p["q_norm"], cfg.norm_eps)
        q = dense(cq, p["w_uq"], mx, fake_quant)
    else:
        q = dense(x, p["w_uq"], mx, fake_quant)
    q = logical(q, "batch", None, "model")
    return q.reshape(b, s, nh, qk)


def mla_attention(p: Params, x: jax.Array, cfg: ModelConfig, *,
                  positions: jax.Array, cache=None, cache_pos=None,
                  fake_quant: bool = False) -> Tuple[jax.Array, Any]:
    """Full (train/prefill) path: materialize per-head k/v from c_kv."""
    b, s, d = x.shape
    nh, dn, dr, dv = (cfg.n_heads, cfg.qk_nope_dim, cfg.qk_rope_dim,
                      cfg.v_head_dim)
    mx = cfg.mx
    q = _q_heads(p, x, cfg, fake_quant)
    qn, qr = q[..., :dn], q[..., dn:]
    ckv = dense(x, p["w_dkv"], mx, fake_quant)
    ckv = rms_norm(ckv, p["kv_norm"], cfg.norm_eps)
    kr = dense(x, p["w_kr"], mx, fake_quant).reshape(b, s, 1, dr)
    cos, sin = rope_tables(positions, dr, cfg.rope_theta)
    qr = apply_rope(qr, cos, sin)
    kr = apply_rope(kr, cos, sin)
    kn = dense(ckv, p["w_uk"], mx, fake_quant).reshape(b, s, nh, dn)
    v = dense(ckv, p["w_uv"], mx, fake_quant).reshape(b, s, nh, dv)
    scale = 1.0 / np.sqrt(dn + dr)
    scores = (jnp.einsum("bqhd,bkhd->bhqk", qn, kn,
                         preferred_element_type=jnp.float32)
              + jnp.einsum("bqhd,bkd->bhqk", qr, kr[:, :, 0, :],
                           preferred_element_type=jnp.float32)) * scale
    qpos = jnp.arange(s)
    kpos = jnp.arange(s)
    mask = kpos[None, None, None, :] <= qpos[None, None, :, None]
    scores = jnp.where(mask, scores, -1e30)
    probs = softmax_f32(scores).astype(x.dtype)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs, v,
                     preferred_element_type=jnp.float32).astype(x.dtype)
    out = dense(out.reshape(b, s, nh * dv), p["wo"], mx, fake_quant,
                tp="row")
    new_cache = cache
    if cache is not None:
        new_cache = _mla_cache_write(cache, ckv, kr[:, :, 0, :], cache_pos
                                     if cache_pos is not None else 0, cfg)
    return logical(out, "batch", None, None), new_cache


def _mla_cache_write(cache, ckv, kr, pos, cfg):
    ckv = logical(ckv, "kv_batch", None, None)
    kr = logical(kr, "kv_batch", None, None)
    if cfg.mx.kv_key is not None:
        cc, cs = _kv_quant(ckv, cfg.mx.kv_key)
        kc, kss = _kv_quant(kr, cfg.mx.kv_key)
        out = {}
        for name, val in dict(ckv_codes=cc, ckv_scales=cs, kr_codes=kc,
                              kr_scales=kss).items():
            tgt = cache[name]
            idx = (0, pos) + (0,) * (tgt.ndim - 2)
            out[name] = jax.lax.dynamic_update_slice(tgt, val, idx)
        return out
    return {"ckv": jax.lax.dynamic_update_slice(
                cache["ckv"], ckv.astype(cache["ckv"].dtype), (0, pos, 0)),
            "kr": jax.lax.dynamic_update_slice(
                cache["kr"], kr.astype(cache["kr"].dtype), (0, pos, 0))}


def _mla_cache_read(cache, cfg, dtype):
    if cfg.mx.kv_key is not None:
        ckv = _kv_dequant(cache["ckv_codes"], cache["ckv_scales"],
                          cfg.mx.kv_key, dtype, cfg.kv_lora)
        kr = _kv_dequant(cache["kr_codes"], cache["kr_scales"],
                         cfg.mx.kv_key, dtype, cfg.qk_rope_dim)
        return ckv, kr
    return cache["ckv"].astype(dtype), cache["kr"].astype(dtype)


def mla_decode(p: Params, x: jax.Array, cfg: ModelConfig, *,
               cache, cache_pos, fake_quant: bool = False
               ) -> Tuple[jax.Array, Any]:
    """Absorbed MLA decode: scores/outputs computed against the compressed
    cache directly (never materializes per-head K/V for past tokens)."""
    b, s, d = x.shape                      # s == 1
    nh, dn, dr, dv = (cfg.n_heads, cfg.qk_nope_dim, cfg.qk_rope_dim,
                      cfg.v_head_dim)
    mx = cfg.mx
    q = _q_heads(p, x, cfg, fake_quant)
    qn, qr = q[..., :dn], q[..., dn:]
    ckv_new = dense(x, p["w_dkv"], mx, fake_quant)
    ckv_new = rms_norm(ckv_new, p["kv_norm"], cfg.norm_eps)
    kr_new = dense(x, p["w_kr"], mx, fake_quant)
    pos = jnp.full((b, s), cache_pos)
    cos, sin = rope_tables(pos, dr, cfg.rope_theta)
    qr = apply_rope(qr, cos, sin)
    kr_new = apply_rope(kr_new.reshape(b, s, 1, dr), cos, sin)[:, :, 0, :]
    cache = _mla_cache_write(cache, ckv_new, kr_new, cache_pos, cfg)
    ckv, kr = _mla_cache_read(cache, cfg, x.dtype)      # (B,S,L), (B,S,dr)
    # absorb W_uk into q:  q_c[b,h,l] = sum_d qn[b,h,d] * W_uk[l, h, d]
    wuk = p["w_uk"].astype(x.dtype).reshape(cfg.kv_lora, nh, dn)
    qc = jnp.einsum("bqhd,lhd->bqhl", qn, wuk,
                    preferred_element_type=jnp.float32).astype(x.dtype)
    scale = 1.0 / np.sqrt(dn + dr)
    scores = (jnp.einsum("bqhl,bkl->bhqk", qc, ckv,
                         preferred_element_type=jnp.float32)
              + jnp.einsum("bqhd,bkd->bhqk", qr, kr,
                           preferred_element_type=jnp.float32)) * scale
    kpos = jnp.arange(ckv.shape[1])
    mask = kpos[None, None, None, :] <= cache_pos
    scores = jnp.where(mask, scores, -1e30)
    probs = softmax_f32(scores).astype(x.dtype)
    ctx = jnp.einsum("bhqk,bkl->bqhl", probs, ckv,
                     preferred_element_type=jnp.float32).astype(x.dtype)
    wuv = p["w_uv"].astype(x.dtype).reshape(cfg.kv_lora, nh, dv)
    out = jnp.einsum("bqhl,lhd->bqhd", ctx, wuv,
                     preferred_element_type=jnp.float32).astype(x.dtype)
    out = dense(out.reshape(b, s, nh * dv), p["wo"], cfg.mx,
                fake_quant, tp="row")
    return logical(out, "batch", None, None), cache


# =============================================================================
# MLP / MoE
# =============================================================================
def mlp_init(key, cfg: ModelConfig, d_ff: Optional[int] = None) -> Params:
    d, ff = cfg.d_model, d_ff or cfg.d_ff
    dt = dtype_of(cfg)
    ks = jax.random.split(key, 3)
    if cfg.gated_mlp:
        return {"w1": dense_init(ks[0], d, ff, dt),
                "w3": dense_init(ks[1], d, ff, dt),
                "w2": dense_init(ks[2], ff, d, dt)}
    return {"w1": dense_init(ks[0], d, ff, dt),
            "w2": dense_init(ks[2], ff, d, dt)}


def mlp(p: Params, x: jax.Array, cfg: ModelConfig,
        fake_quant: bool = False) -> jax.Array:
    mx = cfg.mx
    h = dense(x, p["w1"], mx, fake_quant)
    if cfg.gated_mlp:
        g = dense(x, p["w3"], mx, fake_quant)
        h = jax.nn.silu(h.astype(jnp.float32)).astype(x.dtype) * g
    else:
        h = jax.nn.gelu(h.astype(jnp.float32)).astype(x.dtype)
    h = logical(h, "batch", None, "model")
    out = dense(h, p["w2"], mx, fake_quant, tp="row")
    return logical(out, "batch", None, None)


def moe_init(key, cfg: ModelConfig) -> Params:
    d, ff, e = cfg.d_model, cfg.moe_d_ff, cfg.n_experts
    dt = dtype_of(cfg)
    ks = jax.random.split(key, 5)
    std = 1.0 / np.sqrt(d)
    p = {
        "router": dense_init(ks[0], d, e, jnp.float32),
        "experts": {
            "w1": (jax.random.normal(ks[1], (e, d, ff), jnp.float32) * std
                   ).astype(dt),
            "w3": (jax.random.normal(ks[2], (e, d, ff), jnp.float32) * std
                   ).astype(dt),
            "w2": (jax.random.normal(ks[3], (e, ff, d), jnp.float32)
                   / np.sqrt(ff)).astype(dt),
        },
    }
    if cfg.n_shared_experts:
        p["shared"] = mlp_init(ks[4], dataclasses.replace(
            cfg, gated_mlp=True),
            d_ff=cfg.moe_d_ff * cfg.n_shared_experts)
    return p


GROUP_SIZE = 256   # dispatch group size (GShard-style capacity routing)


def moe(p: Params, x: jax.Array, cfg: ModelConfig,
        fake_quant: bool = False) -> Tuple[jax.Array, jax.Array]:
    """Capacity-factor top-k MoE; returns (out, aux_loss).

    Tokens are grouped (G, gs); dispatch/combine are one-hot einsums that
    lower to all-to-alls when experts are sharded over "model"."""
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.moe_topk
    mx = cfg.mx
    n_tok = b * s
    gs = min(GROUP_SIZE, n_tok)
    g = n_tok // gs
    xt = x.reshape(g, gs, d)
    cap = max(1, int(gs * k / e * cfg.capacity_factor))
    logits = jnp.einsum("gsd,de->gse", xt.astype(jnp.float32),
                        p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    topw, topi = jax.lax.top_k(probs, k)                 # (g, gs, k)
    topw = topw / (jnp.sum(topw, -1, keepdims=True) + 1e-9)
    # position of each (token, choice) in its expert's capacity buffer
    onehot = jax.nn.one_hot(topi, e, dtype=jnp.float32)  # (g, gs, k, e)
    pos_in_e = (jnp.cumsum(onehot.reshape(g, gs * k, e), axis=1)
                .reshape(g, gs, k, e) - 1.0) * onehot
    keep = (pos_in_e < cap) & (onehot > 0)
    posq = jnp.clip(pos_in_e, 0, cap - 1).astype(jnp.int32)
    # (g, gs, k, e, cap): each (token, choice) hits exactly one (e, slot)
    capoh = jax.nn.one_hot(posq, cap, dtype=x.dtype) \
        * keep.astype(x.dtype)[..., None]
    disp = jnp.sum(capoh, axis=2)                        # (g, gs, e, cap)
    comb = jnp.einsum("gsk,gskec->gsec", topw.astype(x.dtype), capoh)
    xe = jnp.einsum("gsec,gsd->gecd", disp, xt)          # (g, e, cap, d)
    xe = logical(xe, "batch", "model", None, None)
    we = p["experts"]

    def act_q(t):
        # activations-role QAT covers the expert matmul inputs too (the
        # dense()/mlp() paths handle their own inputs)
        if fake_quant and mx.activations is not None:
            return quantize_dequantize(t.astype(jnp.float32),
                                       mx.activations,
                                       axis=-1).astype(t.dtype)
        return t

    xe = act_q(xe)

    def exp_mm(t, w, sub):
        if isinstance(w, MXWeight):
            # weight-resident experts: per-expert fused dequant-in-VMEM
            # matmuls (codes stay packed in HBM); einsum fallback
            # materializes the f32 expert stack
            if weight_gather_enabled():
                w = dataclasses.replace(
                    w, codes=logical(w.codes, "model", None, None),
                    scales=logical(w.scales, "model", None, None))
            from repro.kernels.backend import resolve_matmul_impl
            if resolve_matmul_impl() == "fused":
                from repro.kernels.ops import mx_matmul_resident
                cols = [mx_matmul_resident(t[:, i], w.take(i))
                        for i in range(t.shape[1])]
                return jnp.stack(cols, axis=1).astype(t.dtype)
            wd = w.dequantize().astype(t.dtype)
            return jnp.einsum(sub, t, wd,
                              preferred_element_type=jnp.float32
                              ).astype(t.dtype)
        if weight_gather_enabled():
            w = logical(w, "model", None, None)  # EP on E; gather FSDP dim
        if fake_quant and mx.weights is not None:
            w = quantize_dequantize(w.astype(jnp.float32), mx.weights,
                                    axis=1).astype(t.dtype)
        return jnp.einsum(sub, t, w.astype(t.dtype),
                          preferred_element_type=jnp.float32).astype(t.dtype)

    h = exp_mm(xe, we["w1"], "gecd,edf->gecf")
    gte = exp_mm(xe, we["w3"], "gecd,edf->gecf")
    h = jax.nn.silu(h.astype(jnp.float32)).astype(x.dtype) * gte
    h = act_q(h)
    ye = exp_mm(h, we["w2"], "gecf,efd->gecd")
    out = jnp.einsum("gsec,gecd->gsd", comb, ye).reshape(b, s, d)
    if cfg.n_shared_experts:
        out = out + mlp(p["shared"], x, cfg, fake_quant)
    # load-balance aux loss (Switch): e * sum_e f_e * P_e
    f_e = jnp.mean(jnp.sum(onehot, axis=2), axis=(0, 1))
    p_e = jnp.mean(probs, axis=(0, 1))
    aux = e * jnp.sum(f_e * p_e) / k
    return logical(out, "batch", None, None), aux
