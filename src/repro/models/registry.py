"""Architecture registry: name -> (config, model driver, input specs)."""
from __future__ import annotations

import importlib
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.models import decoder, encdec, hybrid, rwkv_model
from repro.models.config import ModelConfig, ShapeSpec

ARCH_IDS = (
    "internvl2_76b", "seamless_m4t_medium", "chatglm3_6b", "yi_34b",
    "deepseek_67b", "glm4_9b", "zamba2_1p2b", "deepseek_v2_236b",
    "moonshot_v1_16b_a3b", "rwkv6_7b",
)

_FAMILY = {"decoder": decoder, "encdec": encdec, "hybrid": hybrid,
           "rwkv": rwkv_model}


def load_config(arch: str, **overrides) -> ModelConfig:
    arch = arch.replace("-", "_").replace(".", "p")
    mod = importlib.import_module(f"repro.configs.{arch}")
    cfg = mod.CONFIG
    if overrides:
        import dataclasses
        cfg = dataclasses.replace(cfg, **overrides)
    return cfg


def load_reduced(arch: str, **overrides) -> ModelConfig:
    """Reduced config for CPU smoke tests.  Defaults to f32 compute: the CPU
    XLA DotThunk cannot execute some bf16xbf16->f32 contractions (MLA); the
    full configs stay bf16 (TPU target, exercised via lowering-only)."""
    arch = arch.replace("-", "_").replace(".", "p")
    mod = importlib.import_module(f"repro.configs.{arch}")
    cfg = mod.reduced()
    over = {"dtype": "float32", "param_dtype": "float32"}
    over.update(overrides)
    import dataclasses
    return dataclasses.replace(cfg, **over)


def family_module(cfg: ModelConfig):
    return _FAMILY[cfg.family]


class Model:
    """Thin functional wrapper: one uniform interface over all families."""

    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.mod = family_module(cfg)

    def init(self, key):
        return self.mod.init(key, self.cfg)

    def forward(self, params, batch: Dict[str, jax.Array], *,
                fake_quant: bool = False):
        cfg = self.cfg
        if cfg.family == "encdec":
            return self.mod.forward(params, batch["frames"], batch["tokens"],
                                    cfg, fake_quant=fake_quant)
        if cfg.family == "decoder":
            return self.mod.forward(params, batch["tokens"], cfg,
                                    prefix_embeds=batch.get("prefix_embeds"),
                                    fake_quant=fake_quant)
        return self.mod.forward(params, batch["tokens"], cfg,
                                fake_quant=fake_quant)

    def prefill(self, params, batch, *, max_len: int,
                fake_quant: bool = False):
        cfg = self.cfg
        if cfg.family == "encdec":
            return self.mod.prefill(params, batch["frames"],
                                    batch["tokens"], cfg, max_len=max_len,
                                    fake_quant=fake_quant)
        if cfg.family == "decoder":
            return self.mod.prefill(params, batch["tokens"], cfg,
                                    max_len=max_len,
                                    prefix_embeds=batch.get("prefix_embeds"),
                                    fake_quant=fake_quant)
        return self.mod.prefill(params, batch["tokens"], cfg,
                                max_len=max_len, fake_quant=fake_quant)

    def decode_step(self, params, token, cache, pos, *,
                    fake_quant: bool = False):
        return self.mod.decode_step(params, token, cache, pos, self.cfg,
                                    fake_quant=fake_quant)

    def quantize_weights(self, params):
        """Convert matmul weights to weight-resident MXWeight storage per
        the policy's ``weights`` role (decoder family; see
        decoder.quantize_weights).  Serve the result as-is — ``dense()``
        routes MXWeight operands through the fused dequant-in-VMEM
        matmul kernel."""
        cfg = self.cfg
        if cfg.family != "decoder":
            raise NotImplementedError(
                f"{cfg.name}: weight-resident storage covers the decoder "
                "family")
        return self.mod.quantize_weights(params, cfg)

    def forward_calib(self, params, batch: Dict[str, jax.Array]):
        """Instrumented forward for repro.calib: (logits, aux, taps) with
        per-layer activation / kv_key / kv_value tensors (GQA decoder
        family only — see decoder.forward_calib)."""
        cfg = self.cfg
        if cfg.family != "decoder":
            raise NotImplementedError(
                f"{cfg.name}: calibration taps cover the decoder family")
        return self.mod.forward_calib(
            params, batch["tokens"], cfg,
            prefix_embeds=batch.get("prefix_embeds"))

    def init_cache(self, batch: int, max_len: int, s_enc: int = 0):
        cfg = self.cfg
        if cfg.family == "encdec":
            return self.mod.init_cache(cfg, batch, max_len, s_enc)
        return self.mod.init_cache(cfg, batch, max_len)

    # ---- paged serving (continuous batching; GQA decoder family) ---------
    def supports_paged(self) -> bool:
        cfg = self.cfg
        return (cfg.family == "decoder" and not cfg.mla
                and cfg.frontend == "none")

    def init_paged_cache(self, num_pages: int, page_size: int):
        if not self.supports_paged():
            raise NotImplementedError(
                f"{self.cfg.name}: paged KV serving needs a GQA decoder")
        return self.mod.init_paged_cache(self.cfg, num_pages, page_size)

    def paged_decode_step(self, params, token, cache, block_tables,
                          lengths, *, fake_quant: bool = False):
        return self.mod.paged_decode_step(params, token, cache,
                                          block_tables, lengths, self.cfg,
                                          fake_quant=fake_quant)

    def paged_decode_multi_step(self, params, token, cache, block_tables,
                                lengths, remaining, keys, *, n_steps: int,
                                temperature: float = 0.0,
                                trash_page: int = 0,
                                fake_quant: bool = False,
                                health: bool = False):
        """``n_steps`` fused decode steps in one lax.scan (device-resident
        sampling; see decoder.paged_decode_multi_step).  ``health=True``
        appends a (B,) non-finite-logits flag to the return tuple."""
        return self.mod.paged_decode_multi_step(
            params, token, cache, block_tables, lengths, remaining, keys,
            self.cfg, n_steps=n_steps, temperature=temperature,
            trash_page=trash_page, fake_quant=fake_quant, health=health)

    def scatter_prefill(self, pool, cache, page_ids):
        """Scatter a batched contiguous prefill cache into the page pool."""
        return self.mod.scatter_prefill(self.cfg, pool, cache, page_ids)

    def paged_prefill_suffix(self, params, tokens, starts, prompt_lens,
                             pool, block_tables, *,
                             fake_quant: bool = False):
        """Prefill only the uncached suffix of G prompts over the paged
        pool (prefix sharing; see decoder.paged_prefill_suffix)."""
        return self.mod.paged_prefill_suffix(
            params, tokens, starts, prompt_lens, pool, block_tables,
            self.cfg, fake_quant=fake_quant)

    def copy_pool_pages(self, pool, src, dst):
        """Copy page contents src[i] -> dst[i] in every pool leaf (COW)."""
        return self.mod.copy_pool_pages(pool, src, dst)


# =============================================================================
# input specs (ShapeDtypeStruct stand-ins — no allocation; dry-run food)
# =============================================================================
def batch_specs(cfg: ModelConfig, shape: ShapeSpec) -> Dict[str, Any]:
    """Training/prefill batch specs for one (arch x shape) cell."""
    b, s = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    if cfg.family == "encdec":
        se, sd = s // 2, s // 2
        return {
            "frames": jax.ShapeDtypeStruct((b, se, cfg.d_model),
                                           jnp.bfloat16),
            "tokens": jax.ShapeDtypeStruct((b, sd), i32),
            "labels": jax.ShapeDtypeStruct((b, sd), i32),
        }
    if cfg.frontend == "patch" and cfg.prefix_len:
        st = s - cfg.prefix_len
        return {
            "prefix_embeds": jax.ShapeDtypeStruct(
                (b, cfg.prefix_len, cfg.d_model), jnp.bfloat16),
            "tokens": jax.ShapeDtypeStruct((b, st), i32),
            "labels": jax.ShapeDtypeStruct((b, st + cfg.prefix_len), i32),
        }
    return {"tokens": jax.ShapeDtypeStruct((b, s), i32),
            "labels": jax.ShapeDtypeStruct((b, s), i32)}


def decode_specs(cfg: ModelConfig, shape: ShapeSpec) -> Dict[str, Any]:
    """Decode-step specs: one new token against a seq_len-deep cache."""
    b, s = shape.global_batch, shape.seq_len
    model = Model(cfg)
    s_enc = s // 2 if cfg.family == "encdec" else 0
    max_len = s // 2 if cfg.family == "encdec" else s
    cache = jax.eval_shape(
        lambda: model.init_cache(b, max_len, s_enc))
    return {
        "token": jax.ShapeDtypeStruct((b,), jnp.int32),
        "cache": cache,
        "pos": jax.ShapeDtypeStruct((), jnp.int32),
    }


def make_concrete_batch(cfg: ModelConfig, b: int, s: int, key=None):
    """Small real batch for smoke tests."""
    key = key if key is not None else jax.random.PRNGKey(0)
    k1, k2, k3 = jax.random.split(key, 3)
    if cfg.family == "encdec":
        se, sd = max(2, s // 2), max(2, s // 2)
        return {
            "frames": jax.random.normal(k1, (b, se, cfg.d_model),
                                        jnp.float32).astype(jnp.bfloat16),
            "tokens": jax.random.randint(k2, (b, sd), 0, cfg.vocab),
            "labels": jax.random.randint(k3, (b, sd), 0, cfg.vocab),
        }
    if cfg.frontend == "patch" and cfg.prefix_len:
        st = max(2, s - cfg.prefix_len)
        return {
            "prefix_embeds": jax.random.normal(
                k1, (b, cfg.prefix_len, cfg.d_model), jnp.float32
            ).astype(jnp.bfloat16),
            "tokens": jax.random.randint(k2, (b, st), 0, cfg.vocab),
            "labels": jax.random.randint(k3, (b, st + cfg.prefix_len), 0,
                                         cfg.vocab),
        }
    return {"tokens": jax.random.randint(k2, (b, s), 0, cfg.vocab),
            "labels": jax.random.randint(k3, (b, s), 0, cfg.vocab)}
