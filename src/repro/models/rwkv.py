"""RWKV6 ("Finch") block — data-dependent per-channel decay linear attention.

Time-mix recurrence per head (d_k = d_v = 64):
    S_t = diag(w_t) S_{t-1} + k_t^T v_t          w_t in (0,1), data-dependent
    o_t = r_t (S_{t-1} + diag(u) k_t^T v_t)
Training/prefill uses a chunked form (chunk=16, matmul-heavy); the exponent
factorization is kept stable by clamping log w at -5 per step (documented —
decode uses the exact recurrence with no clamp).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.dist.sharding import logical
from repro.models.config import ModelConfig
from repro.models.layers import dense, dense_init, dtype_of

HEAD_DIM = 64
CHUNK = 16
LOGW_MIN = -5.0
DECAY_LORA = 64


def rwkv_init(key, cfg: ModelConfig) -> Dict[str, Any]:
    d = cfg.d_model
    nh = d // HEAD_DIM
    ks = jax.random.split(key, 12)
    dt = dtype_of(cfg)
    return {
        "tmix": {
            "mu": (0.5 * jnp.ones((5, d), jnp.float32)).astype(dt),
            "wr": dense_init(ks[0], d, d, dt),
            "wk": dense_init(ks[1], d, d, dt),
            "wv": dense_init(ks[2], d, d, dt),
            "wg": dense_init(ks[3], d, d, dt),
            "w0": jnp.full((d,), -1.5, jnp.float32),
            "w_a": dense_init(ks[4], d, DECAY_LORA, dt),
            "w_b": dense_init(ks[5], DECAY_LORA, d, dt),
            "u": (jax.random.normal(ks[6], (nh, HEAD_DIM), jnp.float32)
                  * 0.3).astype(jnp.float32),
            "ln_w": jnp.ones((d,), dt),
            "wo": dense_init(ks[7], d, d, dt),
        },
        "cmix": {
            "mu": (0.5 * jnp.ones((2, d), jnp.float32)).astype(dt),
            "wk": dense_init(ks[8], d, cfg.d_ff, dt),
            "wv": dense_init(ks[9], cfg.d_ff, d, dt),
            "wr": dense_init(ks[10], d, d, dt),
        },
    }


def _token_shift(x: jax.Array, prev: Optional[jax.Array] = None):
    """x_{t-1} along seq; ``prev`` is the last token of the previous segment
    (decode), zeros otherwise."""
    if prev is None:
        prev = jnp.zeros_like(x[:, :1])
    return jnp.concatenate([prev, x[:, :-1]], axis=1)


def _decay(p, xw: jax.Array) -> jax.Array:
    """log w_t (<= 0), data-dependent (LoRA), clamped for chunk stability."""
    lora = dense(jnp.tanh(dense(xw, p["w_a"]).astype(jnp.float32))
                 .astype(xw.dtype), p["w_b"])
    logw = -jnp.exp(p["w0"][None, None, :].astype(jnp.float32)
                    + lora.astype(jnp.float32))
    return jnp.clip(logw, LOGW_MIN, -1e-5)


def _wkv_chunked(r, k, v, logw, u, chunk: int):
    """Chunked RWKV6 linear attention.

    r,k,v (B,S,H,D); logw (B,S,H,D) per-channel log decay; u (H,D) bonus.
    Returns y (B,S,H,D), final state (B,H,D,D) [k-dim x v-dim].
    """
    b, s, h, dd = r.shape
    nc = s // chunk
    rc = r.reshape(b, nc, chunk, h, dd).astype(jnp.float32)
    kc = k.reshape(b, nc, chunk, h, dd).astype(jnp.float32)
    vc = v.reshape(b, nc, chunk, h, dd).astype(jnp.float32)
    lw = logw.reshape(b, nc, chunk, h, dd)
    lcum = jnp.cumsum(lw, axis=2)                        # inclusive
    lexc = lcum - lw                                     # exclusive
    # factored intra-chunk: A[t,s] = sum_d r_t e^{lexc_t} * k_s e^{-lcum_s}
    #   valid for s < t;   |exponents| <= chunk * |LOGW_MIN| = 80 < 88 (f32)
    r_dec = rc * jnp.exp(lexc)
    k_dec = kc * jnp.exp(-lcum)
    amat = jnp.einsum("bcthd,bcshd->bchts", r_dec, k_dec,
                      preferred_element_type=jnp.float32)
    mask = jnp.tril(jnp.ones((chunk, chunk), bool), k=-1)    # strictly past
    amat = amat * mask[None, None, None, :, :]
    diag = jnp.einsum("bcthd,hd,bcthd->bcth", rc, u, kc)
    y = jnp.einsum("bchts,bcshd->bcthd", amat, vc) \
        + diag[..., None] * vc
    # inter-chunk
    lend = lcum[:, :, -1]                                # (b,c,h,d)
    kin = kc * jnp.exp(lend[:, :, None] - lcum)           # decay s -> end
    state_in = jnp.einsum("bcshd,bcshe->bchde", kin, vc)  # (b,c,h,dk,dv)

    def step(st, inp):
        s_in, le = inp
        new = st * jnp.exp(le)[..., None] + s_in
        return new, st

    s0 = jnp.zeros((b, h, dd, dd), jnp.float32)
    # unrolled for exact HLO cost accounting (see ssm.py note)
    final, prev = jax.lax.scan(
        step, s0, (jnp.moveaxis(state_in, 1, 0), jnp.moveaxis(lend, 1, 0)),
        unroll=True if nc <= 64 else 64)
    prev = jnp.moveaxis(prev, 0, 1)                      # (b,c,h,dk,dv)
    y = y + jnp.einsum("bcthd,bchde->bcthe", r_dec, prev)
    return y.reshape(b, s, h, dd), final


def _group_norm(x: jax.Array, w: jax.Array, eps: float = 64e-5):
    """Per-head LayerNorm on (B,S,H,D) flattened to (B,S,H*D)."""
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    b, s, h, dd = x.shape
    return (y.reshape(b, s, h * dd) * w.astype(jnp.float32)[None, None, :])


def rwkv_time_mix(p, x: jax.Array, cfg: ModelConfig, *,
                  prev_token=None, state=None, fake_quant: bool = False):
    """Returns (out, (last_token, new_state)).  Full-seq when state is None
    begins from zero state; decode passes (B,1,d) with carried state."""
    b, s, d = x.shape
    nh = d // HEAD_DIM
    mxp = cfg.mx
    xx = _token_shift(x, prev_token)
    mu = p["mu"].astype(x.dtype)
    xr, xk, xv, xw, xg = [x + mu[i][None, None, :] * (xx - x)
                          for i in range(5)]
    r = dense(xr, p["wr"], mxp, fake_quant).reshape(b, s, nh, HEAD_DIM)
    k = dense(xk, p["wk"], mxp, fake_quant).reshape(b, s, nh, HEAD_DIM)
    v = dense(xv, p["wv"], mxp, fake_quant).reshape(b, s, nh, HEAD_DIM)
    g = dense(xg, p["wg"], mxp, fake_quant)
    logw = _decay(p, xw).reshape(b, s, nh, HEAD_DIM)
    u = p["u"]
    if s == 1 and state is not None:
        # exact single-step recurrence
        rf, kf, vf = (t.astype(jnp.float32)[:, 0] for t in (r, k, v))
        wt = jnp.exp(logw.astype(jnp.float32))[:, 0]      # (B,H,D)
        kv = jnp.einsum("bhd,bhe->bhde", kf, vf)
        y = jnp.einsum("bhd,bhde->bhe", rf, state + u[None, :, :, None] * kv)
        new_state = state * wt[..., None] + kv
        y = y[:, None]                                    # (B,1,H,Dv)
        y = y.reshape(b, 1, nh, HEAD_DIM)
    else:
        chunk = min(CHUNK, s)
        pad = (-s) % chunk
        rp, kp, vp, lp = (jnp.pad(t, ((0, 0), (0, pad), (0, 0), (0, 0)))
                          for t in (r, k, v, logw))
        if pad:
            lp = lp.at[:, s:].set(-1e-5)
        y, new_state = _wkv_chunked(rp, kp, vp, lp, u, chunk)
        y = y[:, :s]
    y = _group_norm(y, p["ln_w"])
    y = (y * jax.nn.silu(g.astype(jnp.float32))).astype(x.dtype)
    out = dense(y, p["wo"], mxp, fake_quant, tp="row")
    return logical(out, "batch", None, None), (x[:, -1:], new_state)


def rwkv_channel_mix(p, x: jax.Array, cfg: ModelConfig, *,
                     prev_token=None, fake_quant: bool = False):
    mxp = cfg.mx
    xx = _token_shift(x, prev_token)
    mu = p["mu"].astype(x.dtype)
    xk = x + mu[0][None, None, :] * (xx - x)
    xr = x + mu[1][None, None, :] * (xx - x)
    k = dense(xk, p["wk"], mxp, fake_quant)
    k = jnp.square(jax.nn.relu(k.astype(jnp.float32))).astype(x.dtype)
    k = logical(k, "batch", None, "model")
    v = dense(k, p["wv"], mxp, fake_quant, tp="row")
    rgate = jax.nn.sigmoid(dense(xr, p["wr"], mxp, fake_quant)
                           .astype(jnp.float32)).astype(x.dtype)
    return logical(v * rgate, "batch", None, None), x[:, -1:]


def rwkv_init_state(cfg: ModelConfig, batch: int,
                    layers_dim: Tuple[int, ...] = ()):
    d = cfg.d_model
    nh = d // HEAD_DIM
    return {
        "tmix_state": jnp.zeros(layers_dim + (batch, nh, HEAD_DIM, HEAD_DIM),
                                jnp.float32),
        "tmix_prev": jnp.zeros(layers_dim + (batch, 1, d), dtype_of(cfg)),
        "cmix_prev": jnp.zeros(layers_dim + (batch, 1, d), dtype_of(cfg)),
    }
