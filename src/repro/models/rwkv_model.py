"""RWKV6 decoder-only LM driver (attention-free, recurrent-state decode)."""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.dist.sharding import logical
from repro.models import layers as L
from repro.models import rwkv
from repro.models.config import ModelConfig
from repro.models.decoder import padded_vocab


def _layer_norm(x, w, eps=1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, -1, keepdims=True)
    var = jnp.var(xf, -1, keepdims=True)
    return ((xf - mu) * jax.lax.rsqrt(var + eps)
            * w.astype(jnp.float32)).astype(x.dtype)


def _block_init(key, cfg):
    d = cfg.d_model
    dt = L.dtype_of(cfg)
    p = rwkv.rwkv_init(key, cfg)
    p["ln1"] = jnp.ones((d,), dt)
    p["ln2"] = jnp.ones((d,), dt)
    return p


def init(key, cfg: ModelConfig) -> Dict[str, Any]:
    vp = padded_vocab(cfg)
    d = cfg.d_model
    dt = L.dtype_of(cfg)
    ks = jax.random.split(key, 4)
    lkeys = jax.random.split(ks[0], cfg.n_layers)
    return {
        "embed": L.embed_init(ks[1], vp, d, dt),
        "ln_in": jnp.ones((d,), dt),
        "blocks": jax.vmap(lambda k: _block_init(k, cfg))(lkeys),
        "norm_f": jnp.ones((d,), dt),
        "lm_head": L.dense_init(ks[2], d, vp, dt),
    }


def _block(lp, x, cfg, *, state=None, fake_quant=False):
    """state None -> full sequence from zero state; else decode carry."""
    prev_t = state["tmix_prev"] if state is not None else None
    st = state["tmix_state"] if state is not None else None
    h = _layer_norm(x, lp["ln1"], cfg.norm_eps)
    a, (last_t, new_st) = rwkv.rwkv_time_mix(lp["tmix"], h, cfg,
                                             prev_token=prev_t, state=st,
                                             fake_quant=fake_quant)
    x = x + a
    prev_c = state["cmix_prev"] if state is not None else None
    h = _layer_norm(x, lp["ln2"], cfg.norm_eps)
    c, last_c = rwkv.rwkv_channel_mix(lp["cmix"], h, cfg, prev_token=prev_c,
                                      fake_quant=fake_quant)
    new_state = {"tmix_state": new_st, "tmix_prev": last_t,
                 "cmix_prev": last_c}
    return x + c, new_state


def forward(params, tokens, cfg: ModelConfig, *, fake_quant: bool = False
            ) -> Tuple[jax.Array, jax.Array]:
    x = jnp.take(params["embed"], tokens, axis=0).astype(L.dtype_of(cfg))
    x = logical(x, "batch", None, None)
    x = _layer_norm(x, params["ln_in"], cfg.norm_eps)

    def step(carry, lp):
        y, _ = _block(lp, carry, cfg, fake_quant=fake_quant)
        return y, None

    step_fn = jax.checkpoint(step) if cfg.remat else step
    x, _ = L.layer_scan(step_fn, x, params["blocks"], cfg)
    x = _layer_norm(x, params["norm_f"], cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", x,
                        params["lm_head"].astype(x.dtype),
                        preferred_element_type=jnp.float32)
    return logical(logits, "batch", None, "model"), jnp.zeros((),
                                                              jnp.float32)


def init_cache(cfg: ModelConfig, batch: int, max_len: int = 0):
    """max_len unused — RWKV state is O(1) in sequence length (that is the
    point of running long_500k on this family)."""
    return rwkv.rwkv_init_state(cfg, batch, layers_dim=(cfg.n_layers,))


def _run(params, cache, x, cfg, fake_quant):
    def step(carry, xs):
        lp, st = xs
        y, ns = _block(lp, carry, cfg, state=st, fake_quant=fake_quant)
        return y, ns

    x, new_cache = L.layer_scan(step, x, (params["blocks"], cache), cfg)
    return x, new_cache


def prefill(params, tokens, cfg: ModelConfig, *, max_len: int = 0,
            fake_quant: bool = False):
    x = jnp.take(params["embed"], tokens, axis=0).astype(L.dtype_of(cfg))
    b = x.shape[0]
    x = _layer_norm(x, params["ln_in"], cfg.norm_eps)
    cache = init_cache(cfg, b)
    x, cache = _run(params, cache, x, cfg, fake_quant)
    x = _layer_norm(x, params["norm_f"], cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", x,
                        params["lm_head"].astype(x.dtype),
                        preferred_element_type=jnp.float32)
    return logits, cache, tokens.shape[1]


def decode_step(params, token, cache, pos, cfg: ModelConfig,
                fake_quant: bool = False):
    x = jnp.take(params["embed"], token[:, None], axis=0
                 ).astype(L.dtype_of(cfg))
    x = _layer_norm(x, params["ln_in"], cfg.norm_eps)
    x, cache = _run(params, cache, x, cfg, fake_quant)
    x = _layer_norm(x, params["norm_f"], cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", x,
                        params["lm_head"].astype(x.dtype),
                        preferred_element_type=jnp.float32)
    return logits, cache
