"""Mamba2 (SSD) block — chunked-scan JAX implementation (zamba2 backbone).

The SSD recurrence per head h (scalar decay a_t, state S in R^{P x N}):
    S_t = a_t * S_{t-1} + dt_t * x_t (x) B_t          a_t = exp(-softplus(A) dt_t)
    y_t = C_t . S_t
is evaluated in chunks: intra-chunk via a masked (C x C) decay-weighted
attention matmul (MXU-friendly), inter-chunk via a lax.scan over chunk
states.  Decode keeps the exact recurrence (one step).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.dist.sharding import logical
from repro.models.config import ModelConfig
from repro.models.layers import dense, dense_init, dtype_of, rms_norm

HEAD_DIM = 64
CHUNK = 64


def mamba_init(key, cfg: ModelConfig) -> Dict[str, Any]:
    d = cfg.d_model
    din = cfg.ssm_expand * d
    n = cfg.ssm_state
    nh = din // HEAD_DIM
    conv_ch = din + 2 * n
    ks = jax.random.split(key, 6)
    dt = dtype_of(cfg)
    return {
        # fused in_proj: [z din | x din | B n | C n | dt nh]
        "in_proj": dense_init(ks[0], d, 2 * din + 2 * n + nh, dt),
        "conv_w": (jax.random.normal(ks[1], (cfg.d_conv, conv_ch),
                                     jnp.float32) * 0.2).astype(dt),
        "conv_b": jnp.zeros((conv_ch,), dt),
        "a_log": jnp.zeros((nh,), jnp.float32),          # softplus -> decay
        "dt_bias": jnp.full((nh,), -2.0, jnp.float32),
        "d_skip": jnp.ones((nh,), jnp.float32),
        "norm": jnp.ones((din,), dt),
        "out_proj": dense_init(ks[2], din, d, dt),
    }


def _causal_conv_full(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv1d, x (B,S,C), w (K,C)."""
    k = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(xp[:, i: i + x.shape[1], :] * w[i][None, None, :]
              for i in range(k))
    return out + b[None, None, :]


def _ssd_chunked(xh, bmat, cmat, la, chunk: int):
    """Chunked SSD scan.

    xh   (B,S,H,P)  dt-scaled inputs
    bmat (B,S,N), cmat (B,S,N)  shared across heads (n_groups=1)
    la   (B,S,H)    log decay per step (<= 0)
    returns y (B,S,H,P), final state (B,H,P,N)
    """
    bsz, s, h, p = xh.shape
    n = bmat.shape[-1]
    nc = s // chunk
    xc = xh.reshape(bsz, nc, chunk, h, p)
    bc = bmat.reshape(bsz, nc, chunk, n)
    cc = cmat.reshape(bsz, nc, chunk, n)
    lac = la.reshape(bsz, nc, chunk, h)
    lcum = jnp.cumsum(lac, axis=2)                       # inclusive
    # intra-chunk: y[t] += sum_{s<=t} exp(L_t - L_s) (C_t.B_s) xh_s
    g = jnp.einsum("bctn,bcsn->bcts", cc, bc,
                   preferred_element_type=jnp.float32)
    dmat = lcum[:, :, :, None, :] - lcum[:, :, None, :, :]   # (b,c,t,s,h)
    mask = jnp.tril(jnp.ones((chunk, chunk), bool))
    dmat = jnp.where(mask[None, None, :, :, None], dmat, -jnp.inf)
    w = jnp.exp(dmat) * g[..., None]                     # (b,c,t,s,h)
    y_intra = jnp.einsum("bctsh,bcshp->bcthp", w.astype(xh.dtype), xc,
                         preferred_element_type=jnp.float32)
    # inter-chunk state scan
    ldec_in = lcum[:, :, -1:, :] - lcum                  # decay s -> chunk end
    binp = jnp.einsum("bcsn,bcshp->bchpn",
                      bc, xc * jnp.exp(ldec_in).astype(xh.dtype)[..., None],
                      preferred_element_type=jnp.float32)  # (b,c,h,p,n)
    lend = lcum[:, :, -1, :]                             # (b,c,h)

    def step(state, inp):
        b_in, le = inp                                   # (b,h,p,n), (b,h)
        new = state * jnp.exp(le)[:, :, None, None] + b_in
        return new, state                                # emit state BEFORE

    s0 = jnp.zeros((bsz, h, p, n), jnp.float32)
    # unrolled: the inter-chunk state update is a tiny sequential einsum
    # chain; unrolling keeps HLO cost analysis exact (while-loop bodies are
    # counted once by XLA) and is how a TPU would execute it anyway.  For
    # very long sequences partial unroll bounds HLO size (the residual
    # undercount is <0.1% of layer FLOPs — see EXPERIMENTS.md §Dry-run).
    final, prev_states = jax.lax.scan(
        step, s0, (jnp.moveaxis(binp, 1, 0), jnp.moveaxis(lend, 1, 0)),
        unroll=True if nc <= 64 else 64)
    prev = jnp.moveaxis(prev_states, 0, 1)               # (b,c,h,p,n)
    y_inter = jnp.einsum("bctn,bchpn->bcthp", cc, prev.astype(xh.dtype),
                         preferred_element_type=jnp.float32) \
        * jnp.exp(lcum)[..., None]
    y = (y_intra + y_inter).reshape(bsz, s, h, p)
    return y.astype(xh.dtype), final


def _split_proj(zxbcdt, cfg: ModelConfig):
    d = cfg.d_model
    din = cfg.ssm_expand * d
    n = cfg.ssm_state
    nh = din // HEAD_DIM
    z = zxbcdt[..., :din]
    xs = zxbcdt[..., din: 2 * din]
    bmat = zxbcdt[..., 2 * din: 2 * din + n]
    cmat = zxbcdt[..., 2 * din + n: 2 * din + 2 * n]
    dt = zxbcdt[..., 2 * din + 2 * n:]
    return z, xs, bmat, cmat, dt


def mamba_block(p, x: jax.Array, cfg: ModelConfig, *,
                cache: Optional[Dict] = None, fake_quant: bool = False
                ) -> Tuple[jax.Array, Optional[Dict]]:
    """Full-sequence (train/prefill) Mamba2 block.  If ``cache`` is given it
    is filled with the final states (for subsequent decode)."""
    b, s, d = x.shape
    din = cfg.ssm_expand * d
    n = cfg.ssm_state
    nh = din // HEAD_DIM
    zxbcdt = dense(x, p["in_proj"], cfg.mx, fake_quant)
    z, xs, bmat, cmat, dtr = _split_proj(zxbcdt, cfg)
    conv_in = jnp.concatenate([xs, bmat, cmat], axis=-1)
    conv = _causal_conv_full(conv_in, p["conv_w"].astype(x.dtype),
                             p["conv_b"].astype(x.dtype))
    conv = jax.nn.silu(conv.astype(jnp.float32)).astype(x.dtype)
    xs, bmat, cmat = (conv[..., :din], conv[..., din:din + n],
                      conv[..., din + n:])
    dtv = jax.nn.softplus(dtr.astype(jnp.float32)
                          + p["dt_bias"][None, None, :])      # (B,S,H)
    la = -jnp.exp(p["a_log"])[None, None, :] * dtv             # log decay
    xh = xs.reshape(b, s, nh, HEAD_DIM)
    xh = logical(xh, "batch", None, "model", None)
    xdt = xh * dtv[..., None].astype(x.dtype)
    chunk = min(CHUNK, s)
    pad = (-s) % chunk
    if pad:
        xdt = jnp.pad(xdt, ((0, 0), (0, pad), (0, 0), (0, 0)))
        bmat = jnp.pad(bmat, ((0, 0), (0, pad), (0, 0)))
        cmat = jnp.pad(cmat, ((0, 0), (0, pad), (0, 0)))
        la = jnp.pad(la, ((0, 0), (0, pad), (0, 0)))
    y, final = _ssd_chunked(xdt, bmat, cmat, la, chunk)
    y = y[:, :s]
    y = y + xh * p["d_skip"][None, None, :, None].astype(x.dtype)
    y = y.reshape(b, s, din)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    y = rms_norm(y, p["norm"], cfg.norm_eps)
    out = dense(y, p["out_proj"], cfg.mx, fake_quant, tp="row")
    new_cache = None
    if cache is not None:
        conv_tail = jnp.pad(conv_in, ((0, 0), (max(0, cfg.d_conv - 1 - s), 0),
                                      (0, 0)))[:, -(cfg.d_conv - 1):, :]
        new_cache = {"ssm": final, "conv": conv_tail.astype(x.dtype)}
    return logical(out, "batch", None, None), new_cache


def mamba_init_cache(cfg: ModelConfig, batch: int,
                     layers_dim: Tuple[int, ...] = ()):
    d = cfg.d_model
    din = cfg.ssm_expand * d
    n = cfg.ssm_state
    nh = din // HEAD_DIM
    return {"ssm": jnp.zeros(layers_dim + (batch, nh, HEAD_DIM, n),
                             jnp.float32),
            "conv": jnp.zeros(layers_dim + (batch, cfg.d_conv - 1,
                                            din + 2 * n), dtype_of(cfg))}


def mamba_decode(p, x: jax.Array, cfg: ModelConfig, cache: Dict,
                 fake_quant: bool = False) -> Tuple[jax.Array, Dict]:
    """One-token decode with the exact recurrence. x: (B,1,d)."""
    b, s, d = x.shape
    assert s == 1
    din = cfg.ssm_expand * d
    n = cfg.ssm_state
    nh = din // HEAD_DIM
    zxbcdt = dense(x, p["in_proj"], cfg.mx, fake_quant)
    z, xs, bmat, cmat, dtr = _split_proj(zxbcdt, cfg)
    conv_in = jnp.concatenate([xs, bmat, cmat], axis=-1)     # (B,1,C)
    hist = jnp.concatenate([cache["conv"], conv_in], axis=1)  # (B,K,C)
    w = p["conv_w"].astype(x.dtype)
    conv = jnp.einsum("bkc,kc->bc", hist, w)[:, None, :] \
        + p["conv_b"][None, None, :].astype(x.dtype)
    conv = jax.nn.silu(conv.astype(jnp.float32)).astype(x.dtype)
    xs, bmat, cmat = (conv[..., :din], conv[..., din:din + n],
                      conv[..., din + n:])
    dtv = jax.nn.softplus(dtr.astype(jnp.float32)
                          + p["dt_bias"][None, None, :])[:, 0]   # (B,H)
    a = jnp.exp(-jnp.exp(p["a_log"])[None, :] * dtv)             # (B,H)
    xh = xs.reshape(b, nh, HEAD_DIM)
    xdt = (xh * dtv[..., None]).astype(jnp.float32)
    s_new = cache["ssm"] * a[:, :, None, None] \
        + jnp.einsum("bhp,bn->bhpn", xdt, bmat[:, 0].astype(jnp.float32))
    y = jnp.einsum("bn,bhpn->bhp", cmat[:, 0].astype(jnp.float32), s_new)
    y = y + xh.astype(jnp.float32) * p["d_skip"][None, :, None]
    y = y.reshape(b, 1, din).astype(x.dtype)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    y = rms_norm(y, p["norm"], cfg.norm_eps)
    out = dense(y, p["out_proj"], cfg.mx, fake_quant, tp="row")
    return out, {"ssm": s_new, "conv": hist[:, 1:, :]}
