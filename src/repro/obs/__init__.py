from repro.obs.metrics import (Counter, Gauge,  # noqa: F401
                               Histogram, MetricsRegistry, percentile,
                               rate)
from repro.obs.mxhealth import (sample_mx_health,  # noqa: F401
                                scale_stat_names)
from repro.obs.trace import (TRACE_SCHEMA, Tracer,  # noqa: F401
                             chrome_events, validate_nesting)
