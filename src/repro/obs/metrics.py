"""Typed metrics registry for the serving stack (dependency-free).

One :class:`MetricsRegistry` owns every counter, gauge, and histogram a
serving process maintains; the engine, scheduler, block manager, prefix
cache, swap store, and async front end all register their series here
instead of keeping ad-hoc ``self.n_*`` attributes.  The payoff is that
``registry.reset()`` restarts *every* measurement window at once — a new
counter can never again be silently missed by ``reset_metrics`` — and
``registry.snapshot()`` is the single structured view the launcher's
``--metrics-json`` and ``AsyncServer.obs_snapshot()`` export.

Three metric types, each holding labeled series (a series is keyed by
its sorted ``(label, value)`` pairs; the empty label set is a plain
scalar):

* :class:`Counter` — monotone accumulation (``inc``).  Values may be
  float (phase wall-clock seconds accumulate here too).  ``set`` exists
  solely for snapshot *restore* — rolling an engine back to a checkpoint
  legitimately rewinds its counters.
* :class:`Gauge` — last-write-wins level (``set``), with ``set_max`` for
  peak tracking.
* :class:`Histogram` — raw sample retention with nearest-rank
  percentile snapshots (:func:`percentile`) and a monotonic-clock
  ``time()`` context manager.

The helpers :func:`percentile` and :func:`rate` are the *single*
implementations of nearest-rank selection and zero-duration-safe
throughput used by the front end, the launcher, and ``bench_serve`` —
deduplicating the three hand-rolled guards that used to disagree at the
boundaries (an empty window raised IndexError in two of them).
"""
from __future__ import annotations

import math
import time
from contextlib import contextmanager
from typing import Dict, Iterator, List, Optional, Sequence, Tuple

LabelKey = Tuple[Tuple[str, str], ...]


def rate(count: float, seconds: float) -> float:
    """Throughput that tolerates degenerate windows: a zero-decode or
    zero-duration run (all-prefill workloads, ``--new-tokens 1``, warmup
    excision leaving an empty window) reports 0.0 instead of raising
    ZeroDivisionError in the reporter."""
    return count / seconds if seconds > 0 else 0.0


def percentile(samples: Sequence[float], q: float) -> float:
    """Nearest-rank percentile (no interpolation): the ceil(q/100 * n)-th
    smallest sample.  Exactly reproducible from the raw records by the
    dependency-free bench validator — that is the point.

    Boundary semantics (unit-tested in ``tests/test_obs.py``): any
    percentile of a single sample is that sample (rank is clamped to
    >= 1), and an empty sample set raises ValueError with a clear
    message rather than IndexError."""
    if not samples:
        raise ValueError("percentile of an empty sample set")
    if not 0 < q <= 100:
        raise ValueError(f"q must be in (0, 100], got {q}")
    s = sorted(samples)
    rank = max(1, math.ceil((q / 100.0) * len(s)))
    return s[rank - 1]


def _label_key(labels: Dict[str, object]) -> LabelKey:
    return tuple(sorted((k, str(v)) for k, v in labels.items()))


def _key_str(key: LabelKey) -> str:
    return ",".join(f"{k}={v}" for k, v in key)


class _Metric:
    """Common labeled-series plumbing; subclasses define the payload."""

    kind = "metric"

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self._series: Dict[LabelKey, object] = {}

    def labels(self) -> List[LabelKey]:
        return sorted(self._series)

    def reset(self) -> None:
        self._series.clear()

    def __repr__(self) -> str:
        return (f"<{type(self).__name__} {self.name!r} "
                f"{len(self._series)} series>")


class Counter(_Metric):
    """Monotone accumulator.  ``set`` is reserved for snapshot restore."""

    kind = "counter"

    def inc(self, amount: float = 1, **labels) -> None:
        if amount < 0:
            raise ValueError(
                f"counter {self.name}: negative increment {amount}")
        key = _label_key(labels)
        self._series[key] = self._series.get(key, 0) + amount

    def set(self, value: float, **labels) -> None:
        """Overwrite the series value — snapshot/restore only (a rewind
        to a checkpoint legitimately moves a counter backwards)."""
        self._series[_label_key(labels)] = value

    def value(self, **labels) -> float:
        return self._series.get(_label_key(labels), 0)

    def snapshot(self):
        if not self._series:
            return 0
        if list(self._series) == [()]:
            return self._series[()]
        return {_key_str(k): v for k, v in sorted(self._series.items())}

    def merge(self, other: "Counter") -> None:
        for k, v in other._series.items():
            self._series[k] = self._series.get(k, 0) + v


class Gauge(_Metric):
    """Last-write-wins level; ``set_max`` tracks peaks."""

    kind = "gauge"

    def set(self, value: float, **labels) -> None:
        self._series[_label_key(labels)] = value

    def set_max(self, value: float, **labels) -> None:
        key = _label_key(labels)
        self._series[key] = max(self._series.get(key, value), value)

    def value(self, default: float = 0, **labels) -> float:
        return self._series.get(_label_key(labels), default)

    def snapshot(self):
        if not self._series:
            return 0
        if list(self._series) == [()]:
            return self._series[()]
        return {_key_str(k): v for k, v in sorted(self._series.items())}

    def merge(self, other: "Gauge") -> None:
        self._series.update(other._series)


class Histogram(_Metric):
    """Raw-sample histogram with nearest-rank percentile snapshots."""

    kind = "histogram"

    def observe(self, value: float, **labels) -> None:
        self._series.setdefault(_label_key(labels), []).append(
            float(value))

    def values(self, **labels) -> List[float]:
        return list(self._series.get(_label_key(labels), []))

    def count(self, **labels) -> int:
        return len(self._series.get(_label_key(labels), []))

    def sum(self, **labels) -> float:
        return float(sum(self._series.get(_label_key(labels), [])))

    def percentile(self, q: float, **labels) -> float:
        return percentile(self._series.get(_label_key(labels), []), q)

    @contextmanager
    def time(self, **labels) -> Iterator[None]:
        """Observe the monotonic-clock duration of the ``with`` body."""
        t0 = time.perf_counter()
        try:
            yield
        finally:
            self.observe(time.perf_counter() - t0, **labels)

    def _stats(self, vals: List[float]) -> Dict[str, float]:
        out = {"count": len(vals), "sum": float(sum(vals))}
        if vals:
            out["min"] = min(vals)
            out["max"] = max(vals)
            out["p50"] = percentile(vals, 50)
            out["p99"] = percentile(vals, 99)
        return out

    def snapshot(self):
        if not self._series:
            return self._stats([])
        if list(self._series) == [()]:
            return self._stats(self._series[()])
        return {_key_str(k): self._stats(v)
                for k, v in sorted(self._series.items())}

    def merge(self, other: "Histogram") -> None:
        for k, v in other._series.items():
            self._series.setdefault(k, []).extend(v)


class MetricsRegistry:
    """Get-or-create home for every metric of one serving process.

    ``counter`` / ``gauge`` / ``histogram`` return the existing metric
    when the name is already registered (raising if it was registered as
    a different type), so independent subsystems sharing a registry
    converge on the same series without coordination.
    """

    def __init__(self):
        self._metrics: Dict[str, _Metric] = {}

    def _get(self, cls, name: str, help: str) -> _Metric:
        m = self._metrics.get(name)
        if m is None:
            m = cls(name, help)
            self._metrics[name] = m
        elif not isinstance(m, cls):
            raise TypeError(
                f"metric {name!r} already registered as {m.kind}, "
                f"requested {cls.kind}")
        return m

    def counter(self, name: str, help: str = "") -> Counter:
        return self._get(Counter, name, help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._get(Gauge, name, help)

    def histogram(self, name: str, help: str = "") -> Histogram:
        return self._get(Histogram, name, help)

    def get(self, name: str) -> Optional[_Metric]:
        return self._metrics.get(name)

    def names(self) -> List[str]:
        return sorted(self._metrics)

    def reset(self) -> None:
        """Zero every registered series (the metrics stay registered) —
        the one-call measurement-window restart ``reset_metrics``
        delegates to.  A metric registered after the last reset is reset
        too: subsystems can never be silently missed again."""
        for m in self._metrics.values():
            m.reset()

    def merge(self, other: "MetricsRegistry") -> None:
        """Fold ``other`` into this registry: counters add, gauges take
        the other's value, histograms extend their samples.  Used to
        combine per-subsystem registries into one exported view."""
        for name, m in other._metrics.items():
            mine = self._get(type(m), name, m.help)
            mine.merge(m)

    def snapshot(self) -> Dict[str, Dict[str, object]]:
        """Nested plain-data view: ``{kind: {name: value-or-series}}``,
        JSON-serializable, suitable for ``--metrics-json``."""
        out: Dict[str, Dict[str, object]] = {
            "counters": {}, "gauges": {}, "histograms": {}}
        for name in sorted(self._metrics):
            m = self._metrics[name]
            out[m.kind + "s"][name] = m.snapshot()
        return out
