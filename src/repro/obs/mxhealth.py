"""MX quantization-health statistics over the paged KV pool.

The paper's converter gives every 32-element block one E8M0 scale byte
and reserves the top encodings for non-finite blocks (SCALE_INF /
SCALE_NAN — ``core.formats.poison_threshold``).  That byte *is* the
block's health record: a poisoned block carries a marker at/above the
threshold, a block whose absmax railed the E8M0 range sits exactly at
the largest legal exponent (``threshold - 1`` — under a shared scale
this is also the block-level clip indicator: every element was encoded
against the format's widest step), and a denormal-tiny block sits at
encoding 0.  So quantization health over a *serving pool* is a pure
uint8 classification of the scale leaves — no dequantization, no code
pages touched — masked to the positions each slot actually wrote
(``pos < length``), exactly like ``models.health.slot_scale_poison``.

:func:`sample_mx_health` folds that classification into one jit-able
reduction and returns per-role (kv_key / kv_value) totals; the engine
samples it every ``obs_interval`` sync windows and publishes the
``mx.*`` gauges (see README §Observability).  One scalar transfer per
sample — never per token.
"""
from __future__ import annotations

from typing import Dict

import jax.numpy as jnp

from repro.core.formats import poison_threshold
from repro.models.layers import paged_page_size

# per-role stats sample_mx_health returns (and the engine's gauge names
# derive from): total scale bytes in live positions, poison markers,
# blocks at the max legal exponent (the shared-scale clip indicator),
# and blocks at the minimum encoding
scale_stat_names = ("scale_bytes", "poison", "saturated", "underflow")


def _zeros() -> Dict[str, jnp.ndarray]:
    return {k: jnp.zeros((), jnp.int32) for k in scale_stat_names}


def _leaf_stats(leaf, spec, page_tables, live):
    """Classify one scale leaf's bytes inside the live positions.

    ``leaf`` — (P, page, n_kv, blocks) scale pool, or layer-stacked
    (L, P, page, n_kv, blocks); ``live`` (B, n*page) position mask."""
    thr = jnp.uint8(poison_threshold(spec.mode))
    g = leaf[:, page_tables] if leaf.ndim == 5 else leaf[page_tables]
    # per (slot, logical page, position) byte counts over (n_kv, blocks)
    # — and over layers for stacked leaves
    axes = (0, -1, -2) if leaf.ndim == 5 else (-1, -2)
    b = page_tables.shape[0]

    def count(pred) -> jnp.ndarray:
        per_pos = jnp.sum(pred, axis=axes).reshape(b, -1)
        return jnp.sum(jnp.where(live, per_pos, 0)).astype(jnp.int32)

    per_pos_bytes = 1
    for ax in axes:
        per_pos_bytes *= g.shape[ax]
    n_bytes = (jnp.sum(live.astype(jnp.int32))
               * jnp.int32(per_pos_bytes))
    return {"scale_bytes": n_bytes,
            "poison": count((g >= thr).astype(jnp.int32)),
            "saturated": count((g == thr - jnp.uint8(1)
                                ).astype(jnp.int32)),
            "underflow": count((g == jnp.uint8(0)).astype(jnp.int32))}


def _group_stats(acc, group, page_tables, live, kk, kv):
    for sk, spec, role in (("ks_pages", kk, "kv_key"),
                           ("vs_pages", kv, "kv_value")):
        leaf = group.get(sk)
        if leaf is None or spec is None:    # fp pool: no scale bytes
            continue
        st = _leaf_stats(leaf, spec, page_tables, live)
        for k in scale_stat_names:
            acc[role][k] = acc[role][k] + st[k]
    return acc


def sample_mx_health(pool, page_tables, lengths, cfg
                     ) -> Dict[str, Dict[str, jnp.ndarray]]:
    """Per-role scale-byte statistics over every slot's live positions.

    ``pool`` is the engine's page-pool pytree; ``page_tables`` (B, n)
    int32 physical page ids per slot; ``lengths`` (B,) written
    positions.  Returns ``{"kv_key": {stat: scalar}, "kv_value": ...}``
    (int32 device scalars; jit-safe).  Roles quantized as fp in every
    layer report all-zero stats."""
    page = paged_page_size(
        pool["layers"][0] if isinstance(pool["layers"], list)
        else pool["layers"])
    b, n = page_tables.shape
    live = jnp.arange(n * page)[None, :] < lengths[:, None]
    acc = {"kv_key": _zeros(), "kv_value": _zeros()}
    lay = pool["layers"]
    if isinstance(lay, list):               # per-layer PolicyTable pools
        for i, g in enumerate(lay):
            c = cfg.layer_cfg(cfg.n_dense_layers + i)
            acc = _group_stats(acc, g, page_tables, live,
                               c.mx.kv_key, c.mx.kv_value)
    else:
        acc = _group_stats(acc, lay, page_tables, live,
                           cfg.mx.kv_key, cfg.mx.kv_value)
    for i, g in enumerate(pool.get("dense_layers", [])):
        c = cfg.layer_cfg(i)
        acc = _group_stats(acc, g, page_tables, live,
                           c.mx.kv_key, c.mx.kv_value)
    return acc
