"""Per-request trace spans for the serving engine (``trace/v1``).

The engine records *spans* — begin/end pairs at host sync-point
granularity — on independent **tracks**: one track per request (keyed by
rid) plus one engine-level track (rid ``None``) for phase spans
(prefill batches, fused decode windows, swap traffic).  Because every
timestamp the tracer consumes is a ``time.perf_counter`` stamp the
engine *already takes* for its phase accounting, tracing adds zero
per-token host synchronization: a fused decode window contributes one
begin/end pair per live slot, all sharing the window's two existing
stamps.

Event model (``trace/v1`` JSONL):

* line 0 is a header: ``{"schema": "trace/v1", "meta": {...}}``;
* every other line is one event:
  ``{"seq", "ph", "name", "cat", "rid", "t_us"}`` plus optional
  ``"args"`` — ``ph`` is ``"B"`` (span begin), ``"E"`` (span end, name
  must match the innermost open ``B`` of the same track), or ``"I"``
  (instant).  ``seq`` increments by 1 from 0 in emission order, so a
  seeded run's event sequence is deterministic modulo the ``t_us``
  values; ``rid`` is ``null`` on the engine track.

Spans on one track are **strictly nested** — ``end`` closes the
innermost open span and raises on a name mismatch, which is how the
test suite catches lifecycle bugs (a span closed twice, or never).
``benchmarks/validate_trace.py`` re-derives the same nesting from the
JSONL alone with a per-track stack.

Chrome export (:func:`chrome_events` / :meth:`Tracer.write_chrome`)
maps tracks to Chrome ``trace_event`` threads (engine = tid 0, request
rid = tid rid+1) with ``B``/``E``/``i`` phases — load the file in
Perfetto / ``chrome://tracing`` to see queueing, prefill, decode
windows, preemptions, and retries per request on a common timeline.

``annotate=True`` additionally wraps engine-track spans in
``jax.profiler.TraceAnnotation`` so device profiles line up with engine
spans (request tracks interleave and cannot nest globally, so they are
never annotated).
"""
from __future__ import annotations

import json
from time import perf_counter
from typing import Any, Dict, List, Optional, Sequence

TRACE_SCHEMA = "trace/v1"

EVENT_FIELDS = ("seq", "ph", "name", "cat", "rid", "t_us")


class _Span:
    __slots__ = ("name", "t_us", "annotation")

    def __init__(self, name: str, t_us: int, annotation=None):
        self.name = name
        self.t_us = t_us
        self.annotation = annotation


class Tracer:
    """Span recorder for one serving process.

    ``meta`` rides the JSONL header (seed, model, policy — anything the
    launcher wants alongside the events); ``annotate`` wraps
    engine-track spans in ``jax.profiler.TraceAnnotation``.
    """

    def __init__(self, meta: Optional[Dict[str, Any]] = None,
                 annotate: bool = False):
        self.meta = dict(meta or {})
        self.annotate = bool(annotate)
        self.t0 = perf_counter()
        self.events: List[Dict[str, Any]] = []
        self._stacks: Dict[Optional[int], List[_Span]] = {}
        self._seq = 0

    # ------------------------------------------------------------- recording
    def _t_us(self, ts: Optional[float]) -> int:
        return int(round(((perf_counter() if ts is None else ts)
                          - self.t0) * 1e6))

    def _emit(self, ph: str, name: str, cat: str, rid: Optional[int],
              t_us: int, args: Optional[Dict[str, Any]]) -> None:
        ev: Dict[str, Any] = {"seq": self._seq, "ph": ph, "name": name,
                              "cat": cat, "rid": rid, "t_us": t_us}
        if args:
            ev["args"] = args
        self._seq += 1
        self.events.append(ev)

    def begin(self, name: str, cat: str = "engine",
              rid: Optional[int] = None, ts: Optional[float] = None,
              **args) -> None:
        """Open a span on ``rid``'s track (None = the engine track) at
        ``ts`` (a perf_counter stamp; defaults to now)."""
        t_us = self._t_us(ts)
        ann = None
        if self.annotate and rid is None:
            ann = _annotation(name)
        self._stacks.setdefault(rid, []).append(_Span(name, t_us, ann))
        self._emit("B", name, cat, rid, t_us, args or None)

    def end(self, name: str, cat: str = "engine",
            rid: Optional[int] = None, ts: Optional[float] = None,
            **args) -> None:
        """Close the innermost open span of ``rid``'s track; ``name``
        must match it (a mismatch is a lifecycle bug and raises)."""
        stack = self._stacks.get(rid)
        if not stack:
            raise ValueError(
                f"end({name!r}): no open span on track {rid}")
        top = stack.pop()
        if top.name != name:
            stack.append(top)
            raise ValueError(
                f"end({name!r}): innermost open span on track {rid} "
                f"is {top.name!r}")
        if top.annotation is not None:
            top.annotation.__exit__(None, None, None)
        self._emit("E", name, cat, rid, max(self._t_us(ts), top.t_us),
                   args or None)

    def span(self, name: str, cat: str = "engine",
             rid: Optional[int] = None, t0: Optional[float] = None,
             t1: Optional[float] = None, **args) -> None:
        """Record a complete span from two existing stamps (begin at
        ``t0``, end at ``t1``) — the zero-extra-sync path for fused
        decode windows and prefill batches."""
        self.begin(name, cat, rid, ts=t0, **args)
        self.end(name, cat, rid, ts=t1)

    def instant(self, name: str, cat: str = "engine",
                rid: Optional[int] = None, ts: Optional[float] = None,
                **args) -> None:
        self._emit("I", name, cat, rid, self._t_us(ts), args or None)

    # ------------------------------------------------------------- queries
    def open_spans(self, rid: Optional[int] = None) -> List[str]:
        """Names of the open spans on ``rid``'s track, outermost first."""
        return [s.name for s in self._stacks.get(rid, [])]

    def top(self, rid: Optional[int] = None) -> Optional[str]:
        stack = self._stacks.get(rid)
        return stack[-1].name if stack else None

    def open_tracks(self) -> List[Optional[int]]:
        """Track keys with at least one open span (None = engine)."""
        return [rid for rid, st in self._stacks.items() if st]

    # ------------------------------------------------------------- lifecycle
    def unwind(self, rid: Optional[int], ts: Optional[float] = None,
               keep: int = 0, **args) -> int:
        """End open spans on ``rid``'s track (innermost out) until at
        most ``keep`` remain; returns how many were closed.  Recovery
        paths (quarantine, snapshot restore) use this so a rolled-back
        request's track stays well-formed."""
        stack = self._stacks.get(rid, [])
        n = 0
        while len(stack) > keep:
            self.end(stack[-1].name, rid=rid, ts=ts, **args)
            n += 1
        return n

    def close_track(self, rid: Optional[int],
                    ts: Optional[float] = None, **args) -> None:
        """End every open span on ``rid``'s track (the outermost —
        normally the per-request root — gets ``args``, e.g. a terminal
        ``status``)."""
        stack = self._stacks.get(rid, [])
        while len(stack) > 1:
            self.end(stack[-1].name, rid=rid, ts=ts)
        if stack:
            self.end(stack[-1].name, rid=rid, ts=ts, **args)

    # ------------------------------------------------------------- export
    def header(self) -> Dict[str, Any]:
        return {"schema": TRACE_SCHEMA, "meta": self.meta}

    def write_jsonl(self, path) -> None:
        """``trace/v1`` JSONL: one header line, then one event per
        line in ``seq`` order."""
        with open(path, "w") as f:
            f.write(json.dumps(self.header(), sort_keys=True) + "\n")
            for ev in self.events:
                f.write(json.dumps(ev, sort_keys=True) + "\n")

    def write_chrome(self, path) -> None:
        with open(path, "w") as f:
            json.dump({"traceEvents": chrome_events(self.events),
                       "displayTimeUnit": "ms",
                       "otherData": self.meta}, f)


def _annotation(name: str):
    """Enter a jax.profiler.TraceAnnotation (None when jax or the
    profiler is unavailable — the shim is strictly optional)."""
    try:
        from jax.profiler import TraceAnnotation
    except Exception:       # pragma: no cover - depends on jax build
        return None
    ann = TraceAnnotation(name)
    ann.__enter__()
    return ann


def _tid(rid: Optional[int]) -> int:
    return 0 if rid is None else rid + 1


def chrome_events(events: Sequence[Dict[str, Any]]) -> List[Dict]:
    """Translate ``trace/v1`` events into Chrome ``trace_event`` dicts
    (Perfetto-loadable): tracks become threads of one process, B/E map
    verbatim, instants become thread-scoped ``i`` events."""
    out: List[Dict] = [{
        "ph": "M", "pid": 1, "tid": 0, "name": "thread_name",
        "args": {"name": "engine"}}]
    named = {0}
    for ev in events:
        tid = _tid(ev["rid"])
        if tid not in named:
            named.add(tid)
            out.append({"ph": "M", "pid": 1, "tid": tid,
                        "name": "thread_name",
                        "args": {"name": f"request {ev['rid']}"}})
        ch = {"ph": ev["ph"] if ev["ph"] != "I" else "i",
              "pid": 1, "tid": tid, "ts": ev["t_us"],
              "name": ev["name"], "cat": ev["cat"]}
        if ch["ph"] == "i":
            ch["s"] = "t"
        if "args" in ev:
            ch["args"] = ev["args"]
        out.append(ch)
    return out


def validate_nesting(events: Sequence[Dict[str, Any]]
                     ) -> Dict[Optional[int], List[str]]:
    """Re-derive per-track span nesting with a stack (the same check
    ``benchmarks/validate_trace.py`` performs standalone): raises
    ValueError on an E without a matching innermost B, a non-monotone
    track clock, or a track left open; returns the per-track list of
    completed root-level span names."""
    stacks: Dict[Optional[int], List[Dict]] = {}
    roots: Dict[Optional[int], List[str]] = {}
    last_t: Dict[Optional[int], int] = {}
    for ev in events:
        rid = ev["rid"]
        if ev["t_us"] < last_t.get(rid, ev["t_us"]):
            raise ValueError(
                f"seq {ev['seq']}: track {rid} clock moved backwards")
        last_t[rid] = ev["t_us"]
        if ev["ph"] == "B":
            stacks.setdefault(rid, []).append(ev)
        elif ev["ph"] == "E":
            stack = stacks.get(rid)
            if not stack or stack[-1]["name"] != ev["name"]:
                raise ValueError(
                    f"seq {ev['seq']}: E {ev['name']!r} does not close "
                    f"the innermost B of track {rid} "
                    f"({stack[-1]['name'] if stack else 'empty'})")
            stack.pop()
            if not stack:
                roots.setdefault(rid, []).append(ev["name"])
    open_tracks = {rid: [e["name"] for e in st]
                   for rid, st in stacks.items() if st}
    if open_tracks:
        raise ValueError(f"tracks left open: {open_tracks}")
    return roots
