"""AdamW with f32 master weights (params may live in bf16).

State layout (every leaf mirrors the param pytree, all f32):
    master — authoritative f32 weights
    m, v   — moments
Optimizer state shards follow the parameter PartitionSpecs (ZeRO-style);
nothing here is mesh-aware — sharding is applied by the launcher via
in_shardings on the jitted train step.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    schedule: str = "cosine"          # cosine | wsd | const


def cosine_schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    s = step.astype(jnp.float32)
    warm = jnp.minimum(s / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((s - cfg.warmup_steps)
                    / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    return cfg.lr * warm * 0.5 * (1.0 + jnp.cos(jnp.pi * prog))


def wsd_schedule(cfg: AdamWConfig, step: jax.Array,
                 decay_frac: float = 0.1) -> jax.Array:
    """Warmup-Stable-Decay."""
    s = step.astype(jnp.float32)
    warm = jnp.minimum(s / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    decay_start = cfg.total_steps * (1.0 - decay_frac)
    dec = jnp.clip(1.0 - (s - decay_start)
                   / max(cfg.total_steps - decay_start, 1), 0.0, 1.0)
    return cfg.lr * warm * dec


def _lr(cfg: AdamWConfig, step):
    if cfg.schedule == "wsd":
        return wsd_schedule(cfg, step)
    if cfg.schedule == "const":
        return jnp.asarray(cfg.lr, jnp.float32)
    return cosine_schedule(cfg, step)


def adamw_init(params) -> Dict[str, Any]:
    f32 = lambda p: p.astype(jnp.float32)
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "master": jax.tree_util.tree_map(f32, params),
        "m": jax.tree_util.tree_map(zeros, params),
        "v": jax.tree_util.tree_map(zeros, params),
    }


def global_norm(tree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in leaves))


def adamw_update(cfg: AdamWConfig, grads, state, step: jax.Array,
                 param_dtype) -> Tuple[Any, Dict[str, Any], Dict[str, Any]]:
    """One AdamW step.  Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
    lr = _lr(cfg, step)
    t = step.astype(jnp.float32) + 1.0
    bc1 = 1.0 - cfg.b1 ** t
    bc2 = 1.0 - cfg.b2 ** t

    def upd(g, m, v, mast):
        g = g.astype(jnp.float32) * scale
        m2 = cfg.b1 * m + (1.0 - cfg.b1) * g
        v2 = cfg.b2 * v + (1.0 - cfg.b2) * g * g
        mhat = m2 / bc1
        vhat = v2 / bc2
        step_dir = mhat / (jnp.sqrt(vhat) + cfg.eps)
        mast2 = mast - lr * (step_dir + cfg.weight_decay * mast)
        return m2, v2, mast2

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    flat_w = treedef.flatten_up_to(state["master"])
    out = [upd(g, m, v, w) for g, m, v, w in
           zip(flat_g, flat_m, flat_v, flat_w)]
    new_m = treedef.unflatten([o[0] for o in out])
    new_v = treedef.unflatten([o[1] for o in out])
    new_master = treedef.unflatten([o[2] for o in out])
    new_params = jax.tree_util.tree_map(
        lambda w: w.astype(param_dtype), new_master)
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, {"master": new_master, "m": new_m, "v": new_v}, \
        metrics
