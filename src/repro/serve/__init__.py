from repro.serve.engine import ServeEngine, GenerationConfig  # noqa: F401
