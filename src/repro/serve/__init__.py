from repro.serve.engine import (ContinuousBatchingEngine,  # noqa: F401
                                GenerationConfig, ServeEngine)
from repro.serve.frontend import (AsyncServer, RejectedError,  # noqa: F401
                                  RequestStream, latency_summary,
                                  percentile)
from repro.serve.paging import BlockManager, pages_needed  # noqa: F401
from repro.serve.prefix import PrefixCache  # noqa: F401
from repro.serve.scheduler import (Request, RequestState,  # noqa: F401
                                   Scheduler)
from repro.serve.swap import HostSwapStore, SwapData  # noqa: F401
from repro.serve.traffic import (Arrival, TrafficClass,  # noqa: F401
                                 load_trace, on_off_times, poisson_times,
                                 replay, save_trace, synthesize)
