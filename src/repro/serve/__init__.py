from repro.serve.engine import (ContinuousBatchingEngine,  # noqa: F401
                                GenerationConfig, ServeEngine)
from repro.serve.faults import (Fault, FaultError,  # noqa: F401
                                FaultPlan)
from repro.serve.frontend import (AsyncServer,  # noqa: F401
                                  QuarantinedError, RejectedError,
                                  RequestStream, RetriesExhausted,
                                  latency_summary, percentile)
from repro.serve.paging import (BlockManager,  # noqa: F401
                                PageGrantError, pages_needed)
from repro.serve.prefix import PrefixCache  # noqa: F401
from repro.serve.scheduler import (Request, RequestState,  # noqa: F401
                                   Scheduler)
from repro.serve.snapshot import (EngineSnapshot, capture,  # noqa: F401
                                  restore)
from repro.serve.swap import HostSwapStore, SwapData  # noqa: F401
from repro.serve.traffic import (Arrival, TrafficClass,  # noqa: F401
                                 load_trace, on_off_times, poisson_times,
                                 replay, save_trace, synthesize)
