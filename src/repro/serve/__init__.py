from repro.serve.engine import (ContinuousBatchingEngine,  # noqa: F401
                                GenerationConfig, ServeEngine)
from repro.serve.paging import BlockManager, pages_needed  # noqa: F401
from repro.serve.prefix import PrefixCache  # noqa: F401
from repro.serve.scheduler import (Request, RequestState,  # noqa: F401
                                   Scheduler)
