"""Serving engines: jitted prefill + decode with (optionally MX) KV cache.

Two engines share the model zoo's decode path:

``ServeEngine`` — static batch: requests of equal prompt length are batched,
prefilled once, then stepped greedily (or sampled).

``ContinuousBatchingEngine`` — slot-based continuous batching over a paged
MX KV cache: variable-length prompts are admitted into decode slots
mid-flight, each slot's K/V lives in fixed-size pages of packed codes +
E8M0 scales referenced through a per-slot block table, and finished
requests are evicted so their pages recycle immediately.  Prefill runs
per-request (bucketed to page multiples) into a contiguous cache that is
scattered into the slot's pages; decode steps the whole slot batch at once.

Either way the KV quantization policy comes from the model config's
``QuantPolicy`` roles (cfg.mx.kv_key / cfg.mx.kv_value) — this is the
serving-side consumer of the paper's converter: INT8/E4M3 KV cuts decode
HBM traffic ~2x vs bf16 (see the decode_32k roofline cells), K and V may
carry *different* element formats (e.g. INT8 keys + E2M1 values, each
pool sized per-role), and with ``attn_impl="flash"`` the paged Pallas
kernel keeps HBM reads at the quantized bytes end-to-end.
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.pack import pack_codes
from repro.dist.sharding import use_rules
from repro.models.registry import Model
from repro.serve.paging import BlockManager, pages_needed
from repro.serve.scheduler import Request, Scheduler


@dataclasses.dataclass(frozen=True)
class GenerationConfig:
    max_new_tokens: int = 32
    temperature: float = 0.0        # 0 => greedy
    seed: int = 0


class ServeEngine:
    def __init__(self, model: Model, params, max_len: int,
                 rules: Optional[Dict[str, Any]] = None):
        """``rules`` (from repro.dist.sharding.make_rules, decode posture:
        fsdp_params=False) installs the logical sharding constraints inside
        the jitted prefill/decode; None serves single-device."""
        self.model = model
        self.params = params
        self.max_len = max_len
        self.rules = rules      # introspection only; already traced into
        cfg = model.cfg         # the jit closures below

        def _ctx():
            return use_rules(rules) if rules is not None \
                else contextlib.nullcontext()

        def _prefill(params, batch):
            with _ctx():
                return model.prefill(params, batch, max_len=max_len)

        def _decode(params, token, cache, pos):
            with _ctx():
                return model.decode_step(params, token, cache, pos)

        self._prefill = jax.jit(_prefill)
        self._decode = jax.jit(_decode)

    def generate(self, batch: Dict[str, jax.Array],
                 gen: GenerationConfig = GenerationConfig()
                 ) -> np.ndarray:
        """batch: arch input dict with equal-length prompts.
        Returns (B, max_new_tokens) int32."""
        logits, cache, pos = self._prefill(self.params, batch)
        vocab = self.model.cfg.vocab
        key = jax.random.PRNGKey(gen.seed)
        tok = self._pick(logits[:, -1, :vocab], gen, key)
        out = [np.asarray(tok)]
        for i in range(gen.max_new_tokens - 1):
            logits, cache = self._decode(self.params, tok, cache,
                                         jnp.asarray(pos + i,
                                                     dtype=jnp.int32))
            key, sub = jax.random.split(key)
            tok = self._pick(logits[:, -1, :vocab], gen, sub)
            out.append(np.asarray(tok))
        return np.stack(out, axis=1)

    @staticmethod
    def _pick(logits: jax.Array, gen: GenerationConfig, key) -> jax.Array:
        if gen.temperature <= 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jax.random.categorical(
            key, logits.astype(jnp.float32) / gen.temperature, axis=-1
        ).astype(jnp.int32)


# =============================================================================
# Continuous batching over the paged MX KV cache
# =============================================================================
# pool key -> (contiguous prefill-cache key, element-code policy role)
_POOL_KEYS = {
    "kc_pages": ("k_codes", "kv_key"), "ks_pages": ("k_scales", None),
    "vc_pages": ("v_codes", "kv_value"), "vs_pages": ("v_scales", None),
    "k_pages": ("k", None), "v_pages": ("v", None),
}


class ContinuousBatchingEngine:
    """Slot-based continuous batching over a paged (optionally MX) KV cache.

    ``max_slots``  — decode batch width (requests in flight).
    ``page_size``  — tokens per KV page.
    ``max_len``    — per-request cap on prompt + generated tokens; sets the
                     block-table width.
    ``num_pages``  — page-pool size; defaults to full occupancy
                     (max_slots * pages(max_len) + the trash page).
    ``rules``      — sharding rules (repro.dist.sharding.make_rules, decode
                     posture); the page pool follows the "kv_pages" rule.
    """

    def __init__(self, model: Model, params, *, max_slots: int = 8,
                 page_size: int = 16, max_len: int = 256,
                 num_pages: Optional[int] = None,
                 rules: Optional[Dict[str, Any]] = None,
                 gen: GenerationConfig = GenerationConfig()):
        if not model.supports_paged():
            raise NotImplementedError(
                f"{model.cfg.name}: continuous batching needs a GQA "
                "decoder (no MLA / modality frontend)")
        self.model = model
        self.params = params
        self.page_size = page_size
        self.max_pages_per_slot = pages_needed(max_len, page_size)
        if num_pages is None:
            num_pages = 1 + max_slots * self.max_pages_per_slot
        self.blocks = BlockManager(num_pages, page_size, max_slots,
                                   self.max_pages_per_slot)
        self.scheduler = Scheduler(max_slots, self.blocks)
        self.pool = model.init_paged_cache(num_pages, page_size)
        self.gen = gen
        self.rules = rules
        self._key = jax.random.PRNGKey(gen.seed)
        self._next_rid = 0
        self._cur_tok = np.zeros(max_slots, np.int32)
        self._lengths = np.zeros(max_slots, np.int32)
        self.n_steps = 0
        self.n_generated = 0
        cfg = model.cfg
        self.vocab = cfg.vocab

        def _ctx():
            return use_rules(rules) if rules is not None \
                else contextlib.nullcontext()

        def _prefill(params, tokens):
            with _ctx():
                return model.prefill(params, {"tokens": tokens},
                                     max_len=tokens.shape[1])

        def _step(params, tok, pool, bt, lengths):
            with _ctx():
                return model.paged_decode_step(params, tok, pool, bt,
                                               lengths)

        def _scatter(pool, cache, page_ids):
            with _ctx():
                return self._scatter_pages(pool, cache, page_ids)

        self._prefill = jax.jit(_prefill)
        # donate the pool: every decode step / prefill scatter rewrites it
        # wholesale, and without donation XLA double-buffers the dominant
        # serving allocation (the CPU backend ignores donation with a
        # warning; on TPU this halves peak KV memory)
        self._step = jax.jit(_step, donate_argnums=(2,))
        self._scatter = jax.jit(_scatter, donate_argnums=(0,))

    # ------------------------------------------------------------ requests
    def add_request(self, prompt, max_new_tokens: int) -> int:
        """Queue a prompt; returns the request id.  Admission happens on a
        subsequent ``step()`` when a slot and enough pages are free.
        Raises ValueError (from ``Scheduler.submit``) when the sequence can
        never fit a slot or the pool."""
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1 (prefill always "
                             "emits the first generated token)")
        req = Request(rid=self._next_rid, prompt=prompt,
                      max_new_tokens=max_new_tokens)
        self.scheduler.submit(req)              # validates capacity
        self._next_rid += 1
        return req.rid

    # ---------------------------------------------------------- the engine
    def step(self) -> List[Tuple[int, int]]:
        """Admit what fits, run one batched decode step; returns the
        (request id, token) pairs emitted this step (admissions emit their
        prefill token here too)."""
        emitted = []
        for req in self.scheduler.admit():
            emitted.append((req.rid, self._prefill_into_slot(req)))
            if req.done:
                self._release(req)
            else:
                # the decode write position may sit in a page past the
                # prompt's allocation (prompt length a page multiple)
                ok = self.blocks.ensure(req.slot,
                                        self._lengths[req.slot] + 1)
                assert ok, "admission reserved full-sequence capacity"
        if not self.scheduler.running:
            return emitted
        bt = jnp.asarray(self.blocks.tables)
        logits, self.pool = self._step(
            self.params, jnp.asarray(self._cur_tok), self.pool, bt,
            jnp.asarray(self._lengths))
        self.n_steps += 1
        lg = np.asarray(logits[:, -1, :self.vocab], np.float32)
        for slot in sorted(self.scheduler.running):
            req = self.scheduler.running[slot]
            nxt = self._pick_host(lg[slot])
            self._lengths[slot] += 1
            self._cur_tok[slot] = nxt
            req.out.append(nxt)
            self.n_generated += 1
            emitted.append((req.rid, nxt))
            if req.done:
                self._release(req)
            else:
                ok = self.blocks.ensure(slot, self._lengths[slot] + 1)
                assert ok, "admission reserved full-sequence capacity"
        return emitted

    def run(self) -> Dict[int, np.ndarray]:
        """Drive ``step()`` until every queued request finishes; returns
        {request id: generated tokens} for the requests finished by this
        call (the engine is reusable: jitted closures stay warm across
        batches)."""
        start = len(self.scheduler.finished)
        while self.scheduler.has_work():
            if not self.step() and not self.scheduler.running:
                raise RuntimeError(
                    "no progress: waiting requests cannot be admitted")
        return {r.rid: np.asarray(r.out, np.int32)
                for r in self.scheduler.finished[start:]}

    # ------------------------------------------------------------ internals
    def _prefill_into_slot(self, req: Request) -> int:
        """Prefill one admitted request (prompt padded to a page multiple),
        scatter its contiguous cache into the slot's pages, emit the first
        generated token."""
        slot, n = req.slot, req.prompt_len
        npr = pages_needed(n, self.page_size)
        toks = np.zeros((1, npr * self.page_size), np.int32)
        toks[0, :n] = req.prompt
        logits, cache, _ = self._prefill(self.params, jnp.asarray(toks))
        page_ids = jnp.asarray(self.blocks.tables[slot, :npr])
        self.pool = self._scatter(self.pool, cache, page_ids)
        first = self._pick_host(
            np.asarray(logits[0, n - 1, :self.vocab], np.float32))
        self._cur_tok[slot] = first
        self._lengths[slot] = n
        req.out.append(first)
        self.n_generated += 1
        return first

    def _release(self, req: Request) -> None:
        slot = req.slot
        self.scheduler.evict(req)
        self._cur_tok[slot] = 0
        self._lengths[slot] = 0

    def _pick_host(self, logits: np.ndarray) -> int:
        if self.gen.temperature <= 0.0:
            return int(np.argmax(logits))
        self._key, sub = jax.random.split(self._key)
        return int(jax.random.categorical(
            sub, jnp.asarray(logits) / self.gen.temperature))

    def _scatter_pages(self, pool, cache, page_ids):
        """Contiguous prefill cache (B=1, padded to full pages) -> the
        slot's physical pages (packing sub-byte codes per role on the
        way)."""
        policy = self.model.cfg.mx

        def group(pool_g, cache_g):
            out = {}
            for pk, leaf in pool_g.items():
                ck, role = _POOL_KEYS[pk]
                val = cache_g[ck]
                stacked = val.ndim == 5          # (n_scan, 1, L, n_kv, X)
                val = val[:, 0] if stacked else val[0]
                spec = policy.role(role) if role is not None else None
                if spec is not None and spec.packed:
                    val = pack_codes(val, spec.fmt)
                lead = val.shape[:-3]
                npr = val.shape[-3] // self.page_size
                val = val.reshape(lead + (npr, self.page_size)
                                  + val.shape[-2:])
                out[pk] = leaf.at[:, page_ids].set(val) if stacked \
                    else leaf.at[page_ids].set(val)
            return out

        new = {"layers": group(pool["layers"], cache["layers"])}
        if "dense_layers" in pool:
            new["dense_layers"] = [
                group(pg, cg) for pg, cg in zip(pool["dense_layers"],
                                                cache["dense_layers"])]
        return new
