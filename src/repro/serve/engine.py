"""Batched serving engine: jitted prefill + decode with (optionally MX)
KV cache.

Static-batch continuous decode: requests of equal prompt length are batched,
prefilled once, then stepped greedily (or sampled).  The KV cache layout and
quantization policy come from the model config (cfg.mx.kv_cache /
cfg.mx.kv_fmt) — this is the serving-side consumer of the paper's converter:
INT8/E4M3 KV cuts decode HBM traffic ~2x vs bf16 (see the decode_32k
roofline cells).
"""
from __future__ import annotations

import contextlib
import dataclasses
import functools
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.dist.sharding import use_rules
from repro.models.registry import Model


@dataclasses.dataclass(frozen=True)
class GenerationConfig:
    max_new_tokens: int = 32
    temperature: float = 0.0        # 0 => greedy
    seed: int = 0


class ServeEngine:
    def __init__(self, model: Model, params, max_len: int,
                 rules: Optional[Dict[str, Any]] = None):
        """``rules`` (from repro.dist.sharding.make_rules, decode posture:
        fsdp_params=False) installs the logical sharding constraints inside
        the jitted prefill/decode; None serves single-device."""
        self.model = model
        self.params = params
        self.max_len = max_len
        self.rules = rules      # introspection only; already traced into
        cfg = model.cfg         # the jit closures below

        def _ctx():
            return use_rules(rules) if rules is not None \
                else contextlib.nullcontext()

        def _prefill(params, batch):
            with _ctx():
                return model.prefill(params, batch, max_len=max_len)

        def _decode(params, token, cache, pos):
            with _ctx():
                return model.decode_step(params, token, cache, pos)

        self._prefill = jax.jit(_prefill)
        self._decode = jax.jit(_decode)

    def generate(self, batch: Dict[str, jax.Array],
                 gen: GenerationConfig = GenerationConfig()
                 ) -> np.ndarray:
        """batch: arch input dict with equal-length prompts.
        Returns (B, max_new_tokens) int32."""
        logits, cache, pos = self._prefill(self.params, batch)
        vocab = self.model.cfg.vocab
        key = jax.random.PRNGKey(gen.seed)
        tok = self._pick(logits[:, -1, :vocab], gen, key)
        out = [np.asarray(tok)]
        for i in range(gen.max_new_tokens - 1):
            logits, cache = self._decode(self.params, tok, cache,
                                         jnp.asarray(pos + i,
                                                     dtype=jnp.int32))
            key, sub = jax.random.split(key)
            tok = self._pick(logits[:, -1, :vocab], gen, sub)
            out.append(np.asarray(tok))
        return np.stack(out, axis=1)

    @staticmethod
    def _pick(logits: jax.Array, gen: GenerationConfig, key) -> jax.Array:
        if gen.temperature <= 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jax.random.categorical(
            key, logits.astype(jnp.float32) / gen.temperature, axis=-1
        ).astype(jnp.int32)
