"""Serving engines: jitted prefill + decode with (optionally MX) KV cache.

Two engines share the model zoo's decode path:

``ServeEngine`` — static batch: requests of equal prompt length are batched,
prefilled once, then stepped greedily (or sampled).

``ContinuousBatchingEngine`` — slot-based continuous batching over a paged
MX KV cache with a **device-resident decode hot loop**: variable-length
prompts are admitted into decode slots mid-flight, each slot's K/V lives in
fixed-size pages of packed codes + E8M0 scales referenced through a
per-slot block table, and finished requests are evicted so their pages
recycle immediately.  Admissions are *bucket-batched*: same-padded-length
prompts prefill as one batch whose caches scatter (and bit-pack) into their
pages in a single donated call.  Decode fuses up to ``sync_every`` steps
into one jitted ``lax.scan`` that samples on device (greedy + temperature,
per-slot PRNG keys) and keeps tokens, lengths, budgets, and the paged pool
on device — the host is consulted only at window boundaries, where it
drains the emitted-token buffer, evicts finished slots, admits waiting
requests, and pre-grants the pages the next window needs
(``Scheduler.plan_window``).

Either way the KV quantization policy comes from the model config's
``QuantPolicy`` roles (cfg.mx.kv_key / cfg.mx.kv_value) — this is the
serving-side consumer of the paper's converter: INT8/E4M3 KV cuts decode
HBM traffic ~2x vs bf16 (see the decode_32k roofline cells), K and V may
carry *different* element formats (e.g. INT8 keys + E2M1 values, each
pool sized per-role), and with ``attn_impl="flash"`` the paged Pallas
kernel keeps HBM reads at the quantized bytes end-to-end.  A per-layer
``PolicyTable`` (``models.config.apply_policy_table``; usually emitted by
``repro.calib``'s budget-constrained search) additionally varies the
specs *by layer* — the page pools become per-layer lists, each sized by
its own layer's formats.
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.mx_weight import params_nbytes
from repro.dist.sharding import use_rules
from repro.kernels import backend
from repro.models import health as H
from repro.obs.metrics import MetricsRegistry, rate
from repro.obs.mxhealth import sample_mx_health
from repro.obs.trace import Tracer
from repro.models.decoder import sample_tokens
from repro.models.registry import Model
from repro.serve import faults as F
from repro.serve.faults import FaultPlan
from repro.serve.paging import (TRASH_PAGE, BlockManager, PageGrantError,
                                pages_needed)
from repro.serve.prefix import PrefixCache
from repro.serve.scheduler import Request, RequestState, Scheduler
from repro.serve.swap import (HostSwapStore, SwapData, concat_snapshots,
                              gather_pages, scatter_pages)


@dataclasses.dataclass(frozen=True)
class GenerationConfig:
    max_new_tokens: int = 32
    temperature: float = 0.0        # 0 => greedy
    seed: int = 0


class ServeEngine:
    def __init__(self, model: Model, params, max_len: int,
                 rules: Optional[Dict[str, Any]] = None):
        """``rules`` (from repro.dist.sharding.make_rules, decode posture:
        fsdp_params=False) installs the logical sharding constraints inside
        the jitted prefill/decode; None serves single-device."""
        self.model = model
        self.params = params
        self.max_len = max_len
        self.rules = rules      # introspection only; already traced into
        cfg = model.cfg         # the jit closures below

        def _ctx():
            return use_rules(rules) if rules is not None \
                else contextlib.nullcontext()

        def _prefill(params, batch):
            with _ctx():
                return model.prefill(params, batch, max_len=max_len)

        def _decode(params, token, cache, pos):
            with _ctx():
                return model.decode_step(params, token, cache, pos)

        self._prefill = jax.jit(_prefill)
        self._decode = jax.jit(_decode)

    @property
    def weight_pool_nbytes(self) -> int:
        """Serve-time weight HBM bytes as stored (MXWeight leaves count
        their uint8 codes + scales; fp params their dtype width)."""
        return params_nbytes(self.params)

    def generate(self, batch: Dict[str, jax.Array],
                 gen: GenerationConfig = GenerationConfig()
                 ) -> np.ndarray:
        """batch: arch input dict with equal-length prompts.
        Returns (B, max_new_tokens) int32."""
        logits, cache, pos = self._prefill(self.params, batch)
        vocab = self.model.cfg.vocab
        key = jax.random.PRNGKey(gen.seed)
        tok = self._pick(logits[:, -1, :vocab], gen, key)
        out = [np.asarray(tok)]
        for i in range(gen.max_new_tokens - 1):
            logits, cache = self._decode(self.params, tok, cache,
                                         jnp.asarray(pos + i,
                                                     dtype=jnp.int32))
            key, sub = jax.random.split(key)
            tok = self._pick(logits[:, -1, :vocab], gen, sub)
            out.append(np.asarray(tok))
        return np.stack(out, axis=1)

    @staticmethod
    def _pick(logits: jax.Array, gen: GenerationConfig, key) -> jax.Array:
        if gen.temperature <= 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jax.random.categorical(
            key, logits.astype(jnp.float32) / gen.temperature, axis=-1
        ).astype(jnp.int32)


# =============================================================================
# Continuous batching over the paged MX KV cache
# =============================================================================
class ContinuousBatchingEngine:
    """Slot-based continuous batching over a paged (optionally MX) KV cache
    with a fused, device-resident decode loop.

    ``max_slots``      — decode batch width (requests in flight).
    ``page_size``      — tokens per KV page.
    ``max_len``        — per-request cap on prompt + generated tokens; sets
                         the block-table width.
    ``num_pages``      — page-pool size; defaults to full occupancy
                         (max_slots * pages(max_len) + the trash page).
    ``rules``          — sharding rules (repro.dist.sharding.make_rules,
                         decode posture); the page pool follows the
                         "kv_pages" rule.
    ``sync_every``     — decode steps fused per jitted ``lax.scan`` window;
                         the host syncs (drains tokens, evicts, admits)
                         only at window boundaries.  1 reproduces the
                         per-step engine exactly — higher values are
                         token-identical (asserted in tests) but amortize
                         dispatch + host transfers over the window.
    ``prefill_bucket`` — admission prompts are padded to a multiple of
                         this (rounded up to a page multiple; default
                         page_size) and same-bucket admissions prefill as
                         one batch.  Larger buckets mean fewer distinct
                         prefill shapes (fewer retraces) at the cost of
                         padded FLOPs.
    ``preempt``        — enable preempt-and-swap: when the waiting head
                         cannot be admitted and a strictly lower-priority
                         request is running, the victim's KV pages are
                         copied (MX codes still packed) to the host swap
                         store, its slot freed, and the request restored
                         page-for-page on re-admission — continuation is
                         token-identical to an unpreempted run (asserted
                         in tests/test_serve_preempt.py).
    ``prefix_cache``   — enable prefix sharing: finished prefills publish
                         their full KV pages into a trie keyed by page
                         token content (``repro.serve.prefix``); later
                         admissions map the longest cached prefix
                         read-only into their block table and prefill
                         only the uncached suffix, copy-on-write forking
                         any shared page they must write.  Outputs are
                         token-identical to ``prefix_cache=False`` (under
                         MX policies and fp-dense; asserted in tests) —
                         only the prefill compute and fresh-page demand
                         shrink.
    ``health_checks``  — numeric-health guards: every prefill and decode
                         window additionally reduces (in the same jit) a
                         per-slot non-finite-logits flag and an MX-block
                         poison flag (SCALE_NAN/SCALE_INF scale bytes in
                         the slot's live KV pages, a uint8 compare — no
                         dequantization).  A flagged slot is
                         *quarantined* at the window boundary: its
                         window tokens are suppressed, its pages freed,
                         and the request parked in ``scheduler.failed``
                         with a diagnostic — healthy slots stream on
                         token-identically (batch rows are independent).
    ``faults``         — optional ``serve.faults.FaultPlan`` consulted at
                         named sites (page_corrupt / swap_corrupt /
                         prefill_nan / kernel_fail / alloc_fail / stall)
                         for deterministic fault-injection tests and
                         recovery drills.  None (the default) adds no
                         per-step work.
    ``metrics``        — a shared :class:`~repro.obs.metrics
                         .MetricsRegistry`; None creates a private one.
                         Every serving counter (engine, scheduler, block
                         manager, prefix cache, swap store) lives in it,
                         and the legacy ``n_*`` attributes are
                         registry-backed views — equal to the registry
                         snapshot by construction.
    ``tracer``         — optional :class:`~repro.obs.trace.Tracer`:
                         per-request spans (queued / prefill / decode
                         windows / preempt / restore / quarantine /
                         retry) plus engine phase spans, recorded from
                         the stamps the engine already takes — zero
                         extra host syncs, token-identical on/off
                         (asserted in tests/test_obs_identity.py).
    ``obs_interval``   — sample the MX-health gauges (``mx.*``: scale
                         poison markers, saturation/clip and underflow
                         rates per KV role) every N sync windows; 0
                         (default) never samples.  Each sample is one
                         scalar device reduction + transfer.
    """

    _PHASES = ("prefill", "decode", "sync", "swap")

    def __init__(self, model: Model, params, *, max_slots: int = 8,
                 page_size: int = 16, max_len: int = 256,
                 num_pages: Optional[int] = None,
                 rules: Optional[Dict[str, Any]] = None,
                 gen: GenerationConfig = GenerationConfig(),
                 sync_every: int = 8,
                 prefill_bucket: Optional[int] = None,
                 prefix_cache: bool = False,
                 preempt: bool = False,
                 health_checks: bool = True,
                 faults: Optional[FaultPlan] = None,
                 metrics: Optional[MetricsRegistry] = None,
                 tracer: Optional[Tracer] = None,
                 obs_interval: int = 0):
        if not model.supports_paged():
            raise NotImplementedError(
                f"{model.cfg.name}: continuous batching needs a GQA "
                "decoder (no MLA / modality frontend)")
        if sync_every < 1:
            raise ValueError(f"sync_every must be >= 1, got {sync_every}")
        self.model = model
        self.params = params
        self.page_size = page_size
        self.sync_every = int(sync_every)
        pb = page_size if prefill_bucket is None else int(prefill_bucket)
        if pb < 1:
            raise ValueError(f"prefill_bucket must be >= 1, got {pb}")
        self.prefill_bucket = -(-pb // page_size) * page_size
        self.max_pages_per_slot = pages_needed(max_len, page_size)
        if num_pages is None:
            num_pages = 1 + max_slots * self.max_pages_per_slot
        # one registry for the whole serving stack: the block manager,
        # scheduler, prefix cache, and swap store all register their
        # series here, so registry.reset() restarts every measurement
        # window at once and snapshot() is the single exported view
        self.metrics = MetricsRegistry() if metrics is None else metrics
        self.tracer = tracer
        self.obs_interval = int(obs_interval)
        self._mx_health_jit = None       # built lazily on first sample
        self.blocks = BlockManager(num_pages, page_size, max_slots,
                                   self.max_pages_per_slot,
                                   metrics=self.metrics)
        self.prefix = PrefixCache(self.blocks, metrics=self.metrics) \
            if prefix_cache else None
        self.scheduler = Scheduler(max_slots, self.blocks,
                                   prefix=self.prefix,
                                   metrics=self.metrics)
        self.preempt = bool(preempt)
        self.health_checks = bool(health_checks)
        self.faults = faults
        self.swap_store = HostSwapStore(metrics=self.metrics)
        if faults is not None:
            # alloc_fail fires through the BlockManager's grant hook (only
            # non-trivial ensure() grants consult it — admission's reserved
            # allocations stay exact), swap_corrupt through the store's put
            self.blocks.fault_hook = (
                lambda n: faults.should_fire("alloc_fail") is not None)
            self.swap_store.faults = faults
        self.pool = model.init_paged_cache(num_pages, page_size)
        self.gen = gen
        self.rules = rules
        self._key = jax.random.PRNGKey(gen.seed)
        self._next_rid = 0
        self._cur_tok = np.zeros(max_slots, np.int32)
        self._lengths = np.zeros(max_slots, np.int32)
        self._remaining = np.zeros(max_slots, np.int32)
        # per-slot PRNG keys, folded from the engine key at admission and
        # evolved on device by sample_tokens
        self._slot_keys = jnp.zeros((max_slots, 2), jnp.uint32)
        # device-resident block table, re-uploaded only when the host
        # tables actually changed (admission / page grant / eviction)
        self._bt_version = -1
        self._bt_dev = None
        # engine counters (registry series; the legacy n_* attributes
        # below are property views over these, so bench rows, snapshot
        # capture/restore, and the registry snapshot can never diverge)
        m = self.metrics
        self._c_steps = m.counter(
            "engine.steps", "device decode steps (incl. masked tail)")
        self._c_syncs = m.counter(
            "engine.syncs", "host sync points (fused windows run)")
        self._c_generated = m.counter(
            "engine.generated_tokens", "tokens emitted to requests")
        self._c_prefill_tokens = m.counter(
            "engine.prefill_tokens", "unpadded prompt positions prefilled")
        self._c_cow = m.counter(
            "engine.cow_forks", "copy-on-write page forks")
        self._c_preempt = m.counter(
            "engine.preemptions", "requests swapped out to host")
        self._c_restores = m.counter(
            "engine.restores", "swapped requests restored to a slot")
        self._c_quar = m.counter(
            "engine.quarantined", "requests parked by the health guard")
        self._g_peak_mapped = m.gauge(
            "pages.peak_mapped", "peak distinct pages in slot tables")
        self._g_peak_shared = m.gauge(
            "pages.peak_shared", "peak pages mapped by >= 2 entries")
        # per-phase wall clock (bench_serve schema v2; "swap" is v4) —
        # one labeled float counter, surfaced as the ``phase`` dict
        self._c_phase = m.counter(
            "engine.phase_s", "wall seconds by engine phase")
        for k in self._PHASES:
            self._c_phase.inc(0.0, phase=k)
        self._h_window = m.histogram(
            "engine.window_steps", "decode steps fused per sync window")
        self.quarantined_in_step: List[Request] = []
        self._step_progress = False     # quarantine/swap counts as progress
        self._stall_abort = threading.Event()
        self.stall_aborted = False      # watchdog cut a stalled step short
        # latency-observability window start: requests finished before
        # this index in scheduler.finished predate the last reset_metrics
        # (warmup) and are excluded from finished_in_window summaries
        self._metrics_start = 0
        cfg = model.cfg
        self.vocab = cfg.vocab
        temperature = float(gen.temperature)
        health_on = self.health_checks

        def _ctx():
            return use_rules(rules) if rules is not None \
                else contextlib.nullcontext()

        def _prefill_scatter(params, tokens, lens, keys, pool, page_ids):
            """Batched bucket prefill fused with the page scatter: prefill
            G same-bucket prompts at once, scatter every request's pages
            (packing sub-byte codes on device) into the donated pool, and
            sample each request's first token from its own last prompt
            position — one host round-trip per bucket instead of three per
            request.  With health checks on, a per-request guard flag
            (non-finite last-position logits, or an MX poison marker in
            the just-scattered pages) rides along in the same transfer."""
            with _ctx():
                logits, cache, _ = model.prefill(
                    params, {"tokens": tokens}, max_len=tokens.shape[1])
                pool = model.scatter_prefill(pool, cache, page_ids)
                g = tokens.shape[0]
                last = logits[jnp.arange(g), lens - 1, :self.vocab]
                keys, first = sample_tokens(last, keys, temperature)
                if not health_on:
                    return first, keys, pool, jnp.zeros(g, bool)
                bad = ~jnp.all(jnp.isfinite(last), axis=-1)
                bad = bad | H.slot_scale_poison(pool, page_ids, lens, cfg)
                return first, keys, pool, bad

        def _suffix_prefill(params, tokens, starts, lens, keys, pool, bt):
            """Paged suffix prefill for G prefix-cache hits: compute only
            prompt positions [starts, lens) (the shared prefix pages are
            already resident), write their KV into the slots' private
            pages, and sample each request's first token from its last
            prompt position — the hit-path twin of _prefill_scatter
            (including the health-guard flag)."""
            with _ctx():
                logits, pool = model.paged_prefill_suffix(
                    params, tokens, starts, lens, pool, bt)
                g = tokens.shape[0]
                last = logits[jnp.arange(g), lens - starts - 1,
                              :self.vocab]
                keys, first = sample_tokens(last, keys, temperature)
                if not health_on:
                    return first, keys, pool, jnp.zeros(g, bool)
                bad = ~jnp.all(jnp.isfinite(last), axis=-1)
                bad = bad | H.slot_scale_poison(pool, bt, lens, cfg)
                return first, keys, pool, bad

        def _copy_pages(pool, src, dst):
            """Batched COW: duplicate shared pages src -> dst before a
            writer touches them."""
            return model.copy_pool_pages(pool, src, dst)

        def _swap_in(pool, page_ids, host):
            """Batched restore: scatter a swap-store snapshot back into
            freshly allocated pages (donated pool — no double buffer)."""
            return scatter_pages(pool, page_ids, host)

        def _multi(params, tok, pool, bt, lengths, remaining, keys,
                   n_steps):
            """Fused decode window.  With health checks on, two extra (B,)
            flags ride the window's one host transfer: ``bad_logits``
            (any live step saw non-finite logits) and ``poison`` (an MX
            scale byte at/above the mode's poison threshold inside the
            slot's live positions — checked on the post-window pool)."""
            with _ctx():
                if not health_on:
                    toks, pool2, ln, rem, keys2 = \
                        model.paged_decode_multi_step(
                            params, tok, pool, bt, lengths, remaining,
                            keys, n_steps=n_steps,
                            temperature=temperature,
                            trash_page=TRASH_PAGE)
                    z = jnp.zeros(tok.shape, bool)
                    return toks, pool2, ln, rem, keys2, z, z
                toks, pool2, ln, rem, keys2, bad_logits = \
                    model.paged_decode_multi_step(
                        params, tok, pool, bt, lengths, remaining, keys,
                        n_steps=n_steps, temperature=temperature,
                        trash_page=TRASH_PAGE, health=True)
                poison = H.slot_scale_poison(pool2, bt, ln, cfg)
                return toks, pool2, ln, rem, keys2, bad_logits, poison

        # donate the pool: every decode window / prefill scatter rewrites
        # it wholesale, and without donation XLA double-buffers the
        # dominant serving allocation (the CPU backend ignores donation
        # with a warning; on TPU this halves peak KV memory)
        self._fns = {"prefill_scatter": _prefill_scatter,
                     "suffix_prefill": _suffix_prefill,
                     "copy_pages": _copy_pages, "swap_in": _swap_in,
                     "multi": _multi}
        self._rejit()

    def _rejit(self) -> None:
        """(Re)wrap the raw closures in fresh jax.jit caches.  Called once
        at construction and again after a kernel degradation or an armed
        ``backend.inject_failure`` — supervised dispatch decides the
        kernel-vs-dense path at *trace* time, so the next call must
        re-trace for the degraded path to take effect."""
        f = self._fns
        self._prefill_scatter = jax.jit(f["prefill_scatter"],
                                        donate_argnums=(4,))
        self._suffix_prefill = jax.jit(f["suffix_prefill"],
                                       donate_argnums=(5,))
        self._copy_pages = jax.jit(f["copy_pages"], donate_argnums=(0,))
        self._swap_in = jax.jit(f["swap_in"], donate_argnums=(0,))
        self._multi = jax.jit(f["multi"], static_argnums=(7,),
                              donate_argnums=(2,))

    # ------------------------------------- registry-backed counter views
    # The legacy attribute names stay the API (bench_serve, snapshot
    # capture/restore, and tests read/write them), but the storage is the
    # shared MetricsRegistry — "engine counters equal the registry
    # snapshot" is true by construction.  Setters exist because snapshot
    # restore legitimately rewinds them.
    @property
    def n_steps(self) -> int:
        return int(self._c_steps.value())

    @n_steps.setter
    def n_steps(self, v: int) -> None:
        self._c_steps.set(int(v))

    @property
    def n_syncs(self) -> int:
        return int(self._c_syncs.value())

    @n_syncs.setter
    def n_syncs(self, v: int) -> None:
        self._c_syncs.set(int(v))

    @property
    def n_generated(self) -> int:
        return int(self._c_generated.value())

    @n_generated.setter
    def n_generated(self, v: int) -> None:
        self._c_generated.set(int(v))

    @property
    def prefill_tokens_computed(self) -> int:
        return int(self._c_prefill_tokens.value())

    @prefill_tokens_computed.setter
    def prefill_tokens_computed(self, v: int) -> None:
        self._c_prefill_tokens.set(int(v))

    @property
    def n_cow_forks(self) -> int:
        return int(self._c_cow.value())

    @n_cow_forks.setter
    def n_cow_forks(self, v: int) -> None:
        self._c_cow.set(int(v))

    @property
    def n_preemptions(self) -> int:
        return int(self._c_preempt.value())

    @n_preemptions.setter
    def n_preemptions(self, v: int) -> None:
        self._c_preempt.set(int(v))

    @property
    def n_restores(self) -> int:
        return int(self._c_restores.value())

    @n_restores.setter
    def n_restores(self, v: int) -> None:
        self._c_restores.set(int(v))

    @property
    def n_quarantined(self) -> int:
        return int(self._c_quar.value())

    @n_quarantined.setter
    def n_quarantined(self, v: int) -> None:
        self._c_quar.set(int(v))

    @property
    def peak_mapped_pages(self) -> int:
        return int(self._g_peak_mapped.value())

    @peak_mapped_pages.setter
    def peak_mapped_pages(self, v: int) -> None:
        self._g_peak_mapped.set(int(v))

    @property
    def peak_shared_pages(self) -> int:
        return int(self._g_peak_shared.value())

    @peak_shared_pages.setter
    def peak_shared_pages(self, v: int) -> None:
        self._g_peak_shared.set(int(v))

    @property
    def phase(self) -> Dict[str, float]:
        """Per-phase wall clock as a plain dict (bench_serve reads it;
        the storage is the labeled ``engine.phase_s`` counter)."""
        return {k: float(self._c_phase.value(phase=k))
                for k in self._PHASES}

    @phase.setter
    def phase(self, d: Dict[str, float]) -> None:
        for k in self._PHASES:
            self._c_phase.set(float(d.get(k, 0.0)), phase=k)

    def _phase_add(self, k: str, dt: float) -> None:
        # negative clock skew must not trip the counter's monotone check
        self._c_phase.inc(max(0.0, dt), phase=k)

    # ------------------------------------------------------------ queries
    @property
    def kv_pool_nbytes(self) -> int:
        """Allocated page-pool bytes (summed over layers; under a per-layer
        ``PolicyTable`` each layer's pool is sized by its own specs)."""
        return int(sum(np.prod(leaf.shape) * leaf.dtype.itemsize
                       for leaf in jax.tree_util.tree_leaves(self.pool)))

    @property
    def weight_pool_nbytes(self) -> int:
        """Serve-time weight HBM bytes as stored: after
        ``Model.quantize_weights`` the MXWeight leaves flatten to uint8
        codes (bit-packed for sub-byte formats) + E8M0 scales, so this
        reports the ``spec.storage_nbytes`` accounting; fp params count
        at their dtype width."""
        return params_nbytes(self.params)

    @property
    def kv_pool_bytes_effective(self) -> int:
        """Bytes of *distinct* pages the serving working set peaked at —
        peak pages mapped by any slot's block table, times the summed
        per-page bytes across layer pools.  Shared prefix pages count
        once however many slots map them (trie-only pins don't count:
        retention is a cache policy, not working-set demand)."""
        return self.peak_mapped_pages \
            * (self.kv_pool_nbytes // self.blocks.num_pages)

    @property
    def prefix_hit_rate(self) -> float:
        """Fraction of admissions that matched a non-empty cached prefix
        (0.0 with prefix caching off or before any admission)."""
        if self.prefix is None or self.prefix.lookups == 0:
            return 0.0
        return self.prefix.hits / self.prefix.lookups

    def _note_page_stats(self) -> None:
        self._g_peak_mapped.set_max(self.blocks.mapped_pages)
        self._g_peak_shared.set_max(self.blocks.shared_pages)

    @property
    def finished_in_window(self) -> List[Request]:
        """Requests finished since the last ``reset_metrics`` — the
        population latency summaries and bench rows must draw from, so a
        warmup request's TTFT/ITL samples can't leak into steady state."""
        return self.scheduler.finished[self._metrics_start:]

    def reset_metrics(self) -> None:
        """Zero the serving counters, peaks, latency window, and swap
        traffic for a steady-state measurement window (e.g. after a
        warmup request has populated the prefix trie).  The trie, page
        pool, swap-store *residents*, and jitted closures stay warm; only
        the accounting restarts.  Requests finished before the reset drop
        out of ``finished_in_window``, so stale hit-rate or TTFT samples
        cannot survive warmup excision."""
        # one call restarts every subsystem's series at once (engine,
        # scheduler, block manager, prefix cache, swap store — they all
        # live in the shared registry), then the swap store re-anchors
        # its resident-bytes peak to what is still held
        self.metrics.reset()
        self._metrics_start = len(self.scheduler.finished)
        self.swap_store.reset_counters()

    # ------------------------------------------------------------ requests
    def add_request(self, prompt, max_new_tokens: int, *,
                    priority: int = 0,
                    deadline_s: Optional[float] = None,
                    arrival_t: Optional[float] = None) -> int:
        """Queue a prompt; returns the request id.  Admission happens on a
        subsequent ``step()`` when a slot and enough pages are free, in
        (priority, deadline, arrival) order — ``priority`` 0 is the most
        urgent class, ``deadline_s`` an optional TTFT target used for EDF
        ordering within the class and SLO-attainment reporting.
        ``arrival_t`` (a ``time.perf_counter`` stamp) defaults to now;
        the async front end passes the submission-time stamp explicitly
        so queueing delay counts against TTFT.  Raises ValueError (from
        ``Scheduler.submit``) when the sequence can never fit a slot or
        the pool."""
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1 (prefill always "
                             "emits the first generated token)")
        req = Request(rid=self._next_rid, prompt=prompt,
                      max_new_tokens=max_new_tokens, priority=priority,
                      deadline_s=deadline_s,
                      arrival_t=(time.perf_counter()
                                 if arrival_t is None else arrival_t))
        self.scheduler.submit(req)              # validates capacity
        self._next_rid += 1
        if self.tracer is not None:
            self.tracer.begin("request", cat="request", rid=req.rid,
                              ts=req.arrival_t,
                              prompt_len=int(req.prompt_len),
                              max_new_tokens=int(max_new_tokens),
                              priority=int(priority))
            self.tracer.begin("queued", cat="request", rid=req.rid,
                              ts=req.arrival_t)
        return req.rid

    # ---------------------------------------------------------- the engine
    def step(self) -> List[Tuple[int, int]]:
        """One host sync cycle: admit what fits (bucket-batched prefill),
        run one fused decode window of up to ``sync_every`` device steps;
        returns the (request id, token) pairs emitted this cycle in step
        order (admissions emit their prefill token here too).

        Recovery semantics: a slot whose health guard trips is
        quarantined (tokens suppressed, pages freed, request parked in
        ``scheduler.failed``); a mid-window page-grant failure swaps the
        starved slot out and retries next step; a kernel launch failure
        degrades that op to its dense path (``kernels.backend``) and the
        closures re-trace.  ``quarantined_in_step`` holds this cycle's
        quarantined requests for the front end's retry budget."""
        emitted: List[Tuple[int, int]] = []
        self.quarantined_in_step = []
        self._step_progress = False
        if self.faults is not None:
            self._consult_step_faults()
            if self.stall_aborted:
                return emitted          # watchdog cut the stall short
        if self.preempt:
            # swap out one victim at a time until the waiting head fits
            # (or no strictly lower-priority runner remains); the freed
            # slots/pages are re-granted by the admit() right below
            while True:
                victim = self.scheduler.pick_victim()
                if victim is None:
                    break
                self._swap_out(victim)
        t0 = time.perf_counter()
        admitted = self.scheduler.admit()
        t_adm = time.perf_counter()
        self._phase_add("sync", t_adm - t0)
        if self.tracer is not None:
            for r in admitted:
                # re-admissions (restore / retry) re-opened "queued";
                # a track missing it was reconciled by a snapshot
                # restore and needs no end here
                if self.tracer.top(r.rid) == "queued":
                    self.tracer.end("queued", cat="request",
                                    rid=r.rid, ts=t_adm)
                self.tracer.instant(
                    "admitted", cat="request", rid=r.rid, ts=t_adm,
                    slot=int(r.slot),
                    matched_tokens=int(r.matched_tokens),
                    restored=r.rid in self.swap_store)
        if admitted:
            self._batched_prefill(admitted, emitted)
        t0 = time.perf_counter()
        if not self.scheduler.running:
            self._phase_add("sync", time.perf_counter() - t0)
            return emitted
        try:
            window = self.scheduler.plan_window(self._lengths,
                                                self.sync_every)
        except PageGrantError as e:
            # a page grant failed mid-window (alloc_fail injection or a
            # genuinely starved pool): swap the starved slot out instead
            # of crashing — its pages free up and the request re-enters
            # the queue at its original rank
            self._swap_out(self.scheduler.running[e.slot])
            self._phase_add("sync", time.perf_counter() - t0)
            return emitted
        self._note_page_stats()             # post-grant working set
        snapshot = sorted(self.scheduler.running.items())
        rem0 = {slot: req.remaining for slot, req in snapshot}
        bt = self._device_tables()
        t1 = time.perf_counter()
        toks, self.pool, _, _, self._slot_keys, badl, poison = \
            self._multi(
                self.params, jnp.asarray(self._cur_tok), self.pool, bt,
                jnp.asarray(self._lengths), jnp.asarray(self._remaining),
                self._slot_keys, window)
        toks = np.asarray(toks)         # the one host transfer per window
        if self.health_checks:
            badl = np.asarray(badl)
            poison = np.asarray(poison)
            bad = badl | poison
        else:
            bad = np.zeros(toks.shape[1], bool)
        t2 = time.perf_counter()
        self._c_steps.inc(window)
        self._c_syncs.inc()
        self._h_window.observe(window)
        if self.tracer is not None:
            # both spans reuse the window's two existing stamps — the
            # tracer adds no host sync of its own
            self.tracer.span("decode_window", t0=t1, t1=t2,
                             steps=int(window), live=len(snapshot))
            for slot, req in snapshot:
                self.tracer.span(
                    "decode", cat="request", rid=req.rid, t0=t1, t1=t2,
                    steps=int(min(window, rem0[slot])), slot=int(slot))
        if self.obs_interval \
                and self.n_syncs % self.obs_interval == 0:
            self._sample_mx_health()
        for t in range(window):
            for slot, req in snapshot:
                if bad[slot]:
                    continue            # quarantined below; no tokens out
                if t < rem0[slot]:
                    tok = int(toks[t, slot])
                    req.out.append(tok)
                    # tokens become *visible* at the sync boundary: every
                    # token of a fused window shares its drain stamp
                    req.t_tokens.append(t2)
                    emitted.append((req.rid, tok))
                    self._c_generated.inc()
        for slot, req in snapshot:
            if bad[slot]:
                why = ("non-finite logits in decode window"
                       if badl[slot]
                       else "MX scale poison marker in KV pages")
                self._quarantine(req, f"numeric-health guard: {why}")
                continue
            take = min(window, rem0[slot])
            self._lengths[slot] += take
            self._remaining[slot] -= take
            if take:
                self._cur_tok[slot] = toks[take - 1, slot]
            if req.done:
                self._release(req)
        self._phase_add("decode", t2 - t1)
        self._phase_add("sync", (t1 - t0) + (time.perf_counter() - t2))
        return emitted

    def _consult_step_faults(self) -> None:
        """Step-scoped fault-injection sites (no-op without a plan):
        ``stall`` sleeps the host loop (cooperatively — ``abort_stall``
        cuts it short, as the front end's watchdog does before a
        snapshot restore); ``kernel_fail`` arms a one-shot paged-attention
        launch failure and forces the re-trace that lets supervised
        dispatch degrade it; ``page_corrupt`` overwrites one live KV
        position's scale bytes with SCALE_NAN markers — exactly what a
        faulty converter or DMA would leave behind."""
        plan = self.faults
        self.stall_aborted = False
        f = plan.should_fire("stall")
        if f is not None:
            if self.tracer is not None:
                self.tracer.instant("fault:stall", stall_s=f.stall_s)
            deadline = time.monotonic() + f.stall_s
            while time.monotonic() < deadline:
                if self._stall_abort.is_set():
                    self._stall_abort.clear()
                    self.stall_aborted = True
                    return
                time.sleep(0.002)
        if plan.should_fire("kernel_fail") is not None:
            if self.tracer is not None:
                self.tracer.instant("fault:kernel_fail", op="paged_attn")
            backend.inject_failure("paged_attn")
            self._rejit()
        f = plan.should_fire("page_corrupt")
        if f is not None and self.scheduler.running:
            cands = sorted(self.scheduler.running.items())
            if f.rid is not None:
                cands = [(s, r) for s, r in cands if r.rid == f.rid]
            if cands:
                rng = plan.rng("page_corrupt")
                slot, _ = cands[int(rng.integers(len(cands)))]
                length = int(self._lengths[slot])
                if length > 0:
                    pos = int(rng.integers(length))
                    pid = self.blocks.slot_page_ids(slot)[
                        pos // self.page_size]
                    self.pool = F.poison_pool_pages(
                        self.pool, [pid], offset=pos % self.page_size)
                    if self.tracer is not None:
                        self.tracer.instant("fault:page_corrupt",
                                            page=int(pid), pos=pos)

    def _quarantine(self, req: Request, diag: str) -> None:
        """Park a guard-flagged request: free its slot + pages, record the
        diagnostic, suppress its window tokens (already skipped by the
        caller).  Healthy slots are untouched — batch rows are
        independent, so their token streams are identical to a run
        without the poisoned neighbor (asserted in tests)."""
        slot = req.slot
        ids = self.blocks.slot_page_ids(slot)
        self.scheduler.fail(req, diag)
        # quarantine hygiene: the request's now-dead pages hold the very
        # poison that tripped the guard — scrub them to the fresh-page
        # all-zeros state before the allocator can recycle them into a
        # healthy slot (pages still shared/pinned stay untouched: another
        # owner's scan will judge them)
        dead = [pg for pg in ids if self.blocks.page_refcount(pg) == 0]
        if dead:
            self.pool = F.scrub_pool_pages(self.pool, dead)
        req.t_finished = time.perf_counter()
        self._c_quar.inc()
        if self.tracer is not None:
            # leave only the per-request root open: the front end either
            # retries (re-opening "queued") or closes the track with a
            # terminal status once the retry budget is spent
            self.tracer.unwind(req.rid, ts=req.t_finished, keep=1)
            self.tracer.instant("quarantine", cat="request",
                                rid=req.rid, ts=req.t_finished,
                                error=diag)
        self.quarantined_in_step.append(req)
        self._step_progress = True
        self._cur_tok[slot] = 0
        self._lengths[slot] = 0
        self._remaining[slot] = 0

    def retry_request(self, req: Request) -> None:
        """Re-queue a quarantined (failed) request for another attempt —
        the engine half of the front end's retry budget.  The request
        keeps its rid, so its per-slot PRNG key re-derives identically
        and a healthy replay is token-identical at any temperature."""
        self.scheduler.requeue(req)
        if self.tracer is not None:
            if not self.tracer.open_spans(req.rid):
                # track was closed by a snapshot-restore reconciliation;
                # re-open the per-request root for the fresh attempt
                self.tracer.begin("request", cat="request", rid=req.rid,
                                  prompt_len=int(req.prompt_len),
                                  max_new_tokens=int(req.max_new_tokens),
                                  priority=int(req.priority))
            self.tracer.instant("retry", cat="request", rid=req.rid,
                                attempt=int(req.n_retries))
            self.tracer.begin("queued", cat="request", rid=req.rid)

    def resubmit(self, req: Request) -> None:
        """Re-enter a request the engine no longer tracks (post-snapshot
        arrivals discarded by a restore): reset its generation state and
        queue it as if newly submitted, keeping its rid."""
        req.state = RequestState.WAITING
        req.slot = -1
        req.out = []
        req.t_tokens = []
        req.t_finished = None
        req.error = None
        req.matched_tokens = 0
        req.cow_pending = 0
        req.swap_pages = 0
        self.scheduler.submit(req)
        if self.tracer is not None:
            if not self.tracer.open_spans(req.rid):
                self.tracer.begin("request", cat="request", rid=req.rid,
                                  prompt_len=int(req.prompt_len),
                                  max_new_tokens=int(req.max_new_tokens),
                                  priority=int(req.priority))
            else:
                self.tracer.unwind(req.rid, keep=1)
            self.tracer.instant("resubmit", cat="request", rid=req.rid)
            self.tracer.begin("queued", cat="request", rid=req.rid)

    def abort_stall(self) -> None:
        """Cut a faulted ``stall`` sleep short (watchdog thread-safe)."""
        self._stall_abort.set()

    def run(self) -> Dict[int, np.ndarray]:
        """Drive ``step()`` until every queued request finishes; returns
        {request id: generated tokens} for the requests finished by this
        call (the engine is reusable: jitted closures stay warm across
        batches).  Quarantined requests are *not* in the result — they
        sit in ``scheduler.failed`` with ``req.error`` set."""
        start = len(self.scheduler.finished)
        while self.scheduler.has_work():
            emitted = self.step()
            if not emitted and not self.scheduler.running \
                    and not self._step_progress:
                raise RuntimeError(
                    "no progress: waiting requests cannot be admitted")
        return {r.rid: np.asarray(r.out, np.int32)
                for r in self.scheduler.finished[start:]}

    # ------------------------------------------------------------ internals
    def _device_tables(self) -> jax.Array:
        """Device-side block table, refreshed only when the host tables
        changed (BlockManager.version) — steady-state decode windows skip
        the upload entirely."""
        if self._bt_version != self.blocks.version:
            self._bt_dev = jnp.asarray(self.blocks.tables)
            self._bt_version = self.blocks.version
        return self._bt_dev

    def _batched_prefill(self, admitted: List[Request],
                         emitted: List[Tuple[int, int]]) -> None:
        """Prefill admissions bucket-by-bucket: same-padded-length prompts
        run as one batch, and the whole bucket's pages land in a single
        donated prefill+scatter+sample call.

        Prefix-cache hits take the suffix path instead: any owed COW fork
        runs first (one batched device page copy for all hits), then each
        bucket of same-padded *suffix* lengths prefills only its uncached
        positions through the paged pool.  Cold admissions keep the exact
        contiguous prefill+scatter path of ``prefix_cache=False``."""
        restored_rids = {r.rid for r in admitted
                         if r.rid in self.swap_store}
        if restored_rids:
            self._restore_swapped(
                [r for r in admitted if r.rid in restored_rids])
            admitted = [r for r in admitted
                        if r.rid not in restored_rids]
        if not admitted:
            return
        t0 = time.perf_counter()
        cold = [r for r in admitted if r.matched_tokens == 0]
        hits = [r for r in admitted if r.matched_tokens > 0]
        if self.tracer is not None:
            # an open pair, not a retroactive span: a first-decode page
            # grant can swap a request out *inside* _finish_prefill, and
            # that swap_out span must nest within the batch span for the
            # engine track's clock to stay monotone
            self.tracer.begin("prefill_batch", ts=t0,
                              cold=len(cold), hits=len(hits))
        groups: Dict[int, List[Request]] = {}
        for req in cold:
            lp = -(-req.prompt_len // self.prefill_bucket) \
                * self.prefill_bucket
            groups.setdefault(lp, []).append(req)
        for lp, reqs in sorted(groups.items()):
            g = len(reqs)
            toks = np.zeros((g, lp), np.int32)
            lens = np.zeros(g, np.int32)
            slots = np.array([r.slot for r in reqs])
            for i, r in enumerate(reqs):
                toks[i, :r.prompt_len] = r.prompt
                lens[i] = r.prompt_len
            # one vmapped fold per bucket (not one dispatch per request):
            # each slot's key derives from its request id alone, so key
            # evolution is independent of admission grouping
            fresh = jax.vmap(lambda r: jax.random.fold_in(self._key, r))(
                jnp.asarray([r.rid for r in reqs], jnp.uint32))
            # rows are trash-padded past each request's allocation, so a
            # bucket-padded prompt's excess pages scatter harmlessly
            npr = lp // self.page_size
            page_ids = self.blocks.tables[slots, :npr]
            tb = time.perf_counter()
            first, keys, self.pool, bad = self._prefill_scatter(
                self.params, jnp.asarray(toks), jnp.asarray(lens),
                fresh, self.pool, jnp.asarray(page_ids))
            self._finish_prefill(reqs, slots, keys, first, emitted, bad,
                                 t0=tb)
        if hits:
            self._cow_forks(hits)
            self._hit_prefill(hits, emitted)
        self._note_page_stats()
        t1 = time.perf_counter()
        self._phase_add("prefill", t1 - t0)
        if self.tracer is not None:
            self.tracer.end("prefill_batch", ts=t1)

    def _cow_forks(self, hits: List[Request]) -> None:
        """Fork every shared page a hit's suffix prefill will write (only
        a fully-cached prompt has one: its last page is recomputed at
        position L-1 to seed the first token) and batch-copy the page
        contents on device before any write lands."""
        src, dst = [], []
        for r in hits:
            for idx in self.blocks.cow_targets(r.slot, r.prefill_start,
                                               r.prompt_len):
                pair = self.blocks.fork_page(r.slot, idx)
                assert pair is not None, \
                    "admission reserved the copy-on-write page"
                src.append(pair[0])
                dst.append(pair[1])
            r.cow_pending = 0
        if src:
            self._c_cow.inc(len(src))
            self.pool = self._copy_pages(
                self.pool, jnp.asarray(src, jnp.int32),
                jnp.asarray(dst, jnp.int32))

    def _hit_prefill(self, hits: List[Request],
                     emitted: List[Tuple[int, int]]) -> None:
        """Suffix-only prefill for prefix-cache hits, bucketed by padded
        suffix length."""
        groups: Dict[int, List[Request]] = {}
        for req in hits:
            ls = -(-(req.prompt_len - req.prefill_start)
                   // self.prefill_bucket) * self.prefill_bucket
            groups.setdefault(ls, []).append(req)
        bt = self._device_tables()      # post-COW tables
        for ls, reqs in sorted(groups.items()):
            g = len(reqs)
            toks = np.zeros((g, ls), np.int32)
            starts = np.zeros(g, np.int32)
            lens = np.zeros(g, np.int32)
            slots = np.array([r.slot for r in reqs])
            for i, r in enumerate(reqs):
                s0 = r.prefill_start
                toks[i, :r.prompt_len - s0] = r.prompt[s0:]
                starts[i] = s0
                lens[i] = r.prompt_len
            fresh = jax.vmap(lambda r: jax.random.fold_in(self._key, r))(
                jnp.asarray([r.rid for r in reqs], jnp.uint32))
            tb = time.perf_counter()
            first, keys, self.pool, bad = self._suffix_prefill(
                self.params, jnp.asarray(toks), jnp.asarray(starts),
                jnp.asarray(lens), fresh, self.pool,
                bt[jnp.asarray(slots)])
            self._finish_prefill(reqs, slots, keys, first, emitted, bad,
                                 t0=tb)

    # ------------------------------------------------- preempt-and-swap
    def _swap_out(self, req: Request) -> None:
        """Copy ``req``'s KV pages (MX codes still packed) to the host
        swap store and free its slot — the device side of
        ``Scheduler.preempt``.  The saved per-slot PRNG key plus the
        request's own token history make the later restore
        token-identical."""
        t0 = time.perf_counter()
        slot = req.slot
        ids = self.blocks.slot_page_ids(slot)
        host, nbytes = gather_pages(self.pool, ids)
        self.swap_store.put(req.rid, SwapData(
            pages=host, n_pages=len(ids),
            length=int(self._lengths[slot]),
            key=np.asarray(self._slot_keys[slot]), nbytes=nbytes))
        req.swap_pages = len(ids)
        self.scheduler.preempt(req)
        self._c_preempt.inc()
        self._step_progress = True
        self._cur_tok[slot] = 0
        self._lengths[slot] = 0
        self._remaining[slot] = 0
        t1 = time.perf_counter()
        self._phase_add("swap", t1 - t0)
        if self.tracer is not None:
            self.tracer.span("swap_out", t0=t0, t1=t1,
                             pages=len(ids), nbytes=nbytes)
            self.tracer.instant("preempt", cat="request", rid=req.rid,
                                ts=t1, pages=len(ids))
            self.tracer.begin("queued", cat="request", rid=req.rid,
                              ts=t1)

    def _restore_swapped(self, reqs: List[Request]) -> None:
        """Re-admission of preempted requests: scatter their swap-store
        snapshots into the freshly allocated private pages (one batched
        device call for all restores this cycle) and rebuild the slot
        state — current token, cache length, budget, and the PRNG key
        exactly as saved, so the continuation is bit-identical.  No
        prefill runs and no token is emitted (the first token was already
        streamed before the preemption)."""
        t0 = time.perf_counter()
        ids_all: List[int] = []
        datas = []
        for r in reqs:
            data = self.swap_store.pop(r.rid)
            slot_ids = self.blocks.slot_page_ids(r.slot)
            assert len(slot_ids) == data.n_pages, \
                "restore admission allocated the swapped page count"
            ids_all.extend(slot_ids)
            datas.append(data)
        self.pool = self._swap_in(
            self.pool, jnp.asarray(ids_all, jnp.int32),
            concat_snapshots([d.pages for d in datas]))
        for r, data in zip(reqs, datas):
            slot = r.slot
            # out[-1] is the last sampled (not yet decoded) token; the
            # cache holds prompt + out[:-1] = data.length positions
            self._cur_tok[slot] = r.out[-1]
            self._lengths[slot] = data.length
            self._remaining[slot] = r.max_new_tokens - len(r.out)
            self._slot_keys = self._slot_keys.at[slot].set(
                jnp.asarray(data.key))
            r.swap_pages = 0
            self._c_restores.inc()
        self._note_page_stats()
        t1 = time.perf_counter()
        self._phase_add("swap", t1 - t0)
        if self.tracer is not None:
            self.tracer.span("swap_restore", t0=t0, t1=t1,
                             requests=len(reqs), pages=len(ids_all))
            for r, data in zip(reqs, datas):
                self.tracer.span("restore", cat="request", rid=r.rid,
                                 t0=t0, t1=t1, pages=data.n_pages,
                                 slot=int(r.slot))

    def _finish_prefill(self, reqs: List[Request], slots, keys, first,
                        emitted: List[Tuple[int, int]],
                        bad=None, t0: Optional[float] = None) -> None:
        """Common admission epilogue: install per-slot keys, emit each
        request's first token, account computed prefill positions, and
        grant the first decode write's page.  A request whose prefill
        health flag (``bad``) is set — or whose ``prefill_nan`` fault
        fires here — is quarantined instead of emitting; a failed
        first-decode page grant (alloc_fail) swaps the request out to
        resume when pages free up.  ``t0`` is the bucket's pre-dispatch
        stamp — with a tracer on, each request gets a complete
        "prefill" span from it to the bucket's sync point."""
        self._slot_keys = self._slot_keys.at[slots].set(keys)
        first = np.asarray(first)
        bad = None if bad is None else np.asarray(bad).copy()
        now = time.perf_counter()
        if self.tracer is not None:
            for r in reqs:
                self.tracer.span(
                    "prefill", cat="request", rid=r.rid, t0=t0, t1=now,
                    tokens=int(r.prompt_len - r.prefill_start),
                    suffix=bool(r.prefill_start))
        for i, r in enumerate(reqs):
            slot = r.slot
            if self.faults is not None and \
                    self.faults.should_fire("prefill_nan",
                                            rid=r.rid) is not None:
                # poison exactly the pages holding the prompt's KV — the
                # padded tail of the page_ids row may alias the trash
                # page, which every slot reads
                n_live = pages_needed(r.prompt_len, self.page_size)
                ids = self.blocks.slot_page_ids(slot)[:n_live]
                self.pool = F.poison_pool_pages(self.pool, ids)
                if bad is not None:
                    bad[i] = True
            if bad is not None and bad[i]:
                self._c_prefill_tokens.inc(
                    r.prompt_len - r.prefill_start)
                self._quarantine(
                    r, "numeric-health guard: non-finite logits or MX "
                       "poison marker at prefill")
                continue
            tok = int(first[i])
            self._cur_tok[slot] = tok
            self._lengths[slot] = r.prompt_len
            self._remaining[slot] = r.max_new_tokens - 1
            self._c_prefill_tokens.inc(r.prompt_len - r.prefill_start)
            if self.prefix is not None:
                # publish the prompt's full pages (an existing trie chain
                # dedupes; new nodes pin this slot's private pages)
                n_full = r.prompt_len // self.page_size
                self.prefix.insert(
                    r.prompt, self.blocks.slot_page_ids(slot)[:n_full])
            r.out.append(tok)
            r.t_tokens.append(now)      # first-token (TTFT) stamp
            self._c_generated.inc()
            emitted.append((r.rid, tok))
            if r.done:
                self._release(r)
            elif not self.blocks.ensure(slot, r.prompt_len + 1):
                # the decode write position may sit in a page past the
                # prompt's allocation; a failed grant (alloc_fail) parks
                # the request in the swap store to resume later
                self._swap_out(r)

    def _release(self, req: Request) -> None:
        slot = req.slot
        if self.prefix is not None:
            # publish the finished sequence's full pages before the decref:
            # positions [0, L + gen - 1) hold KV for prompt + out[:-1]
            # (the last sampled token is never fed back), and those pages
            # are stable now — a later prompt extending this conversation
            # prefix-matches them
            seq = np.concatenate(
                [req.prompt, np.asarray(req.out[:-1], np.int32)])
            n_full = len(seq) // self.page_size
            self.prefix.insert(
                seq, self.blocks.slot_page_ids(slot)[:n_full])
        self.scheduler.evict(req)
        req.t_finished = time.perf_counter()
        if self.tracer is not None:
            self.tracer.unwind(req.rid, ts=req.t_finished, keep=1)
            self.tracer.close_track(req.rid, ts=req.t_finished,
                                    status="finished",
                                    tokens=len(req.out))
        self._cur_tok[slot] = 0
        self._lengths[slot] = 0
        self._remaining[slot] = 0

    # --------------------------------------------------- MX-health gauges
    def _sample_mx_health(self) -> None:
        """One device reduction over the live KV pages -> ``mx.*`` gauges
        per KV role: scale bytes scanned, poison-marker count, and the
        shared-scale saturation (== block clip under a shared scale) and
        underflow rates.  Jitted once; the tables/lengths upload rides
        the existing device copies."""
        if self._mx_health_jit is None:
            cfg = self.model.cfg
            self._mx_health_jit = jax.jit(
                lambda pool, bt, lens: sample_mx_health(
                    pool, bt, lens, cfg))
        stats = self._mx_health_jit(self.pool, self._device_tables(),
                                    jnp.asarray(self._lengths))
        stats = jax.tree_util.tree_map(int, stats)
        m = self.metrics
        for role, st in stats.items():
            nb = st["scale_bytes"]
            m.gauge("mx.scale_bytes",
                    "E8M0 scale bytes in live KV pages"
                    ).set(nb, role=role)
            m.gauge("mx.poison_markers",
                    "scale bytes at/above the mode's poison threshold"
                    ).set(st["poison"], role=role)
            m.gauge("mx.saturation_rate",
                    "fraction of blocks at the max legal shared scale"
                    ).set(rate(st["saturated"], nb), role=role)
            m.gauge("mx.clip_rate",
                    "fraction of blocks clipping elements (== the "
                    "saturation rate: a shared scale at top-of-range "
                    "is exactly the block-clip indicator)"
                    ).set(rate(st["saturated"], nb), role=role)
            m.gauge("mx.underflow_rate",
                    "fraction of blocks with a zero shared scale"
                    ).set(rate(st["underflow"], nb), role=role)

    def finalize_trace(self) -> None:
        """Close every request track still open (queued, swapped-out, or
        failed-without-retry requests at shutdown) so the exported trace
        validates: failed requests close with status "failed", the rest
        "aborted".  Idempotent; the launcher calls it before writing the
        trace files."""
        if self.tracer is None:
            return
        failed = {r.rid for r in self.scheduler.failed}
        for rid in self.tracer.open_tracks():
            if rid is None:
                continue
            self.tracer.close_track(
                rid, status="failed" if rid in failed else "aborted")
        self.tracer.close_track(None)
