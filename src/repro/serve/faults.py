"""Seeded, deterministic fault injection for the serving stack.

A :class:`FaultPlan` is a list of :class:`Fault` records the engine and
front end consult at **named injection sites**.  Every consultation is
counted, and a fault fires when its site matches and either its ``nth``
occurrence is reached or it is marked ``always`` — so a plan replays
bit-identically run after run, which is what makes every recovery path
*provable* in tests (the same seeded plan must yield the same quarantine
set, the same retry outcomes, the same restored tokens) instead of
hoped-for.

Sites (who consults, what the fault does):

``page_corrupt``   — engine, once per ``step()``: overwrite one live
                     token's MX scale bytes in the target request's pages
                     with the marker value (a real bit-flip in a scale
                     page is detected by exactly this compare); fp pools
                     get NaN.  Detected by the next window's poison scan.
``swap_corrupt``   — ``HostSwapStore.put``, per swap-out (rid-matched):
                     corrupt the host payload; the corruption is detected
                     after restore, at the next decode window.
``prefill_nan``    — engine, per cold admission (rid-matched): poison the
                     freshly scattered prompt pages with SCALE_NAN — the
                     page-level footprint NaN activations leave through
                     the quantizer — and flag the slot.
``kernel_fail``    — engine, once per ``step()``: arm a one-shot Pallas
                     launch failure in ``kernels.backend``; supervised
                     dispatch catches it, logs once, and degrades that op
                     to the dense path for the rest of the process.
``alloc_fail``     — ``BlockManager.ensure`` (via its fault hook), per
                     page grant: fail the allocation; the engine recovers
                     by swapping the affected slot out (token-identical
                     resume on re-admission).
``stall``          — engine, once per ``step()``: spin for ``stall_s``
                     seconds (cooperatively — ``engine.abort_stall()``
                     breaks out) before doing any work, simulating a hung
                     step loop for the watchdog to detect.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.formats import SCALE_NAN


class FaultError(RuntimeError):
    """An injected failure (distinguishable from organic errors)."""


@dataclasses.dataclass(frozen=True)
class Fault:
    """One planned fault: fire at the ``nth`` consultation of ``site``
    (counted per (site, rid) when ``rid`` targets a request, per site
    otherwise), or at every matching consultation when ``always``."""
    site: str
    nth: int = 0
    rid: Optional[int] = None
    always: bool = False
    stall_s: float = 0.25           # stall site only
    n_bytes: int = 4                # page_corrupt: scale bytes to hit

    def __post_init__(self):
        if self.site not in FaultPlan.SITES:
            raise ValueError(f"unknown fault site {self.site!r}; "
                             f"choose from {FaultPlan.SITES}")


class FaultPlan:
    """Deterministic plan: consultations are counted, matches recorded in
    ``fired`` (site, rid, count), and any randomness (which byte to
    corrupt) derives from ``seed`` + the consultation count alone."""

    SITES = ("page_corrupt", "swap_corrupt", "prefill_nan",
             "kernel_fail", "alloc_fail", "stall")

    def __init__(self, faults: Sequence[Fault] = (), seed: int = 0):
        self.faults = list(faults)
        self.seed = int(seed)
        self._counts = {}
        self.fired: List[Tuple[str, Optional[int], int]] = []

    def __repr__(self):
        return f"FaultPlan({self.faults!r}, seed={self.seed})"

    @classmethod
    def parse(cls, text: str, seed: int = 0) -> "FaultPlan":
        """Parse ``--faults`` syntax: comma-separated sites with optional
        ``:key=value`` modifiers, e.g.
        ``"prefill_nan:rid=2,page_corrupt:nth=1,stall:stall_s=0.5,
        prefill_nan:rid=5:always"``."""
        faults = []
        for item in filter(None, (s.strip() for s in text.split(","))):
            site, *mods = item.split(":")
            kw = {}
            for m in mods:
                if m == "always":
                    kw["always"] = True
                    continue
                k, _, v = m.partition("=")
                if k in ("nth", "rid", "n_bytes"):
                    kw[k] = int(v)
                elif k == "stall_s":
                    kw[k] = float(v)
                else:
                    raise ValueError(f"bad fault modifier {m!r} in "
                                     f"{item!r}")
            faults.append(Fault(site=site, **kw))
        return cls(faults, seed=seed)

    def should_fire(self, site: str, rid: Optional[int] = None
                    ) -> Optional[Fault]:
        """Count one consultation of ``site`` (for ``rid``, when the site
        is request-scoped) and return the fault that fires now, if any."""
        if site not in self.SITES:
            raise ValueError(f"unknown fault site {site!r}")
        n_any = self._counts.get((site, None), 0)
        self._counts[(site, None)] = n_any + 1
        n_rid = 0
        if rid is not None:
            n_rid = self._counts.get((site, rid), 0)
            self._counts[(site, rid)] = n_rid + 1
        for f in self.faults:
            if f.site != site:
                continue
            # a fault's rid filters rid-scoped consultations; at a
            # site-wide consultation (rid=None) it is a *target* hint the
            # caller reads off the returned fault, not a mismatch
            if f.rid is not None and rid is not None and rid != f.rid:
                continue
            n = n_rid if (f.rid is not None and rid is not None) else n_any
            if f.always or n == f.nth:
                self.fired.append((site, rid, n))
                return f
        return None

    def rng(self, site: str) -> np.random.Generator:
        """Deterministic per-(site, consultation) generator."""
        n = self._counts.get((site, None), 0)
        return np.random.default_rng(
            (self.seed, self.SITES.index(site), n))


# =============================================================================
# Corruption helpers (the physical half of the injection sites)
# =============================================================================
def _map_groups(pool, fn):
    """Apply ``fn`` to every layer group's leaf dict of a paged pool."""
    out = {}
    lay = pool["layers"]
    out["layers"] = [fn(g) for g in lay] if isinstance(lay, list) \
        else fn(lay)
    if "dense_layers" in pool:
        out["dense_layers"] = [fn(g) for g in pool["dense_layers"]]
    return out


def poison_pool_pages(pool, page_ids, offset: Optional[int] = None):
    """Write SCALE_NAN into every MX scale leaf (NaN into fp leaves) at
    the given physical pages — the whole page, or one token ``offset``.
    Device-side; returns a new pool pytree."""
    ids = jnp.asarray(np.asarray(page_ids, np.int32).reshape(-1))

    def hit(leaf, val):
        if offset is None:
            return leaf.at[:, ids].set(val) if leaf.ndim == 5 \
                else leaf.at[ids].set(val)
        return leaf.at[:, ids, offset].set(val) if leaf.ndim == 5 \
            else leaf.at[ids, offset].set(val)

    def group(g):
        out = dict(g)
        for sk in ("ks_pages", "vs_pages"):
            if sk in g:
                out[sk] = hit(g[sk], jnp.uint8(SCALE_NAN))
        for fk in ("k_pages", "v_pages"):
            if fk in g:
                out[fk] = hit(g[fk], jnp.asarray(jnp.nan, g[fk].dtype))
        return out

    return _map_groups(pool, group)


def scrub_pool_pages(pool, page_ids):
    """Zero every leaf's bytes at the given physical pages — quarantine
    hygiene, not an injection site.  A quarantined request's pages return
    to the free list still holding poison markers / NaN payloads; a later
    allocation re-maps them and the *unwritten tail* of a partially
    filled page is read (masked) by attention, where a stale NaN survives
    the mask as ``0 * NaN``.  Scrubbing the dead pages before reuse
    restores the all-zeros state fresh pages were born with.  Device-side;
    returns a new pool pytree."""
    ids = jnp.asarray(np.asarray(page_ids, np.int32).reshape(-1))

    def group(g):
        out = dict(g)
        for k, leaf in g.items():
            zero = jnp.zeros((), leaf.dtype)
            out[k] = leaf.at[:, ids].set(zero) if leaf.ndim == 5 \
                else leaf.at[ids].set(zero)
        return out

    return _map_groups(pool, group)


def corrupt_swap_payload(host_pool) -> int:
    """Corrupt a ``gather_pages`` host snapshot **in place**: every MX
    scale leaf is overwritten with SCALE_NAN (fp leaves with NaN), so the
    restored request is flagged by the first post-restore health scan.
    Returns the number of leaves touched."""
    hit = 0

    def group(g):
        nonlocal hit
        # gather_pages leaves are read-only views of device arrays —
        # replace them with corrupted writable copies
        for sk in ("ks_pages", "vs_pages"):
            if sk in g:
                g[sk] = np.full_like(np.asarray(g[sk]), SCALE_NAN)
                hit += 1
        for fk in ("k_pages", "v_pages"):
            if fk in g:
                g[fk] = np.full_like(np.asarray(g[fk]), np.nan)
                hit += 1
        return g

    _map_groups(host_pool, group)
    return hit
