"""Asyncio serving front end over ``ContinuousBatchingEngine``.

``AsyncServer`` accepts requests as they arrive (coroutines calling
:meth:`submit`), streams each request's tokens back through its own
``asyncio.Queue``, and drives the engine from a **single background step
loop** — the engine itself stays synchronous and single-threaded, so all
of PR 2-7's token-identity guarantees carry over verbatim.

Concurrency model:

* Submissions land in a pending deque; the step loop applies them to the
  scheduler *between* engine steps, always on the loop task — the
  scheduler is never touched concurrently with a step, even when the
  step itself runs in a worker thread (``use_executor=True``).
* Each accepted request gets a :class:`RequestStream`; the step loop
  pushes ``(token, final)`` pairs into its queue as ``engine.step()``
  emits them, and the caller consumes them with ``async for``.
* Backpressure: ``max_queued`` bounds the number of requests waiting for
  admission; ``submit`` blocks (``admission="block"``) until the backlog
  drains, or raises :class:`RejectedError` (``admission="reject"``) when
  the request could not *start immediately* — the reject-on-full baseline
  the bench's preempt-and-swap claim is measured against.
* ``use_executor=True`` runs each engine step in the default thread-pool
  executor so the event loop stays responsive while the device computes;
  the engine is still only ever stepped by one caller at a time.

Latency accounting is carried by the ``Request`` objects themselves
(``arrival_t`` is stamped at submission, first-token / per-token stamps by
the engine); :func:`latency_summary` aggregates a population of finished
requests into the p50/p99 TTFT + ITL numbers ``bench_serve`` schema v4
reports.
"""
from __future__ import annotations

import asyncio
import collections
import time
from typing import AsyncIterator, Dict, List, Optional, Sequence

import numpy as np

from repro.obs.metrics import MetricsRegistry
from repro.obs.metrics import percentile as percentile  # noqa: F401
from repro.serve import snapshot as snapshot_mod
from repro.serve.engine import ContinuousBatchingEngine
from repro.serve.scheduler import Request, RequestState


class RejectedError(RuntimeError):
    """Raised by ``submit`` under ``admission="reject"`` when the request
    cannot start immediately (no free slot/pages, or a backlog exists)."""


class QuarantinedError(RuntimeError):
    """A request was quarantined by a numeric-health guard and no retry
    budget remains (``retries=0``).  Raised out of the request's stream;
    the message carries the engine's diagnostic."""


class RetriesExhausted(QuarantinedError):
    """A quarantined request failed every attempt of its retry budget."""


# ``percentile`` is re-exported above from repro.obs.metrics — the single
# nearest-rank implementation the launcher, bench, and registry share
# (this module used to carry its own copy, one of three that disagreed
# on empty/singleton windows).


def latency_summary(finished: Sequence[Request]) -> Dict[str, float]:
    """p50/p99 TTFT and ITL (milliseconds) plus SLO attainment over a
    population of finished requests.  Requests lacking stamps (none
    finished, or an engine driven without arrival times) are skipped."""
    ttft = [r.ttft_s for r in finished if r.ttft_s is not None]
    itl: List[float] = []
    for r in finished:
        itl.extend(r.itl_s)
    out: Dict[str, float] = {"n_requests": float(len(finished))}
    if ttft:
        out["ttft_p50_ms"] = percentile(ttft, 50) * 1e3
        out["ttft_p99_ms"] = percentile(ttft, 99) * 1e3
    if itl:
        out["itl_p50_ms"] = percentile(itl, 50) * 1e3
        out["itl_p99_ms"] = percentile(itl, 99) * 1e3
    met = [r.deadline_met for r in finished if r.deadline_met is not None]
    if met:
        out["slo_attainment"] = sum(met) / len(met)
    return out


class RequestStream:
    """One request's token stream: ``async for tok in stream`` yields
    generated token ids as the engine emits them; :meth:`tokens` collects
    the full output.  ``request`` exposes the live ``Request`` (latency
    stamps, preemption count) once finished."""

    def __init__(self, rid: int, request: Request):
        self.rid = rid
        self.request = request
        self._q: asyncio.Queue = asyncio.Queue()
        self._out: List[int] = []
        self._done = False
        # tokens the server has put into the queue — the replay-dedupe
        # baseline for retry and snapshot recovery
        self.n_pushed = 0

    def __aiter__(self) -> AsyncIterator[int]:
        return self._gen()

    async def _gen(self) -> AsyncIterator[int]:
        # once the final token is consumed the stream is exhausted —
        # iterating again (e.g. tokens() after an async-for) must stop
        # instead of awaiting a queue nothing will ever fill
        while not self._done:
            item = await self._q.get()
            if isinstance(item, Exception):
                # terminal failure (QuarantinedError / RetriesExhausted)
                self._done = True
                raise item
            tok, final = item
            self._out.append(tok)
            self._done = final
            yield tok

    async def tokens(self) -> np.ndarray:
        """Drain the stream to completion; returns all generated tokens
        (including any consumed earlier through ``async for``)."""
        async for _ in self:
            pass
        return np.asarray(self._out, np.int32)


class _Pending:
    """One submission awaiting application by the step loop."""

    __slots__ = ("future", "prompt", "max_new_tokens", "priority",
                 "deadline_s", "arrival_t")

    def __init__(self, future, prompt, max_new_tokens, priority,
                 deadline_s, arrival_t):
        self.future = future
        self.prompt = prompt
        self.max_new_tokens = max_new_tokens
        self.priority = priority
        self.deadline_s = deadline_s
        self.arrival_t = arrival_t


class AsyncServer:
    """Single-loop asyncio front end over a ``ContinuousBatchingEngine``.

    ``admission`` — ``"block"`` queues submissions (awaiting when more
    than ``max_queued`` are waiting for admission) or ``"reject"`` raises
    :class:`RejectedError` unless the request can start immediately.
    ``use_executor`` — run each engine step in the default thread-pool
    executor so jitted device work doesn't block the event loop.

    Fault tolerance (engine ``health_checks`` quarantines feed these):

    ``retries``        — per-request retry budget: a quarantined request
                         re-enters the queue after a jittered exponential
                         backoff (``retry_backoff_s * 2**attempt``), same
                         rid — the replay is token-identical, and tokens
                         the stream already delivered are deduplicated.
                         After ``retries`` failed attempts the stream
                         raises :class:`RetriesExhausted` (``retries=0``
                         raises :class:`QuarantinedError` immediately).
    ``watchdog_s``     — stalled-step watchdog (requires
                         ``use_executor=True``): when one engine step
                         exceeds this wall time, the server aborts the
                         stall cooperatively (``engine.abort_stall``) and
                         restores the last snapshot; streams resume
                         token-identically (already-delivered tokens are
                         skipped on replay).
    ``snapshot_every`` — take an engine snapshot
                         (``serve.snapshot.capture``) every N completed
                         steps (an initial one is always taken when this
                         or ``watchdog_s`` is set).

    Use as an async context manager (starts/stops the step loop), or call
    :meth:`start` / :meth:`stop` explicitly.
    """

    def __init__(self, engine: ContinuousBatchingEngine, *,
                 admission: str = "block", max_queued: int = 64,
                 use_executor: bool = False,
                 retries: int = 0, retry_backoff_s: float = 0.05,
                 watchdog_s: Optional[float] = None,
                 snapshot_every: Optional[int] = None):
        if admission not in ("block", "reject"):
            raise ValueError(f"admission must be 'block' or 'reject', "
                             f"got {admission!r}")
        if max_queued < 1:
            raise ValueError(f"max_queued must be >= 1, got {max_queued}")
        if retries < 0:
            raise ValueError(f"retries must be >= 0, got {retries}")
        if watchdog_s is not None and not use_executor:
            raise ValueError(
                "watchdog_s requires use_executor=True: without the "
                "executor the step blocks the event loop and a stalled "
                "step could never be timed out")
        if snapshot_every is not None and snapshot_every < 1:
            raise ValueError(
                f"snapshot_every must be >= 1, got {snapshot_every}")
        self.engine = engine
        self.admission = admission
        self.max_queued = int(max_queued)
        self.use_executor = bool(use_executor)
        self.retries = int(retries)
        self.retry_backoff_s = float(retry_backoff_s)
        self.watchdog_s = watchdog_s
        self.snapshot_every = snapshot_every
        self._pending: collections.deque = collections.deque()
        self._requeue: collections.deque = collections.deque()
        self._streams: Dict[int, RequestStream] = {}
        self._skip: Dict[int, int] = {}     # replay-dedupe counters
        self._task: Optional[asyncio.Task] = None
        self._wake: Optional[asyncio.Event] = None
        self._space: Optional[asyncio.Condition] = None
        self._stopping = False
        self._snap = None                   # last EngineSnapshot
        self._snap_pushed: Dict[int, int] = {}
        self._steps_since_snap = 0
        # the server keeps its *own* registry: engine.reset_metrics()
        # restarts the engine's measurement window without zeroing the
        # server's accept/reject/retry history (exactly the pre-registry
        # behavior); obs_snapshot() exports both side by side
        self.metrics = MetricsRegistry()
        self._c_accepted = self.metrics.counter(
            "server.accepted", "submissions applied to the scheduler")
        self._c_rejected = self.metrics.counter(
            "server.rejected", "admission='reject' turn-aways")
        self._c_retried = self.metrics.counter(
            "server.retried", "retry attempts dispatched")
        self._c_failed = self.metrics.counter(
            "server.failed", "terminal quarantines")
        self._c_recoveries = self.metrics.counter(
            "server.recoveries", "watchdog snapshot restores")

    # ------------------------------------ registry-backed counter views
    @property
    def n_accepted(self) -> int:
        return int(self._c_accepted.value())

    @n_accepted.setter
    def n_accepted(self, v: int) -> None:
        self._c_accepted.set(int(v))

    @property
    def n_rejected(self) -> int:
        return int(self._c_rejected.value())

    @n_rejected.setter
    def n_rejected(self, v: int) -> None:
        self._c_rejected.set(int(v))

    @property
    def n_retried(self) -> int:
        return int(self._c_retried.value())

    @n_retried.setter
    def n_retried(self, v: int) -> None:
        self._c_retried.set(int(v))

    @property
    def n_failed(self) -> int:
        return int(self._c_failed.value())

    @n_failed.setter
    def n_failed(self, v: int) -> None:
        self._c_failed.set(int(v))

    @property
    def n_recoveries(self) -> int:
        return int(self._c_recoveries.value())

    @n_recoveries.setter
    def n_recoveries(self, v: int) -> None:
        self._c_recoveries.set(int(v))

    def obs_snapshot(self) -> Dict[str, object]:
        """One structured view of the whole serving process: the server's
        own counters, the engine registry (every engine / scheduler /
        paging / prefix / swap / mx series), and the latency summary over
        the current measurement window.  JSON-serializable — the
        launcher's ``--metrics-json`` writes exactly this."""
        return {"server": self.metrics.snapshot(),
                "engine": self.engine.metrics.snapshot(),
                "latency": latency_summary(self.engine.finished_in_window)}

    # ------------------------------------------------------------ lifecycle
    async def __aenter__(self) -> "AsyncServer":
        self.start()
        return self

    async def __aexit__(self, *exc) -> None:
        await self.stop()

    def start(self) -> None:
        if self._task is not None:
            raise RuntimeError("server already started")
        self._wake = asyncio.Event()
        self._space = asyncio.Condition()
        self._stopping = False
        self._task = asyncio.get_running_loop().create_task(self._loop())

    async def stop(self) -> None:
        """Drain in-flight work, then stop the step loop."""
        await self.drain()
        self._stopping = True
        self._wake.set()
        await self._task
        self._task = None

    # ------------------------------------------------------------ submission
    def _backlog(self) -> int:
        """Requests accepted but not yet admitted into a slot."""
        return len(self._pending) + len(self.engine.scheduler.waiting)

    async def submit(self, prompt, max_new_tokens: int, *,
                     priority: int = 0,
                     deadline_s: Optional[float] = None) -> RequestStream:
        """Accept one request; resolves to its :class:`RequestStream` once
        the step loop has applied the submission (or raises
        :class:`RejectedError` under ``admission="reject"``).

        The arrival timestamp is taken *here* — queueing delay (backlog
        under ``"block"``, scheduler wait, preemption) all counts against
        the request's TTFT.
        """
        if self._task is None:
            raise RuntimeError("server is not running")
        arrival = time.perf_counter()
        if self.admission == "block":
            async with self._space:
                await self._space.wait_for(
                    lambda: self._backlog() < self.max_queued)
        future = asyncio.get_running_loop().create_future()
        self._pending.append(_Pending(
            future, np.asarray(prompt, np.int32).reshape(-1),
            int(max_new_tokens), int(priority), deadline_s, arrival))
        self._wake.set()
        return await future

    async def drain(self) -> None:
        """Wait until every accepted request has finished streaming (or
        failed terminally)."""
        while (self._pending or self._requeue or self._streams
               or self.engine.scheduler.has_work()):
            self._wake.set()
            await asyncio.sleep(0.001)

    # ------------------------------------------------------------ step loop
    def _apply_pending(self) -> None:
        """Apply queued submissions to the scheduler — always on the loop
        task, between engine steps, so scheduler state is single-writer.
        Backoff-expired retries re-enter first: they keep their original
        arrival rank, so a retried request isn't starved by later
        arrivals."""
        while self._requeue:
            req = self._requeue.popleft()
            if req.state is not RequestState.FAILED:
                continue        # a snapshot restore rewound the failure
            stream = self._streams.get(req.rid)
            if stream is not None:
                # the healthy prefix already streamed is replayed
                # token-identically — skip it on delivery
                self._skip[req.rid] = stream.n_pushed
            self.engine.retry_request(req)
            self._c_retried.inc()
        while self._pending:
            p = self._pending.popleft()
            if p.future.cancelled():
                continue
            if self.admission == "reject" \
                    and not self.engine.scheduler.can_admit_now(
                        p.prompt, p.max_new_tokens):
                self._c_rejected.inc()
                p.future.set_exception(RejectedError(
                    "cannot start immediately: admission='reject'"))
                continue
            try:
                rid = self.engine.add_request(
                    p.prompt, p.max_new_tokens, priority=p.priority,
                    deadline_s=p.deadline_s, arrival_t=p.arrival_t)
            except ValueError as e:        # can never fit slot/pool
                p.future.set_exception(e)
                continue
            req = next(r for r in self.engine.scheduler.waiting
                       if r.rid == rid)
            stream = RequestStream(rid, req)
            self._streams[rid] = stream
            self._c_accepted.inc()
            p.future.set_result(stream)

    async def _loop(self) -> None:
        loop = asyncio.get_running_loop()
        if self.watchdog_s is not None or self.snapshot_every is not None:
            self._take_snapshot()
        while True:
            self._apply_pending()
            if self.engine.scheduler.has_work():
                recovered = False
                if self.use_executor:
                    step = loop.run_in_executor(None, self.engine.step)
                    if self.watchdog_s is not None:
                        try:
                            emitted = await asyncio.wait_for(
                                asyncio.shield(step), self.watchdog_s)
                        except asyncio.TimeoutError:
                            # a stalled step: cut it short cooperatively,
                            # then roll the engine back to the snapshot —
                            # the aborted step's partial work is discarded
                            # and replayed token-identically
                            self.engine.abort_stall()
                            await step
                            self._recover_from_snapshot()
                            recovered = True
                    else:
                        emitted = await step
                else:
                    emitted = self.engine.step()
                    await asyncio.sleep(0)  # let submitters interleave
                if not recovered:
                    self._publish(emitted)
                    self._handle_quarantines(loop)
                    self._steps_since_snap += 1
                    if self.snapshot_every is not None \
                            and self._steps_since_snap \
                            >= self.snapshot_every:
                        self._take_snapshot()
            async with self._space:
                self._space.notify_all()
            if not self.engine.scheduler.has_work() \
                    and not self._pending and not self._requeue:
                if self._stopping:
                    return
                self._wake.clear()
                if self._pending or self._requeue:  # raced with a submit
                    continue
                await self._wake.wait()

    def _publish(self, emitted) -> None:
        done_rids = {r.rid for r in self.engine.scheduler.finished}
        last: Dict[int, int] = {}
        for i, (rid, _) in enumerate(emitted):
            last[rid] = i
        for i, (rid, tok) in enumerate(emitted):
            stream = self._streams.get(rid)
            if stream is None:
                continue
            final = rid in done_rids and i == last[rid]
            skip = self._skip.get(rid, 0)
            if skip > 0:
                # replayed token the stream already delivered (a live
                # stream's final token is never in the skipped prefix:
                # delivering final deletes the stream)
                self._skip[rid] = skip - 1
                continue
            stream._q.put_nowait((tok, final))
            stream.n_pushed += 1
            if final:
                del self._streams[rid]
                self._skip.pop(rid, None)

    # ------------------------------------------------- retry + recovery
    def _backoff_delay(self, req: Request) -> float:
        """Exponential backoff with deterministic per-(rid, attempt)
        jitter in [1.0, 1.25) — decorrelates same-step quarantines
        without a nondeterministic RNG."""
        j = ((req.rid * 2654435761 + req.n_retries * 40503) % 997) / 997.0
        return self.retry_backoff_s * (2 ** req.n_retries) * (1 + 0.25 * j)

    def _handle_quarantines(self, loop) -> None:
        """Route this step's quarantined requests: schedule a backoff'd
        retry while budget remains, otherwise fail the stream."""
        for req in self.engine.quarantined_in_step:
            stream = self._streams.get(req.rid)
            if req.n_retries < self.retries:
                loop.call_later(self._backoff_delay(req),
                                self._requeue_later, req)
                continue
            self._c_failed.inc()
            tr = self.engine.tracer
            if tr is not None and tr.open_spans(req.rid):
                # the engine unwound the track to its root at quarantine
                # time; a spent retry budget is the terminal close
                tr.close_track(req.rid, status="failed")
            if stream is None:
                continue
            if self.retries:
                err: Exception = RetriesExhausted(
                    f"request {req.rid} quarantined after "
                    f"{req.n_retries} retries: {req.error}")
            else:
                err = QuarantinedError(
                    f"request {req.rid} quarantined: {req.error}")
            stream._q.put_nowait(err)
            del self._streams[req.rid]
            self._skip.pop(req.rid, None)

    def _requeue_later(self, req: Request) -> None:
        """call_later target: hand the request back to the loop task (the
        scheduler is single-writer — mutation happens in _apply_pending)."""
        self._requeue.append(req)
        self._wake.set()

    def _take_snapshot(self) -> None:
        self._snap = snapshot_mod.capture(self.engine)
        self._snap_pushed = {rid: st.n_pushed
                             for rid, st in self._streams.items()}
        self._steps_since_snap = 0

    def _recover_from_snapshot(self) -> None:
        """Roll the engine back to the last snapshot and reconcile the
        live streams: tokens delivered since the snapshot will be
        re-emitted token-identically, so each stream skips exactly that
        many; requests the snapshot never saw are resubmitted whole."""
        assert self._snap is not None, "watchdog recovery needs a snapshot"
        snapshot_mod.restore(self.engine, self._snap)
        self.engine._stall_abort.clear()    # no stale abort latch
        tr = self.engine.tracer
        if tr is not None:
            # reconcile the rolled-back request tracks *before* replay:
            # whatever spans opened since the snapshot no longer
            # happened — unwind each live track to its root, and re-open
            # "queued" for requests the restore put back in the queues
            tr.instant("snapshot_restore",
                       recoveries=self.n_recoveries + 1)
            for req, _ in self._snap.requests:
                if not tr.open_spans(req.rid):
                    continue
                tr.unwind(req.rid, keep=1)
                if req.state in (RequestState.WAITING,
                                 RequestState.SWAPPED):
                    tr.begin("queued", cat="request", rid=req.rid)
        known = {r.rid for r, _ in self._snap.requests}
        for rid, stream in list(self._streams.items()):
            if rid in known:
                self._skip[rid] = \
                    stream.n_pushed - self._snap_pushed.get(rid, 0)
            else:
                # submitted after the snapshot: restore dropped it from
                # the queues — re-enter it whole and skip everything the
                # stream already got
                self.engine.resubmit(stream.request)
                self._skip[rid] = stream.n_pushed
        self._c_recoveries.inc()
