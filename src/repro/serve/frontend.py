"""Asyncio serving front end over ``ContinuousBatchingEngine``.

``AsyncServer`` accepts requests as they arrive (coroutines calling
:meth:`submit`), streams each request's tokens back through its own
``asyncio.Queue``, and drives the engine from a **single background step
loop** — the engine itself stays synchronous and single-threaded, so all
of PR 2-7's token-identity guarantees carry over verbatim.

Concurrency model:

* Submissions land in a pending deque; the step loop applies them to the
  scheduler *between* engine steps, always on the loop task — the
  scheduler is never touched concurrently with a step, even when the
  step itself runs in a worker thread (``use_executor=True``).
* Each accepted request gets a :class:`RequestStream`; the step loop
  pushes ``(token, final)`` pairs into its queue as ``engine.step()``
  emits them, and the caller consumes them with ``async for``.
* Backpressure: ``max_queued`` bounds the number of requests waiting for
  admission; ``submit`` blocks (``admission="block"``) until the backlog
  drains, or raises :class:`RejectedError` (``admission="reject"``) when
  the request could not *start immediately* — the reject-on-full baseline
  the bench's preempt-and-swap claim is measured against.
* ``use_executor=True`` runs each engine step in the default thread-pool
  executor so the event loop stays responsive while the device computes;
  the engine is still only ever stepped by one caller at a time.

Latency accounting is carried by the ``Request`` objects themselves
(``arrival_t`` is stamped at submission, first-token / per-token stamps by
the engine); :func:`latency_summary` aggregates a population of finished
requests into the p50/p99 TTFT + ITL numbers ``bench_serve`` schema v4
reports.
"""
from __future__ import annotations

import asyncio
import collections
import time
from typing import AsyncIterator, Dict, List, Optional, Sequence

import numpy as np

from repro.serve.engine import ContinuousBatchingEngine
from repro.serve.scheduler import Request


class RejectedError(RuntimeError):
    """Raised by ``submit`` under ``admission="reject"`` when the request
    cannot start immediately (no free slot/pages, or a backlog exists)."""


def percentile(samples: Sequence[float], q: float) -> float:
    """Nearest-rank percentile (no interpolation): the ceil(q/100 * n)-th
    smallest sample.  Exactly reproducible from the raw records by the
    dependency-free bench validator — that is the point."""
    if not samples:
        raise ValueError("percentile of an empty sample set")
    if not 0 < q <= 100:
        raise ValueError(f"q must be in (0, 100], got {q}")
    s = sorted(samples)
    rank = -(-(q / 100.0) * len(s) // 1)        # ceil without math import
    return s[int(rank) - 1]


def latency_summary(finished: Sequence[Request]) -> Dict[str, float]:
    """p50/p99 TTFT and ITL (milliseconds) plus SLO attainment over a
    population of finished requests.  Requests lacking stamps (none
    finished, or an engine driven without arrival times) are skipped."""
    ttft = [r.ttft_s for r in finished if r.ttft_s is not None]
    itl: List[float] = []
    for r in finished:
        itl.extend(r.itl_s)
    out: Dict[str, float] = {"n_requests": float(len(finished))}
    if ttft:
        out["ttft_p50_ms"] = percentile(ttft, 50) * 1e3
        out["ttft_p99_ms"] = percentile(ttft, 99) * 1e3
    if itl:
        out["itl_p50_ms"] = percentile(itl, 50) * 1e3
        out["itl_p99_ms"] = percentile(itl, 99) * 1e3
    met = [r.deadline_met for r in finished if r.deadline_met is not None]
    if met:
        out["slo_attainment"] = sum(met) / len(met)
    return out


class RequestStream:
    """One request's token stream: ``async for tok in stream`` yields
    generated token ids as the engine emits them; :meth:`tokens` collects
    the full output.  ``request`` exposes the live ``Request`` (latency
    stamps, preemption count) once finished."""

    def __init__(self, rid: int, request: Request):
        self.rid = rid
        self.request = request
        self._q: asyncio.Queue = asyncio.Queue()
        self._out: List[int] = []
        self._done = False

    def __aiter__(self) -> AsyncIterator[int]:
        return self._gen()

    async def _gen(self) -> AsyncIterator[int]:
        # once the final token is consumed the stream is exhausted —
        # iterating again (e.g. tokens() after an async-for) must stop
        # instead of awaiting a queue nothing will ever fill
        while not self._done:
            tok, final = await self._q.get()
            self._out.append(tok)
            self._done = final
            yield tok

    async def tokens(self) -> np.ndarray:
        """Drain the stream to completion; returns all generated tokens
        (including any consumed earlier through ``async for``)."""
        async for _ in self:
            pass
        return np.asarray(self._out, np.int32)


class _Pending:
    """One submission awaiting application by the step loop."""

    __slots__ = ("future", "prompt", "max_new_tokens", "priority",
                 "deadline_s", "arrival_t")

    def __init__(self, future, prompt, max_new_tokens, priority,
                 deadline_s, arrival_t):
        self.future = future
        self.prompt = prompt
        self.max_new_tokens = max_new_tokens
        self.priority = priority
        self.deadline_s = deadline_s
        self.arrival_t = arrival_t


class AsyncServer:
    """Single-loop asyncio front end over a ``ContinuousBatchingEngine``.

    ``admission`` — ``"block"`` queues submissions (awaiting when more
    than ``max_queued`` are waiting for admission) or ``"reject"`` raises
    :class:`RejectedError` unless the request can start immediately.
    ``use_executor`` — run each engine step in the default thread-pool
    executor so jitted device work doesn't block the event loop.

    Use as an async context manager (starts/stops the step loop), or call
    :meth:`start` / :meth:`stop` explicitly.
    """

    def __init__(self, engine: ContinuousBatchingEngine, *,
                 admission: str = "block", max_queued: int = 64,
                 use_executor: bool = False):
        if admission not in ("block", "reject"):
            raise ValueError(f"admission must be 'block' or 'reject', "
                             f"got {admission!r}")
        if max_queued < 1:
            raise ValueError(f"max_queued must be >= 1, got {max_queued}")
        self.engine = engine
        self.admission = admission
        self.max_queued = int(max_queued)
        self.use_executor = bool(use_executor)
        self._pending: collections.deque = collections.deque()
        self._streams: Dict[int, RequestStream] = {}
        self._task: Optional[asyncio.Task] = None
        self._wake: Optional[asyncio.Event] = None
        self._space: Optional[asyncio.Condition] = None
        self._stopping = False
        self.n_accepted = 0
        self.n_rejected = 0

    # ------------------------------------------------------------ lifecycle
    async def __aenter__(self) -> "AsyncServer":
        self.start()
        return self

    async def __aexit__(self, *exc) -> None:
        await self.stop()

    def start(self) -> None:
        if self._task is not None:
            raise RuntimeError("server already started")
        self._wake = asyncio.Event()
        self._space = asyncio.Condition()
        self._stopping = False
        self._task = asyncio.get_running_loop().create_task(self._loop())

    async def stop(self) -> None:
        """Drain in-flight work, then stop the step loop."""
        await self.drain()
        self._stopping = True
        self._wake.set()
        await self._task
        self._task = None

    # ------------------------------------------------------------ submission
    def _backlog(self) -> int:
        """Requests accepted but not yet admitted into a slot."""
        return len(self._pending) + len(self.engine.scheduler.waiting)

    async def submit(self, prompt, max_new_tokens: int, *,
                     priority: int = 0,
                     deadline_s: Optional[float] = None) -> RequestStream:
        """Accept one request; resolves to its :class:`RequestStream` once
        the step loop has applied the submission (or raises
        :class:`RejectedError` under ``admission="reject"``).

        The arrival timestamp is taken *here* — queueing delay (backlog
        under ``"block"``, scheduler wait, preemption) all counts against
        the request's TTFT.
        """
        if self._task is None:
            raise RuntimeError("server is not running")
        arrival = time.perf_counter()
        if self.admission == "block":
            async with self._space:
                await self._space.wait_for(
                    lambda: self._backlog() < self.max_queued)
        future = asyncio.get_running_loop().create_future()
        self._pending.append(_Pending(
            future, np.asarray(prompt, np.int32).reshape(-1),
            int(max_new_tokens), int(priority), deadline_s, arrival))
        self._wake.set()
        return await future

    async def drain(self) -> None:
        """Wait until every accepted request has finished streaming."""
        while (self._pending or self._streams
               or self.engine.scheduler.has_work()):
            self._wake.set()
            await asyncio.sleep(0.001)

    # ------------------------------------------------------------ step loop
    def _apply_pending(self) -> None:
        """Apply queued submissions to the scheduler — always on the loop
        task, between engine steps, so scheduler state is single-writer."""
        while self._pending:
            p = self._pending.popleft()
            if p.future.cancelled():
                continue
            if self.admission == "reject" \
                    and not self.engine.scheduler.can_admit_now(
                        p.prompt, p.max_new_tokens):
                self.n_rejected += 1
                p.future.set_exception(RejectedError(
                    "cannot start immediately: admission='reject'"))
                continue
            try:
                rid = self.engine.add_request(
                    p.prompt, p.max_new_tokens, priority=p.priority,
                    deadline_s=p.deadline_s, arrival_t=p.arrival_t)
            except ValueError as e:        # can never fit slot/pool
                p.future.set_exception(e)
                continue
            req = next(r for r in self.engine.scheduler.waiting
                       if r.rid == rid)
            stream = RequestStream(rid, req)
            self._streams[rid] = stream
            self.n_accepted += 1
            p.future.set_result(stream)

    async def _loop(self) -> None:
        loop = asyncio.get_running_loop()
        while True:
            self._apply_pending()
            if self.engine.scheduler.has_work():
                if self.use_executor:
                    emitted = await loop.run_in_executor(
                        None, self.engine.step)
                else:
                    emitted = self.engine.step()
                    await asyncio.sleep(0)  # let submitters interleave
                self._publish(emitted)
            async with self._space:
                self._space.notify_all()
            if not self.engine.scheduler.has_work() \
                    and not self._pending:
                if self._stopping:
                    return
                self._wake.clear()
                if self._pending:           # raced with a submit
                    continue
                await self._wake.wait()

    def _publish(self, emitted) -> None:
        done_rids = {r.rid for r in self.engine.scheduler.finished}
        last: Dict[int, int] = {}
        for i, (rid, _) in enumerate(emitted):
            last[rid] = i
        for i, (rid, tok) in enumerate(emitted):
            stream = self._streams.get(rid)
            if stream is None:
                continue
            final = rid in done_rids and i == last[rid]
            stream._q.put_nowait((tok, final))
            if final:
                del self._streams[rid]
