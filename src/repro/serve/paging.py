"""Host-side page accounting for the paged MX KV cache.

The device side is a page pool per attention layer (see
``models/layers.init_paged_kv_cache``): ``num_pages`` pages of ``page_size``
tokens of packed codes + E8M0 scales.  This module owns the free list and
the per-slot block tables that map a slot's logical token positions to
physical pages.

Physical page 0 is the **trash page**: it is never handed out, every idle
slot's block-table row points at it, and the decode step's unconditional
scatter for idle slots lands there — masked decode writes can never corrupt
a live request's pages.
"""
from __future__ import annotations

from typing import List

import numpy as np

TRASH_PAGE = 0


def pages_needed(tokens: int, page_size: int) -> int:
    """Pages required to hold ``tokens`` positions (>= 1 so every admitted
    request owns the page its first generated token lands in)."""
    return max(1, -(-tokens // page_size))


# =============================================================================
# KV storage cost (one source of truth for pool sizing, benchmark
# reporting, and repro.calib's byte budgets)
# =============================================================================
def spec_side_nbytes(spec, n_kv: int, hd: int, fp_bytes: int = 2) -> int:
    """Bytes one layer's K *or* V side stores per token position.

    ``spec`` None (fp passthrough) costs ``n_kv * hd * fp_bytes``; an MX
    spec costs the (optionally bit-packed) element codes plus the E8M0
    scales, exactly matching ``models.layers.init_paged_kv_cache``'s
    per-layer pool layout."""
    if spec is None:
        return n_kv * hd * fp_bytes
    cl = -(-hd // spec.block) * spec.block
    return n_kv * (spec.storage_nbytes(cl) + cl // spec.block)


def kv_token_nbytes(policy, n_kv: int, hd: int, fp_bytes: int = 2) -> int:
    """Bytes one layer's KV cache (K + V) stores per token under
    ``policy`` (a ``QuantPolicy``)."""
    return (spec_side_nbytes(policy.kv_key, n_kv, hd, fp_bytes)
            + spec_side_nbytes(policy.kv_value, n_kv, hd, fp_bytes))


def kv_cache_token_nbytes(cfg) -> int:
    """Total KV bytes per token position across every layer of ``cfg`` —
    the quantity ``--quant auto:<budget>`` budgets (per-layer policy
    tables sum each layer's own specs)."""
    import numpy as np                      # dtype width of the fp pages
    fp_bytes = np.dtype(cfg.dtype).itemsize if cfg.dtype != "bfloat16" \
        else 2
    return sum(kv_token_nbytes(cfg.layer_policy(i), cfg.n_kv_heads, cfg.hd,
                               fp_bytes) for i in range(cfg.n_layers))


class BlockManager:
    """Free-list allocator + block tables over a fixed page pool.

    ``tables`` is the host mirror of the device block-table operand: rows
    are zero (the trash page) beyond a slot's allocation, so the kernel's
    out-of-range page lookups always hit valid (masked) memory.

    ``version`` increments on every mutation of ``tables``; the serving
    engine keys its device-resident copy of the block table on it, so the
    host->device upload happens only when an admission/grant/eviction
    actually changed the mapping — not on every decode window.
    """

    def __init__(self, num_pages: int, page_size: int, max_slots: int,
                 max_pages_per_slot: int):
        if num_pages < 2:
            raise ValueError("need >= 2 pages (page 0 is the trash page)")
        self.num_pages = num_pages
        self.page_size = page_size
        self.max_pages_per_slot = max_pages_per_slot
        self.version = 0
        # LIFO free list; page 0 reserved as trash
        self._free: List[int] = list(range(num_pages - 1, TRASH_PAGE, -1))
        self.tables = np.full((max_slots, max_pages_per_slot), TRASH_PAGE,
                              np.int32)
        self._owned = [[] for _ in range(max_slots)]

    # ------------------------------------------------------------- queries
    @property
    def free_pages(self) -> int:
        return len(self._free)

    def can_allocate(self, n: int) -> bool:
        return n <= len(self._free)

    def slot_pages(self, slot: int) -> int:
        return len(self._owned[slot])

    def slot_capacity(self, slot: int) -> int:
        """Token positions the slot's current allocation can hold."""
        return len(self._owned[slot]) * self.page_size

    # ----------------------------------------------------------- mutations
    def allocate(self, slot: int, n: int) -> bool:
        """Append ``n`` pages to ``slot``'s block-table row.  Returns False
        (allocating nothing) if the pool or the row can't hold them."""
        owned = self._owned[slot]
        if not self.can_allocate(n) \
                or len(owned) + n > self.max_pages_per_slot:
            return False
        if n:
            self.version += 1
        for _ in range(n):
            pg = self._free.pop()
            self.tables[slot, len(owned)] = pg
            owned.append(pg)
        return True

    def ensure(self, slot: int, tokens: int) -> bool:
        """Grow ``slot``'s allocation to cover ``tokens`` positions."""
        need = pages_needed(tokens, self.page_size) - self.slot_pages(slot)
        return True if need <= 0 else self.allocate(slot, need)

    def free_slot(self, slot: int) -> None:
        """Return all of ``slot``'s pages and re-point its row at trash."""
        if self._owned[slot]:
            self.version += 1
        self._free.extend(reversed(self._owned[slot]))
        self._owned[slot] = []
        self.tables[slot, :] = TRASH_PAGE
