"""Host-side page accounting for the paged MX KV cache.

The device side is a page pool per attention layer (see
``models/layers.init_paged_kv_cache``): ``num_pages`` pages of ``page_size``
tokens of packed codes + E8M0 scales.  This module owns the free list and
the per-slot block tables that map a slot's logical token positions to
physical pages.

Physical page 0 is the **trash page**: it is never handed out, every idle
slot's block-table row points at it, and the decode step's unconditional
scatter for idle slots lands there — masked decode writes can never corrupt
a live request's pages.

Pages are **refcounted** (PR 6): a physical page may be mapped read-only
into several slots' block tables at once (prefix sharing), and may
additionally be *pinned* by the prefix cache so it outlives the request
that computed it.  A page's refcount is the number of block-table entries
mapping it plus its pins; it returns to the free list only when the
refcount hits zero.  Any write to a shared page must go through
``fork_page`` (copy-on-write): the writer gets a fresh private page and
the shared original is decref'd, so no owner ever observes another
request's writes.
"""
from __future__ import annotations

from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.obs.metrics import MetricsRegistry

TRASH_PAGE = 0


class PageGrantError(RuntimeError):
    """A page grant failed for a slot whose capacity admission had
    reserved (a transient allocator fault, injected or real).  Carries
    the slot so the engine can recover by swapping that request out —
    it resumes token-identically on re-admission — instead of tearing
    the whole window down."""

    def __init__(self, slot: int, need: int):
        super().__init__(
            f"page grant failed for slot {slot} ({need} pages)")
        self.slot = slot
        self.need = need


def pages_needed(tokens: int, page_size: int) -> int:
    """Pages required to hold ``tokens`` positions (>= 1 so every admitted
    request owns the page its first generated token lands in)."""
    return max(1, -(-tokens // page_size))


# =============================================================================
# KV storage cost (one source of truth for pool sizing, benchmark
# reporting, and repro.calib's byte budgets)
# =============================================================================
def spec_side_nbytes(spec, n_kv: int, hd: int, fp_bytes: int = 2) -> int:
    """Bytes one layer's K *or* V side stores per token position.

    ``spec`` None (fp passthrough) costs ``n_kv * hd * fp_bytes``; an MX
    spec costs the (optionally bit-packed) element codes plus the E8M0
    scales, exactly matching ``models.layers.init_paged_kv_cache``'s
    per-layer pool layout."""
    if spec is None:
        return n_kv * hd * fp_bytes
    cl = -(-hd // spec.block) * spec.block
    return n_kv * (spec.storage_nbytes(cl) + cl // spec.block)


def kv_token_nbytes(policy, n_kv: int, hd: int, fp_bytes: int = 2) -> int:
    """Bytes one layer's KV cache (K + V) stores per token under
    ``policy`` (a ``QuantPolicy``)."""
    return (spec_side_nbytes(policy.kv_key, n_kv, hd, fp_bytes)
            + spec_side_nbytes(policy.kv_value, n_kv, hd, fp_bytes))


def kv_cache_token_nbytes(cfg) -> int:
    """Total KV bytes per token position across every layer of ``cfg`` —
    the quantity ``--quant auto:<budget>`` budgets (per-layer policy
    tables sum each layer's own specs)."""
    import numpy as np                      # dtype width of the fp pages
    fp_bytes = np.dtype(cfg.dtype).itemsize if cfg.dtype != "bfloat16" \
        else 2
    return sum(kv_token_nbytes(cfg.layer_policy(i), cfg.n_kv_heads, cfg.hd,
                               fp_bytes) for i in range(cfg.n_layers))


class BlockManager:
    """Refcounted free-list allocator + block tables over a fixed page pool.

    ``tables`` is the host mirror of the device block-table operand: rows
    are zero (the trash page) beyond a slot's allocation, so the kernel's
    out-of-range page lookups always hit valid (masked) memory.

    ``version`` increments on every mutation of ``tables``; the serving
    engine keys its device-resident copy of the block table on it, so the
    host->device upload happens only when an admission/grant/eviction/fork
    actually changed the mapping — not on every decode window.

    A page's refcount decomposes as ``table_refs + pins``: ``table_refs``
    counts block-table entries (one per (slot, logical page) mapping),
    ``pins`` counts external holders (the prefix cache).  The invariants
    the property suite asserts:

    * every non-trash page is on the free list xor has refcount > 0;
    * ``free_pages + live_pages == num_pages - 1`` (page 0 is the trash
      page, never allocated and never freed);
    * per page, ``table_refs`` equals the number of slot-table entries
      mapping it and ``pins`` the number of outstanding ``pin`` calls;
    * ``version`` bumps exactly when ``tables`` mutates (allocate /
      map_shared / fork_page / release of a non-empty row — never on
      pin/unpin, which touch no table).
    """

    def __init__(self, num_pages: int, page_size: int, max_slots: int,
                 max_pages_per_slot: int,
                 metrics: Optional[MetricsRegistry] = None):
        if num_pages < 2:
            raise ValueError("need >= 2 pages (page 0 is the trash page)")
        # page-flow counters (pages.*) — a standalone manager gets its
        # own registry, the engine shares its registry in
        self.metrics = MetricsRegistry() if metrics is None else metrics
        self._c_alloc = self.metrics.counter(
            "pages.allocated", "fresh private pages granted")
        self._c_shared = self.metrics.counter(
            "pages.shared_mapped", "read-only prefix mappings added")
        self._c_forks = self.metrics.counter(
            "pages.cow_forks", "copy-on-write page forks")
        self._c_released = self.metrics.counter(
            "pages.released", "block-table entries released")
        self.num_pages = num_pages
        self.page_size = page_size
        self.max_pages_per_slot = max_pages_per_slot
        self.version = 0
        # LIFO free list; page 0 reserved as trash
        self._free: List[int] = list(range(num_pages - 1, TRASH_PAGE, -1))
        self.tables = np.full((max_slots, max_pages_per_slot), TRASH_PAGE,
                              np.int32)
        self._owned: List[List[int]] = [[] for _ in range(max_slots)]
        # parallel to _owned: True where the entry was mapped read-only
        # from the prefix cache (a write there must fork_page first)
        self._shared: List[List[bool]] = [[] for _ in range(max_slots)]
        self._table_refs = np.zeros(num_pages, np.int32)
        self._pins = np.zeros(num_pages, np.int32)
        # fault-injection hook (serve.faults): called with the page count
        # of every non-trivial ensure(); returning True fails that grant
        self.fault_hook = None

    # ------------------------------------------------------------- queries
    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def live_pages(self) -> int:
        """Distinct non-trash pages with refcount > 0."""
        return self.num_pages - 1 - len(self._free)

    @property
    def mapped_pages(self) -> int:
        """Distinct pages referenced by at least one slot's block table —
        the serving working set (prefix-cache pins excluded)."""
        return int(np.count_nonzero(self._table_refs[1:]))

    @property
    def shared_pages(self) -> int:
        """Distinct pages mapped by two or more block-table entries."""
        return int(np.count_nonzero(self._table_refs[1:] >= 2))

    def can_allocate(self, n: int) -> bool:
        return n <= len(self._free)

    def page_refcount(self, page: int) -> int:
        return int(self._table_refs[page] + self._pins[page])

    def slot_pages(self, slot: int) -> int:
        return len(self._owned[slot])

    def slot_shared_pages(self, slot: int) -> int:
        """Entries of ``slot``'s row still mapped read-only (not forked)."""
        return sum(self._shared[slot])

    def slot_page_ids(self, slot: int) -> List[int]:
        return list(self._owned[slot])

    def slot_capacity(self, slot: int) -> int:
        """Token positions the slot's current allocation can hold."""
        return len(self._owned[slot]) * self.page_size

    def is_shared_entry(self, slot: int, idx: int) -> bool:
        return self._shared[slot][idx]

    def cow_targets(self, slot: int, start: int, end: int) -> List[int]:
        """Logical page indices of ``slot`` that are mapped read-only and
        overlap token positions [start, end) — the pages a writer must
        ``fork_page`` before touching."""
        if end <= start:
            return []
        lo = start // self.page_size
        hi = (end - 1) // self.page_size
        flags = self._shared[slot]
        return [i for i in range(lo, min(hi, len(flags) - 1) + 1)
                if flags[i]]

    # ----------------------------------------------------------- mutations
    def _return_if_dead(self, page: int) -> None:
        if self._table_refs[page] == 0 and self._pins[page] == 0 \
                and page != TRASH_PAGE:
            self._free.append(page)

    def allocate(self, slot: int, n: int) -> bool:
        """Append ``n`` fresh private pages to ``slot``'s block-table row.
        Returns False (allocating nothing) if the pool or the row can't
        hold them."""
        owned = self._owned[slot]
        if not self.can_allocate(n) \
                or len(owned) + n > self.max_pages_per_slot:
            return False
        if n:
            self.version += 1
            self._c_alloc.inc(n)
        for _ in range(n):
            pg = self._free.pop()
            self.tables[slot, len(owned)] = pg
            owned.append(pg)
            self._shared[slot].append(False)
            self._table_refs[pg] += 1
        return True

    def map_shared(self, slot: int, pages: Sequence[int]) -> bool:
        """Append live ``pages`` read-only to ``slot``'s row (refcount++
        each) — prefix-cache admission.  The pages stay owned by whoever
        else maps or pins them; this slot must ``fork_page`` before any
        write.  Returns False (mapping nothing) if the row can't hold
        them; raises if a page is dead or the trash page (a scheduler bug
        — shared mappings must come from live cache entries)."""
        owned = self._owned[slot]
        if len(owned) + len(pages) > self.max_pages_per_slot:
            return False
        if not pages:
            return True
        for pg in pages:
            if pg == TRASH_PAGE or self.page_refcount(pg) == 0:
                raise ValueError(
                    f"map_shared: page {pg} is "
                    f"{'the trash page' if pg == TRASH_PAGE else 'dead'}")
        self.version += 1
        self._c_shared.inc(len(pages))
        for pg in pages:
            self.tables[slot, len(owned)] = pg
            owned.append(pg)
            self._shared[slot].append(True)
            self._table_refs[pg] += 1
        return True

    def fork_page(self, slot: int, idx: int) -> Optional[Tuple[int, int]]:
        """Copy-on-write: replace ``slot``'s read-only entry ``idx`` with a
        fresh private page.  Returns ``(src, dst)`` physical ids — the
        caller must copy the device page contents src -> dst before
        writing — or None when the pool is exhausted.  The shared original
        is decref'd (and freed if this was its last reference)."""
        if not self._shared[slot][idx]:
            raise ValueError(f"fork_page: slot {slot} entry {idx} is "
                             f"already private")
        if not self._free:
            return None
        src = self._owned[slot][idx]
        dst = self._free.pop()
        self.version += 1
        self._c_forks.inc()
        self.tables[slot, idx] = dst
        self._owned[slot][idx] = dst
        self._shared[slot][idx] = False
        self._table_refs[dst] += 1
        self._table_refs[src] -= 1
        self._return_if_dead(src)
        return src, dst

    def ensure(self, slot: int, tokens: int) -> bool:
        """Grow ``slot``'s allocation to cover ``tokens`` positions."""
        need = pages_needed(tokens, self.page_size) - self.slot_pages(slot)
        if need <= 0:
            return True
        if self.fault_hook is not None and self.fault_hook(need):
            return False
        return self.allocate(slot, need)

    def pin(self, page: int) -> None:
        """External (prefix-cache) reference: the page survives ``release``
        of every slot mapping it until ``unpin``.  Never touches tables,
        so ``version`` is unchanged."""
        if page == TRASH_PAGE:
            raise ValueError("pin: the trash page is not pinnable")
        if self.page_refcount(page) == 0:
            raise ValueError(f"pin: page {page} is dead (pin must happen "
                             f"while an owner still maps it)")
        self._pins[page] += 1

    def unpin(self, page: int) -> None:
        if self._pins[page] <= 0:
            raise ValueError(f"unpin: page {page} has no pins")
        self._pins[page] -= 1
        self._return_if_dead(page)

    def swap_out(self, slot: int) -> List[Tuple[int, bool]]:
        """Snapshot-and-release for preemption: returns ``slot``'s
        ``(physical page, was_shared)`` entries in logical order, then
        releases the row exactly like :meth:`release`.  The caller must
        have copied the pages' device contents to the swap store *before*
        this call — afterwards the non-shared, non-pinned pages are back
        on the free list and may be rewritten at any time."""
        row = list(zip(self._owned[slot],
                       (bool(s) for s in self._shared[slot])))
        self.release(slot)
        return row

    def release(self, slot: int) -> None:
        """Decref all of ``slot``'s pages and re-point its row at trash.
        Pages still mapped by other slots or pinned by the prefix cache
        stay live; the rest return to the free list."""
        if self._owned[slot]:
            self.version += 1
            self._c_released.inc(len(self._owned[slot]))
        for pg in reversed(self._owned[slot]):
            self._table_refs[pg] -= 1
            self._return_if_dead(pg)
        self._owned[slot] = []
        self._shared[slot] = []
        self.tables[slot, :] = TRASH_PAGE

    # pre-refcount name (PR 2-5 callers/tests); release semantics are a
    # strict superset — sole-owner pages free exactly as before
    free_slot = release
