"""Prefix cache: a trie over full KV pages keyed by page token content.

Each trie node represents one *full* page of ``page_size`` tokens and is
keyed by that page's token tuple **under its parent chain** — so a node's
path from the root spells out the entire token prefix, and two pages with
identical local tokens but different histories never collide (causal
attention makes a page's KV a function of every token before it, so
content-addressing must hash the whole chain, not the page alone).

A node holds the physical page id of the canonical KV copy and ``pin``s it
in the :class:`~repro.serve.paging.BlockManager`, so the page outlives the
request that computed it.  Admission walks the trie with the new prompt's
page tuples; the longest matched chain's pages are mapped read-only into
the new slot (``map_shared``) and only the uncached suffix is prefilled.

Insertion dedupes: walking an existing node keeps the canonical page and
ignores the caller's duplicate (whose refcount simply drops when its slot
releases).  Under MX quantization the dedupe is exact — a page's quantized
bytes are a deterministic function of the token prefix, so the canonical
copy is bit-identical to the duplicate it shadows.

``reclaim(n)`` unpins least-recently-used *leaves* until ``n`` pages have
actually returned to the free list (an unpinned page still mapped by a
running slot frees nothing yet) — the scheduler calls it when pinned pages
would otherwise starve admission.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.obs.metrics import MetricsRegistry
from repro.serve.paging import BlockManager


class _Node:
    __slots__ = ("key", "page", "parent", "children", "last_use")

    def __init__(self, key: Tuple[int, ...], page: int,
                 parent: Optional["_Node"]):
        self.key = key
        self.page = page
        self.parent = parent
        self.children: Dict[Tuple[int, ...], "_Node"] = {}
        self.last_use = 0


class PrefixCache:
    """Trie of pinned full KV pages over ``blocks``.

    ``max_pages`` caps how many pages the trie may pin (None = unbounded
    up to the pool); insertion past the cap reclaims LRU leaves first and
    skips the insert if nothing can be evicted.
    """

    def __init__(self, blocks: BlockManager,
                 max_pages: Optional[int] = None,
                 metrics: Optional[MetricsRegistry] = None):
        self.blocks = blocks
        self.page_size = blocks.page_size
        self.max_pages = max_pages
        self._root = _Node((), -1, None)
        self._n_nodes = 0
        self._tick = 0
        # admission stats (recorded once per admitted request, not per
        # speculative lookup — see Scheduler.admit); registry-backed so
        # reset_metrics / snapshot export see them with everything else
        self.metrics = MetricsRegistry() if metrics is None else metrics
        self._c_lookups = self.metrics.counter(
            "prefix.lookups", "admissions probing the trie")
        self._c_hits = self.metrics.counter(
            "prefix.hits", "admissions matching a non-empty prefix")
        self._c_tokens = self.metrics.counter(
            "prefix.tokens_matched", "prompt tokens served from cache")

    # registry-backed stat views (setters: snapshot restore rewinds)
    @property
    def lookups(self) -> int:
        return int(self._c_lookups.value())

    @lookups.setter
    def lookups(self, v: int) -> None:
        self._c_lookups.set(int(v))

    @property
    def hits(self) -> int:
        return int(self._c_hits.value())

    @hits.setter
    def hits(self, v: int) -> None:
        self._c_hits.set(int(v))

    @property
    def tokens_matched(self) -> int:
        return int(self._c_tokens.value())

    @tokens_matched.setter
    def tokens_matched(self, v: int) -> None:
        self._c_tokens.set(int(v))

    # ------------------------------------------------------------- queries
    @property
    def pinned_pages(self) -> int:
        return self._n_nodes

    def _keys(self, tokens: Sequence[int]) -> List[Tuple[int, ...]]:
        toks = np.asarray(tokens).reshape(-1)
        ps = self.page_size
        return [tuple(int(t) for t in toks[i * ps:(i + 1) * ps])
                for i in range(len(toks) // ps)]

    def lookup(self, tokens: Sequence[int]) -> Tuple[List[int], int]:
        """Longest cached full-page prefix of ``tokens``.

        Returns ``(pages, matched_tokens)`` — the canonical physical page
        ids of the matched chain (all pinned, hence live) and the token
        count they cover (a page-size multiple).  Touches the chain's LRU
        clocks; stats are recorded separately (``record``) so speculative
        re-lookups of a still-waiting request don't skew the hit rate."""
        self._tick += 1
        node = self._root
        pages: List[int] = []
        for key in self._keys(tokens):
            child = node.children.get(key)
            if child is None:
                break
            child.last_use = self._tick
            pages.append(child.page)
            node = child
        return pages, len(pages) * self.page_size

    def record(self, matched_tokens: int) -> None:
        """Count one admission against the hit-rate stats."""
        self._c_lookups.inc()
        if matched_tokens > 0:
            self._c_hits.inc()
            self._c_tokens.inc(matched_tokens)

    # ----------------------------------------------------------- mutation
    def insert(self, tokens: Sequence[int], page_ids: Sequence[int]) -> int:
        """Insert the full pages of ``tokens``, whose KV lives in
        ``page_ids`` (one physical id per full page, trash-free, currently
        mapped by the caller's slot).  Existing nodes dedupe — the caller's
        duplicate page is not pinned; new nodes pin the caller's page.
        Returns the number of pages newly pinned."""
        self._tick += 1
        keys = self._keys(tokens)
        node = self._root
        added = 0
        for key, pg in zip(keys, page_ids):
            child = node.children.get(key)
            if child is None:
                if self.max_pages is not None \
                        and self._n_nodes >= self.max_pages \
                        and self.reclaim_nodes(1) == 0:
                    break
                self.blocks.pin(int(pg))
                child = _Node(key, int(pg), node)
                node.children[key] = child
                self._n_nodes += 1
                added += 1
            child.last_use = self._tick
            node = child
        return added

    def _leaves(self) -> List[_Node]:
        out = []
        stack = list(self._root.children.values())
        while stack:
            n = stack.pop()
            if n.children:
                stack.extend(n.children.values())
            else:
                out.append(n)
        return out

    def _drop(self, node: _Node) -> None:
        del node.parent.children[node.key]
        self._n_nodes -= 1
        self.blocks.unpin(node.page)

    def reclaim_nodes(self, n: int) -> int:
        """Unpin up to ``n`` LRU leaf nodes; returns how many were
        dropped (regardless of whether their pages freed)."""
        dropped = 0
        while dropped < n:
            leaves = self._leaves()
            if not leaves:
                break
            self._drop(min(leaves, key=lambda nd: nd.last_use))
            dropped += 1
        return dropped

    def reclaim(self, n_pages: int) -> int:
        """Drop LRU leaves until ``n_pages`` pages have actually returned
        to the free list, or the trie is empty.  Returns pages freed."""
        freed = 0
        while freed < n_pages:
            leaves = self._leaves()
            if not leaves:
                break
            victim = min(leaves, key=lambda nd: nd.last_use)
            before = self.blocks.free_pages
            self._drop(victim)
            freed += self.blocks.free_pages - before
        return freed
