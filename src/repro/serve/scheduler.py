"""Slot-based continuous-batching scheduler.

Requests arrive with arbitrary prompt lengths and generation budgets; the
scheduler admits them into a fixed number of decode slots as slots and KV
pages free up, and evicts them on completion.  Admission is conservative:
a request is only admitted when the pool can hold its whole sequence
(prompt + max_new_tokens), so an in-flight request can never stall on page
exhaustion — preemption/swapping is future work.
"""
from __future__ import annotations

import dataclasses
import enum
from collections import deque
from typing import Deque, Dict, List, Optional

import numpy as np

from repro.serve.paging import BlockManager, pages_needed
from repro.serve.prefix import PrefixCache


class RequestState(enum.Enum):
    WAITING = "waiting"
    RUNNING = "running"
    FINISHED = "finished"


@dataclasses.dataclass
class Request:
    """One generation request's lifecycle through the engine."""
    rid: int
    prompt: np.ndarray                  # (L,) int32
    max_new_tokens: int
    state: RequestState = RequestState.WAITING
    slot: int = -1
    out: List[int] = dataclasses.field(default_factory=list)
    # prefix-cache admission outcome (0 / cold when sharing is off):
    # tokens covered by pages mapped read-only from the prefix trie, and
    # the copy-on-write forks the engine still owes before prefill (a
    # fully-cached prompt forks its last page to rewrite position L-1)
    matched_tokens: int = 0
    cow_pending: int = 0

    @property
    def prompt_len(self) -> int:
        return int(self.prompt.shape[0])

    @property
    def prefill_start(self) -> int:
        """First prompt position the engine must actually compute: the
        matched prefix is skipped, but the last prompt position is always
        recomputed — its logits seed the first generated token."""
        return min(self.matched_tokens, self.prompt_len - 1)

    @property
    def total_len(self) -> int:
        """Upper bound on cache positions the request can occupy."""
        return self.prompt_len + self.max_new_tokens

    @property
    def done(self) -> bool:
        return len(self.out) >= self.max_new_tokens

    @property
    def remaining(self) -> int:
        """Tokens the request is still entitled to generate."""
        return self.max_new_tokens - len(self.out)


class Scheduler:
    """FIFO admission into ``max_slots`` decode slots backed by ``blocks``."""

    def __init__(self, max_slots: int, blocks: BlockManager,
                 prefix: Optional[PrefixCache] = None):
        self.max_slots = max_slots
        self.blocks = blocks
        self.prefix = prefix
        self.waiting: Deque[Request] = deque()
        self.running: Dict[int, Request] = {}       # slot -> request
        self.finished: List[Request] = []
        self._free_slots = list(range(max_slots - 1, -1, -1))

    # ------------------------------------------------------------- queries
    @property
    def free_slots(self) -> int:
        return len(self._free_slots)

    def has_work(self) -> bool:
        return bool(self.waiting or self.running)

    # ----------------------------------------------------------- lifecycle
    def submit(self, req: Request) -> None:
        need = pages_needed(req.total_len, self.blocks.page_size)
        if need > self.blocks.max_pages_per_slot \
                or need > self.blocks.num_pages - 1:
            raise ValueError(
                f"request {req.rid}: {req.total_len} tokens ({need} pages) "
                f"can never fit a slot "
                f"({self.blocks.max_pages_per_slot} pages) or the pool "
                f"({self.blocks.num_pages - 1} usable pages)")
        self.waiting.append(req)

    def _outstanding_pages(self) -> int:
        """*Fresh* pages the running set is still entitled to consume.
        Admission must leave these uncommitted or a running slot could
        stall on page exhaustion mid-generation.

        Counting ``pages_needed(total_len)`` per request would double-count
        under prefix sharing: pages mapped read-only into a slot cost the
        pool nothing, yet sole-ownership accounting reserves fresh pages
        for them and starves admission.  ``slot_pages`` already includes
        the shared mappings, so the difference is exactly the private
        growth — plus any copy-on-write fork the engine still owes (a
        fork consumes one fresh page while the shared original lives on).
        """
        return sum(
            pages_needed(r.total_len, self.blocks.page_size)
            - self.blocks.slot_pages(r.slot) + r.cow_pending
            for r in self.running.values())

    def admit(self) -> List[Request]:
        """Admit waiting requests (FIFO, no head-of-line bypass) while a
        slot is free and the pool can hold their full sequence on top of
        what the running set is already entitled to.

        With a :class:`PrefixCache` installed, the longest cached full-page
        prefix of each prompt is mapped read-only into the new slot
        (refcount++, no fresh pages) and only the *private* remainder —
        uncached prompt pages, decode growth, and the COW fork of a
        fully-cached prompt's last page — is charged against the free
        pool.  When pinned-but-unmapped trie pages are all that stand
        between a request and admission, the trie reclaims them LRU-first.
        """
        admitted = []
        while self.waiting and self._free_slots:
            req = self.waiting[0]
            need_total = pages_needed(req.total_len, self.blocks.page_size)
            pages: List[int] = []
            matched = 0
            if self.prefix is not None:
                pages, matched = self.prefix.lookup(req.prompt)
            cow = 1 if matched and matched >= req.prompt_len else 0
            need_private = need_total - len(pages) + cow
            # map the match before any reclaim: table refs protect the
            # matched chain from being recycled by its own unpinning
            slot = self._free_slots[-1]
            if pages:
                ok = self.blocks.map_shared(slot, pages)
                assert ok, "submit() bounded the row; a full-page match "\
                    "of the prompt always fits it"
            avail = self.blocks.free_pages - self._outstanding_pages()
            if need_private > avail and self.prefix is not None:
                avail += self.prefix.reclaim(need_private - avail)
            if need_private > avail:
                self.blocks.release(slot)   # undo the tentative mapping
                break                       # FIFO: wait for evictions
            self._free_slots.pop()
            priv = pages_needed(req.prompt_len, self.blocks.page_size) \
                - len(pages)
            if priv > 0:
                ok = self.blocks.allocate(slot, priv)
                assert ok
            req.slot = slot
            req.matched_tokens = matched
            req.cow_pending = cow
            req.state = RequestState.RUNNING
            self.running[slot] = req
            self.waiting.popleft()
            admitted.append(req)
            if self.prefix is not None:
                self.prefix.record(matched)
        return admitted

    # ------------------------------------------------- decode-window planning
    def grant_horizon(self, req: Request, length: int) -> int:
        """Decode steps ``req``'s slot can take before its next KV write
        would land past the pages it currently owns (writes go to positions
        ``length``, ``length + 1``, ...)."""
        return self.blocks.slot_capacity(req.slot) - length

    def plan_window(self, lengths, sync_every: int) -> int:
        """Plan the next device-resident decode window.

        Returns the number of fused decode steps to run — ``sync_every``
        capped by the longest remaining generation budget (so a window is
        never all dead steps), rounded up to a power of two so the jitted
        scan compiles for at most log2(sync_every)+1 distinct lengths —
        and pre-grants every running slot the pages its window writes
        need, clamped to the request's reserved full-sequence capacity.
        Because admission reserved that capacity, the grants cannot fail,
        and the fused ``lax.scan`` can run to the horizon without exiting
        to the host for a page grant.  Slots whose budget runs out inside
        the window are masked on device (their writes land on the trash
        page) and recycled at the next sync point.
        """
        if not self.running:
            return 0
        need = max(r.remaining for r in self.running.values())
        window = min(max(1, int(sync_every)),
                     1 << (need - 1).bit_length())
        for slot, req in self.running.items():
            tgt = min(int(lengths[slot]) + window + 1, req.total_len)
            ok = self.blocks.ensure(slot, tgt)
            assert ok, "admission reserved full-sequence capacity"
            assert self.grant_horizon(req, int(lengths[slot])) \
                >= min(window, req.remaining), "page grant below horizon"
        return window

    def evict(self, req: Request) -> None:
        """Release a finished request's slot: every page is decref'd —
        pages still shared with other slots or pinned by the prefix cache
        stay live, the rest return to the free list."""
        req.state = RequestState.FINISHED
        self.blocks.release(req.slot)
        del self.running[req.slot]
        self._free_slots.append(req.slot)
        req.slot = -1
        self.finished.append(req)
