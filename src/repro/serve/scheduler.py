"""Slot-based continuous-batching scheduler with SLO-aware admission.

Requests arrive with arbitrary prompt lengths, generation budgets, and an
SLO class (``priority`` — lower is more urgent — plus an optional
time-to-first-token ``deadline_s``); the scheduler admits them into a
fixed number of decode slots as slots and KV pages free up, and evicts
them on completion.

Admission order is (priority, EDF deadline, arrival) — FIFO within a
class, so the PR-2 behavior is unchanged when every request uses the
default class.  Admission is conservative: a request is only admitted
when the pool can hold its whole sequence (prompt + max_new_tokens), so
an in-flight request can never stall on page exhaustion.

**Preempt-and-swap** (this PR): when the head of the queue cannot be
admitted and a strictly lower-priority request is running,
``pick_victim`` nominates the youngest, least-important runner; the
engine copies the victim's KV pages to the host swap store (MX codes
stay packed, so the swap traffic is already compressed) and calls
:meth:`preempt`, which frees the slot and re-queues the victim at its
*original* (priority, arrival) rank — it resumes ahead of later arrivals
of its class, page-for-page, token-identically.  Restored requests skip
prefill entirely: admission allocates the same number of private pages
the victim held and the engine scatters the saved contents back.
"""
from __future__ import annotations

import bisect
import dataclasses
import enum
import time
from typing import Dict, List, Optional

import numpy as np

from repro.obs.metrics import MetricsRegistry
from repro.serve.paging import BlockManager, PageGrantError, pages_needed
from repro.serve.prefix import PrefixCache


class RequestState(enum.Enum):
    WAITING = "waiting"
    RUNNING = "running"
    SWAPPED = "swapped"         # preempted; KV pages live in the swap store
    FINISHED = "finished"
    FAILED = "failed"           # quarantined by a numeric-health guard


@dataclasses.dataclass
class Request:
    """One generation request's lifecycle through the engine."""
    rid: int
    prompt: np.ndarray                  # (L,) int32
    max_new_tokens: int
    # ---- SLO class -------------------------------------------------------
    priority: int = 0                   # lower = more urgent
    deadline_s: Optional[float] = None  # TTFT target, seconds from arrival
    # ----------------------------------------------------------------------
    state: RequestState = RequestState.WAITING
    slot: int = -1
    out: List[int] = dataclasses.field(default_factory=list)
    # prefix-cache admission outcome (0 / cold when sharing is off):
    # tokens covered by pages mapped read-only from the prefix trie, and
    # the copy-on-write forks the engine still owes before prefill (a
    # fully-cached prompt forks its last page to rewrite position L-1)
    matched_tokens: int = 0
    cow_pending: int = 0
    # ---- scheduling / preemption state ----------------------------------
    seq: int = -1                       # arrival rank (set by submit)
    swap_pages: int = 0                 # pages to re-allocate on restore
    n_preemptions: int = 0
    # ---- fault tolerance -------------------------------------------------
    error: Optional[str] = None         # quarantine diagnostic (FAILED)
    n_retries: int = 0                  # times re-queued after quarantine
    # ---- latency observability (bench_serve schema v4) ------------------
    arrival_t: Optional[float] = None   # perf_counter at add_request
    t_admitted: Optional[float] = None  # first admission
    t_tokens: List[float] = dataclasses.field(default_factory=list)
    t_finished: Optional[float] = None

    @property
    def prompt_len(self) -> int:
        return int(self.prompt.shape[0])

    @property
    def prefill_start(self) -> int:
        """First prompt position the engine must actually compute: the
        matched prefix is skipped, but the last prompt position is always
        recomputed — its logits seed the first generated token."""
        return min(self.matched_tokens, self.prompt_len - 1)

    @property
    def total_len(self) -> int:
        """Upper bound on cache positions the request can occupy."""
        return self.prompt_len + self.max_new_tokens

    @property
    def done(self) -> bool:
        return len(self.out) >= self.max_new_tokens

    @property
    def remaining(self) -> int:
        """Tokens the request is still entitled to generate."""
        return self.max_new_tokens - len(self.out)

    # ---- derived latency metrics ----------------------------------------
    @property
    def ttft_s(self) -> Optional[float]:
        """Arrival -> first visible token (None until both exist)."""
        if self.arrival_t is None or not self.t_tokens:
            return None
        return self.t_tokens[0] - self.arrival_t

    @property
    def itl_s(self) -> List[float]:
        """Inter-token gaps between *visible* token timestamps.  Tokens
        surfacing in the same fused decode window share a sync-boundary
        stamp — a gap of ~0 is the honest latency of window delivery."""
        return [b - a for a, b in zip(self.t_tokens, self.t_tokens[1:])]

    @property
    def deadline_met(self) -> Optional[bool]:
        """TTFT SLO outcome (None when no deadline or no token yet)."""
        if self.deadline_s is None:
            return None
        t = self.ttft_s
        return None if t is None else t <= self.deadline_s


def _order(req: Request):
    """Admission rank: priority class first, earliest TTFT deadline (EDF)
    within a class, then arrival order.  Default-class requests with no
    deadline reduce to pure FIFO."""
    if req.arrival_t is not None and req.deadline_s is not None:
        dl = req.arrival_t + req.deadline_s
    else:
        dl = float("inf")
    return (req.priority, dl, req.seq)


class Scheduler:
    """Priority admission into ``max_slots`` decode slots backed by
    ``blocks`` (FIFO within an SLO class; strict FIFO when every request
    uses the default class)."""

    def __init__(self, max_slots: int, blocks: BlockManager,
                 prefix: Optional[PrefixCache] = None,
                 metrics: Optional[MetricsRegistry] = None):
        self.max_slots = max_slots
        self.blocks = blocks
        self.prefix = prefix
        self.waiting: List[Request] = []        # kept sorted by _order
        self.running: Dict[int, Request] = {}   # slot -> request
        self.finished: List[Request] = []
        self.failed: List[Request] = []         # quarantined (FAILED)
        self._free_slots = list(range(max_slots - 1, -1, -1))
        self._seq = 0
        # registry-backed counters (standalone scheduler: own registry)
        self.metrics = MetricsRegistry() if metrics is None else metrics
        self._c_preempt = self.metrics.counter(
            "sched.preemptions", "preempt() calls")
        self._c_restores = self.metrics.counter(
            "sched.restores", "SWAPPED re-admissions")

    # registry-backed counter views (setters: snapshot restore rewinds)
    @property
    def n_preemptions(self) -> int:
        return int(self._c_preempt.value())

    @n_preemptions.setter
    def n_preemptions(self, v: int) -> None:
        self._c_preempt.set(int(v))

    @property
    def n_restores(self) -> int:
        return int(self._c_restores.value())

    @n_restores.setter
    def n_restores(self, v: int) -> None:
        self._c_restores.set(int(v))

    # ------------------------------------------------------------- queries
    @property
    def free_slots(self) -> int:
        return len(self._free_slots)

    def has_work(self) -> bool:
        return bool(self.waiting or self.running)

    # ----------------------------------------------------------- lifecycle
    def submit(self, req: Request) -> None:
        need = pages_needed(req.total_len, self.blocks.page_size)
        if need > self.blocks.max_pages_per_slot \
                or need > self.blocks.num_pages - 1:
            raise ValueError(
                f"request {req.rid}: {req.total_len} tokens ({need} pages) "
                f"can never fit a slot "
                f"({self.blocks.max_pages_per_slot} pages) or the pool "
                f"({self.blocks.num_pages - 1} usable pages)")
        if req.seq < 0:
            req.seq = self._seq
            self._seq += 1
        bisect.insort(self.waiting, req, key=_order)

    def _outstanding_pages(self) -> int:
        """*Fresh* pages the running set is still entitled to consume.
        Admission must leave these uncommitted or a running slot could
        stall on page exhaustion mid-generation.

        Counting ``pages_needed(total_len)`` per request would double-count
        under prefix sharing: pages mapped read-only into a slot cost the
        pool nothing, yet sole-ownership accounting reserves fresh pages
        for them and starves admission.  ``slot_pages`` already includes
        the shared mappings, so the difference is exactly the private
        growth — plus any copy-on-write fork the engine still owes (a
        fork consumes one fresh page while the shared original lives on).
        """
        return sum(
            pages_needed(r.total_len, self.blocks.page_size)
            - self.blocks.slot_pages(r.slot) + r.cow_pending
            for r in self.running.values())

    def admit(self) -> List[Request]:
        """Admit waiting requests in (priority, deadline, arrival) order —
        no head-of-line bypass — while a slot is free and the pool can
        hold their full sequence on top of what the running set is
        already entitled to.

        With a :class:`PrefixCache` installed, the longest cached full-page
        prefix of each prompt is mapped read-only into the new slot
        (refcount++, no fresh pages) and only the *private* remainder —
        uncached prompt pages, decode growth, and the COW fork of a
        fully-cached prompt's last page — is charged against the free
        pool.  When pinned-but-unmapped trie pages are all that stand
        between a request and admission, the trie reclaims them LRU-first.

        A SWAPPED request (preempted earlier) is re-admitted without a
        prefix lookup: it gets exactly the private pages it held at
        swap-out; the engine then restores their contents from the host
        swap store instead of prefilling.
        """
        admitted = []
        while self.waiting and self._free_slots:
            req = self.waiting[0]
            restoring = req.state is RequestState.SWAPPED
            need_total = pages_needed(req.total_len, self.blocks.page_size)
            pages: List[int] = []
            matched = 0
            if self.prefix is not None and not restoring:
                pages, matched = self.prefix.lookup(req.prompt)
            cow = 1 if matched and matched >= req.prompt_len else 0
            need_private = need_total - len(pages) + cow
            # map the match before any reclaim: table refs protect the
            # matched chain from being recycled by its own unpinning
            slot = self._free_slots[-1]
            if pages:
                ok = self.blocks.map_shared(slot, pages)
                assert ok, "submit() bounded the row; a full-page match "\
                    "of the prompt always fits it"
            avail = self.blocks.free_pages - self._outstanding_pages()
            if need_private > avail and self.prefix is not None:
                avail += self.prefix.reclaim(need_private - avail)
            if need_private > avail:
                self.blocks.release(slot)   # undo the tentative mapping
                break                       # in-class FIFO: wait
            self._free_slots.pop()
            if restoring:
                priv = req.swap_pages
                self._c_restores.inc()
            else:
                priv = pages_needed(req.prompt_len, self.blocks.page_size) \
                    - len(pages)
            if priv > 0:
                ok = self.blocks.allocate(slot, priv)
                assert ok
            req.slot = slot
            req.matched_tokens = matched
            req.cow_pending = cow
            req.state = RequestState.RUNNING
            if req.t_admitted is None:
                req.t_admitted = time.perf_counter()
            self.running[slot] = req
            self.waiting.pop(0)
            admitted.append(req)
            if self.prefix is not None and not restoring:
                self.prefix.record(matched)
        return admitted

    # --------------------------------------------------- preempt-and-swap
    def _fits(self, req: Request) -> bool:
        """Would :meth:`admit` take ``req`` right now?  Conservative twin
        of the admit() arithmetic (no trie reclaim attempt): a free slot
        plus enough uncommitted pages for the private part of its full
        sequence."""
        if not self._free_slots:
            return False
        need = pages_needed(req.total_len, self.blocks.page_size)
        if self.prefix is not None \
                and req.state is not RequestState.SWAPPED:
            pages, matched = self.prefix.lookup(req.prompt)
            need -= len(pages)
            if matched and matched >= req.prompt_len:
                need += 1                   # the COW fork of the last page
        return need <= self.blocks.free_pages - self._outstanding_pages()

    def can_admit_now(self, prompt, max_new_tokens: int) -> bool:
        """Reject-on-full admission probe (``AsyncServer``): would a fresh
        request start *immediately* — nothing queued ahead of it and a
        slot + pages available?"""
        if self.waiting:
            return False
        probe = Request(rid=-1, prompt=np.asarray(prompt, np.int32),
                        max_new_tokens=max_new_tokens)
        return self._fits(probe)

    def pick_victim(self) -> Optional[Request]:
        """Nominate a running request to preempt so the head of the
        waiting queue can be admitted: only when the head cannot fit as
        is and a *strictly* lower-priority request is running (strictness
        prevents same-class thrash).  Among candidates the youngest of
        the least important class is chosen — it has the least sunk
        decode work of the requests the SLO ranks lowest.

        Returns None when no preemption is warranted; the engine calls
        this in a loop, swapping one victim at a time, until the head
        fits or no candidate remains."""
        if not self.waiting:
            return None
        head = self.waiting[0]
        if self._fits(head):
            return None                     # admit() will take it as is
        cands = [r for r in self.running.values()
                 if r.priority > head.priority]
        if not cands:
            return None
        return max(cands, key=lambda r: (r.priority, r.seq))

    def preempt(self, req: Request) -> None:
        """Book-keep a preemption *after* the engine copied the victim's
        pages to the swap store: free the slot (its private non-pinned
        pages return to the pool), and re-queue the request at its
        original (priority, arrival) rank so it resumes ahead of later
        arrivals of its class."""
        assert req.state is RequestState.RUNNING, \
            "only a running request can be preempted"
        assert req.swap_pages > 0, \
            "preempt() requires the engine to have swapped the pages out"
        slot = req.slot
        self.blocks.release(slot)
        del self.running[slot]
        self._free_slots.append(slot)
        req.slot = -1
        req.matched_tokens = 0              # restored pages are private
        req.cow_pending = 0
        req.state = RequestState.SWAPPED
        req.n_preemptions += 1
        self._c_preempt.inc()
        bisect.insort(self.waiting, req, key=_order)

    # ------------------------------------------------- decode-window planning
    def grant_horizon(self, req: Request, length: int) -> int:
        """Decode steps ``req``'s slot can take before its next KV write
        would land past the pages it currently owns (writes go to positions
        ``length``, ``length + 1``, ...)."""
        return self.blocks.slot_capacity(req.slot) - length

    def plan_window(self, lengths, sync_every: int) -> int:
        """Plan the next device-resident decode window.

        Returns the number of fused decode steps to run — ``sync_every``
        capped by the longest remaining generation budget (so a window is
        never all dead steps), rounded up to a power of two so the jitted
        scan compiles for at most log2(sync_every)+1 distinct lengths —
        and pre-grants every running slot the pages its window writes
        need, clamped to the request's reserved full-sequence capacity.
        Because admission reserved that capacity, the grants cannot fail,
        and the fused ``lax.scan`` can run to the horizon without exiting
        to the host for a page grant.  Slots whose budget runs out inside
        the window are masked on device (their writes land on the trash
        page) and recycled at the next sync point.
        """
        if not self.running:
            return 0
        need = max(r.remaining for r in self.running.values())
        window = min(max(1, int(sync_every)),
                     1 << (need - 1).bit_length())
        for slot, req in self.running.items():
            tgt = min(int(lengths[slot]) + window + 1, req.total_len)
            ok = self.blocks.ensure(slot, tgt)
            if not ok:
                # admission reserved full-sequence capacity, so a failed
                # grant is a (possibly injected) allocator fault — raise
                # a recoverable error naming the slot; the engine swaps
                # that request out and resumes it token-identically later
                raise PageGrantError(
                    slot, pages_needed(tgt, self.blocks.page_size)
                    - self.blocks.slot_pages(slot))
            assert self.grant_horizon(req, int(lengths[slot])) \
                >= min(window, req.remaining), "page grant below horizon"
        return window

    def evict(self, req: Request) -> None:
        """Release a finished request's slot: every page is decref'd —
        pages still shared with other slots or pinned by the prefix cache
        stay live, the rest return to the free list."""
        req.state = RequestState.FINISHED
        self.blocks.release(req.slot)
        del self.running[req.slot]
        self._free_slots.append(req.slot)
        req.slot = -1
        self.finished.append(req)

    # ---------------------------------------------------- fault tolerance
    def fail(self, req: Request, error: str) -> None:
        """Quarantine a running request: free its slot and pages exactly
        like :meth:`evict`, but record the health-guard diagnostic and
        park it on ``failed`` instead of ``finished`` — its tokens were
        suppressed, not served."""
        assert req.state is RequestState.RUNNING, \
            "only a running request can be quarantined"
        req.error = error
        req.state = RequestState.FAILED
        self.blocks.release(req.slot)
        del self.running[req.slot]
        self._free_slots.append(req.slot)
        req.slot = -1
        self.failed.append(req)

    def requeue(self, req: Request) -> None:
        """Re-queue a quarantined request for a retry: reset its
        generation state (same rid — the per-slot PRNG key derives from
        it, so a clean replay is token-identical) and re-enter the
        waiting queue at the original arrival rank.  The request leaves
        ``failed``; only requests still there when the dust settles are
        permanent failures."""
        assert req.state is RequestState.FAILED, \
            "only a quarantined request can be requeued"
        self.failed.remove(req)
        req.state = RequestState.WAITING
        req.error = None
        req.out = []
        req.t_tokens = []
        req.t_finished = None
        req.matched_tokens = 0
        req.cow_pending = 0
        req.swap_pages = 0
        req.n_retries += 1
        bisect.insort(self.waiting, req, key=_order)
