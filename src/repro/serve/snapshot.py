"""Engine snapshot / restore: a full, token-identical serving checkpoint.

``capture(engine)`` copies everything the continuous-batching engine needs
to reproduce its stream bit-for-bit from this boundary on:

* the paged KV pool (MX codes still packed — the snapshot is as compressed
  as the cache itself), pulled to host numpy;
* the :class:`~repro.serve.paging.BlockManager` — block tables, free list,
  per-slot ownership + shared flags, refcounts, pins, version;
* the scheduler — waiting/running membership, free slots, arrival counter,
  and the full per-request mutable state (out tokens, budgets, timestamps,
  retry counters) of every request the engine tracks;
* the host swap store's resident entries and traffic counters;
* the prefix trie (node keys, canonical pages, LRU clocks) — pins are
  *not* re-taken on restore, they ride the BlockManager refcount arrays;
* the engine's own slot mirrors (current token, lengths, budgets), the
  per-slot PRNG keys and the admission fold key, and the serving counters.

``restore(engine, snap)`` writes that state back **into the same live
objects** — request objects are mutated in place, so front-end streams
holding references keep working — and re-uploads the pool.  Restoring is
token-identical: a stream that continues from the restored state emits
exactly the tokens the original would have (asserted in
``tests/test_serve_snapshot.py``).  Two deliberate non-rollbacks:

* ``engine._next_rid`` / ``scheduler._seq`` keep their *current* values
  (monotone counters) so requests submitted after the snapshot can be
  resubmitted post-restore without rid collisions;
* requests the snapshot never saw are simply dropped from the queues —
  the front end re-enters them via ``engine.resubmit``.

The snapshot is an in-memory object (host numpy + plain python), sized by
the page pool; it is the recovery substrate for the front end's watchdog
(``AsyncServer(watchdog_s=...)``), not an on-disk format.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def _dump_trie(node) -> Dict[str, Any]:
    return {"key": node.key, "page": node.page, "last_use": node.last_use,
            "children": [_dump_trie(c) for c in node.children.values()]}


def _load_trie(parent, dump: Dict[str, Any], node_cls) -> int:
    """Rebuild ``dump``'s children under ``parent``; returns nodes made.
    Pages are NOT pinned here — the restored BlockManager pin array
    already carries the trie's pins."""
    n = 0
    for cd in dump["children"]:
        child = node_cls(tuple(cd["key"]), cd["page"], parent)
        child.last_use = cd["last_use"]
        parent.children[child.key] = child
        n += 1 + _load_trie(child, cd, node_cls)
    return n


_REQ_FIELDS = ("state", "slot", "matched_tokens", "cow_pending", "seq",
               "swap_pages", "n_preemptions", "error", "n_retries",
               "arrival_t", "t_admitted", "t_finished",
               "priority", "deadline_s", "max_new_tokens")


@dataclasses.dataclass
class EngineSnapshot:
    """One ``capture()`` result.  Holds live request-object references
    (restore mutates them in place) plus host copies of everything else;
    ``nbytes`` is the pool payload size for accounting."""
    pool: Any                               # host pytree (numpy leaves)
    blocks: Dict[str, Any]
    sched: Dict[str, Any]
    requests: List[Tuple[Any, Dict[str, Any]]]   # (req, saved fields)
    swap: Dict[str, Any]
    prefix: Optional[Dict[str, Any]]
    engine: Dict[str, Any]
    nbytes: int


def _tracked_requests(engine) -> List[Any]:
    s = engine.scheduler
    reqs = list(s.waiting) + list(s.running.values()) \
        + list(s.finished) + list(s.failed)
    seen, out = set(), []
    for r in reqs:
        if id(r) not in seen:
            seen.add(id(r))
            out.append(r)
    return out


def capture(engine) -> EngineSnapshot:
    """Checkpoint ``engine`` (a ContinuousBatchingEngine) to host memory."""
    blocks = engine.blocks
    s = engine.scheduler
    pool = jax.tree_util.tree_map(np.asarray, engine.pool)
    nbytes = int(sum(v.nbytes for v in jax.tree_util.tree_leaves(pool)))
    req_state = []
    for r in _tracked_requests(engine):
        fields = {k: getattr(r, k) for k in _REQ_FIELDS}
        fields["out"] = list(r.out)
        fields["t_tokens"] = list(r.t_tokens)
        req_state.append((r, fields))
    snap = EngineSnapshot(
        pool=pool,
        blocks={
            "version": blocks.version,
            "free": list(blocks._free),
            "tables": blocks.tables.copy(),
            "owned": [list(o) for o in blocks._owned],
            "shared": [list(sh) for sh in blocks._shared],
            "table_refs": blocks._table_refs.copy(),
            "pins": blocks._pins.copy(),
        },
        sched={
            "waiting": list(s.waiting),
            "running": dict(s.running),
            "n_finished": len(s.finished),
            "n_failed": len(s.failed),
            "free_slots": list(s._free_slots),
            "seq": s._seq,
            "n_preemptions": s.n_preemptions,
            "n_restores": s.n_restores,
        },
        requests=req_state,
        swap={
            "entries": dict(engine.swap_store._entries),
            "bytes_out": engine.swap_store.bytes_out,
            "bytes_in": engine.swap_store.bytes_in,
            "peak_resident_bytes": engine.swap_store.peak_resident_bytes,
        },
        prefix=None if engine.prefix is None else {
            "trie": _dump_trie(engine.prefix._root),
            "n_nodes": engine.prefix._n_nodes,
            "tick": engine.prefix._tick,
            "lookups": engine.prefix.lookups,
            "hits": engine.prefix.hits,
            "tokens_matched": engine.prefix.tokens_matched,
        },
        engine={
            "cur_tok": engine._cur_tok.copy(),
            "lengths": engine._lengths.copy(),
            "remaining": engine._remaining.copy(),
            "slot_keys": np.asarray(engine._slot_keys),
            "key": np.asarray(engine._key),
            "next_rid": engine._next_rid,
            "counters": {k: getattr(engine, k) for k in (
                "n_steps", "n_syncs", "n_generated",
                "prefill_tokens_computed", "n_cow_forks",
                "peak_mapped_pages", "peak_shared_pages",
                "n_preemptions", "n_restores", "n_quarantined",
                "_metrics_start")},
            "phase": dict(engine.phase),
        },
        nbytes=nbytes,
    )
    return snap


def restore(engine, snap: EngineSnapshot) -> None:
    """Write ``snap`` back into ``engine``'s live objects and re-upload
    the pool.  Counters that must stay monotone (``_next_rid``,
    ``scheduler._seq``) keep the larger of current/snapshot values."""
    blocks = engine.blocks
    s = engine.scheduler
    # ---- per-request mutable state (in place: streams hold these) -----
    for r, fields in snap.requests:
        for k in _REQ_FIELDS:
            setattr(r, k, fields[k])
        r.out = list(fields["out"])
        r.t_tokens = list(fields["t_tokens"])
    # ---- block manager ------------------------------------------------
    blocks._free = list(snap.blocks["free"])
    blocks.tables[...] = snap.blocks["tables"]
    blocks._owned = [list(o) for o in snap.blocks["owned"]]
    blocks._shared = [list(sh) for sh in snap.blocks["shared"]]
    blocks._table_refs[...] = snap.blocks["table_refs"]
    blocks._pins[...] = snap.blocks["pins"]
    # bump (never rewind) the version so the engine re-uploads its device
    # block table on the next step
    blocks.version = max(blocks.version, snap.blocks["version"]) + 1
    # ---- scheduler ----------------------------------------------------
    s.waiting = list(snap.sched["waiting"])
    s.running = dict(snap.sched["running"])
    del s.finished[snap.sched["n_finished"]:]
    del s.failed[snap.sched["n_failed"]:]
    s._free_slots = list(snap.sched["free_slots"])
    s._seq = max(s._seq, snap.sched["seq"])
    s.n_preemptions = snap.sched["n_preemptions"]
    s.n_restores = snap.sched["n_restores"]
    # ---- swap store ---------------------------------------------------
    engine.swap_store._entries = dict(snap.swap["entries"])
    engine.swap_store.bytes_out = snap.swap["bytes_out"]
    engine.swap_store.bytes_in = snap.swap["bytes_in"]
    engine.swap_store.peak_resident_bytes = \
        snap.swap["peak_resident_bytes"]
    # ---- prefix trie --------------------------------------------------
    if engine.prefix is not None and snap.prefix is not None:
        p = engine.prefix
        root_cls = type(p._root)
        p._root = root_cls((), -1, None)
        p._n_nodes = _load_trie(p._root, snap.prefix["trie"], root_cls)
        assert p._n_nodes == snap.prefix["n_nodes"], \
            "trie dump/rebuild node count mismatch"
        p._tick = snap.prefix["tick"]
        p.lookups = snap.prefix["lookups"]
        p.hits = snap.prefix["hits"]
        p.tokens_matched = snap.prefix["tokens_matched"]
    # ---- engine mirrors + pool ---------------------------------------
    engine._cur_tok[...] = snap.engine["cur_tok"]
    engine._lengths[...] = snap.engine["lengths"]
    engine._remaining[...] = snap.engine["remaining"]
    engine._slot_keys = jnp.asarray(snap.engine["slot_keys"])
    engine._key = jnp.asarray(snap.engine["key"])
    engine._next_rid = max(engine._next_rid, snap.engine["next_rid"])
    for k, v in snap.engine["counters"].items():
        setattr(engine, k, v)
    engine.phase = dict(snap.engine["phase"])
    engine.pool = jax.tree_util.tree_map(jnp.asarray, snap.pool)
    engine._bt_version = -1         # force the device-table re-upload
    engine.quarantined_in_step = []
    engine.stall_aborted = False
