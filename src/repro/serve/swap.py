"""Host-memory swap store for preempt-and-swap serving.

When the SLO scheduler preempts a running request (``Scheduler.pick_victim``
+ ``ContinuousBatchingEngine._swap_out``), the victim slot's KV pages are
copied from the device page pool into host memory and the slot is freed for
a higher-priority admission.  Because the pool stores *MX codes* (bit-packed
sub-byte elements + E8M0 scales) rather than dequantized floats, the swap
traffic is already compressed — an E2M1-value page moves at ~4.25 bits per
element, the same ratio the OCP MX paper credits for weight/KV residency.

On re-admission the request is restored page-for-page into freshly
allocated private pages (``scatter_pages``); together with the saved
per-slot PRNG key this makes the continuation *token-identical* to an
unpreempted run (asserted across formats/modes/policy tables in
``tests/test_serve_preempt.py``).

The page-pool pytree layout is the same one ``models.decoder.copy_pool_pages``
handles: leaves are ``(P, page, n_kv, X)`` per-layer pools or layer-stacked
``(n_scan, P, page, n_kv, X)`` — the page dimension is axis 0 or 1 by rank,
and the bytes move verbatim whatever each layer's spec, so one code path
covers fp pools, uniform MX policies, and per-layer ``PolicyTable`` mixes.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import numpy as np

from repro.obs.metrics import MetricsRegistry


def _page_axis(leaf) -> int:
    """Page axis of a pool leaf: layer-stacked leaves carry it at 1."""
    return 1 if leaf.ndim == 5 else 0


def gather_pages(pool, page_ids: Sequence[int]) -> Tuple[Any, int]:
    """Copy ``page_ids``'s contents out of every pool leaf to host numpy
    arrays.  Returns ``(host pytree, total bytes)``; the pytree mirrors
    ``pool`` with the page dimension shrunk to ``len(page_ids)``."""
    ids = np.asarray(page_ids, np.int32)

    def leaf(x):
        return np.asarray(x[:, ids] if _page_axis(x) == 1 else x[ids])

    host = jax.tree_util.tree_map(leaf, pool)
    nbytes = int(sum(v.nbytes
                     for v in jax.tree_util.tree_leaves(host)))
    return host, nbytes


def scatter_pages(pool, page_ids, host):
    """Write a ``gather_pages`` snapshot back into ``pool`` at (fresh)
    physical ``page_ids`` — the restore half of preempt-and-swap.  Pure
    function of jax arrays; the engine jits it with the pool donated so
    the restore never double-buffers the dominant serving allocation."""
    def leaf(x, v):
        return x.at[:, page_ids].set(v) if _page_axis(x) == 1 \
            else x.at[page_ids].set(v)

    return jax.tree_util.tree_map(leaf, pool, host)


def concat_snapshots(snapshots: Sequence[Any]):
    """Concatenate several ``gather_pages`` pytrees along the page axis so
    a batch of restores lands in one device scatter."""
    if len(snapshots) == 1:
        return snapshots[0]
    flat = [jax.tree_util.tree_flatten(s) for s in snapshots]
    treedef = flat[0][1]
    leaves = [np.concatenate([f[0][i] for f in flat],
                             axis=_page_axis(flat[0][0][i]))
              for i in range(len(flat[0][0]))]
    return jax.tree_util.tree_unflatten(treedef, leaves)


@dataclasses.dataclass
class SwapData:
    """One preempted request's host-resident state: its KV page contents
    (already MX-packed), how many pages they cover, the per-slot PRNG key
    at the preemption boundary, and the cache length they held — enough,
    with the request's own token history, to continue bit-identically."""
    pages: Any                  # host pytree from gather_pages
    n_pages: int
    length: int                 # cache positions filled at swap-out
    key: np.ndarray             # (2,) uint32 per-slot PRNG key
    nbytes: int


class HostSwapStore:
    """Keyed host-memory store for :class:`SwapData` with byte/level
    accounting (``bench_serve`` schema v4 reports the swap traffic).

    ``reset_counters`` zeroes the traffic counters for a steady-state
    measurement window without touching resident entries — a request
    swapped out before the window must still restore correctly after it.

    The counters live in a :class:`~repro.obs.metrics.MetricsRegistry`
    (``swap.bytes_out`` / ``swap.bytes_in`` counters and the
    ``swap.peak_resident_bytes`` gauge); the ``bytes_out`` /
    ``bytes_in`` / ``peak_resident_bytes`` attributes are registry-backed
    views (writable — snapshot restore rewinds them).  A standalone
    store creates its own registry; the engine shares its registry in.
    """

    def __init__(self, metrics: Optional[MetricsRegistry] = None):
        self._entries: Dict[int, SwapData] = {}
        self.metrics = MetricsRegistry() if metrics is None else metrics
        self._c_out = self.metrics.counter(
            "swap.bytes_out", "device -> host swap-out traffic")
        self._c_in = self.metrics.counter(
            "swap.bytes_in", "host -> device restore traffic")
        self._g_peak = self.metrics.gauge(
            "swap.peak_resident_bytes", "peak host-resident swap bytes")
        self.faults = None          # serve.faults.FaultPlan (swap_corrupt)

    # registry-backed counter views (setters: snapshot restore rewinds)
    @property
    def bytes_out(self) -> int:
        return int(self._c_out.value())

    @bytes_out.setter
    def bytes_out(self, v: int) -> None:
        self._c_out.set(int(v))

    @property
    def bytes_in(self) -> int:
        return int(self._c_in.value())

    @bytes_in.setter
    def bytes_in(self, v: int) -> None:
        self._c_in.set(int(v))

    @property
    def peak_resident_bytes(self) -> int:
        return int(self._g_peak.value())

    @peak_resident_bytes.setter
    def peak_resident_bytes(self, v: int) -> None:
        self._g_peak.set(int(v))

    def __len__(self) -> int:
        return len(self._entries)

    def __contains__(self, rid: int) -> bool:
        return rid in self._entries

    @property
    def resident_bytes(self) -> int:
        return sum(d.nbytes for d in self._entries.values())

    def put(self, rid: int, data: SwapData) -> None:
        if rid in self._entries:
            raise ValueError(f"swap store: request {rid} already resident")
        if self.faults is not None and \
                self.faults.should_fire("swap_corrupt",
                                        rid=rid) is not None:
            # overwrite the host payload with poison markers in place:
            # the restore scatters them back and the next decode window's
            # health guard quarantines exactly this request
            from repro.serve.faults import corrupt_swap_payload
            corrupt_swap_payload(data.pages)
        self._entries[rid] = data
        self._c_out.inc(data.nbytes)
        self._g_peak.set_max(self.resident_bytes)

    def pop(self, rid: int) -> SwapData:
        if rid not in self._entries:
            raise KeyError(f"swap store: request {rid} is not resident")
        data = self._entries.pop(rid)
        self._c_in.inc(data.nbytes)
        return data

    def reset_counters(self) -> None:
        """Zero the traffic counters; the resident peak re-anchors to
        the *current* resident bytes (entries survive a measurement
        reset, so the peak can never report below what is still
        held).  ``engine.reset_metrics`` calls this after the registry
        reset for exactly that re-anchor."""
        self._c_out.set(0)
        self._c_in.set(0)
        self._g_peak.set(self.resident_bytes)
