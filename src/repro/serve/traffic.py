"""Arrival-process generators + trace replay for the async serving stack.

Three ways to produce a workload, all deterministic under a seed:

* :func:`poisson_times` — homogeneous Poisson process at ``rate``
  requests/s (exponential inter-arrival gaps).
* :func:`on_off_times` — bursty two-state (on/off) modulated Poisson:
  bursts of ``rate`` arrivals/s for ``on_s`` seconds separated by silent
  gaps of ``off_s`` seconds — the tail-latency stressor (a burst
  oversubscribes the slot pool; the idle gap lets it drain).
* :func:`load_trace` / :func:`save_trace` — replay a recorded JSONL trace
  (one ``{"t": ..., "prompt": [...], ...}`` object per line).

:func:`synthesize` assigns each arrival time a request drawn from a mix
of :class:`TrafficClass` profiles (prompt/generation length ranges, SLO
priority + TTFT deadline) — e.g. interactive-vs-batch — and
:func:`replay` submits a finished workload against an
:class:`~repro.serve.frontend.AsyncServer`, sleeping to honor arrival
times (or compressed by ``speedup``) and collecting every stream.
"""
from __future__ import annotations

import asyncio
import dataclasses
import json
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.serve.frontend import AsyncServer, RejectedError, RequestStream


@dataclasses.dataclass(frozen=True)
class TrafficClass:
    """One request population in a traffic mix: prompt/output length
    ranges (inclusive low, exclusive high) plus the SLO class its
    requests carry."""
    name: str
    prompt_len: Tuple[int, int]
    max_new_tokens: Tuple[int, int]
    priority: int = 0
    deadline_s: Optional[float] = None
    weight: float = 1.0


@dataclasses.dataclass(frozen=True)
class Arrival:
    """One request of a workload: absolute arrival time (seconds from
    trace start) plus the request payload and SLO class."""
    t: float
    prompt: np.ndarray
    max_new_tokens: int
    priority: int = 0
    deadline_s: Optional[float] = None
    cls: str = ""


# =============================================================================
# Arrival-time processes
# =============================================================================
def poisson_times(rate: float, n: int, seed: int = 0) -> List[float]:
    """``n`` arrival times of a homogeneous Poisson process at ``rate``
    requests/s (deterministic under ``seed``)."""
    if rate <= 0:
        raise ValueError(f"rate must be > 0, got {rate}")
    rng = np.random.default_rng(seed)
    return list(np.cumsum(rng.exponential(1.0 / rate, size=n)))

def on_off_times(rate: float, n: int, *, on_s: float, off_s: float,
                 seed: int = 0) -> List[float]:
    """``n`` arrival times of an on/off modulated Poisson process: the
    source emits at ``rate`` req/s while "on" for ``on_s`` seconds, then
    stays silent for ``off_s`` seconds, repeating.  Bursty traffic with
    this shape is what makes preempt-and-swap pay: a burst oversubscribes
    the pool and the off gap drains it."""
    if rate <= 0 or on_s <= 0 or off_s < 0:
        raise ValueError("rate/on_s must be > 0 and off_s >= 0")
    rng = np.random.default_rng(seed)
    times: List[float] = []
    period_start = 0.0
    t = 0.0
    while len(times) < n:
        t += float(rng.exponential(1.0 / rate))
        # lands past the current on-window: jump to the next burst
        while t > on_s:
            period_start += on_s + off_s
            t -= on_s
        times.append(period_start + t)
    return times


# =============================================================================
# Workload synthesis
# =============================================================================
def synthesize(times: Sequence[float], classes: Sequence[TrafficClass],
               vocab: int, seed: int = 0) -> List[Arrival]:
    """Assign each arrival time a request drawn from the ``classes`` mix
    (weighted choice; prompt tokens uniform over [1, vocab)).  The same
    (times, classes, vocab, seed) always yields the same workload."""
    if not classes:
        raise ValueError("need at least one TrafficClass")
    rng = np.random.default_rng(seed)
    w = np.asarray([c.weight for c in classes], np.float64)
    if (w <= 0).any():
        raise ValueError("class weights must be > 0")
    picks = rng.choice(len(classes), size=len(times), p=w / w.sum())
    out: List[Arrival] = []
    for t, k in zip(times, picks):
        c = classes[k]
        lp = int(rng.integers(c.prompt_len[0], c.prompt_len[1]))
        mnt = int(rng.integers(c.max_new_tokens[0], c.max_new_tokens[1]))
        prompt = rng.integers(1, vocab, size=lp).astype(np.int32)
        out.append(Arrival(t=float(t), prompt=prompt, max_new_tokens=mnt,
                           priority=c.priority, deadline_s=c.deadline_s,
                           cls=c.name))
    return out


# =============================================================================
# JSONL traces
# =============================================================================
def save_trace(path: str, arrivals: Sequence[Arrival]) -> None:
    """Write a workload as JSONL: one arrival object per line, sorted by
    time — a replayable, diffable artifact."""
    with open(path, "w") as f:
        for a in sorted(arrivals, key=lambda a: a.t):
            rec = {"t": a.t, "prompt": [int(x) for x in a.prompt],
                   "max_new_tokens": a.max_new_tokens,
                   "priority": a.priority}
            if a.deadline_s is not None:
                rec["deadline_s"] = a.deadline_s
            if a.cls:
                rec["cls"] = a.cls
            f.write(json.dumps(rec) + "\n")


def load_trace(path: str) -> List[Arrival]:
    """Load a JSONL trace written by :func:`save_trace` (or by hand:
    ``t``, ``prompt``, ``max_new_tokens`` required; ``priority``,
    ``deadline_s``, ``cls`` optional).

    Timestamps are *validated*, not repaired: a negative ``t`` or one
    earlier than the previous line raises ValueError naming the offending
    line — silently re-sorting a corrupt trace would hide exactly the
    kind of recording fault a replay is supposed to reproduce."""
    out: List[Arrival] = []
    prev_t, prev_ln = None, 0
    with open(path) as f:
        for ln, line in enumerate(f, 1):
            line = line.strip()
            if not line:
                continue
            try:
                rec = json.loads(line)
                a = Arrival(
                    t=float(rec["t"]),
                    prompt=np.asarray(rec["prompt"], np.int32),
                    max_new_tokens=int(rec["max_new_tokens"]),
                    priority=int(rec.get("priority", 0)),
                    deadline_s=rec.get("deadline_s"),
                    cls=rec.get("cls", ""))
            except (KeyError, ValueError, json.JSONDecodeError) as e:
                raise ValueError(f"{path}:{ln}: bad trace record: {e}") \
                    from None
            if not np.isfinite(a.t) or a.t < 0:
                raise ValueError(
                    f"{path}:{ln}: arrival time must be finite and >= 0, "
                    f"got {a.t}")
            if prev_t is not None and a.t < prev_t:
                raise ValueError(
                    f"{path}:{ln}: non-monotonic arrival time {a.t} "
                    f"(line {prev_ln} had {prev_t}); traces must be "
                    f"time-sorted")
            prev_t, prev_ln = a.t, ln
            out.append(a)
    return out


# =============================================================================
# Replay
# =============================================================================
async def replay(server: AsyncServer, arrivals: Sequence[Arrival], *,
                 speedup: float = 1.0
                 ) -> Tuple[Dict[int, RequestStream], List[Arrival]]:
    """Submit a workload against ``server``, honoring arrival times
    (divided by ``speedup``; ``float("inf")`` submits as fast as the
    loop allows), then drain every accepted stream to completion.

    Returns ``(streams by index into arrivals, rejected arrivals)`` —
    under ``admission="reject"`` the dropped requests are the baseline's
    cost; under ``"block"`` the rejected list is always empty.
    """
    if speedup <= 0:
        raise ValueError(f"speedup must be > 0, got {speedup}")
    arrivals = sorted(arrivals, key=lambda a: a.t)
    loop = asyncio.get_event_loop()
    start = loop.time()
    streams: Dict[int, RequestStream] = {}
    rejected: List[Arrival] = []
    consumers = []
    for i, a in enumerate(arrivals):
        due = a.t / speedup
        delay = start + due - loop.time()
        if delay > 0:
            await asyncio.sleep(delay)
        try:
            stream = await server.submit(
                a.prompt, a.max_new_tokens, priority=a.priority,
                deadline_s=a.deadline_s)
        except RejectedError:
            rejected.append(a)
            continue
        streams[i] = stream
        consumers.append(asyncio.ensure_future(stream.tokens()))
    if consumers:
        # tolerate terminally failed streams (QuarantinedError /
        # RetriesExhausted under a fault plan): the failures stay
        # recorded on the engine's scheduler, the healthy streams drain
        await asyncio.gather(*consumers, return_exceptions=True)
    return streams, rejected
