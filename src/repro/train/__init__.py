from repro.train.step import (  # noqa: F401
    build_train_step, build_train_step_compressed_dp, cross_entropy,
    init_train_state,
)
from repro.train.loop import LoopConfig, train_loop  # noqa: F401
