"""Fault-tolerant training loop: auto-resume, atomic checkpoints, watchdog.

Failure model for the 1000+-node posture (documented here, exercised in the
single-process container via tests/test_train_integration.py):
  * node crash       -> the launcher (launch/train.py) reruns the job; this
                        loop auto-resumes from the latest atomic checkpoint
                        with a bit-identical data cursor (step number).
  * straggler        -> per-step wall-clock watchdog; if step_time exceeds
                        ``straggler_factor`` x the running median, the event
                        is logged (on real fleets: report to the controller,
                        which can evict the slow host and elastically resume
                        on a smaller "data" axis — the checkpoint is
                        mesh-elastic, see repro/ckpt).
  * preemption       -> checkpoint every ``ckpt_every`` steps bounds lost
                        work; save is atomic (tmp+rename).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict

import jax
import numpy as np

from repro.ckpt.checkpoint import gc_old, latest_step, restore, save_atomic


@dataclasses.dataclass
class LoopConfig:
    total_steps: int
    ckpt_dir: str
    ckpt_every: int = 100
    keep: int = 3
    log_every: int = 10
    straggler_factor: float = 3.0


def train_loop(cfg: LoopConfig, train_step: Callable, params, opt_state,
               batch_fn: Callable[[int], Dict[str, Any]],
               shardings=None, log: Callable[[str], None] = print
               ) -> Dict[str, Any]:
    """Runs to total_steps with auto-resume; returns final state + history."""
    start = 0
    last = latest_step(cfg.ckpt_dir)
    if last is not None:
        state = {"params": params, "opt": opt_state}
        state, meta = restore(cfg.ckpt_dir, last, state, shardings)
        params, opt_state = state["params"], state["opt"]
        start = int(meta.get("next_step", last))
        log(f"[loop] resumed from step_{last:08d} -> next_step={start}")
    history = []
    step_times = []
    for step in range(start, cfg.total_steps):
        batch = batch_fn(step)
        t0 = time.perf_counter()
        params, opt_state, metrics = train_step(
            params, opt_state, batch, jax.numpy.asarray(step))
        loss = float(metrics["loss"])
        dt = time.perf_counter() - t0
        step_times.append(dt)
        med = float(np.median(step_times[-50:]))
        if len(step_times) > 5 and dt > cfg.straggler_factor * med:
            log(f"[watchdog] step {step}: {dt:.2f}s > "
                f"{cfg.straggler_factor:.1f}x median {med:.2f}s — straggler "
                f"event (would report to controller)")
        history.append({"step": step, "loss": loss, "time_s": dt})
        if step % cfg.log_every == 0:
            log(f"[train] step={step} loss={loss:.4f} "
                f"gnorm={float(metrics.get('grad_norm', 0)):.3f} "
                f"({dt*1e3:.0f} ms)")
        if cfg.ckpt_every and (step + 1) % cfg.ckpt_every == 0:
            save_atomic(cfg.ckpt_dir, step + 1,
                        {"params": params, "opt": opt_state},
                        metadata={"next_step": step + 1})
            gc_old(cfg.ckpt_dir, cfg.keep)
    return {"params": params, "opt": opt_state, "history": history}
