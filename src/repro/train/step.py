"""Train-step builders.

``build_train_step``      — GSPMD path: grads/optimizer collectives inserted
                            by the compiler from the param shardings
                            (FSDP x TP); microbatch accumulation via scan.
``build_train_step_compressed_dp`` — explicit-DP path: shard_map over the
                            data-parallel axes ("pod","data") with the model
                            axis left automatic; the gradient all-reduce is
                            the MX-compressed exchange from
                            repro.core.grad_compress (ZeRO-1 posture:
                            params replicated over DP, optimizer sharded by
                            the launcher).
"""
from __future__ import annotations

from typing import Any, Callable, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core.grad_compress import mx_allreduce_tree
from repro.dist import compat
from repro.models.registry import Model
from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update


def cross_entropy(logits: jax.Array, labels: jax.Array,
                  vocab: int) -> jax.Array:
    """Mean CE over valid positions (labels in [0, vocab); -1 = masked).
    Computed in f32; padded-vocab columns are never valid labels."""
    logits = logits.astype(jnp.float32)
    valid = (labels >= 0) & (labels < vocab)
    labs = jnp.clip(labels, 0, vocab - 1)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labs[..., None], axis=-1)[..., 0]
    ce = (logz - gold) * valid
    return jnp.sum(ce) / jnp.maximum(jnp.sum(valid), 1)


def _loss_fn(model: Model, params, batch, *, fake_quant: bool,
             aux_weight: float = 0.01):
    logits, aux = model.forward(params, batch, fake_quant=fake_quant)
    labels = batch["labels"]
    # align: forward emits one logit per input position; labels are
    # already next-token-shifted by the pipeline
    s = min(logits.shape[1], labels.shape[1])
    ce = cross_entropy(logits[:, :s], labels[:, :s], model.cfg.vocab)
    return ce + aux_weight * aux, {"ce": ce, "aux": aux}


def build_train_step(model: Model, opt_cfg: AdamWConfig, *,
                     microbatches: int = 1, fake_quant: bool = False,
                     donate: bool = True) -> Callable:
    """Returns train_step(params, opt_state, batch, step) ->
    (params, opt_state, metrics).  Not jitted — the launcher jits with
    shardings."""
    cfg = model.cfg
    param_dtype = jnp.dtype(cfg.param_dtype)

    def grads_of(params, batch):
        return jax.value_and_grad(
            lambda p: _loss_fn(model, p, batch, fake_quant=fake_quant),
            has_aux=True)(params)

    def train_step(params, opt_state, batch, step):
        if microbatches == 1:
            (loss, met), grads = grads_of(params, batch)
        else:
            def split(x):
                b = x.shape[0]
                return x.reshape((microbatches, b // microbatches)
                                 + x.shape[1:])
            mb = jax.tree_util.tree_map(split, batch)

            def acc_step(carry, mbatch):
                g_acc, l_acc = carry
                (loss, met), g = grads_of(params, mbatch)
                g_acc = jax.tree_util.tree_map(
                    lambda a, b2: a + b2.astype(jnp.float32), g_acc, g)
                return (g_acc, l_acc + loss), met

            g0 = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss), mets = jax.lax.scan(acc_step, (g0, 0.0), mb)
            grads = jax.tree_util.tree_map(lambda g: g / microbatches,
                                           grads)
            loss = loss / microbatches
            met = jax.tree_util.tree_map(lambda m: m[-1], mets)
        new_params, new_opt, omet = adamw_update(
            opt_cfg, grads, opt_state, step, param_dtype)
        metrics = {"loss": loss, **met, **omet}
        return new_params, new_opt, metrics

    return train_step


def build_train_step_compressed_dp(model: Model, opt_cfg: AdamWConfig, *,
                                   mesh, dp_axes: Sequence[str],
                                   spec=None, fmt: Optional[str] = None,
                                   mode: Optional[str] = None,
                                   fake_quant: bool = False) -> Callable:
    """Explicit-DP train step: per-shard grads + MX-compressed all-reduce.

    Parameters are replicated over the DP axes (ZeRO-1); any "model" axis
    stays automatic (GSPMD handles TP inside the shard_map body).

    The gradient-exchange ``spec`` defaults to the model policy's
    ``grads`` role (else e4m3/ocp); the ``fmt=``/``mode=`` kwargs are the
    deprecation shim.
    """
    from jax.sharding import PartitionSpec as P

    from repro.core.spec import QuantSpec, resolve_spec

    cfg = model.cfg
    if spec is None and fmt is None and mode is None:
        spec = cfg.mx.grads or QuantSpec("e4m3", "ocp")
    else:
        spec = resolve_spec(spec, fmt, mode, None,
                            default=QuantSpec("e4m3", "ocp"),
                            caller="build_train_step_compressed_dp")
    param_dtype = jnp.dtype(cfg.param_dtype)
    dp = tuple(dp_axes)

    batch_spec = P(dp)      # batch dim sharded over DP axes
    rep = P()

    def body(params, opt_state, batch, step):
        (loss, met), grads = jax.value_and_grad(
            lambda p: _loss_fn(model, p, batch, fake_quant=fake_quant),
            has_aux=True)(params)
        grads = mx_allreduce_tree(grads, dp, spec)
        loss = jax.lax.pmean(loss, dp)
        new_params, new_opt, omet = adamw_update(
            opt_cfg, grads, opt_state, step, param_dtype)
        return new_params, new_opt, {"loss": loss, **met, **omet}

    def specs_like(tree, spec):
        return jax.tree_util.tree_map(lambda _: spec, tree)

    def train_step(params, opt_state, batch, step):
        in_specs = (specs_like(params, rep), specs_like(opt_state, rep),
                    specs_like(batch, batch_spec), rep)
        out_specs = (specs_like(params, rep), specs_like(opt_state, rep),
                     {"loss": rep, "ce": rep, "aux": rep, "grad_norm": rep,
                      "lr": rep})
        # manual over the DP axes only; any "model" axis stays automatic
        # (on jax 0.4.x compat.shard_map makes it manual-replicated
        # instead — partial-auto there crashes the SPMD partitioner)
        fn = compat.shard_map(body, mesh=mesh, in_specs=in_specs,
                              out_specs=out_specs, check_vma=False,
                              axis_names=set(dp))
        return fn(params, opt_state, batch, step)

    return train_step


def init_train_state(model: Model, key) -> Tuple[Any, Any]:
    params = model.init(key)
    return params, adamw_init(params)
