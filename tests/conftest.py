"""Shared pytest configuration for the repro test-suite."""


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "slow: multi-minute subprocess compile tests (deselect with "
        "-m 'not slow')")
    config.addinivalue_line(
        "markers",
        "property: hypothesis state-machine suites (CI re-runs them with "
        "a fixed seed and a higher example count)")
