"""Per-architecture smoke tests: reduced same-family configs, one forward
(+ one train-style grad step elsewhere), asserting shapes and finiteness."""

import jax
import numpy as np
import pytest

from repro.models import (ARCH_IDS, Model, load_reduced,
                          make_concrete_batch)
from repro.models.config import MXPolicy
from repro.models.decoder import padded_vocab

B, S = 2, 32


def _fwd(arch, **over):
    cfg = load_reduced(arch, **over)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = make_concrete_batch(cfg, B, S)
    logits, aux = model.forward(params, batch)
    return cfg, model, params, batch, logits, aux


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_finite(arch):
    cfg, model, params, batch, logits, aux = _fwd(arch)
    vp = padded_vocab(cfg)
    b = batch["tokens"].shape[0]
    if cfg.family == "encdec":
        s_out = batch["tokens"].shape[1]
    elif cfg.frontend == "patch":
        s_out = batch["tokens"].shape[1] + cfg.prefix_len
    else:
        s_out = batch["tokens"].shape[1]
    assert logits.shape == (b, s_out, vp), arch
    assert np.isfinite(np.asarray(logits, np.float32)).all(), arch
    assert np.isfinite(float(aux)), arch


@pytest.mark.parametrize("arch", ["chatglm3_6b", "deepseek_v2_236b",
                                  "zamba2_1p2b", "rwkv6_7b"])
def test_forward_with_mx_fake_quant(arch):
    """MX weight fake-quantization (the paper's converter in the loop)
    perturbs but does not destroy the forward pass."""
    mx = MXPolicy(fmt="e4m3", mode="paper", weights=True)
    cfg, model, params, batch, logits, aux = _fwd(arch, mx=mx)
    lq, _ = model.forward(params, batch, fake_quant=True)
    base = np.asarray(logits, np.float32)
    quant = np.asarray(lq, np.float32)
    assert np.isfinite(quant).all(), arch
    # quantized forward differs but correlates strongly; recurrent archs
    # (SSM/RWKV) accumulate quantization error through the state scan, so
    # the bar is lower there (paper-mode E4M3 = FTZ + bias-7 scale)
    cc = np.corrcoef(base.ravel(), quant.ravel())[0, 1]
    cfg2 = load_reduced(arch)
    thresh = 0.8 if cfg2.family in ("hybrid", "rwkv") else 0.98
    assert cc > thresh, (arch, cc)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_decode_consistency(arch):
    """decode_step(t) after prefill([:-1]) must match the full forward's
    last-position logits.  MoE capacity dropping is shape-dependent (a token
    can be dropped in the full batch but not in its own decode step), so the
    consistency check uses a no-drop capacity factor."""
    cfg = load_reduced(arch, capacity_factor=64.0)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    batch = make_concrete_batch(cfg, B, S)
    logits_full, _ = model.forward(params, batch)
    toks = batch["tokens"]
    pre = dict(batch)
    pre["tokens"] = toks[:, :-1]
    max_len = toks.shape[1] + cfg.prefix_len   # prefix embeds live in cache
    logits_p, cache, pos = model.prefill(params, pre, max_len=max_len)
    logits_d, _ = model.decode_step(params, toks[:, -1], cache, pos)
    a = np.asarray(logits_full[:, -1], np.float32)
    d = np.asarray(logits_d[:, -1] if logits_d.ndim == 3 else logits_d,
                   np.float32)
    # bf16 compute: compare top-1 agreement and correlation
    cc = np.corrcoef(a.ravel(), d.ravel())[0, 1]
    assert cc > 0.99, (arch, cc)
    assert (np.argmax(a, -1) == np.argmax(d, -1)).mean() >= 0.5, arch


def test_param_count_analytic_close():
    """Analytic 6ND param count tracks the real pytree within 10%."""
    for arch in ("chatglm3_6b", "yi_34b", "rwkv6_7b"):
        cfg = load_reduced(arch)
        model = Model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        real = sum(int(np.prod(p.shape))
                   for p in jax.tree_util.tree_leaves(params))
        # analytic formula uses the unpadded vocab; allow padding slack
        est = cfg.param_count()
        assert 0.5 < est / real < 1.6, (arch, est, real)
