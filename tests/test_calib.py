"""repro.calib unit tests: streaming stats, candidate sweep, and the
budget-constrained policy search."""
import jax
import numpy as np
import pytest

from repro.calib import (collect_model_stats, parse_auto_budget,
                         score_sample, search_kv_policy,
                         search_weights_policy, sweep_role,
                         weight_param_nbytes)
from repro.calib.stats import TensorStats, tensor_reduction, _to_stats
from repro.core import QuantPolicy, QuantSpec
from repro.models import Model, load_reduced
from repro.serve.paging import (kv_cache_token_nbytes, kv_token_nbytes,
                                spec_side_nbytes)

N_LAYERS = 3


@pytest.fixture(scope="module")
def calib_setup():
    cfg = load_reduced("chatglm3_6b", n_layers=N_LAYERS)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    batches = [rng.integers(0, cfg.vocab, size=(2, 32)).astype(np.int32)
               for _ in range(2)]
    stats = collect_model_stats(
        model, params, batches,
        roles=("kv_key", "kv_value", "activations", "weights", "grads"))
    return cfg, model, params, stats


# =============================================================================
# TensorStats streaming semantics
# =============================================================================
def test_streaming_merge_equals_one_shot():
    rng = np.random.default_rng(1)
    a = rng.normal(size=(64, 32)).astype(np.float32)
    b = rng.normal(size=(48, 32)).astype(np.float32) * 3.0
    one = _to_stats(jax.device_get(
        tensor_reduction(np.concatenate([a, b]), sample_rows=1 << 20)))
    strm = TensorStats()
    strm.merge(_to_stats(jax.device_get(
        tensor_reduction(a, sample_rows=1 << 20))), sample_rows=1 << 20)
    strm.merge(_to_stats(jax.device_get(
        tensor_reduction(b, sample_rows=1 << 20))), sample_rows=1 << 20)
    assert strm.count == one.count == a.size + b.size
    np.testing.assert_allclose(strm.absmax, one.absmax)
    np.testing.assert_allclose(strm.total, one.total, rtol=1e-5)
    np.testing.assert_allclose(strm.sumsq, one.sumsq, rtol=1e-5)
    np.testing.assert_array_equal(strm.exp_hist, one.exp_hist)
    np.testing.assert_array_equal(strm.sample, one.sample)


def test_reduction_counts_zeros_and_exponents():
    x = np.array([[0.0, 1.0, 2.0, -2.0]], np.float32)
    ts = _to_stats(jax.device_get(tensor_reduction(x, block=4)))
    assert ts.count == 4 and ts.n_zero == 1
    assert ts.absmax == 2.0
    assert ts.exp_hist[127] == 1           # 1.0
    assert ts.exp_hist[128] == 2           # +/-2.0
    assert ts.exp_hist.sum() == 3          # zeros excluded
    assert ts.exp_percentile(1.0) == 128


def test_sample_rows_capped():
    x = np.ones((100, 32), np.float32)
    ts = _to_stats(jax.device_get(tensor_reduction(x, sample_rows=8)))
    assert ts.sample.shape == (8, 32)
    assert ts.count == 100 * 32            # moments still see everything


# =============================================================================
# collection over the model
# =============================================================================
def test_collect_covers_all_roles_and_layers(calib_setup):
    cfg, _, _, stats = calib_setup
    assert stats.n_layers == N_LAYERS
    for role in ("kv_key", "kv_value", "activations", "weights", "grads"):
        layers = stats.role_layers(role)
        assert sorted(layers) == list(range(N_LAYERS)), role
        for ts in layers.values():
            assert ts.count > 0 and ts.sample is not None
            assert ts.sample.shape[1] == 32          # block rows
            assert np.isfinite(ts.rms) and ts.absmax > 0


def test_collect_unknown_role_rejected(calib_setup):
    cfg, model, params, _ = calib_setup
    with pytest.raises(ValueError, match="unknown tensor role"):
        collect_model_stats(model, params, [], roles=("bogus",))
    weights_only = collect_model_stats(model, params, [],
                                       roles=("weights",))
    with pytest.raises(KeyError, match="not collected"):
        weights_only.role_layers("kv_key")


# =============================================================================
# sweep
# =============================================================================
def test_sweep_orders_by_quality_and_prices_by_spec(calib_setup):
    cfg, _, _, stats = calib_setup
    cost = lambda s: float(spec_side_nbytes(s, cfg.n_kv_heads, cfg.hd))
    sw = sweep_role(stats, "kv_key", cost)
    for layer, scored in sw.items():
        sq = [s.sqnr_db for s in scored]
        assert sq == sorted(sq, reverse=True)
        by_fmt = {s.spec.fmt: s for s in scored}
        # on gaussian-ish data INT8 beats E4M3 at the same byte cost,
        # and both beat the 4-bit format
        assert by_fmt["int8"].sqnr_db > by_fmt["e4m3"].sqnr_db
        assert by_fmt["e4m3"].sqnr_db > by_fmt["e2m1"].sqnr_db
        assert by_fmt["int8"].nbytes == by_fmt["e4m3"].nbytes
        assert by_fmt["e2m1"].nbytes < by_fmt["int8"].nbytes


def test_score_sample_exact_signal():
    x = np.tile([1.0, 0.5, 2.0, 4.0], 8).astype(np.float32)[None, :]
    q = score_sample(x, QuantSpec("e4m3", "ocp", 32))
    assert q["sqnr_db"] > 100 and q["max_rel_err"] == 0.0


# =============================================================================
# budget-constrained search
# =============================================================================
def test_search_respects_budget_and_improves_with_bytes(calib_setup):
    cfg, _, _, stats = calib_setup
    full = kv_token_nbytes(QuantPolicy.parse("kv=int8@32:ocp"),
                           cfg.n_kv_heads, cfg.hd) * N_LAYERS
    rich = search_kv_policy(stats, full, cfg)
    tight = search_kv_policy(stats, full * 0.7, cfg)
    assert rich.total_nbytes <= full
    assert tight.total_nbytes <= full * 0.7
    assert rich.mean_sqnr_db >= tight.mean_sqnr_db
    # generous budget -> the best (8-bit) spec everywhere
    assert all(s.spec.fmt == "int8" for s in rich.chosen.values())


def test_search_applied_cost_matches_accounting(calib_setup):
    """The table the search emits really allocates what it charged for:
    apply it and re-derive bytes/token from the config."""
    from repro.models import apply_policy_table
    cfg, _, _, stats = calib_setup
    budget = 0.7 * kv_token_nbytes(QuantPolicy.parse("kv=int8@32:ocp"),
                                   cfg.n_kv_heads, cfg.hd) * N_LAYERS
    res = search_kv_policy(stats, budget, cfg)
    cfg2 = apply_policy_table(cfg, res.table)
    assert kv_cache_token_nbytes(cfg2) == int(res.total_nbytes)
    assert kv_cache_token_nbytes(cfg2) <= budget


def test_search_infeasible_budget_raises(calib_setup):
    cfg, _, _, stats = calib_setup
    with pytest.raises(ValueError, match="infeasible"):
        search_kv_policy(stats, 1.0, cfg)


def test_search_weights_budget(calib_setup):
    """The weights budget is parameter-weighted: total bytes over total
    params never exceeds the advertised bytes-per-param ceiling."""
    cfg, _, _, stats = calib_setup
    res = search_weights_policy(stats, 0.75, cfg)
    assert res.total_params > 0
    assert res.total_nbytes / res.total_params <= 0.75
    for (role, layer), s in res.chosen.items():
        assert role == "weights"
        # each slot is charged bytes/param x that layer's param count
        np.testing.assert_allclose(
            s.nbytes, weight_param_nbytes(s.spec)
            * stats.role_layers("weights")[layer].count)
        # int8 (1.031 B/param) alone cannot fit a 0.75 B/param average
        assert s.spec.fmt != "int8" \
            or res.total_nbytes < 1.031 * res.total_params


# =============================================================================
# budget grammar
# =============================================================================
def test_parse_auto_budget():
    assert parse_auto_budget("auto:96") == 96.0
    assert parse_auto_budget("auto:1.5") == 1.5
    for bad in ("auto", "auto:", "auto:x", "auto:-3", "auto:0"):
        with pytest.raises(ValueError):
            parse_auto_budget(bad)
    # only the literal 'auto[:...]' form is auto — not any 'auto*' prefix
    for not_auto in ("kv=int8", "autos:12", "automatic:5"):
        with pytest.raises(ValueError, match="not an auto"):
            parse_auto_budget(not_auto)
