"""Multi-device tests (8 host CPU devices in a subprocess so the main test
process keeps seeing 1 device): MX-compressed gradient collectives +
sharded train step + elastic checkpoint restore."""
import os
import subprocess
import sys
import textwrap


ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_devprog(body: str, ndev: int = 8) -> str:
    prog = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = (
            "--xla_force_host_platform_device_count={ndev}")
    """) + textwrap.dedent(body)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run([sys.executable, "-c", prog], capture_output=True,
                         text=True, env=env, timeout=560)
    assert out.returncode == 0, f"STDOUT:{out.stdout}\nSTDERR:{out.stderr}"
    return out.stdout


def test_row_parallel_mx_gather_divisibility():
    """Satellite regression: row-parallel ("model" on K) FSDP gather of an
    MX weight must refuse K//block scale rows that don't divide the model
    axis (codes would shard while scales silently replicate), and still
    serve cleanly when they do divide."""
    run_devprog("""
        import jax, numpy as np
        import jax.numpy as jnp
        from repro.core import MXWeight, QuantSpec
        from repro.core.convert import mx_quantize
        from repro.dist import compat
        from repro.dist.sharding import make_rules, use_rules
        from repro.models.layers import dense

        mesh = jax.make_mesh((2, 2), ("data", "model"))
        rules = make_rules(("data", "model"), fsdp_params=True)
        spec = QuantSpec("e4m3", "ocp", 32, True)
        rng = np.random.default_rng(0)
        fn = jax.jit(lambda x, w: dense(x, w, tp="row"))

        with compat.set_mesh(mesh), use_rules(rules):
            # K=32 -> K//block=1 scale row, model axis 2: codes' K divides,
            # scales' K//block does not -> loud error naming the sizes
            w_bad = rng.normal(size=(32, 16)).astype(np.float32)
            x_bad = jnp.asarray(rng.normal(size=(2, 32)), jnp.float32)
            for bad in (mx_quantize(jnp.asarray(w_bad), spec, axis=0),
                        MXWeight.quantize(jnp.asarray(w_bad), spec)):
                try:
                    fn(x_bad, bad)
                    raise SystemExit("expected ValueError for K//block=1")
                except ValueError as e:
                    assert "K//block=1" in str(e) and "size 2" in str(e), e
            # K=128 -> K//block=4 divides the model axis: both container
            # types serve, matching the unsharded dequant matmul
            w_ok = rng.normal(size=(128, 16)).astype(np.float32) * 0.05
            x_ok = jnp.asarray(rng.normal(size=(2, 128)), jnp.float32)
            mxa = mx_quantize(jnp.asarray(w_ok), spec, axis=0)
            mxw = MXWeight.quantize(jnp.asarray(w_ok), spec)
            ya = np.asarray(fn(x_ok, mxa))
            yw = np.asarray(fn(x_ok, mxw))
            ref = np.asarray(x_ok) @ np.asarray(mxw.dequantize())
            np.testing.assert_allclose(ya, ref, rtol=1e-5, atol=1e-5)
            np.testing.assert_allclose(yw, ref, rtol=1e-5, atol=1e-5)
        print("OK rowshard")
    """, ndev=4)


def test_mx_allreduce_matches_exact_mean():
    run_devprog("""
        import jax, numpy as np
        import jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        from jax.experimental.shard_map import shard_map
        from repro.core.grad_compress import mx_allreduce_mean

        mesh = jax.make_mesh((2, 4), ("pod", "data"))
        rng = np.random.default_rng(0)
        # per-device gradient shards: (8, n) -> each device holds one row
        n = 4096 + 17
        g = rng.normal(size=(8, n)).astype(np.float32)

        def body(gl):
            gl = gl[0]                      # local (n,)
            return mx_allreduce_mean(gl, ("pod", "data"),
                                     fmt="e4m3", mode="ocp")[None]

        fn = jax.jit(shard_map(body, mesh=mesh,
                               in_specs=P(("pod", "data")),
                               out_specs=P(("pod", "data"))))
        out = np.asarray(fn(jnp.asarray(g)))
        exact = g.mean(0)
        # every device must hold the same compressed mean
        for d in range(8):
            np.testing.assert_array_equal(out[d], out[0])
        # error bounded by the E4M3 block ulp relative to block max
        err = np.abs(out[0] - exact)
        blocks = exact[: n // 32 * 32].reshape(-1, 32)
        bmax = np.abs(blocks).max(1)
        tol = np.repeat(bmax, 32) * 2.0 ** -3 * 1.01 + 1e-7
        assert (err[: len(tol)] <= tol).all(), err.max()
        print("OK allreduce")
    """)


def test_compressed_dp_train_step_runs_and_learns():
    run_devprog("""
        import jax, numpy as np
        import jax.numpy as jnp
        from repro.data import DataConfig, SyntheticLM, make_batch_for
        from repro.models import Model, load_reduced
        from repro.optim import AdamWConfig
        from repro.train import (build_train_step_compressed_dp,
                                 init_train_state)

        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
        cfg = load_reduced("chatglm3_6b", remat=False)
        model = Model(cfg)
        params, opt_state = init_train_state(model, jax.random.PRNGKey(0))
        opt_cfg = AdamWConfig(lr=2e-3, warmup_steps=2, total_steps=20,
                              weight_decay=0.0)
        step = build_train_step_compressed_dp(
            model, opt_cfg, mesh=mesh, dp_axes=("pod", "data"))
        step = jax.jit(step)
        data = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=16,
                                      global_batch=8, seed=1))
        losses = []
        with jax.set_mesh(mesh):
            for i in range(12):
                batch = make_batch_for(cfg, data.batch(i))
                params, opt_state, m = step(params, opt_state, batch,
                                            jnp.asarray(i))
                losses.append(float(m["loss"]))
        assert losses[-1] < losses[0], losses
        assert np.isfinite(losses).all()
        print("OK compressed train", losses[0], losses[-1])
    """)


def test_elastic_checkpoint_restore_across_mesh_shapes():
    run_devprog("""
        import os, tempfile
        import jax, numpy as np
        import jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.ckpt import save_atomic, restore, latest_step

        d = tempfile.mkdtemp()
        mesh1 = jax.make_mesh((8,), ("data",))
        x = jnp.arange(64, dtype=jnp.float32).reshape(8, 8)
        xs = jax.device_put(x, NamedSharding(mesh1, P("data", None)))
        save_atomic(d, 3, {"w": xs})
        # restore onto a DIFFERENT mesh shape (elastic rescale 8 -> 4x2)
        mesh2 = jax.make_mesh((4, 2), ("data", "model"))
        tgt = NamedSharding(mesh2, P("model", "data"))
        like = {"w": jax.ShapeDtypeStruct((8, 8), jnp.float32)}
        out, meta = restore(d, 3, like, {"w": tgt})
        np.testing.assert_array_equal(np.asarray(out["w"]), np.asarray(x))
        assert out["w"].sharding == tgt
        print("OK elastic restore")
    """)


def test_sharded_fused_decode_token_identical():
    """The fused multi-step decode window under a (data, model) mesh — the
    paged Pallas kernel's shard_map wrapper running *inside* the scanned
    step — is token-identical to single-device per-step decode."""
    run_devprog("""
        import numpy as np, jax
        from repro.dist import compat
        from repro.dist.sharding import make_rules
        from repro.launch.mesh import make_test_mesh
        from repro.models import Model, load_reduced
        from repro.models.config import QuantPolicy
        from repro.serve import ContinuousBatchingEngine, GenerationConfig

        cfg = load_reduced("chatglm3_6b",
                           mx=QuantPolicy.parse("kv=int8@32:ocp"),
                           attn_impl="flash")
        model = Model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        rng = np.random.default_rng(0)
        prompts = [rng.integers(0, cfg.vocab, size=n).astype(np.int32)
                   for n in (4, 9, 14, 9)]
        gen = GenerationConfig(max_new_tokens=4)

        mesh = make_test_mesh(jax.device_count())
        rules = make_rules(mesh.axis_names, fsdp_params=False,
                           quant=cfg.mx)
        with compat.set_mesh(mesh):
            eng = ContinuousBatchingEngine(
                model, params, max_slots=2, page_size=8, max_len=19,
                rules=rules, gen=gen, sync_every=4)
            for p in prompts:
                eng.add_request(p, 4)
            sharded = eng.run()
        eng1 = ContinuousBatchingEngine(
            model, params, max_slots=2, page_size=8, max_len=19,
            gen=gen, sync_every=1)
        for p in prompts:
            eng1.add_request(p, 4)
        single = eng1.run()
        for r in sharded:
            np.testing.assert_array_equal(sharded[r], single[r])
        assert eng.n_syncs < eng1.n_syncs
        print("OK sharded fused decode")
    """, ndev=2)


def test_exchanged_bytes_accounting():
    from repro.core.grad_compress import exchanged_bytes
    base = exchanged_bytes(1_000_000, 16, compressed=False)
    comp = exchanged_bytes(1_000_000, 16, compressed=True)
    assert 1.5 < base / comp < 1.7   # (8 vs 4+1.03) * (n-1)/n
