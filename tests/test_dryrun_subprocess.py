"""Dry-run path tests on a small (8-device) mesh in a subprocess, plus unit
tests for the HLO collective parser."""
import os
import subprocess
import sys
import textwrap

import pytest

from repro.launch.hlo_stats import collective_bytes, _shape_bytes

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_shape_bytes_parser():
    assert _shape_bytes("bf16[4,512]{1,0}") == 4 * 512 * 2
    assert _shape_bytes("f32[2,3]") == 24
    assert _shape_bytes("(f32[2,2]{1,0}, u8[16]{0})") == 16 + 16
    assert _shape_bytes("pred[8]") == 8


def test_collective_bytes_parser():
    hlo = """
  %ag = bf16[8,128]{1,0} all-gather(%x), dimensions={0}
  %ar.1 = f32[64]{0} all-reduce-start(%y), to_apply=%add
  %ar.2 = f32[64]{0} all-reduce-done(%ar.1)
  %a2a = u8[32,4]{1,0} all-to-all(%z), dimensions={1}
"""
    out = collective_bytes(hlo)
    assert out["all-gather"] == 8 * 128 * 2
    assert out["all-reduce"] == 64 * 4      # start counted, done skipped
    assert out["all-to-all"] == 32 * 4
    assert out["count"] == 3


@pytest.mark.slow
def test_dryrun_small_mesh_lower_compile():
    """The real cell-building path (reduced arch, 8 host devices) lowers,
    compiles, and yields cost/memory analyses for all three cell kinds."""
    prog = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = (
            "--xla_force_host_platform_device_count=8")
        import jax
        from repro.launch.cells import build_cell, lower_cell
        import repro.launch.cells as C
        import repro.models.registry as R
        import dataclasses

        # shrink: monkeypatch the config loader to the reduced config with
        # dims divisible by the 2x4 test mesh
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        real_load = R.load_config
        def tiny(arch, **over):
            cfg = R.load_reduced(arch, dtype="bfloat16",
                                 param_dtype="bfloat16")
            return dataclasses.replace(cfg, **over) if over else cfg
        C.load_config = tiny
        import repro.launch.dryrun  # not imported: avoid 512-dev flag

        from repro.models.config import SHAPES, ShapeSpec
        SHAPES["train_4k"] = ShapeSpec("train_4k", 64, 8, "train")
        SHAPES["prefill_32k"] = ShapeSpec("prefill_32k", 64, 8, "prefill")
        SHAPES["decode_32k"] = ShapeSpec("decode_32k", 64, 8, "decode")

        for shape in ("train_4k", "prefill_32k", "decode_32k"):
            cell = build_cell("chatglm3_6b", shape, mesh, "baseline")
            lowered = lower_cell(cell)
            compiled = lowered.compile()
            ca = compiled.cost_analysis()
            ma = compiled.memory_analysis()
            assert ca.get("flops", 0) > 0, shape
            assert ma.temp_size_in_bytes >= 0, shape
            print("OK", shape, ca.get("flops"))
    """)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run([sys.executable, "-c", prog], capture_output=True,
                         text=True, env=env, timeout=560)
    assert out.returncode == 0, f"STDOUT:{out.stdout}\nSTDERR:{out.stderr}"
    assert out.stdout.count("OK") == 3
