"""End-to-end non-finite goldens: poisoned prompts across the format zoo.

The tentpole guarantee of the numeric-health guards, checked from the
outside in: a prompt whose activations go non-finite (one embedding row
poked to Inf/NaN — the cheapest way to make a *real* forward pass
produce the garbage a hardware fault would) must be quarantined at
admission with a diagnostic, while a healthy request sharing the batch
streams tokens identical to a run without the poisoned neighbor.

Coverage: all six MX element formats x both conversion modes (paper
mode sees SCALE_INF markers from Inf blocks, ocp mode folds Inf into
SCALE_NAN — both sides of ``core.formats.poison_threshold``), the fp
(unquantized) cache where detection rides the finite-logits guard
instead of scale bytes, and the ``health_checks=False`` counterfactual
proving the guard is what stands between a poisoned page and a garbage
stream.
"""
import jax
import numpy as np
import pytest

from repro.core.formats import ALL_FORMATS
from repro.models import Model, load_reduced
from repro.models.config import QuantPolicy, QuantSpec
from repro.serve import ContinuousBatchingEngine, GenerationConfig

PAGE = 8
NEW = 6
BAD_TOK = 5          # the embedding row poked non-finite


def _setup(cfg, bad_val):
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    params["embed"] = params["embed"].at[BAD_TOK].set(bad_val)
    rng = np.random.default_rng(0)
    healthy = rng.integers(BAD_TOK + 1, cfg.vocab, size=9).astype(np.int32)
    poisoned = healthy.copy()
    poisoned[4] = BAD_TOK
    return model, params, healthy, poisoned


def _engine(model, params, **kw):
    return ContinuousBatchingEngine(model, params, max_slots=4,
                                    page_size=PAGE, max_len=32,
                                    sync_every=4,
                                    gen=GenerationConfig(max_new_tokens=NEW),
                                    **kw)


def _assert_quarantined(cfg, bad_val):
    model, params, healthy, poisoned = _setup(cfg, bad_val)
    # reference: the healthy prompt alone (same rid 0 -> same PRNG key)
    ref = _engine(model, params)
    rh0 = ref.add_request(healthy, NEW)
    want = ref.run()

    eng = _engine(model, params)
    rh = eng.add_request(healthy, NEW)
    rp = eng.add_request(poisoned, NEW)
    out = eng.run()
    assert rh == rh0
    failed = {r.rid: r.error for r in eng.scheduler.failed}
    assert set(failed) == {rp}, failed
    assert "health guard" in failed[rp]
    assert eng.n_quarantined == 1 and rp not in out
    # the healthy neighbor is untouched by the quarantine next door
    np.testing.assert_array_equal(out[rh], want[rh0])
    assert len(out[rh]) == NEW


@pytest.mark.parametrize("mode", ["paper", "ocp"])
@pytest.mark.parametrize("fmt", [f.name for f in ALL_FORMATS])
def test_nonfinite_prompt_quarantined_all_formats(fmt, mode):
    """Inf in paper mode exercises the SCALE_INF marker (>= threshold);
    NaN in ocp mode exercises the folded SCALE_NAN marker — together the
    parametrization covers both poison encodings in both modes."""
    kv = QuantSpec(fmt, mode)
    cfg = load_reduced("chatglm3_6b",
                       mx=QuantPolicy(kv_key=kv, kv_value=kv))
    _assert_quarantined(cfg, np.inf if mode == "paper" else np.nan)


def test_nonfinite_prompt_quarantined_fp_cache():
    """No scale bytes in a dense cache: detection rides the in-scan
    finite-logits guard instead."""
    _assert_quarantined(load_reduced("chatglm3_6b"), np.nan)


def test_nonfinite_prompt_quarantined_mixed_roles():
    _assert_quarantined(
        load_reduced("chatglm3_6b", mx=QuantPolicy.parse(
            "kv_key=int8@32:paper,kv_value=e2m1@32:ocp")), np.nan)


def test_health_off_streams_garbage():
    """The counterfactual: with ``health_checks=False`` the poisoned
    request is *not* quarantined — it streams its full budget of garbage
    tokens.  (Healthy rows are still correct: batch rows are
    independent, and with no quarantine no poisoned page is recycled.)"""
    cfg = load_reduced("chatglm3_6b", mx=QuantPolicy.parse(
        "kv_key=int8@32:paper,kv_value=e4m3@32:paper"))
    model, params, healthy, poisoned = _setup(cfg, np.nan)
    ref = _engine(model, params, health_checks=False)
    rh0 = ref.add_request(healthy, NEW)
    want = ref.run()

    eng = _engine(model, params, health_checks=False)
    rh = eng.add_request(healthy, NEW)
    rp = eng.add_request(poisoned, NEW)
    out = eng.run()
    assert not eng.scheduler.failed and eng.n_quarantined == 0
    assert len(out[rp]) == NEW           # garbage, but streamed
    np.testing.assert_array_equal(out[rh], want[rh0])
