"""Flash-attention Pallas kernel vs dense oracle (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attn import _dense_ref, flash_attention


def _qkv(b, sq, sk, h, hkv, d, seed=0, dtype=jnp.float32):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(b, sq, h, d)).astype(np.float32),
                    dtype=dtype)
    k = jnp.asarray(rng.normal(size=(b, sk, hkv, d)).astype(np.float32),
                    dtype=dtype)
    v = jnp.asarray(rng.normal(size=(b, sk, hkv, d)).astype(np.float32),
                    dtype=dtype)
    return q, k, v


@pytest.mark.parametrize("shape", [
    (1, 128, 128, 2, 2, 32),       # MHA, single block
    (2, 512, 512, 4, 1, 64),       # GQA 4:1, multi-block
    (1, 300, 300, 2, 2, 32),       # ragged (padding path)
    (2, 256, 1024, 4, 2, 64),      # cross-ish lengths (causal)
])
def test_flash_matches_dense_causal(shape):
    b, sq, sk, h, hkv, d = shape
    q, k, v = _qkv(*shape)
    out = flash_attention(q, k, v, True)
    ref = _dense_ref(q, k, v, True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_flash_non_causal():
    q, k, v = _qkv(2, 256, 512, 2, 2, 32, seed=1)
    out = flash_attention(q, k, v, False)
    ref = _dense_ref(q, k, v, False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_flash_bf16():
    q, k, v = _qkv(1, 256, 256, 2, 2, 64, seed=2, dtype=jnp.bfloat16)
    out = flash_attention(q, k, v, True)
    ref = _dense_ref(q, k, v, True)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=2e-2, atol=2e-2)


def test_flash_gradient_matches_dense():
    q, k, v = _qkv(1, 128, 128, 2, 1, 32, seed=3)

    def loss_flash(q, k, v):
        return jnp.sum(flash_attention(q, k, v, True) ** 2)

    def loss_dense(q, k, v):
        return jnp.sum(_dense_ref(q, k, v, True) ** 2)

    gf = jax.grad(loss_flash, argnums=(0, 1, 2))(q, k, v)
    gd = jax.grad(loss_dense, argnums=(0, 1, 2))(q, k, v)
    for a, b_ in zip(gf, gd):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                                   rtol=1e-4, atol=1e-4)


def test_flash_records_accounting():
    from repro.kernels import accounting
    q, k, v = _qkv(1, 128, 128, 2, 2, 32, seed=4)
    with accounting.collect() as acc:
        jax.eval_shape(lambda a, b, c: flash_attention(a, b, c, True),
                       q, k, v)
    assert acc["calls"] == 1
    assert acc["flops"] == 4 * 1 * 2 * 128 * 128 * 32 * 0.5
