"""MX decode-attention kernel vs dequantize-then-attend oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import mx_quantize, mx_dequantize
from repro.kernels.mx_decode_attn import mx_decode_attention


def _setup(b, s, hq, hkv, d, fmt, mode, seed=0):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(b, 1, hq, d)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(b, s, hkv, d)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(b, s, hkv, d)).astype(np.float32))
    mk = mx_quantize(k, fmt=fmt, mode=mode, axis=-1)
    mv = mx_quantize(v, fmt=fmt, mode=mode, axis=-1)
    return q, mk, mv


def _oracle(q, mk, mv, pos, rep):
    k = mx_dequantize(mk)
    v = mx_dequantize(mv)
    b, s, hkv, d = k.shape
    hq = q.shape[2]
    idx = jnp.arange(hq) // rep
    ke = jnp.take(k, idx, axis=2)
    ve = jnp.take(v, idx, axis=2)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, ke) / np.sqrt(d)
    mask = jnp.arange(s)[None, None, None, :] <= pos
    scores = jnp.where(mask, scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", p, ve)


@pytest.mark.parametrize("fmt,mode", [("int8", "ocp"), ("e4m3", "paper"),
                                      ("e5m2", "ocp")])
def test_decode_kernel_matches_oracle(fmt, mode):
    b, s, hq, hkv, d = 2, 256, 4, 2, 64
    q, mk, mv = _setup(b, s, hq, hkv, d, fmt, mode)
    pos = jnp.asarray(200, jnp.int32)
    out = mx_decode_attention(q, mk.codes, mk.scales, mv.codes, mv.scales,
                              pos, fmt=fmt, mode=mode, rep=hq // hkv,
                              blk_k=128)
    ref = _oracle(q, mk, mv, pos, hq // hkv)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_decode_kernel_block_invariance():
    b, s, hq, hkv, d = 1, 512, 2, 1, 32
    q, mk, mv = _setup(b, s, hq, hkv, d, "int8", "ocp", seed=1)
    pos = jnp.asarray(317, jnp.int32)
    o1 = mx_decode_attention(q, mk.codes, mk.scales, mv.codes, mv.scales,
                             pos, fmt="int8", mode="ocp", rep=2, blk_k=64)
    o2 = mx_decode_attention(q, mk.codes, mk.scales, mv.codes, mv.scales,
                             pos, fmt="int8", mode="ocp", rep=2, blk_k=512)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), rtol=2e-5,
                               atol=2e-5)


def test_decode_kernel_pos_zero():
    """Only position 0 valid — matches attending to a single key."""
    b, s, hq, hkv, d = 1, 128, 2, 2, 32
    q, mk, mv = _setup(b, s, hq, hkv, d, "int8", "ocp", seed=2)
    pos = jnp.asarray(0, jnp.int32)
    out = mx_decode_attention(q, mk.codes, mk.scales, mv.codes, mv.scales,
                              pos, fmt="int8", mode="ocp", rep=1, blk_k=64)
    v0 = mx_dequantize(mv)[:, 0]                    # softmax over 1 key
    np.testing.assert_allclose(np.asarray(out[:, 0]), np.asarray(v0),
                               rtol=2e-5, atol=2e-5)
