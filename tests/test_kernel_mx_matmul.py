"""Pallas mx_matmul kernel vs oracle across shapes/formats/modes."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ALL_FORMATS, mx_quantize
from repro.kernels.mx_matmul import mx_matmul_2d
from repro.kernels.ops import mx_matmul, mx_quantize_pallas, quantize_weight
from repro.kernels.ref import mx_matmul_2d_ref

ALL_FMTS = [f.name for f in ALL_FORMATS]


def _setup(m, k, n, seed=0):
    rng = np.random.default_rng(seed)
    a = jnp.asarray(rng.normal(size=(m, k)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(k, n)).astype(np.float32) * 0.05)
    return a, w


@pytest.mark.parametrize("fmt", ALL_FMTS)
@pytest.mark.parametrize("mode", ["paper", "ocp"])
def test_matmul_matches_ref_formats(fmt, mode):
    a, w = _setup(32, 128, 64, seed=1)
    mx = mx_quantize(w, fmt=fmt, mode=mode, axis=0)
    out_k = mx_matmul_2d(a, mx.codes, mx.scales, fmt=fmt, mode=mode)
    out_r = mx_matmul_2d_ref(a, mx.codes, mx.scales, fmt=fmt, mode=mode)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("shape", [(8, 32, 16), (64, 256, 128),
                                   (100, 96, 72), (257, 512, 300),
                                   (16, 1024, 16)])
def test_matmul_matches_ref_shapes(shape):
    m, k, n = shape
    a, w = _setup(m, k, n, seed=2)
    mx = mx_quantize(w, fmt="e4m3", mode="ocp", axis=0)
    out_k = mx_matmul_2d(a, mx.codes, mx.scales, fmt="e4m3", mode="ocp")
    out_r = mx_matmul_2d_ref(a, mx.codes, mx.scales, fmt="e4m3", mode="ocp")
    assert out_k.shape == (m, n)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                               rtol=1e-5, atol=1e-5)


def test_matmul_tile_shapes_agree():
    a, w = _setup(64, 512, 96, seed=3)
    mx = mx_quantize(w, fmt="e5m2", mode="paper", axis=0)
    o1 = mx_matmul_2d(a, mx.codes, mx.scales, fmt="e5m2", mode="paper",
                      bm=32, bn=32, bk=64)
    o2 = mx_matmul_2d(a, mx.codes, mx.scales, fmt="e5m2", mode="paper",
                      bm=64, bn=96, bk=512)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                               rtol=1e-5, atol=1e-5)


def test_matmul_error_vs_exact_bounded():
    """MX-weight matmul error vs exact f32 matmul stays within the analytic
    per-block bound: |err| <= sum_k |a_k| * blockmax_k * 2^-R * 2."""
    a, w = _setup(16, 256, 32, seed=4)
    out_exact = np.asarray(a @ w)
    for fmt, rel in [("e4m3", 0.08), ("int8", 0.02), ("e5m2", 0.3)]:
        mx = mx_quantize(w, fmt=fmt, mode="ocp", axis=0)
        out = np.asarray(mx_matmul_2d(a, mx.codes, mx.scales, fmt=fmt,
                                      mode="ocp"))
        scale = np.abs(np.asarray(a)) @ np.abs(np.asarray(w)) + 1e-6
        assert np.max(np.abs(out - out_exact) / scale) < rel, fmt


def test_ops_wrappers_nd():
    rng = np.random.default_rng(5)
    a = jnp.asarray(rng.normal(size=(4, 7, 96)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(96, 40)).astype(np.float32))
    wq = quantize_weight(w, fmt="e4m3", mode="ocp")
    out = mx_matmul(a, wq)
    assert out.shape == (4, 7, 40)
    ref = a.reshape(-1, 96) @ jnp.asarray(
        np.asarray(wq.dequantize()))
    np.testing.assert_allclose(np.asarray(out).reshape(-1, 40),
                               np.asarray(ref), rtol=1e-5, atol=1e-5)


def test_pallas_quant_wrapper_matches_core():
    rng = np.random.default_rng(6)
    x = jnp.asarray(rng.normal(size=(3, 5, 160)).astype(np.float32))
    mx_k = mx_quantize_pallas(x, fmt="e2m1", mode="paper")
    mx_c = mx_quantize(x, fmt="e2m1", mode="paper")
    np.testing.assert_array_equal(np.asarray(mx_k.codes),
                                  np.asarray(mx_c.codes))
    np.testing.assert_array_equal(np.asarray(mx_k.scales),
                                  np.asarray(mx_c.scales))
    np.testing.assert_array_equal(np.asarray(mx_k.dequantize()),
                                  np.asarray(mx_c.dequantize()))
