"""Pallas mx_matmul kernel vs oracle across shapes/formats/modes."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (ALL_FORMATS, QuantSpec, decode_elements, mx_quantize,
                        pack_codes_rows, scale_to_f32)
from repro.kernels.mx_matmul import mx_matmul_2d
from repro.kernels.ops import mx_matmul, mx_quantize_pallas, quantize_weight
from repro.kernels.ref import mx_matmul_2d_ref

ALL_FMTS = [f.name for f in ALL_FORMATS]


def _setup(m, k, n, seed=0):
    rng = np.random.default_rng(seed)
    a = jnp.asarray(rng.normal(size=(m, k)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(k, n)).astype(np.float32) * 0.05)
    return a, w


@pytest.mark.parametrize("fmt", ALL_FMTS)
@pytest.mark.parametrize("mode", ["paper", "ocp"])
def test_matmul_matches_ref_formats(fmt, mode):
    a, w = _setup(32, 128, 64, seed=1)
    mx = mx_quantize(w, fmt=fmt, mode=mode, axis=0)
    out_k = mx_matmul_2d(a, mx.codes, mx.scales, fmt=fmt, mode=mode)
    out_r = mx_matmul_2d_ref(a, mx.codes, mx.scales, fmt=fmt, mode=mode)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("shape", [(8, 32, 16), (64, 256, 128),
                                   (100, 96, 72), (257, 512, 300),
                                   (16, 1024, 16)])
def test_matmul_matches_ref_shapes(shape):
    m, k, n = shape
    a, w = _setup(m, k, n, seed=2)
    mx = mx_quantize(w, fmt="e4m3", mode="ocp", axis=0)
    out_k = mx_matmul_2d(a, mx.codes, mx.scales, fmt="e4m3", mode="ocp")
    out_r = mx_matmul_2d_ref(a, mx.codes, mx.scales, fmt="e4m3", mode="ocp")
    assert out_k.shape == (m, n)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                               rtol=1e-5, atol=1e-5)


def test_matmul_tile_shapes_agree():
    a, w = _setup(64, 512, 96, seed=3)
    mx = mx_quantize(w, fmt="e5m2", mode="paper", axis=0)
    o1 = mx_matmul_2d(a, mx.codes, mx.scales, fmt="e5m2", mode="paper",
                      bm=32, bn=32, bk=64)
    o2 = mx_matmul_2d(a, mx.codes, mx.scales, fmt="e5m2", mode="paper",
                      bm=64, bn=96, bk=512)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                               rtol=1e-5, atol=1e-5)


def test_matmul_error_vs_exact_bounded():
    """MX-weight matmul error vs exact f32 matmul stays within the analytic
    per-block bound: |err| <= sum_k |a_k| * blockmax_k * 2^-R * 2."""
    a, w = _setup(16, 256, 32, seed=4)
    out_exact = np.asarray(a @ w)
    for fmt, rel in [("e4m3", 0.08), ("int8", 0.02), ("e5m2", 0.3)]:
        mx = mx_quantize(w, fmt=fmt, mode="ocp", axis=0)
        out = np.asarray(mx_matmul_2d(a, mx.codes, mx.scales, fmt=fmt,
                                      mode="ocp"))
        scale = np.abs(np.asarray(a)) @ np.abs(np.asarray(w)) + 1e-6
        assert np.max(np.abs(out - out_exact) / scale) < rel, fmt


def test_ops_wrappers_nd():
    rng = np.random.default_rng(5)
    a = jnp.asarray(rng.normal(size=(4, 7, 96)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(96, 40)).astype(np.float32))
    wq = quantize_weight(w, fmt="e4m3", mode="ocp")
    out = mx_matmul(a, wq)
    assert out.shape == (4, 7, 40)
    ref = a.reshape(-1, 96) @ jnp.asarray(
        np.asarray(wq.dequantize()))
    np.testing.assert_allclose(np.asarray(out).reshape(-1, 40),
                               np.asarray(ref), rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------- tiling
def test_bk_not_block_multiple_rounds_down():
    """bk=48 with block=32 used to truncate the scale tile (one scale row
    stretched over 48 code rows); it must now round down to bk=32 and
    agree with the oracle."""
    a, w = _setup(17, 96, 72, seed=7)
    for fmt, mode in [("e4m3", "ocp"), ("e2m1", "paper"), ("int8", "ocp")]:
        mx = mx_quantize(w, fmt=fmt, mode=mode, axis=0)
        out = mx_matmul_2d(a, mx.codes, mx.scales, fmt=fmt, mode=mode,
                           bk=48)
        ref = mx_matmul_2d_ref(a, mx.codes, mx.scales, fmt=fmt, mode=mode)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-4, atol=1e-4)


def test_bk_below_block_raises():
    a, w = _setup(8, 64, 32, seed=8)
    mx = mx_quantize(w, fmt="e4m3", mode="ocp", axis=0)
    with pytest.raises(ValueError, match="scale block"):
        mx_matmul_2d(a, mx.codes, mx.scales, fmt="e4m3", mode="ocp", bk=16)
    with pytest.raises(ValueError, match="positive"):
        mx_matmul_2d(a, mx.codes, mx.scales, fmt="e4m3", mode="ocp", bm=0)


# ---------------------------------------------------------- zero padding
@pytest.mark.parametrize("fmt", ALL_FMTS)
@pytest.mark.parametrize("mode", ["paper", "ocp"])
def test_zero_code_zero_scale_decodes_to_exact_zero(fmt, mode):
    """The kernel zero-pads codes AND scales; the padded region contributes
    exactly 0.0 iff decode(0) * scale_to_f32(0) == 0.0 — including int8's
    two's-complement code space (code 0 is integer 0 in both modes) and
    the 2^-127 subnormal that an all-zero E8M0 scale denotes."""
    spec = QuantSpec(fmt, mode, 32, True)
    elem = decode_elements(jnp.zeros((32,), jnp.uint8), spec.format, mode)
    sfac = scale_to_f32(jnp.zeros((1,), jnp.uint8))
    prod = elem * sfac
    assert np.all(np.asarray(elem) == 0.0), (fmt, mode)
    assert np.all(np.asarray(prod) == 0.0), (fmt, mode)
    # the sign bit must be clean too: 0.0, not -0.0 leaking sign flips
    assert not np.signbit(np.asarray(prod)).any(), (fmt, mode)


@pytest.mark.parametrize("fmt", ALL_FMTS)
@pytest.mark.parametrize("mode", ["paper", "ocp"])
def test_padding_never_leaks_non_aligned(fmt, mode):
    """Golden test on non-aligned M/N/K: tile padding must never leak into
    out[:m, :n].  Tiny tiles force padding on every axis; the oracle sees
    only the unpadded operands."""
    m, k, n = 13, 96, 21
    a, w = _setup(m, k, n, seed=9)
    mx = mx_quantize(w, fmt=fmt, mode=mode, axis=0)
    out = mx_matmul_2d(a, mx.codes, mx.scales, fmt=fmt, mode=mode,
                       bm=8, bn=16, bk=64)
    ref = mx_matmul_2d_ref(a, mx.codes, mx.scales, fmt=fmt, mode=mode)
    assert out.shape == (m, n)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


# ------------------------------------------------------- packed codes path
@pytest.mark.parametrize("fmt", ALL_FMTS)
@pytest.mark.parametrize("mode", ["paper", "ocp"])
def test_packed_codes_bitwise_match_unpacked(fmt, mode):
    """The fused kernel unpacking bit-packed codes in VMEM must produce
    bitwise-identical output to the same kernel fed unpacked codes."""
    a, w = _setup(9, 160, 48, seed=10)
    mx = mx_quantize(w, fmt=fmt, mode=mode, axis=0)
    packed = pack_codes_rows(mx.codes, fmt)
    spec = QuantSpec(fmt, mode, 32, True)
    o_un = mx_matmul_2d(a, mx.codes, mx.scales, spec, bm=8, bn=32, bk=64)
    o_pk = mx_matmul_2d(a, packed, mx.scales, spec, bm=8, bn=32, bk=64)
    np.testing.assert_array_equal(np.asarray(o_un), np.asarray(o_pk))


def test_packed_codes_bad_row_count_raises():
    a, w = _setup(4, 64, 32, seed=11)
    mx = mx_quantize(w, fmt="e2m1", mode="ocp", axis=0)
    with pytest.raises(ValueError, match="rows"):
        mx_matmul_2d(a, mx.codes[:48], mx.scales,
                     QuantSpec("e2m1", "ocp", 32, True))


def test_pallas_quant_wrapper_matches_core():
    rng = np.random.default_rng(6)
    x = jnp.asarray(rng.normal(size=(3, 5, 160)).astype(np.float32))
    mx_k = mx_quantize_pallas(x, fmt="e2m1", mode="paper")
    mx_c = mx_quantize(x, fmt="e2m1", mode="paper")
    np.testing.assert_array_equal(np.asarray(mx_k.codes),
                                  np.asarray(mx_c.codes))
    np.testing.assert_array_equal(np.asarray(mx_k.scales),
                                  np.asarray(mx_c.scales))
    np.testing.assert_array_equal(np.asarray(mx_k.dequantize()),
                                  np.asarray(mx_c.dequantize()))
