"""Pallas mx_quant kernel vs pure-jnp oracle: bit-identity across
shapes / dtypes / formats / modes (interpret mode)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ALL_FORMATS
from repro.kernels.mx_quant import mx_quantize_2d
from repro.kernels.ref import mx_quantize_2d_ref

ALL_FMTS = [f.name for f in ALL_FORMATS]

SHAPES = [(1, 32), (4, 64), (8, 512), (3, 96), (130, 1024), (257, 160)]


def _rand(shape, dtype, seed=0, scale=4.0):
    rng = np.random.default_rng(seed)
    x = rng.normal(scale=scale, size=shape).astype(np.float32)
    # sprinkle exact zeros and tiny/huge values; "huge" stays within the
    # target dtype's finite range (casting overflowing f32 to float16
    # emits RuntimeWarning and turns the values into inf)
    x.flat[:: 7] = 0.0
    x.flat[1:: 13] *= 1e-20
    x.flat[2:: 17] *= 1e20
    lim = float(jnp.finfo(dtype).max) * 0.9
    np.clip(x, -lim, lim, out=x)
    return jnp.asarray(x, dtype=dtype)


@pytest.mark.parametrize("fmt", ALL_FMTS)
@pytest.mark.parametrize("mode", ["paper", "ocp"])
def test_kernel_matches_ref_formats(fmt, mode):
    x = _rand((16, 256), jnp.float32, seed=1)
    ck, sk = mx_quantize_2d(x, fmt=fmt, mode=mode)
    cr, sr = mx_quantize_2d_ref(x, fmt=fmt, mode=mode)
    np.testing.assert_array_equal(np.asarray(ck), np.asarray(cr))
    np.testing.assert_array_equal(np.asarray(sk), np.asarray(sr))


@pytest.mark.parametrize("shape", SHAPES)
def test_kernel_matches_ref_shapes(shape):
    x = _rand(shape, jnp.float32, seed=2)
    ck, sk = mx_quantize_2d(x, fmt="e4m3", mode="paper")
    cr, sr = mx_quantize_2d_ref(x, fmt="e4m3", mode="paper")
    assert ck.shape == x.shape
    np.testing.assert_array_equal(np.asarray(ck), np.asarray(cr))
    np.testing.assert_array_equal(np.asarray(sk), np.asarray(sr))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16, jnp.float16])
def test_kernel_matches_ref_dtypes(dtype):
    x = _rand((32, 512), dtype, seed=3)
    ck, sk = mx_quantize_2d(x, fmt="e5m2", mode="ocp")
    cr, sr = mx_quantize_2d_ref(x.astype(jnp.float32), fmt="e5m2", mode="ocp")
    np.testing.assert_array_equal(np.asarray(ck), np.asarray(cr))
    np.testing.assert_array_equal(np.asarray(sk), np.asarray(sr))


def test_kernel_nonfinite_markers():
    x = np.zeros((2, 64), np.float32)
    x[0, 3] = np.inf
    x[1, 40] = np.nan
    x[1, 41] = 5.0
    ck, sk = mx_quantize_2d(jnp.asarray(x), fmt="e4m3", mode="paper")
    cr, sr = mx_quantize_2d_ref(jnp.asarray(x), fmt="e4m3", mode="paper")
    np.testing.assert_array_equal(np.asarray(ck), np.asarray(cr))
    np.testing.assert_array_equal(np.asarray(sk), np.asarray(sr))
    assert np.asarray(sk)[0, 0] == 0xFE and np.asarray(sk)[1, 1] == 0xFF


def test_kernel_tile_boundary_independence():
    """Same data, different tile shapes -> identical codes (no cross-tile
    state leaks; blocks are 32-aligned within every legal tile)."""
    x = _rand((64, 1024), jnp.float32, seed=4)
    c1, s1 = mx_quantize_2d(x, fmt="e3m2", mode="ocp", bm=16, bn=256)
    c2, s2 = mx_quantize_2d(x, fmt="e3m2", mode="ocp", bm=64, bn=1024)
    np.testing.assert_array_equal(np.asarray(c1), np.asarray(c2))
    np.testing.assert_array_equal(np.asarray(s1), np.asarray(s2))
