"""Golden-value tests for core/metrics (previously untested).

SQNR goldens use signals whose quantization is exactly predictable:
exactly-representable blocks (zero noise), a constant block whose INT8
rounding is computable by hand, and additive noise of known power.  Also
pins the short-trailing-dim fix of ``max_rel_err_vs_blockmax`` (inputs
narrower than one block used to reduce over zero blocks -> ``-inf``).
"""
import jax.numpy as jnp
import numpy as np

from repro.core import QuantSpec, metrics, quantize_dequantize


def _g(shape=(8, 64), seed=0, scale=1.0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(scale=scale, size=shape
                                  ).astype(np.float32))


# =============================================================================
# sqnr_db
# =============================================================================
def test_sqnr_exact_representation_is_huge():
    """Powers of two are exact in every MX float format: zero noise."""
    x = jnp.asarray(np.tile([1.0, 0.5, 2.0, 4.0], 8).astype(np.float32))
    for fmt in ("e4m3", "e2m1", "int8"):
        xq = quantize_dequantize(x, QuantSpec(fmt, "ocp", 32))
        assert float(metrics.sqnr_db(x, xq)) > 100.0, fmt


def test_sqnr_known_noise_power():
    """Additive noise of amplitude a on a signal of RMS r gives exactly
    20*log10(r/a)."""
    x = _g((4, 128), seed=1)
    a = 1e-3
    xq = x + a
    rms = float(jnp.sqrt(jnp.mean(x * x)))
    want = 20.0 * np.log10(rms / a)
    got = float(metrics.sqnr_db(x, xq))
    np.testing.assert_allclose(got, want, rtol=1e-5)


def test_sqnr_int8_constant_block_golden():
    """A constant block quantizes to one hand-computable INT8 code.

    x = f32(1/3) repeated: EV_max is the exponent of 1/3 (biased 125), so
    the OCP shared scale is 2^-2 and the element magnitude is
    RNE(x / 2^-2 * 64) = 85 -> xq = 85/256.  SQNR follows analytically.
    """
    v = np.float32(1.0 / 3.0)
    x = jnp.full((32,), v)
    xq = quantize_dequantize(x, QuantSpec("int8", "ocp", 32))
    want_q = 85.0 / 256.0
    np.testing.assert_allclose(np.asarray(xq), want_q, rtol=0, atol=0)
    want_sqnr = 10.0 * np.log10(float(v) ** 2 / (float(v) - want_q) ** 2)
    np.testing.assert_allclose(float(metrics.sqnr_db(x, xq)), want_sqnr,
                               rtol=1e-5)


def test_mse_golden():
    x = jnp.zeros((10,))
    xq = jnp.full((10,), 2.0)
    np.testing.assert_allclose(float(metrics.mse(x, xq)), 4.0)


# =============================================================================
# max_rel_err_vs_blockmax
# =============================================================================
def test_max_rel_err_golden():
    """One element off by delta in a block whose max is m: err delta/m."""
    x = np.zeros((2, 32), np.float32)
    x[:, 0] = 8.0                      # block max
    xq = x.copy()
    xq[1, 5] = 0.5                     # |err| = 0.5 against max 8
    got = float(metrics.max_rel_err_vs_blockmax(jnp.asarray(x),
                                                jnp.asarray(xq), block=32))
    np.testing.assert_allclose(got, 0.5 / 8.0, rtol=1e-6)


def test_max_rel_err_short_trailing_dim():
    """Trailing dim shorter than the block: full-row max fallback instead
    of reducing over zero blocks (which used to return -inf)."""
    x = jnp.asarray(np.array([4.0, 1.0, 0.0, 0.0, 0.0, 0.0, 0.0, 2.0],
                             np.float32))
    xq = x.at[1].set(0.0)              # err 1.0 against row max 4.0
    got = float(metrics.max_rel_err_vs_blockmax(x, xq, block=32))
    assert np.isfinite(got)
    np.testing.assert_allclose(got, 0.25, rtol=1e-6)


def test_max_rel_err_short_dim_matches_explicit_block():
    """The fallback equals passing block=trailing-dim explicitly."""
    x = _g((4, 8), seed=3)
    xq = quantize_dequantize(x, QuantSpec("e4m3", "ocp", 8))
    a = float(metrics.max_rel_err_vs_blockmax(x, xq, block=32))
    b = float(metrics.max_rel_err_vs_blockmax(x, xq, block=8))
    np.testing.assert_allclose(a, b, rtol=1e-6)


def test_max_rel_err_zero_when_exact():
    x = jnp.asarray(np.tile([2.0, -1.0], 16).astype(np.float32))
    assert float(metrics.max_rel_err_vs_blockmax(x, x)) == 0.0


# =============================================================================
# format-refinement invariant (fixed seeds; the hypothesis variant lives
# in test_metrics_properties.py and runs where hypothesis is installed)
# =============================================================================
def test_wider_mantissa_never_scores_lower_sqnr_fixed_seeds():
    """E2M3's code grid is a superset of E2M1's at the same shared scale
    (same exponent bits), so its round-trip SQNR can never be lower."""
    narrow = QuantSpec("e2m1", "ocp", 32)
    wide = QuantSpec("e2m3", "ocp", 32)
    for seed in range(5):
        for scale in (1e-3, 1.0, 1e3):
            x = _g((16, 64), seed=seed, scale=scale)
            sn = float(metrics.sqnr_db(x, quantize_dequantize(x, narrow)))
            sw = float(metrics.sqnr_db(x, quantize_dequantize(x, wide)))
            assert sw >= sn - 1e-6, (seed, scale, sn, sw)
