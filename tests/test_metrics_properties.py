"""Property-based tests (hypothesis) for core/metrics invariants."""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="hypothesis not installed (pip install -e '.[test]')")
from hypothesis import given, settings, strategies as st

from repro.core import QuantSpec, metrics, quantize_dequantize

# bounded away from the marker-reserved top binade and from subnormals
finite_f32 = st.floats(
    min_value=-1e30, max_value=1e30, allow_nan=False, allow_infinity=False,
    width=32).filter(lambda v: v == 0 or abs(v) >= 1e-30)

blocks = st.lists(finite_f32, min_size=32, max_size=64).filter(
    lambda vs: any(v != 0 for v in vs))


@settings(max_examples=50, deadline=None)
@given(blocks)
def test_wider_mantissa_never_scores_lower_sqnr(vs):
    """Quantize-dequantize at a wider format (E2M3: same exponent bits,
    superset code grid) never scores lower SQNR than the narrower E2M1 on
    the same block."""
    x = jnp.asarray(np.asarray(vs, np.float32))
    sn = float(metrics.sqnr_db(
        x, quantize_dequantize(x, QuantSpec("e2m1", "ocp", 32))))
    sw = float(metrics.sqnr_db(
        x, quantize_dequantize(x, QuantSpec("e2m3", "ocp", 32))))
    assert sw >= sn - 1e-6


@settings(max_examples=50, deadline=None)
@given(blocks)
def test_block_rel_err_bounded_and_nonneg(vs):
    """Block-relative max error is finite, non-negative, and zero for the
    identity round trip — including rows shorter than one block."""
    x = jnp.asarray(np.asarray(vs, np.float32))
    assert float(metrics.max_rel_err_vs_blockmax(x, x)) == 0.0
    short = x[:8]
    if np.any(np.asarray(short) != 0):
        xq = quantize_dequantize(short, QuantSpec("e4m3", "ocp", 8))
        e = float(metrics.max_rel_err_vs_blockmax(short, xq, block=32))
        assert np.isfinite(e) and e >= 0.0
