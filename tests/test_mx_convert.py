"""Unit tests for the FP32->MX converter: rounding tables, markers, INT8,
packing, and paper-vs-ocp mode contrasts."""

import jax.numpy as jnp
import numpy as np
import pytest

import repro.core.convert as C
from repro.core import (ALL_FORMATS, FORMATS, SCALE_INF, SCALE_NAN,
                        get_format, mx_dequantize, mx_quantize, pack_codes,
                        quantize_dequantize, unpack_codes)

FLOAT_FMTS = [f.name for f in ALL_FORMATS if not f.is_int]
ALL_FMTS = [f.name for f in ALL_FORMATS]


def make_block(vals, n=32):
    x = np.zeros(n, np.float32)
    x[: len(vals)] = vals
    return jnp.asarray(x)


def fp32(sign, exp, man23):
    return np.uint32((sign << 31) | (exp << 23) | man23).view(np.float32)


# ---------------------------------------------------------------- rounding
def _round_table(r_in: int, r_out: int):
    """Paper ties-away rounding r_in -> r_out bits: (kept+1)>>1 with carry."""
    out = {}
    for v in range(1 << r_in):
        rnd = (v + 1) >> 1
        out[v] = ("carry", 0) if rnd >> r_out else ("ok", rnd)
    return out


@pytest.mark.parametrize("fmt", ["e5m2", "e3m2"])
def test_rounding_tables_3to2(fmt):
    """Paper §III.C bullet rules for the 3->2-bit formats:
    111->carry, 110/101->11, 100/011->10, 010/001->01, 000->00."""
    f = get_format(fmt)
    # construct a block whose max sets X so the probe element lands at a
    # mid-range exponent; probe all 8 mantissa patterns of the R+1 kept bits
    maxv = fp32(0, 150, 0)
    expect = {0b000: 0b00, 0b001: 0b01, 0b010: 0b01, 0b011: 0b10,
              0b100: 0b10, 0b101: 0b11, 0b110: 0b11}
    for pat, want in expect.items():
        x = make_block([maxv, fp32(0, 145, pat << 20)])
        mx = mx_quantize(x, fmt=fmt, mode="paper")
        code = int(np.asarray(mx.codes)[1])
        assert code & f.mant_mask == want, f"{pat:03b}: {code:#x}"
    # 111 -> carry: mantissa 0, exponent +1
    x = make_block([maxv, fp32(0, 145, 0b111 << 20)])
    mx = mx_quantize(x, fmt=fmt, mode="paper")
    code = int(np.asarray(mx.codes)[1])
    base = mx_quantize(make_block([maxv, fp32(0, 145, 0)]),
                       fmt=fmt, mode="paper")
    base_exp = (int(np.asarray(base.codes)[1]) >> f.mbits) & f.exp_mask
    assert code & f.mant_mask == 0
    assert (code >> f.mbits) & f.exp_mask == base_exp + 1


@pytest.mark.parametrize("fmt", ["e4m3", "e2m3"])
def test_rounding_tables_4to3(fmt):
    f = get_format(fmt)
    maxv = fp32(0, 150, 0)
    # probe exponent must land inside the format's (tiny, for e2m3) normal
    # range: eb = E - X + bias with X = 150 - bias
    probe = 146 if fmt == "e4m3" else 149
    expect = {0b0000: 0b000, 0b0001: 0b001, 0b0010: 0b001, 0b0011: 0b010,
              0b0100: 0b010, 0b0101: 0b011, 0b0110: 0b011, 0b0111: 0b100,
              0b1000: 0b100, 0b1001: 0b101, 0b1010: 0b101, 0b1011: 0b110,
              0b1100: 0b110, 0b1101: 0b111, 0b1110: 0b111}
    for pat, want in expect.items():
        x = make_block([maxv, fp32(0, probe, pat << 19)])
        mx = mx_quantize(x, fmt=fmt, mode="paper")
        code = int(np.asarray(mx.codes)[1])
        assert code & f.mant_mask == want, f"{pat:04b}: {code:#x}"


def test_rounding_e2m1():
    f = get_format("e2m1")
    maxv = fp32(0, 150, 0)
    # 2 kept bits -> 1: 00->0, 01->1(ties-away), 10->1, 11->carry
    for pat, want in {0b00: 0, 0b01: 1, 0b10: 1}.items():
        x = make_block([maxv, fp32(0, 149, pat << 21)])
        mx = mx_quantize(x, fmt="e2m1", mode="paper")
        code = int(np.asarray(mx.codes)[1])
        assert code & 1 == want, f"{pat:02b}: {code:#x}"


def test_saturation_at_top_paper():
    """Carry at the max exponent saturates ('no quantization' rows)."""
    for fmt in FLOAT_FMTS:
        f = get_format(fmt)
        r1 = f.mbits + 1
        # max element with all-ones kept mantissa -> would carry past top
        man = ((1 << r1) - 1) << (23 - r1)
        x = make_block([fp32(0, 150, man)])
        mx = mx_quantize(x, fmt=fmt, mode="paper")
        code = int(np.asarray(mx.codes)[0])
        assert (code >> f.mbits) & f.exp_mask == f.max_exp_paper, fmt
        assert code & f.mant_mask == f.mant_mask, fmt


def test_nan_marker_block():
    x = make_block([1.0, np.float32(np.nan), -2.0])
    for fmt in ALL_FMTS:
        mx = mx_quantize(x, fmt=fmt, mode="paper")
        assert int(np.asarray(mx.scales)[0]) == SCALE_NAN, fmt
        y = np.asarray(mx_dequantize(mx))
        assert np.all(np.isnan(y)), fmt


def test_inf_marker_block():
    x = make_block([1.0, np.float32(np.inf), -2.0])
    for fmt in FLOAT_FMTS:
        mx = mx_quantize(x, fmt=fmt, mode="paper")
        assert int(np.asarray(mx.scales)[0]) == SCALE_INF, fmt
        y = np.asarray(mx_dequantize(mx))
        assert np.all(np.isinf(y)), fmt
        # element signs are preserved on the markers
        assert y[2] < 0, fmt


def test_zero_block_quantizes_to_zero():
    x = jnp.zeros(64, jnp.float32)
    for fmt in ALL_FMTS:
        for mode in ("paper", "ocp"):
            y = np.asarray(quantize_dequantize(x, fmt=fmt, mode=mode))
            np.testing.assert_array_equal(y, 0.0)


def test_scale_law_paper():
    """X = EV_max - bias (clamped at 0) for every float format."""
    for fmt in FLOAT_FMTS:
        f = get_format(fmt)
        for ev in (1, 20, 127, 200, 254):
            x = make_block([fp32(0, ev, 0)])
            mx = mx_quantize(x, fmt=fmt, mode="paper")
            assert int(np.asarray(mx.scales)[0]) == max(ev - f.bias, 0), \
                (fmt, ev)


def test_scale_law_ocp():
    for fmt in ALL_FMTS:
        f = get_format(fmt)
        for ev in (1, 20, 127, 200, 254):
            x = make_block([fp32(0, ev, 0)])
            mx = mx_quantize(x, fmt=fmt, mode="ocp")
            assert int(np.asarray(mx.scales)[0]) == max(ev - f.emax_ocp, 0), \
                (fmt, ev)


def test_ocp_rne_vs_paper_ties_away():
    """A tie rounds away in paper mode but to-even in ocp mode."""
    maxv = fp32(0, 150, 0)
    # element mantissa = 0b001 in the top 3 bits, rest zero: exactly halfway
    # between M=00 and M=01 for an R=2 format
    x = make_block([maxv, fp32(0, 150, 0b001 << 20)])
    p = mx_quantize(x, fmt="e5m2", mode="paper")
    o = mx_quantize(x, fmt="e5m2", mode="ocp")
    assert int(np.asarray(p.codes)[1]) & 0b11 == 0b01   # ties away -> up
    assert int(np.asarray(o.codes)[1]) & 0b11 == 0b00   # ties even -> down


def test_ocp_subnormals_vs_paper_ftz():
    """An element far below the block max survives as a subnormal in ocp mode
    but flushes to zero in paper mode (for E5M2: eb <= 0 region)."""
    maxv = fp32(0, 150, 0)
    small = fp32(0, 150 - 30, 0)       # eb = E - X + 15 = 0 for e5m2
    x = make_block([maxv, small])
    yp = np.asarray(quantize_dequantize(x, fmt="e5m2", mode="paper"))
    yo = np.asarray(quantize_dequantize(x, fmt="e5m2", mode="ocp"))
    assert yp[1] == 0.0
    assert yo[1] != 0.0
    assert abs(yo[1] - float(small)) / float(small) < 0.5


def test_int8_paper_sign_magnitude():
    x = make_block([2.0, 1.0, -1.0, 0.5, 1.984375])
    mx = mx_quantize(x, fmt="int8", mode="paper")
    codes = np.asarray(mx.codes)
    # X = EV_max = 128 (2.0); scaled: 2.0->64/64... wait scale=2^1 so 2.0 -> 1.0
    assert int(np.asarray(mx.scales)[0]) == 128
    assert codes[0] == 64          # +1.0 * 64
    assert codes[1] == 32          # +0.5 * 64
    assert codes[2] == (1 << 7) | 32
    assert codes[3] == 16
    y = np.asarray(mx_dequantize(mx))
    assert y[0] == 2.0 and y[2] == -1.0


def test_int8_ocp_twos_complement():
    x = make_block([1.0, -1.0, -2.0])
    mx = mx_quantize(x, fmt="int8", mode="ocp")
    y = np.asarray(mx_dequantize(mx))
    assert y[0] == 1.0 and y[1] == -1.0 and y[2] == -2.0


def test_block_padding_and_axis():
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=(3, 50)).astype(np.float32))
    for axis in (0, 1, -1):
        y = quantize_dequantize(x, fmt="e4m3", mode="ocp", axis=axis)
        assert y.shape == x.shape
        assert np.isfinite(np.asarray(y)).all()


@pytest.mark.parametrize("fmt", ALL_FMTS)
def test_pack_roundtrip(fmt):
    rng = np.random.default_rng(2)
    f = get_format(fmt)
    n = 128
    codes = jnp.asarray(
        rng.integers(0, 1 << f.code_bits, size=(5, n)).astype(np.uint8))
    packed = pack_codes(codes, fmt)
    from repro.core.pack import packed_nbytes
    assert packed.shape[-1] == packed_nbytes(fmt, n)
    out = unpack_codes(packed, fmt, n)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(codes))


def test_bits_per_element_accounting():
    assert FORMATS["e4m3"].bits_per_element() == 8.25
    assert FORMATS["e2m1"].bits_per_element() == 4.25
    assert FORMATS["e3m2"].bits_per_element() == 6.25
