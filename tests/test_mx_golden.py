"""Golden vectors from the paper's worked Example (Parts 1-3, §II-§III).

The paper converts four FP32 inputs to E5M2:
    V1 = 0 10101011 011...   (sign 0, E=171, mantissa 011 in top bits)
    V2 = 0 10101000 110...
    V3 = 0 00101011 001...
    V4 = 1 10001111 001...
and derives:
    Part 1:  max(|EV_i|) = EV_1 = 10101011 (= 171)
    Part 2:  X_temp = 171 - 15 = 156 = 0b10011100  -> X = 0x9C
    Part 3:  P1 = 0 11110 10 = 0x7A      (EK = 30, mantissa 011 -> 10)
             P2 = 0 11011 11 = 0x6F      (EK = 27, mantissa 110 -> 11)
             P3 = 0 00000 00 = 0x00      (underflow -> flush to zero)
             P4 = 1 00000 00 = 0x80      (underflow, sign preserved)
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (ALL_FORMATS, E5M2, SCALE_INF, SCALE_NAN,
                        block_max_exponent, max_exponent_tree, mx_dequantize,
                        mx_quantize)

ALL_FMTS = [f.name for f in ALL_FORMATS]


def fp32_from_parts(sign: int, exp: int, man23: int) -> np.float32:
    bits = (sign << 31) | (exp << 23) | man23
    return np.uint32(bits).view(np.float32)


# top-3 mantissa bits per the example; remaining bits zero
V1 = fp32_from_parts(0, 0b10101011, 0b011 << 20)
V2 = fp32_from_parts(0, 0b10101000, 0b110 << 20)
V3 = fp32_from_parts(0, 0b00101011, 0b001 << 20)
V4 = fp32_from_parts(1, 0b10001111, 0b001 << 20)


def _block():
    x = np.zeros(32, np.float32)
    x[:4] = [V1, V2, V3, V4]
    return jnp.asarray(x)


def test_part1_max_exponent_tree():
    x = _block()
    import repro.core.convert as C
    _, exp, _ = C._f32_fields(x.reshape(1, 32))
    ev = block_max_exponent(exp, exp != 0xFF)
    assert int(ev[0]) == 0b10101011 == 171


def test_part2_shared_scale():
    mx = mx_quantize(_block(), fmt="e5m2", mode="paper")
    assert int(mx.scales.reshape(-1)[0]) == 0b10011100 == 0x9C


def test_part3_private_elements():
    """Corrected magnitude-based rule (framework default).

    P1..P3 match the paper exactly.  P4 differs: the paper's ±E sign rule
    (an erratum — see DESIGN.md §1) flushes the representable value
    -1.125*2^16 to -0; the corrected rule emits sign=1, EK=2, M=01.
    """
    mx = mx_quantize(_block(), fmt="e5m2", mode="paper")
    codes = np.asarray(mx.codes).reshape(-1)
    assert codes[0] == 0b01111010, f"P1: got {codes[0]:#010b}"
    assert codes[1] == 0b01101111, f"P2: got {codes[1]:#010b}"
    assert codes[2] == 0b00000000, f"P3: got {codes[2]:#010b}"
    assert codes[3] == 0b10001001, f"P4: got {codes[3]:#010b}"


def test_part3_sign_erratum_bit_exact():
    """With sign_erratum=True we reproduce the paper's worked example
    bit-for-bit, including P4 = 10000000 (the flushed negative)."""
    mx = mx_quantize(_block(), fmt="e5m2", mode="paper", sign_erratum=True)
    codes = np.asarray(mx.codes).reshape(-1)
    assert codes[0] == 0b01111010
    assert codes[1] == 0b01101111
    assert codes[2] == 0b00000000
    assert codes[3] == 0b10000000, f"P4: got {codes[3]:#010b}"


def test_golden_dequant_values():
    """Backward transform of the golden block: P1 = 1.5 * 2^15 * scale etc."""
    mx = mx_quantize(_block(), fmt="e5m2", mode="paper")
    y = np.asarray(mx_dequantize(mx)).reshape(-1)
    scale = 2.0 ** (0x9C - 127)                      # 2^29
    assert y[0] == pytest.approx((1 + 2 / 4) * 2.0 ** (30 - 15) * scale)
    assert y[1] == pytest.approx((1 + 3 / 4) * 2.0 ** (27 - 15) * scale)
    assert y[2] == 0.0
    assert y[3] == pytest.approx(-(1 + 1 / 4) * 2.0 ** (2 - 15) * scale)
    # relative reconstruction error of surviving elements is within one
    # mantissa ulp of the format
    for i, v in enumerate([float(V1), float(V2), float(V3), float(V4)]):
        if y[i] != 0.0:
            assert abs(y[i] - v) / abs(v) <= 2.0 ** (-E5M2.mbits)


# =============================================================================
# scale special markers (paper §II: X=0xFF NaN block, X=0xFE Inf block)
# =============================================================================
@pytest.mark.parametrize("fmt", ALL_FMTS)
@pytest.mark.parametrize("mode", ["paper", "ocp"])
def test_nan_block_marker(fmt, mode):
    """A block containing NaN gets the X=0xFF marker scale and dequantizes
    to NaN everywhere (paper: marker poisons the block; ocp: NaN scale)."""
    x = np.linspace(-4.0, 4.0, 32).astype(np.float32)
    x[5] = np.nan
    mx = mx_quantize(jnp.asarray(x), fmt=fmt, mode=mode)
    assert int(np.asarray(mx.scales).reshape(-1)[0]) == SCALE_NAN == 0xFF
    y = np.asarray(mx_dequantize(mx))
    assert np.isnan(y).all(), (fmt, mode)


@pytest.mark.parametrize("fmt", ALL_FMTS)
@pytest.mark.parametrize("mode", ["paper", "ocp"])
def test_inf_block_marker(fmt, mode):
    """±Inf (and no NaN) in a block: paper mode emits the X=0xFE marker and
    dequantizes to ±Inf with each element's own sign; ocp mode folds Inf
    into the NaN scale (the OCP spec has no Inf marker)."""
    x = np.linspace(-4.0, 4.0, 32).astype(np.float32)
    x[3] = np.inf
    x[7] = -np.inf
    mx = mx_quantize(jnp.asarray(x), fmt=fmt, mode=mode)
    scale = int(np.asarray(mx.scales).reshape(-1)[0])
    y = np.asarray(mx_dequantize(mx))
    if mode == "paper":
        assert scale == SCALE_INF == 0xFE, (fmt, hex(scale))
        assert np.isinf(y).all(), (fmt, mode)
        # element signs survive the marker codes
        assert y[3] == np.inf and y[7] == -np.inf
        assert (np.signbit(y) == np.signbit(x)).all()
    else:
        assert scale == SCALE_NAN == 0xFF, (fmt, hex(scale))
        assert np.isnan(y).all(), (fmt, mode)


@pytest.mark.parametrize("fmt", ALL_FMTS)
@pytest.mark.parametrize("mode", ["paper", "ocp"])
def test_all_zero_block(fmt, mode):
    """An all-zero block: EV_max = 0 so X clamps to 0, every element code
    is zero, and the round trip is exact."""
    x = np.zeros(32, np.float32)
    mx = mx_quantize(jnp.asarray(x), fmt=fmt, mode=mode)
    assert int(np.asarray(mx.scales).reshape(-1)[0]) == 0
    assert (np.asarray(mx.codes) == 0).all(), (fmt, mode)
    y = np.asarray(mx_dequantize(mx))
    np.testing.assert_array_equal(y, x)


def test_tree_matches_plain_max():
    rng = np.random.default_rng(0)
    e = jnp.asarray(rng.integers(0, 255, size=(17, 32), dtype=np.int32))
    np.testing.assert_array_equal(
        np.asarray(max_exponent_tree(e)), np.asarray(e).max(-1))
