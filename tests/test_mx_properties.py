"""Property-based tests (hypothesis) for the MX converter's invariants."""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="hypothesis not installed (pip install -e '.[test]')")

pytestmark = pytest.mark.property
from hypothesis import given, settings, strategies as st

from repro.core import (ALL_FORMATS, get_format, mx_dequantize, mx_quantize,
                        quantize_dequantize)

ALL_FMTS = [f.name for f in ALL_FORMATS]
FLOAT_FMTS = [f.name for f in ALL_FORMATS if not f.is_int]

# stay below 2^126: paper mode reserves scale codes 0xFE/0xFF for markers, so
# blocks whose max is in the top f32 binade saturate (pinned in
# test_int8_top_binade_saturates, excluded from the generic bound here)
_LIM = float(np.float32(8.0e37))
finite_f32 = st.floats(
    min_value=-_LIM, max_value=_LIM, allow_nan=False,
    allow_infinity=False, width=32).filter(lambda v: v == 0 or abs(v) >= 1e-35)


def test_int8_top_binade_saturates():
    """|v| >= 2^127 with the paper's 0xFD scale clamp: INT8 saturates to
    127/64 * 2^126 — documented marker-reservation corner."""
    x = jnp.asarray(np.asarray([1.7412941507288328e38] + [0.0] * 31,
                               np.float32))
    from repro.core import mx_quantize as q
    mx = q(x, fmt="int8", mode="paper")
    assert int(np.asarray(mx.scales)[0]) == 0xFD
    y = np.asarray(mx_dequantize(mx))
    assert y[0] == np.float32(127 / 64 * 2.0 ** 126)

blocks = st.lists(finite_f32, min_size=1, max_size=64)


def _q(vals, fmt, mode):
    x = jnp.asarray(np.asarray(vals, np.float32))
    mx = mx_quantize(x, fmt=fmt, mode=mode)
    return x, mx, np.asarray(mx_dequantize(mx))


@settings(max_examples=60, deadline=None)
@given(vals=blocks, fmt=st.sampled_from(ALL_FMTS),
       mode=st.sampled_from(["paper", "ocp"]))
def test_roundtrip_error_bound(vals, fmt, mode):
    """|dq(q(v)) - v| <= max|block| * 2^-R for every finite element (shared-
    scale formats: the ulp is set by the block max, not the element)."""
    x, mx, y = _q(vals, fmt, mode)
    xs = np.asarray(x)
    f = get_format(fmt)
    n = len(vals)
    for s in range(0, n, 32):
        blk = xs[s: s + 32]
        yb = y[s: s + 32]
        bmax = np.abs(blk).max()
        if bmax == 0:
            np.testing.assert_array_equal(yb, 0.0)
            continue
        # error bound: one ulp at the top binade = 2^floor(log2 bmax) * 2^-R
        binade = 2.0 ** np.floor(np.log2(bmax))
        ulp = binade * 2.0 ** (-f.mbits)
        tol = 2.0 * ulp  # ties-away keeps R+1 bits -> < 2 top-binade ulps
        if mode == "paper" and not f.is_int:
            # paper flush-to-zero: anything below the normal range (eb <= 0)
            # vanishes; largest flushable magnitude < binade * 2^(1 - 2*bias)
            tol = max(tol, binade * 2.0 ** (1 - 2 * f.bias))
        assert np.all(np.abs(yb - blk) <= tol * 1.0001), (
            fmt, mode, np.abs(yb - blk).max(), tol)


@settings(max_examples=40, deadline=None)
@given(vals=blocks, fmt=st.sampled_from(ALL_FMTS),
       mode=st.sampled_from(["paper", "ocp"]))
def test_sign_preserved(vals, fmt, mode):
    x, mx, y = _q(vals, fmt, mode)
    xs = np.asarray(x)
    nz = y != 0
    assert np.all(np.sign(y[nz]) == np.sign(xs[nz])), (fmt, mode)


@settings(max_examples=40, deadline=None)
@given(vals=blocks, fmt=st.sampled_from(ALL_FMTS),
       mode=st.sampled_from(["paper", "ocp"]))
def test_idempotent(vals, fmt, mode):
    """Quantizing an already-quantized tensor is a fixed point."""
    x, mx, y = _q(vals, fmt, mode)
    y2 = np.asarray(quantize_dequantize(jnp.asarray(y), fmt=fmt, mode=mode))
    np.testing.assert_array_equal(y, y2, err_msg=f"{fmt}/{mode}")


@settings(max_examples=40, deadline=None)
@given(vals=blocks, fmt=st.sampled_from(ALL_FMTS),
       mode=st.sampled_from(["paper", "ocp"]))
def test_scale_is_blockmax_exponent_law(vals, fmt, mode):
    x, mx, _ = _q(vals, fmt, mode)
    f = get_format(fmt)
    xs = np.asarray(x)
    scales = np.asarray(mx.scales)
    sub = f.bias if mode == "paper" else f.emax_ocp
    n = len(vals)
    for b in range(scales.shape[-1]):
        blk = xs[b * 32: (b + 1) * 32]
        if blk.size == 0 or np.abs(blk).max() == 0:
            assert scales[b] == 0
            continue
        ev = int(np.abs(blk).max().view(np.uint32) >> 23) & 0xFF
        # paper mode reserves 0xFE/0xFF for the Inf/NaN markers => clamp 0xFD
        hi = 0xFD if mode == "paper" else 0xFE
        assert scales[b] == min(max(ev - sub, 0), hi), (fmt, mode, ev)


@settings(max_examples=30, deadline=None)
@given(vals=blocks, fmt=st.sampled_from(ALL_FMTS))
def test_quantization_shrinks_or_keeps_magnitude_order(vals, fmt):
    """Monotone-ish: dequantized magnitudes never exceed max|block| * (1+2^-R)
    (saturation never amplifies beyond one ulp above the max)."""
    x, mx, y = _q(vals, fmt, "ocp")
    xs = np.abs(np.asarray(x))
    f = get_format(fmt)
    for s in range(0, len(vals), 32):
        blk, yb = xs[s:s + 32], np.abs(y[s:s + 32])
        if blk.max() == 0:
            continue
        assert yb.max() <= blk.max() * (1 + 2.0 ** (-f.mbits)) * 1.0001


@settings(max_examples=30, deadline=None)
@given(scale_exp=st.integers(min_value=-120, max_value=120),
       fmt=st.sampled_from(FLOAT_FMTS))
def test_scaling_equivariance(scale_exp, fmt):
    """q(2^k * x) == 2^k * q(x) — the format is scale-free by construction."""
    rng = np.random.default_rng(42)
    x = rng.standard_normal(32).astype(np.float32)
    k = np.float32(2.0 ** scale_exp)
    y1 = np.asarray(quantize_dequantize(jnp.asarray(x), fmt=fmt, mode="ocp"))
    y2 = np.asarray(quantize_dequantize(jnp.asarray(x * k), fmt=fmt,
                                        mode="ocp"))
    np.testing.assert_allclose(y2, y1 * k, rtol=1e-6)


@pytest.mark.parametrize("fmt", ALL_FMTS)
@pytest.mark.parametrize("mode", ["paper", "ocp"])
def test_exhaustive_code_dequant_finite(fmt, mode):
    """Every possible (code, scale) pair dequantizes to a finite value or the
    documented marker — no surprise NaNs from decode arithmetic."""
    f = get_format(fmt)
    codes = jnp.arange(1 << f.code_bits, dtype=jnp.uint8)
    from repro.core.convert import decode_elements
    vals = np.asarray(decode_elements(codes, f, mode))
    if mode == "paper" and not f.is_int:
        top = ((np.arange(1 << f.code_bits) >> f.mbits) & f.exp_mask) \
            == f.exp_mask
        assert np.all(np.isfinite(vals[~top]))
    elif fmt == "e5m2" and mode == "ocp":
        pass  # E5M2 keeps IEEE Inf/NaN space
    else:
        finite_mask = np.isfinite(vals)
        if f.e4m3_style_nan:
            assert (~finite_mask).sum() == 2  # +/- NaN codes only
        elif not f.is_int:
            assert finite_mask.all()
        else:
            assert finite_mask.all()
