"""Observability layer: metrics registry, percentiles, trace spans.

Three layers of coverage:

* **Pure unit tests** (no jax): ``percentile`` boundary semantics (the
  satellite bugfix — single sample returns itself at every q, empty
  raises ValueError not IndexError), ``rate`` zero-duration guard,
  Counter/Gauge/Histogram labeled series, registry get-or-create /
  kind-conflict / reset / merge, Tracer span lifecycle (end-mismatch
  raises, unwind, close_track), ``validate_nesting`` re-derivation, and
  the Chrome trace_event export.
* **Engine integration**: the legacy ``n_*`` counters are property
  views over the registry, so the engine's numbers and
  ``metrics.snapshot()`` must agree bit-for-bit; ``reset_metrics``
  must zero every registered series; ``obs_interval`` publishes the
  ``mx.*`` health gauges per KV role.
* **Trace lifecycle property**: a seeded fault plan served through the
  asyncio front end (one request retried to success, one driven to
  ``RetriesExhausted``) must leave every track well-formed — spans nest
  and close exactly once across quarantine/retry — with exactly one
  completed root ``request`` span per rid and the right terminal
  status.
"""
import asyncio
import json

import jax
import numpy as np
import pytest

from repro.kernels import backend
from repro.models import Model, load_reduced
from repro.models.config import QuantPolicy
from repro.obs import (Counter, Gauge, Histogram, MetricsRegistry,
                       Tracer, chrome_events, percentile, rate,
                       validate_nesting)
from repro.obs.trace import EVENT_FIELDS, TRACE_SCHEMA
from repro.serve import (AsyncServer, ContinuousBatchingEngine,
                         FaultPlan, GenerationConfig, RetriesExhausted)

PAGE = 8
NEW = 6
TIMEOUT = 180


@pytest.fixture(autouse=True)
def _clean_backend():
    backend.reset_degradation()
    yield
    backend.reset_degradation()


@pytest.fixture(scope="module")
def served():
    cfg = load_reduced("chatglm3_6b",
                       mx=QuantPolicy.parse("kv=int8@32:ocp"))
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _prompts(cfg, lens=(7, 12, 9), seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(1, cfg.vocab, size=n).astype(np.int32)
            for n in lens]


def _engine(model, params, **kw):
    kw.setdefault("max_slots", 4)
    kw.setdefault("max_len", 40)
    kw.setdefault("sync_every", 4)
    kw.setdefault("gen", GenerationConfig(max_new_tokens=NEW))
    return ContinuousBatchingEngine(model, params, page_size=PAGE, **kw)


def _run(coro):
    return asyncio.run(asyncio.wait_for(coro, TIMEOUT))


# =============================================================================
# percentile / rate: the deduplicated single implementations
# =============================================================================
def test_percentile_empty_raises_value_error():
    """The satellite bugfix: an empty sample set must raise ValueError
    with a clear message, never IndexError from ``s[-1]``."""
    with pytest.raises(ValueError, match="empty sample set"):
        percentile([], 50)


def test_percentile_single_sample_is_itself():
    for q in (0.001, 1, 50, 99, 100):
        assert percentile([42.5], q) == 42.5


def test_percentile_rejects_q_out_of_range():
    for q in (0, -1, 101):
        with pytest.raises(ValueError, match="q must be in"):
            percentile([1.0], q)


def test_percentile_nearest_rank_goldens():
    s = list(range(1, 11))           # 1..10
    assert percentile(s, 10) == 1
    assert percentile(s, 50) == 5
    assert percentile(s, 51) == 6
    assert percentile(s, 99) == 10
    assert percentile(s, 100) == 10
    # input order must not matter
    assert percentile([3, 1, 2], 50) == 2


def test_percentile_reexports_are_the_same_function():
    """The three former hand-rolled copies now resolve to one object."""
    from repro.obs.metrics import percentile as obs_p
    from repro.serve.frontend import percentile as fe_p
    assert fe_p is obs_p


def test_rate_zero_duration_guard():
    assert rate(5, 0) == 0.0
    assert rate(5, -1) == 0.0
    assert rate(10, 2) == 5.0
    from repro.launch.serve import safe_rate
    assert safe_rate is rate


# =============================================================================
# Counter / Gauge / Histogram
# =============================================================================
def test_counter_labeled_series_and_snapshot():
    c = Counter("c")
    assert c.snapshot() == 0                 # empty -> scalar zero
    c.inc(2)
    assert c.value() == 2 and c.snapshot() == 2
    c2 = Counter("c2")
    c2.inc(1, phase="prefill")
    c2.inc(0.5, phase="decode")
    c2.inc(1, phase="prefill")
    assert c2.value(phase="prefill") == 2
    assert c2.snapshot() == {"phase=decode": 0.5, "phase=prefill": 2}


def test_counter_rejects_negative_but_set_rewinds():
    c = Counter("c")
    c.inc(3)
    with pytest.raises(ValueError, match="negative increment"):
        c.inc(-1)
    c.set(1)                                 # snapshot restore path
    assert c.value() == 1


def test_counter_merge_adds():
    a, b = Counter("c"), Counter("c")
    a.inc(1, k="x")
    b.inc(2, k="x")
    b.inc(5, k="y")
    a.merge(b)
    assert a.value(k="x") == 3 and a.value(k="y") == 5


def test_gauge_set_max_and_default():
    g = Gauge("g")
    assert g.value() == 0 and g.value(default=7) == 7
    g.set_max(4)
    g.set_max(2)
    assert g.value() == 4
    g.set(1)
    assert g.value() == 1


def test_histogram_stats_and_time():
    h = Histogram("h")
    for v in (3.0, 1.0, 2.0):
        h.observe(v)
    assert h.count() == 3 and h.sum() == 6.0
    assert h.percentile(50) == 2.0
    snap = h.snapshot()
    assert snap == {"count": 3, "sum": 6.0, "min": 1.0, "max": 3.0,
                    "p50": 2.0, "p99": 3.0}
    assert Histogram("e").snapshot() == {"count": 0, "sum": 0.0}
    with h.time(op="x"):
        pass
    assert h.count(op="x") == 1 and h.values(op="x")[0] >= 0.0


# =============================================================================
# MetricsRegistry
# =============================================================================
def test_registry_get_or_create_and_kind_conflict():
    m = MetricsRegistry()
    c = m.counter("a.b", "help")
    assert m.counter("a.b") is c
    with pytest.raises(TypeError, match="already registered as counter"):
        m.gauge("a.b")
    assert m.names() == ["a.b"]


def test_registry_reset_zeroes_everything():
    m = MetricsRegistry()
    m.counter("c").inc(5, k="x")
    m.gauge("g").set(3)
    m.histogram("h").observe(1.0)
    m.reset()
    assert m.counter("c").value(k="x") == 0
    assert m.gauge("g").value() == 0
    assert m.histogram("h").count() == 0
    # metrics stay registered after reset
    assert m.names() == ["c", "g", "h"]


def test_registry_merge_and_snapshot_shape():
    a, b = MetricsRegistry(), MetricsRegistry()
    a.counter("c").inc(1)
    b.counter("c").inc(2)
    b.gauge("g").set(9)
    b.histogram("h").observe(4.0)
    a.merge(b)
    snap = a.snapshot()
    assert set(snap) == {"counters", "gauges", "histograms"}
    assert snap["counters"]["c"] == 3
    assert snap["gauges"]["g"] == 9
    assert snap["histograms"]["h"]["count"] == 1
    json.dumps(snap)                         # JSON-serializable throughout


# =============================================================================
# Tracer: span lifecycle, export, nesting validation
# =============================================================================
def test_tracer_span_lifecycle_and_mismatch():
    tr = Tracer()
    tr.begin("request", cat="request", rid=0)
    tr.begin("queued", cat="request", rid=0)
    assert tr.open_spans(0) == ["request", "queued"]
    assert tr.top(0) == "queued"
    with pytest.raises(ValueError, match="innermost open span"):
        tr.end("request", rid=0)
    assert tr.open_spans(0) == ["request", "queued"]   # stack intact
    tr.end("queued", rid=0)
    tr.end("request", rid=0)
    with pytest.raises(ValueError, match="no open span"):
        tr.end("request", rid=0)
    assert tr.open_tracks() == []
    roots = validate_nesting(tr.events)
    assert roots == {0: ["request"]}


def test_tracer_end_clamps_to_begin_time():
    """An E stamped before its B (clock jitter at us resolution) clamps
    to the begin time, keeping the track clock monotone."""
    tr = Tracer()
    t = tr.t0 + 1.0
    tr.begin("s", ts=t)
    tr.end("s", ts=t - 0.5)
    b, e = tr.events
    assert e["t_us"] == b["t_us"] == 1_000_000
    validate_nesting(tr.events)


def test_tracer_unwind_and_close_track():
    tr = Tracer()
    tr.begin("request", rid=3)
    tr.begin("queued", rid=3)
    tr.begin("inner", rid=3)
    assert tr.unwind(3, keep=1) == 2
    assert tr.open_spans(3) == ["request"]
    tr.close_track(3, status="failed")
    assert tr.open_tracks() == []
    last = tr.events[-1]
    assert last["ph"] == "E" and last["name"] == "request"
    assert last["args"] == {"status": "failed"}
    validate_nesting(tr.events)


def test_tracer_event_schema_and_determinism():
    tr = Tracer(meta={"seed": 7})
    tr.begin("a", ts=tr.t0)
    tr.instant("mark", ts=tr.t0, k=1)
    tr.end("a", ts=tr.t0)
    assert tr.header() == {"schema": TRACE_SCHEMA, "meta": {"seed": 7}}
    for i, ev in enumerate(tr.events):
        assert ev["seq"] == i                # dense, emission-ordered
        assert set(ev) - {"args"} == set(EVENT_FIELDS)
    # the same operations replayed on a fresh tracer yield the same
    # events modulo nothing (timestamps pinned to t0 here)
    tr2 = Tracer(meta={"seed": 7})
    tr2.begin("a", ts=tr2.t0)
    tr2.instant("mark", ts=tr2.t0, k=1)
    tr2.end("a", ts=tr2.t0)
    assert tr2.events == tr.events


def test_tracer_write_jsonl_roundtrip(tmp_path):
    tr = Tracer(meta={"arch": "t"})
    tr.begin("request", cat="request", rid=1, ts=tr.t0)
    tr.end("request", rid=1, ts=tr.t0)
    p = tmp_path / "t.jsonl"
    tr.write_jsonl(p)
    lines = [json.loads(x) for x in p.read_text().splitlines()]
    assert lines[0] == {"schema": "trace/v1", "meta": {"arch": "t"}}
    assert lines[1:] == tr.events
    validate_nesting(lines[1:])


def test_chrome_export_maps_tracks_to_threads(tmp_path):
    tr = Tracer()
    tr.span("decode_window", t0=tr.t0, t1=tr.t0, steps=4)
    tr.begin("request", cat="request", rid=2, ts=tr.t0)
    tr.instant("admitted", cat="request", rid=2, ts=tr.t0)
    tr.end("request", rid=2, ts=tr.t0)
    evs = chrome_events(tr.events)
    meta = [e for e in evs if e["ph"] == "M"]
    assert {m["tid"]: m["args"]["name"] for m in meta} == {
        0: "engine", 3: "request 2"}
    inst = [e for e in evs if e["ph"] == "i"]
    assert inst[0]["s"] == "t" and inst[0]["tid"] == 3
    assert all(e["pid"] == 1 for e in evs)
    p = tmp_path / "t.json"
    tr.write_chrome(p)
    doc = json.loads(p.read_text())
    assert doc["traceEvents"] == evs


def test_validate_nesting_rejects_malformed_streams():
    def ev(seq, ph, name, rid, t):
        return {"seq": seq, "ph": ph, "name": name, "cat": "x",
                "rid": rid, "t_us": t}
    with pytest.raises(ValueError, match="does not close"):
        validate_nesting([ev(0, "B", "a", 0, 0), ev(1, "E", "b", 0, 1)])
    with pytest.raises(ValueError, match="clock moved backwards"):
        validate_nesting([ev(0, "I", "a", 0, 5), ev(1, "I", "b", 0, 1)])
    with pytest.raises(ValueError, match="tracks left open"):
        validate_nesting([ev(0, "B", "a", 0, 0)])
    # independent tracks do not interleave-break each other
    roots = validate_nesting([
        ev(0, "B", "a", 0, 0), ev(1, "B", "b", 1, 0),
        ev(2, "E", "a", 0, 2), ev(3, "E", "b", 1, 3)])
    assert roots == {0: ["a"], 1: ["b"]}


# =============================================================================
# Engine integration: counters == registry snapshot, reset, mx gauges
# =============================================================================
def test_engine_counters_equal_registry_snapshot(served):
    cfg, model, params = served
    eng = _engine(model, params, obs_interval=2)
    for p in _prompts(cfg):
        eng.add_request(p, NEW)
    out = eng.run()
    assert sum(len(v) for v in out.values()) == 3 * NEW
    snap = eng.metrics.snapshot()
    c = snap["counters"]
    assert c["engine.steps"] == eng.n_steps > 0
    assert c["engine.syncs"] == eng.n_syncs > 0
    assert c["engine.generated_tokens"] == eng.n_generated == 3 * NEW
    assert c["engine.prefill_tokens"] == eng.prefill_tokens_computed \
        == 7 + 12 + 9
    assert c["engine.cow_forks"] == eng.n_cow_forks
    assert c["engine.preemptions"] == eng.n_preemptions == 0
    assert c["engine.quarantined"] == eng.n_quarantined == 0
    assert c["engine.phase_s"] == {
        f"phase={k}": v for k, v in eng.phase.items()}
    g = snap["gauges"]
    assert g["pages.peak_mapped"] == eng.peak_mapped_pages > 0
    assert g["pages.peak_shared"] == eng.peak_shared_pages
    assert snap["histograms"]["engine.window_steps"]["count"] \
        == eng.n_syncs
    # obs_interval=2 sampled the MX health gauges per KV role
    for name in ("mx.scale_bytes", "mx.poison_markers",
                 "mx.saturation_rate", "mx.clip_rate",
                 "mx.underflow_rate"):
        assert set(g[name]) == {"role=kv_key", "role=kv_value"}, name
    assert g["mx.poison_markers"]["role=kv_key"] == 0


def test_engine_reset_metrics_zeroes_registry(served):
    cfg, model, params = served
    eng = _engine(model, params)
    for p in _prompts(cfg):
        eng.add_request(p, NEW)
    eng.run()
    assert eng.n_steps > 0
    eng.reset_metrics()
    assert eng.n_steps == eng.n_syncs == eng.n_generated == 0
    assert eng.phase == {"prefill": 0.0, "decode": 0.0,
                         "sync": 0.0, "swap": 0.0}
    assert eng.swap_store.bytes_out == eng.swap_store.bytes_in == 0
    snap = eng.metrics.snapshot()
    assert snap["counters"]["engine.steps"] == 0
    assert snap["histograms"]["engine.window_steps"]["count"] == 0
    assert eng.finished_in_window == []


# =============================================================================
# Trace lifecycle property: spans close exactly once across faults
# =============================================================================
def test_trace_lifecycle_under_faults_and_retry(served):
    """One request quarantined once and retried to success, one poisoned
    on every attempt until RetriesExhausted — every track must stay
    well-formed (validate_nesting raises otherwise) and finish with
    exactly one completed root ``request`` span carrying the right
    terminal status."""
    cfg, model, params = served
    plan = FaultPlan.parse("prefill_nan:rid=1,prefill_nan:rid=2:always",
                           seed=3)
    tracer = Tracer(meta={"plan": str(plan.faults)})
    eng = _engine(model, params, faults=plan, tracer=tracer,
                  metrics=MetricsRegistry())
    prompts = _prompts(cfg)

    async def go():
        async with AsyncServer(eng, retries=1,
                               retry_backoff_s=0.01) as srv:
            streams = [await srv.submit(p, NEW) for p in prompts]
            res = await asyncio.gather(
                *(s.tokens() for s in streams), return_exceptions=True)
            return srv, streams, res

    srv, streams, res = _run(go())
    assert isinstance(res[2], RetriesExhausted)      # rid 2: exhausted
    assert streams[1].request.n_retries == 1         # rid 1: retried ok
    assert len(res[0]) == len(res[1]) == NEW

    eng.finalize_trace()
    roots = validate_nesting(tracer.events)
    # every request track completes exactly one root "request" span
    for rid in (0, 1, 2):
        assert roots[rid] == ["request"], rid

    def terminal(rid):
        ends = [e for e in tracer.events
                if e["rid"] == rid and e["ph"] == "E"
                and e["name"] == "request"]
        assert len(ends) == 1
        return (ends[0].get("args") or {}).get("status")

    assert terminal(0) == "finished"
    assert terminal(1) == "finished"
    assert terminal(2) == "failed"

    names = {(e["rid"], e["name"], e["ph"]) for e in tracer.events}
    assert (1, "quarantine", "I") in names
    assert (1, "retry", "I") in names
    assert (1, "prefill", "B") in names
    assert (0, "decode", "B") in names
    assert (None, "prefill_batch", "B") in names
    assert (None, "decode_window", "B") in names
    assert (None, "fault:stall", "I") not in names

    # finalize_trace is idempotent
    n = len(tracer.events)
    eng.finalize_trace()
    assert len(tracer.events) == n

    # engine counters agree with what the trace recorded
    assert eng.n_quarantined == eng.metrics.counter(
        "engine.quarantined").value() == 3   # rid1 once + rid2 twice
    snap = srv.obs_snapshot()
    assert set(snap) == {"server", "engine", "latency"}
    assert snap["server"]["counters"]["server.retried"] \
        == srv.n_retried == 2                # rid1 + rid2 first retry
    assert snap["server"]["counters"]["server.failed"] \
        == srv.n_failed == 1
    assert snap["engine"] == eng.metrics.snapshot()
    assert snap["latency"]["n_requests"] == 2.0
    assert snap["latency"]["ttft_p99_ms"] > 0


def test_trace_preempt_restore_spans(served):
    """Preempt-and-swap leaves well-formed tracks: the preempted
    request re-queues (preempt instant + fresh queued span), its
    restore is a span on its own track, and it still completes exactly
    one root request span."""
    cfg, model, params = served
    tracer = Tracer()
    eng = _engine(model, params, max_slots=2, preempt=True,
                  tracer=tracer)
    rng = np.random.default_rng(3)
    # low-priority victim mid-generation, then two high-priority
    # arrivals oversubscribe the 2 slots -> deterministic swap-out
    victim = eng.add_request(
        rng.integers(1, cfg.vocab, size=9).astype(np.int32), 12,
        priority=5)
    eng.step()
    others = [eng.add_request(
        rng.integers(1, cfg.vocab, size=17).astype(np.int32), 6,
        priority=0) for _ in range(2)]
    out = eng.run()
    assert eng.n_preemptions >= 1 and eng.n_restores >= 1
    assert len(out[victim]) == 12
    eng.finalize_trace()
    roots = validate_nesting(tracer.events)
    for rid in (victim, *others):
        assert roots[rid] == ["request"], rid
    engine_spans = {(e["name"], e["ph"]) for e in tracer.events
                    if e["rid"] is None}
    assert ("swap_out", "B") in engine_spans
    assert ("swap_restore", "B") in engine_spans
    victim_evs = {(e["name"], e["ph"]) for e in tracer.events
                  if e["rid"] == victim}
    assert ("preempt", "I") in victim_evs
    assert ("restore", "B") in victim_evs
    # the victim re-queued: two completed queued spans on its track
    queued = [e for e in tracer.events
              if e["rid"] == victim and e["name"] == "queued"
              and e["ph"] == "B"]
    assert len(queued) >= 2
