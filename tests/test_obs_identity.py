"""Token-identity regression: observability must be a pure observer.

Tracing reuses the host-sync perf_counter stamps the engine already
takes and the MX-health sampler only *reads* the pool, so turning on
the full observability stack — registry metrics, per-request trace
spans, and per-window health sampling (``obs_interval=1``, the most
aggressive setting) — must not perturb a single sampled token.  Run
the same seeded workload with observability off and fully on, across
every element format and the mixed per-role policy, and require the
streams array-equal.
"""
import jax
import numpy as np
import pytest

from repro.kernels import backend
from repro.models import Model, load_reduced
from repro.models.config import QuantPolicy
from repro.obs import MetricsRegistry, Tracer, validate_nesting
from repro.serve import ContinuousBatchingEngine, GenerationConfig

PAGE = 8
NEW = 4
LENS = (5, 9, 6)

POLICIES = [
    "kv=int8@32:ocp",
    "kv=e4m3@32:ocp",
    "kv=e5m2@32:ocp",
    "kv=e3m2@32:ocp",
    "kv=e2m3@32:ocp",
    "kv=e2m1@32:ocp",
    "kv_key=int8@32:paper,kv_value=e4m3@32:paper",
]


@pytest.fixture(autouse=True)
def _clean_backend():
    backend.reset_degradation()
    yield
    backend.reset_degradation()


def _serve(model, cfg, params, *, traced: bool):
    obs = {}
    if traced:
        obs = dict(metrics=MetricsRegistry(), tracer=Tracer(),
                   obs_interval=1)
    eng = ContinuousBatchingEngine(
        model, params, max_slots=2, page_size=PAGE,
        max_len=max(LENS) + NEW + 1,
        gen=GenerationConfig(max_new_tokens=NEW), sync_every=2, **obs)
    rng = np.random.default_rng(11)
    for n in LENS:
        eng.add_request(
            rng.integers(1, cfg.vocab, size=n).astype(np.int32), NEW)
    out = eng.run()
    return eng, out


@pytest.mark.parametrize("policy", POLICIES)
def test_tokens_identical_with_observability_on(policy):
    cfg = load_reduced("chatglm3_6b", mx=QuantPolicy.parse(policy))
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    _, want = _serve(model, cfg, params, traced=False)
    eng, out = _serve(model, cfg, params, traced=True)

    assert sorted(out) == sorted(want)
    for rid in want:
        np.testing.assert_array_equal(out[rid], want[rid])

    # the traced run really observed: spans well-formed, one completed
    # root per request, per-window health gauges published per role
    eng.finalize_trace()
    roots = validate_nesting(eng.tracer.events)
    for rid in out:
        assert roots[rid] == ["request"], rid
    snap = eng.metrics.snapshot()
    assert snap["counters"]["engine.generated_tokens"] \
        == len(LENS) * NEW
    sat = snap["gauges"]["mx.saturation_rate"]
    assert set(sat) == {"role=kv_key", "role=kv_value"}
