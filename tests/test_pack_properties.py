"""Property-based tests (hypothesis) for repro.core.pack: bit-packed storage
of sub-byte MX element codes must be a lossless trailing-axis transform."""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="hypothesis not installed (pip install -e '.[test]')")

pytestmark = pytest.mark.property
from hypothesis import given, settings, strategies as st

from repro.core.formats import ALL_FORMATS, get_format
from repro.core.pack import pack_codes, packed_nbytes, unpack_codes

ALL_FMTS = [f.name for f in ALL_FORMATS]
# trailing lengths aligned per bit width: 4-bit needs %2, 6-bit needs %4
ALIGN = {4: 2, 6: 4, 8: 1}


def _aligned(fmt: str, n: int) -> int:
    a = ALIGN[get_format(fmt).code_bits]
    return -(-n // a) * a


@st.composite
def codes_and_fmt(draw):
    fmt = draw(st.sampled_from(ALL_FMTS))
    f = get_format(fmt)
    lead = draw(st.sampled_from([(), (3,), (2, 5)]))
    n = _aligned(fmt, draw(st.integers(min_value=1, max_value=96)))
    bits = draw(st.integers(0, 2 ** 32 - 1))
    rng = np.random.default_rng(bits)
    codes = rng.integers(0, 1 << f.code_bits,
                         size=lead + (n,)).astype(np.uint8)
    return fmt, codes


@given(codes_and_fmt())
@settings(max_examples=60, deadline=None)
def test_roundtrip_is_identity(args):
    """unpack(pack(x)) == x on the trailing axis for every format."""
    fmt, codes = args
    packed = pack_codes(jnp.asarray(codes), fmt)
    assert packed.shape[:-1] == codes.shape[:-1]
    assert packed.shape[-1] == packed_nbytes(fmt, codes.shape[-1])
    out = unpack_codes(packed, fmt, codes.shape[-1])
    np.testing.assert_array_equal(np.asarray(out), codes)


@pytest.mark.parametrize("fmt", ALL_FMTS)
def test_adversarial_bit_patterns(fmt):
    """All-zeros, all-ones (full code width), and alternating min/max codes
    survive the roundtrip — the patterns most likely to smear across byte
    boundaries in the 6-bit 4->3 layout."""
    f = get_format(fmt)
    top = (1 << f.code_bits) - 1
    n = _aligned(fmt, 24)
    pats = [np.zeros(n, np.uint8),
            np.full(n, top, np.uint8),
            np.asarray([0, top] * (n // 2), np.uint8),
            np.asarray([top, 1] * (n // 2), np.uint8)]
    for pat in pats:
        out = unpack_codes(pack_codes(jnp.asarray(pat), fmt), fmt, n)
        np.testing.assert_array_equal(np.asarray(out), pat)


@given(st.integers(min_value=1, max_value=128),
       st.sampled_from(ALL_FMTS))
@settings(max_examples=60, deadline=None)
def test_nonaligned_pad_then_pack(n, fmt):
    """Non-aligned trailing lengths, padded the way mx_quantize pads (zeros
    to the alignment), roundtrip to the padded identity and the original
    prefix — the kernel-facing contract for ragged head dims."""
    f = get_format(fmt)
    rng = np.random.default_rng(n)
    codes = rng.integers(0, 1 << f.code_bits, size=n).astype(np.uint8)
    na = _aligned(fmt, n)
    padded = np.pad(codes, (0, na - n))
    out = np.asarray(unpack_codes(pack_codes(jnp.asarray(padded), fmt),
                                  fmt, na))
    np.testing.assert_array_equal(out, padded)
    np.testing.assert_array_equal(out[:n], codes)


@pytest.mark.parametrize("fmt", ALL_FMTS)
def test_packed_nbytes_ratio(fmt):
    """Packed bytes per code reflect the format's bit width (the HBM win
    the page pool banks on): 4-bit -> 1/2, 6-bit -> 3/4, 8-bit -> 1."""
    f = get_format(fmt)
    n = 96
    ratio = packed_nbytes(fmt, n) / n
    assert ratio == {4: 0.5, 6: 0.75, 8: 1.0}[f.code_bits]
