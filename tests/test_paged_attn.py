"""Differential oracle tests for the paged MX decode-attention kernel.

Three implementations must agree on identical requests:
  * ``mx_paged_decode_attention``  — Pallas, block-table gather at the
    HBM->VMEM boundary, bit-packed sub-byte codes;
  * ``mx_decode_attention``        — the existing contiguous Pallas kernel;
  * ``kernels.ref``                — pure-JAX dense-softmax references.

Paged vs contiguous is asserted *bit-identical* (same dequant + online
softmax arithmetic, only the page gather differs); vs the dense-softmax
reference we allow float round-off.  All six formats x both modes.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import mx_quantize
from repro.core.formats import ALL_FORMATS
from repro.core.pack import pack_codes, packed_nbytes
from repro.kernels.mx_decode_attn import (mx_decode_attention,
                                          mx_paged_decode_attention)
from repro.kernels.ref import (mx_decode_attention_ref,
                               mx_paged_decode_attention_ref)

B, S, HQ, HKV, D, PAGE = 2, 64, 4, 2, 32, 16
NPG = S // PAGE


def _quantized_kv(fmt, mode, seed=0):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(B, 1, HQ, D)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(B, S, HKV, D)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(B, S, HKV, D)).astype(np.float32))
    mk = mx_quantize(k, fmt=fmt, mode=mode, axis=-1)
    mv = mx_quantize(v, fmt=fmt, mode=mode, axis=-1)
    return q, mk, mv


def _paged_layout(mk, mv, fmt, seed=0):
    """Scatter the contiguous cache into a page pool with a shuffled
    physical page order; page 0 is the (zeroed) trash page."""
    rng = np.random.default_rng(seed + 100)
    pk = np.asarray(pack_codes(mk.codes, fmt))
    pv = np.asarray(pack_codes(mv.codes, fmt))
    ks, vs = np.asarray(mk.scales), np.asarray(mv.scales)
    cb = packed_nbytes(fmt, D)
    n_pool = B * NPG + 1
    perm = rng.permutation(np.arange(1, n_pool))
    bt = np.zeros((B, NPG), np.int32)
    kc_pool = np.zeros((n_pool, PAGE, HKV, cb), np.uint8)
    vc_pool = np.zeros_like(kc_pool)
    ks_pool = np.zeros((n_pool, PAGE, HKV, D // 32), np.uint8)
    vs_pool = np.zeros_like(ks_pool)
    for i, (b, j) in enumerate((b, j) for b in range(B)
                               for j in range(NPG)):
        pg = int(perm[i])
        bt[b, j] = pg
        sl = slice(j * PAGE, (j + 1) * PAGE)
        kc_pool[pg], vc_pool[pg] = pk[b, sl], pv[b, sl]
        ks_pool[pg], vs_pool[pg] = ks[b, sl], vs[b, sl]
    return tuple(jnp.asarray(a) for a in
                 (kc_pool, ks_pool, vc_pool, vs_pool, bt))


@pytest.mark.parametrize("mode", ["paper", "ocp"])
@pytest.mark.parametrize("fmt", [f.name for f in ALL_FORMATS])
def test_paged_matches_contiguous_and_ref(fmt, mode):
    """Same tokens in, same attention out — paged vs contiguous vs pure-JAX
    reference, all six formats, both modes."""
    q, mk, mv = _quantized_kv(fmt, mode)
    pools = _paged_layout(mk, mv, fmt)
    pos = 50
    lengths = jnp.full((B,), pos, jnp.int32)
    out_c = mx_decode_attention(q, mk.codes, mk.scales, mv.codes, mv.scales,
                                jnp.asarray(pos, jnp.int32), fmt=fmt,
                                mode=mode, rep=HQ // HKV, blk_k=PAGE)
    out_p = mx_paged_decode_attention(q, *pools, lengths, fmt=fmt,
                                      mode=mode, rep=HQ // HKV)
    # identical dequant + online-softmax arithmetic => bit-identical
    np.testing.assert_array_equal(np.asarray(out_p), np.asarray(out_c))
    ref_c = mx_decode_attention_ref(q, mk.codes, mk.scales, mv.codes,
                                    mv.scales, lengths, fmt=fmt, mode=mode,
                                    rep=HQ // HKV)
    ref_p = mx_paged_decode_attention_ref(q, *pools, lengths, fmt=fmt,
                                          mode=mode, rep=HQ // HKV)
    np.testing.assert_array_equal(np.asarray(ref_p), np.asarray(ref_c))
    np.testing.assert_allclose(np.asarray(out_p), np.asarray(ref_p),
                               rtol=2e-5, atol=2e-5)


def test_paged_mixed_lengths():
    """Per-slot lengths: each slot must see exactly its own prefix."""
    fmt, mode = "int8", "ocp"
    q, mk, mv = _quantized_kv(fmt, mode, seed=3)
    pools = _paged_layout(mk, mv, fmt, seed=3)
    lengths = jnp.asarray([13, 57], jnp.int32)
    out = mx_paged_decode_attention(q, *pools, lengths, fmt=fmt, mode=mode,
                                    rep=HQ // HKV)
    ref = mx_paged_decode_attention_ref(q, *pools, lengths, fmt=fmt,
                                        mode=mode, rep=HQ // HKV)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-5,
                               atol=2e-5)
    # slot 0 must agree with the contiguous kernel at its own pos
    out_c = mx_decode_attention(q, mk.codes, mk.scales, mv.codes, mv.scales,
                                jnp.asarray(13, jnp.int32), fmt=fmt,
                                mode=mode, rep=HQ // HKV, blk_k=PAGE)
    np.testing.assert_array_equal(np.asarray(out[0]), np.asarray(out_c[0]))


def test_paged_trash_page_rows_are_inert():
    """A slot with length 0 and a zeroed block-table row attends only to
    position 0 of the trash page — finite output, no NaN leakage from
    whatever the trash page holds."""
    fmt, mode = "e4m3", "ocp"
    q, mk, mv = _quantized_kv(fmt, mode, seed=4)
    kc, ks, vc, vs, bt = _paged_layout(mk, mv, fmt, seed=4)
    bt = bt.at[1, :].set(0)                   # slot 1 -> trash page
    lengths = jnp.asarray([50, 0], jnp.int32)
    out = mx_paged_decode_attention(q, kc, ks, vc, vs, bt, lengths,
                                    fmt=fmt, mode=mode, rep=HQ // HKV)
    assert np.isfinite(np.asarray(out)).all()
    # slot 0 is unaffected by slot 1's row
    out_c = mx_decode_attention(q, mk.codes, mk.scales, mv.codes, mv.scales,
                                jnp.asarray(50, jnp.int32), fmt=fmt,
                                mode=mode, rep=HQ // HKV, blk_k=PAGE)
    np.testing.assert_array_equal(np.asarray(out[0]), np.asarray(out_c[0]))


# =============================================================================
# mixed per-role specs (INT8 keys / E2M1 values)
# =============================================================================
def test_paged_mixed_role_specs_match_contiguous_and_ref():
    """K and V pools in different formats: the paged kernel, the contiguous
    kernel and the dense-softmax reference must agree on the same tokens."""
    from repro.core import QuantSpec

    key_spec = QuantSpec("int8", "ocp")
    value_spec = QuantSpec("e2m1", "ocp")
    rng = np.random.default_rng(11)
    q = jnp.asarray(rng.normal(size=(B, 1, HQ, D)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(B, S, HKV, D)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(B, S, HKV, D)).astype(np.float32))
    mk = mx_quantize(k, key_spec, axis=-1)
    mv = mx_quantize(v, value_spec, axis=-1)
    pos = 50
    lengths = jnp.full((B,), pos, jnp.int32)
    out_c = mx_decode_attention(q, mk.codes, mk.scales, mv.codes, mv.scales,
                                jnp.asarray(pos, jnp.int32),
                                key_spec=key_spec, value_spec=value_spec,
                                rep=HQ // HKV, blk_k=PAGE)
    ref_c = mx_decode_attention_ref(q, mk.codes, mk.scales, mv.codes,
                                    mv.scales, lengths, key_spec=key_spec,
                                    value_spec=value_spec, rep=HQ // HKV)
    np.testing.assert_allclose(np.asarray(out_c), np.asarray(ref_c),
                               rtol=2e-5, atol=2e-5)
    # paged layout: per-role storage bytes (packed E2M1 half the bytes)
    pk = np.asarray(pack_codes(mk.codes, key_spec.fmt))
    pv = np.asarray(pack_codes(mv.codes, value_spec.fmt))
    assert pv.shape[-1] * 2 == pk.shape[-1] == packed_nbytes("int8", D)
    ks_np, vs_np = np.asarray(mk.scales), np.asarray(mv.scales)
    n_pool = B * NPG + 1
    perm = np.random.default_rng(12).permutation(np.arange(1, n_pool))
    bt = np.zeros((B, NPG), np.int32)
    kc_pool = np.zeros((n_pool, PAGE, HKV, pk.shape[-1]), np.uint8)
    vc_pool = np.zeros((n_pool, PAGE, HKV, pv.shape[-1]), np.uint8)
    ks_pool = np.zeros((n_pool, PAGE, HKV, D // 32), np.uint8)
    vs_pool = np.zeros_like(ks_pool)
    for i, (b, j) in enumerate((b, j) for b in range(B)
                               for j in range(NPG)):
        pg = int(perm[i])
        bt[b, j] = pg
        sl = slice(j * PAGE, (j + 1) * PAGE)
        kc_pool[pg], vc_pool[pg] = pk[b, sl], pv[b, sl]
        ks_pool[pg], vs_pool[pg] = ks_np[b, sl], vs_np[b, sl]
    pools = tuple(jnp.asarray(a) for a in
                  (kc_pool, ks_pool, vc_pool, vs_pool, bt))
    out_p = mx_paged_decode_attention(q, *pools, lengths,
                                      key_spec=key_spec,
                                      value_spec=value_spec, rep=HQ // HKV)
    # same dequant + online-softmax arithmetic => bit-identical to the
    # contiguous kernel even with mixed per-role formats
    np.testing.assert_array_equal(np.asarray(out_p), np.asarray(out_c))
    ref_p = mx_paged_decode_attention_ref(q, *pools, lengths,
                                          key_spec=key_spec,
                                          value_spec=value_spec,
                                          rep=HQ // HKV)
    np.testing.assert_allclose(np.asarray(out_p), np.asarray(ref_p),
                               rtol=2e-5, atol=2e-5)
