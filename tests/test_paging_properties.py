"""Property-based tests (hypothesis) for the refcounted page allocator.

A random interleaving of admissions, shared mappings, copy-on-write forks,
pins, releases and preemption swap-outs must never violate the
BlockManager invariants its docstring promises:

* every non-trash page is on the free list xor has refcount > 0;
* per page, ``table_refs`` equals the number of block-table entries
  mapping it and ``pins`` the number of outstanding ``pin`` calls;
* ``free_pages + live_pages == num_pages - 1`` (page 0 is the trash page,
  never allocated, never pinned, never freed);
* ``version`` bumps exactly when ``tables`` mutates (allocate /
  map_shared / fork_page / release of a non-empty row) and never on
  pin/unpin.

``ModelChecker`` keeps an independent model of every slot row and pin
count and cross-checks the manager's public accounting after each
operation.  The hypothesis ``RuleBasedStateMachine`` drives it with
shrinkable random programs (CI runs it under ``-m property`` with a fixed
seed); a seeded random-walk fallback drives the same checker where
hypothesis isn't installed.  Error-path unit tests (fork of a private
entry, pin of a dead page, map of the trash page) close out the file.
"""
import collections
import os

import numpy as np
import pytest

from repro.serve.paging import TRASH_PAGE, BlockManager, pages_needed

NUM_PAGES = 12
PAGE = 4
MAX_SLOTS = 4
MAX_PPS = 6


class ModelChecker:
    """Independent model of the allocator: `rows` mirrors each slot's
    (page, shared) entries, `pins` the external pin counts, `version`
    the expected table-mutation counter.  Every op_* both applies the
    operation and asserts the manager agreed with the model about its
    outcome; `check()` asserts the global invariants."""

    def __init__(self):
        self.bm = BlockManager(NUM_PAGES, PAGE, MAX_SLOTS, MAX_PPS)
        self.rows = [[] for _ in range(MAX_SLOTS)]
        self.pins = collections.Counter()
        self.version = 0

    # -------------------------------------------------------- model views
    def table_refs(self):
        return collections.Counter(p for row in self.rows for p, _ in row)

    def refcounts(self):
        refs = self.table_refs()
        for p, c in self.pins.items():
            refs[p] += c
        return +refs

    def live_set(self):
        return set(self.refcounts())

    def shared_entries(self):
        return [(s, i) for s, row in enumerate(self.rows)
                for i, (_, sh) in enumerate(row) if sh]

    # -------------------------------------------------------- operations
    def op_allocate(self, slot, n):
        live_before = self.live_set()
        ok = self.bm.allocate(slot, n)
        assert ok == (len(self.rows[slot]) + n <= MAX_PPS
                      and n <= NUM_PAGES - 1 - len(live_before))
        if ok and n:
            self.version += 1
            fresh = self.bm.slot_page_ids(slot)[len(self.rows[slot]):]
            assert len(fresh) == n
            for pg in fresh:
                # freshly allocated pages must come off the free list
                assert pg != TRASH_PAGE and pg not in live_before
                self.rows[slot].append((pg, False))

    def op_map_shared(self, slot, pages):
        assert all(pg in self.live_set() for pg in pages)
        ok = self.bm.map_shared(slot, pages)
        assert ok == (len(self.rows[slot]) + len(pages) <= MAX_PPS)
        if ok and pages:
            self.version += 1
            self.rows[slot].extend((pg, True) for pg in pages)

    def op_fork_page(self, slot, idx):
        assert self.rows[slot][idx][1]
        live_before = self.live_set()
        pool_empty = self.bm.free_pages == 0
        got = self.bm.fork_page(slot, idx)
        if pool_empty:
            assert got is None           # exhausted pool: nothing changed
            return
        src, dst = got
        self.version += 1
        assert src == self.rows[slot][idx][0]
        assert dst != TRASH_PAGE and dst not in live_before
        self.rows[slot][idx] = (dst, False)

    def op_ensure(self, slot, tokens):
        need = pages_needed(tokens, PAGE) - len(self.rows[slot])
        live_before = self.live_set()
        ok = self.bm.ensure(slot, tokens)
        if need <= 0:
            assert ok                    # already covered: no-op
            return
        assert ok == (len(self.rows[slot]) + need <= MAX_PPS
                      and need <= NUM_PAGES - 1 - len(live_before))
        if ok:
            self.version += 1
            fresh = self.bm.slot_page_ids(slot)[len(self.rows[slot]):]
            for pg in fresh:
                assert pg not in live_before
                self.rows[slot].append((pg, False))

    def op_pin(self, pg):
        assert pg in self.live_set()
        v = self.bm.version
        self.bm.pin(pg)                  # never raises on a live page
        assert self.bm.version == v      # and never bumps version
        self.pins[pg] += 1

    def op_unpin(self, pg):
        assert self.pins[pg] > 0
        v = self.bm.version
        self.bm.unpin(pg)
        assert self.bm.version == v
        self.pins[pg] -= 1

    def op_release(self, slot):
        if self.rows[slot]:
            self.version += 1
        self.bm.release(slot)
        self.rows[slot] = []

    def op_swap_out(self, slot):
        # snapshot-and-release: the returned (page, shared) rows must
        # mirror the logical row exactly, then the slot empties like a
        # release — shared/pinned pages stay live for their other owners
        expect = list(self.rows[slot])
        if self.rows[slot]:
            self.version += 1
        got = self.bm.swap_out(slot)
        assert got == expect
        self.rows[slot] = []

    # -------------------------------------------------------- invariants
    def check(self):
        refs = self.refcounts()
        for pg in range(1, NUM_PAGES):
            assert self.bm.page_refcount(pg) == refs.get(pg, 0)
        live = self.live_set()
        assert self.bm.live_pages == len(live)
        assert self.bm.free_pages + self.bm.live_pages == NUM_PAGES - 1
        trefs = self.table_refs()
        assert self.bm.mapped_pages == len(trefs)
        assert self.bm.shared_pages == sum(
            1 for c in trefs.values() if c >= 2)
        assert self.bm.page_refcount(TRASH_PAGE) == 0
        assert self.bm.version == self.version
        for slot, row in enumerate(self.rows):
            ids = [p for p, _ in row]
            assert self.bm.slot_page_ids(slot) == ids
            assert self.bm.slot_pages(slot) == len(ids)
            assert self.bm.slot_capacity(slot) == len(ids) * PAGE
            assert list(self.bm.tables[slot, :len(ids)]) == ids
            # beyond the allocation the row points at the trash page
            assert (self.bm.tables[slot, len(ids):] == TRASH_PAGE).all()
            assert TRASH_PAGE not in ids
            shared_idx = [i for i, (_, sh) in enumerate(row) if sh]
            assert [i for i in range(len(ids))
                    if self.bm.is_shared_entry(slot, i)] == shared_idx
            assert self.bm.slot_shared_pages(slot) == len(shared_idx)
            assert self.bm.cow_targets(slot, 0, len(ids) * PAGE) \
                == shared_idx


# =============================================================================
# hypothesis state machine (CI: -m property, fixed seed, more examples)
# =============================================================================
try:
    from hypothesis import settings
    from hypothesis import strategies as st
    from hypothesis.stateful import (RuleBasedStateMachine, initialize,
                                     invariant, precondition, rule)
    HAVE_HYPOTHESIS = True
except ImportError:          # container without test extras: the seeded
    HAVE_HYPOTHESIS = False  # random walk below still drives ModelChecker

if HAVE_HYPOTHESIS:

    class PagingMachine(RuleBasedStateMachine):

        @initialize()
        def setup(self):
            self.m = ModelChecker()

        @rule(slot=st.integers(0, MAX_SLOTS - 1), n=st.integers(0, 4))
        def allocate(self, slot, n):
            self.m.op_allocate(slot, n)

        @precondition(lambda self: self.m.live_set())
        @rule(slot=st.integers(0, MAX_SLOTS - 1), data=st.data())
        def map_shared(self, slot, data):
            live = sorted(self.m.live_set())
            pages = data.draw(st.lists(st.sampled_from(live), max_size=3))
            self.m.op_map_shared(slot, pages)

        @precondition(lambda self: self.m.shared_entries())
        @rule(data=st.data())
        def fork_page(self, data):
            slot, idx = data.draw(st.sampled_from(self.m.shared_entries()))
            self.m.op_fork_page(slot, idx)

        @rule(slot=st.integers(0, MAX_SLOTS - 1),
              tokens=st.integers(0, MAX_PPS * PAGE))
        def ensure(self, slot, tokens):
            self.m.op_ensure(slot, tokens)

        @precondition(lambda self: self.m.live_set())
        @rule(data=st.data())
        def pin(self, data):
            self.m.op_pin(data.draw(st.sampled_from(
                sorted(self.m.live_set()))))

        @precondition(lambda self: +self.m.pins)
        @rule(data=st.data())
        def unpin(self, data):
            self.m.op_unpin(data.draw(st.sampled_from(
                sorted((+self.m.pins).keys()))))

        @rule(slot=st.integers(0, MAX_SLOTS - 1))
        def release(self, slot):
            self.m.op_release(slot)

        @rule(slot=st.integers(0, MAX_SLOTS - 1))
        def swap_out(self, slot):
            self.m.op_swap_out(slot)

        @invariant()
        def invariants_hold(self):
            if hasattr(self, "m"):
                self.m.check()

    PagingMachine.TestCase.settings = settings(
        max_examples=int(os.environ.get("HYPOTHESIS_MAX_EXAMPLES", "30")),
        stateful_step_count=40, deadline=None)
    TestPagingMachine = PagingMachine.TestCase
    TestPagingMachine.pytestmark = [pytest.mark.property]


# =============================================================================
# seeded random walk over the same checker (runs without hypothesis)
# =============================================================================
@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_random_walk_invariants(seed):
    rng = np.random.default_rng(seed)
    m = ModelChecker()
    for _ in range(300):
        op = rng.integers(0, 8)
        slot = int(rng.integers(0, MAX_SLOTS))
        if op == 0:
            m.op_allocate(slot, int(rng.integers(0, 5)))
        elif op == 1 and m.live_set():
            live = sorted(m.live_set())
            k = int(rng.integers(0, 4))
            m.op_map_shared(slot, [live[rng.integers(0, len(live))]
                                   for _ in range(k)])
        elif op == 2 and m.shared_entries():
            ents = m.shared_entries()
            m.op_fork_page(*ents[rng.integers(0, len(ents))])
        elif op == 3:
            m.op_ensure(slot, int(rng.integers(0, MAX_PPS * PAGE + 1)))
        elif op == 4 and m.live_set():
            live = sorted(m.live_set())
            m.op_pin(live[rng.integers(0, len(live))])
        elif op == 5 and +m.pins:
            pinned = sorted((+m.pins).keys())
            m.op_unpin(pinned[rng.integers(0, len(pinned))])
        elif op == 6:
            m.op_release(slot)
        elif op == 7:
            m.op_swap_out(slot)
        m.check()


# =============================================================================
# error paths and edge semantics
# =============================================================================
def _bm(num_pages=8, page=4, slots=2, pps=4):
    return BlockManager(num_pages, page, slots, pps)


def test_map_shared_rejects_trash_and_dead_pages():
    bm = _bm()
    with pytest.raises(ValueError, match="trash"):
        bm.map_shared(0, [TRASH_PAGE])
    with pytest.raises(ValueError, match="dead"):
        bm.map_shared(0, [3])            # never allocated -> refcount 0
    assert bm.version == 0               # failed maps change nothing


def test_map_shared_row_overflow_maps_nothing():
    bm = _bm(pps=2)
    assert bm.allocate(0, 2)
    pg = bm.slot_page_ids(0)[0]
    assert not bm.map_shared(1, [pg, pg, pg])
    assert bm.slot_pages(1) == 0
    assert bm.page_refcount(pg) == 1     # no partial refcount leak


def test_fork_private_entry_raises():
    bm = _bm()
    assert bm.allocate(0, 1)
    with pytest.raises(ValueError, match="already private"):
        bm.fork_page(0, 0)


def test_fork_exhausted_pool_returns_none():
    bm = _bm(num_pages=3, pps=4)         # 2 usable pages
    assert bm.allocate(0, 2)
    src = bm.slot_page_ids(0)[0]
    assert bm.map_shared(1, [src])
    assert bm.fork_page(1, 0) is None    # nothing free to copy into
    assert bm.page_refcount(src) == 2    # shared mapping intact


def test_fork_frees_last_reference():
    bm = _bm()
    assert bm.allocate(0, 1)
    src = bm.slot_page_ids(0)[0]
    assert bm.map_shared(1, [src])
    bm.release(0)
    assert bm.page_refcount(src) == 1    # slot 1's shared mapping holds it
    free_before = bm.free_pages
    out = bm.fork_page(1, 0)
    assert out is not None and out[0] == src
    # the fork drops the last reference: src returns to the free list
    assert bm.free_pages == free_before  # -1 for dst, +1 for freed src
    assert bm.page_refcount(src) == 0


def test_pin_requires_live_page_and_survives_release():
    bm = _bm()
    with pytest.raises(ValueError, match="not pinnable"):
        bm.pin(TRASH_PAGE)
    with pytest.raises(ValueError, match="dead"):
        bm.pin(2)
    assert bm.allocate(0, 1)
    pg = bm.slot_page_ids(0)[0]
    v = bm.version
    bm.pin(pg)
    assert bm.version == v               # pin never bumps version
    bm.release(0)
    assert bm.page_refcount(pg) == 1     # pin outlives the slot
    bm.unpin(pg)
    assert bm.page_refcount(pg) == 0     # last unpin frees
    with pytest.raises(ValueError, match="no pins"):
        bm.unpin(pg)


def test_release_keeps_shared_pages_live():
    bm = _bm()
    assert bm.allocate(0, 2)
    ids = bm.slot_page_ids(0)
    assert bm.map_shared(1, ids)
    bm.release(0)
    assert all(bm.page_refcount(p) == 1 for p in ids)
    assert bm.slot_page_ids(1) == ids    # reader unaffected by the release
    bm.release(1)
    assert bm.free_pages == 7            # now everything is back
