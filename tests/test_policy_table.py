"""PolicyTable / QuantPolicy JSON round-trips, precise parse errors, and
the config-side plumbing (`apply_policy_table`, uniform collapse)."""
import pytest

from repro.core import PolicyTable, QuantPolicy, QuantSpec
from repro.models import apply_policy_table, load_reduced

KV8 = "kv=int8@32:ocp"
KV4 = "kv=e2m1@32:ocp"


# =============================================================================
# construction + accessors
# =============================================================================
def test_table_construction_and_lookup():
    t = PolicyTable(KV8, {1: KV4})
    assert t.layer(0) == QuantPolicy.parse(KV8)
    assert t.layer(1) == QuantPolicy.parse(KV4)
    assert t.layer(7) == t.default
    assert t.spec("kv_key", 1) == QuantSpec("e2m1", "ocp", 32)
    assert t.spec("kv_key", 0).fmt == "int8"
    assert not t.is_uniform
    assert t.collapse() is t


def test_table_uniform_collapse():
    t = PolicyTable(KV8, {0: KV8, 3: KV8})
    assert t.is_uniform
    assert t.collapse() == QuantPolicy.parse(KV8)
    assert PolicyTable(KV8).collapse() == QuantPolicy.parse(KV8)


def test_table_is_hashable_and_ordered():
    a = PolicyTable(KV8, {2: KV4, 1: KV4})
    b = PolicyTable(KV8, ((1, QuantPolicy.parse(KV4)),
                          (2, QuantPolicy.parse(KV4))))
    assert a == b and hash(a) == hash(b)
    assert [i for i, _ in a.overrides] == [1, 2]


def test_table_construction_errors():
    with pytest.raises(ValueError, match="non-negative"):
        PolicyTable(KV8, {-1: KV4})
    with pytest.raises(ValueError, match="twice"):
        PolicyTable(KV8, ((1, QuantPolicy.parse(KV4)),
                          (1, QuantPolicy.parse(KV8))))
    with pytest.raises(TypeError, match="QuantPolicy"):
        PolicyTable(KV8, {0: 42})
    with pytest.raises(TypeError, match="QuantPolicy"):
        PolicyTable(default=3.14)


# =============================================================================
# JSON round-trip + precise errors
# =============================================================================
def test_policy_json_roundtrip():
    p = QuantPolicy.parse("kv_key=int8@32:ocp,kv_value=e2m1@32:ocp,"
                          "weights=e4m3@16:paper+unpacked")
    assert QuantPolicy.from_json_dict(p.to_json_dict()) == p
    assert QuantPolicy.from_json_dict({}) == QuantPolicy()


def test_policy_json_errors_name_role_and_spec():
    with pytest.raises(ValueError, match=r"role 'kv_key'.*'e9m9@32'"):
        QuantPolicy.from_json_dict({"kv_key": "e9m9@32",
                                    "kv_value": "int8"})
    with pytest.raises(ValueError, match="unknown tensor role 'zz'"):
        QuantPolicy.from_json_dict({"zz": "int8"})
    with pytest.raises(ValueError, match="spec string"):
        QuantPolicy.from_json_dict({"kv_key": 8, "kv_value": "int8"})
    with pytest.raises(ValueError, match="kv_key and kv_value"):
        QuantPolicy.from_json_dict({"kv_key": "int8"})


def test_table_json_roundtrip():
    t = PolicyTable(KV8, {1: KV4, 3: "kv_key=e4m3@32:ocp,"
                                     "kv_value=e2m1@32:ocp"})
    assert PolicyTable.from_json(t.to_json()) == t
    # dict form round-trips too
    assert PolicyTable.from_json_dict(t.to_json_dict()) == t


def test_table_json_errors_name_layer_role_spec():
    doc = ('{"schema": "policy_table/v1", "default": {"kv_key": "int8", '
           '"kv_value": "int8"}, "layers": {"2": {"kv_key": "e9m9", '
           '"kv_value": "int8"}}}')
    with pytest.raises(ValueError,
                       match=r"layer 2.*role 'kv_key'.*'e9m9'"):
        PolicyTable.from_json(doc)
    with pytest.raises(ValueError, match="bad layer index 'x'"):
        PolicyTable.from_json_dict(
            {"schema": "policy_table/v1", "layers": {"x": {}}})
    with pytest.raises(ValueError, match="schema"):
        PolicyTable.from_json_dict({"schema": "policy_table/v9"})
    with pytest.raises(ValueError, match="unknown field"):
        PolicyTable.from_json_dict(
            {"schema": "policy_table/v1", "extra": 1})
    with pytest.raises(ValueError, match="invalid JSON"):
        PolicyTable.from_json("{nope")


# =============================================================================
# apply_policy_table
# =============================================================================
def test_apply_collapses_uniform_to_plain_policy():
    cfg = load_reduced("chatglm3_6b")
    t = PolicyTable(KV8, {0: KV8, 1: KV8})
    out = apply_policy_table(cfg, t)
    assert out.mx_table is None
    # bit-identical config to the uniform QuantPolicy it collapses to
    assert out == load_reduced("chatglm3_6b",
                               mx=QuantPolicy.parse(KV8))


def test_apply_non_uniform_sets_table_and_layer_policies():
    cfg = load_reduced("chatglm3_6b")
    out = apply_policy_table(cfg, PolicyTable(KV8, {1: KV4}))
    assert out.per_layer_mx
    assert out.mx == QuantPolicy.parse(KV8)        # mirrors the default
    assert out.layer_policy(0).kv_key.fmt == "int8"
    assert out.layer_policy(1).kv_key.fmt == "e2m1"
    assert out.layer_cfg(1).mx_table is None
    assert out.layer_cfg(1).mx == QuantPolicy.parse(KV4)


def test_apply_rejects_out_of_range_layers_and_non_decoder():
    cfg = load_reduced("chatglm3_6b")
    with pytest.raises(ValueError, match=r"layer\(s\) \[9\]"):
        apply_policy_table(cfg, PolicyTable(KV8, {9: KV4}))
    rwkv = load_reduced("rwkv6_7b")
    with pytest.raises(NotImplementedError, match="decoder"):
        apply_policy_table(rwkv, PolicyTable(KV8, {1: KV4}))
    # uniform tables are fine on any family (they collapse)
    assert apply_policy_table(rwkv, PolicyTable(KV8)).mx_table is None
