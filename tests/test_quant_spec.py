"""Unit tests for the unified QuantSpec/QuantPolicy API: grammar
round-trips, precise parse errors, MXArray.from_spec validation, and the
deprecation shims (old fmt=/mode=/block= call forms must produce identical
arrays and emit exactly one DeprecationWarning)."""
import dataclasses
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (ALL_FORMATS, MXArray, QuantPolicy, QuantSpec,
                        get_format, mx_quantize, quantize_dequantize)
from repro.core.spec import (ROLES, as_spec, reset_deprecation_warnings,
                             resolve_spec)
from repro.kernels.mx_quant import mx_quantize_2d
from repro.kernels.ops import mx_quantize_pallas, quantize_weight


def _rand(shape=(4, 64), seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=shape).astype(np.float32))


# =============================================================================
# QuantSpec grammar
# =============================================================================
@pytest.mark.parametrize("fmt", [f.name for f in ALL_FORMATS])
@pytest.mark.parametrize("mode", ["paper", "ocp"])
def test_spec_str_parse_roundtrip(fmt, mode):
    for packed in (True, False):
        s = QuantSpec(fmt, mode, 32, packed)
        assert QuantSpec.parse(str(s)) == s


def test_spec_parse_defaults_and_none():
    s = QuantSpec.parse("e4m3")
    assert (s.fmt, s.mode, s.block, s.packed) == ("e4m3", "ocp", 32, True)
    assert QuantSpec.parse("int8@16").block == 16
    assert QuantSpec.parse("e2m1:paper").mode == "paper"
    assert QuantSpec.parse("e2m1@32:ocp+unpacked").packed is False
    for tok in ("none", "off", "fp", " NONE "):
        assert QuantSpec.parse(tok) is None


def test_spec_parse_precise_errors():
    with pytest.raises(ValueError, match="unknown MX format"):
        QuantSpec.parse("e9m9")
    with pytest.raises(ValueError, match="e4m3"):   # lists the valid names
        QuantSpec.parse("float8")
    with pytest.raises(ValueError, match="block must be a positive"):
        QuantSpec.parse("e4m3@zero")
    with pytest.raises(ValueError, match="block must be a positive"):
        QuantSpec.parse("e4m3@0")
    with pytest.raises(ValueError, match="choose from"):
        QuantSpec.parse("e4m3@32:fast")
    with pytest.raises(ValueError, match="flags"):
        QuantSpec.parse("e4m3+zipped")
    with pytest.raises(ValueError, match="empty"):
        QuantSpec.parse("   ")


def test_spec_constructor_validates():
    with pytest.raises(ValueError, match="unknown MX format"):
        QuantSpec("nope")
    with pytest.raises(ValueError, match="mode"):
        QuantSpec("e4m3", "fast")
    with pytest.raises(ValueError, match="block"):
        QuantSpec("e4m3", "ocp", 0)
    # name normalization through the registry
    assert QuantSpec("E4M3").fmt == "e4m3"


def test_spec_is_hashable_and_jit_static():
    s1, s2 = QuantSpec("int8", "ocp"), QuantSpec("int8", "ocp")
    assert hash(s1) == hash(s2) and s1 == s2

    @jax.jit
    def roundtrip(x):
        return quantize_dequantize(x, s1, axis=-1)

    np.testing.assert_allclose(np.asarray(roundtrip(_rand())),
                               np.asarray(quantize_dequantize(
                                   _rand(), s2)), rtol=0, atol=0)


def test_get_format_error_lists_valid_names():
    with pytest.raises(ValueError) as ei:
        get_format("fp8")
    msg = str(ei.value)
    for f in ALL_FORMATS:
        assert f.name in msg


def test_storage_nbytes_per_packing():
    assert QuantSpec("int8").storage_nbytes(64) == 64
    assert QuantSpec("e2m1").storage_nbytes(64) == 32
    assert QuantSpec("e3m2").storage_nbytes(64) == 48
    assert QuantSpec("e2m1", packed=False).storage_nbytes(64) == 64


# =============================================================================
# QuantPolicy
# =============================================================================
def test_policy_parse_roles_and_roundtrip():
    p = QuantPolicy.parse(
        "kv_key=int8@32:ocp,kv_value=e2m1@32:ocp,grads=e4m3")
    assert p.kv_key.fmt == "int8" and p.kv_value.fmt == "e2m1"
    assert p.grads == QuantSpec("e4m3", "ocp", 32)
    assert p.weights is None and p.activations is None
    assert QuantPolicy.parse(str(p)) == p
    assert str(QuantPolicy()) == "none"
    assert QuantPolicy.parse("none") == QuantPolicy()


def test_policy_kv_shorthand_and_str_coercion():
    p = QuantPolicy.parse("kv=e4m3@32:paper")
    assert p.kv_key == p.kv_value == QuantSpec("e4m3", "paper")
    # constructor coerces spec strings per role
    q = QuantPolicy(kv_key="int8", kv_value="int8")
    assert q.kv_key == QuantSpec("int8", "ocp")


def test_policy_parse_errors():
    with pytest.raises(ValueError, match="unknown tensor role"):
        QuantPolicy.parse("cache=int8")
    with pytest.raises(ValueError, match="role=spec"):
        QuantPolicy.parse("int8")
    with pytest.raises(ValueError, match="twice"):
        QuantPolicy.parse("kv=int8,kv_key=e4m3")
    with pytest.raises(ValueError, match="kv_key and kv_value"):
        QuantPolicy(kv_key=QuantSpec("int8"))
    with pytest.raises(ValueError, match="unknown tensor role"):
        QuantPolicy().role("caches")
    assert [QuantPolicy().role(r) for r in ROLES] == [None] * len(ROLES)


def test_mx_policy_shim_maps_and_warns_once():
    from repro.models.config import MXPolicy
    reset_deprecation_warnings()
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        p = MXPolicy(fmt="e5m2", mode="paper", weights=True, kv_cache=True,
                     kv_fmt="int8", grads=True, grad_fmt="e4m3")
        MXPolicy()          # second call: no second warning
    dep = [w for w in rec if issubclass(w.category, DeprecationWarning)]
    assert len(dep) == 1
    assert p.weights == QuantSpec("e5m2", "paper")
    assert p.kv_key == p.kv_value == QuantSpec("int8", "paper")
    assert p.grads == QuantSpec("e4m3", "paper")
    assert isinstance(p, QuantPolicy)


# =============================================================================
# MXArray.from_spec validation
# =============================================================================
def test_from_spec_accepts_consistent_and_sets_fields():
    mx = mx_quantize(_rand(), QuantSpec("e4m3", "ocp"))
    rebuilt = MXArray.from_spec(mx.codes, mx.scales, mx.spec,
                                orig_len=mx.orig_len, axis=mx.axis)
    assert rebuilt.fmt == "e4m3" and rebuilt.mode == "ocp" \
        and rebuilt.block == 32
    # MXArray codes are stored one byte per element, so .spec reports the
    # unpacked layout (storage_nbytes matches the container)
    assert rebuilt.spec == QuantSpec("e4m3", "ocp", packed=False)
    assert rebuilt.spec.storage_nbytes(64) == 64


def test_from_spec_rejects_none_spec():
    mx = mx_quantize(_rand(), QuantSpec("e4m3", "ocp"))
    with pytest.raises(ValueError, match="concrete"):
        MXArray.from_spec(mx.codes, mx.scales, "none")
    with pytest.raises(TypeError):
        MXArray.from_spec(mx.codes, mx.scales, None)


def test_from_spec_rejects_inconsistent():
    mx = mx_quantize(_rand(), QuantSpec("e4m3", "ocp"))
    with pytest.raises(ValueError, match="multiple of"):
        MXArray.from_spec(mx.codes[..., :33], mx.scales, mx.spec)
    with pytest.raises(ValueError, match="scales shape"):
        MXArray.from_spec(mx.codes, mx.scales[..., :1], mx.spec)
    with pytest.raises(ValueError, match="orig_len"):
        MXArray.from_spec(mx.codes, mx.scales, mx.spec, orig_len=5)
    with pytest.raises(ValueError, match="unknown MX format"):
        MXArray.from_spec(mx.codes, mx.scales,
                          dataclasses.replace(mx.spec))  # sanity: valid
        MXArray.from_spec(mx.codes, mx.scales, "e9m9")


# =============================================================================
# deprecation shims: identical arrays + exactly one warning
# =============================================================================
def _one_warning(fn):
    reset_deprecation_warnings()
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        out = fn()
        fn()                      # repeated call must not warn again
    dep = [w for w in rec if issubclass(w.category, DeprecationWarning)]
    assert len(dep) == 1, [str(w.message) for w in rec]
    return out


@pytest.mark.parametrize("mode", ["paper", "ocp"])
def test_mx_quantize_shim_identical(mode):
    x = _rand()
    new = mx_quantize(x, QuantSpec("e3m2", mode, 32))
    old = _one_warning(lambda: mx_quantize(x, fmt="e3m2", mode=mode,
                                           block=32))
    np.testing.assert_array_equal(np.asarray(new.codes),
                                  np.asarray(old.codes))
    np.testing.assert_array_equal(np.asarray(new.scales),
                                  np.asarray(old.scales))


def test_quantize_dequantize_shim_identical():
    x = _rand(seed=3)
    new = quantize_dequantize(x, QuantSpec("e5m2", "ocp"))
    old = _one_warning(lambda: quantize_dequantize(x, fmt="e5m2",
                                                   mode="ocp"))
    np.testing.assert_array_equal(np.asarray(new), np.asarray(old))


def test_legacy_positional_fmt_string_warns():
    x = _rand(seed=4)
    new = mx_quantize(x, QuantSpec("e4m3", "ocp"))
    old = _one_warning(lambda: mx_quantize(x, "e4m3", "ocp"))
    np.testing.assert_array_equal(np.asarray(new.codes),
                                  np.asarray(old.codes))


def test_ops_wrapper_shims_identical():
    x = _rand(seed=5)
    new = mx_quantize_pallas(x, QuantSpec("e2m3", "paper"))
    old = _one_warning(lambda: mx_quantize_pallas(x, fmt="e2m3",
                                                  mode="paper"))
    np.testing.assert_array_equal(np.asarray(new.codes),
                                  np.asarray(old.codes))
    w = _rand((64, 8), seed=6)
    new_w = quantize_weight(w, QuantSpec("e4m3", "ocp"))
    old_w = _one_warning(lambda: quantize_weight(w, fmt="e4m3",
                                                 mode="ocp"))
    np.testing.assert_array_equal(np.asarray(new_w.codes),
                                  np.asarray(old_w.codes))


def test_kernel_2d_shim_identical():
    x = _rand(seed=7)
    cn, sn = mx_quantize_2d(x, QuantSpec("int8", "ocp"))
    co, so = _one_warning(lambda: mx_quantize_2d(x, fmt="int8",
                                                 mode="ocp"))
    np.testing.assert_array_equal(np.asarray(cn), np.asarray(co))
    np.testing.assert_array_equal(np.asarray(sn), np.asarray(so))


def test_resolve_spec_conflicts_and_as_spec():
    with pytest.raises(TypeError, match="not both"):
        resolve_spec(QuantSpec("e4m3"), fmt="e4m3")
    with pytest.raises(TypeError, match="twice"):
        resolve_spec("e4m3", fmt="e5m2")
    with pytest.raises(TypeError):
        resolve_spec(123)
    assert as_spec("e4m3@32:paper") == QuantSpec("e4m3", "paper")
    with pytest.raises(ValueError, match="concrete"):
        as_spec("none")
    with pytest.raises(TypeError):
        as_spec(None)


def test_decode_kernels_reject_non32_blocks():
    """The decode-attention kernels' scale layout is hardwired to 32-wide
    blocks; other blocks must raise, not silently mis-dequantize."""
    from repro.kernels.mx_decode_attn import (mx_decode_attention,
                                              mx_paged_decode_attention)
    from repro.kernels.ref import mx_decode_attention_ref

    b, s, h, d = 1, 32, 1, 32
    x = _rand((b, s, h, d), seed=9)
    q = _rand((b, 1, h, d), seed=10)
    bad = QuantSpec("int8", "ocp", 16)
    mk = mx_quantize(x, bad, axis=-1)
    for fn in (mx_decode_attention, mx_decode_attention_ref):
        with pytest.raises(ValueError, match="block=32"):
            fn(q, mk.codes, mk.scales, mk.codes, mk.scales,
               jnp.asarray(3, jnp.int32), key_spec=bad, value_spec=bad)
    with pytest.raises(ValueError, match="block=32"):
        mx_paged_decode_attention(
            q, mk.codes, mk.scales, mk.codes, mk.scales,
            jnp.zeros((b, 2), jnp.int32), jnp.zeros((b,), jnp.int32),
            key_spec=bad, value_spec=bad)


def test_moe_applies_activations_role():
    """The activations role fake-quantizes MoE expert matmul inputs too."""
    from repro.models import layers as L
    from repro.models.config import ModelConfig

    base = dict(name="t", family="decoder", n_layers=1, d_model=32,
                n_heads=2, n_kv_heads=2, d_ff=64, vocab=128, n_experts=4,
                moe_topk=2, moe_d_ff=32, dtype="float32",
                param_dtype="float32")
    cfg_fp = ModelConfig(**base)
    cfg_act = ModelConfig(
        **base, mx=QuantPolicy.parse("activations=e2m1@32:ocp"))
    p = L.moe_init(jax.random.PRNGKey(0), cfg_fp)
    x = _rand((2, 8, 32), seed=11)
    out_fp, _ = L.moe(p, x, cfg_fp, fake_quant=True)
    out_q, _ = L.moe(p, x, cfg_act, fake_quant=True)
    out_q2, _ = L.moe(p, x, cfg_act, fake_quant=False)  # gated off
    assert np.isfinite(np.asarray(out_q)).all()
    assert not np.allclose(np.asarray(out_fp), np.asarray(out_q))
    np.testing.assert_array_equal(np.asarray(out_fp), np.asarray(out_q2))
