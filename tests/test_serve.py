"""Serving tests: MX KV-cache error bounds; engine greedy decode matches a
step-by-step full-forward reference."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import Model, load_reduced, make_concrete_batch
from repro.models.config import MXPolicy
from repro.serve import GenerationConfig, ServeEngine

B, S = 2, 24


def test_engine_matches_full_forward_greedy():
    cfg = load_reduced("chatglm3_6b")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = make_concrete_batch(cfg, B, S)
    batch.pop("labels")
    eng = ServeEngine(model, params, max_len=S + 8)
    out = eng.generate(batch, GenerationConfig(max_new_tokens=6))
    assert out.shape == (B, 6)
    # reference: re-run full forward each step (no cache) and compare tokens
    toks = batch["tokens"]
    ref = []
    cur = toks
    for _ in range(6):
        logits, _ = model.forward(params, {"tokens": cur})
        nxt = np.asarray(jnp.argmax(logits[:, -1, :cfg.vocab], -1),
                         np.int32)
        ref.append(nxt)
        cur = jnp.concatenate([cur, jnp.asarray(nxt)[:, None]], axis=1)
    ref = np.stack(ref, 1)
    agree = (out == ref).mean()
    assert agree >= 0.9, (out, ref)


@pytest.mark.parametrize("kv_fmt", ["int8", "e4m3", "e5m2"])
def test_mx_kv_cache_decode_error_bounded(kv_fmt):
    """Decode with an MX-quantized KV cache stays close to the bf16-cache
    decode (logit correlation)."""
    cfg_fp = load_reduced("yi_34b")
    mx = MXPolicy(fmt="e4m3", mode="ocp", kv_cache=True, kv_fmt=kv_fmt)
    cfg_mx = load_reduced("yi_34b", mx=mx)
    model_fp, model_mx = Model(cfg_fp), Model(cfg_mx)
    params = model_fp.init(jax.random.PRNGKey(1))
    batch = make_concrete_batch(cfg_fp, B, S)
    toks = batch["tokens"]
    pre = {"tokens": toks[:, :-1]}
    _, cache_fp, pos = model_fp.prefill(params, pre, max_len=S)
    _, cache_mx, _ = model_mx.prefill(params, pre, max_len=S)
    lf, _ = model_fp.decode_step(params, toks[:, -1], cache_fp, pos)
    lm, _ = model_mx.decode_step(params, toks[:, -1], cache_mx, pos)
    a = np.asarray(lf, np.float32).ravel()
    b = np.asarray(lm, np.float32).ravel()
    cc = np.corrcoef(a, b)[0, 1]
    assert cc > 0.99, (kv_fmt, cc)
    assert np.isfinite(b).all()


def test_mx_kv_cache_mla():
    """deepseek-v2's compressed MLA cache can itself be MX-quantized."""
    mx = MXPolicy(fmt="e4m3", mode="ocp", kv_cache=True, kv_fmt="int8")
    cfg_fp = load_reduced("deepseek_v2_236b", capacity_factor=64.0)
    cfg_mx = load_reduced("deepseek_v2_236b", capacity_factor=64.0, mx=mx)
    model_fp, model_mx = Model(cfg_fp), Model(cfg_mx)
    params = model_fp.init(jax.random.PRNGKey(2))
    batch = make_concrete_batch(cfg_fp, B, S)
    toks = batch["tokens"]
    pre = {"tokens": toks[:, :-1]}
    _, cache_fp, pos = model_fp.prefill(params, pre, max_len=S)
    _, cache_mx, _ = model_mx.prefill(params, pre, max_len=S)
    lf, _ = model_fp.decode_step(params, toks[:, -1], cache_fp, pos)
    lm, _ = model_mx.decode_step(params, toks[:, -1], cache_mx, pos)
    cc = np.corrcoef(np.asarray(lf, np.float32).ravel(),
                     np.asarray(lm, np.float32).ravel())[0, 1]
    assert cc > 0.98, cc


def test_kv_cache_bytes_accounting():
    """MX-INT8 KV cache is ~2x smaller than bf16 (u8 codes + u8/32 scales)."""
    mx = MXPolicy(kv_cache=True, kv_fmt="int8")
    cfg = load_reduced("yi_34b", mx=mx)
    model = Model(cfg)
    cache_mx = jax.eval_shape(lambda: model.init_cache(4, 128))
    cfg2 = load_reduced("yi_34b")
    cache_fp = jax.eval_shape(lambda: Model(cfg2).init_cache(4, 128))
    nb = lambda c: sum(np.prod(l.shape) * l.dtype.itemsize
                       for l in jax.tree_util.tree_leaves(c))
    ratio = nb(cache_fp) / nb(cache_mx)
    assert 1.8 < ratio < 2.1, ratio
