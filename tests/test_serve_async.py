"""Asyncio serving front end: token identity, admission control, traffic
generators, latency stamps, and the warmup-excision metrics reset.

The load-bearing guarantee mirrors PR 2-7's: routing requests through
``AsyncServer`` (pending queue, step loop, per-request streams) changes
*when* work is applied, never *what* is computed — the streamed tokens
are identical to driving the same ``ContinuousBatchingEngine``
synchronously.  Every async test is wrapped in ``asyncio.wait_for`` so a
deadlocked loop fails the suite instead of hanging it (CI runs this file
as its own tier-1 job under a timeout).
"""
import asyncio
import json

import jax
import numpy as np
import pytest

from repro.launch.serve import parse_arrival, safe_rate
from repro.models import Model, load_reduced
from repro.models.config import QuantPolicy
from repro.serve import (AsyncServer, ContinuousBatchingEngine,
                         GenerationConfig, RejectedError, latency_summary,
                         on_off_times, percentile, poisson_times, replay,
                         save_trace, synthesize, load_trace, Arrival,
                         TrafficClass)

LENS = [4, 9, 14, 9, 4]
NEW = 6
PAGE = 8
TIMEOUT = 180.0      # generous: CI containers compile jit closures cold


def _run(coro):
    return asyncio.run(asyncio.wait_for(coro, timeout=TIMEOUT))


@pytest.fixture(scope="module")
def setup():
    cfg = load_reduced("chatglm3_6b", mx=QuantPolicy.parse("kv=int8@32:ocp"))
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab, size=n).astype(np.int32)
               for n in LENS]
    return cfg, model, params, prompts


def _engine(model, params, *, max_slots=2, prefix_cache=False,
            new=NEW, max_len=None, num_pages=None):
    return ContinuousBatchingEngine(
        model, params, max_slots=max_slots, page_size=PAGE,
        max_len=max_len or (max(LENS) + new + 1), num_pages=num_pages,
        gen=GenerationConfig(max_new_tokens=new), sync_every=4,
        prefix_cache=prefix_cache)


# =============================================================================
# token identity: async front end == synchronous engine
# =============================================================================
def test_async_streams_match_sync_engine(setup):
    """The same prompts in the same order produce the same rids and the
    same tokens whether submitted through AsyncServer or added directly."""
    cfg, model, params, prompts = setup
    sync_eng = _engine(model, params)
    rids = [sync_eng.add_request(p, NEW) for p in prompts]
    want = sync_eng.run()

    async def go():
        eng = _engine(model, params)
        async with AsyncServer(eng) as srv:
            streams = [await srv.submit(p, NEW) for p in prompts]
            toks = [await s.tokens() for s in streams]
        assert srv.n_accepted == len(prompts) and srv.n_rejected == 0
        return [s.rid for s in streams], toks

    got_rids, got = _run(go())
    assert got_rids == rids
    for rid, toks in zip(rids, got):
        np.testing.assert_array_equal(toks, want[rid])


def test_async_executor_steps_match(setup):
    """use_executor=True moves each step to a worker thread; the pending
    queue still serializes scheduler writes, so tokens are unchanged."""
    cfg, model, params, prompts = setup
    sync_eng = _engine(model, params)
    rids = [sync_eng.add_request(p, NEW) for p in prompts]
    want = sync_eng.run()

    async def go():
        eng = _engine(model, params)
        async with AsyncServer(eng, use_executor=True) as srv:
            streams = [await srv.submit(p, NEW) for p in prompts]
            return [await s.tokens() for s in streams]

    for rid, toks in zip(rids, _run(go())):
        np.testing.assert_array_equal(toks, want[rid])


def test_async_iteration_streams_incrementally(setup):
    """``async for`` over a stream yields every generated token in order
    (the queue carries (token, final) pairs; final closes the stream)."""
    cfg, model, params, prompts = setup

    async def go():
        eng = _engine(model, params)
        async with AsyncServer(eng) as srv:
            stream = await srv.submit(prompts[0], NEW)
            seen = [tok async for tok in stream]
            rest = await stream.tokens()
        return seen, rest

    seen, rest = _run(go())
    assert len(seen) == NEW
    np.testing.assert_array_equal(np.asarray(seen, np.int32), rest)


# =============================================================================
# admission control
# =============================================================================
def test_block_admission_bounds_backlog(setup):
    """admission='block': submit awaits until the backlog (pending +
    scheduler waiting) is below max_queued — sampled continuously while
    8 submitters race a 1-slot engine, it never exceeds the bound."""
    cfg, model, params, prompts = setup
    peak = 0

    async def go():
        nonlocal peak
        eng = _engine(model, params, max_slots=1)
        async with AsyncServer(eng, max_queued=2) as srv:
            async def one(p):
                s = await srv.submit(p, NEW)
                return await s.tokens()

            tasks = [asyncio.ensure_future(one(prompts[i % len(prompts)]))
                     for i in range(8)]
            while not all(t.done() for t in tasks):
                peak = max(peak, srv._backlog())
                await asyncio.sleep(0)
            return await asyncio.gather(*tasks)

    outs = _run(go())
    assert len(outs) == 8 and all(len(o) == NEW for o in outs)
    assert peak <= 2


def test_reject_admission_raises_when_full(setup):
    """admission='reject': a request that cannot start immediately (the
    single slot is busy) raises RejectedError instead of queueing —
    the reject-on-full baseline of the bench's traffic claim."""
    cfg, model, params, prompts = setup

    async def go():
        eng = _engine(model, params, max_slots=1, new=16,
                      max_len=max(LENS) + 17)
        async with AsyncServer(eng, admission="reject") as srv:
            first = await srv.submit(prompts[0], 16)
            with pytest.raises(RejectedError):
                await srv.submit(prompts[1], 16)
            toks = await first.tokens()
        return srv.n_accepted, srv.n_rejected, toks

    acc, rej, toks = _run(go())
    assert (acc, rej) == (1, 1)
    assert len(toks) == 16


def test_submit_on_stopped_server_raises(setup):
    cfg, model, params, prompts = setup

    async def go():
        eng = _engine(model, params)
        srv = AsyncServer(eng)
        with pytest.raises(RuntimeError, match="not running"):
            await srv.submit(prompts[0], NEW)

    _run(go())


def test_async_server_validates_args(setup):
    cfg, model, params, _ = setup
    eng = _engine(model, params)
    with pytest.raises(ValueError, match="admission"):
        AsyncServer(eng, admission="drop")
    with pytest.raises(ValueError, match="max_queued"):
        AsyncServer(eng, max_queued=0)


# =============================================================================
# latency stamps + percentile plumbing
# =============================================================================
def test_latency_stamps_recorded(setup):
    """Every finished request carries arrival/first-token/per-token/finish
    stamps: monotone, one stamp per generated token, TTFT/ITL derivable."""
    cfg, model, params, prompts = setup

    async def go():
        eng = _engine(model, params)
        async with AsyncServer(eng) as srv:
            streams = [await srv.submit(p, NEW, deadline_s=30.0)
                       for p in prompts]
            for s in streams:
                await s.tokens()
        return eng

    eng = _run(go())
    fin = eng.finished_in_window
    assert len(fin) == len(prompts)
    for r in fin:
        assert r.arrival_t is not None
        assert len(r.t_tokens) == len(r.out) == NEW
        assert r.arrival_t <= r.t_tokens[0]
        assert all(b >= a for a, b in zip(r.t_tokens, r.t_tokens[1:]))
        assert r.t_finished >= r.t_tokens[-1]
        assert r.ttft_s is not None and r.ttft_s >= 0
        assert len(r.itl_s) == NEW - 1
        assert r.deadline_met is True          # 30s deadline on a toy model
    summ = latency_summary(fin)
    assert summ["n_requests"] == len(prompts)
    for k in ("ttft_p50_ms", "ttft_p99_ms", "itl_p50_ms", "itl_p99_ms"):
        assert summ[k] >= 0.0
    assert summ["slo_attainment"] == 1.0


def test_percentile_nearest_rank():
    s = [10.0, 20.0, 30.0, 40.0]
    assert percentile(s, 50) == 20.0      # ceil(0.5*4) = 2nd smallest
    assert percentile(s, 75) == 30.0
    assert percentile(s, 76) == 40.0
    assert percentile(s, 100) == 40.0
    assert percentile([7.0], 99) == 7.0
    with pytest.raises(ValueError):
        percentile([], 50)
    with pytest.raises(ValueError):
        percentile(s, 0)
    with pytest.raises(ValueError):
        percentile(s, 101)


# =============================================================================
# metrics reset: warmup excision cannot leak stale samples
# =============================================================================
def test_reset_metrics_clears_latency_and_prefix_window(setup):
    """reset_metrics after warmup: finished_in_window, prefix lookup/hit
    counters, COW/preemption/swap accounting all restart at zero, so a
    measurement window reports only its own requests."""
    cfg, model, params, prompts = setup
    eng = _engine(model, params, prefix_cache=True)
    for p in prompts[:3]:
        eng.add_request(p, NEW)
    eng.run()
    assert eng.finished_in_window and eng.prefix.lookups > 0
    eng.reset_metrics()
    assert eng.finished_in_window == []
    assert eng.prefix.lookups == 0 and eng.prefix.hits == 0
    assert eng.prefill_tokens_computed == 0
    assert eng.n_cow_forks == 0
    assert eng.n_preemptions == 0 and eng.n_restores == 0
    assert eng.swap_store.bytes_out == 0 and eng.swap_store.bytes_in == 0
    assert all(v == 0.0 for v in eng.phase.values())
    # the next window sees exactly its own population
    eng.add_request(prompts[3], NEW)
    eng.run()
    fin = eng.finished_in_window
    assert len(fin) == 1
    assert latency_summary(fin)["n_requests"] == 1.0


# =============================================================================
# traffic generators: determinism + shape
# =============================================================================
def test_poisson_times_deterministic():
    a = poisson_times(50.0, 64, seed=3)
    b = poisson_times(50.0, 64, seed=3)
    assert a == b
    assert a != poisson_times(50.0, 64, seed=4)
    assert len(a) == 64
    assert all(t2 > t1 for t1, t2 in zip(a, a[1:]))
    with pytest.raises(ValueError):
        poisson_times(0.0, 4)


def test_on_off_times_respect_burst_windows():
    on_s, off_s = 0.2, 1.0
    a = on_off_times(100.0, 50, on_s=on_s, off_s=off_s, seed=7)
    assert a == on_off_times(100.0, 50, on_s=on_s, off_s=off_s, seed=7)
    assert all(t2 >= t1 for t1, t2 in zip(a, a[1:]))
    period = on_s + off_s
    for t in a:
        assert (t % period) <= on_s + 1e-9    # never inside an off gap
    with pytest.raises(ValueError):
        on_off_times(10.0, 4, on_s=0.0, off_s=1.0)


def test_synthesize_deterministic_and_class_tagged():
    classes = [TrafficClass("i", (4, 8), (2, 4), priority=0,
                            deadline_s=0.1, weight=2.0),
               TrafficClass("b", (8, 16), (8, 12), priority=1)]
    times = poisson_times(20.0, 40, seed=1)
    a = synthesize(times, classes, vocab=128, seed=9)
    b = synthesize(times, classes, vocab=128, seed=9)
    assert len(a) == 40
    for x, y in zip(a, b):
        assert x.t == y.t and x.cls == y.cls
        np.testing.assert_array_equal(x.prompt, y.prompt)
    names = {x.cls for x in a}
    assert names <= {"i", "b"}
    for x in a:
        c = classes[0] if x.cls == "i" else classes[1]
        assert c.prompt_len[0] <= len(x.prompt) < c.prompt_len[1]
        assert c.max_new_tokens[0] <= x.max_new_tokens < c.max_new_tokens[1]
        assert x.priority == c.priority and x.deadline_s == c.deadline_s
        assert x.prompt.min() >= 1
    with pytest.raises(ValueError):
        synthesize(times, [], vocab=128)


def test_trace_save_load_round_trip(tmp_path):
    classes = [TrafficClass("i", (4, 8), (2, 4), deadline_s=0.25),
               TrafficClass("b", (8, 16), (8, 12), priority=1)]
    arrivals = synthesize(poisson_times(20.0, 16, seed=2), classes,
                          vocab=64, seed=2)
    path = str(tmp_path / "trace.jsonl")
    save_trace(path, arrivals)
    back = load_trace(path)
    assert len(back) == len(arrivals)
    for x, y in zip(sorted(arrivals, key=lambda a: a.t), back):
        assert x.t == y.t and x.max_new_tokens == y.max_new_tokens
        assert x.priority == y.priority and x.deadline_s == y.deadline_s
        assert x.cls == y.cls
        np.testing.assert_array_equal(x.prompt, y.prompt)


def test_load_trace_reports_bad_line(tmp_path):
    path = tmp_path / "bad.jsonl"
    path.write_text(json.dumps({"t": 0.0, "prompt": [1],
                                "max_new_tokens": 2}) + "\n"
                    + "{not json}\n")
    with pytest.raises(ValueError, match="bad.jsonl:2"):
        load_trace(str(path))


def test_replay_serves_a_trace(setup):
    """End to end: a synthesized workload replayed (speedup=inf) against
    the async server completes every request with its own length."""
    cfg, model, params, _ = setup
    classes = [TrafficClass("i", (4, 10), (2, 5), deadline_s=10.0)]
    arrivals = synthesize(poisson_times(50.0, 6, seed=5), classes,
                          vocab=cfg.vocab, seed=5)

    async def go():
        eng = _engine(model, params, new=8, max_len=24)
        async with AsyncServer(eng) as srv:
            return await replay(srv, arrivals, speedup=float("inf"))

    streams, rejected = _run(go())
    assert rejected == [] and len(streams) == len(arrivals)
    for i, a in enumerate(sorted(arrivals, key=lambda a: a.t)):
        assert len(streams[i]._out) == a.max_new_tokens


def test_replay_rejects_speedup_zero(setup):
    cfg, model, params, _ = setup

    async def go():
        eng = _engine(model, params)
        async with AsyncServer(eng) as srv:
            with pytest.raises(ValueError, match="speedup"):
                await replay(srv, [Arrival(t=0.0,
                                           prompt=np.ones(4, np.int32),
                                           max_new_tokens=2)], speedup=0.0)

    _run(go())


# =============================================================================
# launch helpers (zero-decode guards + --arrival grammar)
# =============================================================================
def test_safe_rate_zero_window():
    assert safe_rate(10, 2.0) == 5.0
    assert safe_rate(10, 0.0) == 0.0     # --new-tokens 1: no decode window
    assert safe_rate(0, 0.0) == 0.0
    assert safe_rate(10, -1.0) == 0.0


def test_parse_arrival_grammar():
    assert parse_arrival("batch") == ("batch", ())
    assert parse_arrival("poisson:12.5") == ("poisson", (12.5,))
    assert parse_arrival("onoff:60:0.15:2.0") == ("onoff", (60.0, 0.15, 2.0))
    kind, (path,) = parse_arrival("trace:/tmp/a:b.jsonl")
    assert kind == "trace" and path == "/tmp/a:b.jsonl"
    for bad in ("poisson", "poisson:x", "onoff:60", "burst:1", "trace"):
        with pytest.raises(ValueError, match="bad --arrival"):
            parse_arrival(bad)
