"""Continuous-batching serve equivalence + scheduler/page accounting.

The load-bearing guarantee: mixed-length requests served through the
slot-based continuous-batching engine over the paged MX KV cache produce
token-for-token the same greedy outputs as each request served alone
through the contiguous-cache engine (temperature=0, fixed seed) — for all
six MX element formats x both conversion modes (uniform policies), for
mixed per-role policies (INT8 keys + E2M1 values), and for the
unquantized cache.
"""
import jax
import numpy as np
import pytest

from repro.core.formats import ALL_FORMATS
from repro.models import Model, load_reduced
from repro.models.config import QuantPolicy, QuantSpec
from repro.serve import (BlockManager, ContinuousBatchingEngine,
                         GenerationConfig, Request, RequestState, Scheduler,
                         ServeEngine, pages_needed)

MIXED = QuantPolicy.parse("kv_key=int8@32:ocp,kv_value=e2m1@32:ocp")

# >= 8 requests, mixed lengths (3 distinct values to bound jit retraces)
LENS = [4, 9, 14, 4, 9, 14, 9, 4]
NEW = 4
PAGE = 8
SLOTS = 4          # < len(LENS): admission + eviction + slot reuse on path


def _prompts(vocab, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, vocab, size=n).astype(np.int32) for n in LENS]


def _serve_both(cfg):
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompts = _prompts(cfg.vocab)
    eng = ContinuousBatchingEngine(model, params, max_slots=SLOTS,
                                   page_size=PAGE,
                                   max_len=max(LENS) + NEW + 1)
    rids = [eng.add_request(p, NEW) for p in prompts]
    outs = eng.run()
    solos = {}
    for p in prompts:
        n = p.shape[0]
        if n not in solos:
            solos[n] = ServeEngine(model, params, max_len=n + NEW + 2)
        ref = solos[n].generate({"tokens": np.asarray(p)[None, :]},
                                GenerationConfig(max_new_tokens=NEW))[0]
        yield outs[rids.pop(0)], ref


@pytest.mark.parametrize("mode", ["ocp", "paper"])
@pytest.mark.parametrize("fmt", [f.name for f in ALL_FORMATS])
def test_continuous_matches_solo_all_formats(fmt, mode):
    """Token-identical to solo contiguous serving — all six MX formats x
    both modes, K and V set to the same spec through the policy (the
    uniform path of the pre-spec engine)."""
    kv = QuantSpec(fmt, mode)
    cfg = load_reduced("chatglm3_6b",
                       mx=QuantPolicy(kv_key=kv, kv_value=kv))
    for got, ref in _serve_both(cfg):
        np.testing.assert_array_equal(got, ref)


def test_continuous_matches_solo_fp_cache():
    """The paged pool also serves the unquantized cache (dense pages)."""
    cfg = load_reduced("chatglm3_6b")
    for got, ref in _serve_both(cfg):
        np.testing.assert_array_equal(got, ref)


def test_continuous_matches_solo_flash_kernel():
    """attn_impl=flash routes decode through the paged Pallas kernel."""
    cfg = load_reduced("chatglm3_6b", mx=QuantPolicy.parse("kv=int8@32:ocp"),
                       attn_impl="flash")
    for got, ref in _serve_both(cfg):
        np.testing.assert_array_equal(got, ref)


# =============================================================================
# mixed per-role policies (INT8 keys / E2M1 values)
# =============================================================================
def test_continuous_matches_solo_mixed_roles():
    """INT8 keys + E2M1 values end-to-end: the paged continuous engine is
    token-identical to solo contiguous serving under the same policy."""
    cfg = load_reduced("chatglm3_6b", mx=MIXED)
    for got, ref in _serve_both(cfg):
        np.testing.assert_array_equal(got, ref)


def test_continuous_matches_solo_mixed_roles_flash():
    """Mixed-role policy through the paged Pallas kernel (per-role pool
    layouts resolved at the HBM->VMEM boundary)."""
    cfg = load_reduced("chatglm3_6b", mx=MIXED, attn_impl="flash")
    for got, ref in _serve_both(cfg):
        np.testing.assert_array_equal(got, ref)


def test_mixed_role_pool_sized_per_role():
    """The E2M1 value pool is bit-packed to half the bytes of the INT8 key
    pool; same scale layout."""
    cfg = load_reduced("chatglm3_6b", mx=MIXED)
    model = Model(cfg)
    pool = jax.eval_shape(lambda: model.init_paged_cache(8, 8))
    kc = pool["layers"]["kc_pages"]
    vc = pool["layers"]["vc_pages"]
    assert vc.shape[-1] * 2 == kc.shape[-1]
    assert pool["layers"]["ks_pages"].shape \
        == pool["layers"]["vs_pages"].shape


def test_mla_rejects_paged():
    cfg = load_reduced("deepseek_v2_236b")
    model = Model(cfg)
    with pytest.raises(NotImplementedError):
        model.init_paged_cache(8, 8)


# =============================================================================
# scheduler / page accounting (no model)
# =============================================================================
def test_block_manager_trash_page_reserved():
    bm = BlockManager(num_pages=9, page_size=8, max_slots=2,
                      max_pages_per_slot=4)
    assert bm.free_pages == 8
    assert bm.allocate(0, 4) and bm.allocate(1, 4)
    owned = set(bm.tables[0]) | set(bm.tables[1])
    assert 0 not in owned                     # trash page never handed out
    assert not bm.allocate(0, 1)              # pool and row exhausted
    bm.free_slot(0)
    assert bm.free_pages == 4
    assert (bm.tables[0] == 0).all()          # row re-points at trash


def test_scheduler_admission_eviction_cycle():
    bm = BlockManager(num_pages=5, page_size=8, max_slots=2,
                      max_pages_per_slot=2)
    sch = Scheduler(max_slots=2, blocks=bm)
    reqs = [Request(rid=i, prompt=np.zeros(9, np.int32), max_new_tokens=4)
            for i in range(3)]                # each needs 2 pages total
    for r in reqs:
        sch.submit(r)
    first = sch.admit()
    assert [r.rid for r in first] == [0, 1]   # FIFO; pool fits exactly 2
    assert sch.admit() == []                  # no slot/pages for rid 2
    assert reqs[2].state is RequestState.WAITING
    sch.evict(reqs[0])
    assert reqs[0].state is RequestState.FINISHED
    second = sch.admit()
    assert [r.rid for r in second] == [2]     # recycled slot + pages
    assert reqs[2].state is RequestState.RUNNING
    assert reqs[2].slot != -1


def test_scheduler_reserves_growth_pages():
    """Admission must not hand a later request the pages a running request
    is still entitled to grow into."""
    bm = BlockManager(num_pages=4, page_size=8, max_slots=2,
                      max_pages_per_slot=2)
    sch = Scheduler(max_slots=2, blocks=bm)
    # rid 0: 1-page prompt that will grow into a 2nd page
    sch.submit(Request(rid=0, prompt=np.zeros(6, np.int32),
                       max_new_tokens=8))
    # rid 1: needs 2 pages up front
    sch.submit(Request(rid=1, prompt=np.zeros(9, np.int32),
                       max_new_tokens=4))
    assert [r.rid for r in sch.admit()] == [0]
    # 2 pages free, but one is reserved for rid 0's growth
    assert bm.free_pages == 2
    assert sch.admit() == []
    assert bm.ensure(0, 14)                   # rid 0 grows into its reserve


def test_oversized_request_rejected():
    cfg = load_reduced("chatglm3_6b")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = ContinuousBatchingEngine(model, params, max_slots=2,
                                   page_size=PAGE, max_len=16)
    with pytest.raises(ValueError):
        eng.add_request(np.zeros(14, np.int32), max_new_tokens=8)
    with pytest.raises(ValueError):
        eng.add_request(np.zeros(4, np.int32), max_new_tokens=0)


def test_pages_needed():
    assert pages_needed(0, 8) == 1
    assert pages_needed(1, 8) == 1
    assert pages_needed(8, 8) == 1
    assert pages_needed(9, 8) == 2
